//! Quickstart: load the AOT artifacts, run one gradient step and one
//! eval pass, and round-trip a weight matrix through the unified
//! `QuantSpec` / `Quantizer` API — the whole public surface in ~60
//! lines. Any scheme is one parseable string: `pq:k=64,d=8`,
//! `pq:k=256,cb=int8` (§3.3), `int8:per_channel` (Table 10), …
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use quant_noise::model::tensor::Tensor;
use quant_noise::quant::scheme::{QuantSpec, Quantizer};
use quant_noise::runtime::client::Runtime;
use quant_noise::runtime::executable::{BatchInput, ModelSession};
use quant_noise::runtime::manifest::Manifest;
use quant_noise::util::rng::Pcg;

fn main() -> Result<()> {
    quant_noise::util::logging::init();
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;

    // A session owns persistent device buffers for one model's params.
    let (mut sess, params) = ModelSession::new(&rt, &manifest, "lm_tiny")?;
    let meta = sess.meta.clone();
    println!(
        "model lm_tiny: {} params across {} tensors",
        params.total_params(),
        params.len()
    );

    // One Quant-Noise gradient step (proxy noise, p = 0.1).
    let n = meta.batch * meta.seq_len;
    let tokens: Vec<i32> = (0..n).map(|i| (i % meta.vocab) as i32).collect();
    let targets: Vec<i32> = (0..n).map(|i| ((i + 1) % meta.vocab) as i32).collect();
    let keep = vec![1.0f32; meta.n_layers];
    let (loss, grads) = sess.grad(
        "grad_mix",
        &BatchInput::Tokens(&tokens),
        &targets,
        &keep,
        0.1, // noise rate p
        42,  // mask seed
    )?;
    println!("grad step: loss {loss:.4}, {} gradient tensors", grads.len());

    // One eval pass → perplexity.
    let (sum_nll, _) = sess.eval("eval", &BatchInput::Tokens(&tokens), &targets, &keep)?;
    println!("eval: ppl {:.2}", (sum_nll / n as f64).exp());

    // Product-quantize one weight matrix (paper Eq. 1/3) through the
    // unified scheme API: parse a spec, resolve it for the parameter,
    // fit, and read the storage bill off the same object.
    let spec: QuantSpec = "pq:k=64,d=8,iters=8".parse()?;
    let w: &Tensor = params.get("layer00.w1").unwrap();
    let (rows, cols) = w.view2d();
    let info = meta.param("layer00.w1").unwrap().to_param_info(None);
    let quantizer = spec.resolve(&info);
    let qt = quantizer.fit(&w.data, rows, cols, &mut Pcg::new(1))?;
    let bits = quantizer.storage_bits(&info);
    let err = w
        .data
        .iter()
        .zip(&qt.data)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / w.numel() as f64;
    println!(
        "`{spec}` round-trip of layer00.w1: {} -> {bits} bits ({:.1}x), mse/elem {err:.5}",
        w.numel() * 32,
        (w.numel() * 32) as f64 / bits as f64,
    );
    Ok(())
}
