//! Quickstart: load the AOT artifacts, run one gradient step and one
//! eval pass, and round-trip a weight matrix through Product
//! Quantization — the whole public API surface in ~60 lines.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use quant_noise::model::tensor::Tensor;
use quant_noise::quant::pq::{fit, PqConfig};
use quant_noise::runtime::client::Runtime;
use quant_noise::runtime::executable::{BatchInput, ModelSession};
use quant_noise::runtime::manifest::Manifest;
use quant_noise::util::rng::Pcg;

fn main() -> Result<()> {
    quant_noise::util::logging::init();
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;

    // A session owns persistent device buffers for one model's params.
    let (mut sess, params) = ModelSession::new(&rt, &manifest, "lm_tiny")?;
    let meta = sess.meta.clone();
    println!(
        "model lm_tiny: {} params across {} tensors",
        params.total_params(),
        params.len()
    );

    // One Quant-Noise gradient step (proxy noise, p = 0.1).
    let n = meta.batch * meta.seq_len;
    let tokens: Vec<i32> = (0..n).map(|i| (i % meta.vocab) as i32).collect();
    let targets: Vec<i32> = (0..n).map(|i| ((i + 1) % meta.vocab) as i32).collect();
    let keep = vec![1.0f32; meta.n_layers];
    let (loss, grads) = sess.grad(
        "grad_mix",
        &BatchInput::Tokens(&tokens),
        &targets,
        &keep,
        0.1, // noise rate p
        42,  // mask seed
    )?;
    println!("grad step: loss {loss:.4}, {} gradient tensors", grads.len());

    // One eval pass → perplexity.
    let (sum_nll, _) = sess.eval("eval", &BatchInput::Tokens(&tokens), &targets, &keep)?;
    println!("eval: ppl {:.2}", (sum_nll / n as f64).exp());

    // Product-quantize one weight matrix (paper Eq. 1/3).
    let w: &Tensor = params.get("layer00.w1").unwrap();
    let (rows, cols) = w.view2d();
    let pq = fit(&w.data, rows, cols, &PqConfig { block_size: 8, n_centroids: 64, kmeans_iters: 8, threads: 0 }, &mut Pcg::new(1));
    let err = pq.objective(&w.data) / w.numel() as f64;
    println!(
        "PQ round-trip of layer00.w1: {} -> {} bits ({:.1}x), mse/elem {err:.5}",
        w.numel() * 32,
        pq.storage_bits(),
        (w.numel() * 32) as f64 / pq.storage_bits() as f64,
    );
    Ok(())
}
