//! Vision pipeline (EfficientNet/ImageNet stand-in): train MicroConv on
//! the procedural pattern dataset with Quant-Noise on conv weights
//! (block sizes 4 for 1×1, 9 for dw3×3 per the paper), iPQ-quantize,
//! report Table-1-shaped rows.
//!
//!     make artifacts && cargo run --release --example vision_quantnoise

use anyhow::Result;
use quant_noise::bench_harness::common::Workbench;
use quant_noise::bench_harness::e2e;

fn main() -> Result<()> {
    quant_noise::util::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let scale = args
        .iter()
        .position(|a| a == "--scale")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    let mut wb = Workbench::new(std::path::Path::new("artifacts"))?;
    wb.step_scale = scale;
    e2e::run(&wb, "img_tiny", None)
}
