//! Vision pipeline (EfficientNet/ImageNet stand-in): train MicroConv on
//! the procedural pattern dataset with Quant-Noise on conv weights
//! (block sizes 4 for 1×1, 9 for dw3×3 per the paper; override every
//! conv family at once with `pq:...,block.conv=9`), iPQ-quantize,
//! report Table-1-shaped rows.
//!
//! Runs out of the box on the checked-in interpreter fixture — the
//! interpreter executes the ConvNet op set (convolution, reverse,
//! reduce-window) directly:
//!
//!     cargo run --release --example vision_quantnoise
//!
//! With `make artifacts` the full artifact zoo is used instead.

use std::path::Path;

use anyhow::Result;
use quant_noise::bench_harness::common::Workbench;
use quant_noise::bench_harness::e2e;

fn main() -> Result<()> {
    quant_noise::util::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let scale = args
        .iter()
        .position(|a| a == "--scale")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    let artifacts = Path::new("artifacts");
    let mut wb = if artifacts.join("manifest.json").exists() {
        Workbench::new(artifacts)?
    } else {
        // checked-in interpreter fixture: zero-setup runs, works from
        // the repo root or from rust/
        let fixture = ["rust/tests/fixtures/interp", "tests/fixtures/interp"]
            .into_iter()
            .map(Path::new)
            .find(|d| d.join("manifest.json").exists())
            .ok_or_else(|| anyhow::anyhow!("no artifacts/ and no checked-in fixture found"))?;
        Workbench::at(fixture, Path::new("target/qn-example-cache"))?
    };
    wb.step_scale = scale;
    e2e::run(&wb, "img_tiny", None)
}
