//! Mini Fig. 3: sweep the Quant-Noise rate p for the proxy noise and
//! report quantized perplexity per point — shows the paper's
//! "moderate p beats both extremes" shape on the tiny LM.
//!
//!     make artifacts && cargo run --release --example noise_rate_ablation -- --scale 0.25

use anyhow::Result;
use quant_noise::bench_harness::common::Workbench;
use quant_noise::bench_harness::figures;

fn main() -> Result<()> {
    quant_noise::util::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let scale = args
        .iter()
        .position(|a| a == "--scale")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    let mut wb = Workbench::new(std::path::Path::new("artifacts"))?;
    wb.step_scale = scale;
    figures::fig3(&wb, "lm_tiny")?;
    Ok(())
}
