//! End-to-end LM driver (the repo's headline validation): train the
//! Transformer LM on the synthetic corpus with Quant-Noise, log the
//! loss curve, iPQ-quantize, and compare against the no-noise baseline
//! at the same compressed size.
//!
//!     make artifacts && cargo run --release --example lm_quantnoise
//!     # quick smoke: cargo run --release --example lm_quantnoise -- --scale 0.1

use anyhow::Result;
use quant_noise::bench_harness::common::Workbench;
use quant_noise::bench_harness::e2e;

fn main() -> Result<()> {
    quant_noise::util::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let scale = args
        .iter()
        .position(|a| a == "--scale")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    let mut wb = Workbench::new(std::path::Path::new("artifacts"))?;
    wb.step_scale = scale;
    e2e::run(&wb, "lm_tiny", None)
}
