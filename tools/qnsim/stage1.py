"""Verify statistical test assertions from rng.rs, corpus.rs, observer.rs, optim.rs."""
import math
import numpy as np
from pcg import Pcg

ok = []
bad = []


def check(name, cond, detail=""):
    (ok if cond else bad).append((name, detail))
    print(("PASS " if cond else "FAIL ") + name + (" — " + str(detail) if detail else ""))


# ---------------- rng.rs ----------------
a, b = Pcg(1), Pcg(2)
same = sum(1 for _ in range(64) if a.next_u32() == b.next_u32())
check("rng::seeds_differ", same < 4, same)

r = Pcg(7)
check("rng::f32_in_unit_interval", all(0.0 <= r.next_f32() < 1.0 for _ in range(10000)))

r = Pcg(3)
mean = sum(r.next_f64() for _ in range(100_000)) / 100_000
check("rng::uniform_mean", abs(mean - 0.5) < 0.01, mean)

r = Pcg(9)
counts = [0] * 5
for _ in range(50_000):
    counts[r.below(5)] += 1
check("rng::below_unbiased_small", all(abs(c - 10_000) < 500 for c in counts), counts)

r = Pcg(11)
xs = np.array([r.next_normal() for _ in range(100_000)], dtype=np.float64)
m, v = xs.mean(), ((xs - xs.mean()) ** 2).mean()
check("rng::normal_moments", abs(m) < 0.02 and abs(v - 1.0) < 0.03, (m, v))

root = Pcg(1)
sa, sb = root.split(1), root.split(2)
same = sum(1 for _ in range(64) if sa.next_u32() == sb.next_u32())
check("rng::split_streams_independent", same < 4, same)

r = Pcg(5)
idx = r.sample_indices(100, 30)
check("rng::sample_indices_distinct", len(set(idx)) == 30 and all(i < 100 for i in idx))

# ---------------- corpus.rs ----------------
def zipf_weights(vocab, alpha):
    w = [1.0 / ((t + 1) ** alpha) for t in range(vocab)]
    s = sum(w)
    return [x / s for x in w]


def sample_from(weights, rng):
    t = rng.next_f64()
    for i, w in enumerate(weights):
        t -= w
        if t <= 0.0:
            return i
    return len(weights) - 1


def corpus_generate(vocab, n_tokens, seed):
    rng = Pcg(seed)
    unigram = zipf_weights(vocab - 1, 1.2)
    markov_p, doc_len = 0.7, 256
    successors = [[1 + sample_from(unigram, rng) for _ in range(4)] for _ in range(vocab)]
    tokens = []
    prev = 1
    for _ in range(n_tokens):
        if rng.next_f64() < 1.0 / doc_len:
            t = 0
        elif rng.next_f64() < markov_p:
            t = successors[prev][rng.below(4)]
        else:
            t = 1 + sample_from(unigram, rng)
        tokens.append(t)
        prev = max(t, 1)
    return tokens


def unigram_entropy(tokens, vocab):
    counts = np.bincount(tokens, minlength=vocab)
    n = len(tokens)
    p = counts[counts > 0] / n
    return float(-(p * np.log(p)).sum())


toks = corpus_generate(64, 5000, 7)
toks2 = corpus_generate(64, 5000, 7)
check("corpus::deterministic_and_in_vocab", toks == toks2 and all(0 <= t < 64 for t in toks))

toks = corpus_generate(128, 200_000, 1)
uni = unigram_entropy(toks, 128)
pair = {}
prev_counts = [0] * 128
for x, y in zip(toks, toks[1:]):
    pair[(x, y)] = pair.get((x, y), 0) + 1
    prev_counts[x] += 1
n = len(toks) - 1
cond = sum(-(c / n) * math.log(c / prev_counts[p]) for (p, _), c in pair.items())
check("corpus::has_markov_structure", cond < uni * 0.8, (cond, uni))

toks = corpus_generate(256, 100_000, 2)
counts = np.bincount(toks, minlength=256)
head, tail = counts[1:17].sum(), counts[128:].sum()
check("corpus::zipf_head_heavy", head > tail * 3, (head, tail))

small = unigram_entropy(corpus_generate(128, 50_000, 9), 128)
large = unigram_entropy(corpus_generate(128, 200_000, 9), 128)
check("corpus::stats_stable (data_integration)", abs(small - large) < 0.2, (small, large))


def make_cls_dataset(n, seq_len, vocab, n_classes, seed):
    rng = Pcg(seed)
    tokens, labels = [], []
    for _ in range(n):
        label = rng.below(n_classes)
        seq = [2 * n_classes + 1 + rng.below(vocab - 2 * n_classes - 1) for _ in range(seq_len)]
        n_markers = max(seq_len // 5, 2)
        for _ in range(n_markers):
            pos = rng.below(seq_len)
            which = rng.below(2)
            seq[pos] = 1 + 2 * label + which
        tokens.extend(seq)
        labels.append(label)
    return tokens, labels


tokens, labels = make_cls_dataset(512, 32, 256, 4, 3)
okm = True
for i in range(64):
    l = labels[i]
    seq = tokens[i * 32:(i + 1) * 32]
    if not any(t in (1 + 2 * l, 2 + 2 * l) for t in seq):
        okm = False
per = [labels.count(c) for c in range(4)]
check("corpus::cls_learnable_and_balanced", okm and all(c > 64 for c in per), per)

# ---------------- observer.rs ----------------
F32 = np.float32


def from_range(lo, hi, bits):
    qmax = F32((1 << bits) - 1)
    scale = F32((F32(hi) - F32(lo)) / qmax)
    if not (scale > 0.0):
        scale = F32(1.0)
    zero = F32(np.round(F32(lo) / scale))
    return scale, zero, bits


def roundtrip_vals(x, scale, zero, bits):
    qmax = F32((1 << bits) - 1)
    q = np.clip(np.round(x / scale) - zero, F32(0.0), qmax).astype(np.float32)
    return ((q + zero) * scale).astype(np.float32)


def quant_mse(data, qp):
    scale, zero, bits = qp
    rt = roundtrip_vals(np.asarray(data, dtype=np.float32), scale, zero, bits)
    e = (np.asarray(data, dtype=np.float64) - rt.astype(np.float64))
    return float((e * e).mean()) if len(data) else 0.0


def heavy_tail(seed, n):
    r = Pcg(seed)
    out = []
    for i in range(n):
        v = r.next_normal()
        out.append(F32(v * F32(30.0)) if i % 97 == 0 else v)
    return np.array(out, dtype=np.float32)


class Hist:
    def __init__(self, n_bins):
        self.bins = np.zeros(n_bins)
        self.lo = F32(0.0)
        self.hi = F32(0.0)
        self.seen = False
        self.n = n_bins

    def observe(self, data):
        data = np.asarray(data, dtype=np.float32)
        if len(data) == 0:
            return
        lo, hi = F32(data.min()), F32(data.max())
        if not self.seen:
            self.lo = lo
            self.hi = max(hi, F32(lo + F32(1e-12)))
            self.seen = True
        elif lo < self.lo or hi > self.hi:
            self.rebin(min(self.lo, lo), max(self.hi, hi))
        width = max(F32(self.hi - self.lo), F32(1e-12))
        b = ((data - self.lo) / width * F32(self.n)).astype(np.int64)
        b = np.minimum(np.maximum(b, 0), self.n - 1)  # as usize saturates at 0 for negatives
        for x in b:
            self.bins[x] += 1.0

    def rebin(self, new_lo, new_hi):
        new_bins = np.zeros(self.n)
        old_w = max(F32(self.hi - self.lo), F32(1e-12)) / F32(self.n)
        new_w = max(F32(new_hi - new_lo), F32(1e-12)) / F32(self.n)
        for i, mass in enumerate(self.bins):
            if mass == 0.0:
                continue
            center = F32(self.lo + F32(i + 0.5) * old_w)
            bidx = min(int(F32((center - new_lo) / new_w)), self.n - 1)
            new_bins[bidx] += mass
        self.bins = new_bins
        self.lo, self.hi = F32(new_lo), F32(new_hi)

    def l2_error(self, clip_lo, clip_hi, bits):
        qp = from_range(clip_lo, clip_hi, bits)
        bin_w = max(float(self.hi - self.lo) / self.n, 1e-18)
        err = 0.0
        centers = []
        masses = []
        for i, mass in enumerate(self.bins):
            if mass == 0.0:
                continue
            centers.append(F32(float(self.lo) + (i + 0.5) * bin_w))
            masses.append(mass)
        if not centers:
            return 0.0
        c = np.array(centers, dtype=np.float32)
        rt = roundtrip_vals(c, *qp)
        e = c.astype(np.float64) - rt.astype(np.float64)
        return float((np.array(masses) * e * e).sum())

    def best_range(self, bits):
        if not self.seen:
            return (0.0, 0.0)
        width = F32(self.hi - self.lo)
        best = (self.lo, self.hi)
        best_err = self.l2_error(self.lo, self.hi, bits)
        steps = 64
        for i in range(steps):
            for j in range(steps):
                if i + j >= steps:
                    break
                lo = F32(self.lo + width * F32(i / steps) * F32(0.5))
                hi = F32(self.hi - width * F32(j / steps) * F32(0.5))
                if hi <= lo:
                    continue
                err = self.l2_error(lo, hi, bits)
                if err < best_err:
                    best_err = err
                    best = (lo, hi)
        return best

    def qparams(self, bits):
        lo, hi = self.best_range(bits)
        return from_range(lo, hi, bits)


data = heavy_tail(1, 20_000)
mm_qp = from_range(data.min(), data.max(), 4)
h = Hist(2048)
h.observe(data)
mse_mm = quant_mse(data, mm_qp)
mse_h = quant_mse(data, h.qparams(4))
check("observer::histogram_beats_minmax_on_outliers", mse_h < mse_mm, (mse_h, mse_mm))

r = Pcg(2)
data = np.array([F32(r.next_f32() * F32(2.0) - F32(1.0)) for _ in range(10_000)], dtype=np.float32)
h = Hist(2048)
h.observe(data)
mse_h = quant_mse(data, h.qparams(8))
mse_mm = quant_mse(data, from_range(data.min(), data.max(), 8))
check("observer::histogram_matches_minmax_on_uniform", mse_h <= mse_mm * 2.0 + 1e-12, (mse_h, mse_mm))

data = heavy_tail(3, 5_000)
h = Hist(512)
h.observe(data)
lo, hi = h.best_range(8)
check("observer::best_range_within_observed", lo >= h.lo - 1e-6 and hi <= h.hi + 1e-6 and lo < hi)

# observers_agree_on_clean_data (quant_integration): weight(7,64,64) = normal*0.1
r = Pcg(7)
data = np.array([F32(r.next_normal() * F32(0.1)) for _ in range(64 * 64)], dtype=np.float32)
h = Hist(2048)
h.observe(data)
e_h = quant_mse(data, h.qparams(8))
e_mm = quant_mse(data, from_range(data.min(), data.max(), 8))
check("quant_integration::observers_agree_on_clean_data", e_h <= e_mm * 2.0, (e_h, e_mm))

# scalar::per_channel_beats_or_matches_per_tensor
r = Pcg(3)
data = np.array([F32(r.next_normal() * F32(2.0)) for _ in range(256)], dtype=np.float32)
data[:128] = (data[:128] * F32(100.0)).astype(np.float32)
qp = from_range(data.min(), data.max(), 4)
mse_tensor = quant_mse(data, qp)
per_ch = data.copy()
for row in range(2):
    seg = per_ch[row * 128:(row + 1) * 128]
    qpr = from_range(seg.min(), seg.max(), 4)
    per_ch[row * 128:(row + 1) * 128] = roundtrip_vals(seg, *qpr)
mse_channel = float(((data.astype(np.float64) - per_ch.astype(np.float64)) ** 2).mean())
check("scalar::per_channel_beats_per_tensor", mse_channel < mse_tensor, (mse_channel, mse_tensor))

# quantize.rs per_channel_beats_per_tensor_on_scaled_rows uses tiny_params (seed 3) — analogous, skip.

# ---------------- optim.rs convergence ----------------
def sgd_run(x0, momentum, nesterov, lr, iters):
    x = np.array([x0, -x0], dtype=np.float32)
    v = np.zeros(2, dtype=np.float32)
    for _ in range(iters):
        g = x.copy()
        v = (v * F32(momentum) - F32(lr) * g).astype(np.float32)
        if nesterov:
            x = (x + F32(momentum) * v - F32(lr) * g).astype(np.float32)
        else:
            x = (x + v).astype(np.float32)
    return np.abs(x).max()


check("optim::sgd_converges", sgd_run(5.0, 0.9, True, 0.05, 200) < 1e-2,
      sgd_run(5.0, 0.9, True, 0.05, 200))


def adam_run(x0, iters):
    x = np.array([x0, -x0], dtype=np.float32)
    m = np.zeros(2, dtype=np.float32)
    v = np.zeros(2, dtype=np.float32)
    b1, b2, eps, lr = F32(0.9), F32(0.98), F32(1e-8), F32(0.05)
    for t in range(1, iters + 1):
        g = x.copy()
        m = (b1 * m + (F32(1.0) - b1) * g).astype(np.float32)
        v = (b2 * v + (F32(1.0) - b2) * g * g).astype(np.float32)
        bc1 = F32(1.0) - F32(np.float32(b1) ** t)
        bc2 = F32(1.0) - F32(np.float32(b2) ** t)
        mh = (m / bc1).astype(np.float32)
        vh = (v / bc2).astype(np.float32)
        x = (x - lr * mh / (np.sqrt(vh) + eps)).astype(np.float32)
    return np.abs(x).max()


check("optim::adam_converges", adam_run(3.0, 500) < 1e-2, adam_run(3.0, 500))

print()
print(f"{len(ok)} pass, {len(bad)} FAIL")
for name, d in bad:
    print("  FAIL:", name, d)
