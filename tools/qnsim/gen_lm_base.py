#!/usr/bin/env python3
"""Generate the paper-scale `lm_base`-shaped benchmark HLO.

Emits a self-contained HLO-text module — a 12-layer, 1024-dim residual
MLP stack with an explicit hand-derived backward pass — shaped like the
lm_base config (ROADMAP item 1's "RoBERTa-ish dims"): per layer one
[B,D]x[D,D] forward dot, a relu/scale/residual elementwise chain, and in
the backward sweep the two transposed dots (dW = xT.dy, dx = dy.wT) plus
the select/scale chains the grad entry lowers to. Weights are runtime
parameters (the bench synthesizes values); no training and no JAX are
needed, so `make fixture` can regenerate the file anywhere.

The module is exactly the workload the compiled-tier kernels target:
36 blocked [batch][free][k] dots at 1024-dim and one elementwise chain
per layer per direction, so `benches/interp_step.rs` uses it to record
the paper-scale grad-step wall clock and the `chain_speedup_grad_1t` /
`dot_tile_speedup` fields of BENCH_interp.json.

Usage: python3 tools/qnsim/gen_lm_base.py \
           [--config python/configs/lm_base.json] \
           [--out rust/benches/fixtures/lm_base.grad.hlo.txt]

Validation: tools/qnsim/plan_mirror.py runs this module through the
reference and fused mirrors and asserts bit-identity + chain census.
"""

import argparse
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(os.path.dirname(HERE))


def generate(batch, dim, layers):
    B, D, L = batch, dim, layers
    lines = []
    counter = [4]  # sum.1 region uses .1-.4

    def fresh(prefix):
        counter[0] += 1
        return f"{prefix}.{counter[0]}"

    def emit(prefix, shape, expr):
        name = fresh(prefix)
        lines.append(f"  {name} = {shape} {expr}")
        return name

    mat = f"f32[{D},{D}]{{1,0}}"
    vec = f"f32[{D}]{{0}}"
    act = f"f32[{B},{D}]{{1,0}}"
    actT = f"f32[{D},{B}]{{1,0}}"
    pred = f"pred[{B},{D}]{{1,0}}"

    header = (
        "HloModule lm_base_grad\n"
        "\n"
        "sum.1 {\n"
        "  a.2 = f32[] parameter(0)\n"
        "  b.3 = f32[] parameter(1)\n"
        "  ROOT add.4 = f32[] add(a.2, b.3)\n"
        "}\n"
        "\n"
    )

    x0 = fresh("x")
    lines.append(f"  {x0} = {act} parameter(0)")
    ws, bs = [], []
    for l in range(L):
        w = fresh("w")
        lines.append(f"  {w} = {mat} parameter({1 + 2 * l})")
        b = fresh("b")
        lines.append(f"  {b} = {vec} parameter({2 + 2 * l})")
        ws.append(w)
        bs.append(b)

    c0 = emit("c0", "f32[]", "constant(0)")
    c1 = emit("c1", "f32[]", "constant(1)")
    ch = emit("ch", "f32[]", "constant(0.5)")

    # ---- forward: x_{l+1} = x_l + 0.5*relu(x_l.w_l + b_l) ----
    xs = [x0]       # layer inputs
    hbs, preds = [], []   # pre-activations + relu masks (reused in bwd)
    x = x0
    for l in range(L):
        h = emit("dot", act, f"dot({x}, {ws[l]}), "
                 "lhs_contracting_dims={1}, rhs_contracting_dims={0}")
        bb = emit("bcast", act, f"broadcast({bs[l]}), dimensions={{1}}")
        hb = emit("add", act, f"add({h}, {bb})")
        zero = emit("bcast", act, f"broadcast({c0}), dimensions={{}}")
        p = emit("compare", pred, f"compare({hb}, {zero}), direction=GT")
        r = emit("select", act, f"select({p}, {hb}, {zero})")
        half = emit("bcast", act, f"broadcast({ch}), dimensions={{}}")
        s = emit("multiply", act, f"multiply({r}, {half})")
        x = emit("add", act, f"add({s}, {x})")
        xs.append(x)
        hbs.append(hb)
        preds.append(p)

    # ---- loss = sum(x_L) ----
    loss = emit("reduce", "f32[]", f"reduce({x}, {c0}), dimensions={{0,1}}, "
                "to_apply=sum.1")

    # ---- backward sweep ----
    g = emit("bcast", act, f"broadcast({c1}), dimensions={{}}")  # d loss/d x_L
    gw_total, gb_total = None, None
    for l in reversed(range(L)):
        half = emit("bcast", act, f"broadcast({ch}), dimensions={{}}")
        dr = emit("multiply", act, f"multiply({g}, {half})")
        zero = emit("bcast", act, f"broadcast({c0}), dimensions={{}}")
        dhb = emit("select", act, f"select({preds[l]}, {dr}, {zero})")
        db = emit("reduce", vec, f"reduce({dhb}, {c0}), dimensions={{0}}, "
                  "to_apply=sum.1")
        xT = emit("transpose", actT, f"transpose({xs[l]}), dimensions={{1,0}}")
        dW = emit("dot", mat, f"dot({xT}, {dhb}), "
                  "lhs_contracting_dims={1}, rhs_contracting_dims={0}")
        wT = emit("transpose", mat, f"transpose({ws[l]}), dimensions={{1,0}}")
        dx = emit("dot", act, f"dot({dhb}, {wT}), "
                  "lhs_contracting_dims={1}, rhs_contracting_dims={0}")
        g = emit("add", act, f"add({dx}, {g})")  # residual skip path
        gw_total = dW if gw_total is None else emit(
            "add", mat, f"add({gw_total}, {dW})")
        gb_total = db if gb_total is None else emit(
            "add", vec, f"add({gb_total}, {db})")

    root = fresh("tuple")
    lines.append(
        f"  ROOT {root} = (f32[], {mat}, {vec}) "
        f"tuple({loss}, {gw_total}, {gb_total})"
    )
    entry = f"ENTRY main.{counter[0] + 1} {{\n" + "\n".join(lines) + "\n}\n"
    return header + entry


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--config",
                    default=os.path.join(REPO, "python/configs/lm_base.json"))
    ap.add_argument("--out",
                    default=os.path.join(
                        REPO, "rust/benches/fixtures/lm_base.grad.hlo.txt"))
    args = ap.parse_args()
    with open(args.config) as f:
        cfg = json.load(f)
    text = generate(cfg["batch"], cfg["d_model"], cfg["n_layers"])
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    n_instr = text.count(" = ")
    print(f"wrote {args.out}: d_model={cfg['d_model']} "
          f"n_layers={cfg['n_layers']} batch={cfg['batch']} "
          f"({n_instr} instructions)")


if __name__ == "__main__":
    main()
