"""Python mirror of the planned rust/src/runtime/interp/ design.

Structure mirrors the Rust 1:1 (cursor parser, flat row-major arrays,
explicit index math in the big ops) so that validating this file against
jax validates the algorithms that will be translated to Rust.
"""
import math
import numpy as np

# --------------------------------------------------------------- shapes ---

ELEM = ("f32", "s32", "u32", "pred")


class Shape:
    __slots__ = ("ty", "dims", "elems")

    def __init__(self, ty, dims=None, elems=None):
        self.ty = ty          # element type, or "tuple"
        self.dims = dims or []
        self.elems = elems    # for tuples: list[Shape]

    def numel(self):
        n = 1
        for d in self.dims:
            n *= d
        return n

    def __repr__(self):
        if self.ty == "tuple":
            return "(" + ", ".join(map(repr, self.elems)) + ")"
        return f"{self.ty}{self.dims}"


class Instr:
    __slots__ = ("name", "opcode", "shape", "operands", "attrs", "literal")

    def __init__(self, name, opcode, shape, operands, attrs, literal):
        self.name = name
        self.opcode = opcode
        self.shape = shape
        self.operands = operands  # indices into computation instrs
        self.attrs = attrs        # dict key -> raw string
        self.literal = literal    # parsed constant payload (flat list) or None


class Computation:
    __slots__ = ("name", "instrs", "root", "n_params", "index")

    def __init__(self, name):
        self.name = name
        self.instrs = []
        self.root = None
        self.n_params = 0
        self.index = {}  # instr name -> position


class Module:
    def __init__(self):
        self.comps = {}   # name -> Computation
        self.entry = None


# --------------------------------------------------------------- parser ---

class Cursor:
    def __init__(self, text):
        self.t = text
        self.i = 0

    def eof(self):
        return self.i >= len(self.t)

    def skip_ws(self, newlines=True):
        while not self.eof():
            c = self.t[self.i]
            if c in " \t" or (newlines and c in "\r\n"):
                self.i += 1
            elif self.t.startswith("/*", self.i):
                j = self.t.find("*/", self.i + 2)
                assert j >= 0, "unterminated comment"
                self.i = j + 2
            else:
                break

    def peek(self):
        return self.t[self.i] if not self.eof() else ""

    def eat(self, s):
        assert self.t.startswith(s, self.i), (
            f"expected {s!r} at ...{self.t[self.i:self.i+40]!r}")
        self.i += len(s)

    def try_eat(self, s):
        if self.t.startswith(s, self.i):
            self.i += len(s)
            return True
        return False

    def ident(self):
        # HLO instruction/computation names: letters digits _ . - %
        j = self.i
        while j < len(self.t) and (self.t[j].isalnum() or self.t[j] in "_.-%"):
            j += 1
        assert j > self.i, f"expected ident at {self.t[self.i:self.i+40]!r}"
        s = self.t[self.i:j]
        self.i = j
        return s.lstrip("%")

    def until_any(self, stops):
        j = self.i
        depth = 0
        while j < len(self.t):
            c = self.t[j]
            if c == "{":
                depth += 1
            elif c == "}":
                if depth == 0:
                    break
                depth -= 1
            elif c in stops and depth == 0:
                break
            j += 1
        s = self.t[self.i:j]
        self.i = j
        return s


def parse_shape(c: Cursor):
    c.skip_ws()
    if c.peek() == "(":
        c.eat("(")
        elems = []
        while True:
            c.skip_ws()
            if c.try_eat(")"):
                break
            elems.append(parse_shape(c))
            c.skip_ws()
            c.try_eat(",")
        return Shape("tuple", elems=elems)
    ty = c.ident()
    assert ty in ELEM, f"unsupported element type {ty}"
    c.eat("[")
    dims = []
    while True:
        c.skip_ws()
        if c.try_eat("]"):
            break
        d = c.until_any(",]").strip()
        if d:
            dims.append(int(d))
        c.try_eat(",")
    # optional layout {1,0} — physical only, ignored (logical row-major)
    c.skip_ws(newlines=False)
    if c.peek() == "{":
        c.eat("{")
        c.until_any("}")  # consume digits/commas
        c.eat("}")
    return Shape(ty, dims=list(dims))


def parse_literal(c: Cursor, shape: Shape):
    """Parse a constant(...) payload into a flat row-major list."""
    def scalar():
        c.skip_ws()
        tok = c.until_any(",})").strip()
        if shape.ty == "f32":
            return float(tok)  # handles inf/-inf/nan/exponents
        if shape.ty == "pred":
            return {"false": 0, "true": 1}[tok]
        return int(tok)

    def nested():
        c.skip_ws()
        if c.try_eat("{"):
            out = []
            while True:
                c.skip_ws()
                if c.try_eat("}"):
                    return out
                out.extend(nested())
                c.skip_ws()
                c.try_eat(",")
        return [scalar()]

    flat = nested()
    assert len(flat) == shape.numel(), (len(flat), shape)
    return flat


def parse_module(text):
    m = Module()
    c = Cursor(text)
    # header line: HloModule <name>[, attr...]  — skip the whole line
    c.skip_ws()
    c.eat("HloModule")
    nl = c.t.find("\n", c.i)
    c.i = nl + 1
    while True:
        c.skip_ws()
        if c.eof():
            break
        is_entry = c.try_eat("ENTRY")
        c.skip_ws()
        name = c.ident()
        c.skip_ws()
        c.eat("{")
        comp = parse_computation(c, name)
        m.comps[name] = comp
        if is_entry:
            m.entry = name
    assert m.entry, "no ENTRY computation"
    return m


def parse_computation(c: Cursor, name):
    comp = Computation(name)
    while True:
        c.skip_ws()
        if c.try_eat("}"):
            break
        is_root = c.try_eat("ROOT")
        c.skip_ws()
        iname = c.ident()
        c.skip_ws()
        c.eat("=")
        shape = parse_shape(c)
        c.skip_ws()
        opcode = c.ident()
        c.eat("(")
        operands = []
        literal = None
        if opcode == "constant":
            literal = parse_literal(c, shape)
            c.skip_ws()
            c.eat(")")
        elif opcode == "parameter":
            num = int(c.until_any(")").strip())
            c.eat(")")
            operands = [("param", num)]
        else:
            while True:
                c.skip_ws()
                if c.try_eat(")"):
                    break
                oname = c.ident()
                assert oname in comp.index, f"{opcode} operand {oname} undefined"
                operands.append(comp.index[oname])
                c.skip_ws()
                c.try_eat(",")
        # attrs: ", key=value" until end of line
        attrs = {}
        while True:
            c.skip_ws(newlines=False)
            if not c.try_eat(","):
                break
            c.skip_ws(newlines=False)
            key = c.ident()
            c.skip_ws(newlines=False)
            c.eat("=")
            c.skip_ws(newlines=False)
            if c.peek() == "{":
                c.eat("{")
                val = "{" + c.until_any("") + "}"
                c.eat("}")
            else:
                val = c.until_any(",\n").strip()
            attrs[key] = val
        if opcode == "parameter":
            pnum = operands[0][1]
            # parameters may appear in any textual order (use order)
            comp.n_params = max(comp.n_params, pnum + 1)
            operands = []
            attrs["parameter_number"] = str(pnum)
        idx = len(comp.instrs)
        comp.instrs.append(Instr(iname, opcode, shape, operands, attrs, literal))
        comp.index[iname] = idx
        if is_root:
            comp.root = idx
    assert comp.root is not None, f"{name}: no ROOT"
    return comp


# ---------------------------------------------------------- attr helpers ---

def int_list(s):
    s = s.strip().lstrip("{").rstrip("}").strip()
    if not s:
        return []
    return [int(x) for x in s.split(",")]


def parse_slice_attr(s):
    # {[0:1], [2:8:2]} -> list of (start, limit, stride)
    out = []
    for part in s.strip().lstrip("{").rstrip("}").split("]"):
        part = part.strip().lstrip(",").strip().lstrip("[")
        if not part:
            continue
        nums = [int(x) for x in part.split(":")]
        if len(nums) == 2:
            nums.append(1)
        out.append(tuple(nums))
    return out


def parse_window(s):
    # {size=3x3 stride=2x2 pad=1_1x1_1 lhs_dilate=2x2 rhs_dilate=2x2} ->
    # per-dim (size, stride, pad_lo, pad_hi, base_dilation, window_dilation);
    # absent fields default to stride=1, pad=0_0, dilations=1 (HLO text
    # omits defaults, e.g. `window={size=16x16}`).
    fields = {}
    for part in s.strip().lstrip("{").rstrip("}").split():
        k, v = part.split("=")
        fields[k] = v.split("x")
    sizes = [int(v) for v in fields["size"]]
    nd = len(sizes)

    def ints(key):
        if key not in fields:
            return [1] * nd
        return [int(v) for v in fields[key]]

    strides = ints("stride")
    base = ints("lhs_dilate")
    wdil = ints("rhs_dilate")
    if "pad" in fields:
        pads = [tuple(int(p) for p in v.split("_")) for v in fields["pad"]]
    else:
        pads = [(0, 0)] * nd
    return [
        (sizes[d], strides[d], pads[d][0], pads[d][1], base[d], wdil[d])
        for d in range(nd)
    ]


def parse_dim_labels(s):
    # b01f_01io->b01f -> ((lhs_b, lhs_f, lhs_spatial[]),
    #                     (rhs_i, rhs_o, rhs_spatial[]),
    #                     (out_b, out_f, out_spatial[]))
    # where spatial[k] is the tensor dim holding spatial dimension k.
    lhs, rest = s.split("_", 1)
    rhs, out = rest.split("->")

    def spec(part, a_ch, b_ch):
        a_pos = b_pos = -1
        sp = [0] * (len(part) - 2)
        for pos, ch in enumerate(part):
            if ch == a_ch:
                a_pos = pos
            elif ch == b_ch:
                b_pos = pos
            else:
                sp[int(ch)] = pos
        assert a_pos >= 0 and b_pos >= 0, part
        return a_pos, b_pos, sp

    return spec(lhs, "b", "f"), spec(rhs, "i", "o"), spec(out, "b", "f")


def resolve_window_pos(out_coord, win_coord, w, in_size):
    # Map (output coord, window tap) -> input coord, or None when the tap
    # lands in padding or between base-dilation lattice points. The check
    # order matters: negativity BEFORE the modulo (Rust `%` keeps sign).
    size, stride, pad_lo, pad_hi, base_dil, win_dil = w
    pos = out_coord * stride + win_coord * win_dil - pad_lo
    if pos < 0:
        return None
    if base_dil > 1:
        if pos % base_dil != 0:
            return None
        pos //= base_dil
    if pos >= in_size:
        return None
    return pos


# ---------------------------------------------------------------- values ---

NP_TY = {"f32": np.float32, "s32": np.int32, "u32": np.uint32, "pred": np.bool_}


class Arr:
    __slots__ = ("ty", "dims", "data")

    def __init__(self, ty, dims, data):
        self.ty = ty
        self.dims = list(dims)
        self.data = np.asarray(data, NP_TY[ty]).ravel()
        assert self.data.size == int(np.prod(dims)) if dims else self.data.size == 1

    def numel(self):
        n = 1
        for d in self.dims:
            n *= d
        return n


def strides_of(dims):
    st = [1] * len(dims)
    for i in range(len(dims) - 2, -1, -1):
        st[i] = st[i + 1] * dims[i + 1]
    return st


def unflatten(flat, dims, st):
    idx = []
    for s in st:
        idx.append(flat // s)
        flat %= s
    return idx


# -------------------------------------------------------------- evaluator ---

class Interp:
    def __init__(self, module: Module):
        self.m = module

    def run_entry(self, args):
        return self.run(self.m.comps[self.m.entry], args)

    def run(self, comp: Computation, args):
        env = [None] * len(comp.instrs)
        for i, ins in enumerate(comp.instrs):
            env[i] = self.eval_instr(comp, ins, env, args)
        return env[comp.root]

    def eval_instr(self, comp, ins, env, args):
        op = ins.opcode
        a = ins.attrs
        sh = ins.shape
        opv = [env[j] for j in ins.operands]

        if op == "parameter":
            return args[int(a["parameter_number"])]
        if op == "constant":
            return Arr(sh.ty, sh.dims, ins.literal)
        if op == "tuple":
            return ("tuple", opv)
        if op == "get-tuple-element":
            t = opv[0]
            assert t[0] == "tuple"
            return t[1][int(a["index"])]
        if op == "call":
            return self.run(self.m.comps[a["to_apply"]], opv)
        if op == "while":
            cond = self.m.comps[a["condition"]]
            body = self.m.comps[a["body"]]
            state = opv[0]
            while True:
                p = self.run(cond, [state])
                if not bool(p.data[0]):
                    break
                state = self.run(body, [state])
            return state

        if op == "iota":
            dim = int(a["iota_dimension"])
            st = strides_of(sh.dims)
            n = sh.numel()
            out = np.empty(n, NP_TY[sh.ty])
            for f in range(n):
                out[f] = (f // st[dim]) % sh.dims[dim]
            return Arr(sh.ty, sh.dims, out)

        if op == "broadcast":
            x = opv[0]
            dims = int_list(a.get("dimensions", "{}"))
            xst = strides_of(x.dims)
            ost = strides_of(sh.dims)
            n = sh.numel()
            out = np.empty(n, NP_TY[sh.ty])
            for f in range(n):
                oi = unflatten(f, sh.dims, ost)
                xi = 0
                for k, d in enumerate(dims):
                    xi += oi[d] * xst[k]
                out[f] = x.data[xi]
            return Arr(sh.ty, sh.dims, out)

        if op == "reshape":
            return Arr(sh.ty, sh.dims, opv[0].data)

        if op == "transpose":
            x = opv[0]
            perm = int_list(a["dimensions"])
            xst = strides_of(x.dims)
            ost = strides_of(sh.dims)
            n = sh.numel()
            out = np.empty(n, NP_TY[sh.ty])
            for f in range(n):
                oi = unflatten(f, sh.dims, ost)
                xi = 0
                for d in range(len(perm)):
                    xi += oi[d] * xst[perm[d]]
                out[f] = x.data[xi]
            return Arr(sh.ty, sh.dims, out)

        if op == "slice":
            x = opv[0]
            spec = parse_slice_attr(a["slice"])
            xst = strides_of(x.dims)
            ost = strides_of(sh.dims)
            n = sh.numel()
            out = np.empty(n, NP_TY[sh.ty])
            for f in range(n):
                oi = unflatten(f, sh.dims, ost)
                xi = 0
                for d, (s0, _, stp) in enumerate(spec):
                    xi += (s0 + oi[d] * stp) * xst[d]
                out[f] = x.data[xi]
            return Arr(sh.ty, sh.dims, out)

        if op == "concatenate":
            dim = int_list(a["dimensions"])[0]
            n = sh.numel()
            ost = strides_of(sh.dims)
            out = np.empty(n, NP_TY[sh.ty])
            # offsets along dim
            starts = []
            acc = 0
            for x in opv:
                starts.append(acc)
                acc += x.dims[dim]
            for f in range(n):
                oi = unflatten(f, sh.dims, ost)
                k = 0
                while k + 1 < len(opv) and oi[dim] >= starts[k + 1]:
                    k += 1
                x = opv[k]
                xst = strides_of(x.dims)
                xi = 0
                for d in range(len(sh.dims)):
                    c = oi[d] - (starts[k] if d == dim else 0)
                    xi += c * xst[d]
                out[f] = x.data[xi]
            return Arr(sh.ty, sh.dims, out)

        if op == "select":
            p, t, fv = opv
            out = np.where(p.data.astype(bool), t.data, fv.data)
            return Arr(sh.ty, sh.dims, out)

        if op == "compare":
            l, r = opv
            d = a["direction"]
            fn = {
                "EQ": np.equal, "NE": np.not_equal, "LT": np.less,
                "LE": np.less_equal, "GT": np.greater, "GE": np.greater_equal,
            }[d]
            return Arr("pred", sh.dims, fn(l.data, r.data))

        if op == "convert":
            x = opv[0]
            if sh.ty == "u32" and x.ty == "s32":
                out = x.data.astype(np.int64).astype(np.uint32)
            elif sh.ty == "s32" and x.ty == "f32":
                out = np.trunc(x.data).astype(np.int32)
            else:
                out = x.data.astype(NP_TY[sh.ty])
            return Arr(sh.ty, sh.dims, out)

        if op == "bitcast-convert":
            x = opv[0]
            out = x.data.view(NP_TY[sh.ty])
            return Arr(sh.ty, sh.dims, out)

        # --- elementwise ---
        if op in UNARY_F32:
            x = opv[0]
            out = UNARY_F32[op](x.data)
            return Arr(sh.ty, sh.dims, out.astype(NP_TY[sh.ty]))
        if op == "negate":
            return Arr(sh.ty, sh.dims, -opv[0].data)
        if op in BINARY:
            l, r = opv
            if op in ("shift-left", "shift-right-logical"):
                amt = r.data.astype(np.uint64)
                big = amt >= 32
                shifted = (
                    np.left_shift(l.data, np.where(big, 0, amt).astype(np.uint32))
                    if op == "shift-left"
                    else np.right_shift(l.data, np.where(big, 0, amt).astype(np.uint32))
                )
                out = np.where(big, np.uint32(0), shifted)
            else:
                with np.errstate(all="ignore"):
                    out = BINARY[op](l.data, r.data)
            return Arr(sh.ty, sh.dims, out.astype(NP_TY[sh.ty]))

        if op == "dot":
            return self.dot(sh, opv[0], opv[1], a)
        if op == "reduce":
            return self.reduce(sh, opv, a)
        if op == "gather":
            return self.gather(sh, opv[0], opv[1], a)
        if op == "scatter":
            return self.scatter(sh, opv, a)

        if op == "reverse":
            x = opv[0]
            dims = int_list(a["dimensions"])
            xst = strides_of(x.dims)
            ost = strides_of(sh.dims)
            n = sh.numel()
            out = np.empty(n, NP_TY[sh.ty])
            for f in range(n):
                oi = unflatten(f, sh.dims, ost)
                xi = 0
                for d in range(len(sh.dims)):
                    c = x.dims[d] - 1 - oi[d] if d in dims else oi[d]
                    xi += c * xst[d]
                out[f] = x.data[xi]
            return Arr(sh.ty, sh.dims, out)

        if op == "convolution":
            return self.conv(sh, opv[0], opv[1], a)
        if op == "reduce-window":
            return self.reduce_window(sh, opv, a)

        raise NotImplementedError(op)

    # ------------------------------------------------------------- dot ---

    def dot(self, sh, lhs, rhs, a):
        lb = int_list(a.get("lhs_batch_dims", "{}"))
        rb = int_list(a.get("rhs_batch_dims", "{}"))
        lc = int_list(a.get("lhs_contracting_dims", "{}"))
        rc = int_list(a.get("rhs_contracting_dims", "{}"))
        lfree = [d for d in range(len(lhs.dims)) if d not in lb and d not in lc]
        rfree = [d for d in range(len(rhs.dims)) if d not in rb and d not in rc]
        # output dims: batch..., lhs free..., rhs free...
        lst = strides_of(lhs.dims)
        rst = strides_of(rhs.dims)
        ost = strides_of(sh.dims)
        n = sh.numel()
        kdims = [lhs.dims[d] for d in lc]
        kst = strides_of(kdims)
        kn = 1
        for d in kdims:
            kn *= d
        out = np.empty(n, NP_TY[sh.ty])
        nb = len(lb)
        nlf = len(lfree)
        for f in range(n):
            oi = unflatten(f, sh.dims, ost)
            lbase = 0
            rbase = 0
            for k in range(nb):
                lbase += oi[k] * lst[lb[k]]
                rbase += oi[k] * rst[rb[k]]
            for k in range(nlf):
                lbase += oi[nb + k] * lst[lfree[k]]
            for k in range(len(rfree)):
                rbase += oi[nb + nlf + k] * rst[rfree[k]]
            # 4-way partial sums over ascending k, combined as
            # (s0+s1)+(s2+s3) with a sequential tail — the operation
            # order of quant::assign::dot, mirrored by ops::dot and the
            # planned executor's blocked lane kernel.
            def term(kf):
                ki = unflatten(kf, kdims, kst)
                li = lbase
                ri = rbase
                for t in range(len(lc)):
                    li += ki[t] * lst[lc[t]]
                    ri += ki[t] * rst[rc[t]]
                return np.float32(lhs.data[li] * rhs.data[ri])

            s = [np.float32(0.0)] * 4
            kn4 = kn - kn % 4
            kf = 0
            while kf < kn4:
                for t in range(4):
                    s[t] = np.float32(s[t] + term(kf + t))
                kf += 4
            acc = np.float32(np.float32(s[0] + s[1]) + np.float32(s[2] + s[3]))
            while kf < kn:
                acc = np.float32(acc + term(kf))
                kf += 1
            out[f] = acc
        return Arr(sh.ty, sh.dims, out)

    # ---------------------------------------------------------- reduce ---

    def reduce(self, sh, opv, a):
        nin = len(opv) // 2
        inputs = opv[:nin]
        inits = opv[nin:]
        dims = int_list(a["dimensions"])
        comp = self.m.comps[a["to_apply"]]
        x = inputs[0]
        kept = [d for d in range(len(x.dims)) if d not in dims]
        out_dims = [x.dims[d] for d in kept]
        red_dims = [x.dims[d] for d in dims]
        xst = strides_of(x.dims)
        ost = strides_of(out_dims)
        rst = strides_of(red_dims)
        rn = 1
        for d in red_dims:
            rn *= d
        n = 1
        for d in out_dims:
            n *= d
        shapes = sh.elems if sh.ty == "tuple" else [sh]
        outs = [np.empty(n, NP_TY[s.ty]) for s in shapes]
        for f in range(n):
            oi = unflatten(f, out_dims, ost)
            base = 0
            for k, d in enumerate(kept):
                base += oi[k] * xst[d]
            accs = [Arr(inits[j].ty, [], [inits[j].data[0]]) for j in range(nin)]
            for rf in range(rn):
                ri = unflatten(rf, red_dims, rst)
                xi = base
                for k, d in enumerate(dims):
                    xi += ri[k] * xst[d]
                vals = [Arr(inputs[j].ty, [], [inputs[j].data[xi]]) for j in range(nin)]
                res = self.run(comp, accs + vals)
                accs = list(res[1]) if isinstance(res, tuple) and res[0] == "tuple" else [res]
            for j in range(nin):
                outs[j][f] = accs[j].data[0]
        if sh.ty == "tuple":
            return ("tuple", [Arr(s.ty, s.dims, o) for s, o in zip(shapes, outs)])
        return Arr(sh.ty, sh.dims, outs[0])

    # ---------------------------------------------------------- gather ---

    def gather(self, sh, operand, start, a):
        offset_dims = int_list(a.get("offset_dims", "{}"))
        collapsed = int_list(a.get("collapsed_slice_dims", "{}"))
        ob_dims = int_list(a.get("operand_batching_dims", "{}"))
        sb_dims = int_list(a.get("start_indices_batching_dims", "{}"))
        sim = int_list(a.get("start_index_map", "{}"))
        ivd = int(a["index_vector_dim"])
        slice_sizes = int_list(a.get("slice_sizes", "{}"))

        # start_indices dims excluding index_vector_dim, in order
        sdims = [d for d in range(len(start.dims)) if d != ivd]
        batch_dims_out = [d for d in range(len(sh.dims)) if d not in offset_dims]
        # operand dims contributing offsets (not collapsed, not batching)
        off_operand_dims = [
            d for d in range(len(operand.dims))
            if d not in collapsed and d not in ob_dims
        ]
        assert len(off_operand_dims) == len(offset_dims)
        ost = strides_of(sh.dims)
        pst = strides_of(operand.dims)
        sst = strides_of(start.dims)
        n = sh.numel()
        out = np.empty(n, NP_TY[sh.ty])
        for f in range(n):
            oi = unflatten(f, sh.dims, ost)
            g = [oi[d] for d in batch_dims_out]   # maps to sdims order
            # full start index into operand
            full = [0] * len(operand.dims)
            for k, od in enumerate(sim):
                si = 0
                for j, sd in enumerate(sdims):
                    si += g[j] * sst[sd]
                if ivd < len(start.dims):
                    si += k * sst[ivd]
                idx = int(start.data[si])
                lo, hi = 0, operand.dims[od] - slice_sizes[od]
                full[od] = min(max(idx, lo), hi)
            for od, sd in zip(ob_dims, sb_dims):
                full[od] = g[sdims.index(sd)]
            pi = 0
            for d in range(len(operand.dims)):
                pi += full[d] * pst[d]
            for k, d in enumerate(off_operand_dims):
                pi += oi[offset_dims[k]] * pst[d]
            out[f] = operand.data[pi]
        return Arr(sh.ty, sh.dims, out)

    # --------------------------------------------------------- scatter ---

    def scatter(self, sh, opv, a):
        operand, indices, updates = opv
        uw_dims = int_list(a.get("update_window_dims", "{}"))
        inserted = int_list(a.get("inserted_window_dims", "{}"))
        ib_dims = int_list(a.get("input_batching_dims", "{}"))
        sb_dims = int_list(a.get("scatter_indices_batching_dims", "{}"))
        sdod = int_list(a.get("scatter_dims_to_operand_dims", "{}"))
        ivd = int(a["index_vector_dim"])
        comp = self.m.comps[a["to_apply"]]

        sdims = [d for d in range(len(indices.dims)) if d != ivd]
        scatter_dims_u = [d for d in range(len(updates.dims)) if d not in uw_dims]
        window_operand_dims = [
            d for d in range(len(operand.dims))
            if d not in inserted and d not in ib_dims
        ]
        assert len(window_operand_dims) == len(uw_dims)
        out = operand.data.copy()
        pst = strides_of(operand.dims)
        ust = strides_of(updates.dims)
        sst = strides_of(indices.dims)
        n = updates.numel()
        for f in range(n):
            ui = unflatten(f, updates.dims, ust)
            g = [ui[d] for d in scatter_dims_u]
            full = [0] * len(operand.dims)
            for k, od in enumerate(sdod):
                si = 0
                for j, sd in enumerate(sdims):
                    si += g[j] * sst[sd]
                if ivd < len(indices.dims):
                    si += k * sst[ivd]
                full[od] = int(indices.data[si])
            for od, sd in zip(ib_dims, sb_dims):
                full[od] = g[sdims.index(sd)]
            for k, d in enumerate(window_operand_dims):
                full[d] += ui[uw_dims[k]]
            ok = all(0 <= full[d] < operand.dims[d] for d in range(len(operand.dims)))
            if not ok:
                continue
            pi = 0
            for d in range(len(operand.dims)):
                pi += full[d] * pst[d]
            cur = Arr(operand.ty, [], [out[pi]])
            upd = Arr(updates.ty, [], [updates.data[f]])
            res = self.run(comp, [cur, upd])
            out[pi] = res.data[0]
        return Arr(sh.ty, sh.dims, out)

    # ----------------------------------------------------- convolution ---

    def conv(self, sh, lhs, rhs, a):
        # General conv_general_dilated: output cells in ascending flat
        # order; per cell, kernel spatial taps row-major ascending with
        # the input channel innermost; one f32 accumulator. Feature and
        # batch groups both use XLA's blocked indexing:
        #   group        = oc // (O / feature_group_count)
        #   batch_group  = oc // (O / batch_group_count)
        #   lhs_batch    = batch_group * (N / batch_group_count) + out_b
        win = parse_window(a.get("window", "{}"))
        (lb, lf, lsp), (rin, rout, rsp), (ob, of, osp) = parse_dim_labels(
            a["dim_labels"]
        )
        fg = int(a.get("feature_group_count", "1"))
        bg = int(a.get("batch_group_count", "1"))
        nsp = len(lsp)
        lst = strides_of(lhs.dims)
        rst = strides_of(rhs.dims)
        ost = strides_of(sh.dims)
        o_size = rhs.dims[rout]
        i_size = rhs.dims[rin]
        lb_size = lhs.dims[lb]
        assert o_size % fg == 0 and o_size % bg == 0 and lb_size % bg == 0
        kdims = [rhs.dims[rsp[s]] for s in range(nsp)]
        kst = strides_of(kdims)
        kn = 1
        for d in kdims:
            kn *= d
        n = sh.numel()
        out = np.empty(n, NP_TY[sh.ty])
        for f in range(n):
            oi = unflatten(f, sh.dims, ost)
            oc = oi[of]
            g = oc // (o_size // fg)
            bgi = oc // (o_size // bg)
            b = bgi * (lb_size // bg) + oi[ob]
            acc = np.float32(0.0)
            for kf in range(kn):
                ki = unflatten(kf, kdims, kst)
                lbase = b * lst[lb]
                ok = True
                for s in range(nsp):
                    pos = resolve_window_pos(
                        oi[osp[s]], ki[s], win[s], lhs.dims[lsp[s]]
                    )
                    if pos is None:
                        ok = False
                        break
                    lbase += pos * lst[lsp[s]]
                if not ok:
                    continue
                rbase = oc * rst[rout]
                for s in range(nsp):
                    rbase += ki[s] * rst[rsp[s]]
                for ic in range(i_size):
                    li = lbase + (g * i_size + ic) * lst[lf]
                    ri = rbase + ic * rst[rin]
                    acc = np.float32(acc + np.float32(lhs.data[li] * rhs.data[ri]))
            out[f] = acc
        return Arr(sh.ty, sh.dims, out)

    # ---------------------------------------------------- reduce-window ---

    def reduce_window(self, sh, opv, a):
        # Region fold like `reduce`: acc starts at init, in-bounds window
        # elements fold in ascending row-major window-position order;
        # out-of-bounds taps (padding / dilation gaps) are skipped, which
        # is exactly "padding is init-valued" for any fold with identity
        # init.
        x, init = opv
        win = parse_window(a.get("window", "{}"))
        comp = self.m.comps[a["to_apply"]]
        rank = len(x.dims)
        assert len(win) == rank
        xst = strides_of(x.dims)
        ost = strides_of(sh.dims)
        wdims = [w[0] for w in win]
        wst = strides_of(wdims)
        wn = 1
        for d in wdims:
            wn *= d
        n = sh.numel()
        out = np.empty(n, NP_TY[sh.ty])
        for f in range(n):
            oi = unflatten(f, sh.dims, ost)
            acc = Arr(init.ty, [], [init.data[0]])
            for wf in range(wn):
                wi = unflatten(wf, wdims, wst)
                xi = 0
                ok = True
                for d in range(rank):
                    pos = resolve_window_pos(oi[d], wi[d], win[d], x.dims[d])
                    if pos is None:
                        ok = False
                        break
                    xi += pos * xst[d]
                if not ok:
                    continue
                val = Arr(x.ty, [], [x.data[xi]])
                acc = self.run(comp, [acc, val])
            out[f] = acc.data[0]
        return Arr(sh.ty, sh.dims, out)


UNARY_F32 = {
    "round-nearest-even": lambda x: np.round(x),
    "exponential": lambda x: np.exp(x),
    "log": lambda x: np.log(x),
    "rsqrt": lambda x: np.float32(1.0) / np.sqrt(x),
    "sine": lambda x: np.sin(x),
    "cosine": lambda x: np.cos(x),
}

BINARY = {
    "add": np.add,
    "subtract": np.subtract,
    "multiply": np.multiply,
    "divide": lambda l, r: np.divide(l, r) if l.dtype == np.float32 else
        (l.astype(np.int64) // np.where(r == 0, 1, r)).astype(l.dtype),
    "maximum": np.maximum,
    "minimum": np.minimum,
    "power": lambda l, r: np.power(l, r),
    "and": np.bitwise_and,
    "or": np.bitwise_or,
    "xor": np.bitwise_xor,
    "shift-left": None,
    "shift-right-logical": None,
}
