"""Mirror of the QNC1 checkpoint framing + resume math (DESIGN.md §10).

The risky logic behind `rust/src/coordinator/checkpoint.rs` and the
`Trainer::resume_from` contract, re-implemented independently from the
on-disk spec so the properties the Rust tests assert can be validated
without a Rust toolchain:

1. fnv1a64 — known vectors, and the injectivity argument behind
   "every single-bit flip is detected": each FNV-1a update step
   h' = (h ^ b) * prime is injective in h (odd prime, invertible mod
   2^64) and in b, so a flip anywhere in a fixed-length body always
   changes the trailer.
2. QNC1 wire format — magic | u32 LE header len | compact JSON header
   | f32 LE payload (params, opt slots, hats sorted by idx) | fnv1a64
   LE trailer, trailer verified FIRST. Properties: canonical encode,
   roundtrip, every truncation rejected, every single-bit flip
   rejected.
3. resume math — a toy trainer drawing from the real Pcg in the
   trainer's per-step order (hat-refresh splits, layerdrop f32 draws,
   per-step seed mask) with f32 SGD-momentum updates and a counted
   data cursor. Capturing (rng state_parts, batches drawn, params,
   velocity, hats) at step k and rebuilding from the decoded bytes
   must replay the remaining steps bit-identically.

Run: python3 ckpt_mirror.py  (prints PASS/FAIL per assertion)
"""
import json
import struct

import numpy as np

from pcg import Pcg

M64 = (1 << 64) - 1

# ------------------------------------------------------------ fnv1a64

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3


def fnv1a64(data: bytes) -> int:
    h = FNV_OFFSET
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & M64
    return h


# ------------------------------------------------------- QNC1 framing


def compact_json(obj) -> str:
    """Match rust util/json.rs Display: no spaces, f64 with zero
    fraction printed as integers, insertion-ordered keys."""
    return json.dumps(obj, separators=(",", ":"))


def f32_bytes(xs) -> bytes:
    return np.asarray(xs, dtype="<f4").tobytes()


def encode(ck: dict) -> bytes:
    hats = sorted(ck["hats"], key=lambda h: h[0])
    opt = ck["opt"]
    slots = 1 if opt["kind"] == "sgd" else 2
    header = compact_json(
        {
            "version": 1,
            "model": ck["model"],
            "step": ck["step"],
            "batches": ck["batches"],
            "rng_state": "%016x" % ck["rng"][0],
            "rng_inc": "%016x" % ck["rng"][1],
            "cfg_digest": "%016x" % ck["cfg_digest"],
            "opt": {"kind": opt["kind"], "t": opt.get("t", 0), "slots": slots},
            "params": [
                {"name": n, "shape": list(t.shape)} for n, t in ck["params"]
            ],
            "hats": [{"idx": i, "len": len(h)} for i, h in hats],
        }
    ).encode()
    out = b"QNC1" + struct.pack("<I", len(header)) + header
    for _, t in ck["params"]:
        out += f32_bytes(t.ravel())
    for slot in opt["slots_data"]:
        for t in slot:
            out += f32_bytes(t.ravel())
    for _, h in hats:
        out += f32_bytes(h)
    return out + struct.pack("<Q", fnv1a64(out))


class Corrupt(Exception):
    pass


def decode(bytes_: bytes) -> dict:
    if len(bytes_) < 16:
        raise Corrupt("file too short")
    body = bytes_[:-8]
    (want,) = struct.unpack("<Q", bytes_[-8:])
    if fnv1a64(body) != want:
        raise Corrupt("trailer hash mismatch")
    if bytes_[:4] != b"QNC1":
        raise Corrupt("bad magic")
    (hlen,) = struct.unpack("<I", bytes_[4:8])
    if 8 + hlen > len(body):
        raise Corrupt("header length exceeds file")
    j = json.loads(body[8 : 8 + hlen].decode())
    if j["version"] != 1:
        raise Corrupt("unsupported version")
    off = 8 + hlen

    def take(n):
        nonlocal off
        need = n * 4
        if off + need > len(body):
            raise Corrupt("truncated payload")
        v = np.frombuffer(body[off : off + need], dtype="<f4").copy()
        off += need
        return v

    params = []
    for p in j["params"]:
        numel = int(np.prod(p["shape"])) if p["shape"] else 1
        params.append((p["name"], take(numel).reshape(p["shape"])))
    slots = []
    for _ in range(j["opt"]["slots"]):
        slots.append([take(t.size).reshape(t.shape) for _, t in params])
    hats = [(h["idx"], take(h["len"])) for h in j["hats"]]
    if off != len(body):
        raise Corrupt("trailing bytes after payload")
    return {
        "model": j["model"],
        "step": j["step"],
        "batches": j["batches"],
        "rng": (int(j["rng_state"], 16), int(j["rng_inc"], 16)),
        "cfg_digest": int(j["cfg_digest"], 16),
        "params": params,
        "opt": {"kind": j["opt"]["kind"], "t": j["opt"]["t"], "slots_data": slots},
        "hats": hats,
    }


# ----------------------------------------------------- toy resume sim


class ToyTrainer:
    """Draws from the real Pcg in the trainer's per-step order:
    hat-refresh splits at the refresh boundary, per-chunk layerdrop
    f32s, then the per-step noise-seed mask; f32 SGD with momentum."""

    HAT_REFRESH = 4
    LR = np.float32(0.1)
    MOM = np.float32(0.9)

    def __init__(self, seed):
        self.rng = Pcg(seed)
        self.params = [
            np.linspace(-1, 1, 12, dtype=np.float32).reshape(3, 4),
            np.ones(4, dtype=np.float32) * np.float32(0.5),
        ]
        self.vel = [np.zeros_like(p) for p in self.params]
        self.hats = []
        self.step = 0
        self.batches = 0
        self.data_cursor = 0  # the "batcher": a counted token stream

    def next_batch(self):
        self.data_cursor += 7
        self.batches += 1
        return np.float32(1.0 + (self.data_cursor % 13) * 0.25)

    def one_step(self):
        if self.step % self.HAT_REFRESH == 0:
            # hat refresh: one split per noised param, two f32 draws each
            self.hats = []
            for i in range(len(self.params)):
                sub = self.rng.split(i)
                self.hats.append(
                    (i, [np.float32(sub.next_f32()), np.float32(sub.next_f32())])
                )
        drop = np.float32(self.rng.next_f32())  # layerdrop draw
        seed = self.rng.next_u32() & 0x7FFFFFFF  # per-step noise seed
        x = self.next_batch()
        scale = np.float32(seed % 97) * np.float32(0.01) + drop
        for i, p in enumerate(self.params):
            g = (p * x + scale + self.hats[i][1][0]).astype(np.float32)
            self.vel[i] = (self.MOM * self.vel[i] + g).astype(np.float32)
            self.params[i] = (p - self.LR * self.vel[i]).astype(np.float32)
        self.step += 1

    def run(self, steps):
        while self.step < steps:
            self.one_step()

    def to_checkpoint(self):
        return {
            "model": "toy",
            "step": self.step,
            "batches": self.batches,
            "rng": (self.rng.state, self.rng.inc),
            "cfg_digest": 0xDEADBEEFCAFEF00D,
            "params": [("w%d" % i, p.copy()) for i, p in enumerate(self.params)],
            "opt": {
                "kind": "sgd",
                "t": 0,
                "slots_data": [[v.copy() for v in self.vel]],
            },
            "hats": [(i, list(h)) for i, h in self.hats],
        }

    @classmethod
    def resume(cls, ck, seed):
        t = cls(seed)  # fresh world, as after a crash
        # the resume math under test: restore the rng position from
        # state_parts, re-draw and discard `batches` from the data
        # source, and reload params/velocity/hats
        t.rng.state, t.rng.inc = ck["rng"]
        for _ in range(ck["batches"]):
            t.next_batch()
        t.batches = ck["batches"]
        t.step = ck["step"]
        t.params = [p.copy() for _, p in ck["params"]]
        t.vel = [v.copy() for v in ck["opt"]["slots_data"][0]]
        t.hats = [(i, [np.float32(x) for x in h]) for i, h in ck["hats"]]
        return t


# ------------------------------------------------------------- checks

PASS = 0
FAIL = 0


def check(name, ok, detail=""):
    global PASS, FAIL
    if ok:
        PASS += 1
        print("PASS %s" % name)
    else:
        FAIL += 1
        print("FAIL %s %s" % (name, detail))


def bits(arrs):
    return [a.astype(np.float32).view(np.uint32).tolist() for a in arrs]


def sample_ck():
    t = ToyTrainer(11)
    t.run(5)
    return t.to_checkpoint()


def main():
    # 1. fnv1a64 vectors (reference values of the 64-bit FNV-1a spec)
    check("fnv.empty", fnv1a64(b"") == 0xCBF29CE484222325)
    check("fnv.a", fnv1a64(b"a") == 0xAF63DC4C8601EC8C)
    check("fnv.foobar", fnv1a64(b"foobar") == 0x85944171F73967E8)

    # 2. QNC1 framing
    ck = sample_ck()
    enc = encode(ck)
    check("qnc1.canonical", enc == encode(decode(enc)))
    back = decode(enc)
    check(
        "qnc1.roundtrip.scalars",
        (back["step"], back["batches"], back["rng"], back["cfg_digest"])
        == (ck["step"], ck["batches"], ck["rng"], ck["cfg_digest"]),
    )
    check("qnc1.roundtrip.params", bits([p for _, p in back["params"]])
          == bits([p for _, p in ck["params"]]))
    check(
        "qnc1.roundtrip.opt",
        bits(back["opt"]["slots_data"][0]) == bits(ck["opt"]["slots_data"][0]),
    )
    check(
        "qnc1.roundtrip.hats",
        bits([np.asarray(h, np.float32) for _, h in back["hats"]])
        == bits([np.asarray(h, np.float32) for _, h in sorted(ck["hats"])]),
    )

    every_cut = all(_rejected(enc[:cut]) for cut in range(len(enc)))
    check("qnc1.every_truncation_rejected", every_cut)

    every_flip = True
    for i in range(len(enc)):
        for bit in range(8):
            m = bytearray(enc)
            m[i] ^= 1 << bit
            if not _rejected(bytes(m)):
                every_flip = False
                print("  surviving flip at byte %d bit %d" % (i, bit))
    check("qnc1.every_bitflip_rejected", every_flip)

    # hats arrive sorted regardless of capture order
    shuffled = dict(ck, hats=list(reversed(ck["hats"])))
    check("qnc1.hats_canonical_order", encode(shuffled) == enc)

    # adam framing: two slots roundtrip with t
    adam = dict(
        ck,
        opt={
            "kind": "adam",
            "t": 5,
            "slots_data": [
                [p * np.float32(0.1) for _, p in ck["params"]],
                [p * np.float32(0.2) for _, p in ck["params"]],
            ],
        },
    )
    aback = decode(encode(adam))
    check(
        "qnc1.adam_two_slots",
        aback["opt"]["t"] == 5
        and bits(aback["opt"]["slots_data"][1])
        == bits(adam["opt"]["slots_data"][1]),
    )

    # 3. resume math: kill at k, rebuild from decoded bytes, finish —
    # bit-identical to the uninterrupted run for every kill point,
    # including kills straddling the hat-refresh boundary (refresh=4)
    TOTAL = 9
    ref = ToyTrainer(23)
    ref.run(TOTAL)
    ref_bits = bits(ref.params)
    ref_rng = (ref.rng.state, ref.rng.inc)
    all_ok = True
    for kill in range(1, TOTAL):
        t = ToyTrainer(23)
        t.run(kill)
        wire = encode(t.to_checkpoint())
        del t  # the crash
        r = ToyTrainer.resume(decode(wire), 23)
        r.run(TOTAL)
        if bits(r.params) != ref_bits or (r.rng.state, r.rng.inc) != ref_rng:
            all_ok = False
            print("  divergence after kill@%d" % kill)
    check("resume.kill_matrix_bit_identical", all_ok)

    # the negative control: dropping any piece of state breaks replay,
    # proving each checkpointed field is load-bearing
    t = ToyTrainer(23)
    t.run(3)
    ck3 = t.to_checkpoint()
    stale_rng = dict(ck3, rng=(Pcg(23).state, Pcg(23).inc))
    r = ToyTrainer.resume(stale_rng, 23)
    r.run(TOTAL)
    check("resume.rng_is_load_bearing", bits(r.params) != ref_bits)
    stale_cursor = dict(ck3, batches=0)
    r = ToyTrainer.resume(stale_cursor, 23)
    r.run(TOTAL)
    check("resume.cursor_is_load_bearing", bits(r.params) != ref_bits)
    no_hats = dict(ck3, hats=[(i, [0.0, 0.0]) for i, _ in ck3["hats"]])
    r = ToyTrainer.resume(no_hats, 23)
    r.run(TOTAL)
    check("resume.hats_are_load_bearing", bits(r.params) != ref_bits)

    print("summary: %d passed, %d failed" % (PASS, FAIL))
    raise SystemExit(1 if FAIL else 0)


def _rejected(b):
    try:
        decode(b)
        return False
    except (Corrupt, Exception):
        return True


if __name__ == "__main__":
    main()
