"""Mirror of the planned executor's new kernels (rust/src/runtime/interp/plan.rs
and the lane-blocked kernel in rust/src/quant/assign.rs), validated for
BIT-IDENTITY against the reference mirror (`hlo_mirror.py`) on the
checked-in fixture.

The Rust planned executor claims bit-identity with the tree-walking
evaluator because every new kernel visits the same elements in the same
order with the same scalar ops. This file re-implements exactly those
kernels (packed dot, fused binary reduce, fused binary scatter, the
8-lane dot) in numpy float32 and checks them against the reference
algorithms — catching any index-math or accumulation-order mistake
before it ships as Rust that this container cannot compile. Run:

    cd tools/qnsim && python3 plan_mirror.py        # ~2 min (pure python)
"""
import os
import sys

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, HERE)

from hlo_mirror import (
    Arr, BINARY, Interp, int_list, parse_module, strides_of, unflatten,
)

ROOT = os.path.dirname(os.path.dirname(HERE))
FIX = os.path.join(ROOT, "rust", "tests", "fixtures", "interp")


# ------------------------------------------------- planned dot (packed) ---

def group_offsets(dims, st, group):
    sizes = [dims[d] for d in group]
    n = 1
    for s in sizes:
        n *= s
    n = max(n, 1)
    offs = []
    idx = [0] * len(group)
    for _ in range(n):
        offs.append(sum(c * st[d] for c, d in zip(idx, group)))
        for t in range(len(group) - 1, -1, -1):
            idx[t] += 1
            if idx[t] < sizes[t]:
                break
            idx[t] = 0
    return offs


def pack_f32(src, dims, outer, mid, inner):
    st = strides_of(dims)
    oo = group_offsets(dims, st, outer)
    mo = group_offsets(dims, st, mid)
    io = group_offsets(dims, st, inner)
    out = np.empty(len(oo) * len(mo) * len(io), np.float32)
    w = 0
    for a in oo:
        for b in mo:
            base = a + b
            for c in io:
                out[w] = src[base + c]
                w += 1
    return out


class PlannedInterp(Interp):
    """Reference mirror with the planned executor's kernels swapped in."""

    def dot(self, sh, lhs, rhs, a):
        lb = int_list(a.get("lhs_batch_dims", "{}"))
        rb = int_list(a.get("rhs_batch_dims", "{}"))
        lc = int_list(a.get("lhs_contracting_dims", "{}"))
        rc = int_list(a.get("rhs_contracting_dims", "{}"))
        lfree = [d for d in range(len(lhs.dims)) if d not in lb and d not in lc]
        rfree = [d for d in range(len(rhs.dims)) if d not in rb and d not in rc]
        kdims = [lhs.dims[d] for d in lc]
        bn = 1
        for d in lb:
            bn *= lhs.dims[d]
        mn = 1
        for d in lfree:
            mn *= lhs.dims[d]
        nn = 1
        for d in rfree:
            nn *= rhs.dims[d]
        total = bn * mn * nn
        if total == 0:
            return Arr(sh.ty, sh.dims, np.empty(0, np.float32))
        kn_raw = 1
        for d in kdims:
            kn_raw *= d
        if kdims and kn_raw == 0:
            return Arr(sh.ty, sh.dims, np.zeros(total, np.float32))
        kn = max(kn_raw, 1)
        lp = pack_f32(lhs.data, lhs.dims, lb, lfree, lc)
        rp = pack_f32(rhs.data, rhs.dims, rb, rfree, rc)
        out = np.empty(total, np.float32)
        for row in range(bn * mn):
            b = row // mn
            xr = lp[row * kn:(row + 1) * kn]
            rbp = rp[b * nn * kn:(b + 1) * nn * kn]
            for j in range(nn):
                yr = rbp[j * kn:(j + 1) * kn]
                acc = np.float32(0.0)
                for t in range(kn):
                    acc = np.float32(acc + np.float32(xr[t] * yr[t]))
                out[row * nn + j] = acc
        return Arr(sh.ty, sh.dims, out)

    # -------------------------------------------------- fused regions ---

    def _match_bin_region(self, comp):
        if len(comp.instrs) != 3 or comp.n_params != 2:
            return None
        p = {}
        for i, ins in enumerate(comp.instrs):
            if ins.opcode == "parameter":
                p[int(ins.attrs["parameter_number"])] = i
        if set(p) != {0, 1}:
            return None
        root = comp.instrs[comp.root]
        if root.opcode not in BINARY or BINARY[root.opcode] is None:
            return None
        if root.operands == [p[0], p[1]]:
            return root.opcode, True
        if root.operands == [p[1], p[0]]:
            return root.opcode, False
        return None

    def reduce(self, sh, opv, a):
        comp = self.m.comps[a["to_apply"]]
        hit = self._match_bin_region(comp)
        if len(opv) != 2 or sh.ty == "tuple" or hit is None:
            return super().reduce(sh, opv, a)
        opcode, acc_first = hit
        fn = BINARY[opcode]
        x, init = opv
        dims = int_list(a["dimensions"])
        kept = [d for d in range(len(x.dims)) if d not in dims]
        out_dims = [x.dims[d] for d in kept]
        red_dims = [x.dims[d] for d in dims]
        xst = strides_of(x.dims)
        ost = strides_of(out_dims)
        rst = strides_of(red_dims)
        rn = 1
        for d in red_dims:
            rn *= d
        n = 1
        for d in out_dims:
            n *= d
        i0 = init.data[0]
        contiguous = all(
            dims[t] == len(x.dims) - len(dims) + t for t in range(len(dims)))
        out = np.empty(n, x.data.dtype)
        for f in range(n):
            if contiguous:
                run = x.data[f * rn:(f + 1) * rn]
                acc = i0
                for v in run:
                    acc = fn(acc, v) if acc_first else fn(v, acc)
            else:
                oi = unflatten(f, out_dims, ost)
                base = sum(oi[k] * xst[d] for k, d in enumerate(kept))
                acc = i0
                for rf in range(rn):
                    ri = unflatten(rf, red_dims, rst)
                    xi = base + sum(ri[k] * xst[d] for k, d in enumerate(dims))
                    v = x.data[xi]
                    acc = fn(acc, v) if acc_first else fn(v, acc)
            out[f] = acc
        return Arr(sh.ty, sh.dims, out)

    def scatter(self, sh, opv, a):
        comp = self.m.comps[a["to_apply"]]
        hit = self._match_bin_region(comp)
        if hit is None:
            return super().scatter(sh, opv, a)
        opcode, acc_first = hit
        fn = BINARY[opcode]
        operand, indices, updates = opv
        uw_dims = int_list(a.get("update_window_dims", "{}"))
        inserted = int_list(a.get("inserted_window_dims", "{}"))
        ib_dims = int_list(a.get("input_batching_dims", "{}"))
        sb_dims = int_list(a.get("scatter_indices_batching_dims", "{}"))
        sdod = int_list(a.get("scatter_dims_to_operand_dims", "{}"))
        ivd = int(a["index_vector_dim"])
        sdims = [d for d in range(len(indices.dims)) if d != ivd]
        scatter_dims_u = [d for d in range(len(updates.dims)) if d not in uw_dims]
        window_operand_dims = [
            d for d in range(len(operand.dims))
            if d not in inserted and d not in ib_dims
        ]
        out = operand.data.copy()
        pst = strides_of(operand.dims)
        ust = strides_of(updates.dims)
        sst = strides_of(indices.dims)
        for f in range(updates.numel()):
            ui = unflatten(f, updates.dims, ust)
            g = [ui[d] for d in scatter_dims_u]
            full = [0] * len(operand.dims)
            for k, od in enumerate(sdod):
                si = sum(g[j] * sst[sd] for j, sd in enumerate(sdims))
                if ivd < len(indices.dims):
                    si += k * sst[ivd]
                full[od] = int(indices.data[si])
            for od, sd in zip(ib_dims, sb_dims):
                full[od] = g[sdims.index(sd)]
            for k, d in enumerate(window_operand_dims):
                full[d] += ui[uw_dims[k]]
            if not all(0 <= full[d] < operand.dims[d]
                       for d in range(len(operand.dims))):
                continue
            pi = sum(full[d] * pst[d] for d in range(len(operand.dims)))
            cur, upd = out[pi], updates.data[f]
            out[pi] = fn(cur, upd) if acc_first else fn(upd, cur)
        return Arr(sh.ty, sh.dims, out)


# ------------------------------------------ assign.rs dot8 lane kernel ---

def rust_dot(a, b):
    """quant::assign::dot — 4-way unrolled f32 dot, bit-exact."""
    n = len(a)
    s = [np.float32(0.0)] * 4
    n4 = n - n % 4
    i = 0
    while i < n4:
        for t in range(4):
            s[t] = np.float32(s[t] + np.float32(a[i + t] * b[i + t]))
        i += 4
    acc = np.float32(np.float32(s[0] + s[1]) + np.float32(s[2] + s[3]))
    while i < n:
        acc = np.float32(acc + np.float32(a[i] * b[i]))
        i += 1
    return acc


def rust_dot8(p, tile, d):
    """quant::assign::dot8 — 8 lanes against a [d][8] transposed tile."""
    s = [np.zeros(8, np.float32) for _ in range(4)]
    d4 = d - d % 4
    t = 0
    while t < d4:
        for q in range(4):
            r = tile[(t + q) * 8:(t + q + 1) * 8]
            s[q] = np.float32(s[q] + np.float32(np.float32(p[t + q]) * r))
        t += 4
    out = np.float32(np.float32(s[0] + s[1]) + np.float32(s[2] + s[3]))
    while t < d:
        r = tile[t * 8:(t + 1) * 8]
        out = np.float32(out + np.float32(np.float32(p[t]) * r))
        t += 1
    return out


def check_dot8():
    rng = np.random.default_rng(0)
    for d in (1, 2, 3, 4, 7, 8, 9, 16, 31):
        p = rng.standard_normal(d).astype(np.float32)
        cents = rng.standard_normal((8, d)).astype(np.float32)
        tile = np.ascontiguousarray(cents.T).reshape(-1)  # [d][8]
        got = rust_dot8(p, tile, d)
        for lane in range(8):
            want = rust_dot(p, cents[lane])
            assert got[lane].tobytes() == want.tobytes(), (d, lane)
    print("dot8 lane kernel == scalar 4-way dot, bitwise, d in 1..31  OK")


# ----------------------------------------------------------- fixture ---

def bits(x):
    return np.asarray(x).tobytes()


def assert_same(a, b, path):
    if isinstance(a, tuple):
        assert isinstance(b, tuple) and len(a[1]) == len(b[1]), path
        for i, (x, y) in enumerate(zip(a[1], b[1])):
            assert_same(x, y, f"{path}.{i}")
        return
    assert a.dims == b.dims, (path, a.dims, b.dims)
    assert bits(a.data) == bits(b.data), f"{path}: payload differs"


def fixture_args(grad):
    import json
    import struct
    man = json.load(open(os.path.join(FIX, "manifest.json")))
    meta = man["models"]["lm_tiny"]
    with open(os.path.join(FIX, meta["init"]), "rb") as f:
        assert f.read(4) == b"QNP1"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        params = []
        for p in header["params"]:
            numel = int(np.prod(p["shape"])) if p["shape"] else 1
            data = np.frombuffer(f.read(4 * numel), np.float32)
            params.append(Arr("f32", list(p["shape"]), data))
    b, t = meta["tokens_shape"]
    vocab = meta["config"]["vocab"]
    n_layers = meta["config"]["n_layers"]
    tokens = Arr("s32", [b, t], [(i * 7 + 3) % vocab for i in range(b * t)])
    targets = Arr("s32", [b, t], [(i * 5 + 1) % vocab for i in range(b * t)])
    keep = Arr("f32", [n_layers], [1.0] * n_layers)
    args = list(params)
    if grad:
        args += [Arr("f32", p.dims, np.zeros(max(p.numel(), 1), np.float32))
                 for p in params]
    args += [tokens, targets, keep]
    if grad:
        args += [Arr("f32", [], [0.5]), Arr("s32", [], [42])]
    return args


def check_fixture(entry, grad):
    text = open(os.path.join(FIX, f"lm_tiny.{entry}.hlo.txt")).read()
    m = parse_module(text)
    args = fixture_args(grad)
    ref = Interp(m).run_entry(args)
    planned = PlannedInterp(m).run_entry(args)
    assert_same(planned, ref, entry)
    n_out = len(ref[1])
    print(f"{entry}: planned kernels bit-identical to reference "
          f"({n_out} outputs)  OK")


def main():
    check_dot8()
    check_fixture("eval", grad=False)
    check_fixture("grad_mix", grad=True)
    print("PLANNED KERNELS VALIDATED (bitwise) against the reference mirror")


if __name__ == "__main__":
    main()
