"""Mirror of the planned executor's new kernels (rust/src/runtime/interp/plan.rs,
the loop-fusion pass in rust/src/runtime/interp/fuse.rs, and the
lane-blocked kernel in rust/src/quant/assign.rs), validated for
BIT-IDENTITY against the reference mirror (`hlo_mirror.py`) on the
checked-in fixture.

The Rust planned executor claims bit-identity with the tree-walking
evaluator because every new kernel visits the same elements in the same
order with the same scalar ops. This file re-implements exactly those
kernels (packed dot, fused binary reduce, fused binary scatter, the
8-lane dot, and — since the loop-fusion PR — the counted-loop
superinstruction and the native threefry2x32 round kernel) in numpy and
checks them against the reference algorithms — catching any index-math
or accumulation-order mistake before it ships as Rust that this
container cannot compile. Since the vision PR it also runs the img_tiny
conv fixture (shared `convolution` kernel, fused `reduce-window` fold)
through all three tiers. Since the compiled-tier-kernels PR the fused
tier additionally mirrors `fuse::match_chains`: single-use elementwise
cones collapse into one tape superinstruction per chain root, interior
steps are elided (never evaluated, never counted), and the executed
instruction counts printed per fixture are the acceptance metric for
the chain pass. Run:

    cd tools/qnsim && python3 plan_mirror.py        # ~5 min (pure python)
"""
import os
import sys
import time

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, HERE)

from hlo_mirror import (
    Arr, BINARY, Interp, NP_TY, UNARY_F32, int_list, parse_module,
    parse_slice_attr, parse_window, resolve_window_pos, strides_of,
    unflatten,
)

ROOT = os.path.dirname(os.path.dirname(HERE))
FIX = os.path.join(ROOT, "rust", "tests", "fixtures", "interp")


# ------------------------------------------------- planned dot (packed) ---

def group_offsets(dims, st, group):
    sizes = [dims[d] for d in group]
    n = 1
    for s in sizes:
        n *= s
    n = max(n, 1)
    offs = []
    idx = [0] * len(group)
    for _ in range(n):
        offs.append(sum(c * st[d] for c, d in zip(idx, group)))
        for t in range(len(group) - 1, -1, -1):
            idx[t] += 1
            if idx[t] < sizes[t]:
                break
            idx[t] = 0
    return offs


def pack_f32(src, dims, outer, mid, inner):
    st = strides_of(dims)
    oo = group_offsets(dims, st, outer)
    mo = group_offsets(dims, st, mid)
    io = group_offsets(dims, st, inner)
    out = np.empty(len(oo) * len(mo) * len(io), np.float32)
    w = 0
    for a in oo:
        for b in mo:
            base = a + b
            for c in io:
                out[w] = src[base + c]
                w += 1
    return out


class PlannedInterp(Interp):
    """Reference mirror with the planned executor's kernels swapped in."""

    def dot(self, sh, lhs, rhs, a):
        lb = int_list(a.get("lhs_batch_dims", "{}"))
        rb = int_list(a.get("rhs_batch_dims", "{}"))
        lc = int_list(a.get("lhs_contracting_dims", "{}"))
        rc = int_list(a.get("rhs_contracting_dims", "{}"))
        lfree = [d for d in range(len(lhs.dims)) if d not in lb and d not in lc]
        rfree = [d for d in range(len(rhs.dims)) if d not in rb and d not in rc]
        kdims = [lhs.dims[d] for d in lc]
        bn = 1
        for d in lb:
            bn *= lhs.dims[d]
        mn = 1
        for d in lfree:
            mn *= lhs.dims[d]
        nn = 1
        for d in rfree:
            nn *= rhs.dims[d]
        total = bn * mn * nn
        if total == 0:
            return Arr(sh.ty, sh.dims, np.empty(0, np.float32))
        kn_raw = 1
        for d in kdims:
            kn_raw *= d
        if kdims and kn_raw == 0:
            return Arr(sh.ty, sh.dims, np.zeros(total, np.float32))
        kn = max(kn_raw, 1)
        # Blocked microkernel mirror: full 8-column tiles go through the
        # rust_dot8 lane kernel against a transposed [kn][8] tile,
        # remainder columns through the scalar 4-way rust_dot — exactly
        # plan.rs::dot_rows (and, per element, exactly ops::dot).
        lp = pack_f32(lhs.data, lhs.dims, lb, lfree, lc)
        rp = pack_f32(rhs.data, rhs.dims, rb, rfree, rc)
        out = np.empty(total, np.float32)
        nblk = nn // 8
        panels = []
        for b in range(bn):
            rbp = rp[b * nn * kn:(b + 1) * nn * kn].reshape(nn, kn)
            tiles = [
                np.ascontiguousarray(rbp[blk * 8:(blk + 1) * 8, :].T).reshape(-1)
                for blk in range(nblk)
            ]
            panels.append((rbp, tiles))
        for row in range(bn * mn):
            rbp, tiles = panels[row // mn]
            xr = lp[row * kn:(row + 1) * kn]
            orow = out[row * nn:(row + 1) * nn]
            for blk in range(nblk):
                orow[blk * 8:(blk + 1) * 8] = rust_dot8(xr, tiles[blk], kn)
            for j in range(nblk * 8, nn):
                orow[j] = rust_dot(xr, rbp[j])
        return Arr(sh.ty, sh.dims, out)

    # -------------------------------------------------- fused regions ---

    def _match_bin_region(self, comp):
        if len(comp.instrs) != 3 or comp.n_params != 2:
            return None
        p = {}
        for i, ins in enumerate(comp.instrs):
            if ins.opcode == "parameter":
                p[int(ins.attrs["parameter_number"])] = i
        if set(p) != {0, 1}:
            return None
        root = comp.instrs[comp.root]
        if root.opcode not in BINARY or BINARY[root.opcode] is None:
            return None
        if root.operands == [p[0], p[1]]:
            return root.opcode, True
        if root.operands == [p[1], p[0]]:
            return root.opcode, False
        return None

    def reduce(self, sh, opv, a):
        comp = self.m.comps[a["to_apply"]]
        hit = self._match_bin_region(comp)
        if len(opv) != 2 or sh.ty == "tuple" or hit is None:
            return super().reduce(sh, opv, a)
        opcode, acc_first = hit
        fn = BINARY[opcode]
        x, init = opv
        dims = int_list(a["dimensions"])
        kept = [d for d in range(len(x.dims)) if d not in dims]
        out_dims = [x.dims[d] for d in kept]
        red_dims = [x.dims[d] for d in dims]
        xst = strides_of(x.dims)
        ost = strides_of(out_dims)
        rst = strides_of(red_dims)
        rn = 1
        for d in red_dims:
            rn *= d
        n = 1
        for d in out_dims:
            n *= d
        i0 = init.data[0]
        contiguous = all(
            dims[t] == len(x.dims) - len(dims) + t for t in range(len(dims)))
        out = np.empty(n, x.data.dtype)
        for f in range(n):
            if contiguous:
                run = x.data[f * rn:(f + 1) * rn]
                acc = i0
                for v in run:
                    acc = fn(acc, v) if acc_first else fn(v, acc)
            else:
                oi = unflatten(f, out_dims, ost)
                base = sum(oi[k] * xst[d] for k, d in enumerate(kept))
                acc = i0
                for rf in range(rn):
                    ri = unflatten(rf, red_dims, rst)
                    xi = base + sum(ri[k] * xst[d] for k, d in enumerate(dims))
                    v = x.data[xi]
                    acc = fn(acc, v) if acc_first else fn(v, acc)
            out[f] = acc
        return Arr(sh.ty, sh.dims, out)

    def reduce_window(self, sh, opv, a):
        # plan.rs fused reduce-window: same ascending output-cell /
        # row-major window-tap order as the oracle, but folding with the
        # raw scalar helper instead of invoking the region per element.
        comp = self.m.comps[a["to_apply"]]
        hit = self._match_bin_region(comp)
        if len(opv) != 2 or sh.ty == "tuple" or hit is None:
            return super().reduce_window(sh, opv, a)
        opcode, acc_first = hit
        fn = BINARY[opcode]
        x, init = opv
        win = parse_window(a.get("window", "{}"))
        rank = len(x.dims)
        xst = strides_of(x.dims)
        ost = strides_of(sh.dims)
        wdims = [w[0] for w in win]
        wst = strides_of(wdims)
        wn = 1
        for d in wdims:
            wn *= d
        n = sh.numel()
        i0 = init.data[0]
        out = np.empty(n, x.data.dtype)
        for f in range(n):
            oi = unflatten(f, sh.dims, ost)
            acc = i0
            for wf in range(wn):
                wi = unflatten(wf, wdims, wst)
                xi = 0
                ok = True
                for d in range(rank):
                    pos = resolve_window_pos(oi[d], wi[d], win[d], x.dims[d])
                    if pos is None:
                        ok = False
                        break
                    xi += pos * xst[d]
                if not ok:
                    continue
                v = x.data[xi]
                acc = fn(acc, v) if acc_first else fn(v, acc)
            out[f] = acc
        return Arr(sh.ty, sh.dims, out)

    def scatter(self, sh, opv, a):
        comp = self.m.comps[a["to_apply"]]
        hit = self._match_bin_region(comp)
        if hit is None:
            return super().scatter(sh, opv, a)
        opcode, acc_first = hit
        fn = BINARY[opcode]
        operand, indices, updates = opv
        uw_dims = int_list(a.get("update_window_dims", "{}"))
        inserted = int_list(a.get("inserted_window_dims", "{}"))
        ib_dims = int_list(a.get("input_batching_dims", "{}"))
        sb_dims = int_list(a.get("scatter_indices_batching_dims", "{}"))
        sdod = int_list(a.get("scatter_dims_to_operand_dims", "{}"))
        ivd = int(a["index_vector_dim"])
        sdims = [d for d in range(len(indices.dims)) if d != ivd]
        scatter_dims_u = [d for d in range(len(updates.dims)) if d not in uw_dims]
        window_operand_dims = [
            d for d in range(len(operand.dims))
            if d not in inserted and d not in ib_dims
        ]
        out = operand.data.copy()
        pst = strides_of(operand.dims)
        ust = strides_of(updates.dims)
        sst = strides_of(indices.dims)
        for f in range(updates.numel()):
            ui = unflatten(f, updates.dims, ust)
            g = [ui[d] for d in scatter_dims_u]
            full = [0] * len(operand.dims)
            for k, od in enumerate(sdod):
                si = sum(g[j] * sst[sd] for j, sd in enumerate(sdims))
                if ivd < len(indices.dims):
                    si += k * sst[ivd]
                full[od] = int(indices.data[si])
            for od, sd in zip(ib_dims, sb_dims):
                full[od] = g[sdims.index(sd)]
            for k, d in enumerate(window_operand_dims):
                full[d] += ui[uw_dims[k]]
            if not all(0 <= full[d] < operand.dims[d]
                       for d in range(len(operand.dims))):
                continue
            pi = sum(full[d] * pst[d] for d in range(len(operand.dims)))
            cur, upd = out[pi], updates.data[f]
            out[pi] = fn(cur, upd) if acc_first else fn(upd, cur)
        return Arr(sh.ty, sh.dims, out)


# ------------------------------------- fuse.rs counted-loop + threefry ---

def rotl32(v, r):
    """rotl via the HLO composition shl(v,r) | shr(v, 32-r) with XLA
    shift semantics (shift >= 32 yields 0) — exactly `ops::rotl_xla`."""
    shl = (v << r) & 0xFFFFFFFF if r < 32 else 0
    s = (32 - r) & 0xFFFFFFFF
    shr = (v >> s) if s < 32 else 0
    return (shl | shr) & 0xFFFFFFFF


def threefry2x32(x0, x1, rot, k0, k1):
    """ops::threefry2x32 — four rounds + key injection per lane, exact
    u32 wrapping arithmetic (python ints, masked)."""
    out0, out1 = [], []
    for a, b in zip(x0, x1):
        x, y = a, b
        for r in rot:
            x = (x + y) & 0xFFFFFFFF
            y = x ^ rotl32(y, r)
        out0.append((x + k0) & 0xFFFFFFFF)
        out1.append((y + k1) & 0xFFFFFFFF)
    return out0, out1


def match_counted_loop(cond, body):
    """fuse::match_counted_loop 1:1 — returns the full execution spec
    {idx, bound, state_reads, steps, root_ops} or None.

    cond must be {param; gte(param, idx); const scalar; ROOT
    compare(gte, const) LT} modulo dead instructions; body must be a
    single param used only by gte's, ROOT tuple, whose element `idx` is
    add(gte(param, idx), 1). Like the Rust executor, a fused trip
    plumbs the state slots straight into the gte registers and runs
    only `steps` — the parameter, the state reads and the root tuple
    are elided, never executed."""
    params = [i for i, s in enumerate(cond.instrs) if s.opcode == "parameter"]
    if cond.n_params != 1 or len(params) != 1:
        return None
    p = params[0]
    root = cond.instrs[cond.root]
    if (root.opcode != "compare" or root.attrs.get("direction") != "LT"
            or len(root.operands) != 2):
        return None
    ia, ib = cond.instrs[root.operands[0]], cond.instrs[root.operands[1]]
    if ia.opcode != "get-tuple-element" or ia.operands != [p]:
        return None
    if (ib.opcode != "constant" or ib.shape.dims
            or ib.shape.ty not in ("s32", "u32")):
        return None
    idx, bound = int(ia.attrs["index"]), int(ib.literal[0])

    params = [i for i, s in enumerate(body.instrs) if s.opcode == "parameter"]
    if body.n_params != 1 or len(params) != 1:
        return None
    bp = params[0]
    broot = body.instrs[body.root]
    if broot.opcode != "tuple":
        return None
    arity = len(broot.operands)
    if idx >= arity:
        return None
    for s in body.instrs:
        if bp in s.operands and s.opcode != "get-tuple-element":
            return None
        if (s.opcode == "get-tuple-element" and s.operands == [bp]
                and int(s.attrs["index"]) >= arity):
            return None
    inc = body.instrs[broot.operands[idx]]
    if inc.opcode != "add" or len(inc.operands) != 2:
        return None

    def is_counter(i):
        s = body.instrs[i]
        return (s.opcode == "get-tuple-element" and s.operands == [bp]
                and int(s.attrs["index"]) == idx)

    def is_one(i):
        s = body.instrs[i]
        return (s.opcode == "constant" and not s.shape.dims
                and s.shape.ty in ("s32", "u32") and int(s.literal[0]) == 1)

    x, y = inc.operands
    if not ((is_counter(x) and is_one(y)) or (is_counter(y) and is_one(x))):
        return None
    state_reads = [
        (i, int(s.attrs["index"])) for i, s in enumerate(body.instrs)
        if s.opcode == "get-tuple-element" and s.operands == [bp]]
    read_regs = {i for i, _ in state_reads}
    steps = [i for i in range(len(body.instrs))
             if i != bp and i != body.root and i not in read_regs]
    return {"idx": idx, "bound": bound, "state_reads": state_reads,
            "steps": steps, "root_ops": broot.operands}


def match_threefry(comp):
    """fuse::match_threefry 1:1 — structural match of the jax
    threefry2x32 round body via symbolic expression trees (reshape and
    scalar-broadcast are transparent, slice-of-rot-param is a lane)."""
    ins = comp.instrs
    if comp.n_params != 8:
        return False
    ppos = {}
    for i, s in enumerate(ins):
        if s.opcode == "parameter":
            k = int(s.attrs["parameter_number"])
            if k in ppos:
                return False
            ppos[k] = i
    if set(ppos) != set(range(8)):
        return False

    def sh(k):
        return ins[ppos[k]].shape

    if sh(0).ty != "s32" or sh(0).dims:
        return False
    if sh(1).ty != "u32" or sh(2).ty != "u32" or sh(1).dims != sh(2).dims:
        return False
    if any(sh(k).ty != "u32" or sh(k).dims for k in (3, 4, 5)):
        return False
    if any(sh(k).ty != "u32" or sh(k).dims != [4] for k in (6, 7)):
        return False
    root = ins[comp.root]
    if root.opcode != "tuple" or len(root.operands) != 8:
        return False
    # output shapes must be the canonical state shapes: resolve() sees
    # through reshape/broadcast, but the executor rebuilds the result
    # tuple from the input shapes, so a shape-changing wrapper on a
    # root operand must fall back to the generic call
    out_shapes = [sh(0), sh(1), sh(2), sh(4), sh(5), sh(3), sh(7), sh(6)]
    for o, want in zip(root.operands, out_shapes):
        osh = ins[o].shape
        if osh.ty != want.ty or osh.dims != want.dims:
            return False

    memo = {}

    def ex(i):
        if i in memo:
            return memo[i]
        s = ins[i]
        op = s.opcode
        r = None
        if op == "parameter":
            r = ("p", int(s.attrs["parameter_number"]))
        elif op == "constant":
            if not s.shape.dims and s.shape.ty in ("u32", "s32"):
                r = ("c", s.shape.ty, int(s.literal[0]))
        elif op == "reshape":
            r = ex(s.operands[0])
        elif op == "broadcast":
            if ins[s.operands[0]].shape.numel() == 1:
                r = ex(s.operands[0])
        elif op == "convert":
            if s.shape.ty == "u32" and ins[s.operands[0]].shape.ty == "s32":
                sub = ex(s.operands[0])
                r = ("u32", sub) if sub else None
        elif op == "slice":
            o = ins[s.operands[0]]
            spec = parse_slice_attr(s.attrs["slice"])
            if (o.opcode == "parameter" and len(spec) == 1
                    and spec[0][2] == 1 and spec[0][1] == spec[0][0] + 1):
                r = ("lane", int(o.attrs["parameter_number"]), spec[0][0])
        elif op in ("add", "xor", "or", "subtract", "shift-left",
                    "shift-right-logical") and len(s.operands) == 2:
            a_, b_ = ex(s.operands[0]), ex(s.operands[1])
            if a_ is not None and b_ is not None:
                r = (op, a_, b_)
        memo[i] = r
        return r

    def p(k):
        return ("p", k)

    def lane(j):
        return ("lane", 6, j)

    def rot(x, j):
        return ("or", ("shift-left", x, lane(j)),
                ("shift-right-logical", x,
                 ("subtract", ("c", "u32", 32), lane(j))))

    x0 = ("add", p(1), p(2))
    x1 = ("xor", x0, rot(p(2), 0))
    for j in (1, 2, 3):
        x0n = ("add", x0, x1)
        x1 = ("xor", x0n, rot(x1, j))
        x0 = x0n
    out_i = ("add", p(0), ("c", "s32", 1))
    out_x0 = ("add", x0, p(3))
    out_x1 = ("add", ("add", x1, p(4)), ("u32", out_i))
    want = [out_i, out_x0, out_x1, p(4), p(5), p(3), p(7), p(6)]
    return [ex(o) for o in root.operands] == want


# ------------------------------------------------- elementwise chains ---

# fuse.rs `fusable`: Op::Unary | Op::Binary | Op::Select | Op::Compare
# | Op::Convert — broadcast and bitcast-convert are deliberately out.
CHAIN_UNARY = ("negate",) + tuple(UNARY_F32)
CHAIN_FUSABLE = frozenset(CHAIN_UNARY) | frozenset(BINARY) | {
    "select", "compare", "convert"}


def match_chains(comp):
    """fuse.rs match_chains, 1:1: greedily grow maximal single-use
    elementwise cones from the last instruction down; returns
    (root, {steps, inputs, tape}) in ascending root order, where
    inputs are ("full", reg) | ("scalar", reg) slots in first-reference
    order and tape op `t` writes slot `len(inputs) + t`."""
    n = len(comp.instrs)
    uses = [0] * n
    for ins in comp.instrs:
        for o in ins.operands:
            uses[o] += 1
    uses[comp.root] += 1  # the root's value escapes

    def arr_dims(i):
        sh = comp.instrs[i].shape
        return None if sh.ty == "tuple" else tuple(sh.dims)

    def fusable(i):
        return comp.instrs[i].opcode in CHAIN_FUSABLE

    claimed = [False] * n
    out = []
    for root in range(n - 1, -1, -1):
        if claimed[root] or not fusable(root):
            continue
        dims = arr_dims(root)
        if dims is None:
            continue
        member = [False] * n
        member[root] = True
        count = 1
        stack = [root]
        while stack:
            for o in comp.instrs[stack.pop()].operands:
                if (not member[o] and not claimed[o] and fusable(o)
                        and uses[o] == 1 and arr_dims(o) == dims):
                    member[o] = True
                    count += 1
                    stack.append(o)
        if count < 2:
            continue  # a lone step gains nothing from a tape
        members = [i for i in range(root + 1) if member[i]]

        tape_slot = {s: t for t, s in enumerate(members)}
        inputs = []
        folded = []
        in_slot = {}
        ok = True
        for s in members:
            for o in comp.instrs[s].operands:
                if o in tape_slot or o in in_slot:
                    continue
                io = comp.instrs[o]
                fold = (io.opcode == "broadcast" and uses[o] == 1
                        and not claimed[o] and arr_dims(o) == dims
                        and len(io.operands) == 1
                        and comp.instrs[io.operands[0]].shape.numel() == 1
                        and not member[io.operands[0]])
                in_slot[o] = len(inputs)
                if fold:
                    folded.append(o)
                    inputs.append(("scalar", io.operands[0]))
                elif arr_dims(o) == dims:
                    inputs.append(("full", o))
                else:
                    ok = False  # ill-shaped operand: no fusion at all
                    break
            if not ok:
                break
        if not ok or len(inputs) + len(members) > 0xFFFF:
            continue

        n_in = len(inputs)

        def sl(o):
            return n_in + tape_slot[o] if o in tape_slot else in_slot[o]

        tape = []
        for s in members:
            ins = comp.instrs[s]
            op, oty, opr = ins.opcode, ins.shape.ty, ins.operands
            if op in CHAIN_UNARY and len(opr) == 1:
                tape.append(("unary", op, oty, sl(opr[0])))
            elif op in BINARY and len(opr) == 2:
                tape.append(("binary", op, oty, sl(opr[0]), sl(opr[1])))
            elif op == "compare" and len(opr) == 2:
                sty = comp.instrs[opr[0]].shape.ty
                tape.append(("compare", ins.attrs["direction"], sty,
                             sl(opr[0]), sl(opr[1])))
            elif op == "select" and len(opr) == 3:
                tape.append(("select", sl(opr[0]), sl(opr[1]), sl(opr[2])))
            elif op == "convert" and len(opr) == 1:
                sty = comp.instrs[opr[0]].shape.ty
                tape.append(("convert", sty, oty, sl(opr[0])))
            else:
                ok = False  # unexpected arity: fall back
                break
        if not ok:
            continue

        steps = sorted([s for s in members if s != root] + folded)
        for s in steps:
            claimed[s] = True
        claimed[root] = True
        out.append((root, {"steps": steps, "inputs": inputs, "tape": tape}))
    out.reverse()
    return out


def tape_step(op, slots):
    """One chain tape op over full slot arrays — the same arithmetic as
    the reference eval_instr arms, so per-element Rust == this."""
    kind = op[0]
    if kind == "unary":
        _, name, ty, a = op
        x = slots[a]
        out = -x if name == "negate" else UNARY_F32[name](x)
        return out.astype(NP_TY[ty], copy=False)
    if kind == "binary":
        _, name, ty, a, b = op
        l, r = slots[a], slots[b]
        if name in ("shift-left", "shift-right-logical"):
            amt = r.astype(np.uint64)
            big = amt >= 32
            sh_amt = np.where(big, 0, amt).astype(np.uint32)
            shifted = (np.left_shift(l, sh_amt) if name == "shift-left"
                       else np.right_shift(l, sh_amt))
            out = np.where(big, np.uint32(0), shifted)
        else:
            with np.errstate(all="ignore"):
                out = BINARY[name](l, r)
        return out.astype(NP_TY[ty], copy=False)
    if kind == "compare":
        _, dirn, _sty, a, b = op
        fn = {"EQ": np.equal, "NE": np.not_equal, "LT": np.less,
              "LE": np.less_equal, "GT": np.greater,
              "GE": np.greater_equal}[dirn]
        return fn(slots[a], slots[b])
    if kind == "select":
        _, p, t, f = op
        return np.where(slots[p].astype(bool), slots[t], slots[f])
    _, sty, ty, a = op  # convert
    x = slots[a]
    if ty == "u32" and sty == "s32":
        return x.astype(np.int64).astype(np.uint32)
    if ty == "s32" and sty == "f32":
        return np.trunc(x).astype(np.int32)
    return x.astype(NP_TY[ty])


# The diamond fixture from fuse.rs `chain_matches_cone_with_diamond_and_splat`
CHAIN_FIXTURE = """HloModule t

ENTRY main.1 {
  x.1 = f32[4]{0} parameter(0)
  c.2 = f32[] constant(2)
  b.3 = f32[4]{0} broadcast(c.2), dimensions={}
  e.4 = f32[4]{0} exponential(x.1)
  m.5 = f32[4]{0} multiply(e.4, b.3)
  p.6 = pred[4]{0} compare(x.1, e.4), direction=LT
  ROOT s.7 = f32[4]{0} select(p.6, m.5, x.1)
}
"""


def check_chain_matcher():
    """Pin the matcher's canonical form to the fuse.rs unit test and
    check the tape execution bitwise against the plain interpreter."""
    m = parse_module(CHAIN_FIXTURE)
    comp = m.comps[m.entry]
    chains = match_chains(comp)
    assert len(chains) == 1, chains
    root, spec = chains[0]
    assert root == 6, root
    assert spec["steps"] == [2, 4, 5], spec["steps"]
    assert spec["inputs"] == [("full", 3), ("scalar", 1), ("full", 0)], \
        spec["inputs"]
    assert spec["tape"] == [
        ("binary", "multiply", "f32", 0, 1),
        ("compare", "LT", "f32", 2, 0),
        ("select", 4, 3, 2),
    ], spec["tape"]
    x = Arr("f32", [4], np.array([-1.5, 0.0, 0.25, 3.0], np.float32))
    fi = FusedInterp(m)
    got = fi.run_entry([x])
    want = Interp(m).run_entry([x])
    assert_same(got, want, "chain fixture")
    assert fi.fused_chains == 1 and fi.chain_steps == 3
    print("chain matcher == fuse.rs canonical form; tape bitwise vs "
          "tree-walk  OK")


class FusedInterp(PlannedInterp):
    """Planned mirror with the loop-fusion layer: counted `while` loops
    skip per-iteration condition evaluation (trip count read from the
    initial state), threefry round-body calls run the native kernel,
    and single-use elementwise cones run as one chain superinstruction
    with their interior steps elided."""

    def __init__(self, module):
        super().__init__(module)
        self._counted = {}
        self._threefry = {}
        self._chains = {}
        self.fused_whiles = 0
        self.generic_whiles = 0
        self.threefry_calls = 0
        self.fused_chains = 0
        self.chain_steps = 0

    def chains_of(self, comp):
        hit = self._chains.get(comp.name)
        if hit is None:
            matches = match_chains(comp)
            roots = dict(matches)
            elided = frozenset(
                s for _, spec in matches for s in spec["steps"])
            hit = self._chains[comp.name] = (roots, elided)
        return hit

    def elided_of(self, comp):
        return self.chains_of(comp)[1]

    def run(self, comp, args):
        roots, elided = self.chains_of(comp)
        if not roots:
            return super().run(comp, args)
        env = [None] * len(comp.instrs)
        for i, ins in enumerate(comp.instrs):
            if i in elided:
                continue  # interior: never evaluated, register never written
            if i in roots:
                env[i] = self.chain_exec(comp, i, roots[i], env)
            else:
                env[i] = self.eval_instr(comp, ins, env, args)
        return env[comp.root]

    def chain_exec(self, comp, root, spec, env):
        sh = comp.instrs[root].shape
        n = sh.numel()
        slots = []
        for kind, reg in spec["inputs"]:
            v = env[reg]
            if kind == "scalar":
                # folded broadcast: splat the source's lone element
                slots.append(np.broadcast_to(v.data.ravel()[:1], (n,)))
            else:
                slots.append(v.data)
        for op in spec["tape"]:
            slots.append(tape_step(op, slots))
        self.fused_chains += 1
        self.chain_steps += len(spec["steps"])
        return Arr(sh.ty, sh.dims, slots[-1])

    def counted_trip(self, body, spec, state):
        """One fused counted-loop iteration, exactly the Rust
        `Executor::counted_loop` body: state slots plumbed straight
        into the gte registers, only `steps` executed (parameter, state
        reads and the root tuple are elided), chains apply inside."""
        env = [None] * len(body.instrs)
        for gi, e in spec["state_reads"]:
            env[gi] = state[e]
        roots, elided = self.chains_of(body)
        for i in spec["steps"]:
            if i in elided:
                continue
            if i in roots:
                env[i] = self.chain_exec(body, i, roots[i], env)
            else:
                env[i] = self.eval_instr(body, body.instrs[i], env, ())
        return [env[o] for o in spec["root_ops"]]

    def counted(self, cond_name, body_name):
        key = (cond_name, body_name)
        if key not in self._counted:
            self._counted[key] = match_counted_loop(
                self.m.comps[cond_name], self.m.comps[body_name])
        return self._counted[key]

    def is_threefry(self, name):
        if name not in self._threefry:
            self._threefry[name] = match_threefry(self.m.comps[name])
        return self._threefry[name]

    def eval_instr(self, comp, ins, env, args):
        if ins.opcode == "while":
            hit = self.counted(ins.attrs["condition"], ins.attrs["body"])
            if hit is not None:
                body = self.m.comps[ins.attrs["body"]]
                state = list(env[ins.operands[0]][1])
                start = int(state[hit["idx"]].data[0])
                trips = max(0, hit["bound"] - start)
                self.fused_whiles += 1
                for _ in range(trips):
                    state = self.counted_trip(body, hit, state)
                return ("tuple", state)
            self.generic_whiles += 1
        elif ins.opcode == "call" and self.is_threefry(ins.attrs["to_apply"]):
            self.threefry_calls += 1
            return self.threefry_call([env[j] for j in ins.operands])
        return super().eval_instr(comp, ins, env, args)

    def threefry_call(self, opv):
        i, x0, x1, k0, k1, k2, rota, rotb = opv
        new_i = int(i.data[0]) + 1           # s32 wrapping add
        if new_i > 0x7FFFFFFF:
            new_i -= 1 << 32
        rot = [int(v) for v in rota.data]
        kx0 = int(k0.data[0])
        kx1 = (int(k1.data[0]) + (new_i & 0xFFFFFFFF)) & 0xFFFFFFFF
        o0, o1 = threefry2x32([int(v) for v in x0.data],
                              [int(v) for v in x1.data], rot, kx0, kx1)
        return ("tuple", [
            Arr("s32", [], [new_i]),
            Arr("u32", x0.dims, o0),
            Arr("u32", x1.dims, o1),
            k1, k2, k0, rotb, rota,
        ])


# ------------------------------------------ assign.rs dot8 lane kernel ---

def rust_dot(a, b):
    """quant::assign::dot — 4-way unrolled f32 dot, bit-exact."""
    n = len(a)
    s = [np.float32(0.0)] * 4
    n4 = n - n % 4
    i = 0
    while i < n4:
        for t in range(4):
            s[t] = np.float32(s[t] + np.float32(a[i + t] * b[i + t]))
        i += 4
    acc = np.float32(np.float32(s[0] + s[1]) + np.float32(s[2] + s[3]))
    while i < n:
        acc = np.float32(acc + np.float32(a[i] * b[i]))
        i += 1
    return acc


def rust_dot8(p, tile, d):
    """quant::assign::dot8 — 8 lanes against a [d][8] transposed tile."""
    s = [np.zeros(8, np.float32) for _ in range(4)]
    d4 = d - d % 4
    t = 0
    while t < d4:
        for q in range(4):
            r = tile[(t + q) * 8:(t + q + 1) * 8]
            s[q] = np.float32(s[q] + np.float32(np.float32(p[t + q]) * r))
        t += 4
    out = np.float32(np.float32(s[0] + s[1]) + np.float32(s[2] + s[3]))
    while t < d:
        r = tile[t * 8:(t + 1) * 8]
        out = np.float32(out + np.float32(np.float32(p[t]) * r))
        t += 1
    return out


def check_dot8():
    rng = np.random.default_rng(0)
    for d in (1, 2, 3, 4, 7, 8, 9, 16, 31):
        p = rng.standard_normal(d).astype(np.float32)
        cents = rng.standard_normal((8, d)).astype(np.float32)
        tile = np.ascontiguousarray(cents.T).reshape(-1)  # [d][8]
        got = rust_dot8(p, tile, d)
        for lane in range(8):
            want = rust_dot(p, cents[lane])
            assert got[lane].tobytes() == want.tobytes(), (d, lane)
    print("dot8 lane kernel == scalar 4-way dot, bitwise, d in 1..31  OK")


# ----------------------------------------------------------- fixture ---

def bits(x):
    return np.asarray(x).tobytes()


def assert_same(a, b, path):
    if isinstance(a, tuple):
        assert isinstance(b, tuple) and len(a[1]) == len(b[1]), path
        for i, (x, y) in enumerate(zip(a[1], b[1])):
            assert_same(x, y, f"{path}.{i}")
        return
    assert a.dims == b.dims, (path, a.dims, b.dims)
    assert bits(a.data) == bits(b.data), f"{path}: payload differs"


def fixture_args(model, grad, rate=0.5, seed=42):
    import json
    import struct
    man = json.load(open(os.path.join(FIX, "manifest.json")))
    meta = man["models"][model]
    with open(os.path.join(FIX, meta["init"]), "rb") as f:
        assert f.read(4) == b"QNP1"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        params = []
        for p in header["params"]:
            numel = int(np.prod(p["shape"])) if p["shape"] else 1
            data = np.frombuffer(f.read(4 * numel), np.float32)
            params.append(Arr("f32", list(p["shape"]), data))
    n_layers = meta["n_layers"]
    if meta["task"] == "img":
        # same deterministic inputs as tests/runtime_integration.rs
        tsh = meta["tokens_shape"]
        n = int(np.prod(tsh))
        tokens = Arr("f32", tsh, [(i % 256) / 255.0 for i in range(n)])
        targets = Arr(
            "s32", meta["targets_shape"],
            [i % meta["n_classes"] for i in range(meta["targets_shape"][0])])
    else:
        b, t = meta["tokens_shape"]
        vocab = meta["config"]["vocab"]
        tokens = Arr("s32", [b, t], [(i * 7 + 3) % vocab for i in range(b * t)])
        targets = Arr("s32", [b, t], [(i * 5 + 1) % vocab for i in range(b * t)])
    keep = Arr("f32", [n_layers], [1.0] * n_layers)
    args = list(params)
    if grad:
        args += [Arr("f32", p.dims, np.zeros(max(p.numel(), 1), np.float32))
                 for p in params]
    args += [tokens, targets, keep]
    if grad:
        args += [Arr("f32", [], [rate]), Arr("s32", [], [seed])]
    return args


class Counting:
    """Mixin: count instruction executions, bucketed by opcode. The
    count follows what the Rust executor actually runs: chain interiors
    and a fused counted trip's state plumbing (parameter, state gte's,
    root tuple) are elided — never executed — so a chain-aware interp's
    count reflects one superinstruction per chain (its root opcode)
    plus only the live body steps per loop trip."""

    def _bump(self, opcode):
        hist = getattr(self, "hist", None)
        if hist is None:
            hist = self.hist = {}
        hist[opcode] = hist.get(opcode, 0) + 1

    def run(self, comp, args):
        elided = self.elided_of(comp) if hasattr(self, "elided_of") else ()
        for i, ins in enumerate(comp.instrs):
            if i not in elided:
                self._bump(ins.opcode)
        return super().run(comp, args)

    def counted_trip(self, body, spec, state):
        elided = self.elided_of(body)
        for i in spec["steps"]:
            if i not in elided:
                self._bump(body.instrs[i].opcode)
        return super().counted_trip(body, spec, state)


class CountingInterp(Counting, Interp):
    pass


class CountingFused(Counting, FusedInterp):
    pass


def check_fixture(model, entry, grad, rate=0.5, seed=42):
    text = open(os.path.join(FIX, f"{model}.{entry}.hlo.txt")).read()
    m = parse_module(text)
    args = fixture_args(model, grad, rate, seed)
    t0 = time.perf_counter()
    ref_i = CountingInterp(m)
    ref = ref_i.run_entry(args)
    t_ref = time.perf_counter() - t0
    planned = PlannedInterp(m).run_entry(args)
    assert_same(planned, ref, entry)
    t0 = time.perf_counter()
    fused_i = CountingFused(m)
    fused = fused_i.run_entry(args)
    t_fused = time.perf_counter() - t0
    assert_same(fused, ref, f"{entry}(fused)")
    n_out = len(ref[1])
    n_ref = sum(ref_i.hist.values())
    n_fused = sum(fused_i.hist.values())
    print(f"{model}.{entry}: planned+fused kernels bit-identical to reference "
          f"({n_out} outputs)  OK")
    print(f"  instr executions: reference {n_ref}, fused {n_fused} "
          f"({n_ref / max(n_fused, 1):.2f}x fewer); mirror wall-clock "
          f"{t_ref:.2f}s -> {t_fused:.2f}s")
    print(f"  fused chains: {fused_i.fused_chains} superinstruction runs, "
          f"{fused_i.chain_steps} interior steps elided")
    assert fused_i.fused_chains > 0, "no elementwise chain fused"
    if grad:
        # every threefry while must fuse — a fallback storm here means
        # the matchers regressed against the real jax lowering
        assert fused_i.generic_whiles == 0, fused_i.generic_whiles
        assert fused_i.fused_whiles > 0 and fused_i.threefry_calls > 0
        top = sorted(ref_i.hist.items(), key=lambda kv: -kv[1])[:6]
        print(f"  fused whiles: {fused_i.fused_whiles}, native threefry "
              f"calls: {fused_i.threefry_calls}")
        print(f"  reference opcode histogram (top): {top}")
    return n_fused


def check_lm_base():
    """The paper-scale bench module (tools/qnsim/gen_lm_base.py): run
    the generator at reduced dims — the emitted structure is identical,
    only the shape numbers in the text change, and the full-size bit-
    faithful mirror dot is prohibitively slow — and assert the fused
    mirror is bit-identical to the reference walk, that the per-layer
    relu/scale/residual and select/scale chains actually fuse, and the
    (dim-independent) executed-instruction census."""
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "gen_lm_base", os.path.join(HERE, "gen_lm_base.py"))
    gen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(gen)
    B, D, L = 4, 64, 12
    m = parse_module(gen.generate(B, D, L))
    args = [Arr("f32", [B, D],
                (np.arange(B * D, dtype=np.int64) % 97)
                .astype(np.float32) / 97.0 - 0.5)]
    for l in range(L):
        w = (((np.arange(D * D, dtype=np.int64) * 31 + l) % 113)
             .astype(np.float32) / 113.0 - 0.5) * 0.02
        args.append(Arr("f32", [D, D], w))
        b = ((np.arange(D, dtype=np.int64) + l) % 7)\
            .astype(np.float32) / 7.0 - 0.5
        args.append(Arr("f32", [D], b))
    args = tuple(args)
    ref_i = CountingInterp(m)
    ref = ref_i.run_entry(args)
    fused_i = CountingFused(m)
    fused = fused_i.run_entry(args)
    assert_same(fused, ref, "lm_base_grad(fused)")
    n_ref = sum(ref_i.hist.values())
    n_fused = sum(fused_i.hist.values())
    # one fwd chain + one bwd chain per layer, plus grad accumulators
    assert fused_i.fused_chains >= 2 * L, fused_i.fused_chains
    print(f"lm_base (generator, D={D}): fused bit-identical to reference "
          f"(3 outputs)  OK")
    print(f"  instr executions: reference walk {n_ref}, fused {n_fused}; "
          f"{fused_i.fused_chains} chains / {fused_i.chain_steps} elided "
          f"(counts are dim-independent — same at D=1024)")


# A self-contained counted threefry while (regions copied verbatim from
# the fixture, lanes=1) used to pin the exact u32 trajectory in the Rust
# regression test (tests/interp_fuse.rs) — integer-only, so the pinned
# values are platform-exact. The checked-in copy this validates is
# rust/tests/fixtures/interp/threefry_pin.hlo.txt.
THREEFRY_PIN = """HloModule threefry_pin

None.163 {
  Arg_0.164 = s32[] parameter(0)
  constant.173 = s32[] constant(1)
  add.174 = s32[] add(Arg_0.164, constant.173)
  Arg_1.165 = u32[1]{0} parameter(1)
  Arg_2.166 = u32[1]{0} parameter(2)
  add.177 = u32[1]{0} add(Arg_1.165, Arg_2.166)
  Arg_6.170 = u32[4]{0} parameter(6)
  slice.175 = u32[1]{0} slice(Arg_6.170), slice={[0:1]}
  shift-left.178 = u32[1]{0} shift-left(Arg_2.166, slice.175)
  constant.172 = u32[] constant(32)
  reshape.176 = u32[] reshape(slice.175)
  subtract.179 = u32[] subtract(constant.172, reshape.176)
  reshape.180 = u32[1]{0} reshape(subtract.179)
  shift-right-logical.181 = u32[1]{0} shift-right-logical(Arg_2.166, reshape.180)
  or.182 = u32[1]{0} or(shift-left.178, shift-right-logical.181)
  xor.183 = u32[1]{0} xor(add.177, or.182)
  add.186 = u32[1]{0} add(add.177, xor.183)
  slice.184 = u32[1]{0} slice(Arg_6.170), slice={[1:2]}
  shift-left.187 = u32[1]{0} shift-left(xor.183, slice.184)
  reshape.185 = u32[] reshape(slice.184)
  subtract.188 = u32[] subtract(constant.172, reshape.185)
  reshape.189 = u32[1]{0} reshape(subtract.188)
  shift-right-logical.190 = u32[1]{0} shift-right-logical(xor.183, reshape.189)
  or.191 = u32[1]{0} or(shift-left.187, shift-right-logical.190)
  xor.192 = u32[1]{0} xor(add.186, or.191)
  add.195 = u32[1]{0} add(add.186, xor.192)
  slice.193 = u32[1]{0} slice(Arg_6.170), slice={[2:3]}
  shift-left.196 = u32[1]{0} shift-left(xor.192, slice.193)
  reshape.194 = u32[] reshape(slice.193)
  subtract.197 = u32[] subtract(constant.172, reshape.194)
  reshape.198 = u32[1]{0} reshape(subtract.197)
  shift-right-logical.199 = u32[1]{0} shift-right-logical(xor.192, reshape.198)
  or.200 = u32[1]{0} or(shift-left.196, shift-right-logical.199)
  xor.201 = u32[1]{0} xor(add.195, or.200)
  add.204 = u32[1]{0} add(add.195, xor.201)
  Arg_3.167 = u32[] parameter(3)
  reshape.211 = u32[1]{0} reshape(Arg_3.167)
  add.212 = u32[1]{0} add(add.204, reshape.211)
  slice.202 = u32[1]{0} slice(Arg_6.170), slice={[3:4]}
  shift-left.205 = u32[1]{0} shift-left(xor.201, slice.202)
  reshape.203 = u32[] reshape(slice.202)
  subtract.206 = u32[] subtract(constant.172, reshape.203)
  reshape.207 = u32[1]{0} reshape(subtract.206)
  shift-right-logical.208 = u32[1]{0} shift-right-logical(xor.201, reshape.207)
  or.209 = u32[1]{0} or(shift-left.205, shift-right-logical.208)
  xor.210 = u32[1]{0} xor(add.204, or.209)
  Arg_4.168 = u32[] parameter(4)
  reshape.213 = u32[1]{0} reshape(Arg_4.168)
  add.214 = u32[1]{0} add(xor.210, reshape.213)
  add.215 = s32[] add(Arg_0.164, constant.173)
  convert.216 = u32[] convert(add.215)
  reshape.217 = u32[1]{0} reshape(convert.216)
  add.218 = u32[1]{0} add(add.214, reshape.217)
  Arg_5.169 = u32[] parameter(5)
  Arg_7.171 = u32[4]{0} parameter(7)
  ROOT tuple.219 = (s32[], u32[1]{0}, u32[1]{0}, u32[], u32[], /*index=5*/u32[], u32[4]{0}, u32[4]{0}) tuple(add.174, add.212, add.218, Arg_4.168, Arg_5.169, Arg_3.167, Arg_7.171, Arg_6.170)
}

region_0.220 {
  arg_tuple.221 = (s32[], s32[], u32[1]{0}, u32[1]{0}, u32[], /*index=5*/u32[], u32[], u32[4]{0}, u32[4]{0}) parameter(0)
  get-tuple-element.222 = s32[] get-tuple-element(arg_tuple.221), index=0
  constant.231 = s32[] constant(1)
  add.241 = s32[] add(get-tuple-element.222, constant.231)
  get-tuple-element.223 = s32[] get-tuple-element(arg_tuple.221), index=1
  get-tuple-element.224 = u32[1]{0} get-tuple-element(arg_tuple.221), index=2
  get-tuple-element.225 = u32[1]{0} get-tuple-element(arg_tuple.221), index=3
  get-tuple-element.226 = u32[] get-tuple-element(arg_tuple.221), index=4
  get-tuple-element.227 = u32[] get-tuple-element(arg_tuple.221), index=5
  get-tuple-element.228 = u32[] get-tuple-element(arg_tuple.221), index=6
  get-tuple-element.229 = u32[4]{0} get-tuple-element(arg_tuple.221), index=7
  get-tuple-element.230 = u32[4]{0} get-tuple-element(arg_tuple.221), index=8
  call.232 = (s32[], u32[1]{0}, u32[1]{0}, u32[], u32[], /*index=5*/u32[], u32[4]{0}, u32[4]{0}) call(get-tuple-element.223, get-tuple-element.224, get-tuple-element.225, get-tuple-element.226, get-tuple-element.227, get-tuple-element.228, get-tuple-element.229, get-tuple-element.230), to_apply=None.163
  get-tuple-element.233 = s32[] get-tuple-element(call.232), index=0
  get-tuple-element.234 = u32[1]{0} get-tuple-element(call.232), index=1
  get-tuple-element.235 = u32[1]{0} get-tuple-element(call.232), index=2
  get-tuple-element.236 = u32[] get-tuple-element(call.232), index=3
  get-tuple-element.237 = u32[] get-tuple-element(call.232), index=4
  get-tuple-element.238 = u32[] get-tuple-element(call.232), index=5
  get-tuple-element.239 = u32[4]{0} get-tuple-element(call.232), index=6
  get-tuple-element.240 = u32[4]{0} get-tuple-element(call.232), index=7
  ROOT tuple.242 = (s32[], s32[], u32[1]{0}, u32[1]{0}, u32[], /*index=5*/u32[], u32[], u32[4]{0}, u32[4]{0}) tuple(add.241, get-tuple-element.233, get-tuple-element.234, get-tuple-element.235, get-tuple-element.236, get-tuple-element.237, get-tuple-element.238, get-tuple-element.239, get-tuple-element.240)
}

region_1.243 {
  arg_tuple.244 = (s32[], s32[], u32[1]{0}, u32[1]{0}, u32[], /*index=5*/u32[], u32[], u32[4]{0}, u32[4]{0}) parameter(0)
  get-tuple-element.245 = s32[] get-tuple-element(arg_tuple.244), index=0
  constant.254 = s32[] constant(5)
  ROOT compare.255 = pred[] compare(get-tuple-element.245, constant.254), direction=LT
}

ENTRY main.1 {
  x0.1 = u32[1]{0} parameter(0)
  x1.2 = u32[1]{0} parameter(1)
  k0.3 = u32[] parameter(2)
  k1.4 = u32[] parameter(3)
  k2.5 = u32[] parameter(4)
  z.6 = s32[] constant(0)
  ra.7 = u32[4]{0} constant({13, 15, 26, 6})
  rb.8 = u32[4]{0} constant({17, 29, 16, 24})
  st.9 = (s32[], s32[], u32[1]{0}, u32[1]{0}, u32[], /*index=5*/u32[], u32[], u32[4]{0}, u32[4]{0}) tuple(z.6, z.6, x0.1, x1.2, k0.3, k1.4, k2.5, ra.7, rb.8)
  w.10 = (s32[], s32[], u32[1]{0}, u32[1]{0}, u32[], /*index=5*/u32[], u32[], u32[4]{0}, u32[4]{0}) while(st.9), condition=region_1.243, body=region_0.220
  o0.11 = u32[1]{0} get-tuple-element(w.10), index=2
  o1.12 = u32[1]{0} get-tuple-element(w.10), index=3
  ROOT t.13 = (u32[1]{0}, u32[1]{0}) tuple(o0.11, o1.12)
}
"""

PIN_ARGS = [
    Arr("u32", [1], [0x1BD11BDA]),
    Arr("u32", [1], [0xDEADBEEF]),
    Arr("u32", [], [42]),
    Arr("u32", [], [7]),
    Arr("u32", [], [0x1BD11BDA ^ 42 ^ 7]),
]


# A self-contained reduce-window module exercising the window geometry
# corners the img fixture doesn't reach (img_tiny pools via plain
# `reduce`): max pool with asymmetric padding, add pool SAME-style,
# window dilation, and a non-binary region that must take the generic
# fold path. The checked-in copy (window_pin.hlo.txt) is include_str!'d
# by tests/interp_conv.rs and linted in CI.
WINDOW_PIN = """HloModule window_pin

max_region {
  a.1 = f32[] parameter(0)
  b.2 = f32[] parameter(1)
  ROOT m.3 = f32[] maximum(a.1, b.2)
}

add_region {
  a.4 = f32[] parameter(0)
  b.5 = f32[] parameter(1)
  ROOT s.6 = f32[] add(a.4, b.5)
}

sumsq_region {
  a.7 = f32[] parameter(0)
  b.8 = f32[] parameter(1)
  sq.9 = f32[] multiply(b.8, b.8)
  ROOT s.10 = f32[] add(a.7, sq.9)
}

ENTRY main.11 {
  x.1 = f32[2,5,6]{2,1,0} parameter(0)
  ninf.2 = f32[] constant(-3e38)
  zero.3 = f32[] constant(0)
  mp.4 = f32[2,3,3]{2,1,0} reduce-window(x.1, ninf.2), window={size=1x2x2 stride=1x2x2 pad=0_0x0_1x0_1}, to_apply=max_region
  ap.5 = f32[2,5,6]{2,1,0} reduce-window(x.1, zero.3), window={size=1x3x3 pad=0_0x1_1x1_1}, to_apply=add_region
  dl.6 = f32[2,3,2]{2,1,0} reduce-window(x.1, zero.3), window={size=1x2x2 stride=1x1x2 rhs_dilate=1x2x2}, to_apply=add_region
  gn.7 = f32[2,2,3]{2,1,0} reduce-window(x.1, zero.3), window={size=1x3x2 stride=1x2x2}, to_apply=sumsq_region
  ROOT t.8 = (f32[2,3,3]{2,1,0}, f32[2,5,6]{2,1,0}, f32[2,3,2]{2,1,0}, f32[2,2,3]{2,1,0}) tuple(mp.4, ap.5, dl.6, gn.7)
}
"""

WINDOW_PIN_ARGS = [Arr(
    "f32", [2, 5, 6],
    [((i * 37 + 11) % 101) * 0.25 - 12.0 for i in range(60)])]


def check_window_pin():
    checked_in = open(os.path.join(FIX, "window_pin.hlo.txt")).read()
    assert checked_in == WINDOW_PIN, "window_pin.hlo.txt drifted"
    m = parse_module(WINDOW_PIN)
    fused_i = FusedInterp(m)
    assert fused_i._match_bin_region(m.comps["max_region"]) == ("maximum", True)
    assert fused_i._match_bin_region(m.comps["add_region"]) == ("add", True)
    assert fused_i._match_bin_region(m.comps["sumsq_region"]) is None
    ref = Interp(m).run_entry(WINDOW_PIN_ARGS)
    fused = fused_i.run_entry(WINDOW_PIN_ARGS)
    assert_same(fused, ref, "window_pin")
    heads = [" ".join(f"{float(v):g}" for v in arr.data[:3]) for arr in ref[1]]
    print(f"window pin (max/add/dilated/generic pools): fused == oracle "
          f"bitwise; heads: {' | '.join(heads)}  OK")


def check_threefry_pin():
    # the Rust test include_str!s the checked-in copy; keep them equal
    checked_in = open(os.path.join(FIX, "threefry_pin.hlo.txt")).read()
    assert checked_in == THREEFRY_PIN, "threefry_pin.hlo.txt drifted"
    m = parse_module(THREEFRY_PIN)
    fused_i = FusedInterp(m)
    assert match_threefry(m.comps["None.163"]), "round body must match"
    spec = fused_i.counted("region_1.243", "region_0.220")
    assert (spec["idx"], spec["bound"]) == (0, 5), spec
    ref = Interp(m).run_entry(PIN_ARGS)
    fused = fused_i.run_entry(PIN_ARGS)
    assert_same(fused, ref, "threefry_pin")
    assert fused_i.fused_whiles == 1 and fused_i.threefry_calls == 5
    o0, o1 = (int(v.data[0]) for v in ref[1])
    print(f"threefry pin (5 fused iterations): x0=0x{o0:08X} x1=0x{o1:08X}  "
          f"OK (hardcoded in tests/interp_fuse.rs)")


# Executed-instruction count for lm_tiny.grad_mix before the chain
# pass; the pass must cut it by >= 1.5x (the tentpole acceptance bar).
PRE_CHAIN_GRAD_MIX = 9389


def main():
    check_dot8()
    check_chain_matcher()
    check_threefry_pin()
    check_window_pin()
    check_fixture("lm_tiny", "eval", grad=False)
    n = check_fixture("lm_tiny", "grad_mix", grad=True)
    assert 2 * PRE_CHAIN_GRAD_MIX >= 3 * n, \
        f"chain elision below 1.5x: {PRE_CHAIN_GRAD_MIX} -> {n}"
    print(f"  chain acceptance: grad_mix {PRE_CHAIN_GRAD_MIX} -> {n} "
          f"executed instructions ({PRE_CHAIN_GRAD_MIX / n:.2f}x)  OK")
    check_fixture("img_tiny", "eval", grad=False)
    check_fixture("img_tiny", "grad_mix", grad=True)
    check_fixture("img_tiny", "grad_mix", grad=True, rate=0.9, seed=7)
    check_lm_base()
    print("PLANNED+FUSED KERNELS VALIDATED (bitwise) against the "
          "reference mirror")


if __name__ == "__main__":
    main()
