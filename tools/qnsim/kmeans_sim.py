"""Exact-f32 simulation of quant::assign engine + quant::kmeans + pq::fit."""
import numpy as np
from pcg import Pcg

F32 = np.float32


def dist2_seed(P, C):
    """Seed's sequential f32 dist2 for all (point, centroid) pairs.
    P: (n, d) f32, C: (k, d) f32 -> (n, k) f32, accumulation in t order."""
    n, d = P.shape
    k = C.shape[0]
    acc = np.zeros((n, k), dtype=np.float32)
    for t in range(d):
        diff = (P[:, None, t] - C[None, :, t]).astype(np.float32)
        acc = (acc + (diff * diff).astype(np.float32)).astype(np.float32)
    return acc


def dot_engine_pair(A, B):
    """Engine's 4-way unrolled f32 dot along the last axis, broadcast.
    A: (..., d), B: (..., d) -> (...) f32 with exact accumulation order."""
    d = A.shape[-1]
    n4 = d - d % 4
    s = [np.zeros(np.broadcast_shapes(A.shape[:-1], B.shape[:-1]), dtype=np.float32)
         for _ in range(4)]
    i = 0
    while i < n4:
        for lane in range(4):
            prod = (A[..., i + lane] * B[..., i + lane]).astype(np.float32)
            s[lane] = (s[lane] + prod).astype(np.float32)
        i += 4
    acc = ((s[0] + s[1]).astype(np.float32) + (s[2] + s[3]).astype(np.float32)).astype(np.float32)
    while i < d:
        acc = (acc + (A[..., i] * B[..., i]).astype(np.float32)).astype(np.float32)
        i += 1
    return acc


def engine_assign(P, C, want_dists=True):
    """assign::assign — codes, dists, objective. P: (n,d), C: (k,d)."""
    norms = dot_engine_pair(C, C)                      # (k,)
    dots = dot_engine_pair(P[:, None, :], C[None, :, :])  # (n, k)
    v = (norms[None, :] - (F32(2.0) * dots).astype(np.float32)).astype(np.float32)
    codes = np.argmin(v, axis=1)  # first-min, matches strict < scan
    best = v[np.arange(len(codes)), codes]
    if not want_dists:
        return codes.astype(np.uint32), None, None
    pn = dot_engine_pair(P, P)
    dists = np.maximum((best + pn).astype(np.float32), F32(0.0))
    objective = float(np.sum(dists.astype(np.float64)))
    return codes.astype(np.uint32), dists, objective


def init_pp(P, k, rng):
    n, d = P.shape
    first = rng.below(n)
    cents = [P[first].copy()]
    dists = dist2_seed(P, np.array([cents[0]]))[:, 0].copy()
    for _ in range(1, k):
        total = 0.0
        for x in dists:           # sequential f64 sum, iterator order
            total += float(x)
        if total <= 0.0:
            nxt = rng.below(n)
        else:
            target = rng.next_f64() * total
            pick = n - 1
            for i, w in enumerate(dists):
                target -= float(w)
                if target <= 0.0:
                    pick = i
                    break
            nxt = pick
        c = P[nxt].copy()
        cents.append(c)
        dd = dist2_seed(P, np.array([c]))[:, 0]
        mask = dd < dists
        dists[mask] = dd[mask]
    return np.array(cents, dtype=np.float32)


def kmeans(P, k_req, max_iters, tol, rng, collect_assign_checks=False):
    n, d = P.shape
    k = min(k_req, n)
    if n <= k:
        cents = np.zeros((k, d), dtype=np.float32)
        cents[:n] = P
        return dict(centroids=cents, k=k, assignments=np.arange(n, dtype=np.uint32),
                    history=[0.0])
    C = init_pp(P, k, rng)
    history = []
    last_obj = float("inf")

    def assign_step(P, C):
        # engine argmin; dists/objective recomputed with exact dist2
        # (mirrors kmeans::assign_step post-review)
        codes, _, _ = engine_assign(P, C, want_dists=False)
        true_d = dist2_seed(P, C)
        dists = true_d[np.arange(len(codes)), codes]
        obj = float(np.sum(dists.astype(np.float64)))
        return codes, dists, obj

    for _ in range(max_iters):
        codes, dists, obj = assign_step(P, C)
        history.append(obj)
        # update: f64 sums in point order
        sums = np.zeros((k, d), dtype=np.float64)
        counts = np.zeros(k, dtype=np.int64)
        np.add.at(sums, codes, P.astype(np.float64))
        np.add.at(counts, codes, 1)
        order = sorted(range(n), key=lambda i: dists[i], reverse=True)  # stable desc
        steal = iter(order)
        for j in range(k):
            if counts[j] == 0:
                p = next(steal, None)
                if p is not None:
                    C[j] = P[p]
            else:
                C[j] = (sums[j] / counts[j]).astype(np.float32)
        if np.isfinite(last_obj) and abs(last_obj - obj) <= tol * abs(last_obj):
            break
        last_obj = obj
    codes, dists, obj = assign_step(P, C)
    history.append(obj)
    return dict(centroids=C, k=k, assignments=codes, history=history, dists=dists)


def decode(centroids, d, codes):
    return centroids.reshape(-1, d)[codes].reshape(-1)


def pq_fit(w, rows, cols, block, k, iters, rng, tol=1e-5):
    P = np.asarray(w, dtype=np.float32).reshape(-1, block)
    km = kmeans(P, k, iters, tol, rng)
    return km


def objective_vs(w, centroids, block, codes):
    rec = decode(centroids, block, codes)
    e = np.asarray(w, dtype=np.float64) - rec.astype(np.float64)
    return float((e * e).sum())
