"""Exact Python port of rust/src/util/rng.rs (PCG-XSH-RR 64/32)."""
import numpy as np

M64 = (1 << 64) - 1
MUL = 6364136223846793005


def ror32(x, r):
    r &= 31
    return ((x >> r) | (x << (32 - r))) & 0xFFFFFFFF


class Pcg:
    def __init__(self, seed, stream=0xDA3E39CB94B95BDB):
        self.state = 0
        self.inc = ((stream << 1) | 1) & M64
        self.next_u32()
        self.state = (self.state + seed) & M64
        self.next_u32()

    def split(self, tag):
        seed = ((self.next_u32() << 32) | self.next_u32()) & M64
        t = (tag * 0x9E3779B97F4A7C15) & M64
        return Pcg(seed ^ t, tag)

    def next_u32(self):
        old = self.state
        self.state = (old * MUL + self.inc) & M64
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = (old >> 59) & 0xFFFFFFFF
        return ror32(xorshifted, rot)

    def next_u64(self):
        return ((self.next_u32() << 32) | self.next_u32()) & M64

    def next_f32(self):
        # (u32 >> 8) as f32 * (1/2^24) as f32
        return np.float32(self.next_u32() >> 8) * np.float32(1.0 / (1 << 24))

    def next_f64(self):
        return float(self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        assert n > 0
        neg_mod = ((1 << 32) - n) % n  # n.wrapping_neg() % n for u32
        while True:
            x = self.next_u32()
            m = x * n
            l = m & 0xFFFFFFFF
            if l >= n or l >= neg_mod:
                return m >> 32

    def next_normal(self):
        u1 = max(self.next_f64(), 1e-12)
        u2 = self.next_f64()
        import math
        return np.float32(math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2))

    def shuffle(self, xs):
        for i in range(len(xs) - 1, 0, -1):
            j = self.below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]

    def sample_indices(self, n, k):
        chosen = set()
        out = []
        for j in range(n - k, n):
            t = self.below(j + 1)
            v = j if t in chosen else t
            chosen.add(v)
            out.append(v)
        return out
