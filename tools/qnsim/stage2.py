"""Verify engine-affected kmeans/pq test assertions with exact-f32 sim."""
import numpy as np
from pcg import Pcg
from kmeans_sim import (dist2_seed, dot_engine_pair, engine_assign, kmeans,
                        pq_fit, decode, objective_vs)

F32 = np.float32
ok, bad = [], []


def check(name, cond, detail=""):
    (ok if cond else bad).append((name, detail))
    print(("PASS " if cond else "FAIL ") + name + (" — " + str(detail) if detail else ""))


def blob_data(seed, per_blob, d):
    rng = Pcg(seed)
    pts = []
    for b in range(4):
        center = F32(b * 10.0)
        for _ in range(per_blob):
            for _ in range(d):
                pts.append(F32(center + F32(rng.next_normal() * F32(0.1))))
    return np.array(pts, dtype=np.float32).reshape(-1, d)


# --- kmeans::objective_nonincreasing (k=8, iters=20, tol=0, threads=2) ---
pts = blob_data(1, 100, 4)
r = kmeans(pts, 8, 20, 0.0, Pcg(2))
h = r["history"]
viol = [(a, b) for a, b in zip(h, h[1:]) if b > a + 1e-6 * max(abs(a), 1.0)]
check("kmeans::objective_nonincreasing", not viol, viol or h[:3])

# --- kmeans::finds_separated_blobs (k=4, 25 iters, tol=1e-9) ---
pts = blob_data(3, 200, 2)
r = kmeans(pts, 4, 25, 1e-9, Pcg(4))
final = r["history"][-1]
ratio = final / (pts.size)
check("kmeans::finds_separated_blobs", ratio < 0.1, ratio)

# --- kmeans::assignments_are_nearest (k=6, 10 iters, tol=1e-7, d=3) ---
pts = blob_data(6, 50, 3)
r = kmeans(pts, 6, 10, 1e-7, Pcg(7))
C = r["centroids"]
true_d = dist2_seed(pts, C)  # naive f32 dist2, same as test's dist2
assigned = true_d[np.arange(len(pts)), r["assignments"]]
best = true_d.min(axis=1)
worst = float((assigned.astype(np.float64) - best.astype(np.float64)).max())
check("kmeans::assignments_are_nearest slack (seed slack 1e-5)", worst <= 1e-5, worst)
print("   max |assigned - best| =", worst)

# --- kmeans::deterministic_given_seed: structural (same code path) ---

# --- kmeans::no_empty_clusters_on_degenerate_data ---
pts = np.full(64 * 2, 0.5, dtype=np.float32)
pts[0] = 5.0
pts[3] = -5.0
pts = pts.reshape(-1, 2)
r = kmeans(pts, 4, 8, 0.0, Pcg(8))
check("kmeans::no_empty_clusters", all(a < r["k"] for a in r["assignments"]))

# --- pq::decode_shape_and_determinism: structural ---
# --- pq::more_centroids_lower_error (32x64, d=8, k in 4,16,64,256) ---
rng = Pcg(2)
w = np.array([rng.next_normal() for _ in range(32 * 64)], dtype=np.float32)
errs = []
for k in [4, 16, 64, 256]:
    km = pq_fit(w, 32, 64, 8, k, 12, Pcg(3))
    errs.append(objective_vs(w, km["centroids"], 8, km["assignments"]))
mono = all(b <= a * 1.05 for a, b in zip(errs, errs[1:]))
check("pq::more_centroids_lower_error", mono and errs[3] < 1e-9, errs)

# --- pq::repeated_rows_reconstruct_exactly ---
pattern = [1.0, -1.0, 0.5, 2.0]
w = []
for r_ in range(32):
    for _ in range(4):
        w.extend([pattern[r_ % 4]] * 4)
w = np.array(w, dtype=np.float32)
km = pq_fit(w, 32, 16, 4, 8, 10, Pcg(5))
err = objective_vs(w, km["centroids"], 4, km["assignments"])
check("pq::repeated_rows_reconstruct_exactly", err < 1e-10, err)

# --- pq::encode_matches_fit_assignments (16x16, d=4, k=16) ---
rng = Pcg(4)
w = np.array([rng.next_normal() for _ in range(16 * 16)], dtype=np.float32)
km = pq_fit(w, 16, 16, 4, 16, 10, Pcg(6))
codes2, _, _ = engine_assign(w.reshape(-1, 4), km["centroids"], want_dists=False)
rec_fit = objective_vs(w, km["centroids"], 4, km["assignments"])
rec_enc = objective_vs(w, km["centroids"], 4, codes2)
check("pq::encode_matches_fit_assignments", rec_enc <= rec_fit + 1e-9, (rec_enc, rec_fit))
check("pq::fit/encode same kernel -> identical codes",
      np.array_equal(codes2, km["assignments"]))

# --- quant_integration::pq_pipeline_end_to_end (256x128, d=8, k=64, 12 iters) ---
rng = Pcg(1)
w = np.array([F32(rng.next_normal() * F32(0.1)) for _ in range(256 * 128)], dtype=np.float32)
km = pq_fit(w, 256, 128, 8, 64, 12, Pcg(2))
dec = decode(km["centroids"], 8, km["assignments"])
codes2, _, _ = engine_assign(dec.reshape(-1, 8), km["centroids"], want_dists=False)
check("quant_integration::pq_pipeline_end_to_end",
      np.array_equal(codes2, km["assignments"]))

# --- quant_integration::kmeans_objective_equals_pq_objective (64x64, d=8, k=16) ---
rng = Pcg(5)
w = np.array([F32(rng.next_normal() * F32(0.1)) for _ in range(64 * 64)], dtype=np.float32)
km = kmeans(w.reshape(-1, 8), 16, 10, 1e-5, Pcg(6))
last = km["history"][-1]
obj = objective_vs(w, km["centroids"], 8, km["assignments"])
check("quant_integration::kmeans_objective_equals_pq_objective",
      abs(last - obj) <= 1e-3 * max(last, 1.0), (last, obj))

# --- quant_integration::pq_then_int8_centroids_error_budget (128x64, d=8, k=32) ---
def from_minmax(data, bits):
    lo, hi = F32(data.min()), F32(data.max())
    qmax = F32((1 << bits) - 1)
    scale = F32((hi - lo) / qmax)
    if not (scale > 0.0):
        scale = F32(1.0)
    zero = F32(np.round(lo / scale))
    return scale, zero, qmax


rng = Pcg(3)
w = np.array([F32(rng.next_normal() * F32(0.1)) for _ in range(128 * 64)], dtype=np.float32)
km = pq_fit(w, 128, 64, 8, 32, 10, Pcg(4))
err_pq = objective_vs(w, km["centroids"], 8, km["assignments"])
cents = km["centroids"].reshape(-1)
scale, zero, qmax = from_minmax(cents, 8)
q = np.clip(np.round(cents / scale) - zero, F32(0.0), qmax).astype(np.float32)
cents8 = ((q + zero) * scale).astype(np.float32)
cmse = float(((cents.astype(np.float64) - cents8.astype(np.float64)) ** 2).mean())
err_combo = objective_vs(w, cents8, 8, km["assignments"])
n = w.size
bound = (err_pq ** 0.5 + (cmse * n) ** 0.5) ** 2 + 1e-6
check("quant_integration::pq_then_int8_budget", err_combo <= bound, (err_combo, bound))

# --- proptest: prop_kmeans (40 cases) ---
CASES_SEED = 0xC0FFEE
M64 = (1 << 64) - 1


def case_rng(case):
    return Pcg(CASES_SEED ^ ((case * 0x9E3779B97F4A7C15) & M64))


def gen_dim(rng, size):
    caps = [1, 2, 3, 4, 7, 8, 12, 16, 31, 32, 64]
    mx = min(size + 1, len(caps))
    return caps[rng.below(mx)]


def gen_weights(rng, n):
    return np.array([F32(rng.next_normal() * (F32(1.0) + rng.next_f32()))
                     for _ in range(n)], dtype=np.float32)


fails = []
for case in range(40):
    rng = case_rng(case)
    size = 1 + case * 64 // 40
    d = [2, 4, 8][rng.below(3)]
    n = (gen_dim(rng, size) + 2) * 8
    pts = gen_weights(rng, n * d).reshape(-1, d)
    k = 1 + rng.below(16)
    r = kmeans(pts, k, 6, 0.0, rng)
    h = r["history"]
    for a, b in zip(h, h[1:]):
        if b > a * (1 + 1e-5) + 1e-9:
            fails.append((case, a, b))
    if not all(x < r["k"] for x in r["assignments"]):
        fails.append((case, "assign-range"))
check("proptest::prop_kmeans (40 cases)", not fails, fails[:3])

# --- proptest: prop_pq_decode_error_le_variance (30 cases) ---
fails = []
for case in range(30):
    rng = case_rng(case)
    size = 1 + case * 64 // 30
    rows = (gen_dim(rng, size) + 1) * 4
    cols = 16
    w = gen_weights(rng, rows * cols)
    km = pq_fit(w, rows, cols, 8, 8, 6, rng)
    err = objective_vs(w, km["centroids"], 8, km["assignments"])
    mean = F32(np.sum(w, dtype=np.float32) / F32(w.size))  # Rust f32 iter().sum()
    var = float(((w.astype(np.float64) - float(mean)) ** 2).sum())
    if err > var * 1.01 + 1e-6:
        fails.append((case, err, var))
check("proptest::prop_pq_decode_error_le_variance (30 cases)", not fails, fails[:3])

print()
print(f"{len(ok)} pass, {len(bad)} FAIL")
for name, d in bad:
    print("  FAIL:", name, d)
