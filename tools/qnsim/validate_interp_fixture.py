"""Cross-validate the checked-in interpreter fixture against jax.

Runs the HLO mirror interpreter (`hlo_mirror.py` — a structural 1:1
Python port of `rust/src/runtime/interp/`) on
`rust/tests/fixtures/interp/` — both the `lm_tiny` Transformer and the
`img_tiny` ConvNet (convolution / reverse / reduce-window path) — and
compares loss + every gradient with jax executing the original lowered
functions. Run after `make fixture` or after touching the Rust
interpreter's algorithms:

    cd tools/qnsim && python3 validate_interp_fixture.py

Needs jax (the same dependency `make fixture` needs). ~2 min on CPU.
"""
import json
import os
import struct
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(os.path.dirname(HERE))
sys.path.insert(0, HERE)
sys.path.insert(0, os.path.join(ROOT, "python"))
os.environ.setdefault("QN_KERNEL_IMPL", "jnp")

import numpy as np
import jax
import jax.numpy as jnp

from hlo_mirror import parse_module, Interp, Arr
from compile import convnet, model

FIX = os.path.join(ROOT, "rust", "tests", "fixtures", "interp")


def load_params(meta):
    with open(os.path.join(FIX, meta["init"]), "rb") as f:
        assert f.read(4) == b"QNP1"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        params = {}
        for p in header["params"]:
            numel = int(np.prod(p["shape"])) if p["shape"] else 1
            params[p["name"]] = np.frombuffer(
                f.read(4 * numel), np.float32).reshape(p["shape"])
    return params


def load_fixture():
    man = json.load(open(os.path.join(FIX, "manifest.json")))
    meta = man["models"]["lm_tiny"]
    c = meta["config"]
    cfg = model.TransformerConfig(
        vocab=c["vocab"], d_model=c["d_model"], n_layers=c["n_layers"],
        n_heads=c["n_heads"], d_ffn=c["d_ffn"], seq_len=c["seq_len"],
        batch=c["batch"], noise_block_size=c["noise_block_size"],
    )
    return cfg, meta, load_params(meta)


def load_img_fixture():
    man = json.load(open(os.path.join(FIX, "manifest.json")))
    meta = man["models"]["img_tiny"]
    c = meta["config"]
    cfg = convnet.ConvConfig(
        image_size=c["image_size"], in_channels=c["in_channels"],
        stem_channels=c["stem_channels"],
        blocks=tuple(tuple(b) for b in c["blocks"]),
        n_classes=c["n_classes"], batch=c["batch"],
    )
    return cfg, meta, load_params(meta)


def to_args(arrs):
    out = []
    for a in arrs:
        a = np.asarray(a)
        ty = {"float32": "f32", "int32": "s32"}[str(a.dtype)]
        out.append(Arr(ty, list(a.shape), a.ravel()))
    return out


def validate_img():
    """img_tiny: deterministic pixels/labels (same as the Rust tests)
    through conv forward + both conv grad forms vs jax."""
    cfg, meta, params = load_img_fixture()
    names = sorted(convnet.param_shapes(cfg))
    b, h, w, c = meta["tokens_shape"]
    images = (np.arange(b * h * w * c) % 256).astype(
        np.float32).reshape(b, h, w, c) / 255.0
    labels = (np.arange(b) % meta["n_classes"]).astype(np.int32)
    keep = np.ones(meta["n_layers"], np.float32)
    jp = {n: jnp.asarray(params[n]) for n in names}

    em = parse_module(open(os.path.join(FIX, "img_tiny.eval.hlo.txt")).read())
    res = Interp(em).run_entry(
        to_args([params[n] for n in names] + [images, labels, keep]))
    got = [float(x.data[0]) for x in res[1]]
    want = convnet.img_eval(cfg, jp, images, labels, keep)
    assert abs(got[0] - float(want[0])) < 1e-3, (got, want)
    assert got[1] == float(want[1]), (got, want)
    print(f"img eval: mirror {got[0]:.6f} jax {float(want[0]):.6f} OK")

    gm = parse_module(open(os.path.join(FIX, "img_tiny.grad_mix.hlo.txt")).read())
    gi = Interp(gm)
    loss_fn = convnet.noisy_loss_fn(cfg, "mix")
    gfn = jax.jit(lambda p, ht, im, lb, k, r, s:
                  jax.value_and_grad(loss_fn)(p, ht, im, lb, k, r, s))
    hats = [np.zeros_like(params[n]) for n in names]
    jh = {n: jnp.zeros_like(jp[n]) for n in names}
    for rate, seed in [(0.0, 1), (0.5, 42)]:
        res = gi.run_entry(to_args(
            [params[n] for n in names] + hats
            + [images, labels, keep, np.float32(rate), np.int32(seed)]))
        loss_m = float(res[1][0].data[0])
        wl, wg = gfn(jp, jh, images, labels, keep,
                     jnp.float32(rate), jnp.int32(seed))
        assert abs(loss_m - float(wl)) < 2e-3, (rate, seed, loss_m, float(wl))
        maxerr = 0.0
        for i, n in enumerate(names):
            g = np.asarray(res[1][1 + i].data, np.float32).reshape(params[n].shape)
            ref = np.asarray(wg[n])
            scale = max(1e-6, float(np.max(np.abs(ref))))
            maxerr = max(maxerr, float(np.max(np.abs(g - ref))) / scale)
        assert maxerr < 5e-3, (rate, seed, maxerr)
        print(f"img grad rate={rate} seed={seed}: loss {loss_m:.6f} "
              f"(jax {float(wl):.6f}), max rel grad err {maxerr:.1e} OK")


def main():
    cfg, meta, params = load_fixture()
    names = sorted(model.param_shapes(cfg))
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32)
    targets = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32)
    keep = np.ones(cfg.n_layers, np.float32)
    jp = {n: jnp.asarray(params[n]) for n in names}

    # ---- eval entry
    em = parse_module(open(os.path.join(FIX, "lm_tiny.eval.hlo.txt")).read())
    res = Interp(em).run_entry(
        to_args([params[n] for n in names] + [tokens, targets, keep]))
    got = [float(x.data[0]) for x in res[1]]
    want = model.lm_eval(cfg, jp, tokens, targets, keep)
    assert abs(got[0] - float(want[0])) < 1e-3, (got, want)
    assert got[1] == float(want[1]), (got, want)
    print(f"eval: mirror {got[0]:.6f} jax {float(want[0]):.6f} OK")

    # ---- grad entry across rates/seeds
    gm = parse_module(open(os.path.join(FIX, "lm_tiny.grad_mix.hlo.txt")).read())
    gi = Interp(gm)
    loss_fn = model.noisy_loss_fn(cfg, "mix", "lm")
    gfn = jax.jit(lambda p, h, tok, tgt, k, r, s:
                  jax.value_and_grad(loss_fn)(p, h, tok, tgt, k, r, s))
    hats = [np.zeros_like(params[n]) for n in names]
    jh = {n: jnp.zeros_like(jp[n]) for n in names}
    for rate, seed in [(0.0, 1), (0.5, 42), (1.0, 7)]:
        res = gi.run_entry(to_args(
            [params[n] for n in names] + hats
            + [tokens, targets, keep, np.float32(rate), np.int32(seed)]))
        loss_m = float(res[1][0].data[0])
        wl, wg = gfn(jp, jh, tokens, targets, keep,
                     jnp.float32(rate), jnp.int32(seed))
        assert abs(loss_m - float(wl)) < 2e-3, (rate, seed, loss_m, float(wl))
        maxerr = 0.0
        for i, n in enumerate(names):
            g = np.asarray(res[1][1 + i].data, np.float32).reshape(params[n].shape)
            w = np.asarray(wg[n])
            scale = max(1e-6, float(np.max(np.abs(w))))
            maxerr = max(maxerr, float(np.max(np.abs(g - w))) / scale)
        assert maxerr < 5e-3, (rate, seed, maxerr)
        print(f"grad rate={rate} seed={seed}: loss {loss_m:.6f} "
              f"(jax {float(wl):.6f}), max rel grad err {maxerr:.1e} OK")
    validate_img()
    print("FIXTURE VALIDATED against jax")


if __name__ == "__main__":
    main()
