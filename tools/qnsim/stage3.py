"""Verify the NEW tests added in this PR: engine prop slacks, lattice
regression, fixed-seed hat determinism margins."""
import numpy as np
from pcg import Pcg
from kmeans_sim import dist2_seed, engine_assign, kmeans

F32 = np.float32
ok, bad = [], []


def check(name, cond, detail=""):
    (ok if cond else bad).append((name, detail))
    print(("PASS " if cond else "FAIL ") + name + (" — " + str(detail) if detail else ""))


CASES_SEED = 0xC0FFEE
M64 = (1 << 64) - 1


def case_rng(case):
    return Pcg(CASES_SEED ^ ((case * 0x9E3779B97F4A7C15) & M64))


def gen_dim(rng, size):
    caps = [1, 2, 3, 4, 7, 8, 12, 16, 31, 32, 64]
    return caps[rng.below(min(size + 1, len(caps)))]


def gen_weights(rng, n):
    return np.array([F32(rng.next_normal() * (F32(1.0) + rng.next_f32()))
                     for _ in range(n)], dtype=np.float32)


# --- prop_assign_engine_picks_nearest (60 cases) ---
worst_sel, worst_dist = 0.0, 0.0
fails = []
for case in range(60):
    rng = case_rng(case)
    size = 1 + case * 64 // 60
    d = [2, 4, 8][rng.below(3)]
    n = 1 + gen_dim(rng, size) * 2
    k = 1 + rng.below(32)
    pts = gen_weights(rng, n * d).reshape(-1, d)
    cbs = gen_weights(rng, k * d).reshape(-1, d)
    codes, dists, _ = engine_assign(pts, cbs)
    true_d = dist2_seed(pts, cbs)
    assigned = true_d[np.arange(n), codes].astype(np.float64)
    best = true_d.min(axis=1).astype(np.float64)
    sel = ((assigned - best) / (1.0 + best)).max()
    dd = (np.abs(dists.astype(np.float64) - assigned) / (1.0 + assigned)).max()
    worst_sel = max(worst_sel, sel)
    worst_dist = max(worst_dist, dd)
    if sel > 1e-4 or dd > 1e-3:
        fails.append((case, sel, dd))
check("new::prop_assign_engine_picks_nearest", not fails,
      f"worst sel={worst_sel:.2e} dist={worst_dist:.2e}")

# --- prop_assign_engine_bit_identical: also sanity the generator shapes ---
shapes = set()
for case in range(60):
    rng = case_rng(case)
    size = 1 + case * 64 // 60
    d = [1, 2, 3, 4, 7, 8][rng.below(6)]
    n = 1 + gen_dim(rng, size) * 3
    k = 1 + rng.below(80)
    shapes.add((n < 16, k > n, d))
check("new::prop_bit_identical covers n<threads and k>n",
      any(s[0] for s in shapes) and any(s[1] for s in shapes), sorted(shapes)[:4])

# --- assign.rs::agrees_with_naive_dist2_up_to_ties (n=300,d=8,k=32, seeds 7/8) ---
def randv(seed, n):
    r = Pcg(seed)
    return np.array([r.next_normal() for _ in range(n)], dtype=np.float32)


pts = randv(7, 300 * 8).reshape(-1, 8)
cbs = randv(8, 32 * 8).reshape(-1, 8)
codes, dists, _ = engine_assign(pts, cbs)
true_d = dist2_seed(pts, cbs)
ncodes = np.argmin(true_d, axis=1)
ndists = true_d.min(axis=1)
failed = []
for i in range(300):
    if codes[i] != ncodes[i]:
        dd = float(true_d[i, codes[i]])
        if abs(dd - float(ndists[i])) > 1e-4 * (1.0 + float(ndists[i])):
            failed.append(i)
    else:
        if abs(float(dists[i]) - float(ndists[i])) > 1e-3 * (1.0 + float(ndists[i])):
            failed.append(i)
mismatches = int((codes != ncodes).sum())
check("new::agrees_with_naive_dist2_up_to_ties", not failed,
      f"{mismatches} tie-flips, 0 violations" if not failed else failed[:5])

# --- assign.rs::dists_are_true_squared_distances (seeds 11/12, 50x8, k=16) ---
pts = randv(11, 50 * 8).reshape(-1, 8)
cbs = randv(12, 16 * 8).reshape(-1, 8)
codes, dists, obj = engine_assign(pts, cbs)
true_d = dist2_seed(pts, cbs)
exact = true_d[np.arange(50), codes].astype(np.float64)
rel = (np.abs(dists.astype(np.float64) - exact) / (1.0 + exact)).max()
ssum = float(dists.astype(np.float64).sum())
check("new::dists_are_true_squared_distances", rel <= 1e-3 and abs(obj - ssum) <= 1e-6 * max(abs(ssum), 1.0),
      f"rel={rel:.2e}")

# --- assign.rs::well_separated lattice (d=4,k=16, seed 3) ---
d, k = 4, 16
rng = Pcg(3)
centroids = np.array([(i // d) * 10.0 + (i % d) for i in range(k * d)],
                     dtype=np.float32).reshape(k, d)
pts = []
for i in range(200):
    j = i % k
    pts.append([F32(centroids[j, t] + F32(rng.next_normal() * F32(0.05))) for t in range(d)])
pts = np.array(pts, dtype=np.float32)
codes, _, _ = engine_assign(pts, centroids)
ncodes = np.argmin(dist2_seed(pts, centroids), axis=1)
check("new::well_separated_codebook_matches_naive", np.array_equal(codes, ncodes))

# --- quant_integration::engine_encode_matches_seed_scalar_loop ---
d, k, rows, cols = 8, 32, 64, 64
centroids = np.array([(i // d) * 4.0 - 2.0 * (i % d) for i in range(k * d)],
                     dtype=np.float32).reshape(k, d)
rng = Pcg(11)
w = np.empty(rows * cols, dtype=np.float32)
for i in range(rows * cols):
    sv = i // d
    j = sv % k
    w[i] = F32(centroids[j, i % d] + F32(rng.next_normal() * F32(0.05)))
P = w.reshape(-1, d)
codes, _, _ = engine_assign(P, centroids)
ncodes = np.argmin(dist2_seed(P, centroids), axis=1)
check("new::engine_encode_matches_seed_scalar_loop", np.array_equal(codes, ncodes),
      int((codes != ncodes).sum()))

# --- noise::exact_pq_hat_deterministic: break-margin analysis ---
rng = Pcg(9)
w = np.array([rng.next_normal() for _ in range(32 * 32)], dtype=np.float32)
km = kmeans(w.reshape(-1, 8), 16, 6, 1e-5, Pcg(4))
h = km["history"]
margins = [abs(abs(a - b) / max(abs(a), 1e-30) - 1e-5) for a, b in zip(h, h[1:])]
check("new::hat_deterministic break margins far from tol", min(margins) > 1e-7,
      [f"{m:.1e}" for m in margins])

print()
print(f"{len(ok)} pass, {len(bad)} FAIL")
for name, dd in bad:
    print("  FAIL:", name, dd)
