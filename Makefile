# Quant-Noise reproduction — top-level targets.
#
#   make verify        tier-1 gate: build + test the Rust coordinator
#   make test-faults   crash-safety + fault-injection suites (DESIGN.md §10)
#   make artifacts     export all model artifacts (needs python + jax)
#   make fixture       regenerate the checked-in interpreter test fixture
#   make bench-interp  interpreter step latency -> BENCH_interp.json
#   make bench-serve   qn serve HTTP/batching latency -> BENCH_serve.json
#   make lint          rustfmt + clippy (what CI enforces)
#   make lint-plan     static plan verifier over the checked-in fixtures
#   make doc           rustdoc with warnings denied (what CI enforces)
#
# The Rust side never needs Python at build or test time: the
# interpreter fixture under rust/tests/fixtures/interp/ is checked in.
# QN_KERNEL_IMPL=jnp lowers the noise math through the pure-jnp oracle,
# the fast path on CPU PJRT (see python/compile/qnoise.py).

PY ?= python3
CONFIGS := python/configs/lm_tiny.json \
           python/configs/cls_tiny.json \
           python/configs/img_tiny.json

.PHONY: verify test-faults artifacts fixture bench-interp bench-serve lint lint-plan doc

verify:
	cd rust && cargo build --release && cargo test -q

# The fault-tolerance tier (DESIGN.md §10): kill-and-resume bit
# identity, every save-protocol fault leaving a loadable last-good,
# corruption sweeps over QNP1/QNC1/HLO loaders, and the serve edge
# under hostile clients — all with the plan verifier on.
test-faults:
	cd rust && QN_PLAN_VERIFY=1 cargo test -q \
		--test resume_determinism \
		--test fault_injection \
		--test artifact_corruption \
		--test serve_faults

# Static plan verification + census for every checked-in HLO fixture,
# at every fusion setting (DESIGN.md §8; CI runs this after the build).
lint-plan:
	cd rust && cargo run --release --bin qn -- lint-plan \
		tests/fixtures/interp/lm_tiny.grad_mix.hlo.txt \
		tests/fixtures/interp/lm_tiny.eval.hlo.txt \
		tests/fixtures/interp/img_tiny.grad_mix.hlo.txt \
		tests/fixtures/interp/img_tiny.eval.hlo.txt \
		tests/fixtures/interp/threefry_pin.hlo.txt \
		tests/fixtures/interp/window_pin.hlo.txt \
		benches/fixtures/lm_base.grad.hlo.txt

# Per-step grad_mix/eval latency of the planned interpreter vs the
# tree-walking evaluator on the checked-in fixture (no Python, no
# artifacts); records the perf trajectory in BENCH_interp.json.
# QUICK=1 shrinks warmup/budget to a smoke run (what CI executes) so
# kernel-dispatch regressions surface without stable-median cost.
bench-interp:
	cd rust && QN_BENCH_JSON=$(abspath BENCH_interp.json) \
		QN_BENCH_QUICK=$(QUICK) cargo bench --bench interp_step

# End-to-end `qn serve` numbers on the same fixture: solo HTTP eval
# latency, a concurrent-client burst through the coalescing batcher
# (asserts macro-batches > 1 actually formed), online int8 re-encode
# cost, and the lazy JSON path-extraction micro-bench. QUICK=1 is the
# CI smoke run.
bench-serve:
	cd rust && QN_BENCH_JSON=$(abspath BENCH_serve.json) \
		QN_BENCH_QUICK=$(QUICK) cargo bench --bench serve

artifacts:
	cd python && QN_KERNEL_IMPL=jnp $(PY) -m compile.aot \
		--configs $(patsubst python/%,%,$(CONFIGS)) \
		--out-dir ../rust/artifacts

fixture:
	cd python && QN_KERNEL_IMPL=jnp $(PY) -m compile.aot \
		--configs configs/lm_tiny.json configs/img_tiny.json \
		--entries grad_mix eval \
		--out-dir ../rust/tests/fixtures/interp
	$(PY) tools/qnsim/gen_lm_base.py \
		--config python/configs/lm_base.json \
		--out rust/benches/fixtures/lm_base.grad.hlo.txt

lint:
	cd rust && cargo fmt --check && cargo clippy --all-targets -- -D warnings

doc:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
