"""L2: Transformer language model / sequence classifier with Quant-Noise.

Pre-norm Transformer (Baevski & Auli-style block structure, adaptive
input/softmax replaced by a tied full softmax — the synthetic corpus
vocabulary is small; see DESIGN.md §Substitutions).  All linear weights
use the (out, in) layout with ``y = x @ W.T``; Quant-Noise blocks run
along the ``in`` axis (block size 8, the paper's Transformer setting).

The model is a pure function of a params dict so that:
  * jax.grad gives the grad artifact,
  * the coordinator owns every parameter (Rust init matches `init_params`),
  * LayerDrop is an input mask `layer_keep[L]`, weight sharing is the
    coordinator feeding identical buffers.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import qnoise


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ffn: int = 512
    seq_len: int = 64
    batch: int = 8
    noise_block_size: int = 8
    # classifier head (sequence classification variant); 0 = LM only
    n_classes: int = 0
    layerdrop_ste: bool = False
    int8_activations: bool = False

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


# ------------------------------------------------------------- params ---

def param_shapes(cfg: TransformerConfig):
    """name → shape, in the canonical (sorted-name) order used everywhere."""
    shapes = {"embed": (cfg.vocab, cfg.d_model)}
    for l in range(cfg.n_layers):
        p = f"layer{l:02d}."
        shapes[p + "wq"] = (cfg.d_model, cfg.d_model)
        shapes[p + "wk"] = (cfg.d_model, cfg.d_model)
        shapes[p + "wv"] = (cfg.d_model, cfg.d_model)
        shapes[p + "wo"] = (cfg.d_model, cfg.d_model)
        shapes[p + "w1"] = (cfg.d_ffn, cfg.d_model)
        shapes[p + "w2"] = (cfg.d_model, cfg.d_ffn)
        shapes[p + "ln1_g"] = (cfg.d_model,)
        shapes[p + "ln1_b"] = (cfg.d_model,)
        shapes[p + "ln2_g"] = (cfg.d_model,)
        shapes[p + "ln2_b"] = (cfg.d_model,)
    shapes["lnf_g"] = (cfg.d_model,)
    shapes["lnf_b"] = (cfg.d_model,)
    if cfg.n_classes:
        shapes["cls"] = (cfg.n_classes, cfg.d_model)
    return shapes


def quant_specs(cfg: TransformerConfig):
    """name → (rows, cols, noise_block_size) for every *noised* weight.

    Norm scales/biases are excluded (the paper noise targets FFN,
    embeddings and attention).  Also doubles as the PQ layout spec the
    coordinator reads from the manifest: structure group per name.
    """
    bs = cfg.noise_block_size
    specs = {"embed": (cfg.vocab, cfg.d_model, bs)}
    for l in range(cfg.n_layers):
        p = f"layer{l:02d}."
        for w in ("wq", "wk", "wv", "wo"):
            specs[p + w] = (cfg.d_model, cfg.d_model, bs)
        specs[p + "w1"] = (cfg.d_ffn, cfg.d_model, bs)
        specs[p + "w2"] = (cfg.d_model, cfg.d_ffn, bs)
    if cfg.n_classes:
        specs["cls"] = (cfg.n_classes, cfg.d_model, 4)
    return specs


def structure_of(name: str) -> str:
    """Paper §7.11.4 structure groups: emb / attn / ffn / cls / norm."""
    if name == "embed":
        return "emb"
    if name == "cls":
        return "cls"
    if name.endswith(("wq", "wk", "wv", "wo")):
        return "attn"
    if name.endswith(("w1", "w2")):
        return "ffn"
    return "norm"


def init_params(cfg: TransformerConfig, seed: int = 0):
    """Scaled-normal init; the Rust coordinator reproduces this exactly
    (same PCG stream, see rust/src/model/params.rs) so artifacts and
    host state always agree."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_shapes(cfg).items():
        key, sub = jax.random.split(key)
        if name.endswith("_g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[-1]
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) * (fan_in ** -0.5)
            )
    return params


# ------------------------------------------------------------ forward ---

def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(cfg: TransformerConfig, p, x, causal: bool):
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head

    def split(w):
        return (x @ w.T).reshape(b, t, h, dh).transpose(0, 2, 1, 3)

    q, k, v = split(p["wq"]), split(p["wk"]), split(p["wv"])
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(jnp.float32(dh))
    if causal:
        mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
        scores = jnp.where(mask[None, None], scores, jnp.float32(-1e9))
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bhsd->bhtd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, t, d)
    return ctx @ p["wo"].T


def _residual(cfg: TransformerConfig, x, branch, keep):
    """LayerDrop residual: forward drops the branch when keep==0.

    Default (paper §4.2): no STE — a dropped branch contributes nothing
    to forward or backward.  layerdrop_ste=True (Table 11 ablation)
    keeps the backward of the *kept* computation: forward uses
    x + keep·f(x), backward pretends keep==1.
    """
    if cfg.layerdrop_ste:
        full = x + branch
        dropped = x + keep * branch
        return full + jax.lax.stop_gradient(dropped - full)
    return x + keep * branch


def _act_q(cfg: TransformerConfig, x):
    return qnoise.fake_quant_activations(x) if cfg.int8_activations else x


def forward(cfg: TransformerConfig, params, tokens, layer_keep, causal=True):
    """tokens (B, T) int32 → hidden states (B, T, D)."""
    x = params["embed"][tokens] * jnp.sqrt(jnp.float32(cfg.d_model))
    # fixed sinusoidal positions — nothing to quantize, nothing to learn
    t = jnp.arange(cfg.seq_len, dtype=jnp.float32)[:, None]
    dims = jnp.arange(cfg.d_model // 2, dtype=jnp.float32)[None, :]
    freqs = t / jnp.power(10000.0, 2.0 * dims / cfg.d_model)
    pos = jnp.concatenate([jnp.sin(freqs), jnp.cos(freqs)], axis=-1)
    x = x + pos[None]
    x = _act_q(cfg, x)
    for l in range(cfg.n_layers):
        p = {k[len(f"layer{l:02d}.") :]: v for k, v in params.items()
             if k.startswith(f"layer{l:02d}.")}
        keep = layer_keep[l]
        h = _layer_norm(x, p["ln1_g"], p["ln1_b"])
        x = _residual(cfg, x, _attention(cfg, p, h, causal), keep)
        x = _act_q(cfg, x)
        h = _layer_norm(x, p["ln2_g"], p["ln2_b"])
        ffn = jax.nn.relu(h @ p["w1"].T) @ p["w2"].T
        x = _residual(cfg, x, ffn, keep)
        x = _act_q(cfg, x)
    return _layer_norm(x, params["lnf_g"], params["lnf_b"])


def lm_logits(cfg: TransformerConfig, params, h):
    # tied output embedding (standard for small-vocab LMs)
    return h @ params["embed"].T


def lm_loss(cfg: TransformerConfig, params, tokens, targets, layer_keep):
    h = forward(cfg, params, tokens, layer_keep, causal=True)
    logits = lm_logits(cfg, params, _act_q(cfg, h))
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def lm_eval(cfg: TransformerConfig, params, tokens, targets, layer_keep):
    """(sum_nll, n_correct) — PPL = exp(sum_nll / ntokens), ntokens = B·T."""
    h = forward(cfg, params, tokens, layer_keep, causal=True)
    logits = lm_logits(cfg, params, _act_q(cfg, h))
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    correct = (jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32)
    return jnp.sum(nll), jnp.sum(correct)


def cls_loss(cfg: TransformerConfig, params, tokens, labels, layer_keep):
    h = forward(cfg, params, tokens, layer_keep, causal=False)
    pooled = jnp.mean(h, axis=1)
    logits = pooled @ params["cls"].T
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def cls_eval(cfg: TransformerConfig, params, tokens, labels, layer_keep):
    h = forward(cfg, params, tokens, layer_keep, causal=False)
    pooled = jnp.mean(h, axis=1)
    logits = pooled @ params["cls"].T
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    return jnp.sum(nll), jnp.sum(correct)


# ------------------------------------------------- noise-wrapped grads ---

def noisy_loss_fn(cfg: TransformerConfig, kind: str, task: str):
    """Returns loss(params, params_hat, tokens, targets, layer_keep,
    rate, seed) with Quant-Noise `kind` applied to the weights."""
    specs = quant_specs(cfg)
    loss = cls_loss if task == "cls" else lm_loss

    def fn(params, params_hat, tokens, targets, layer_keep, rate, seed):
        noised = qnoise.noise_params(
            params, specs, kind, rate, seed,
            params_hat=params_hat if kind == "mix" else None,
        )
        return loss(cfg, noised, tokens, targets, layer_keep)

    return fn
