"""L2: "MicroConv" — depthwise-separable ConvNet with Quant-Noise.

Stands in for EfficientNet-B3/ImageNet (DESIGN.md §Substitutions): it has
exactly the conv kinds the paper assigns block sizes to — 1×1 pointwise
convs (noise/PQ block size 4 along input channels), depthwise 3×3 convs
(block size 9 = one whole filter) and a linear classifier (block size 4).
Inverted-residual shape (expand 1×1 → dw3×3 → project 1×1, residual when
stride 1), SE blocks omitted (the paper excludes them from noise anyway).

NHWC activations, HWIO conv weights.  Each conv weight's canonical 2-D
view (the one Quant-Noise and coordinator-side PQ share) is:
  * pointwise 1×1 (1,1,I,O):  (O, I),  blocks of 4 along I
  * depthwise 3×3 (3,3,C,1):  (C, 9),  one 9-element block per filter
  * stem 3×3 (3,3,I,O):       (O, 9·I), blocks of 9 (whole 3×3 slice)
  * classifier (n_classes,D): (n_classes, D), blocks of 4
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import qnoise


@dataclasses.dataclass(frozen=True)
class ConvConfig:
    image_size: int = 16
    in_channels: int = 3
    stem_channels: int = 16
    # (channels, stride, expand) per inverted-residual block
    blocks: tuple = ((16, 1, 2), (24, 2, 2), (24, 1, 2), (32, 2, 2))
    n_classes: int = 10
    batch: int = 32
    int8_activations: bool = False

    @property
    def head_dim(self) -> int:
        return self.blocks[-1][0]


# ------------------------------------------------------------- params ---

def param_shapes(cfg: ConvConfig):
    shapes = {"stem": (3, 3, cfg.in_channels, cfg.stem_channels)}
    cin = cfg.stem_channels
    for i, (cout, _stride, expand) in enumerate(cfg.blocks):
        p = f"block{i:02d}."
        mid = cin * expand
        shapes[p + "expand"] = (1, 1, cin, mid)
        shapes[p + "dw"] = (3, 3, 1, mid)  # HWIO, I=1 for depthwise
        shapes[p + "project"] = (1, 1, mid, cout)
        shapes[p + "bn1_g"] = (mid,)
        shapes[p + "bn1_b"] = (mid,)
        shapes[p + "bn2_g"] = (mid,)
        shapes[p + "bn2_b"] = (mid,)
        shapes[p + "bn3_g"] = (cout,)
        shapes[p + "bn3_b"] = (cout,)
        cin = cout
    shapes["head_g"] = (cin,)
    shapes["head_b"] = (cin,)
    shapes["cls"] = (cfg.n_classes, cin)
    return shapes


def quant_specs(cfg: ConvConfig):
    """2-D view + block size per noised weight (paper §7.6/§7.8 sizes)."""
    specs = {}
    stem = param_shapes(cfg)["stem"]
    # stem 3×3: (O, 9·I) with 9-element blocks (whole 3×3 spatial slice)
    specs["stem"] = (stem[3], 9 * stem[2], 9)
    cin = cfg.stem_channels
    for i, (cout, _stride, expand) in enumerate(cfg.blocks):
        p = f"block{i:02d}."
        mid = cin * expand
        specs[p + "expand"] = (mid, cin, 4)    # 1×1: bs 4 along in-ch
        specs[p + "dw"] = (mid, 9, 9)          # dw3×3: bs 9 (whole filter)
        specs[p + "project"] = (cout, mid, 4)  # 1×1: bs 4
        cin = cout
    specs["cls"] = (cfg.n_classes, cin, 4)
    return specs


def structure_of(name: str) -> str:
    if name == "stem":
        return "stem"
    if name == "cls":
        return "cls"
    if name.endswith("expand") or name.endswith("project"):
        return "conv1x1"
    if name.endswith("dw"):
        return "dw3x3"
    return "norm"


def to2d(name: str, w, cfg: ConvConfig):
    """Canonical 2-D view used by noise AND coordinator-side PQ."""
    if w.ndim == 2:
        return w
    kh, kw, ci, co = w.shape
    # depthwise (3,3,1,C) and full/pointwise (kh,kw,I,O) share the same
    # canonical layout: one row per output channel, kh·kw·I columns.
    return w.transpose(3, 0, 1, 2).reshape(co, kh * kw * ci)


def from2d(name: str, w2d, orig_shape):
    kh, kw, ci, co = orig_shape
    return w2d.reshape(co, kh, kw, ci).transpose(1, 2, 3, 0)


def init_params(cfg: ConvConfig, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_shapes(cfg).items():
        key, sub = jax.random.split(key)
        if name.endswith("_g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0] * shape[1] * shape[2] if len(shape) == 4 else shape[-1]
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) * (fan_in ** -0.5)
            )
    return params


# ------------------------------------------------------------ forward ---

def _norm_act(x, g, b, act=True, eps=1e-5):
    # batch-free "layer" normalization over channels (GroupNorm(1)-style):
    # keeps eval independent of batch statistics, which matters because
    # the coordinator evaluates quantized weights with batch size 1.
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps) * g + b
    return jax.nn.relu(x) if act else x


def _conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def forward(cfg: ConvConfig, params, images, block_keep):
    """images (B, H, W, C) f32 → logits (B, n_classes)."""
    aq = (lambda t: qnoise.fake_quant_activations(t)) if cfg.int8_activations else (lambda t: t)
    x = aq(_conv(images, params["stem"], stride=1))
    cin = cfg.stem_channels
    for i, (cout, stride, expand) in enumerate(cfg.blocks):
        p = f"block{i:02d}."
        mid = cin * expand
        h = _conv(x, params[p + "expand"])
        h = _norm_act(h, params[p + "bn1_g"], params[p + "bn1_b"])
        h = _conv(h, params[p + "dw"], stride=stride, groups=mid)
        h = _norm_act(h, params[p + "bn2_g"], params[p + "bn2_b"])
        h = _conv(h, params[p + "project"])
        h = _norm_act(h, params[p + "bn3_g"], params[p + "bn3_b"], act=False)
        if stride == 1 and cin == cout:
            # residual block — the LayerDrop/sharing "chunk" unit (§7.6)
            h = x + block_keep[i] * h
        x = aq(h)
        cin = cout
    x = _norm_act(x, params["head_g"], params["head_b"])
    pooled = jnp.mean(x, axis=(1, 2))
    return aq(pooled) @ params["cls"].T


def img_loss(cfg: ConvConfig, params, images, labels, block_keep):
    logits = forward(cfg, params, images, block_keep)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def img_eval(cfg: ConvConfig, params, images, labels, block_keep):
    logits = forward(cfg, params, images, block_keep)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    return jnp.sum(nll), jnp.sum(correct)


# ------------------------------------------------- noise-wrapped grads ---

def noisy_loss_fn(cfg: ConvConfig, kind: str):
    specs = quant_specs(cfg)

    def fn(params, params_hat, images, labels, block_keep, rate, seed):
        base = jax.random.PRNGKey(seed)
        noised = {}
        for i, name in enumerate(sorted(params)):
            w = params[name]
            if name not in specs:
                noised[name] = w
                continue
            rows, cols, bs = specs[name]
            w2d = to2d(name, w, cfg).reshape(rows, cols)
            w_hat2d = None
            if kind == "mix":
                w_hat2d = to2d(name, params_hat[name], cfg).reshape(rows, cols)
            key = jax.random.fold_in(base, i)
            n2d = qnoise.apply_noise(name, w2d, kind, rate, key, bs, w_hat2d)
            noised[name] = (
                n2d if w.ndim == 2 else from2d(name, n2d, w.shape)
            )
        return img_loss(cfg, noised, images, labels, block_keep)

    return fn
