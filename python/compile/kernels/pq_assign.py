"""L1 Pallas kernel: PQ nearest-centroid assignment (paper Eq. 10).

The GPU formulation of PQ encode is a per-thread scan over centroids;
the TPU re-think (DESIGN.md §Hardware-Adaptation) turns the distance
computation into a matmul on the MXU:

    argmin_c |b - c|^2 = argmin_c (|c|^2 - 2 b.c)

so each (subvector-tile x centroid-set) step is a (T, d) @ (d, K)
contraction — systolic-array work — followed by a cheap row argmin on
the VPU.  |b|^2 is constant per row and dropped.

Tiling: subvectors are tiled in chunks of TILE_N rows; the centroid
matrix (K x d, typically 256 x 8 = 8 KiB) fits entirely in VMEM and is
re-used by every grid step.  interpret=True as everywhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 128


def _assign_kernel(b_ref, c_ref, o_ref):
    b = b_ref[...]          # (tile, d)
    c = c_ref[...]          # (K, d)
    dots = jnp.dot(b, c.T)  # MXU: (tile, K)
    c2 = jnp.sum(c * c, axis=1)
    d2 = c2[None, :] - 2.0 * dots
    o_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)


def pq_assign(subvectors, centroids):
    """Nearest-centroid codes: (n, d), (K, d) -> int32 (n,)."""
    n, d = subvectors.shape
    k, d2 = centroids.shape
    assert d == d2, (d, d2)
    tile = TILE_N if n % TILE_N == 0 else 1
    grid = (n // tile,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(subvectors, centroids)


def pq_decode(codes, centroids):
    """Gather reconstruction; a pure gather, left to XLA (no kernel win)."""
    return centroids[codes]
