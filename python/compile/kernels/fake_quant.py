"""L1 Pallas kernel: uniform intN fake-quantization (paper Eq. 2/9).

Two-pass structure: the scale/zero-point depend on the global min/max of
the tensor (the paper updates s and z during training from the live
weights), which a tiled kernel cannot see locally.  Pass 1 is a cheap
jnp reduction (XLA fuses it); pass 2 — the elementwise rounding over the
whole tensor, the actual hot loop — is the Pallas kernel.  Per-channel
mode keeps one (s, z) per output row, so the row-tiled kernel computes
its own reduction per row and needs only one pass.

Memory-bound like the mix kernel: read W once, write W_hat once.
interpret=True for CPU-PJRT executability (see quant_noise.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 8


def _round_kernel(w_ref, sz_ref, o_ref, *, qmax: float):
    w = w_ref[...]
    s = sz_ref[0]
    z = sz_ref[1]
    q = jnp.clip(jnp.round(w / s) - z, 0.0, qmax)
    o_ref[...] = (q + z) * s


def _round_channel_kernel(w_ref, o_ref, *, qmax: float):
    """Per-channel: each row computes its own (s, z) then rounds."""
    w = w_ref[...]
    lo = jnp.min(w, axis=1, keepdims=True)
    hi = jnp.max(w, axis=1, keepdims=True)
    s = (hi - lo) / qmax
    s = jnp.where(s <= 0.0, jnp.float32(1.0), s)
    z = jnp.round(lo / s)
    q = jnp.clip(jnp.round(w / s) - z, 0.0, qmax)
    o_ref[...] = (q + z) * s


def fake_quant(w, bits: int):
    """Per-tensor intN fake-quant; forward only (wrap for STE)."""
    qmax = float(2**bits - 1)
    lo = jnp.min(w)
    hi = jnp.max(w)
    s = (hi - lo) / qmax
    s = jnp.where(s <= 0.0, jnp.float32(1.0), s)
    z = jnp.round(lo / s)
    sz = jnp.stack([s, z])
    out_rows, in_dim = w.shape
    tile = TILE_ROWS if out_rows % TILE_ROWS == 0 else 1
    grid = (out_rows // tile,)
    return pl.pallas_call(
        functools.partial(_round_kernel, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, in_dim), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((tile, in_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((out_rows, in_dim), jnp.float32),
        interpret=True,
    )(w, sz)


def fake_quant_channel(w, bits: int):
    """Per-channel intN fake-quant; forward only (wrap for STE)."""
    qmax = float(2**bits - 1)
    out_rows, in_dim = w.shape
    tile = TILE_ROWS if out_rows % TILE_ROWS == 0 else 1
    grid = (out_rows // tile,)
    return pl.pallas_call(
        functools.partial(_round_channel_kernel, qmax=qmax),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, in_dim), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, in_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((out_rows, in_dim), jnp.float32),
        interpret=True,
    )(w)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fake_quant_frozen(w, bits: int, per_channel: bool = False):
    """fake_quant with ZERO backward.

    Used when the quantized image feeds the mix kernel's ``w_hat`` input:
    the mix's STE already returns a zero cotangent there, but JAX cannot
    prove that symbolically and would otherwise try to transpose the
    Pallas call.  Declaring the vjp as zero cuts the path.
    """
    return fake_quant_channel(w, bits) if per_channel else fake_quant(w, bits)


def _fqz_vjp_fwd(w, bits, per_channel):
    return fake_quant_frozen(w, bits, per_channel), None


def _fqz_vjp_bwd(bits, per_channel, _res, g):
    return (jnp.zeros_like(g),)


fake_quant_frozen.defvjp(_fqz_vjp_fwd, _fqz_vjp_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fake_quant_ste(w, bits: int, per_channel: bool = False):
    """intN fake-quant with straight-through backward (QAT building block).

    custom_vjp (not stop_gradient): pallas_call has no JVP rule, so the
    linearizer must never see inside the kernel.
    """
    return fake_quant_channel(w, bits) if per_channel else fake_quant(w, bits)


def _fq_vjp_fwd(w, bits, per_channel):
    return fake_quant_ste(w, bits, per_channel), None


def _fq_vjp_bwd(bits, per_channel, _res, g):
    return (g,)  # STE: identity cotangent


fake_quant_ste.defvjp(_fq_vjp_fwd, _fq_vjp_bwd)
