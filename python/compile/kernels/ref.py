"""Pure-jnp correctness oracles for the Pallas kernels.

These implement, with no Pallas and no cleverness, the exact math the
kernels must reproduce:

  * ``quant_noise_mix`` — paper Eq. (6)/(7): replace a randomly selected
    subset of weight *blocks* by their quantized image, with STE so the
    backward sees the identity on noised blocks.
  * ``fake_quant`` — paper Eq. (2)/(9): uniform intN rounding with scale
    ``s`` and zero-point ``z``.
  * ``pq_assign`` — paper Eq. (10): nearest-centroid assignment of
    subvectors under squared L2.

All oracles operate on 2-D weight matrices ``W`` of shape (out, in) with
blocks of ``block_size`` contiguous elements along the *in* axis (the
fairseq quant_noise convention; the paper's "block size 8" for linears).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def block_mask(unif: jnp.ndarray, rate) -> jnp.ndarray:
    """Per-block Bernoulli(rate) noise mask from uniform(0,1) draws.

    ``unif`` has one entry per block; returns 1.0 where the block is
    *noised* (selected into J), 0.0 where it is left alone.
    """
    return (unif < rate).astype(jnp.float32)


def expand_mask(mask_blocks: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Expand a (out, in//bs) block mask to elementwise (out, in)."""
    return jnp.repeat(mask_blocks, block_size, axis=-1)


def quant_noise_mix(w, w_hat, unif, rate, block_size: int):
    """Eq. (6)/(7) with STE: ``w_noise = w + sg(mask * (w_hat - w))``.

    * Forward: noised blocks take the value of ``w_hat`` (the quantized
      image — zeros for phi_proxy, PQ-decoded weights for exact phi_PQ,
      intN-rounded weights for scalar schemes).
    * Backward: d w_noise / d w = identity everywhere — the straight
      through estimator on noised blocks, true gradient elsewhere.
    """
    m = expand_mask(block_mask(unif, rate), block_size)
    return w + jax.lax.stop_gradient(m * (w_hat - w))


def int_qparams(w, bits: int):
    """Scale and zero-point from the min/max of ``w`` (paper Eq. 2).

    Degenerate (constant) tensors get s = 1 to avoid division by zero;
    the round-trip error is then bounded by 1/2 (value rounds to the
    nearest integer), mirroring PyTorch's scale=1 fallback.
    """
    lo = jnp.min(w)
    hi = jnp.max(w)
    qmax = jnp.float32(2**bits - 1)
    s = (hi - lo) / qmax
    s = jnp.where(s <= 0.0, jnp.float32(1.0), s)
    z = jnp.round(lo / s)
    return s, z


def fake_quant(w, bits: int):
    """Uniform intN fake-quantization, Eq. (2)/(9).

    q = clip(round(w/s) - z, 0, 2^N - 1);  w_hat = (q + z) * s
    (the paper's (round(w/s + z') - z') * s in the opposite sign
    convention; the clamp is explicit so out-of-range values saturate
    exactly as integer hardware would).
    """
    s, z = int_qparams(w, bits)
    qmax = jnp.float32(2**bits - 1)
    q = jnp.clip(jnp.round(w / s) - z, 0.0, qmax)
    return (q + z) * s


def fake_quant_ste(w, bits: int):
    """fake_quant with a straight-through estimator backward."""
    return w + jax.lax.stop_gradient(fake_quant(w, bits) - w)


def fake_quant_channel(w, bits: int):
    """Per-channel (axis 0 = output channel) intN fake-quantization."""
    lo = jnp.min(w, axis=1, keepdims=True)
    hi = jnp.max(w, axis=1, keepdims=True)
    qmax = jnp.float32(2**bits - 1)
    s = (hi - lo) / qmax
    s = jnp.where(s <= 0.0, jnp.float32(1.0), s)
    z = jnp.round(lo / s)
    q = jnp.clip(jnp.round(w / s) - z, 0.0, qmax)
    return (q + z) * s


def pq_assign(subvectors, centroids):
    """Nearest centroid per subvector (paper Eq. 10).

    subvectors: (n, d); centroids: (K, d) → int32 (n,) of argmin indices.
    Ties broken toward the lower index (argmin convention).
    """
    # |b - c|^2 = |b|^2 - 2 b.c + |c|^2 ; |b|^2 is constant per row.
    dots = subvectors @ centroids.T
    c2 = jnp.sum(centroids * centroids, axis=1)
    d2 = c2[None, :] - 2.0 * dots
    return jnp.argmin(d2, axis=1).astype(jnp.int32)


def pq_decode(codes, centroids):
    """Reconstruct (n, d) subvectors from codes (n,) and centroids (K, d)."""
    return centroids[codes]
