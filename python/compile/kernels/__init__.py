# L1: Pallas kernels for the Quant-Noise hot-spots (interpret=True —
# CPU-PJRT executable; see DESIGN.md §Hardware-Adaptation).
from . import fake_quant, pq_assign, quant_noise, ref  # noqa: F401
