"""L1 Pallas kernel: blockwise Quant-Noise mix (paper Eq. 6/7 + STE).

The compute hot-spot of Quant-Noise training is the per-forward weight
transformation: for every weight matrix, select a random subset of
blocks and replace them by their quantized image.  This kernel fuses the
mask expansion and the select into a single pass over W — each of W,
W_hat and the per-block uniforms is read exactly once from HBM and
W_noise is written once (arithmetic intensity ~ 1 op/byte: memory bound,
so the BlockSpec's job is simply to touch every byte once, streaming
row-tiles through VMEM).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks row tiles of
``TILE_ROWS`` rows; each tile holds the full ``in`` dimension so the
per-block mask broadcast (repeat along the lane axis) stays inside one
VMEM tile.  f32 tile of (8, in) costs 32*in bytes — for in <= 4096 this
is ~128 KiB x 3 buffers, well under the ~16 MiB VMEM budget, leaving
room for double buffering.

interpret=True always: CPU PJRT cannot run Mosaic custom-calls; the
interpret path lowers to plain HLO which the rust runtime executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 8


def _mix_kernel(w_ref, w_hat_ref, unif_ref, rate_ref, o_ref, *, block_size: int):
    """One row-tile: o = w + mask*(w_hat - w), mask per block of lanes."""
    w = w_ref[...]
    w_hat = w_hat_ref[...]
    unif = unif_ref[...]  # (tile_rows, in // block_size)
    rate = rate_ref[0]
    mask = (unif < rate).astype(jnp.float32)
    # Expand the per-block mask across the block_size lanes it governs.
    rows, nblocks = unif.shape
    m = jnp.repeat(mask, block_size, axis=1)
    o_ref[...] = w + m * (w_hat - w)


def quant_noise_mix_fwd(w, w_hat, unif, rate, *, block_size: int):
    """Forward-only mix; no STE (used inside the custom-vjp wrapper)."""
    out_rows, in_dim = w.shape
    assert in_dim % block_size == 0, (in_dim, block_size)
    nblocks = in_dim // block_size
    assert unif.shape == (out_rows, nblocks), (unif.shape, out_rows, nblocks)
    rate = jnp.asarray(rate, jnp.float32).reshape((1,))
    # Row-tile the grid; pad-free because callers use multiple-of-8 rows
    # (model dims are multiples of 8) — asserted here for safety.
    tile = TILE_ROWS if out_rows % TILE_ROWS == 0 else 1
    grid = (out_rows // tile,)
    return pl.pallas_call(
        functools.partial(_mix_kernel, block_size=block_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, in_dim), lambda i: (i, 0)),
            pl.BlockSpec((tile, in_dim), lambda i: (i, 0)),
            pl.BlockSpec((tile, nblocks), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((tile, in_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((out_rows, in_dim), jnp.float32),
        interpret=True,
    )(w, w_hat, unif, rate)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def quant_noise_mix(w, w_hat, unif, rate, block_size: int):
    """Quant-Noise weight transformation with STE backward.

    Matches ``ref.quant_noise_mix``: forward mixes in the quantized image
    on selected blocks; backward is the identity w.r.t. ``w`` (straight
    through estimator) and zero w.r.t. ``w_hat``/``unif``/``rate``.
    (custom_vjp rather than stop_gradient: pallas_call has no JVP rule,
    so linearization must never look inside the kernel.)
    """
    return quant_noise_mix_fwd(w, w_hat, unif, rate, block_size=block_size)


def _mix_vjp_fwd(w, w_hat, unif, rate, block_size):
    return quant_noise_mix_fwd(w, w_hat, unif, rate, block_size=block_size), None


def _mix_vjp_bwd(block_size, _res, g):
    # STE: pass the cotangent straight through to w; w_hat/unif/rate get 0.
    rows, in_dim = g.shape
    zero_unif = jnp.zeros((rows, in_dim // block_size), jnp.float32)
    return (g, jnp.zeros_like(g), zero_unif, jnp.zeros((), jnp.float32))


quant_noise_mix.defvjp(_mix_vjp_fwd, _mix_vjp_bwd)
