"""Quant-Noise plumbing for L2 models.

Applies the L1 kernels to a *params dict* according to each parameter's
quantization spec (its block size and whether it participates — norms and
biases are never noised, matching the paper's choice of FFN/emb/attn for
Transformers and conv/classifier weights for ConvNets).

Noise kinds (compile-time constant per artifact — see DESIGN.md):
  * "mix"          — W_noise = W + sg(mask (Ŵ − W)); Ŵ supplied by the
                     coordinator (zeros = φ_proxy, PQ decode = exact φ_PQ,
                     blockwise mean = the mean-subvector variant).
  * "int8"/"int4"  — φ_intN computed in-graph (Eq. 9, per-tensor, scale
                     and zero-point live-updated from the weights).
  * "int8_channel"/"int4_channel" — per-channel variant (Table 10).

Every noised weight is handled in its 2-D (rows, cols) view with blocks
of ``block_size`` contiguous elements along ``cols``; conv weights are
reshaped per DESIGN.md (1×1 → (O, I) bs 4; dw3×3 → (C, 9) bs 9).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .kernels import fake_quant, quant_noise, ref

# Perf knob (EXPERIMENTS.md §Perf): QN_KERNEL_IMPL=jnp lowers the noise
# math through the pure-jnp oracle instead of the Pallas kernels. The
# two are verified equivalent by pytest; on CPU PJRT the interpret-mode
# Pallas call becomes a scalar while-loop, so the jnp lowering is the
# fast CPU build. Pallas remains the reference (TPU-shaped) path.
_IMPL = os.environ.get("QN_KERNEL_IMPL", "pallas")


def apply_noise(
    name: str,
    w2d,
    kind: str,
    rate,
    key,
    block_size: int,
    w_hat2d=None,
):
    """Noise one weight's 2-D view. Returns the noised 2-D view."""
    rows, cols = w2d.shape
    assert cols % block_size == 0, (name, w2d.shape, block_size)
    nblocks = cols // block_size
    unif = jax.random.uniform(key, (rows, nblocks), jnp.float32)
    jnp_impl = _IMPL == "jnp"
    if kind == "mix":
        assert w_hat2d is not None, name
        if jnp_impl:
            return ref.quant_noise_mix(w2d, w_hat2d, unif, rate, block_size)
        return quant_noise.quant_noise_mix(w2d, w_hat2d, unif, rate, block_size)
    if kind in ("int8", "int4", "int8_channel", "int4_channel"):
        bits = 8 if kind.startswith("int8") else 4
        per_channel = kind.endswith("channel")
        if jnp_impl:
            fq = (
                ref.fake_quant_channel(w2d, bits)
                if per_channel
                else ref.fake_quant(w2d, bits)
            )
            return ref.quant_noise_mix(
                w2d, jax.lax.stop_gradient(fq), unif, rate, block_size
            )
        # frozen (zero-vjp) image: the mix STE passes gradient to w only
        w_hat = fake_quant.fake_quant_frozen(w2d, bits, per_channel)
        return quant_noise.quant_noise_mix(w2d, w_hat, unif, rate, block_size)
    raise ValueError(f"unknown noise kind {kind!r}")


def noise_params(params, specs, kind: str, rate, seed, params_hat=None):
    """Apply Quant-Noise across a params dict.

    ``specs`` maps name → (rows, cols, block_size) 2-D view spec; names
    missing from specs (norms, biases) pass through untouched.  Each
    weight gets an independent rng stream (fold_in on its index) so a
    single int32 seed drives the whole step.
    """
    base = jax.random.PRNGKey(seed)
    out = {}
    for i, name in enumerate(sorted(params)):
        w = params[name]
        if name not in specs:
            out[name] = w
            continue
        rows, cols, bs = specs[name]
        w2d = w.reshape(rows, cols)
        w_hat2d = None
        if kind == "mix":
            w_hat2d = params_hat[name].reshape(rows, cols)
        key = jax.random.fold_in(base, i)
        out[name] = apply_noise(
            name, w2d, kind, rate, key, bs, w_hat2d
        ).reshape(w.shape)
    return out


def fake_quant_activations(x, bits: int = 8):
    """Dynamic per-tensor intN fake-quant of activations (§3.3 combo).

    Plain jnp (not Pallas): activation tensors are shaped (B, T, D) or
    (B, H, W, C) and XLA fuses this into the surrounding ops; the paper's
    static histogram calibration is implemented coordinator-side for
    weights, while activations use dynamic min/max — the substitution is
    recorded in DESIGN.md.
    """
    qmax = jnp.float32(2**bits - 1)
    lo = jnp.min(x)
    hi = jnp.max(x)
    s = (hi - lo) / qmax
    s = jnp.where(s <= 0.0, jnp.float32(1.0), s)
    z = jnp.round(lo / s)
    q = jnp.clip(jnp.round(x / s) - z, 0.0, qmax)
    return (q + z) * s
