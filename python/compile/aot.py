"""AOT export: lower every entry point to HLO *text* + manifest.json.

This is the only place Python touches the pipeline; after `make
artifacts` the Rust binary is self-contained.  HLO text (NOT
`.serialize()`): jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Per model config this writes:
  artifacts/<model>.<entry>.hlo.txt   — one compiled-ready module each
  artifacts/<model>.init.bin          — initial params, QNP1 format
  artifacts/manifest.json             — input/output orders, param specs

Entry points (DESIGN.md §1):
  grad_mix / grad_int8 / grad_int4 / grad_int8_channel /
  grad_int4_channel / grad_mix_ldste : (params*, params_hat*, tokens,
      targets, layer_keep, rate, seed) → (loss, grads*)
  eval / eval_int8act : (params*, tokens, targets, layer_keep)
      → (sum_nll, sum_correct)

QNP1 format: magic b"QNP1", u32 LE header length, JSON header
{"params": [{"name", "shape"}...]}, then concatenated f32 LE data in
header order.
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import convnet, model

GRAD_ENTRIES = [
    "grad_mix",
    "grad_int8",
    "grad_int4",
    "grad_int8_channel",
    "grad_int4_channel",
]
LM_ENTRIES = GRAD_ENTRIES + ["grad_mix_ldste", "eval", "eval_int8act"]
CLS_ENTRIES = ["grad_mix", "eval"]
IMG_ENTRIES = GRAD_ENTRIES + ["eval", "eval_int8act"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_qnp1(path: str, names, params):
    header = json.dumps(
        {"params": [{"name": n, "shape": list(params[n].shape)} for n in names]}
    ).encode()
    with open(path, "wb") as f:
        f.write(b"QNP1")
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for n in names:
            f.write(np.asarray(params[n], np.float32).tobytes())


# ------------------------------------------------------------------ LM ---

def build_transformer(cfg_dict):
    cfg = model.TransformerConfig(
        vocab=cfg_dict["vocab"],
        d_model=cfg_dict["d_model"],
        n_layers=cfg_dict["n_layers"],
        n_heads=cfg_dict["n_heads"],
        d_ffn=cfg_dict["d_ffn"],
        seq_len=cfg_dict["seq_len"],
        batch=cfg_dict["batch"],
        noise_block_size=cfg_dict.get("noise_block_size", 8),
        n_classes=cfg_dict.get("n_classes", 0),
    )
    task = cfg_dict["task"]
    names = sorted(model.param_shapes(cfg))
    shapes = model.param_shapes(cfg)
    specs = model.quant_specs(cfg)

    tok_shape = (cfg.batch, cfg.seq_len)
    tgt_shape = tok_shape if task == "lm" else (cfg.batch,)

    def grad_entry(kind, ldste=False):
        c = (
            model.TransformerConfig(**{**cfg.__dict__, "layerdrop_ste": True})
            if ldste
            else cfg
        )
        loss_fn = model.noisy_loss_fn(c, kind, task)

        def fn(*flat):
            n = len(names)
            params = dict(zip(names, flat[:n]))
            params_hat = dict(zip(names, flat[n : 2 * n]))
            tokens, targets, layer_keep, rate, seed = flat[2 * n :]
            loss, grads = jax.value_and_grad(loss_fn)(
                params, params_hat, tokens, targets, layer_keep, rate, seed
            )
            return (loss,) + tuple(grads[n] for n in names)

        args = (
            [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names] * 2
            + [
                jax.ShapeDtypeStruct(tok_shape, jnp.int32),
                jax.ShapeDtypeStruct(tgt_shape, jnp.int32),
                jax.ShapeDtypeStruct((cfg.n_layers,), jnp.float32),
                jax.ShapeDtypeStruct((), jnp.float32),
                jax.ShapeDtypeStruct((), jnp.int32),
            ]
        )
        return fn, args

    def eval_entry(int8act=False):
        c = (
            model.TransformerConfig(**{**cfg.__dict__, "int8_activations": True})
            if int8act
            else cfg
        )
        ev = model.cls_eval if task == "cls" else model.lm_eval

        def fn(*flat):
            n = len(names)
            params = dict(zip(names, flat[:n]))
            tokens, targets, layer_keep = flat[n:]
            return ev(c, params, tokens, targets, layer_keep)

        args = [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names] + [
            jax.ShapeDtypeStruct(tok_shape, jnp.int32),
            jax.ShapeDtypeStruct(tgt_shape, jnp.int32),
            jax.ShapeDtypeStruct((cfg.n_layers,), jnp.float32),
        ]
        return fn, args

    entries = {}
    wanted = LM_ENTRIES if task == "lm" else CLS_ENTRIES
    for e in wanted:
        if e.startswith("grad"):
            kind = "mix" if "mix" in e else e[len("grad_") :]
            entries[e] = grad_entry(kind, ldste=e.endswith("ldste"))
        else:
            entries[e] = eval_entry(int8act=e.endswith("int8act"))

    param_meta = [
        {
            "name": n,
            "shape": list(shapes[n]),
            "structure": model.structure_of(n),
            "noised": n in specs,
            "view": list(specs[n][:2]) if n in specs else None,
            "block_size": specs[n][2] if n in specs else None,
        }
        for n in names
    ]
    init = model.init_params(cfg, seed=0)
    meta = {
        "task": task,
        "n_layers": cfg.n_layers,
        "batch": cfg.batch,
        "seq_len": cfg.seq_len,
        "tokens_shape": list(tok_shape),
        "targets_shape": list(tgt_shape),
        "vocab": cfg.vocab,
        "n_classes": cfg.n_classes,
    }
    return names, init, entries, param_meta, meta


# ----------------------------------------------------------------- IMG ---

def build_convnet(cfg_dict):
    cfg = convnet.ConvConfig(
        image_size=cfg_dict["image_size"],
        in_channels=cfg_dict["in_channels"],
        stem_channels=cfg_dict["stem_channels"],
        blocks=tuple(tuple(b) for b in cfg_dict["blocks"]),
        n_classes=cfg_dict["n_classes"],
        batch=cfg_dict["batch"],
    )
    names = sorted(convnet.param_shapes(cfg))
    shapes = convnet.param_shapes(cfg)
    specs = convnet.quant_specs(cfg)
    img_shape = (cfg.batch, cfg.image_size, cfg.image_size, cfg.in_channels)
    lbl_shape = (cfg.batch,)
    n_blocks = len(cfg.blocks)

    def grad_entry(kind):
        loss_fn = convnet.noisy_loss_fn(cfg, kind)

        def fn(*flat):
            n = len(names)
            params = dict(zip(names, flat[:n]))
            params_hat = dict(zip(names, flat[n : 2 * n]))
            images, labels, block_keep, rate, seed = flat[2 * n :]
            loss, grads = jax.value_and_grad(loss_fn)(
                params, params_hat, images, labels, block_keep, rate, seed
            )
            return (loss,) + tuple(grads[n] for n in names)

        args = (
            [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names] * 2
            + [
                jax.ShapeDtypeStruct(img_shape, jnp.float32),
                jax.ShapeDtypeStruct(lbl_shape, jnp.int32),
                jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
                jax.ShapeDtypeStruct((), jnp.float32),
                jax.ShapeDtypeStruct((), jnp.int32),
            ]
        )
        return fn, args

    def eval_entry(int8act=False):
        c = (
            convnet.ConvConfig(**{**cfg.__dict__, "int8_activations": True})
            if int8act
            else cfg
        )

        def fn(*flat):
            n = len(names)
            params = dict(zip(names, flat[:n]))
            images, labels, block_keep = flat[n:]
            return convnet.img_eval(c, params, images, labels, block_keep)

        args = [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names] + [
            jax.ShapeDtypeStruct(img_shape, jnp.float32),
            jax.ShapeDtypeStruct(lbl_shape, jnp.int32),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
        ]
        return fn, args

    entries = {}
    for e in IMG_ENTRIES:
        if e.startswith("grad"):
            kind = "mix" if "mix" in e else e[len("grad_") :]
            entries[e] = grad_entry(kind)
        else:
            entries[e] = eval_entry(int8act=e.endswith("int8act"))

    param_meta = [
        {
            "name": n,
            "shape": list(shapes[n]),
            "structure": convnet.structure_of(n),
            "noised": n in specs,
            "view": list(specs[n][:2]) if n in specs else None,
            "block_size": specs[n][2] if n in specs else None,
        }
        for n in names
    ]
    init = convnet.init_params(cfg, seed=0)
    meta = {
        "task": "img",
        "n_layers": n_blocks,
        "batch": cfg.batch,
        "seq_len": 0,
        "tokens_shape": list(img_shape),
        "targets_shape": list(lbl_shape),
        "vocab": 0,
        "n_classes": cfg.n_classes,
    }
    return names, init, entries, param_meta, meta


# ---------------------------------------------------------------- main ---

def export_model(cfg_dict, out_dir, only_entries=None, manifest_models=None):
    name = cfg_dict["name"]
    task = cfg_dict["task"]
    build = build_convnet if task == "img" else build_transformer
    names, init, entries, param_meta, meta = build(cfg_dict)

    wanted = cfg_dict.get("entries") or list(entries)
    if only_entries:
        wanted = [e for e in wanted if e in only_entries]

    entry_meta = {}
    for e in wanted:
        fn, args = entries[e]
        # keep_unused: intN-noise grads ignore params_hat; without this
        # XLA would prune them and every entry would need its own input
        # layout. A uniform signature keeps the Rust runtime simple.
        lowered = jax.jit(fn, keep_unused=True).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.{e}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        # Input layout descriptor the Rust runtime follows verbatim.
        n = len(names)
        if e.startswith("grad"):
            inputs = (
                [f"param:{p}" for p in names]
                + [f"param_hat:{p}" for p in names]
                + ["tokens", "targets", "layer_keep", "rate", "seed"]
            )
            outputs = ["loss"] + [f"grad:{p}" for p in names]
        else:
            inputs = [f"param:{p}" for p in names] + [
                "tokens", "targets", "layer_keep",
            ]
            outputs = ["sum_nll", "sum_correct"]
        entry_meta[e] = {"file": fname, "inputs": inputs, "outputs": outputs}
        print(f"  [{name}] {e}: {len(text)} chars, {len(inputs)} inputs")

    write_qnp1(os.path.join(out_dir, f"{name}.init.bin"), names, init)
    manifest_models[name] = {
        **meta,
        "config": cfg_dict,
        "params": param_meta,
        "entries": entry_meta,
        "init": f"{name}.init.bin",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", nargs="+", required=True)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--entries", nargs="*", default=None)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    models = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            models = json.load(f).get("models", {})

    for cfg_path in args.configs:
        with open(cfg_path) as f:
            cfg_dict = json.load(f)
        print(f"exporting {cfg_dict['name']} ({cfg_dict['task']})")
        export_model(cfg_dict, args.out_dir, args.entries, models)

    with open(manifest_path, "w") as f:
        json.dump({"version": 1, "models": models}, f, indent=1, sort_keys=True)
    print(f"wrote {manifest_path} ({len(models)} models)")


if __name__ == "__main__":
    main()
