# AOT export contract tests: manifest structure, QNP1 format, input
# ordering — the exact things the Rust runtime depends on.
import json
import os
import struct
import subprocess
import sys

import numpy as np
import pytest

from compile import aot, model

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# `make artifacts` exports here — the same tree the Rust integration
# tests (CARGO_MANIFEST_DIR/artifacts) and the `qn` CLI default read.
ARTIFACTS = os.path.join(os.path.dirname(HERE), "rust", "artifacts")


def test_qnp1_roundtrip(tmp_path):
    params = {
        "a": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b": np.array([1.5, -2.5], np.float32),
    }
    path = str(tmp_path / "p.bin")
    aot.write_qnp1(path, ["a", "b"], params)
    with open(path, "rb") as f:
        assert f.read(4) == b"QNP1"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        assert header["params"][0] == {"name": "a", "shape": [2, 3]}
        data = np.frombuffer(f.read(), np.float32)
    np.testing.assert_array_equal(data[:6], params["a"].ravel())
    np.testing.assert_array_equal(data[6:], params["b"])


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_manifest_contract():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    models = manifest["models"]
    assert "lm_tiny" in models
    m = models["lm_tiny"]
    names = [p["name"] for p in m["params"]]
    assert names == sorted(names), "params must be in sorted-name order"
    # grad input order: params, hats, batch, targets, keep, rate, seed
    grad = m["entries"]["grad_mix"]
    n = len(names)
    assert grad["inputs"][:n] == [f"param:{x}" for x in names]
    assert grad["inputs"][n : 2 * n] == [f"param_hat:{x}" for x in names]
    assert grad["inputs"][2 * n :] == ["tokens", "targets", "layer_keep", "rate", "seed"]
    assert grad["outputs"] == ["loss"] + [f"grad:{x}" for x in names]
    # eval entry omits hats and scalars
    ev = m["entries"]["eval"]
    assert ev["inputs"] == [f"param:{x}" for x in names] + ["tokens", "targets", "layer_keep"]
    # every referenced file exists
    for e in m["entries"].values():
        assert os.path.exists(os.path.join(ARTIFACTS, e["file"])), e["file"]
    assert os.path.exists(os.path.join(ARTIFACTS, m["init"]))


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_hlo_entry_parameter_counts():
    # ENTRY computations must keep every manifest input (keep_unused)
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        m = json.load(f)["models"]["lm_tiny"]
    for ename in ["grad_mix", "grad_int8", "eval"]:
        e = m["entries"][ename]
        text = open(os.path.join(ARTIFACTS, e["file"])).read()
        entry = text.split("ENTRY", 1)[1]
        count = entry.count("= f32[") + entry.count("= s32[")
        n_params = sum(
            1 for line in entry.splitlines() if "parameter(" in line
        )
        assert n_params == len(e["inputs"]), f"{ename}: {n_params} vs {len(e['inputs'])}"


def test_structure_groups_cover_transformer():
    cfg = model.TransformerConfig(n_classes=2)
    names = model.param_shapes(cfg)
    groups = {model.structure_of(n) for n in names}
    assert groups == {"emb", "attn", "ffn", "norm", "cls"}
