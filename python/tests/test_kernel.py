# pytest: Pallas kernels vs pure-jnp ref — the CORE correctness signal.
# Hypothesis sweeps shapes, rates and bit-widths; every kernel must match
# its oracle bit-for-bit (or to fp32 round-off for the rounding paths).
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fake_quant, pq_assign, quant_noise, ref

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- mix ---
@settings(**SETTINGS)
@given(
    rows=st.sampled_from([1, 7, 8, 16, 64]),
    nblocks=st.sampled_from([1, 2, 4, 16]),
    block_size=st.sampled_from([1, 4, 8]),
    rate=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_mix_matches_ref(rows, nblocks, block_size, rate, seed):
    w = rand(seed, (rows, nblocks * block_size))
    w_hat = rand(seed + 1, (rows, nblocks * block_size))
    unif = jax.random.uniform(jax.random.PRNGKey(seed + 2), (rows, nblocks))
    got = quant_noise.quant_noise_mix(w, w_hat, unif, rate, block_size)
    want = ref.quant_noise_mix(w, w_hat, unif, rate, block_size)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_mix_rate_zero_is_identity():
    w = rand(0, (16, 32))
    unif = jax.random.uniform(jax.random.PRNGKey(1), (16, 4))
    got = quant_noise.quant_noise_mix(w, jnp.zeros_like(w), unif, 0.0, 8)
    np.testing.assert_array_equal(got, w)


def test_mix_rate_one_is_qat():
    # rate=1 quantizes every block: Quant-Noise degenerates to QAT (§4.1).
    w = rand(0, (16, 32))
    w_hat = rand(1, (16, 32))
    unif = jax.random.uniform(jax.random.PRNGKey(2), (16, 4))
    got = quant_noise.quant_noise_mix(w, w_hat, unif, 1.0, 8)
    # w + 1.0*(w_hat - w) equals w_hat only up to fp32 round-off
    np.testing.assert_allclose(got, w_hat, rtol=1e-5, atol=1e-6)


def test_mix_block_granularity():
    # Within one block, either every element is noised or none is.
    w = rand(3, (8, 64))
    unif = jax.random.uniform(jax.random.PRNGKey(4), (8, 8))
    got = quant_noise.quant_noise_mix(w, jnp.zeros_like(w), unif, 0.5, 8)
    changed = np.asarray(got != w).reshape(8, 8, 8)
    per_block = changed.any(axis=2)
    np.testing.assert_array_equal(changed.all(axis=2), per_block)


@settings(**SETTINGS)
@given(rate=st.floats(0.0, 1.0), seed=st.integers(0, 2**16))
def test_mix_ste_gradient_is_identity(rate, seed):
    # Backward of the noised matmul: dL/dW must ignore the noise (STE).
    w = rand(seed, (8, 32))
    w_hat = jnp.zeros_like(w)
    unif = jax.random.uniform(jax.random.PRNGKey(seed), (8, 4))
    g = jax.grad(
        lambda w: quant_noise.quant_noise_mix(
            w, w_hat, unif, jnp.float32(rate), 8
        ).sum()
    )(w)
    np.testing.assert_array_equal(g, jnp.ones_like(w))


def test_mix_expected_noised_fraction():
    # E[#noised blocks] = rate * #blocks; check within 5 sigma.
    rows, nblocks, rate = 64, 64, 0.3
    w = jnp.ones((rows, nblocks * 8))
    unif = jax.random.uniform(jax.random.PRNGKey(7), (rows, nblocks))
    got = quant_noise.quant_noise_mix(w, jnp.zeros_like(w), unif, rate, 8)
    frac = float((got == 0).mean())
    n = rows * nblocks
    sigma = (rate * (1 - rate) / n) ** 0.5
    assert abs(frac - rate) < 5 * sigma


# --------------------------------------------------------- fake quant ---
@settings(**SETTINGS)
@given(
    rows=st.sampled_from([1, 5, 8, 32]),
    cols=st.sampled_from([8, 16, 64]),
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_fake_quant_matches_ref(rows, cols, bits, seed):
    w = rand(seed, (rows, cols)) * 3.0
    np.testing.assert_allclose(
        fake_quant.fake_quant(w, bits), ref.fake_quant(w, bits),
        rtol=1e-5, atol=1e-6,
    )


@settings(**SETTINGS)
@given(
    rows=st.sampled_from([1, 8, 32]),
    cols=st.sampled_from([8, 64]),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_fake_quant_channel_matches_ref(rows, cols, bits, seed):
    w = rand(seed, (rows, cols)) * 2.0
    np.testing.assert_allclose(
        fake_quant.fake_quant_channel(w, bits), ref.fake_quant_channel(w, bits),
        rtol=1e-5, atol=1e-6,
    )


@settings(**SETTINGS)
@given(bits=st.sampled_from([4, 8]), seed=st.integers(0, 2**16))
def test_fake_quant_levels(bits, seed):
    # Output must take at most 2^bits distinct values.
    w = rand(seed, (16, 64))
    fq = np.asarray(fake_quant.fake_quant(w, bits))
    assert len(np.unique(fq)) <= 2**bits


@settings(**SETTINGS)
@given(bits=st.sampled_from([4, 8]), seed=st.integers(0, 2**16))
def test_fake_quant_error_bound(bits, seed):
    # Round-trip error is bounded by s/2 per element (uniform rounding).
    w = rand(seed, (16, 64))
    s = float((w.max() - w.min()) / (2**bits - 1))
    err = np.abs(np.asarray(fake_quant.fake_quant(w, bits)) - np.asarray(w))
    assert err.max() <= s / 2 + 1e-6


def test_fake_quant_constant_tensor():
    # Degenerate input: s falls back to 1 so the error is bounded by s/2,
    # and the kernel must agree with the oracle exactly.
    w = jnp.full((8, 16), 0.37, jnp.float32)
    got = fake_quant.fake_quant(w, 8)
    np.testing.assert_allclose(got, ref.fake_quant(w, 8), atol=1e-7)
    assert float(jnp.max(jnp.abs(got - w))) <= 0.5


def test_fake_quant_ste_gradient():
    w = rand(0, (8, 32))
    for per_channel in (False, True):
        g = jax.grad(lambda w: fake_quant.fake_quant_ste(w, 4, per_channel).sum())(w)
        np.testing.assert_array_equal(g, jnp.ones_like(w))


def test_fake_quant_idempotent():
    w = rand(9, (8, 32))
    fq1 = fake_quant.fake_quant(w, 8)
    fq2 = fake_quant.fake_quant(fq1, 8)
    np.testing.assert_allclose(fq1, fq2, rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------- pq assign ---
@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 100, 128, 256, 300]),
    d=st.sampled_from([4, 8]),
    k=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**16),
)
def test_pq_assign_matches_ref(n, d, k, seed):
    sub = rand(seed, (n, d))
    cent = rand(seed + 1, (k, d))
    np.testing.assert_array_equal(
        pq_assign.pq_assign(sub, cent), ref.pq_assign(sub, cent)
    )


@settings(**SETTINGS)
@given(n=st.sampled_from([16, 128]), d=st.sampled_from([4, 8]), seed=st.integers(0, 2**16))
def test_pq_assign_is_true_argmin(n, d, seed):
    # Brute-force distance check: the chosen centroid is never beaten.
    sub = np.asarray(rand(seed, (n, d)))
    cent = np.asarray(rand(seed + 1, (32, d)))
    codes = np.asarray(pq_assign.pq_assign(jnp.asarray(sub), jnp.asarray(cent)))
    d2 = ((sub[:, None, :] - cent[None, :, :]) ** 2).sum(-1)
    best = d2.min(axis=1)
    chosen = d2[np.arange(n), codes]
    np.testing.assert_allclose(chosen, best, rtol=1e-4, atol=1e-5)


def test_pq_assign_centroids_map_to_themselves():
    cent = rand(5, (32, 8))
    codes = pq_assign.pq_assign(cent, cent)
    np.testing.assert_array_equal(codes, np.arange(32))


def test_pq_decode_roundtrip():
    cent = rand(6, (16, 8))
    codes = jnp.asarray(np.random.RandomState(0).randint(0, 16, size=100), jnp.int32)
    dec = pq_assign.pq_decode(codes, cent)
    np.testing.assert_array_equal(dec, np.asarray(cent)[np.asarray(codes)])
