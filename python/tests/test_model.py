# L2 model tests: shapes, loss sanity, STE gradient identities, noise
# plumbing, conv canonical-view round-trips, LayerDrop semantics.
import jax
import jax.numpy as jnp
import numpy as np

from compile import convnet, model, qnoise

jax.config.update("jax_platform_name", "cpu")

CFG = model.TransformerConfig(
    vocab=64, d_model=32, n_layers=2, n_heads=2, d_ffn=64, seq_len=16, batch=2
)


def params_and_batch(seed=0):
    params = model.init_params(CFG, seed)
    key = jax.random.PRNGKey(seed + 1)
    tokens = jax.random.randint(key, (CFG.batch, CFG.seq_len), 0, CFG.vocab)
    targets = jnp.roll(tokens, -1, axis=1)
    return params, tokens, targets


def test_param_shapes_cover_init():
    shapes = model.param_shapes(CFG)
    params = model.init_params(CFG)
    assert set(shapes) == set(params)
    for n, s in shapes.items():
        assert params[n].shape == s


def test_quant_specs_only_noised_weights():
    specs = model.quant_specs(CFG)
    assert "embed" in specs and "layer00.wq" in specs
    assert "layer00.ln1_g" not in specs and "lnf_b" not in specs
    for name, (rows, cols, bs) in specs.items():
        assert cols % bs == 0, name
        assert np.prod(model.param_shapes(CFG)[name]) == rows * cols


def test_lm_loss_near_uniform_at_init():
    params, tokens, targets = params_and_batch()
    keep = jnp.ones(CFG.n_layers)
    loss = model.lm_loss(CFG, params, tokens, targets, keep)
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0


def test_lm_eval_matches_loss():
    params, tokens, targets = params_and_batch()
    keep = jnp.ones(CFG.n_layers)
    loss = model.lm_loss(CFG, params, tokens, targets, keep)
    sum_nll, _ = model.lm_eval(CFG, params, tokens, targets, keep)
    np.testing.assert_allclose(
        float(sum_nll) / (CFG.batch * CFG.seq_len), float(loss), rtol=1e-5
    )


def test_causality():
    # changing a future token must not affect past logits
    params, tokens, _ = params_and_batch()
    keep = jnp.ones(CFG.n_layers)
    h1 = model.forward(CFG, params, tokens, keep)
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % CFG.vocab)
    h2 = model.forward(CFG, params, tokens2, keep)
    np.testing.assert_allclose(h1[:, :-1], h2[:, :-1], atol=1e-5)


def test_layerdrop_zero_mask_is_identity_path():
    params, tokens, targets = params_and_batch()
    keep_none = jnp.zeros(CFG.n_layers)
    keep_all = jnp.ones(CFG.n_layers)
    l0 = model.lm_loss(CFG, params, tokens, targets, keep_none)
    l1 = model.lm_loss(CFG, params, tokens, targets, keep_all)
    assert not np.isclose(float(l0), float(l1))


def test_noise_grads_flow_to_all_weights():
    params, tokens, targets = params_and_batch()
    fn = model.noisy_loss_fn(CFG, "mix", "lm")
    hats = {k: jnp.zeros_like(v) for k, v in params.items()}
    keep = jnp.ones(CFG.n_layers)
    grads = jax.grad(fn)(params, hats, tokens, targets, keep, jnp.float32(0.5), 3)
    for name, g in grads.items():
        assert g.shape == params[name].shape
        assert np.all(np.isfinite(np.asarray(g))), name


def test_int_noise_rate_zero_matches_plain_loss():
    params, tokens, targets = params_and_batch()
    keep = jnp.ones(CFG.n_layers)
    fn = model.noisy_loss_fn(CFG, "int8", "lm")
    hats = {k: jnp.zeros_like(v) for k, v in params.items()}
    noisy = fn(params, hats, tokens, targets, keep, jnp.float32(0.0), 3)
    plain = model.lm_loss(CFG, params, tokens, targets, keep)
    np.testing.assert_allclose(float(noisy), float(plain), rtol=1e-5)


def test_cls_heads():
    cfg = model.TransformerConfig(
        vocab=32, d_model=32, n_layers=1, n_heads=2, d_ffn=32, seq_len=8,
        batch=4, n_classes=3,
    )
    params = model.init_params(cfg)
    tokens = jnp.zeros((4, 8), jnp.int32)
    labels = jnp.array([0, 1, 2, 0], jnp.int32)
    keep = jnp.ones(1)
    loss = model.cls_loss(cfg, params, tokens, labels, keep)
    assert abs(float(loss) - np.log(3)) < 0.5
    sum_nll, correct = model.cls_eval(cfg, params, tokens, labels, keep)
    assert 0 <= float(correct) <= 4


# ------------------------------------------------------------- conv ---

CCFG = convnet.ConvConfig(image_size=8, blocks=((16, 1, 2), (24, 2, 2)), batch=2)


def test_conv_shapes_and_loss():
    params = convnet.init_params(CCFG)
    imgs = jnp.ones((2, 8, 8, 3)) * 0.5
    labels = jnp.array([1, 2], jnp.int32)
    keep = jnp.ones(len(CCFG.blocks))
    loss = convnet.img_loss(CCFG, params, imgs, labels, keep)
    assert abs(float(loss) - np.log(CCFG.n_classes)) < 1.0


def test_conv_2d_view_roundtrip():
    params = convnet.init_params(CCFG)
    for name in ["stem", "block00.expand", "block00.dw", "block01.project"]:
        w = params[name]
        w2d = convnet.to2d(name, w, CCFG)
        back = convnet.from2d(name, w2d, w.shape)
        np.testing.assert_array_equal(np.asarray(w), np.asarray(back))


def test_conv_quant_specs_match_views():
    params = convnet.init_params(CCFG)
    specs = convnet.quant_specs(CCFG)
    for name, (rows, cols, bs) in specs.items():
        w2d = convnet.to2d(name, params[name], CCFG)
        assert w2d.reshape(-1).shape[0] == rows * cols, name
        assert cols % bs == 0, name
    # paper block sizes: 1x1 -> 4, dw3x3 -> 9
    assert specs["block00.expand"][2] == 4
    assert specs["block00.dw"][2] == 9
    assert specs["cls"][2] == 4


def test_conv_noise_grads_finite():
    params = convnet.init_params(CCFG)
    fn = convnet.noisy_loss_fn(CCFG, "mix")
    hats = {k: jnp.zeros_like(v) for k, v in params.items()}
    imgs = jnp.ones((2, 8, 8, 3)) * 0.3
    labels = jnp.array([0, 1], jnp.int32)
    keep = jnp.ones(len(CCFG.blocks))
    loss, grads = jax.value_and_grad(fn)(
        params, hats, imgs, labels, keep, jnp.float32(0.3), 5
    )
    assert np.isfinite(float(loss))
    for name, g in grads.items():
        assert np.all(np.isfinite(np.asarray(g))), name


def test_activation_fake_quant_levels():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    q = qnoise.fake_quant_activations(x, bits=8)
    assert len(np.unique(np.asarray(q))) <= 256
    np.testing.assert_allclose(np.asarray(q), np.asarray(x), atol=float(x.max() - x.min()) / 255)
