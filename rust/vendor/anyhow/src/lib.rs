//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no network and no crates.io registry, so
//! this vendored crate provides the slice of anyhow the coordinator
//! uses: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Errors carry a display message plus an optional chained cause;
//! `{:#}` formatting prints the full chain like upstream anyhow.
//! Errors built from a concrete `std::error::Error` type (via `?` or
//! [`Error::new`]) additionally keep the original value as a typed
//! payload, so [`Error::downcast_ref`] works through `.context(...)`
//! wrapping like upstream.

use std::any::Any;
use std::fmt;

/// `Result<T, anyhow::Error>` with the same default-parameter shape as
/// upstream anyhow, so `anyhow::Result<()>` and `Result<T, E>` both work.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-plus-cause error chain. Deliberately does NOT implement
/// `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
/// impl coherent (the same trick upstream anyhow relies on).
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
    // The concrete error value this node was built from, when known.
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), cause: None, payload: None }
    }

    /// Build an error from a concrete error value, keeping it as a
    /// typed payload retrievable with [`Error::downcast_ref`].
    pub fn new<E>(e: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error::from(e)
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), cause: Some(Box::new(self)), payload: None }
    }

    /// The first payload in the chain (outermost first) that is a `T`.
    pub fn downcast_ref<T: Any>(&self) -> Option<&T> {
        self.chain().find_map(|e| e.payload.as_ref()?.downcast_ref::<T>())
    }

    /// Whether any payload in the chain is a `T`.
    pub fn is<T: Any>(&self) -> bool {
        self.downcast_ref::<T>().is_some()
    }

    /// Iterate the chain from the outermost message to the root cause.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The root cause's message.
    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(c) = &cur.cause {
            cur = c;
        }
        cur
    }
}

pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;
    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.cause.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain on one line, anyhow-style.
            let mut first = true;
            for e in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.cause.is_some() {
            write!(f, "\n\nCaused by:")?;
            for e in self.chain().skip(1) {
                write!(f, "\n    {}", e.msg)?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Flatten the std source chain into our chain so `{:#}` and
        // Debug keep the full story.
        let mut stack = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            stack.push(s.to_string());
            src = s.source();
        }
        let mut err = Error::msg(stack.pop().unwrap());
        while let Some(msg) = stack.pop() {
            err = err.context(msg);
        }
        // The outermost node keeps the concrete value for downcasting.
        err.payload = Some(Box::new(e));
        err
    }
}

/// Context-attachment extension for `Result` and `Option`, mirroring
/// `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<()> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        let e = anyhow!("plain {}", "fmt");
        assert_eq!(e.to_string(), "plain fmt");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn root_cause_walks_chain() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(e.root_cause().to_string(), "root");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn downcast_ref_finds_payload_through_context() {
        let e = Error::new(io_err());
        let kind = e.downcast_ref::<std::io::Error>().unwrap().kind();
        assert_eq!(kind, std::io::ErrorKind::NotFound);
        let wrapped = e.context("loading model").context("serving request");
        assert!(wrapped.is::<std::io::Error>());
        assert_eq!(
            wrapped.downcast_ref::<std::io::Error>().unwrap().to_string(),
            "file missing"
        );
        assert!(!wrapped.is::<std::fmt::Error>());
    }

    #[test]
    fn question_mark_preserves_payload() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff]).context("decoding")?;
            Ok(s)
        }
        let e = f().unwrap_err();
        assert!(e.is::<std::string::FromUtf8Error>());
        assert!(Error::msg("plain").downcast_ref::<std::io::Error>().is_none());
    }
}
