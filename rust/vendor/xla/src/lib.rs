//! Compile-time stub of the PJRT/XLA binding (`xla-rs` API surface).
//!
//! The real binding needs a PJRT plugin and compiled XLA artifacts,
//! neither of which exists in the offline build image. This stub keeps
//! the whole coordinator compiling and unit-testable: host-side buffer
//! bookkeeping works, while anything that would actually compile or
//! execute HLO returns [`Error::Unavailable`]. Every integration test
//! and bench that needs real execution is gated on `artifacts/` being
//! present and skips cleanly when it is not.

use std::fmt;

#[derive(Debug, Clone)]
pub enum Error {
    /// The stub cannot perform real XLA work.
    Unavailable(&'static str),
    /// Malformed host-side request (wrong element size, bad dims).
    InvalidArgument(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "XLA runtime unavailable in this build (stub backend): {what}"
            ),
            Error::InvalidArgument(msg) => write!(f, "invalid argument: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-transferable element types (the subset the coordinator uses).
pub trait NativeType: Copy {
    const SIZE: usize;
    fn to_le(&self, out: &mut Vec<u8>);
    fn from_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const SIZE: usize = 4;
    fn to_le(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn from_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for i32 {
    const SIZE: usize = 4;
    fn to_le(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn from_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

/// A device handle. The stub exposes a single fake host device.
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice;

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// The stub "CPU client" always constructs: sessions can be built,
    /// buffers uploaded, and manifests inspected without a real PJRT.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    /// Real compilation is impossible without XLA.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("HLO compilation"))
    }

    /// Host-side buffer bookkeeping: stores the bytes so uploads are
    /// observable (and cheap) even without a device.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        let numel: usize = dims.iter().product::<usize>().max(1);
        if !dims.is_empty() && numel != data.len() {
            return Err(Error::InvalidArgument(format!(
                "dims {dims:?} ({numel} elems) vs {} host elems",
                data.len()
            )));
        }
        let mut bytes = Vec::with_capacity(data.len() * T::SIZE);
        for v in data {
            v.to_le(&mut bytes);
        }
        Ok(PjRtBuffer { bytes, dims: dims.to_vec() })
    }
}

/// Parsed HLO module (stub: parsing requires XLA).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HLO text parsing"))
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable (stub: can never be constructed via compile,
/// but the type must exist for session plumbing).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("executable execution"))
    }
}

/// A device buffer (stub: host bytes + dims).
pub struct PjRtBuffer {
    bytes: Vec<u8>,
    dims: Vec<usize>,
}

impl PjRtBuffer {
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn byte_len(&self) -> usize {
        self.bytes.len()
    }

    /// Download back to host. The stub round-trips its stored bytes.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal { bytes: self.bytes.clone(), parts: None })
    }
}

/// A host literal; may be a tuple of sub-literals.
pub struct Literal {
    bytes: Vec<u8>,
    parts: Option<Vec<Literal>>,
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.parts {
            Some(p) => Ok(p),
            None => Err(Error::Unavailable("tuple decomposition of non-tuple literal")),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.bytes.len() % T::SIZE != 0 {
            return Err(Error::InvalidArgument(format!(
                "literal of {} bytes is not a whole number of {}-byte elements",
                self.bytes.len(),
                T::SIZE
            )));
        }
        Ok(self.bytes.chunks_exact(T::SIZE).map(T::from_le).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_and_buffers_roundtrip() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        let b = c
            .buffer_from_host_buffer(&[1.0f32, -2.5, 3.25], &[3], None)
            .unwrap();
        assert_eq!(b.dims(), &[3]);
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);
    }

    #[test]
    fn scalar_buffers_allowed() {
        let c = PjRtClient::cpu().unwrap();
        let b = c.buffer_from_host_buffer(&[7i32], &[], None).unwrap();
        assert_eq!(b.byte_len(), 4);
        assert_eq!(b.to_literal_sync().unwrap().to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_buffer(&[1.0f32; 5], &[2, 2], None).is_err());
    }

    #[test]
    fn execution_paths_unavailable() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let c = PjRtClient::cpu().unwrap();
        assert!(c.compile(&XlaComputation::from_proto(&HloModuleProto)).is_err());
        let e = PjRtLoadedExecutable;
        assert!(e.execute_b(&[]).is_err());
    }
}
