//! Static plan verification on the checked-in fixtures (DESIGN.md §8):
//! every `lm_tiny` entry and the threefry pin module must compile to a
//! plan the verifier accepts at *every* `PlanOptions` setting — the
//! same guarantee `QN_PLAN_VERIFY=1` enforces process-wide in CI — and
//! the `qn lint-plan` census must see the fusions the planner reports.

use std::path::Path;

use quant_noise::runtime::interp::{verify, HloModule, Plan, PlanOptions};

fn fixture_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp")
}

fn fixture_module(file: &str) -> HloModule {
    let path = fixture_dir().join(file);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    HloModule::parse_str(&text).unwrap_or_else(|e| panic!("parsing {file}: {e:#}"))
}

const FIXTURES: [&str; 3] =
    ["lm_tiny.grad_mix.hlo.txt", "lm_tiny.eval.hlo.txt", "threefry_pin.hlo.txt"];

const ALL_OPTIONS: [(bool, bool, bool); 8] = [
    (true, true, true),
    (true, true, false),
    (true, false, true),
    (true, false, false),
    (false, true, true),
    (false, true, false),
    (false, false, true),
    (false, false, false),
];

#[test]
fn fixture_plans_verify_clean_at_every_option() {
    for file in FIXTURES {
        let m = fixture_module(file);
        for (counted_loops, threefry, chains) in ALL_OPTIONS {
            let opts = PlanOptions { counted_loops, threefry, chains };
            let plan = Plan::compile_unverified(&m, opts);
            let diags = verify::verify(&plan);
            assert!(
                diags.is_empty(),
                "{file} (counted_loops={counted_loops} threefry={threefry} \
                 chains={chains}):\n{}",
                verify::render(&diags)
            );
        }
    }
}

#[test]
fn verified_compile_path_accepts_fixtures() {
    // Plan::compile panics on a diagnostic in debug builds — compiling
    // each fixture through the production path is itself the assertion
    for file in FIXTURES {
        let _ = Plan::compile(&fixture_module(file));
    }
}

#[test]
fn census_agrees_with_fusion_stats() {
    let m = fixture_module("lm_tiny.grad_mix.hlo.txt");
    let plan = Plan::compile_unverified(&m, PlanOptions::default());
    let fs = plan.fusion_stats();
    let c = verify::census(&plan);
    assert_eq!(c.fusion, fs);
    assert!(c.instrs > 0 && c.comps > 0);
    // the grad entry runs in-graph threefry noise: the census must see
    // the native kernel both as an op label and as a sharding kernel
    assert!(fs.threefry_calls > 0, "{fs:?}");
    assert_eq!(c.op_counts.get("call[threefry2x32]"), Some(&fs.threefry_calls));
    assert_eq!(c.shard_kernels.get("call[threefry2x32]"), Some(&fs.threefry_calls));
    // every sharding kernel the plan uses is a registered one (the
    // clean verify above already implies this; assert it directly)
    for kernel in c.shard_kernels.keys() {
        assert!(
            verify::SHARD_REGISTRY.iter().any(|e| e.name == *kernel),
            "unregistered sharding kernel {kernel}"
        );
    }
    // census renders
    let s = c.to_string();
    assert!(s.contains("instructions by op"), "{s}");
}
