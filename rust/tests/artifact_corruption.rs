//! Corrupt-artifact hardening (DESIGN.md §10): every loader that
//! touches bytes from disk — QNP1 param files, QNC1 checkpoints, HLO
//! text — must answer truncation and bit rot with a *typed error*
//! carrying byte-offset context, never a panic and never a silently
//! half-loaded state. These properties run against the real checked-in
//! fixture artifacts, not synthetic minimal files.

use std::path::{Path, PathBuf};

use quant_noise::coordinator::checkpoint::{self, Checkpoint, OptState};
use quant_noise::model::params::ParamStore;
use quant_noise::model::tensor::Tensor;
use quant_noise::runtime::interp::parser::HloModule;
use quant_noise::util::testing::temp_dir;

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp")
}

// ------------------------------------------------------------ QNP1 ---

#[test]
fn qnp1_fixture_truncations_are_typed_errors() {
    let bytes = std::fs::read(fixture_dir().join("lm_tiny.init.bin")).expect("fixture init");
    assert!(ParamStore::load_qnp1_bytes(&bytes).is_ok(), "fixture must load intact");
    // every boundary in the header region, then a stride through the
    // payload (the unit suite already covers every byte of a small
    // synthetic store; this asserts the same property on a real file)
    let cuts = (0..512.min(bytes.len()))
        .chain((512..bytes.len()).step_by(13))
        .chain(bytes.len().saturating_sub(16)..bytes.len());
    for cut in cuts {
        let err = ParamStore::load_qnp1_bytes(&bytes[..cut])
            .expect_err(&format!("truncation to {cut}/{} bytes accepted", bytes.len()));
        let msg = err.to_string();
        assert!(msg.contains("byte"), "error must carry a byte offset, got: {msg}");
    }
}

#[test]
fn qnp1_bit_flips_never_panic_or_grow_the_store() {
    let bytes = std::fs::read(fixture_dir().join("lm_tiny.init.bin")).expect("fixture init");
    let want = ParamStore::load_qnp1_bytes(&bytes).expect("intact").total_params();
    // QNP1 carries no checksum (uploads add one out of band), so a
    // payload flip may legally load — but it must never panic, and a
    // structural flip must never fabricate parameters
    for i in (0..bytes.len()).step_by(11) {
        for bit in [0x01u8, 0x80] {
            let mut m = bytes.clone();
            m[i] ^= bit;
            if let Ok(store) = ParamStore::load_qnp1_bytes(&m) {
                assert_eq!(
                    store.total_params(),
                    want,
                    "flip at byte {i} changed the parameter count"
                );
            }
        }
    }
}

// ------------------------------------------------------------ QNC1 ---

fn sample_checkpoint() -> Checkpoint {
    let mut params = ParamStore::new();
    params.insert("w0", Tensor::from_vec(&[4, 2], vec![0.5; 8]));
    params.insert("b0", Tensor::from_vec(&[2], vec![-1.0, 1.0]));
    let velocity =
        vec![Tensor::from_vec(&[4, 2], vec![0.25; 8]), Tensor::from_vec(&[2], vec![0.0; 2])];
    Checkpoint {
        model: "lm_tiny".to_string(),
        step: 5,
        batches: 6,
        rng: (0x1111_2222_3333_4444, 0x5555_6666_7777_8889),
        cfg_digest: 0x0123_4567_89ab_cdef,
        params,
        opt: OptState::Sgd { velocity },
        hats: vec![(0, vec![0.5; 8]), (1, vec![0.0; 2])],
    }
}

#[test]
fn qnc1_every_truncation_and_bit_flip_is_detected() {
    let bytes = checkpoint::encode(&sample_checkpoint());
    assert!(checkpoint::decode(&bytes).is_ok());
    for cut in 0..bytes.len() {
        assert!(
            checkpoint::decode(&bytes[..cut]).is_err(),
            "truncation to {cut}/{} bytes accepted",
            bytes.len()
        );
    }
    // the FNV trailer makes *every* single-bit flip detectable — walk
    // all bytes × all 8 bits
    for i in 0..bytes.len() {
        for bit in 0..8u8 {
            let mut m = bytes.clone();
            m[i] ^= 1 << bit;
            assert!(
                checkpoint::decode(&m).is_err(),
                "flip of byte {i} bit {bit} accepted"
            );
        }
    }
}

#[test]
fn qnc1_errors_carry_byte_offsets() {
    let bytes = checkpoint::encode(&sample_checkpoint());
    let err = checkpoint::decode(&bytes[..bytes.len() / 2]).expect_err("truncated");
    assert!(err.to_string().contains("byte"), "offset missing: {err}");
    let mut flipped = bytes.clone();
    flipped[bytes.len() / 2] ^= 0x40;
    let err = checkpoint::decode(&flipped).expect_err("flipped");
    assert!(err.to_string().contains("trailer hash"), "trailer should trip first: {err}");
}

#[test]
fn corrupt_checkpoint_on_disk_is_skipped_not_loaded() {
    let dir = temp_dir("corrupt-ckpt");
    let mut ck = sample_checkpoint();
    checkpoint::save_checkpoint(&dir, &ck).expect("save step 5");
    ck.step = 7;
    ck.batches = 8;
    let path = checkpoint::save_checkpoint(&dir, &ck).expect("save step 7");
    // bit rot in the newest file: loading must fall back to step 5
    let mut bytes = std::fs::read(&path).expect("read newest");
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x08;
    std::fs::write(&path, &bytes).expect("rot");
    let got = checkpoint::load_latest(&dir).expect("load").expect("fallback");
    assert_eq!(got.step, 5);
    std::fs::remove_dir_all(dir).ok();
}

// ------------------------------------------------------- HLO text ---

#[test]
fn hlo_text_truncations_error_instead_of_panicking() {
    let text = std::fs::read_to_string(fixture_dir().join("lm_tiny.eval.hlo.txt"))
        .expect("fixture HLO text");
    assert!(HloModule::parse_str(&text).is_ok(), "fixture must parse intact");
    let lines: Vec<&str> = text.lines().collect();
    for cut in 0..lines.len() {
        let prefix = lines[..cut].join("\n");
        // a prefix that only sheds trailing whitespace is still the
        // whole module; every shorter prefix must be a parse error
        // (the ENTRY computation is last in the dump)
        if prefix.trim_end() == text.trim_end() {
            continue;
        }
        assert!(
            HloModule::parse_str(&prefix).is_err(),
            "prefix of {cut}/{} lines parsed as a complete module",
            lines.len()
        );
    }
}

#[test]
fn hlo_text_byte_garbage_is_an_error() {
    for junk in [
        "",
        "HloModule",
        "HloModule x",
        "HloModule x\nENTRY main {",
        "HloModule x\nENTRY main {\n ROOT r = f32[] parameter(0)",
        "not an hlo module at all",
        "\u{0}\u{0}\u{0}",
    ] {
        assert!(HloModule::parse_str(junk).is_err(), "junk accepted: {junk:?}");
    }
}
