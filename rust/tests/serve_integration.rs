//! `qn serve` integration: real HTTP over localhost against the
//! checked-in interpreter fixture (tests/fixtures/interp).
//!
//! The load-bearing assertions:
//! - eval responses are bit-identical to a direct `ModelSession` run,
//!   at any server thread count, alone or coalesced with strangers
//!   (`selfcheck` additionally asserts it inside the batcher);
//! - the admission queue answers 429 + `Retry-After` past `max_queue`;
//! - an online `/reencode` under concurrent eval traffic never 5xxes
//!   and every response matches either the pre- or post-swap bits.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::time::Duration;

use quant_noise::coordinator::quantize::reencode_params;
use quant_noise::model::params::ParamStore;
use quant_noise::quant::scheme::QuantSpec;
use quant_noise::runtime::client::{Backend, Runtime};
use quant_noise::runtime::executable::{BatchInput, ModelSession};
use quant_noise::runtime::manifest::Manifest;
use quant_noise::serve::{ServeConfig, Server};
use quant_noise::util::json::Json;
use quant_noise::util::rng::Pcg;

fn fixture_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp")
}

fn cfg_interp() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        backend: Some(Backend::Interp), // immune to QN_BACKEND in the env
        selfcheck: true,
        ..ServeConfig::default()
    }
}

/// One-shot HTTP exchange: returns (status, headers, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    // evals can sit behind a macro-batch; be generous
    stream.set_read_timeout(Some(Duration::from_secs(150))).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).expect("send");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("recv");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad response: {raw}"));
    let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
    (status, head.to_string(), body.to_string())
}

fn lm_payload(man: &Manifest) -> (String, Vec<i32>, Vec<i32>) {
    let meta = man.model("lm_tiny").unwrap();
    let n = meta.batch * meta.seq_len;
    let tokens: Vec<i32> = (0..n).map(|i| (i % meta.vocab) as i32).collect();
    let targets: Vec<i32> = (0..n).map(|i| ((i + 1) % meta.vocab) as i32).collect();
    let join = |v: &[i32]| v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
    let body = format!(
        r#"{{"model": "lm_tiny", "tokens": [{}], "targets": [{}]}}"#,
        join(&tokens),
        join(&targets)
    );
    (body, tokens, targets)
}

/// Reference bits from a direct (non-HTTP) session on the same params.
fn direct_bits(man: &Manifest, params: &ParamStore, tokens: &[i32], targets: &[i32]) -> (u64, u64) {
    let rt = Runtime::interp();
    let meta = man.model("lm_tiny").unwrap().clone();
    let mut sess = ModelSession::with_params(&rt, man, &meta, params).unwrap();
    let keep = vec![1.0f32; meta.n_layers];
    let input = BatchInput::Tokens(tokens);
    let (nll, correct) = sess.eval("eval", &input, targets, &keep).unwrap();
    (nll.to_bits(), correct.to_bits())
}

fn response_bits(body: &str) -> (u64, u64) {
    let j = Json::parse(body).unwrap_or_else(|e| panic!("bad body {body}: {e}"));
    let nll = j.get("sum_nll").as_f64().unwrap_or_else(|| panic!("no sum_nll in {body}"));
    let correct = j.get("sum_correct").as_f64().unwrap();
    (nll.to_bits(), correct.to_bits())
}

#[test]
fn eval_bits_match_cli_at_every_thread_count() {
    let man = Manifest::load(&fixture_dir()).unwrap();
    let (body, tokens, targets) = lm_payload(&man);
    let meta = man.model("lm_tiny").unwrap();
    let init = ParamStore::load_qnp1(&man.init_path(meta)).unwrap();
    let want = direct_bits(&man, &init, &tokens, &targets);
    for threads in [1usize, 3, 8] {
        let cfg = ServeConfig { threads, ..cfg_interp() };
        let server = Server::start(&fixture_dir(), cfg).unwrap();
        let (status, _, resp) = http(server.addr(), "POST", "/v1/eval", &body);
        assert_eq!(status, 200, "threads={threads}: {resp}");
        assert_eq!(response_bits(&resp), want, "threads={threads}: {resp}");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("version").as_f64(), Some(1.0));
        server.shutdown();
    }
}

#[test]
fn concurrent_strangers_coalesce_and_keep_their_bits() {
    let man = Manifest::load(&fixture_dir()).unwrap();
    let (body, tokens, targets) = lm_payload(&man);
    let meta = man.model("lm_tiny").unwrap();
    let init = ParamStore::load_qnp1(&man.init_path(meta)).unwrap();
    let want = direct_bits(&man, &init, &tokens, &targets);

    let cfg = ServeConfig {
        threads: 2,
        http_threads: 16,
        max_batch: 8,
        linger: Duration::from_millis(200),
        ..cfg_interp()
    };
    let server = Server::start(&fixture_dir(), cfg).unwrap();
    let addr = server.addr();

    // selfcheck (on) makes the batcher itself assert bit-identity of
    // every coalesced shard vs a solo run; here we assert the
    // client-visible half and that coalescing actually happened
    let mut max_batch = 0.0;
    for round in 0..5 {
        std::thread::scope(|s| {
            let handles: Vec<_> =
                (0..8).map(|_| s.spawn(|| http(addr, "POST", "/v1/eval", &body))).collect();
            for h in handles {
                let (status, _, resp) = h.join().unwrap();
                assert_eq!(status, 200, "round {round}: {resp}");
                assert_eq!(response_bits(&resp), want, "round {round}: {resp}");
            }
        });
        let (status, _, stats) = http(addr, "GET", "/v1/stats", "");
        assert_eq!(status, 200);
        let j = Json::parse(&stats).unwrap();
        max_batch = j.get_path("batching.max_batch").as_f64().unwrap();
        if max_batch > 1.0 {
            break;
        }
    }
    assert!(max_batch > 1.0, "8-way concurrent traffic never coalesced (max_batch 1)");

    let (_, _, stats) = http(addr, "GET", "/v1/stats", "");
    let j = Json::parse(&stats).unwrap();
    assert!(j.get_path("batching.batches").as_f64().unwrap() >= 1.0);
    assert_eq!(j.get_path("queue.depth").as_f64(), Some(0.0));
    assert!(j.get_path("routes.eval.requests").as_f64().unwrap() >= 8.0);
    server.shutdown();
}

#[test]
fn backpressure_answers_429_with_retry_after() {
    let man = Manifest::load(&fixture_dir()).unwrap();
    let (body, _, _) = lm_payload(&man);
    let cfg = ServeConfig {
        threads: 1,
        http_threads: 20,
        max_batch: 1,
        max_queue: 1,
        linger: Duration::ZERO,
        selfcheck: false,
        ..cfg_interp()
    };
    let server = Server::start(&fixture_dir(), cfg).unwrap();
    let addr = server.addr();

    let mut saw_429 = false;
    for _ in 0..10 {
        let results = std::thread::scope(|s| {
            let handles: Vec<_> =
                (0..16).map(|_| s.spawn(|| http(addr, "POST", "/v1/eval", &body))).collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
        });
        for (status, head, resp) in results {
            match status {
                200 => {}
                429 => {
                    assert!(head.contains("Retry-After: 1"), "{head}");
                    assert!(resp.contains("queue full"), "{resp}");
                    saw_429 = true;
                }
                other => panic!("unexpected status {other}: {resp}"),
            }
        }
        if saw_429 {
            break;
        }
    }
    assert!(saw_429, "16-way burst against max_queue=1 never got a 429");
    let (_, _, stats) = http(addr, "GET", "/v1/stats", "");
    let j = Json::parse(&stats).unwrap();
    assert!(j.get("rejected").as_f64().unwrap() >= 1.0);
    server.shutdown();
}

#[test]
fn online_reencode_under_load_is_atomic_and_5xx_free() {
    let man = Manifest::load(&fixture_dir()).unwrap();
    let (body, tokens, targets) = lm_payload(&man);
    let meta = man.model("lm_tiny").unwrap().clone();
    let init = ParamStore::load_qnp1(&man.init_path(&meta)).unwrap();
    let fp_bits = direct_bits(&man, &init, &tokens, &targets);
    // reproduce what the server's /reencode will publish: same spec,
    // same seed, fit on the same pristine fp32 weights
    let spec = QuantSpec::parse("int8").unwrap();
    let q = reencode_params(&init, &meta, &spec, &mut Pcg::new(17)).unwrap();
    let q_bits = direct_bits(&man, &q.store, &tokens, &targets);
    assert_ne!(fp_bits, q_bits, "int8 must change eval bits for this test to bite");

    let cfg = ServeConfig {
        threads: 2,
        http_threads: 16,
        linger: Duration::from_millis(5),
        ..cfg_interp()
    };
    let server = Server::start(&fixture_dir(), cfg).unwrap();
    let addr = server.addr();

    std::thread::scope(|s| {
        let hammers: Vec<_> = (0..4)
            .map(|_| {
                s.spawn(|| {
                    for _ in 0..12 {
                        let (status, _, resp) = http(addr, "POST", "/v1/eval", &body);
                        assert_eq!(status, 200, "eval during reencode: {resp}");
                        let bits = response_bits(&resp);
                        let version = Json::parse(&resp).unwrap().get("version").as_f64().unwrap();
                        // snapshot atomicity: bits always match the
                        // version the response claims, never a blend
                        if version == 1.0 {
                            assert_eq!(bits, fp_bits, "v1 response, non-fp32 bits: {resp}");
                        } else {
                            assert_eq!(version, 2.0, "{resp}");
                            assert_eq!(bits, q_bits, "v2 response, wrong bits: {resp}");
                        }
                    }
                })
            })
            .collect();

        std::thread::sleep(Duration::from_millis(40));
        let (status, _, resp) = http(
            addr,
            "POST",
            "/v1/models/lm_tiny/reencode",
            r#"{"scheme": "int8", "seed": 17}"#,
        );
        assert_eq!(status, 200, "{resp}");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("version").as_f64(), Some(2.0), "{resp}");

        for h in hammers {
            h.join().unwrap();
        }
    });

    // steady state after the swap: everyone sees v2 / quantized bits
    let (status, _, resp) = http(addr, "POST", "/v1/eval", &body);
    assert_eq!(status, 200);
    assert_eq!(response_bits(&resp), q_bits);
    assert_eq!(Json::parse(&resp).unwrap().get("version").as_f64(), Some(2.0));

    let (status, _, info) = http(addr, "GET", "/v1/models/lm_tiny", "");
    assert_eq!(status, 200);
    let j = Json::parse(&info).unwrap();
    assert_eq!(j.get("version").as_f64(), Some(2.0));
    assert!(j.get("scheme").as_str().unwrap().starts_with("int8"), "{info}");
    let bytes = j.get("storage_bytes").as_f64().unwrap();
    let fp_bytes = j.get("fp32_bytes").as_f64().unwrap();
    assert!(bytes < fp_bytes, "int8 must shrink storage: {info}");
    assert!(j.get("sq_error").as_f64().unwrap() > 0.0);
    server.shutdown();
}

#[test]
fn quantize_on_upload_publishes_derived_model() {
    let man = Manifest::load(&fixture_dir()).unwrap();
    let (body, tokens, targets) = lm_payload(&man);
    let meta = man.model("lm_tiny").unwrap().clone();
    let init = ParamStore::load_qnp1(&man.init_path(&meta)).unwrap();
    let spec = QuantSpec::parse("int8").unwrap();
    let q = reencode_params(&init, &meta, &spec, &mut Pcg::new(17)).unwrap();
    let q_bits = direct_bits(&man, &q.store, &tokens, &targets);

    let server = Server::start(&fixture_dir(), cfg_interp()).unwrap();
    let addr = server.addr();

    let req = r#"{"model": "lm_tiny", "scheme": "int8", "id": "lm8", "seed": 17}"#;
    let (status, _, resp) = http(addr, "POST", "/v1/quantize", req);
    assert_eq!(status, 200, "{resp}");
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("id").as_str(), Some("lm8"));
    assert!(j.get("compression").as_f64().unwrap() > 1.0, "{resp}");

    // same id again ⇒ conflict
    let (status, _, resp) = http(addr, "POST", "/v1/quantize", req);
    assert_eq!(status, 409, "{resp}");

    // the derived model evaluates with the locally-reproduced bits
    // while the source keeps serving fp32
    let derived_body = body.replace("\"lm_tiny\"", "\"lm8\"");
    let (status, _, resp) = http(addr, "POST", "/v1/eval", &derived_body);
    assert_eq!(status, 200, "{resp}");
    assert_eq!(response_bits(&resp), q_bits, "{resp}");
    let fp_bits = direct_bits(&man, &init, &tokens, &targets);
    let (_, _, resp) = http(addr, "POST", "/v1/eval", &body);
    assert_eq!(response_bits(&resp), fp_bits);

    let (status, _, listing) = http(addr, "GET", "/v1/models", "");
    assert_eq!(status, 200);
    let j = Json::parse(&listing).unwrap();
    let models = j.get("models").as_arr().unwrap();
    assert_eq!(models.len(), 2, "{listing}");
    assert!(j.get_path("plan_cache.hits").as_f64().is_some(), "{listing}");
    server.shutdown();
}

#[test]
fn pjrt_stub_backend_degrades_to_503_not_panic() {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        backend: Some(Backend::Pjrt),
        ..ServeConfig::default()
    };
    let server = Server::start(&fixture_dir(), cfg).unwrap();
    let addr = server.addr();
    let man = Manifest::load(&fixture_dir()).unwrap();
    let (body, _, _) = lm_payload(&man);
    for _ in 0..2 {
        let (status, _, resp) = http(addr, "POST", "/v1/eval", &body);
        assert_eq!(status, 503, "{resp}");
        assert!(resp.contains("unavailable"), "{resp}");
    }
    // the control plane stays healthy while the data plane declines
    let (status, _, _) = http(addr, "GET", "/v1/stats", "");
    assert_eq!(status, 200);
    let (status, _, _) = http(addr, "GET", "/v1/models", "");
    assert_eq!(status, 200);
    server.shutdown();
}

#[test]
fn protocol_errors_are_typed_not_fatal() {
    let server = Server::start(&fixture_dir(), cfg_interp()).unwrap();
    let addr = server.addr();
    let (status, _, _) = http(addr, "GET", "/nope", "");
    assert_eq!(status, 404);
    let (status, _, _) = http(addr, "GET", "/v1/eval", "");
    assert_eq!(status, 405);
    let (status, _, resp) = http(addr, "POST", "/v1/eval", "{not json");
    assert_eq!(status, 400, "{resp}");
    let (status, _, resp) = http(addr, "POST", "/v1/eval", r#"{"model": "ghost"}"#);
    assert_eq!(status, 404, "{resp}");
    let (status, _, resp) =
        http(addr, "POST", "/v1/quantize", r#"{"model": "lm_tiny", "scheme": "zap"}"#);
    assert_eq!(status, 400, "{resp}");
    let (status, _, resp) = http(
        addr,
        "POST",
        "/v1/eval",
        r#"{"model": "lm_tiny", "tokens": [1], "targets": [2]}"#,
    );
    assert_eq!(status, 400, "wrong token count must 400: {resp}");
    // fp32 model + bodyless reencode: nothing to refresh
    let (status, _, resp) = http(addr, "POST", "/v1/models/lm_tiny/reencode", "");
    assert_eq!(status, 400, "{resp}");
    // the server survives all of the above
    let man = Manifest::load(&fixture_dir()).unwrap();
    let (body, _, _) = lm_payload(&man);
    let (status, _, _) = http(addr, "POST", "/v1/eval", &body);
    assert_eq!(status, 200);
    server.shutdown();
}

#[test]
fn run_until_drains_and_returns_when_stop_flag_raised() {
    use std::sync::atomic::{AtomicBool, Ordering};
    // `qn serve` wires its SIGINT/SIGTERM handler to exactly this flag;
    // flipping it here stands in for delivering the signal.
    let stop = AtomicBool::new(false);
    let cfg = ServeConfig { addr: "127.0.0.1:0".into(), ..cfg_interp() };
    std::thread::scope(|s| {
        let h = s.spawn(|| quant_noise::serve::run_until(&fixture_dir(), cfg, &stop));
        std::thread::sleep(Duration::from_millis(300));
        assert!(!h.is_finished(), "run_until must serve until the flag is raised");
        stop.store(true, Ordering::Relaxed);
        h.join().expect("serve thread").expect("graceful shutdown");
    });
}
