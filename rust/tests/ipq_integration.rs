//! iPQ pipeline integration on the checked-in interpreter fixture:
//! quantization + Eq. (4) finetuning improves on one-shot PQ. Executes
//! real grad/eval entries through the pure-Rust HLO interpreter — no
//! artifacts, no skips (DESIGN.md §4).
//!
//! K is chosen so PQ is genuinely lossy on the tiny fixture (K=8 vs 16
//! subvectors in the smallest matrices) — at larger K the tiny model
//! quantizes losslessly and the comparison would be vacuous.

use std::path::Path;

use quant_noise::bench_harness::specs::{base_ipq, base_train, with_noise};
use quant_noise::coordinator::evaluator::{evaluate, lm_eval_batches};
use quant_noise::coordinator::ipq::{post_pq, run_ipq};
use quant_noise::coordinator::quantize::quantize_params;
use quant_noise::coordinator::trainer::{LmSource, Trainer};
use quant_noise::data::batcher::LmBatcher;
use quant_noise::data::corpus::MarkovCorpus;
use quant_noise::quant::scheme::QuantSpec;
use quant_noise::runtime::client::Runtime;
use quant_noise::runtime::executable::{BatchInput, ModelSession};
use quant_noise::runtime::manifest::Manifest;
use quant_noise::util::rng::Pcg;

#[test]
fn ipq_finetune_beats_oneshot_pq() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp");
    let man = Manifest::load(&dir).expect("checked-in interp fixture must load");
    let rt = Runtime::interp();
    let (mut sess, init) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let meta = sess.meta.clone();
    let corpus = MarkovCorpus::generate(meta.vocab, 120_000, 21);
    let split = corpus.tokens.len() * 9 / 10;
    let mut src = LmSource {
        batcher: LmBatcher::new(&corpus.tokens[..split], meta.batch, meta.seq_len),
    };
    let evalb = lm_eval_batches(&corpus.tokens[split..], meta.batch, meta.seq_len, 6);
    let keep = vec![1.0f32; meta.n_layers];

    // quick training so quantization has something to lose
    let mut tcfg = with_noise(base_train("lm", 60), QuantSpec::Proxy, 0.1);
    tcfg.log_every = 1000;
    let mut tr = Trainer::new(&mut sess, init, tcfg);
    tr.train(&mut src).unwrap();
    let trained = tr.into_params();

    // one-shot PQ
    let mut cfg = base_ipq(10);
    cfg.k = 8;
    let oneshot = post_pq(&trained, &meta, &cfg).unwrap();
    sess.upload_all_params(&oneshot.store).unwrap();
    let ev_one = evaluate(&mut sess, "eval", &evalb, &keep).unwrap();

    // iPQ with Eq. 4 finetuning
    sess.upload_all_params(&trained).unwrap();
    sess.zero_hats().unwrap();
    let (ipq, report) = run_ipq(&mut sess, &trained, &mut src, &cfg).unwrap();
    sess.upload_all_params(&ipq.store).unwrap();
    let ev_ipq = evaluate(&mut sess, "eval", &evalb, &keep).unwrap();

    // quantization must actually cost something at this K
    assert!(ipq.sq_error > 0.0, "K=8 PQ should be lossy on the fixture");
    // same storage, finetuned should not be (much) worse
    assert_eq!(oneshot.bytes, ipq.bytes);
    assert!(
        ev_ipq.nll <= ev_one.nll * 1.02,
        "iPQ {:.4} should beat/match one-shot {:.4}",
        ev_ipq.nll,
        ev_one.nll
    );
    assert_eq!(report.group_losses.len(), 3); // ffn, emb, attn groups

    // fp32 eval must be better than both (quantization costs something)
    sess.upload_all_params(&trained).unwrap();
    let ev_fp = evaluate(&mut sess, "eval", &evalb, &keep).unwrap();
    assert!(ev_fp.nll <= ev_ipq.nll + 1e-6);
}

#[test]
fn img_conv_block_pq_quantizes_and_evals_on_fixture() {
    // Fig. 6b at fixture scale: whole-filter blocks (d=9) on the
    // spatial conv families via the `conv` alias, with the pointwise
    // 1×1s pinned back to d=4 by the more-specific structure override
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp");
    let man = Manifest::load(&dir).expect("checked-in interp fixture must load");
    let rt = Runtime::interp();
    let (mut sess, init) = ModelSession::new(&rt, &man, "img_tiny").unwrap();
    let meta = sess.meta.clone();
    let spec = QuantSpec::parse("pq:k=8,block.conv=9,block.conv1x1=4").unwrap();
    let q = quantize_params(&init, &meta, &spec, &mut Pcg::new(11)).unwrap();
    assert!(q.sq_error > 0.0, "K=8 PQ should be lossy on the conv weights");
    assert_eq!(q.pq.get("stem").unwrap().block_size(), 9);
    assert_eq!(q.pq.get("block00.dw").unwrap().block_size(), 9);
    assert_eq!(q.pq.get("block00.expand").unwrap().block_size(), 4);
    let fp32: u64 = meta
        .params
        .iter()
        .map(|p| 4 * p.shape.iter().product::<usize>() as u64)
        .sum();
    assert!(q.bytes < fp32, "{} bytes should compress fp32 {fp32}", q.bytes);

    // the quantized weights still run the conv graph end-to-end
    sess.upload_all_params(&q.store).unwrap();
    let n_px: usize = meta.tokens_shape.iter().product();
    let images: Vec<f32> = (0..n_px).map(|i| (i % 256) as f32 / 255.0).collect();
    let labels: Vec<i32> =
        (0..meta.batch).map(|i| (i % meta.n_classes) as i32).collect();
    let keep = vec![1.0f32; meta.n_layers];
    let (sum_nll, correct) = sess
        .eval("eval", &BatchInput::Images(&images), &labels, &keep)
        .unwrap();
    assert!(sum_nll.is_finite() && sum_nll > 0.0, "{sum_nll}");
    assert!(correct <= meta.batch as f64);
}
