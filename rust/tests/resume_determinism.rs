//! Bit-exact checkpoint/resume (DESIGN.md §10): a run killed at step k
//! and resumed from its checkpoint must finish with *byte-identical*
//! parameters and final loss to the uninterrupted run, at any thread
//! count. Two layers:
//!
//! 1. an in-process kill matrix — schemes × threads × kill steps —
//!    driving `Trainer::train_for` / `to_checkpoint` / `resume_from`
//!    through the real QNC1 disk roundtrip, and
//! 2. a true subprocess kill via `QN_FAULT=train.step=kill@N` (exit
//!    137, no destructors) followed by `qn train --resume`, comparing
//!    the saved QNP1 files byte for byte.
//!
//! Scheme coverage: pq (hats + per-refresh RNG draws), mean_sub (hats,
//! no refresh RNG), proxy (no hats). intN is excluded on purpose: the
//! checked-in lm_tiny fixture ships only the `eval` and `grad_mix`
//! entries, and intN noise needs its own grad kernels (`int8_tensor`
//! etc.) that the fixture does not carry.

use std::path::{Path, PathBuf};
use std::process::Command;

use quant_noise::bench_harness::specs::{base_train, with_noise};
use quant_noise::coordinator::checkpoint::{load_latest, save_checkpoint};
use quant_noise::coordinator::trainer::{LmSource, TrainConfig, Trainer};
use quant_noise::data::batcher::LmBatcher;
use quant_noise::data::corpus::MarkovCorpus;
use quant_noise::model::params::ParamStore;
use quant_noise::quant::scheme::QuantSpec;
use quant_noise::runtime::client::Runtime;
use quant_noise::runtime::executable::ModelSession;
use quant_noise::runtime::manifest::Manifest;
use quant_noise::util::testing::temp_dir;

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp")
}

fn fixture() -> (Runtime, Manifest) {
    let man = Manifest::load(&fixture_dir()).expect("checked-in interp fixture must load");
    (Runtime::interp(), man)
}

fn lm_source(meta: &quant_noise::model::config::ModelMeta) -> LmSource {
    let corpus = MarkovCorpus::generate(meta.vocab, 60_000, 11);
    LmSource { batcher: LmBatcher::new(&corpus.tokens, meta.batch, meta.seq_len) }
}

/// 9 steps with hat_refresh 4 so the kill points {1, 3, 7} land before
/// the first refresh, just before one, and well past one — the cases
/// where un-checkpointed hats or RNG state would diverge.
fn cfg_for(scheme: QuantSpec, rate: f32, threads: usize) -> TrainConfig {
    let mut cfg = with_noise(base_train("lm", 9), scheme, rate);
    cfg.hat_refresh = 4;
    cfg.threads = threads;
    cfg.log_every = 1000;
    cfg
}

fn run_uninterrupted(cfg: &TrainConfig) -> (ParamStore, f32) {
    let (rt, man) = fixture();
    let (mut sess, params) = ModelSession::new(&rt, &man, "lm_tiny").expect("session");
    let mut src = lm_source(&sess.meta.clone());
    let mut tr = Trainer::new(&mut sess, params, cfg.clone());
    let stats = tr.train(&mut src).expect("uninterrupted train");
    (tr.into_params(), stats.final_loss)
}

/// Simulate a crash at `kill_at` completed steps: train that far,
/// checkpoint to disk, drop every live object (session, trainer, data
/// source), then rebuild the world from scratch and resume.
fn run_killed_and_resumed(cfg: &TrainConfig, kill_at: usize) -> (ParamStore, f32) {
    let dir = temp_dir("resume");
    {
        let (rt, man) = fixture();
        let (mut sess, params) = ModelSession::new(&rt, &man, "lm_tiny").expect("session");
        let mut src = lm_source(&sess.meta.clone());
        let mut tr = Trainer::new(&mut sess, params, cfg.clone());
        tr.train_for(&mut src, kill_at).expect("pre-kill train");
        assert_eq!(tr.completed_steps(), kill_at);
        save_checkpoint(&dir, &tr.to_checkpoint()).expect("save");
    } // <- the "crash": all trainer/session/batcher state is gone
    let (rt, man) = fixture();
    let (mut sess, params) = ModelSession::new(&rt, &man, "lm_tiny").expect("session");
    let mut src = lm_source(&sess.meta.clone());
    let mut tr = Trainer::new(&mut sess, params, cfg.clone());
    let ck = load_latest(&dir).expect("load").expect("checkpoint exists");
    assert_eq!(ck.step, kill_at);
    tr.resume_from(ck).expect("resume");
    let stats = tr.train(&mut src).expect("resumed train");
    std::fs::remove_dir_all(dir).ok();
    (tr.into_params(), stats.final_loss)
}

fn assert_bits_equal(tag: &str, got: &(ParamStore, f32), want: &(ParamStore, f32)) {
    assert_eq!(
        got.1.to_bits(),
        want.1.to_bits(),
        "{tag}: final loss diverged ({} vs {})",
        got.1,
        want.1
    );
    assert_eq!(got.0.names(), want.0.names(), "{tag}: param set diverged");
    for name in want.0.names() {
        assert_eq!(got.0.get(name), want.0.get(name), "{tag}: param '{name}' diverged");
    }
}

/// The headline matrix: kill ∈ {1,3,7} × threads ∈ {1,3,8} × schemes.
/// The reference for each scheme is computed once at threads=1, so the
/// comparison simultaneously asserts resume-exactness *and* the
/// thread-invariance contract the checkpoint digest relies on.
#[test]
fn resume_matrix_is_bit_identical() {
    let schemes: [(&str, QuantSpec, f32); 3] = [
        ("pq", QuantSpec::pq_noise(8), 0.3),
        ("mean_sub", QuantSpec::MeanSub, 0.3),
        ("proxy", QuantSpec::Proxy, 0.2),
    ];
    for (name, scheme, rate) in schemes {
        let reference = run_uninterrupted(&cfg_for(scheme.clone(), rate, 1));
        for threads in [1usize, 3, 8] {
            for kill_at in [1usize, 3, 7] {
                let cfg = cfg_for(scheme.clone(), rate, threads);
                let got = run_killed_and_resumed(&cfg, kill_at);
                let tag = format!("{name} threads={threads} kill@{kill_at}");
                assert_bits_equal(&tag, &got, &reference);
            }
        }
    }
}

/// Resume must refuse a checkpoint taken under a bit-affecting config
/// change (here: a different seed), instead of silently diverging.
#[test]
fn resume_rejects_mismatched_config() {
    let dir = temp_dir("resume-mismatch");
    let cfg = cfg_for(QuantSpec::Proxy, 0.2, 1);
    {
        let (rt, man) = fixture();
        let (mut sess, params) = ModelSession::new(&rt, &man, "lm_tiny").expect("session");
        let mut src = lm_source(&sess.meta.clone());
        let mut tr = Trainer::new(&mut sess, params, cfg.clone());
        tr.train_for(&mut src, 2).expect("train");
        save_checkpoint(&dir, &tr.to_checkpoint()).expect("save");
    }
    let (rt, man) = fixture();
    let (mut sess, params) = ModelSession::new(&rt, &man, "lm_tiny").expect("session");
    let mut changed = cfg.clone();
    changed.seed += 1;
    let mut tr = Trainer::new(&mut sess, params, changed);
    let ck = load_latest(&dir).expect("load").expect("checkpoint exists");
    let err = tr.resume_from(ck).expect_err("mismatched config must be refused");
    assert!(err.to_string().contains("config"), "unexpected error: {err}");
    std::fs::remove_dir_all(dir).ok();
}

// ------------------------------------------------ subprocess kill ---

fn qn(dir_envs: &[(&str, &str)], args: &[&str]) -> std::process::Output {
    let mut c = Command::new(env!("CARGO_BIN_EXE_qn"));
    c.args(args);
    // never inherit a fault plan or backend override from the harness
    c.env_remove("QN_FAULT");
    c.env("QN_BACKEND", "interp");
    for (k, v) in dir_envs {
        c.env(k, v);
    }
    c.output().expect("spawn qn")
}

fn assert_ok(out: &std::process::Output, what: &str) {
    assert!(
        out.status.success(),
        "{what} failed (status {:?}):\n{}",
        out.status.code(),
        String::from_utf8_lossy(&out.stderr)
    );
}

/// The real thing: `qn train` is SIGKILL-alike'd (exit 137, no
/// unwinding) after step 4 by the fault layer, then resumed from the
/// checkpoint directory. The resumed run's saved QNP1 bytes must equal
/// the uninterrupted run's exactly.
#[test]
fn subprocess_kill_and_resume_is_byte_identical() {
    let base = temp_dir("killsub");
    let fixture = fixture_dir();
    let art = fixture.to_str().expect("utf8 path");
    let p = |s: &str| base.join(s).to_string_lossy().into_owned();
    let (cache_a, cache_b) = (p("cache-a"), p("cache-b"));
    let (ckpt_a, ckpt_b) = (p("ckpt-a"), p("ckpt-b"));
    let (save_a, save_b) = (p("base.qnp1"), p("resumed.qnp1"));

    let train_args = |cache: &str| -> Vec<String> {
        [
            "train", "--artifacts", art, "--cache", cache, "--model", "lm_tiny",
            "--scheme", "proxy", "--rate", "0.2", "--steps", "8",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect()
    };

    // uninterrupted baseline — give it a checkpoint dir too (with
    // periodic saves off) so both runs drive the same direct-Trainer
    // code path in `qn train`
    let mut args = train_args(&cache_a);
    args.extend([
        "--checkpoint".into(),
        ckpt_a.clone(),
        "--checkpoint-every".into(),
        "0".into(),
        "--save".into(),
        save_a.clone(),
    ]);
    let argv: Vec<&str> = args.iter().map(String::as_str).collect();
    assert_ok(&qn(&[], &argv), "baseline train");

    // killed run: checkpoints every 2 steps, killed right after step 4
    // (the `train.step` point is hit once per completed step)
    let mut args = train_args(&cache_b);
    args.extend([
        "--checkpoint".into(),
        ckpt_b.clone(),
        "--checkpoint-every".into(),
        "2".into(),
        "--save".into(),
        save_b.clone(),
    ]);
    let argv: Vec<&str> = args.iter().map(String::as_str).collect();
    let out = qn(&[("QN_FAULT", "train.step=kill@4")], &argv);
    assert_eq!(
        out.status.code(),
        Some(137),
        "killed run must exit 137:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(!Path::new(&save_b).exists(), "killed run must not reach --save");
    assert!(
        Path::new(&ckpt_b).join("LATEST").exists(),
        "killed run must leave a checkpoint behind"
    );

    // resume from the checkpoint directory and finish
    let mut args = train_args(&cache_b);
    args.extend(["--resume".into(), ckpt_b.clone(), "--save".into(), save_b.clone()]);
    let argv: Vec<&str> = args.iter().map(String::as_str).collect();
    assert_ok(&qn(&[], &argv), "resumed train");

    let a = std::fs::read(&save_a).expect("baseline QNP1");
    let b = std::fs::read(&save_b).expect("resumed QNP1");
    assert_eq!(a, b, "resumed QNP1 bytes differ from the uninterrupted run");
    std::fs::remove_dir_all(base).ok();
}
