//! Vision-workload golden and property tests (DESIGN.md §4): the
//! interpreter's convolution / reverse / reduce-window kernels must be
//! bit-identical across the tree-walking oracle, the fusion-disabled
//! plan and the fused plan on the checked-in `img_tiny` fixture across
//! threads {1, 3, 8} at two (rate, seed) points; the reduce-window
//! heads are pinned to mirror-computed constants
//! (`tools/qnsim/plan_mirror.py check_window_pin`); and window-geometry
//! corner cases (asymmetric padding, stride > window, dilations, 1×1,
//! degenerate and all-padding windows) are checked against a naive
//! quadruple-loop reference implemented in this file.

use std::path::Path;

use quant_noise::model::params::ParamStore;
use quant_noise::runtime::interp::{
    ArrayValue, Buf, FusionStats, HloModule, Interp, Plan, PlanOptions, Value,
};
use quant_noise::runtime::manifest::Manifest;

fn fixture_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp")
}

fn f32v(dims: &[usize], data: Vec<f32>) -> Value {
    Value::Array(ArrayValue::new(dims.to_vec(), Buf::F32(data)).unwrap())
}

fn i32v(dims: &[usize], data: Vec<i32>) -> Value {
    Value::Array(ArrayValue::new(dims.to_vec(), Buf::S32(data)).unwrap())
}

/// Exact structural + bitwise equality (f32 compared by bit pattern).
fn assert_bit_identical(a: &Value, b: &Value, path: &str) {
    match (a, b) {
        (Value::Tuple(xs), Value::Tuple(ys)) => {
            assert_eq!(xs.len(), ys.len(), "{path}: tuple arity");
            for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
                assert_bit_identical(x, y, &format!("{path}.{i}"));
            }
        }
        (Value::Array(x), Value::Array(y)) => {
            assert_eq!(x.dims, y.dims, "{path}: dims");
            match (&*x.buf, &*y.buf) {
                (Buf::F32(p), Buf::F32(q)) => {
                    for (i, (u, v)) in p.iter().zip(q).enumerate() {
                        assert_eq!(u.to_bits(), v.to_bits(), "{path}[{i}]");
                    }
                }
                (p, q) => assert_eq!(p, q, "{path}: buffer"),
            }
        }
        _ => panic!("{path}: array/tuple kind mismatch"),
    }
}

/// Oracle vs fused plan vs fusion-disabled plan on one module, across
/// thread counts — the vision byte-stability contract pre/post fusion.
fn assert_fused_matches(m: &HloModule, args: &[Value], label: &str) -> FusionStats {
    let golden = Interp::new(m).run_entry(args).unwrap();
    let fused = Plan::compile(m);
    let nofuse =
        Plan::compile_opts(m, PlanOptions { counted_loops: false, threefry: false, chains: false });
    for threads in [1usize, 3, 8] {
        let got = fused.run_entry(args.to_vec(), threads).unwrap();
        assert_bit_identical(&got, &golden, &format!("{label}[fused,t={threads}]"));
        let got = nofuse.run_entry(args.to_vec(), threads).unwrap();
        assert_bit_identical(&got, &golden, &format!("{label}[nofuse,t={threads}]"));
    }
    fused.fusion_stats()
}

/// Fixture entry + args, mirroring `tools/qnsim/plan_mirror.py
/// fixture_args`: deterministic images `(i % 256) / 255`, labels
/// `i % n_classes`, full layer-keep, zero hats for grad entries.
fn load_img(entry: &str, rate_seed: Option<(f32, i32)>) -> (HloModule, Vec<Value>) {
    let dir = fixture_dir();
    let man = Manifest::load(&dir).expect("checked-in interp fixture must load");
    let meta = man.model("img_tiny").unwrap().clone();
    let params = ParamStore::load_qnp1(&man.init_path(&meta)).unwrap();
    let n_px: usize = meta.tokens_shape.iter().product();
    let images: Vec<f32> = (0..n_px).map(|i| (i % 256) as f32 / 255.0).collect();
    let labels: Vec<i32> =
        (0..meta.batch).map(|i| (i % meta.n_classes) as i32).collect();
    let keep = vec![1.0f32; meta.n_layers];
    let mut args: Vec<Value> =
        params.iter().map(|(_, t)| f32v(&t.shape, t.data.clone())).collect();
    if rate_seed.is_some() {
        args.extend(
            params.iter().map(|(_, t)| f32v(&t.shape, vec![0.0; t.data.len()])),
        );
    }
    args.push(f32v(&meta.tokens_shape, images));
    args.push(i32v(&meta.targets_shape, labels));
    args.push(f32v(&[keep.len()], keep));
    if let Some((rate, seed)) = rate_seed {
        args.push(f32v(&[], vec![rate]));
        args.push(i32v(&[], vec![seed]));
    }
    let m = HloModule::parse_file(&man.hlo_path(&meta, entry).unwrap()).unwrap();
    (m, args)
}

// ------------------------------------------------- img fixture golden ---

#[test]
fn img_grad_fused_bit_identical_across_threads() {
    // rate 0.5 drives the in-graph threefry noise masks through the
    // conv forward AND both conv grad forms (input grad: reversed
    // kernels + lhs_dilate; weight grad: batch_group_count)
    let (m, args) = load_img("grad_mix", Some((0.5, 42)));
    let fs = assert_fused_matches(&m, &args, "img.grad_mix@0.5,42");
    assert_eq!(fs.generic_whiles, 0, "fallback storm: {fs:?}");
    assert!(fs.counted_loops >= 1 && fs.threefry_calls >= 1, "{fs:?}");
    // relu/mask/noise cones chain in the conv graph too
    assert!(fs.fused_chains > 0 && fs.chain_steps >= fs.fused_chains, "{fs:?}");
}

#[test]
fn img_grad_second_rate_seed_still_matches() {
    let (m, args) = load_img("grad_mix", Some((0.9, 7)));
    assert_fused_matches(&m, &args, "img.grad_mix@0.9,7");
}

#[test]
fn img_eval_fused_bit_identical_across_threads() {
    let (m, args) = load_img("eval", None);
    assert_fused_matches(&m, &args, "img.eval");
}

// --------------------------------------------------------- window pin ---

/// Self-contained reduce-window pools covering geometry the img model
/// doesn't reach (it pools via plain `reduce`); heads below are the
/// mirror-computed constants, exact in f32.
const WINDOW_PIN: &str = include_str!("fixtures/interp/window_pin.hlo.txt");

#[test]
fn window_pin_exact_heads() {
    let m = HloModule::parse_str(WINDOW_PIN).unwrap();
    let data: Vec<f32> =
        (0..60).map(|i| ((i * 37 + 11) % 101) as f32 * 0.25 - 12.0).collect();
    let args = vec![f32v(&[2, 5, 6], data)];
    let fs = assert_fused_matches(&m, &args, "window_pin");
    // max/add/dilated pools fuse; the sumsq region stays generic
    assert_eq!(fs.fused_windows, 3, "{fs:?}");
    let out = Plan::compile(&m).run_entry(args, 3).unwrap();
    let parts = out.tuple().unwrap();
    let mp = parts[0].array().unwrap().as_f32().unwrap();
    let dl = parts[2].array().unwrap().as_f32().unwrap();
    assert_eq!(&mp[..3], &[5.0, 9.25, 11.75], "max-pool head");
    assert_eq!(&dl[..3], &[-5.25, 18.25, -10.5], "dilated-pool head");
}

// ------------------------------------------- window-geometry property ---

/// One spatial window dimension of the naive reference (deliberately
/// its own struct: this file must not lean on the parser's types).
#[derive(Clone, Copy)]
struct Win {
    size: usize,
    stride: usize,
    pad_lo: i64,
    pad_hi: i64,
    lhs_dilate: usize,
    rhs_dilate: usize,
}

const UNIT: Win =
    Win { size: 1, stride: 1, pad_lo: 0, pad_hi: 0, lhs_dilate: 1, rhs_dilate: 1 };

fn out_size(w: &Win, n: usize) -> usize {
    let dilated = if n == 0 { 0 } else { (n as i64 - 1) * w.lhs_dilate as i64 + 1 };
    let window = (w.size as i64 - 1) * w.rhs_dilate as i64 + 1;
    let padded = dilated + w.pad_lo + w.pad_hi;
    if padded < window {
        0
    } else {
        ((padded - window) / w.stride as i64) as usize + 1
    }
}

/// Input position of window coordinate `kc` at output coordinate `oc`,
/// None when it lands in padding or between dilation holes.
fn tap(oc: usize, kc: usize, w: &Win, n: usize) -> Option<usize> {
    let mut pos = oc as i64 * w.stride as i64 + kc as i64 * w.rhs_dilate as i64 - w.pad_lo;
    if pos < 0 {
        return None;
    }
    if w.lhs_dilate > 1 {
        if pos % w.lhs_dilate as i64 != 0 {
            return None;
        }
        pos /= w.lhs_dilate as i64;
    }
    if (pos as usize) < n {
        Some(pos as usize)
    } else {
        None
    }
}

/// Naive quadruple-loop NHWC × HWIO → NHWC convolution with the same
/// accumulation order as the kernel (row-major kernel taps, input
/// channel innermost, one f32 accumulator — so equality is bitwise).
fn naive_conv(
    x: &[f32],
    xd: [usize; 4],
    k: &[f32],
    kd: [usize; 4],
    win: &[Win; 2],
    fg: usize,
) -> (Vec<f32>, Vec<usize>) {
    let [n, h, w_in, cin_total] = xd;
    let [kh, kw, cin, cout] = kd;
    assert_eq!(cin_total, cin * fg, "case is self-inconsistent");
    let (oh, ow) = (out_size(&win[0], h), out_size(&win[1], w_in));
    let per_group = cout / fg;
    let mut out = vec![0.0f32; n * oh * ow * cout];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for oc in 0..cout {
                    let g = oc / per_group;
                    let mut acc = 0.0f32;
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let (Some(iy), Some(ix)) =
                                (tap(oy, ky, &win[0], h), tap(ox, kx, &win[1], w_in))
                            else {
                                continue;
                            };
                            for ic in 0..cin {
                                let xi = ((b * h + iy) * w_in + ix) * cin_total
                                    + (g * cin + ic);
                                let ki = ((ky * kw + kx) * cin + ic) * cout + oc;
                                acc += x[xi] * k[ki];
                            }
                        }
                    }
                    out[((b * oh + oy) * ow + ox) * cout + oc] = acc;
                }
            }
        }
    }
    (out, vec![n, oh, ow, cout])
}

fn conv_text(
    xd: &[usize; 4],
    kd: &[usize; 4],
    od: &[usize],
    win: &[Win; 2],
    fg: usize,
) -> String {
    let dim =
        |d: &[usize]| d.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",");
    let w = format!(
        "size={}x{} stride={}x{} pad={}_{}x{}_{} lhs_dilate={}x{} rhs_dilate={}x{}",
        win[0].size,
        win[1].size,
        win[0].stride,
        win[1].stride,
        win[0].pad_lo,
        win[0].pad_hi,
        win[1].pad_lo,
        win[1].pad_hi,
        win[0].lhs_dilate,
        win[1].lhs_dilate,
        win[0].rhs_dilate,
        win[1].rhs_dilate,
    );
    format!(
        "HloModule convprop\n\nENTRY main.1 {{\n  \
         x.1 = f32[{}]{{3,2,1,0}} parameter(0)\n  \
         k.2 = f32[{}]{{3,2,1,0}} parameter(1)\n  \
         ROOT c.3 = f32[{}]{{3,2,1,0}} convolution(x.1, k.2), window={{{w}}}, \
         dim_labels=b01f_01io->b01f, feature_group_count={fg}\n}}\n",
        dim(xd),
        dim(kd),
        dim(od)
    )
}

fn check_conv_case(label: &str, xd: [usize; 4], kd: [usize; 4], win: [Win; 2], fg: usize) {
    let xn: usize = xd.iter().product();
    let kn: usize = kd.iter().product();
    let x: Vec<f32> =
        (0..xn).map(|i| ((i * 37 + 11) % 101) as f32 * 0.25 - 12.0).collect();
    let k: Vec<f32> =
        (0..kn).map(|i| ((i * 53 + 29) % 97) as f32 * 0.125 - 6.0).collect();
    let (want, od) = naive_conv(&x, xd, &k, kd, &win, fg);
    let text = conv_text(&xd, &kd, &od, &win, fg);
    let m = HloModule::parse_str(&text).unwrap_or_else(|e| panic!("{label}: {e:#}"));
    let args = vec![f32v(&xd, x), f32v(&kd, k)];
    let golden = Interp::new(&m).run_entry(&args).unwrap();
    let got = golden.array().unwrap();
    assert_eq!(got.dims, od, "{label}: dims");
    let got = got.as_f32().unwrap();
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: oracle[{i}] {a} vs naive {b}");
    }
    let plan = Plan::compile(&m);
    for threads in [1usize, 3, 8] {
        let got = plan.run_entry(args.clone(), threads).unwrap();
        assert_bit_identical(&got, &golden, &format!("{label}[t={threads}]"));
    }
}

#[test]
fn conv_asymmetric_padding_matches_naive() {
    let wy = Win { size: 3, pad_lo: 2, ..UNIT };
    let wx = Win { size: 2, stride: 2, pad_hi: 1, ..UNIT };
    check_conv_case("asym-pad", [2, 5, 7, 3], [3, 2, 3, 5], [wy, wx], 1);
}

#[test]
fn conv_stride_larger_than_window_matches_naive() {
    let w = Win { size: 2, stride: 3, ..UNIT };
    check_conv_case("stride>window", [1, 8, 8, 2], [2, 2, 2, 4], [w, w], 1);
}

#[test]
fn conv_window_dilation_matches_naive() {
    let w = Win { size: 3, pad_lo: 2, pad_hi: 2, rhs_dilate: 2, ..UNIT };
    check_conv_case("rhs-dilate", [1, 9, 9, 2], [3, 3, 2, 4], [w, w], 1);
}

#[test]
fn conv_1x1_matches_naive() {
    check_conv_case("1x1", [2, 4, 4, 6], [1, 1, 6, 8], [UNIT, UNIT], 1);
}

#[test]
fn conv_degenerate_spatial_dim_matches_naive() {
    let wx = Win { size: 3, pad_lo: 1, pad_hi: 1, ..UNIT };
    check_conv_case("degenerate-h", [1, 1, 6, 2], [1, 3, 2, 2], [UNIT, wx], 1);
}

#[test]
fn conv_all_padding_windows_match_naive() {
    // pad 3 on a 2-row input: the first and last output rows see only
    // padding and must come out exactly 0.0
    let wy = Win { size: 2, stride: 2, pad_lo: 3, pad_hi: 3, ..UNIT };
    let wx = Win { size: 2, ..UNIT };
    check_conv_case("all-padding", [1, 2, 2, 1], [2, 2, 1, 1], [wy, wx], 1);
}

#[test]
fn conv_base_dilation_matches_naive() {
    // lhs_dilate is the input-gradient transpose-conv form
    let w = Win { size: 2, pad_lo: 1, pad_hi: 1, lhs_dilate: 2, ..UNIT };
    check_conv_case("lhs-dilate", [1, 4, 4, 2], [2, 2, 2, 3], [w, w], 1);
}

#[test]
fn conv_feature_groups_match_naive() {
    let w = Win { size: 3, pad_lo: 1, pad_hi: 1, ..UNIT };
    check_conv_case("feature-groups", [2, 5, 5, 6], [3, 3, 3, 8], [w, w], 2);
}

#[test]
fn reduce_window_all_padding_cells_return_init() {
    let text = "HloModule rwpad\n\nmax.1 {\n  a.1 = f32[] parameter(0)\n  \
        b.2 = f32[] parameter(1)\n  ROOT m.3 = f32[] maximum(a.1, b.2)\n}\n\n\
        ENTRY main.1 {\n  x.1 = f32[3]{0} parameter(0)\n  \
        ni.2 = f32[] constant(-7.5)\n  \
        ROOT r.3 = f32[4]{0} reduce-window(x.1, ni.2), \
        window={size=2 stride=2 pad=4_1}, to_apply=max.1\n}\n";
    let m = HloModule::parse_str(text).unwrap();
    let args = vec![f32v(&[3], vec![1.0, -2.0, 5.5])];
    assert_fused_matches(&m, &args, "rwpad");
    let out = Plan::compile(&m).run_entry(args, 1).unwrap();
    let got = out.array().unwrap().as_f32().unwrap().to_vec();
    // cells 0/1 cover only padding and keep the init value
    assert_eq!(got, vec![-7.5, -7.5, 1.0, 5.5]);
}
