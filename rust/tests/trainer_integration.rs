//! Trainer integration on the checked-in interpreter fixture: loss
//! decreases under training, sharing keeps siblings identical,
//! LayerDrop and exact-PQ noise train. These execute real grad entries
//! through the pure-Rust HLO interpreter — no artifacts, no skips
//! (DESIGN.md §4; the fixture regenerates with `make fixture`).

use std::path::Path;

use quant_noise::bench_harness::specs::{base_train, with_noise};
use quant_noise::coordinator::trainer::{BatchSource, LmSource, Trainer};
use quant_noise::data::batcher::LmBatcher;
use quant_noise::data::corpus::MarkovCorpus;
use quant_noise::quant::scheme::QuantSpec;
use quant_noise::runtime::client::Runtime;
use quant_noise::runtime::executable::ModelSession;
use quant_noise::runtime::manifest::Manifest;

fn fixture() -> (Runtime, Manifest) {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp");
    let man = Manifest::load(&dir).expect("checked-in interp fixture must load");
    (Runtime::interp(), man)
}

fn lm_source(meta: &quant_noise::model::config::ModelMeta) -> LmSource {
    let corpus = MarkovCorpus::generate(meta.vocab, 60_000, 11);
    LmSource { batcher: LmBatcher::new(&corpus.tokens, meta.batch, meta.seq_len) }
}

#[test]
fn loss_decreases_over_training() {
    let (rt, man) = fixture();
    let (mut sess, params) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let mut src = lm_source(&sess.meta.clone());
    let mut cfg = with_noise(base_train("lm", 40), QuantSpec::Proxy, 0.1);
    cfg.log_every = 1000;
    let mut tr = Trainer::new(&mut sess, params, cfg);
    let stats = tr.train(&mut src).unwrap();
    let first = stats.history.first().unwrap().1;
    assert!(
        stats.final_loss < first * 0.8,
        "loss should drop: {first} -> {}",
        stats.final_loss
    );
}

#[test]
fn sharing_keeps_siblings_identical() {
    let (rt, man) = fixture();
    let (mut sess, params) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let mut src = lm_source(&sess.meta.clone());
    let mut cfg = with_noise(base_train("lm", 6), QuantSpec::None, 0.0);
    cfg.share_chunk = 2;
    cfg.log_every = 1000;
    let mut tr = Trainer::new(&mut sess, params, cfg);
    tr.train(&mut src).unwrap();
    let p = tr.into_params();
    // layers 0/1 and 2/3 are shared pairs
    for (a, b) in [("layer00.w1", "layer01.w1"), ("layer02.wq", "layer03.wq")] {
        assert_eq!(p.get(a).unwrap(), p.get(b).unwrap(), "{a} != {b}");
    }
    // canonical layers of different chunks must differ (they trained)
    assert_ne!(p.get("layer00.w1").unwrap(), p.get("layer02.w1").unwrap());
}

#[test]
fn layerdrop_training_runs_and_learns() {
    let (rt, man) = fixture();
    let (mut sess, params) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let mut src = lm_source(&sess.meta.clone());
    let mut cfg = with_noise(base_train("lm", 20), QuantSpec::Proxy, 0.1);
    cfg.layerdrop = 0.5;
    cfg.log_every = 1000;
    let mut tr = Trainer::new(&mut sess, params, cfg);
    let stats = tr.train(&mut src).unwrap();
    assert!(stats.final_loss.is_finite());
}

#[test]
fn exact_pq_noise_trains() {
    let (rt, man) = fixture();
    let (mut sess, params) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let mut src = lm_source(&sess.meta.clone());
    // exact-φ_PQ noise via its spec: K=16 codewords, refresh budget
    let mut cfg = with_noise(base_train("lm", 10), QuantSpec::pq_noise(16), 0.3);
    cfg.hat_refresh = 5;
    cfg.log_every = 1000;
    let mut tr = Trainer::new(&mut sess, params, cfg);
    let stats = tr.train(&mut src).unwrap();
    assert!(stats.final_loss.is_finite());
}

#[test]
fn training_is_deterministic_across_runs() {
    // Same fixture, same seeds ⇒ bit-identical trained parameters. The
    // interpreter is single-threaded and the coordinator's parallelism
    // is thread-count-invariant, so this holds on any machine.
    let run = || {
        let (rt, man) = fixture();
        let (mut sess, params) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
        let mut src = lm_source(&sess.meta.clone());
        let mut cfg = with_noise(base_train("lm", 5), QuantSpec::Proxy, 0.2);
        cfg.log_every = 1000;
        let mut tr = Trainer::new(&mut sess, params, cfg);
        tr.train(&mut src).unwrap();
        tr.into_params()
    };
    let a = run();
    let b = run();
    for name in a.names() {
        assert_eq!(a.get(name).unwrap(), b.get(name).unwrap(), "{name} diverged");
    }
}
