//! `qn serve` robustness under hostile clients and injected faults
//! (DESIGN.md §10): slow-header and mid-body-drop peers, per-model
//! admission quotas, checksum-validated uploads, dropped connections,
//! and a wedged backend that must not hold shutdown hostage.
//!
//! The fault registry is process-global, so every test in this binary
//! — whether it arms faults or not — serializes on one mutex, and
//! armed plans clear through a drop guard.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use quant_noise::coordinator::checkpoint::{self, Checkpoint, OptState};
use quant_noise::model::params::ParamStore;
use quant_noise::model::tensor::Tensor;
use quant_noise::runtime::client::Backend;
use quant_noise::runtime::manifest::Manifest;
use quant_noise::serve::{ServeConfig, Server};
use quant_noise::util::fault;
use quant_noise::util::hash::{fnv1a64, to_hex};
use quant_noise::util::json::Json;

static LOCK: Mutex<()> = Mutex::new(());

fn guard() -> MutexGuard<'static, ()> {
    let g = LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    fault::clear();
    g
}

/// Arm a fault plan for the test's lifetime; clears even on panic.
struct Armed<'a> {
    _guard: MutexGuard<'a, ()>,
}

fn arm(spec: &str) -> Armed<'static> {
    let g = guard();
    fault::install(spec).expect("valid fault spec");
    Armed { _guard: g }
}

impl Drop for Armed<'_> {
    fn drop(&mut self) {
        fault::clear();
    }
}

fn fixture_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp")
}

fn cfg_interp() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        backend: Some(Backend::Interp),
        ..ServeConfig::default()
    }
}

/// One-shot HTTP exchange over raw bytes: returns (status, head, body).
fn http_bytes(addr: SocketAddr, method: &str, path: &str, body: &[u8]) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(150))).expect("timeout");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes()).expect("send head");
    stream.write_all(body).expect("send body");
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("recv");
    let raw = String::from_utf8_lossy(&raw).into_owned();
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad response: {raw}"));
    let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
    (status, head.to_string(), body.to_string())
}

fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String, String) {
    http_bytes(addr, method, path, body.as_bytes())
}

fn lm_eval_body(man: &Manifest) -> String {
    let meta = man.model("lm_tiny").expect("lm_tiny in fixture");
    let n = meta.batch * meta.seq_len;
    let tokens: Vec<String> = (0..n).map(|i| (i % meta.vocab).to_string()).collect();
    let targets: Vec<String> = (0..n).map(|i| ((i + 1) % meta.vocab).to_string()).collect();
    format!(
        r#"{{"model": "lm_tiny", "tokens": [{}], "targets": [{}]}}"#,
        tokens.join(","),
        targets.join(",")
    )
}

fn stat_f64(addr: SocketAddr, path: &str) -> f64 {
    let (status, _, body) = http(addr, "GET", "/v1/stats", "");
    assert_eq!(status, 200, "{body}");
    let j = Json::parse(&body).unwrap_or_else(|e| panic!("bad stats {body}: {e}"));
    j.get_path(path).as_f64().unwrap_or_else(|| panic!("no {path} in {body}"))
}

// -------------------------------------------------- hostile clients ---

#[test]
fn slow_header_client_gets_408_and_is_counted() {
    let _g = guard();
    let cfg = ServeConfig { io_timeout: Duration::from_millis(300), ..cfg_interp() };
    let server = Server::start(&fixture_dir(), cfg).expect("start");
    let addr = server.addr();

    // start a request, then stall past the whole-request deadline — the
    // classic slowloris shape a per-read timeout alone cannot catch
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(b"POST /v1/eval HTTP/1.1\r\nHost: t\r\n").expect("partial head");
    std::thread::sleep(Duration::from_millis(800));
    let mut raw = String::new();
    let _ = stream.read_to_string(&mut raw);
    assert!(raw.starts_with("HTTP/1.1 408"), "want 408, got: {raw:?}");
    assert!(stat_f64(addr, "timeouts") >= 1.0);
    server.shutdown();
}

#[test]
fn idle_keepalive_connection_closes_silently() {
    let _g = guard();
    let cfg = ServeConfig { io_timeout: Duration::from_millis(300), ..cfg_interp() };
    let server = Server::start(&fixture_dir(), cfg).expect("start");
    let addr = server.addr();

    // a connection that never starts a request is idle, not stalled:
    // it must be closed without a 408 (and without a timeout count)
    let mut stream = TcpStream::connect(addr).expect("connect");
    std::thread::sleep(Duration::from_millis(800));
    let mut raw = String::new();
    let _ = stream.read_to_string(&mut raw);
    assert!(raw.is_empty(), "idle expiry must close silently, got: {raw:?}");
    server.shutdown();
}

#[test]
fn mid_body_drop_leaves_the_worker_alive() {
    let _g = guard();
    let server = Server::start(&fixture_dir(), cfg_interp()).expect("start");
    let addr = server.addr();

    // claim a body, send a fragment, vanish
    {
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream
            .write_all(
                b"POST /v1/eval HTTP/1.1\r\nHost: t\r\nContent-Length: 500\r\n\r\n{\"mo",
            )
            .expect("fragment");
    } // <- dropped: connection closed mid-body

    // the worker that hit the truncated read must survive to serve this
    let man = Manifest::load(&fixture_dir()).expect("manifest");
    let (status, _, resp) = http(addr, "POST", "/v1/eval", &lm_eval_body(&man));
    assert_eq!(status, 200, "{resp}");
    server.shutdown();
}

// --------------------------------------------------- injected faults ---

#[test]
fn dropped_accept_does_not_take_down_the_acceptor() {
    let _armed = arm("serve.accept=err@1");
    let server = Server::start(&fixture_dir(), cfg_interp()).expect("start");
    let addr = server.addr();

    // first connection is dropped on the floor by the fault point
    let mut stream = TcpStream::connect(addr).expect("connect");
    let _ = stream.write_all(b"GET /v1/stats HTTP/1.1\r\nHost: t\r\n\r\n");
    let mut raw = String::new();
    let got = stream.read_to_string(&mut raw);
    assert!(
        raw.is_empty() || got.is_err(),
        "faulted connection must see no response, got: {raw:?}"
    );

    // the acceptor itself survives and serves the next peer
    let (status, _, _) = http(addr, "GET", "/v1/stats", "");
    assert_eq!(status, 200);
    server.shutdown();
}

#[test]
fn connection_faults_after_read_and_before_write_are_contained() {
    // hit counts are per-point: connection 1 dies at serve.read before
    // its serve.write check ever runs, so connection 2 is the write
    // point's FIRST hit
    let _armed = arm("serve.read=err@1;serve.write=err@1");
    let server = Server::start(&fixture_dir(), cfg_interp()).expect("start");
    let addr = server.addr();

    // hit 1: connection dies right after the request is read
    let mut stream = TcpStream::connect(addr).expect("connect");
    let _ = stream.write_all(b"GET /v1/stats HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
    let mut raw = String::new();
    let got = stream.read_to_string(&mut raw);
    assert!(raw.is_empty() || got.is_err(), "no response expected, got: {raw:?}");

    // hit 2 of serve.read passes; serve.write's hit 1 then fires —
    // response computed, then dropped before send
    let mut stream = TcpStream::connect(addr).expect("connect");
    let _ = stream.write_all(b"GET /v1/stats HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
    let mut raw = String::new();
    let got = stream.read_to_string(&mut raw);
    assert!(raw.is_empty() || got.is_err(), "no response expected, got: {raw:?}");

    // both workers survive
    let (status, _, _) = http(addr, "GET", "/v1/stats", "");
    assert_eq!(status, 200);
    server.shutdown();
}

#[test]
fn per_model_quota_answers_429_and_is_counted() {
    // wedge each batch briefly so admitted jobs pile up behind the
    // batcher and the quota actually binds
    let _armed = arm("serve.batch=hang:500");
    let man = Manifest::load(&fixture_dir()).expect("manifest");
    let body = lm_eval_body(&man);
    let cfg = ServeConfig {
        max_batch: 1,
        max_per_model: 1,
        http_threads: 8,
        linger: Duration::ZERO,
        ..cfg_interp()
    };
    let server = Server::start(&fixture_dir(), cfg).expect("start");
    let addr = server.addr();

    let mut saw_quota = false;
    for _ in 0..5 {
        let results = std::thread::scope(|s| {
            let handles: Vec<_> =
                (0..4).map(|_| s.spawn(|| http(addr, "POST", "/v1/eval", &body))).collect();
            handles.into_iter().map(|h| h.join().expect("client")).collect::<Vec<_>>()
        });
        for (status, head, resp) in results {
            match status {
                200 => {}
                429 => {
                    assert!(head.contains("Retry-After"), "{head}");
                    if resp.contains("quota") {
                        saw_quota = true;
                    }
                }
                other => panic!("unexpected status {other}: {resp}"),
            }
        }
        if saw_quota {
            break;
        }
    }
    assert!(saw_quota, "4-way burst against max_per_model=1 never hit the quota");
    assert!(stat_f64(addr, "rejected_quota") >= 1.0);
    server.shutdown();
}

#[test]
fn wedged_backend_cannot_hold_shutdown_hostage() {
    // every batch sleeps 10s — far past the 300ms drain budget
    let _armed = arm("serve.batch=hang:10000");
    let man = Manifest::load(&fixture_dir()).expect("manifest");
    let body = lm_eval_body(&man);
    let cfg = ServeConfig {
        drain_timeout: Duration::from_millis(300),
        linger: Duration::ZERO,
        ..cfg_interp()
    };
    let server = Server::start(&fixture_dir(), cfg).expect("start");
    let addr = server.addr();

    std::thread::scope(|s| {
        let stuck = s.spawn(move || http(addr, "POST", "/v1/eval", &body));
        // let the job reach the batcher and wedge
        std::thread::sleep(Duration::from_millis(300));
        // elapsed-time check only — never reaches result bits
        #[allow(clippy::disallowed_methods)]
        let t0 = std::time::Instant::now();
        server.shutdown();
        let took = t0.elapsed();
        assert!(
            took < Duration::from_secs(5),
            "shutdown took {took:?} against a 300ms drain budget"
        );
        // the abandoned handler answers 503, not a hang or a panic
        let (status, _, resp) = stuck.join().expect("stuck client");
        assert_eq!(status, 503, "{resp}");
        assert!(resp.contains("abandon"), "{resp}");
    });
}

// ------------------------------------------------------------ upload ---

fn scaled(store: &ParamStore, f: f32) -> ParamStore {
    let mut out = ParamStore::new();
    for (n, t) in store.iter() {
        out.insert(n, Tensor::from_vec(&t.shape, t.data.iter().map(|x| x * f).collect()));
    }
    out
}

#[test]
fn upload_swaps_weights_and_rejects_corruption() {
    let _g = guard();
    let man = Manifest::load(&fixture_dir()).expect("manifest");
    let meta = man.model("lm_tiny").expect("meta");
    let init = ParamStore::load_qnp1(&man.init_path(meta)).expect("init");
    let body = lm_eval_body(&man);
    let server = Server::start(&fixture_dir(), cfg_interp()).expect("start");
    let addr = server.addr();

    let (_, _, before) = http(addr, "POST", "/v1/eval", &body);
    let v1_bits = Json::parse(&before).expect("json").get("sum_nll").as_f64();

    // 1. valid QNP1 upload with a matching checksum
    let up = scaled(&init, 0.5).to_qnp1_bytes();
    let path = format!("/v1/models/lm_tiny/params?checksum={}", to_hex(fnv1a64(&up)));
    let (status, _, resp) = http_bytes(addr, "POST", &path, &up);
    assert_eq!(status, 200, "{resp}");
    let j = Json::parse(&resp).expect("json");
    assert_eq!(j.get("version").as_f64(), Some(2.0), "{resp}");
    assert_eq!(j.get("scheme").as_str(), Some("none"), "{resp}");
    assert!(j.get("sq_error").as_f64().expect("sq_error") > 0.0, "{resp}");

    // evals now run on the uploaded weights (version 2, new bits)
    let (status, _, after) = http(addr, "POST", "/v1/eval", &body);
    assert_eq!(status, 200, "{after}");
    let j = Json::parse(&after).expect("json");
    assert_eq!(j.get("version").as_f64(), Some(2.0), "{after}");
    assert_ne!(j.get("sum_nll").as_f64(), v1_bits, "halved weights must change the loss");

    // 2. QNC1 checkpoint bodies are accepted too (params extracted)
    let velocity: Vec<Tensor> =
        init.iter().map(|(_, t)| Tensor::from_vec(&t.shape, vec![0.0; t.numel()])).collect();
    let ck = Checkpoint {
        model: "lm_tiny".into(),
        step: 3,
        batches: 3,
        rng: (1, 3),
        cfg_digest: 0,
        params: init.clone(),
        opt: OptState::Sgd { velocity },
        hats: vec![],
    };
    let (status, _, resp) =
        http_bytes(addr, "POST", "/v1/models/lm_tiny/params", &checkpoint::encode(&ck));
    assert_eq!(status, 200, "{resp}");
    assert_eq!(Json::parse(&resp).expect("json").get("version").as_f64(), Some(3.0));

    // 3. checksum mismatch is a typed 400, nothing swaps
    let path = format!("/v1/models/lm_tiny/params?checksum={}", to_hex(0xdead_beef));
    let (status, _, resp) = http_bytes(addr, "POST", &path, &up);
    assert_eq!(status, 400, "{resp}");
    assert!(resp.contains("checksum mismatch"), "{resp}");

    // 4. truncated QNP1 → 400 with byte-offset context
    let (status, _, resp) =
        http_bytes(addr, "POST", "/v1/models/lm_tiny/params", &up[..up.len() / 2]);
    assert_eq!(status, 400, "{resp}");
    assert!(resp.contains("byte"), "{resp}");

    // 5. bit-flipped QNC1 → 400 (the trailer catches it)
    let mut rot = checkpoint::encode(&ck);
    let mid = rot.len() / 2;
    rot[mid] ^= 0x20;
    let (status, _, resp) = http_bytes(addr, "POST", "/v1/models/lm_tiny/params", &rot);
    assert_eq!(status, 400, "{resp}");
    assert!(resp.contains("trailer hash"), "{resp}");

    // 6. wrong-shaped payload / unknown model / empty body
    let mut tiny = ParamStore::new();
    tiny.insert("w", Tensor::from_vec(&[2], vec![1.0, 2.0]));
    let (status, _, resp) =
        http_bytes(addr, "POST", "/v1/models/lm_tiny/params", &tiny.to_qnp1_bytes());
    assert_eq!(status, 400, "{resp}");
    let (status, _, _) = http_bytes(addr, "POST", "/v1/models/ghost/params", &up);
    assert_eq!(status, 404);
    let (status, _, resp) = http_bytes(addr, "POST", "/v1/models/lm_tiny/params", b"");
    assert_eq!(status, 400, "{resp}");

    // none of the rejects swapped anything: still version 3
    let (_, _, info) = http(addr, "GET", "/v1/models/lm_tiny", "");
    assert_eq!(Json::parse(&info).expect("json").get("version").as_f64(), Some(3.0), "{info}");
    assert!(stat_f64(addr, "swaps") >= 2.0);
    server.shutdown();
}
