//! The unified-scheme API contract:
//!
//! 1. `QuantSpec::parse(spec.to_string()) == spec` for every variant ×
//!    option combination (exhaustive enumeration + randomized cases on
//!    the in-repo prop harness).
//! 2. Golden equivalence: the trait-dispatched `quantize_params` and
//!    the `storage_bits`-derived `model_bytes` are byte/bit-identical
//!    on fixed seeds to the pre-refactor pipeline (re-implemented here
//!    verbatim as the oracle).
//! 3. Extension: a toy scheme implemented entirely in this file (one
//!    module, zero consumer edits) runs through the whole PTQ + size
//!    pipeline.

use std::collections::BTreeMap;

use quant_noise::coordinator::quantize::{quantize_params, quantize_params_with, scheme_bytes};
use quant_noise::model::config::{ModelMeta, ParamMeta};
use quant_noise::model::params::ParamStore;
use quant_noise::model::tensor::Tensor;
use quant_noise::quant::observer::HistogramObserver;
use quant_noise::quant::pq::{fit, PqConfig};
use quant_noise::quant::scalar;
use quant_noise::quant::scheme::{
    HatKind, IntObserver, PqSpec, QuantSpec, QuantizedTensor, Quantizer, QuantizerFactory,
    SchemeError,
};
use quant_noise::quant::size::{model_bytes, model_bytes_with, ParamInfo};
use quant_noise::util::rng::Pcg;
use quant_noise::util::testing::{prop_check, PropConfig};

// ------------------------------------------------- spec round-trips ---

fn all_int_specs() -> Vec<QuantSpec> {
    let mut out = Vec::new();
    for bits in [1u8, 2, 4, 6, 8] {
        for obs in [IntObserver::MinMax, IntObserver::Histogram, IntObserver::PerChannel] {
            out.push(QuantSpec::int(bits, obs));
        }
    }
    out
}

fn all_pq_specs() -> Vec<QuantSpec> {
    let mut out = Vec::new();
    for k in [1usize, 2, 64, 256, 1 << 12] {
        for block in [None, Some(4), Some(9)] {
            for iters in [0usize, 6, 12, 15] {
                for codebook_bits in [None, Some(8u8), Some(4u8)] {
                    for threads in [0usize, 3] {
                        for overrides in [
                            BTreeMap::new(),
                            BTreeMap::from([("ffn".to_string(), 16usize)]),
                            BTreeMap::from([
                                ("emb".to_string(), 4usize),
                                ("dw3x3".to_string(), 9),
                            ]),
                        ] {
                            out.push(QuantSpec::Pq(PqSpec {
                                k,
                                block,
                                kmeans_iters: iters,
                                codebook_bits,
                                block_override: overrides,
                                threads,
                            }));
                        }
                    }
                }
            }
        }
    }
    out
}

#[test]
fn every_spec_roundtrips_through_its_string_form() {
    let mut specs = vec![QuantSpec::None, QuantSpec::Proxy, QuantSpec::MeanSub];
    specs.extend(all_int_specs());
    specs.extend(all_pq_specs());
    assert!(specs.len() > 700, "combination sweep shrank: {}", specs.len());
    for spec in specs {
        let s = spec.to_string();
        let back = QuantSpec::parse(&s)
            .unwrap_or_else(|e| panic!("'{s}' failed to re-parse: {e}"));
        assert_eq!(back, spec, "round-trip through '{s}'");
        // Display is canonical: printing the re-parsed spec is a fixpoint
        assert_eq!(back.to_string(), s);
    }
}

#[test]
fn prop_random_pq_specs_roundtrip() {
    let structures = ["emb", "attn", "ffn", "cls", "conv1x1", "dw3x3", "stem"];
    prop_check("spec roundtrip", PropConfig { cases: 200, ..Default::default() }, |rng, _| {
        let mut p = PqSpec {
            k: 1 + rng.below(4096) as usize,
            block: if rng.below(2) == 0 { None } else { Some(1 + rng.below(64) as usize) },
            kmeans_iters: rng.below(40) as usize,
            codebook_bits: [None, Some(8u8), Some(4u8)][rng.below(3) as usize],
            block_override: BTreeMap::new(),
            threads: rng.below(9) as usize,
        };
        for _ in 0..rng.below(4) {
            let s = structures[rng.below(structures.len() as u32) as usize];
            p.block_override.insert(s.to_string(), 1 + rng.below(32) as usize);
        }
        let spec = QuantSpec::Pq(p);
        let s = spec.to_string();
        match QuantSpec::parse(&s) {
            Ok(back) if back == spec => Ok(()),
            Ok(back) => Err(format!("'{s}' parsed to {back:?}")),
            Err(e) => Err(format!("'{s}' failed: {e}")),
        }
    });
}

// ---------------------------------------------- golden equivalence ---

fn golden_meta() -> ModelMeta {
    ModelMeta {
        name: "golden".into(),
        task: "lm".into(),
        n_layers: 1,
        batch: 1,
        seq_len: 4,
        tokens_shape: vec![1, 4],
        targets_shape: vec![1, 4],
        vocab: 8,
        n_classes: 0,
        params: vec![
            ParamMeta {
                name: "emb".into(),
                shape: vec![32, 16],
                structure: "emb".into(),
                noised: true,
                view: Some((32, 16)),
                block_size: Some(4),
            },
            ParamMeta {
                name: "w1".into(),
                shape: vec![16, 32],
                structure: "ffn".into(),
                noised: true,
                view: Some((16, 32)),
                block_size: Some(8),
            },
            ParamMeta {
                name: "ln".into(),
                shape: vec![16],
                structure: "norm".into(),
                noised: false,
                view: None,
                block_size: None,
            },
        ],
        entries: vec![],
        init_file: String::new(),
    }
}

fn golden_params() -> ParamStore {
    let mut rng = Pcg::new(1234);
    let mut p = ParamStore::new();
    p.insert(
        "emb",
        Tensor::from_vec(&[32, 16], (0..512).map(|_| rng.next_normal()).collect()),
    );
    p.insert(
        "w1",
        Tensor::from_vec(&[16, 32], (0..512).map(|_| rng.next_normal() * 0.5).collect()),
    );
    p.insert("ln", Tensor::from_vec(&[16], vec![1.0; 16]));
    p
}

/// The pre-refactor `WeightScheme` pipeline, re-implemented verbatim as
/// the oracle (same primitives, same order, same RNG draws).
enum LegacyScheme {
    None,
    Int { bits: u8, mode: IntObserver },
    Pq {
        k: usize,
        kmeans_iters: usize,
        block_override: BTreeMap<String, usize>,
        int8_centroids: bool,
        threads: usize,
    },
}

fn legacy_quantize(
    params: &ParamStore,
    meta: &ModelMeta,
    scheme: &LegacyScheme,
    rng: &mut Pcg,
) -> (ParamStore, f64) {
    let mut store = ParamStore::new();
    let mut sq_error = 0.0f64;
    for pm in &meta.params {
        let t = params.get(&pm.name).unwrap();
        if !pm.noised {
            store.insert(&pm.name, t.clone());
            continue;
        }
        let (rows, cols) = pm.view.unwrap_or((1, t.numel()));
        let mut data = t.data.clone();
        match scheme {
            LegacyScheme::None => {}
            LegacyScheme::Int { bits, mode } => match mode {
                IntObserver::MinMax => {
                    let qp = scalar::QParams::from_minmax(&data, *bits);
                    scalar::roundtrip(&mut data, &qp);
                }
                IntObserver::Histogram => {
                    let mut h = HistogramObserver::new(2048);
                    h.observe(&data);
                    let qp = h.qparams(*bits);
                    scalar::roundtrip(&mut data, &qp);
                }
                IntObserver::PerChannel => {
                    scalar::roundtrip_per_channel(&mut data, rows, cols, *bits);
                }
            },
            LegacyScheme::Pq { k, kmeans_iters, block_override, int8_centroids, threads } => {
                let bs = block_override
                    .get(&pm.structure)
                    .copied()
                    .or(pm.block_size)
                    .unwrap_or(8);
                let cfg = PqConfig {
                    block_size: bs,
                    n_centroids: *k,
                    kmeans_iters: *kmeans_iters,
                    threads: *threads,
                };
                let mut m = fit(&data, rows, cols, &cfg, rng);
                if *int8_centroids {
                    m.codebook.compress_int8();
                }
                data = m.decode();
            }
        }
        sq_error += t
            .data
            .iter()
            .zip(&data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>();
        store.insert(&pm.name, Tensor::from_vec(&pm.shape, data));
    }
    (store, sq_error)
}

fn assert_stores_identical(a: &ParamStore, b: &ParamStore, tag: &str) {
    for name in a.names() {
        let (ta, tb) = (a.get(name).unwrap(), b.get(name).unwrap());
        assert_eq!(ta.data, tb.data, "{tag}: param {name} diverged");
    }
}

#[test]
fn quantize_params_bit_identical_to_legacy_pipeline() {
    let meta = golden_meta();
    let params = golden_params();
    let override_map = BTreeMap::from([("ffn".to_string(), 16usize)]);
    let cases: Vec<(&str, QuantSpec, LegacyScheme)> = vec![
        ("none", QuantSpec::None, LegacyScheme::None),
        (
            "int8 minmax",
            QuantSpec::int(8, IntObserver::MinMax),
            LegacyScheme::Int { bits: 8, mode: IntObserver::MinMax },
        ),
        (
            "int4 histogram",
            QuantSpec::int(4, IntObserver::Histogram),
            LegacyScheme::Int { bits: 4, mode: IntObserver::Histogram },
        ),
        (
            "int4 per-channel",
            QuantSpec::int(4, IntObserver::PerChannel),
            LegacyScheme::Int { bits: 4, mode: IntObserver::PerChannel },
        ),
        (
            "pq k=16",
            QuantSpec::Pq(PqSpec { kmeans_iters: 8, ..PqSpec::new(16) }),
            LegacyScheme::Pq {
                k: 16,
                kmeans_iters: 8,
                block_override: BTreeMap::new(),
                int8_centroids: false,
                threads: 0,
            },
        ),
        (
            "pq k=8 int8-cb + ffn override",
            QuantSpec::Pq(PqSpec {
                kmeans_iters: 6,
                codebook_bits: Some(8),
                block_override: override_map.clone(),
                ..PqSpec::new(8)
            }),
            LegacyScheme::Pq {
                k: 8,
                kmeans_iters: 6,
                block_override: override_map,
                int8_centroids: true,
                threads: 0,
            },
        ),
    ];
    for (tag, spec, legacy) in cases {
        let got = quantize_params(&params, &meta, &spec, &mut Pcg::new(77)).unwrap();
        let (want_store, want_err) = legacy_quantize(&params, &meta, &legacy, &mut Pcg::new(77));
        assert_stores_identical(&got.store, &want_store, tag);
        assert_eq!(got.sq_error.to_bits(), want_err.to_bits(), "{tag}: sq_error");
    }
}

#[test]
fn model_bytes_bit_identical_to_legacy_formulas() {
    // the exact arithmetic the pre-refactor size.rs used, per scheme
    let meta = golden_meta();
    let infos = meta.param_infos();
    let legacy_int = |bits: u64| -> u64 {
        infos
            .iter()
            .map(|p| if p.quantized { bits * p.numel as u64 + 64 } else { 32 * p.numel as u64 })
            .sum::<u64>()
            / 8
    };
    let legacy_pq = |k: usize, int8: bool, block_of: &dyn Fn(&ParamInfo) -> usize| -> u64 {
        infos
            .iter()
            .map(|p| {
                if !p.quantized {
                    return 32 * p.numel as u64;
                }
                let d = block_of(p);
                let n_sub = (p.numel / d) as u64;
                let index_bits = (k.max(2) as f64).log2().ceil() as u64;
                let centroid_bits = if int8 { 8 } else { 32 } * (k * d) as u64;
                centroid_bits + index_bits * n_sub + if int8 { 64 } else { 0 }
            })
            .sum::<u64>()
            / 8
    };
    let fp: u64 = infos.iter().map(|p| 32 * p.numel as u64).sum::<u64>() / 8;

    assert_eq!(scheme_bytes(&meta, &QuantSpec::None), fp);
    for bits in [4u64, 8] {
        let spec = QuantSpec::int(bits as u8, IntObserver::Histogram);
        assert_eq!(scheme_bytes(&meta, &spec), legacy_int(bits), "int{bits}");
    }
    for int8 in [false, true] {
        let spec = QuantSpec::Pq(PqSpec { codebook_bits: int8.then_some(8), ..PqSpec::new(64) });
        assert_eq!(
            scheme_bytes(&meta, &spec),
            legacy_pq(64, int8, &|p| p.pq_block),
            "pq cb-int8={int8}"
        );
    }
    // per-structure override, resolved exactly like the old
    // `to_param_info(block_override.get(structure))` path
    let spec = QuantSpec::Pq(PqSpec {
        block_override: BTreeMap::from([("ffn".to_string(), 16usize)]),
        ..PqSpec::new(64)
    });
    let with_override =
        legacy_pq(64, false, &|p| if p.structure == "ffn" { 16 } else { p.pq_block });
    assert_eq!(scheme_bytes(&meta, &spec), with_override);
    // and model_bytes over a raw inventory agrees with scheme_bytes
    assert_eq!(model_bytes(&infos, &QuantSpec::pq(64)), legacy_pq(64, false, &|p| p.pq_block));
}

// ------------------------------------------------------ toy scheme ---

/// 1-bit sign quantization: ŵ = α·sign(w), α = mean |w|. Lives entirely
/// in this test — proving a new scheme needs edits in exactly one
/// module to join PTQ, noise, and size accounting.
struct SignQuant;

impl Quantizer for SignQuant {
    fn name(&self) -> &'static str {
        "sign"
    }

    fn fit(
        &self,
        w: &[f32],
        _rows: usize,
        _cols: usize,
        _rng: &mut Pcg,
    ) -> Result<QuantizedTensor, SchemeError> {
        let alpha = w.iter().map(|x| x.abs()).sum::<f32>() / w.len().max(1) as f32;
        let data = w.iter().map(|&x| if x >= 0.0 { alpha } else { -alpha }).collect();
        Ok(QuantizedTensor { data, pq: None })
    }

    fn hat(
        &self,
        w: &[f32],
        rows: usize,
        cols: usize,
        rng: &mut Pcg,
    ) -> Result<HatKind, SchemeError> {
        Ok(HatKind::Host(self.fit(w, rows, cols, rng)?.data))
    }

    /// 1 bit per weight + one fp32 α.
    fn storage_bits(&self, p: &ParamInfo) -> u64 {
        if !p.quantized {
            return 32 * p.numel as u64;
        }
        p.numel as u64 + 32
    }
}

struct SignFamily;

impl QuantizerFactory for SignFamily {
    fn for_param(&self, _p: &ParamInfo) -> Box<dyn Quantizer> {
        Box::new(SignQuant)
    }

    fn spec_string(&self) -> String {
        "sign".to_string()
    }
}

#[test]
fn toy_scheme_plugs_into_the_full_pipeline() {
    let meta = golden_meta();
    let params = golden_params();
    let q = quantize_params_with(&params, &meta, &SignFamily, &mut Pcg::new(9)).unwrap();
    // norms untouched, noised weights collapsed to ±α
    assert_eq!(q.store.get("ln").unwrap(), params.get("ln").unwrap());
    let w = q.store.get("w1").unwrap();
    let alpha = w.data[0].abs();
    assert!(alpha > 0.0);
    assert!(w.data.iter().all(|&x| x.abs() == alpha));
    // signs preserved
    for (&orig, &got) in params.get("w1").unwrap().data.iter().zip(&w.data) {
        assert_eq!(orig >= 0.0, got >= 0.0);
    }
    // storage accounting flows through the same trait
    let infos = meta.param_infos();
    let expect: u64 = infos
        .iter()
        .map(|p| if p.quantized { p.numel as u64 + 32 } else { 32 * p.numel as u64 })
        .sum::<u64>()
        / 8;
    assert_eq!(q.bytes, expect);
    assert_eq!(model_bytes_with(&infos, &SignFamily), expect);
    // ~32x on quantized params, so well below the fp32 total
    assert!(q.bytes < model_bytes(&infos, &QuantSpec::None));
    // and it can serve as a noise hat too
    match SignQuant.hat(&params.get("w1").unwrap().data, 16, 32, &mut Pcg::new(0)).unwrap() {
        HatKind::Host(h) => assert_eq!(h, w.data),
        other => panic!("{other:?}"),
    }
}
