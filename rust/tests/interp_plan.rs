//! Golden bit-identity of the planned in-place executor vs the
//! tree-walking reference evaluator on the checked-in `lm_tiny`
//! fixture (grad_mix + eval), across thread counts {1, 3, 8}, plus
//! copy-on-write aliasing properties (shared argument buffers survive
//! in-place execution unchanged) and batch-sharded eval equivalence
//! through the full runtime seam (DESIGN.md §4).

use std::path::Path;

use quant_noise::model::params::ParamStore;
use quant_noise::runtime::client::Runtime;
use quant_noise::runtime::executable::{BatchInput, ModelSession};
use quant_noise::runtime::interp::{ArrayValue, Buf, HloModule, Interp, Plan, Value};
use quant_noise::runtime::manifest::Manifest;

fn fixture_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp")
}

fn f32v(dims: &[usize], data: Vec<f32>) -> Value {
    Value::Array(ArrayValue::new(dims.to_vec(), Buf::F32(data)).unwrap())
}

fn i32v(dims: &[usize], data: Vec<i32>) -> Value {
    Value::Array(ArrayValue::new(dims.to_vec(), Buf::S32(data)).unwrap())
}

/// Exact structural + bitwise equality (f32 compared by bit pattern,
/// so even NaN payloads and zero signs must agree).
fn assert_bit_identical(a: &Value, b: &Value, path: &str) {
    match (a, b) {
        (Value::Tuple(xs), Value::Tuple(ys)) => {
            assert_eq!(xs.len(), ys.len(), "{path}: tuple arity");
            for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
                assert_bit_identical(x, y, &format!("{path}.{i}"));
            }
        }
        (Value::Array(x), Value::Array(y)) => {
            assert_eq!(x.dims, y.dims, "{path}: dims");
            match (&*x.buf, &*y.buf) {
                (Buf::F32(p), Buf::F32(q)) => {
                    for (i, (u, v)) in p.iter().zip(q).enumerate() {
                        assert_eq!(u.to_bits(), v.to_bits(), "{path}[{i}]");
                    }
                }
                (p, q) => assert_eq!(p, q, "{path}: buffer"),
            }
        }
        _ => panic!("{path}: array/tuple kind mismatch"),
    }
}

struct Fixture {
    grad_mod: HloModule,
    eval_mod: HloModule,
    grad_args: Vec<Value>,
    eval_args: Vec<Value>,
}

fn load_fixture(rate: f32, seed: i32) -> Fixture {
    let dir = fixture_dir();
    let man = Manifest::load(&dir).expect("checked-in interp fixture must load");
    let meta = man.model("lm_tiny").unwrap().clone();
    let params = ParamStore::load_qnp1(&man.init_path(&meta)).unwrap();
    let n = meta.batch * meta.seq_len;
    let tokens: Vec<i32> = (0..n).map(|i| ((i * 7 + 3) % meta.vocab) as i32).collect();
    let targets: Vec<i32> = (0..n).map(|i| ((i * 5 + 1) % meta.vocab) as i32).collect();
    let keep = vec![1.0f32; meta.n_layers];

    let pvals: Vec<Value> =
        params.iter().map(|(_, t)| f32v(&t.shape, t.data.clone())).collect();
    let hvals: Vec<Value> =
        params.iter().map(|(_, t)| f32v(&t.shape, vec![0.0; t.data.len()])).collect();
    let mut grad_args = pvals.clone();
    grad_args.extend(hvals);
    grad_args.push(i32v(&meta.tokens_shape, tokens.clone()));
    grad_args.push(i32v(&meta.targets_shape, targets.clone()));
    grad_args.push(f32v(&[keep.len()], keep.clone()));
    grad_args.push(f32v(&[], vec![rate]));
    grad_args.push(i32v(&[], vec![seed]));
    let mut eval_args = pvals;
    eval_args.push(i32v(&meta.tokens_shape, tokens));
    eval_args.push(i32v(&meta.targets_shape, targets));
    eval_args.push(f32v(&[keep.len()], keep));

    let grad_mod = HloModule::parse_file(&man.hlo_path(&meta, "grad_mix").unwrap()).unwrap();
    let eval_mod = HloModule::parse_file(&man.hlo_path(&meta, "eval").unwrap()).unwrap();
    Fixture { grad_mod, eval_mod, grad_args, eval_args }
}

#[test]
fn grad_mix_planned_bit_identical_across_threads() {
    // rate 0.5 exercises the threefry while-loops + noise select paths
    let fx = load_fixture(0.5, 42);
    let golden = Interp::new(&fx.grad_mod).run_entry(&fx.grad_args).unwrap();
    let plan = Plan::compile(&fx.grad_mod);
    for threads in [1usize, 3, 8] {
        let got = plan.run_entry(fx.grad_args.clone(), threads).unwrap();
        assert_bit_identical(&got, &golden, &format!("grad_mix[t={threads}]"));
    }
}

#[test]
fn eval_planned_bit_identical_across_threads() {
    let fx = load_fixture(0.0, 1);
    let golden = Interp::new(&fx.eval_mod).run_entry(&fx.eval_args).unwrap();
    let plan = Plan::compile(&fx.eval_mod);
    for threads in [1usize, 3, 8] {
        let got = plan.run_entry(fx.eval_args.clone(), threads).unwrap();
        assert_bit_identical(&got, &golden, &format!("eval[t={threads}]"));
    }
}

#[test]
fn shared_argument_buffers_survive_inplace_execution() {
    // All argument values share their buffers with this test (and with
    // each other across the two runs): if the in-place executor ever
    // wrote through a shared buffer instead of copy-on-write, either
    // the second run would diverge or the snapshot comparison below
    // would fail.
    let fx = load_fixture(1.0, 7);
    let snapshot: Vec<Value> = fx.grad_args.clone(); // shares every Arc
    let plan = Plan::compile(&fx.grad_mod);
    let a = plan.run_entry(fx.grad_args.clone(), 1).unwrap();
    let b = plan.run_entry(fx.grad_args.clone(), 1).unwrap();
    assert_bit_identical(&a, &b, "rerun");
    for (i, (now, before)) in fx.grad_args.iter().zip(&snapshot).enumerate() {
        assert_bit_identical(now, before, &format!("arg{i}"));
    }
}

#[test]
fn batched_eval_matches_sequential_at_all_thread_counts() {
    let dir = fixture_dir();
    let man = Manifest::load(&dir).unwrap();
    let rt = Runtime::interp();
    let (mut sess, _params) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let meta = sess.meta.clone();
    let n = meta.batch * meta.seq_len;
    let keep = vec![1.0f32; meta.n_layers];
    // three distinct batches
    let batches: Vec<(Vec<i32>, Vec<i32>)> = (0..3)
        .map(|s| {
            let tokens: Vec<i32> =
                (0..n).map(|i| ((i * 3 + s * 11 + 1) % meta.vocab) as i32).collect();
            let targets: Vec<i32> =
                (0..n).map(|i| ((i * 13 + s * 5 + 2) % meta.vocab) as i32).collect();
            (tokens, targets)
        })
        .collect();
    // golden: sequential single-batch evals
    let golden: Vec<(f64, f64)> = batches
        .iter()
        .map(|(t, g)| sess.eval("eval", &BatchInput::Tokens(t), g, &keep).unwrap())
        .collect();
    let macro_tokens: Vec<i32> = batches.iter().flat_map(|(t, _)| t.iter().copied()).collect();
    let macro_targets: Vec<i32> = batches.iter().flat_map(|(_, g)| g.iter().copied()).collect();
    for threads in [1usize, 3, 8] {
        rt.set_threads(threads);
        let got = sess
            .eval_batched("eval", &BatchInput::Tokens(&macro_tokens), &macro_targets, &keep)
            .unwrap();
        assert_eq!(got.len(), golden.len(), "threads={threads}");
        for (s, (g, w)) in got.iter().zip(&golden).enumerate() {
            assert_eq!(g.0.to_bits(), w.0.to_bits(), "shard {s} nll, threads={threads}");
            assert_eq!(g.1.to_bits(), w.1.to_bits(), "shard {s} correct, threads={threads}");
        }
    }
}

#[test]
fn grad_entry_through_session_matches_raw_plan() {
    // the ModelSession seam (buffers, uploads, threads knob) must not
    // perturb results relative to driving the plan directly
    let fx = load_fixture(0.25, 9);
    let golden = Interp::new(&fx.grad_mod).run_entry(&fx.grad_args).unwrap();
    let loss_golden = golden.tuple().unwrap()[0].array().unwrap().as_f32().unwrap()[0];

    let dir = fixture_dir();
    let man = Manifest::load(&dir).unwrap();
    let rt = Runtime::interp();
    let (mut sess, _params) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let meta = sess.meta.clone();
    let n = meta.batch * meta.seq_len;
    let tokens: Vec<i32> = (0..n).map(|i| ((i * 7 + 3) % meta.vocab) as i32).collect();
    let targets: Vec<i32> = (0..n).map(|i| ((i * 5 + 1) % meta.vocab) as i32).collect();
    let keep = vec![1.0f32; meta.n_layers];
    for threads in [1usize, 3, 8] {
        rt.set_threads(threads);
        let (loss, _grads) = sess
            .grad("grad_mix", &BatchInput::Tokens(&tokens), &targets, &keep, 0.25, 9)
            .unwrap();
        assert_eq!(loss.to_bits(), loss_golden.to_bits(), "threads={threads}");
    }
}
