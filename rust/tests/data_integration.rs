//! Data pipeline integration: corpus → batcher → (shapes, coverage,
//! vocabulary bounds) as the trainer consumes them.

use quant_noise::data::batcher::{EpochBatcher, LmBatcher};
use quant_noise::data::corpus::{make_cls_dataset, make_img_dataset, MarkovCorpus};

#[test]
fn lm_corpus_through_batcher() {
    let c = MarkovCorpus::generate(512, 100_000, 1);
    let mut b = LmBatcher::new(&c.tokens, 8, 64);
    for _ in 0..b.batches_per_epoch().min(50) {
        let batch = b.next();
        assert_eq!(batch.tokens.len(), 8 * 64);
        assert!(batch.tokens.iter().all(|&t| (0..512).contains(&t)));
        assert!(batch.targets.iter().all(|&t| (0..512).contains(&t)));
    }
}

#[test]
fn train_eval_split_has_no_overlap() {
    let c = MarkovCorpus::generate(64, 10_000, 2);
    let split = c.tokens.len() * 9 / 10;
    let (train, eval) = c.tokens.split_at(split);
    assert_eq!(train.len() + eval.len(), c.tokens.len());
    // different stream positions: the eval tail differs from train head
    assert_ne!(&train[..100], &eval[..100]);
}

#[test]
fn cls_batches_align_tokens_with_labels() {
    let (tokens, labels) = make_cls_dataset(200, 32, 256, 4, 3);
    let b = EpochBatcher::new(tokens.clone(), labels.clone(), 32, 10, 1);
    let (ex, lb) = b.eval_batch(2);
    assert_eq!(ex.len(), 10 * 32);
    // eval batch i is examples [i*10, (i+1)*10)
    assert_eq!(lb, labels[20..30].to_vec());
    assert_eq!(&ex[..32], &tokens[20 * 32..21 * 32]);
}

#[test]
fn img_batcher_shapes_for_model_input() {
    let (px, labels) = make_img_dataset(100, 16, 3, 5);
    let mut b = EpochBatcher::new(px, labels, 16 * 16 * 3, 32, 2);
    let (ex, lb) = b.next();
    assert_eq!(ex.len(), 32 * 16 * 16 * 3); // (B,H,W,C) flat
    assert_eq!(lb.len(), 32);
}

#[test]
fn corpus_statistics_stable_across_sizes() {
    // entropy estimates shouldn't swing wildly with corpus length
    let small = MarkovCorpus::generate(128, 50_000, 9).unigram_entropy();
    let large = MarkovCorpus::generate(128, 200_000, 9).unigram_entropy();
    assert!((small - large).abs() < 0.2, "{small} vs {large}");
}
