//! Checkpoint/artifact I/O under injected faults (DESIGN.md §10).
//!
//! The tentpole crash-safety claim: a failure at *any* point of the
//! save protocol — mid-write, pre-fsync, pre-rename, while rewriting
//! the `LATEST` pointer — leaves the checkpoint directory loadable,
//! with `load_latest` returning the last durable state.
//!
//! The fault registry is process-global, so these tests live in their
//! own binary and serialize on a mutex; each arms its plan through a
//! drop guard so a panicking assertion cannot leak faults into the
//! next test.

use std::fs;
use std::path::Path;
use std::sync::{Mutex, MutexGuard, PoisonError};

use quant_noise::coordinator::checkpoint::{load_latest, save_checkpoint, Checkpoint, OptState};
use quant_noise::model::params::ParamStore;
use quant_noise::model::tensor::Tensor;
use quant_noise::util::fault;
use quant_noise::util::testing::temp_dir;

static LOCK: Mutex<()> = Mutex::new(());

/// Serialize tests and guarantee `fault::clear()` on every exit path.
struct Armed<'a> {
    _guard: MutexGuard<'a, ()>,
}

fn arm(spec: &str) -> Armed<'static> {
    let guard = LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    fault::clear();
    fault::install(spec).expect("valid fault spec");
    Armed { _guard: guard }
}

impl Drop for Armed<'_> {
    fn drop(&mut self) {
        fault::clear();
    }
}

fn sample(step: usize) -> Checkpoint {
    let mut params = ParamStore::new();
    params.insert("w0", Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 4.25, -0.5]));
    params.insert("b0", Tensor::from_vec(&[3], vec![0.1, 0.2, 0.3]));
    let velocity =
        vec![Tensor::from_vec(&[2, 3], vec![0.0; 6]), Tensor::from_vec(&[3], vec![9.0; 3])];
    Checkpoint {
        model: "lm".to_string(),
        step,
        batches: step + 1,
        rng: (0x0123_4567_89ab_cdef, 0xfedc_ba98_7654_3211),
        cfg_digest: 0xdead_beef_cafe_f00d,
        params,
        opt: OptState::Sgd { velocity },
        hats: vec![(0, vec![1.5, 2.5])],
    }
}

fn loadable_step(dir: &Path) -> usize {
    load_latest(dir)
        .expect("load_latest must not error on a crashed directory")
        .expect("directory must stay loadable")
        .step
}

#[test]
fn short_write_leaves_loadable_last_good() {
    let dir = temp_dir("fault-short");
    {
        let _armed = arm("ckpt.write=short@2");
        save_checkpoint(&dir, &sample(2)).expect("first save clean");
        let err = save_checkpoint(&dir, &sample(4)).expect_err("short write must fail");
        assert!(err.to_string().contains("write"), "unexpected error: {err:#}");
    }
    // the torn step-4 temp file must not shadow the durable step-2
    assert_eq!(loadable_step(&dir), 2);
    assert!(
        !dir.join("step-00000004.qnc1").exists(),
        "a torn write must never be renamed into place"
    );
    fs::remove_dir_all(dir).ok();
}

#[test]
fn fsync_failure_keeps_previous_checkpoint() {
    let dir = temp_dir("fault-sync");
    {
        // ckpt.sync is hit twice per save (checkpoint file + LATEST
        // pointer), so hit 3 is the second save's checkpoint fsync
        let _armed = arm("ckpt.sync=err@3");
        save_checkpoint(&dir, &sample(1)).expect("first save clean");
        save_checkpoint(&dir, &sample(3)).expect_err("fsync fault must fail the save");
    }
    assert_eq!(loadable_step(&dir), 1);
    fs::remove_dir_all(dir).ok();
}

#[test]
fn rename_failure_keeps_previous_checkpoint() {
    let dir = temp_dir("fault-rename");
    {
        // like ckpt.sync, the rename point fires for both the file and
        // the LATEST pointer: hit 3 = second save's checkpoint rename
        let _armed = arm("ckpt.rename=err@3");
        save_checkpoint(&dir, &sample(1)).expect("first save clean");
        save_checkpoint(&dir, &sample(3)).expect_err("rename fault must fail the save");
    }
    assert_eq!(loadable_step(&dir), 1);
    fs::remove_dir_all(dir).ok();
}

#[test]
fn torn_latest_pointer_still_loads() {
    let dir = temp_dir("fault-latest");
    {
        // second save: checkpoint file lands durably, then the LATEST
        // rewrite tears — the old pointer (still valid) wins
        let _armed = arm("ckpt.latest.write=short@2");
        save_checkpoint(&dir, &sample(2)).expect("first save clean");
        save_checkpoint(&dir, &sample(4)).expect_err("torn LATEST must surface as an error");
    }
    assert_eq!(loadable_step(&dir), 2, "stale-but-valid LATEST is the crash contract");
    // if the pointer is lost entirely, the scan must recover the newest
    // durable file — which is step 4, whose write succeeded
    fs::remove_file(dir.join("LATEST")).expect("remove LATEST");
    assert_eq!(loadable_step(&dir), 4, "fallback scan must find the durable step-4 file");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn repeated_crashes_never_lose_the_directory() {
    // every third write tears, deterministically; progress continues
    // and the directory stays loadable after every attempt
    let dir = temp_dir("fault-repeat");
    let mut last_good = None;
    {
        let _armed = arm("ckpt.write=err~333:7");
        for step in 1..=12 {
            match save_checkpoint(&dir, &sample(step)) {
                Ok(_) => last_good = Some(step),
                Err(_) => {}
            }
            if let Some(want) = last_good {
                assert_eq!(loadable_step(&dir), want, "after save attempt {step}");
            }
        }
    }
    assert!(last_good.is_some(), "permille plan should let some saves through");
    fs::remove_dir_all(dir).ok();
}

#[test]
fn qnp1_load_fault_is_an_error_not_a_panic() {
    let dir = temp_dir("fault-qnp1");
    let path = dir.join("w.qnp1");
    let mut store = ParamStore::new();
    store.insert("w", Tensor::from_vec(&[2], vec![1.0, 2.0]));
    store.save_qnp1(&path).expect("save");
    {
        let _armed = arm("load.qnp1=err");
        let err = ParamStore::load_qnp1(&path).expect_err("injected read fault");
        assert!(err.to_string().contains("injected fault"), "unexpected error: {err:#}");
    }
    // with the plan cleared the same file loads fine
    let back = ParamStore::load_qnp1(&path).expect("clean load");
    assert_eq!(back.get("w"), store.get("w"));
    fs::remove_dir_all(dir).ok();
}

#[test]
fn unarmed_points_cost_nothing_and_fire_nothing() {
    let _guard = LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    fault::clear();
    assert!(!fault::active());
    assert!(fault::check("ckpt.write").is_ok());
    let dir = temp_dir("fault-off");
    save_checkpoint(&dir, &sample(9)).expect("saves succeed with no plan armed");
    assert_eq!(loadable_step(&dir), 9);
    fs::remove_dir_all(dir).ok();
}
