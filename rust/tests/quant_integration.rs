//! Cross-module quantization integration: PQ + codebooks + size
//! accounting + observers working together on realistic weight shapes.

use quant_noise::quant::codebook::Codebook;
use quant_noise::quant::kmeans::{kmeans, KmeansConfig};
use quant_noise::quant::observer::{HistogramObserver, MinMaxObserver};
use quant_noise::quant::pq::{decode_codes_into, encode, encode_scalar, fit, PqConfig, PqMatrix};
use quant_noise::quant::scalar::{quant_mse, QParams};
use quant_noise::quant::scheme::{IntObserver, PqSpec, QuantSpec};
use quant_noise::quant::size::{compression_ratio, ParamInfo};
use quant_noise::util::rng::Pcg;

fn weight(seed: u64, rows: usize, cols: usize) -> Vec<f32> {
    let mut r = Pcg::new(seed);
    (0..rows * cols).map(|_| r.next_normal() * 0.1).collect()
}

#[test]
fn pq_pipeline_end_to_end() {
    // fit → decode → re-encode must be stable (idempotent assignments)
    let w = weight(1, 256, 128);
    let cfg = PqConfig { block_size: 8, n_centroids: 64, kmeans_iters: 12, threads: 0 };
    let m = fit(&w, 256, 128, &cfg, &mut Pcg::new(2));
    let dec = m.decode();
    let codes2 = encode(&dec, 256, 128, &m.codebook);
    assert_eq!(m.codes, codes2, "decoded weights must re-encode to the same codes");
}

#[test]
fn pq_then_int8_centroids_error_budget() {
    // §3.3: int8 centroids add at most the int8 rounding error on top
    let w = weight(3, 128, 64);
    let cfg = PqConfig { block_size: 8, n_centroids: 32, kmeans_iters: 10, threads: 0 };
    let mut m = fit(&w, 128, 64, &cfg, &mut Pcg::new(4));
    let err_pq = m.objective(&w);
    let cmse = m.codebook.compress_int8();
    let err_combo = m.objective(&w);
    // combined error bounded loosely: PQ error + 2*sqrt(pq*int8) + int8
    let n = w.len() as f64;
    let bound = (err_pq.sqrt() + (cmse * n).sqrt()).powi(2) + 1e-6;
    assert!(err_combo <= bound, "{err_combo} > {bound}");
}

#[test]
fn pq_then_int4_centroids_error_budget_and_size() {
    // cb=int4: half the codebook bits of cb=int8, coarser grid, but the
    // same additive error-budget structure
    let w = weight(3, 128, 64);
    let cfg = PqConfig { block_size: 8, n_centroids: 32, kmeans_iters: 10, threads: 0 };
    let mut m4 = fit(&w, 128, 64, &cfg, &mut Pcg::new(4));
    let mut m8 = m4.clone();
    let err_pq = m4.objective(&w);
    let cmse8 = m8.codebook.compress(8);
    let cmse4 = m4.codebook.compress(4);
    assert!(cmse4 > cmse8, "{cmse4} vs {cmse8}");
    let err_combo = m4.objective(&w);
    let n = w.len() as f64;
    let bound = (err_pq.sqrt() + (cmse4 * n).sqrt()).powi(2) + 1e-6;
    assert!(err_combo <= bound, "{err_combo} > {bound}");
    // accounting: only the codebook term differs between the variants
    assert_eq!(m8.codebook.storage_bits(), 2 * m4.codebook.storage_bits());
    assert_eq!(
        m8.storage_bits() - m8.codebook.storage_bits(),
        m4.storage_bits() - m4.codebook.storage_bits()
    );
}

#[test]
fn kmeans_objective_equals_pq_objective() {
    let w = weight(5, 64, 64);
    let mut rng = Pcg::new(6);
    let km = kmeans(&w, 8, &KmeansConfig { k: 16, max_iters: 10, ..Default::default() }, &mut rng);
    let m = PqMatrix {
        codebook: Codebook::new(km.centroids.clone(), km.k, 8),
        codes: km.assignments.clone(),
        rows: 64,
        cols: 64,
    };
    let last = *km.objective_history.last().unwrap();
    let obj = m.objective(&w);
    assert!((last - obj).abs() <= 1e-3 * last.max(1.0), "{last} vs {obj}");
}

#[test]
fn observers_agree_on_clean_data() {
    // without outliers the two observers should produce similar MSE
    let w = weight(7, 64, 64);
    let mut mm = MinMaxObserver::new();
    mm.observe(&w);
    let mut h = HistogramObserver::new(2048);
    h.observe(&w);
    let e_mm = quant_mse(&w, &mm.qparams(8));
    let e_h = quant_mse(&w, &h.qparams(8));
    assert!(e_h <= e_mm * 2.0, "{e_h} vs {e_mm}");
}

#[test]
fn compression_ratios_ordering() {
    // fp32 < int8 < int4 < PQ(d8,K64) compression on a realistic mix
    let params: Vec<ParamInfo> = (0..10)
        .map(|i| ParamInfo {
            name: format!("w{i}"),
            structure: "ffn".into(),
            numel: 512 * 128,
            rows: 512,
            cols: 128,
            quantized: i % 5 != 4, // some fp32 norms
            pq_block: 8,
        })
        .collect();
    let pq8 = QuantSpec::Pq(PqSpec { codebook_bits: Some(8), ..PqSpec::new(64) });
    let pq4 = QuantSpec::Pq(PqSpec { codebook_bits: Some(4), ..PqSpec::new(64) });
    let r8 = compression_ratio(&params, &QuantSpec::int(8, IntObserver::MinMax));
    let r4 = compression_ratio(&params, &QuantSpec::int(4, IntObserver::MinMax));
    let rpq = compression_ratio(&params, &QuantSpec::pq(64));
    let rpq8 = compression_ratio(&params, &pq8);
    let rpq4 = compression_ratio(&params, &pq4);
    assert!(
        1.0 < r8 && r8 < r4 && r4 < rpq && rpq < rpq8 && rpq8 < rpq4,
        "{r8} {r4} {rpq} {rpq8} {rpq4}"
    );
}

#[test]
fn engine_encode_matches_seed_scalar_loop() {
    // Regression for the assignment-engine refactor: on a codebook
    // whose decision margins dwarf fp noise (codewords on a coarse
    // lattice, points jittered around them), the norm-decomposed
    // parallel encode must reproduce the seed's scalar dist2 loop
    // bit-for-bit — which makes the exact-PQ hat byte-identical across
    // the refactor.
    let d = 8usize;
    let k = 32usize;
    let (rows, cols) = (64usize, 64usize);
    let centroids: Vec<f32> = (0..k * d)
        .map(|i| (i / d) as f32 * 4.0 - 2.0 * (i % d) as f32)
        .collect();
    let cb = Codebook::new(centroids.clone(), k, d);
    let mut rng = Pcg::new(11);
    let w: Vec<f32> = (0..rows * cols)
        .map(|i| {
            let sv = i / d;
            let j = sv % k;
            centroids[j * d + i % d] + rng.next_normal() * 0.05
        })
        .collect();
    let fast = encode(&w, rows, cols, &cb);
    let slow = encode_scalar(&w, rows, cols, &cb);
    assert_eq!(fast, slow);
    // decoding the engine's codes equals the scalar path's decode
    let mut hat = vec![0.0f32; w.len()];
    decode_codes_into(&cb, &fast, &mut hat);
    let m = PqMatrix { codebook: cb, codes: slow, rows, cols };
    assert_eq!(hat, m.decode());
}

#[test]
fn qparams_roundtrip_stability_across_magnitudes() {
    for scale in [1e-4f32, 1.0, 1e4] {
        let w: Vec<f32> = weight(9, 32, 32).iter().map(|x| x * scale).collect();
        let qp = QParams::from_minmax(&w, 8);
        let mse = quant_mse(&w, &qp);
        // error scales with the square of the range
        assert!(mse.sqrt() <= (qp.scale / 2.0) as f64 + 1e-9);
    }
}
