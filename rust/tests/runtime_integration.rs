//! Runtime integration: run grad and eval steps, verify numerics make
//! sense (finite loss near ln(vocab) at init, grads nonzero,
//! noise-rate behaviour, LayerDrop masks, seed determinism).
//!
//! LM and img tests execute for real on the checked-in interpreter
//! fixture (tests/fixtures/interp — DESIGN.md §4) and never skip: the
//! interpreter covers the ConvNet op set (convolution, reverse,
//! reduce-window). Only the cls and intN-entry tests need the full
//! artifact zoo and still skip without `make artifacts`.

use std::path::Path;

use quant_noise::model::tensor::Tensor;
use quant_noise::runtime::client::Runtime;
use quant_noise::runtime::executable::{BatchInput, ModelSession};
use quant_noise::runtime::manifest::Manifest;

fn fixture() -> (Runtime, Manifest) {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp");
    let man = Manifest::load(&dir).expect("checked-in interp fixture must load");
    (Runtime::interp(), man)
}

fn artifacts() -> Option<Manifest> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (needs real artifacts): {e}");
            None
        }
    }
}

fn lm_batch(meta: &quant_noise::model::config::ModelMeta) -> (Vec<i32>, Vec<i32>) {
    let n = meta.batch * meta.seq_len;
    let tokens: Vec<i32> = (0..n).map(|i| (i % meta.vocab) as i32).collect();
    let targets: Vec<i32> = (0..n).map(|i| ((i + 1) % meta.vocab) as i32).collect();
    (tokens, targets)
}

#[test]
fn lm_eval_loss_near_uniform_at_init() {
    let (rt, man) = fixture();
    let (mut sess, _params) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let (tokens, targets) = lm_batch(&sess.meta);
    let keep = vec![1.0f32; sess.meta.n_layers];
    let (sum_nll, correct) = sess
        .eval("eval", &BatchInput::Tokens(&tokens), &targets, &keep)
        .unwrap();
    let ntok = sess.meta.eval_denominator() as f64;
    let nll = sum_nll / ntok;
    let uniform = (sess.meta.vocab as f64).ln();
    assert!(
        (nll - uniform).abs() < 1.0,
        "init LM nll {nll} should be near ln(V) = {uniform}"
    );
    assert!(correct >= 0.0 && correct <= ntok);
}

#[test]
fn lm_grad_step_produces_finite_grads() {
    let (rt, man) = fixture();
    let (mut sess, params) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let (tokens, targets) = lm_batch(&sess.meta);
    let keep = vec![1.0f32; sess.meta.n_layers];
    let (loss, grads) = sess
        .grad("grad_mix", &BatchInput::Tokens(&tokens), &targets, &keep, 0.0, 1)
        .unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    assert_eq!(grads.len(), params.len());
    let mut nonzero = 0;
    for g in &grads {
        assert!(g.data.iter().all(|x| x.is_finite()));
        if g.max_abs() > 0.0 {
            nonzero += 1;
        }
    }
    // every param should receive gradient signal at rate 0
    assert!(nonzero as f64 > grads.len() as f64 * 0.9, "{nonzero}/{}", grads.len());
}

#[test]
fn noise_rate_changes_loss() {
    // At rate 1.0 with zero hats (proxy/QAT limit), all noised weights
    // are zeroed in the forward: the loss must differ from rate 0.0,
    // and be close to ln(V) (embedding zeroed ⇒ near-uniform logits).
    let (rt, man) = fixture();
    let (mut sess, _) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let (tokens, targets) = lm_batch(&sess.meta);
    let keep = vec![1.0f32; sess.meta.n_layers];
    let (l0, _) = sess
        .grad("grad_mix", &BatchInput::Tokens(&tokens), &targets, &keep, 0.0, 7)
        .unwrap();
    let (l1, _) = sess
        .grad("grad_mix", &BatchInput::Tokens(&tokens), &targets, &keep, 1.0, 7)
        .unwrap();
    assert!((l1 - l0).abs() > 1e-6, "rate must affect forward: {l0} vs {l1}");
    let uniform = (sess.meta.vocab as f32).ln();
    assert!((l1 - uniform).abs() < 0.2, "all-zero weights ⇒ uniform {l1} vs {uniform}");
}

#[test]
fn grad_deterministic_given_seed() {
    let (rt, man) = fixture();
    let (mut sess, _) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let (tokens, targets) = lm_batch(&sess.meta);
    let keep = vec![1.0f32; sess.meta.n_layers];
    let (la, ga) = sess
        .grad("grad_mix", &BatchInput::Tokens(&tokens), &targets, &keep, 0.5, 42)
        .unwrap();
    let (lb, gb) = sess
        .grad("grad_mix", &BatchInput::Tokens(&tokens), &targets, &keep, 0.5, 42)
        .unwrap();
    assert_eq!(la, lb);
    assert_eq!(ga[0].data, gb[0].data);
    // different seed ⇒ different mask ⇒ different loss (w.h.p.)
    let (lc, _) = sess
        .grad("grad_mix", &BatchInput::Tokens(&tokens), &targets, &keep, 0.5, 43)
        .unwrap();
    assert_ne!(la, lc);
}

#[test]
fn layerdrop_mask_affects_loss() {
    let (rt, man) = fixture();
    let (mut sess, _) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let (tokens, targets) = lm_batch(&sess.meta);
    let all = vec![1.0f32; sess.meta.n_layers];
    let mut half = all.clone();
    half[1] = 0.0;
    let (s_all, _) = sess
        .eval("eval", &BatchInput::Tokens(&tokens), &targets, &all)
        .unwrap();
    let (s_half, _) = sess
        .eval("eval", &BatchInput::Tokens(&tokens), &targets, &half)
        .unwrap();
    assert_ne!(s_all, s_half);
    assert!(s_half.is_finite());
}

#[test]
fn param_upload_changes_eval() {
    let (rt, man) = fixture();
    let (mut sess, params) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let (tokens, targets) = lm_batch(&sess.meta);
    let keep = vec![1.0f32; sess.meta.n_layers];
    let (before, _) = sess
        .eval("eval", &BatchInput::Tokens(&tokens), &targets, &keep)
        .unwrap();
    // zero the embedding
    let idx = sess.param_index("embed").unwrap();
    let zero = Tensor::zeros(&params.get("embed").unwrap().shape);
    sess.upload_param(idx, &zero).unwrap();
    let (after, _) = sess
        .eval("eval", &BatchInput::Tokens(&tokens), &targets, &keep)
        .unwrap();
    assert_ne!(before, after);
    let ntok = sess.meta.eval_denominator() as f64;
    let uniform = (sess.meta.vocab as f64).ln();
    assert!((after / ntok - uniform).abs() < 0.05);
}

#[test]
fn img_model_grad_and_eval() {
    // runs on the checked-in interpreter fixture: convolution,
    // reverse and reduce-window are in the interpreter's op set
    let (rt, man) = fixture();
    let (mut sess, _) = ModelSession::new(&rt, &man, "img_tiny").unwrap();
    let meta = sess.meta.clone();
    let n_px: usize = meta.tokens_shape.iter().product();
    let images: Vec<f32> = (0..n_px).map(|i| (i % 256) as f32 / 255.0).collect();
    let labels: Vec<i32> = (0..meta.batch).map(|i| (i % meta.n_classes) as i32).collect();
    let keep = vec![1.0f32; meta.n_layers];
    let (loss, grads) = sess
        .grad("grad_mix", &BatchInput::Images(&images), &labels, &keep, 0.1, 5)
        .unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!(grads.iter().any(|g| g.max_abs() > 0.0));
    let (sum_nll, correct) = sess
        .eval("eval", &BatchInput::Images(&images), &labels, &keep)
        .unwrap();
    let per = sum_nll / meta.batch as f64;
    assert!((per - (meta.n_classes as f64).ln()).abs() < 1.0, "{per}");
    assert!(correct <= meta.batch as f64);
}

// ------------------------------------------------- artifact-gated ---
// These need entries/models the tiny fixture does not carry; they run
// only against `make artifacts` output.

#[test]
fn int8_noise_entry_runs() {
    let Some(man) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let (mut sess, _) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let (tokens, targets) = lm_batch(&sess.meta);
    let keep = vec![1.0f32; sess.meta.n_layers];
    // int8 QAT (rate 1.0) at init should stay near the fp32 loss —
    // int8 rounding is mild (Table 1's int8 row barely degrades).
    let (l_fp, _) = sess
        .grad("grad_int8", &BatchInput::Tokens(&tokens), &targets, &keep, 0.0, 3)
        .unwrap();
    let (l_q, _) = sess
        .grad("grad_int8", &BatchInput::Tokens(&tokens), &targets, &keep, 1.0, 3)
        .unwrap();
    assert!((l_fp - l_q).abs() < 0.1, "int8 QAT loss jump: {l_fp} vs {l_q}");
}

#[test]
fn cls_model_eval() {
    let Some(man) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let (mut sess, _) = ModelSession::new(&rt, &man, "cls_tiny").unwrap();
    let meta = sess.meta.clone();
    let n = meta.batch * meta.seq_len;
    let tokens: Vec<i32> = (0..n).map(|i| (i % meta.vocab) as i32).collect();
    let labels: Vec<i32> = (0..meta.batch).map(|i| (i % meta.n_classes) as i32).collect();
    let keep = vec![1.0f32; meta.n_layers];
    let (sum_nll, _) = sess
        .eval("eval", &BatchInput::Tokens(&tokens), &labels, &keep)
        .unwrap();
    let per = sum_nll / meta.batch as f64;
    assert!((per - (meta.n_classes as f64).ln()).abs() < 0.5, "{per}");
}
