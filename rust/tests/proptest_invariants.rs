//! Property-based invariants over the coordinator substrate (in-repo
//! prop_check runner; proptest is not in the offline registry). Each
//! property runs over 100+ seeded cases with ramped sizes.

use quant_noise::quant::assign::{assign, assign_codes, assign_reference};
use quant_noise::quant::kmeans::{kmeans, KmeansConfig};
use quant_noise::quant::pq::{fit, mean_subvector_hat, PqConfig};
use quant_noise::quant::prune::{every_other_chunk_mask, flops_fraction, share_map, stored_layers};
use quant_noise::quant::scalar::{quant_mse, QParams};
use quant_noise::quant::scheme::{IntObserver, PqSpec, QuantSpec};
use quant_noise::quant::size::{param_bits, ParamInfo};
use quant_noise::util::rng::Pcg;
use quant_noise::util::testing::{gen_dim, prop_check, PropConfig, Size};

fn gen_weights(rng: &mut Pcg, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_normal() * (1.0 + rng.next_f32())).collect()
}

#[test]
fn prop_scalar_roundtrip_error_bound() {
    prop_check("scalar bound", PropConfig::default(), |rng, size| {
        let n = (gen_dim(rng, size) * 8).max(8);
        let w = gen_weights(rng, n);
        for bits in [2u8, 4, 8] {
            let qp = QParams::from_minmax(&w, bits);
            for &x in &w {
                let err = (x - qp.roundtrip_one(x)).abs();
                if err > qp.scale / 2.0 + 1e-4 {
                    return Err(format!("bits {bits}: err {err} > s/2 {}", qp.scale / 2.0));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scalar_mse_monotone_in_bits() {
    prop_check("mse monotone", PropConfig { cases: 64, ..Default::default() }, |rng, size| {
        let n = (gen_dim(rng, size) * 16).max(32);
        let w = gen_weights(rng, n);
        let mut last = f64::INFINITY;
        for bits in [2u8, 4, 6, 8] {
            let qp = QParams::from_minmax(&w, bits);
            let mse = quant_mse(&w, &qp);
            if mse > last + 1e-9 {
                return Err(format!("mse not monotone at {bits} bits: {mse} > {last}"));
            }
            last = mse;
        }
        Ok(())
    });
}

#[test]
fn prop_kmeans_objective_nonincreasing_and_assignments_valid() {
    prop_check("kmeans", PropConfig { cases: 40, ..Default::default() }, |rng, size| {
        let d = [2usize, 4, 8][rng.below(3) as usize];
        let n = (gen_dim(rng, size) + 2) * 8;
        let pts = gen_weights(rng, n * d);
        let k = 1 + rng.below(16) as usize;
        let r = kmeans(&pts, d, &KmeansConfig { k, max_iters: 6, tol: 0.0, threads: 2 }, rng);
        for w in r.objective_history.windows(2) {
            if w[1] > w[0] * (1.0 + 1e-5) + 1e-9 {
                return Err(format!("objective increased: {:?}", r.objective_history));
            }
        }
        if !r.assignments.iter().all(|&a| (a as usize) < r.k) {
            return Err("assignment out of range".into());
        }
        Ok(())
    });
}

#[test]
fn prop_pq_decode_error_le_variance() {
    // PQ with k-means can never be worse than assigning everything to
    // the mean (within slack): ‖W−Ŵ‖² ≤ Σ‖w−mean‖² · (1+ε)
    prop_check("pq vs mean", PropConfig { cases: 30, ..Default::default() }, |rng, size| {
        let rows = (gen_dim(rng, size) + 1) * 4;
        let cols = 16;
        let w = gen_weights(rng, rows * cols);
        let cfg = PqConfig { block_size: 8, n_centroids: 8, kmeans_iters: 6, threads: 0 };
        let m = fit(&w, rows, cols, &cfg, rng);
        let err = m.objective(&w);
        let mean = w.iter().sum::<f32>() / w.len() as f32;
        let var: f64 = w.iter().map(|&x| ((x - mean) as f64).powi(2)).sum();
        if err > var * 1.01 + 1e-6 {
            return Err(format!("pq err {err} > total variance {var}"));
        }
        Ok(())
    });
}

#[test]
fn prop_mean_hat_preserves_subvector_means() {
    prop_check("mean hat", PropConfig { cases: 60, ..Default::default() }, |rng, size| {
        let rows = gen_dim(rng, size).max(1);
        let d = [2usize, 4, 8][rng.below(3) as usize];
        let cols = d * (1 + rng.below(6) as usize);
        let w = gen_weights(rng, rows * cols);
        let hat = mean_subvector_hat(&w, rows, cols, d);
        for s in 0..w.len() / d {
            let m_orig: f32 = w[s * d..(s + 1) * d].iter().sum::<f32>() / d as f32;
            let m_hat: f32 = hat[s * d..(s + 1) * d].iter().sum::<f32>() / d as f32;
            if (m_orig - m_hat).abs() > 1e-4 * (1.0 + m_orig.abs()) {
                return Err(format!("subvector {s}: mean {m_orig} vs {m_hat}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sharing_pruning_composition() {
    prop_check("share/prune", PropConfig { cases: 100, ..Default::default() }, |rng, _| {
        let n = 1 + rng.below(16) as usize;
        let chunk = 1 + rng.below(3) as usize;
        let map = share_map(n, chunk);
        // canonical of canonical is itself; canonical ≤ layer
        for l in 0..n {
            if map[map[l]] != map[l] || map[l] > l {
                return Err(format!("bad share map {map:?}"));
            }
        }
        let keep = every_other_chunk_mask(n, chunk);
        let stored = stored_layers(n, chunk, &keep);
        // stored layers are exactly the kept canonical layers
        for l in 0..n {
            let expect = map[l] == l && keep[l] > 0.0;
            if stored[l] != expect {
                return Err(format!("stored {stored:?} keep {keep:?} map {map:?}"));
            }
        }
        let f = flops_fraction(&keep);
        if !(0.0..=1.0).contains(&f) {
            return Err(format!("flops fraction {f}"));
        }
        Ok(())
    });
}

#[test]
fn prop_size_accounting_additive_and_positive() {
    prop_check("size", PropConfig { cases: 80, ..Default::default() }, |rng, size| {
        let rows = (gen_dim(rng, size) + 1) * 8;
        let cols = 64;
        let p = ParamInfo {
            name: "w".into(),
            structure: "ffn".into(),
            numel: rows * cols,
            rows,
            cols,
            quantized: true,
            pq_block: 8,
        };
        for scheme in [
            QuantSpec::None,
            QuantSpec::int(4, IntObserver::MinMax),
            QuantSpec::int(8, IntObserver::MinMax),
            QuantSpec::pq(64),
            QuantSpec::Pq(PqSpec { codebook_bits: Some(8), ..PqSpec::new(64) }),
        ] {
            let bits = param_bits(&p, &scheme);
            if bits == 0 {
                return Err(format!("zero bits under {scheme:?}"));
            }
            if bits > 32 * p.numel as u64 && !matches!(scheme, QuantSpec::None) {
                // compression never exceeds fp32 except tiny-matrix PQ
                // codebook overhead, allowed only when numel is small
                if p.numel > 64 * 8 * 4 {
                    return Err(format!("{scheme:?} bigger than fp32 on large matrix"));
                }
            }
        }
        Ok(())
    });
}

fn dist2(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| (x - y) * (x - y)).sum()
}

#[test]
fn prop_assign_engine_bit_identical_across_thread_counts() {
    // The parallel engine must reproduce the single-threaded scalar
    // reference exactly — codes and distances — for any sharding,
    // including n < threads and K > n.
    prop_check("assign engine", PropConfig { cases: 60, ..Default::default() }, |rng, size| {
        let d = [1usize, 2, 3, 4, 7, 8][rng.below(6) as usize];
        let n = 1 + gen_dim(rng, size) * 3;
        let k = 1 + rng.below(80) as usize;
        let pts = gen_weights(rng, n * d);
        let cbs = gen_weights(rng, k * d);
        let reference = assign_reference(&pts, d, &cbs, k);
        for threads in [1usize, 2, 5, 16, 64] {
            let got = assign(&pts, d, &cbs, k, threads);
            if got.codes != reference.codes {
                return Err(format!("codes diverge: n={n} d={d} k={k} threads={threads}"));
            }
            if got.dists != reference.dists {
                return Err(format!("dists diverge: n={n} d={d} k={k} threads={threads}"));
            }
            if assign_codes(&pts, d, &cbs, k, threads) != reference.codes {
                return Err(format!(
                    "codes-only path diverges: n={n} d={d} k={k} threads={threads}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_assign_engine_picks_nearest_codeword() {
    // Against the naive O(n·K·d) dist2 loop: the assigned codeword's
    // true squared distance must match the true minimum up to fp noise
    // (the decomposed metric may legitimately flip exact near-ties).
    prop_check("assign nearest", PropConfig { cases: 60, ..Default::default() }, |rng, size| {
        let d = [2usize, 4, 8][rng.below(3) as usize];
        let n = 1 + gen_dim(rng, size) * 2;
        let k = 1 + rng.below(32) as usize;
        let pts = gen_weights(rng, n * d);
        let cbs = gen_weights(rng, k * d);
        let got = assign(&pts, d, &cbs, k, 3);
        for i in 0..n {
            let p = &pts[i * d..(i + 1) * d];
            let assigned = dist2(p, &cbs[got.codes[i] as usize * d..][..d]);
            let best = (0..k)
                .map(|j| dist2(p, &cbs[j * d..(j + 1) * d]))
                .fold(f32::INFINITY, f32::min);
            if assigned > best + 1e-4 * (1.0 + best) {
                return Err(format!(
                    "point {i}: assigned d²={assigned} but true min is {best} (n={n} d={d} k={k})"
                ));
            }
            if (got.dists[i] - assigned).abs() > 1e-3 * (1.0 + assigned) {
                return Err(format!(
                    "point {i}: reported d²={} vs recomputed {assigned}",
                    got.dists[i]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pcg_below_is_in_range() {
    prop_check("pcg below", PropConfig { cases: 200, ..Default::default() }, |rng, _| {
        let n = 1 + rng.below(1000);
        let x = rng.below(n);
        if x >= n {
            return Err(format!("below({n}) returned {x}"));
        }
        Ok(())
    });
}
