//! CLI smoke tests: run the qn binary's cheap subcommands end-to-end.
//! (Training subcommands are covered by trainer_integration; here we
//! check the binary wiring, help paths and info output.)

use std::process::Command;

fn qn() -> Command {
    Command::new(env!("CARGO_BIN_EXE_qn"))
}

fn artifacts_present() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

#[test]
fn help_lists_subcommands() {
    let out = qn().output().unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    for sub in ["info", "train", "quantize", "eval", "e2e", "bench", "lint-plan"] {
        assert!(text.contains(sub), "missing {sub} in help: {text}");
    }
    assert!(out.status.success());
}

#[test]
fn unknown_option_fails_with_usage() {
    let out = qn().args(["train", "--bogus"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn info_reads_interp_fixture() {
    // `qn info` against the checked-in interpreter fixture: exercises
    // manifest loading through the binary with no artifacts present.
    let out = qn()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .args(["info", "--artifacts", "tests/fixtures/interp"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("lm_tiny"), "{text}");
}

#[test]
fn info_prints_models_and_entries() {
    if !artifacts_present() {
        eprintln!("SKIP cli info test");
        return;
    }
    let out = qn()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .args(["info"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("lm_tiny"));
    assert!(text.contains("grad_mix"));
    assert!(text.contains("eval"));
}

#[test]
fn lint_plan_passes_checked_in_fixture() {
    // the fixture entries must verify clean at every fusion setting;
    // the census (default run) must render without panicking
    let out = qn()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .args(["lint-plan", "tests/fixtures/interp/threefry_pin.hlo.txt"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("verified clean"), "{text}");
    assert!(text.contains("instructions by op"), "{text}");
}

#[test]
fn lint_plan_without_files_fails_with_usage() {
    let out = qn().args(["lint-plan"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("usage"), "{err}");
}

#[test]
fn bench_rejects_unknown_experiment() {
    if !artifacts_present() {
        return;
    }
    let out = qn()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .args(["bench", "--exp", "table99"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}
