//! Loop-fusion golden and property tests (DESIGN.md §4): the fused
//! planned executor (counted `while` superinstruction + native
//! threefry2x32 kernel + elementwise-chain superinstructions + sharded
//! fused reduces/elementwise) must be bit-identical to both the
//! fusion-disabled plan and the tree-walking oracle on the checked-in
//! `lm_tiny` fixture across threads {1, 3, 8}; near-miss loops and
//! chains (multi-use intermediates, dtype-reinterpreting
//! bitcast-convert) must fall back and still match; and the threefry
//! u32 trajectory is pinned to mirror-computed constants so the PRNG
//! can never drift across PRs.

use std::path::Path;

use quant_noise::model::params::ParamStore;
use quant_noise::runtime::interp::{
    ArrayValue, Buf, FusionStats, HloModule, Interp, Plan, PlanOptions, Value,
};
use quant_noise::runtime::manifest::Manifest;

fn fixture_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp")
}

fn f32v(dims: &[usize], data: Vec<f32>) -> Value {
    Value::Array(ArrayValue::new(dims.to_vec(), Buf::F32(data)).unwrap())
}

fn i32v(dims: &[usize], data: Vec<i32>) -> Value {
    Value::Array(ArrayValue::new(dims.to_vec(), Buf::S32(data)).unwrap())
}

fn u32v(dims: &[usize], data: Vec<u32>) -> Value {
    Value::Array(ArrayValue::new(dims.to_vec(), Buf::U32(data)).unwrap())
}

/// Exact structural + bitwise equality (f32 compared by bit pattern).
fn assert_bit_identical(a: &Value, b: &Value, path: &str) {
    match (a, b) {
        (Value::Tuple(xs), Value::Tuple(ys)) => {
            assert_eq!(xs.len(), ys.len(), "{path}: tuple arity");
            for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
                assert_bit_identical(x, y, &format!("{path}.{i}"));
            }
        }
        (Value::Array(x), Value::Array(y)) => {
            assert_eq!(x.dims, y.dims, "{path}: dims");
            match (&*x.buf, &*y.buf) {
                (Buf::F32(p), Buf::F32(q)) => {
                    for (i, (u, v)) in p.iter().zip(q).enumerate() {
                        assert_eq!(u.to_bits(), v.to_bits(), "{path}[{i}]");
                    }
                }
                (p, q) => assert_eq!(p, q, "{path}: buffer"),
            }
        }
        _ => panic!("{path}: array/tuple kind mismatch"),
    }
}

/// Oracle vs fused plan vs fusion-disabled plan on one module, across
/// thread counts — the noise byte-stability contract pre/post fusion.
fn assert_fused_matches(m: &HloModule, args: &[Value], label: &str) -> FusionStats {
    let golden = Interp::new(m).run_entry(args).unwrap();
    let fused = Plan::compile(m);
    let nofuse =
        Plan::compile_opts(m, PlanOptions { counted_loops: false, threefry: false, chains: false });
    let nf = nofuse.fusion_stats();
    assert_eq!((nf.counted_loops, nf.threefry_calls), (0, 0), "{label}: opts ignored");
    for threads in [1usize, 3, 8] {
        let got = fused.run_entry(args.to_vec(), threads).unwrap();
        assert_bit_identical(&got, &golden, &format!("{label}[fused,t={threads}]"));
        let got = nofuse.run_entry(args.to_vec(), threads).unwrap();
        assert_bit_identical(&got, &golden, &format!("{label}[nofuse,t={threads}]"));
    }
    fused.fusion_stats()
}

fn load_fixture_grad(rate: f32, seed: i32) -> (HloModule, Vec<Value>) {
    let dir = fixture_dir();
    let man = Manifest::load(&dir).expect("checked-in interp fixture must load");
    let meta = man.model("lm_tiny").unwrap().clone();
    let params = ParamStore::load_qnp1(&man.init_path(&meta)).unwrap();
    let n = meta.batch * meta.seq_len;
    let tokens: Vec<i32> = (0..n).map(|i| ((i * 7 + 3) % meta.vocab) as i32).collect();
    let targets: Vec<i32> = (0..n).map(|i| ((i * 5 + 1) % meta.vocab) as i32).collect();
    let keep = vec![1.0f32; meta.n_layers];
    let pvals: Vec<Value> =
        params.iter().map(|(_, t)| f32v(&t.shape, t.data.clone())).collect();
    let hvals: Vec<Value> =
        params.iter().map(|(_, t)| f32v(&t.shape, vec![0.0; t.data.len()])).collect();
    let mut args = pvals;
    args.extend(hvals);
    args.push(i32v(&meta.tokens_shape, tokens));
    args.push(i32v(&meta.targets_shape, targets));
    args.push(f32v(&[keep.len()], keep));
    args.push(f32v(&[], vec![rate]));
    args.push(i32v(&[], vec![seed]));
    let m = HloModule::parse_file(&man.hlo_path(&meta, "grad_mix").unwrap()).unwrap();
    (m, args)
}

#[test]
fn fixture_grad_fused_bit_identical_and_fully_fused() {
    // rate 0.5 samples the in-graph noise mask through every threefry
    // while-loop; fixed seed pins the mask byte-for-byte pre/post
    // fusion (the fusion-disabled plan is the pre-fusion executor)
    let (m, args) = load_fixture_grad(0.5, 42);
    let fs = assert_fused_matches(&m, &args, "grad_mix");
    // every jax threefry while in the fixture must take the fused path
    // — a generic_whiles regression here is a fallback storm
    assert_eq!(fs.generic_whiles, 0, "fallback storm: {fs:?}");
    assert!(fs.counted_loops >= 10, "{fs:?}");
    assert!(fs.threefry_calls >= 10, "{fs:?}");
    assert!(fs.fused_reduces > 0 && fs.fused_scatters > 0, "{fs:?}");
    // the elementwise-chain census: the grad graph is full of
    // single-use softmax/mask/noise cones, and every chain elides at
    // least one interior step
    assert!(fs.fused_chains > 0, "{fs:?}");
    assert!(fs.chain_steps >= fs.fused_chains, "{fs:?}");
}

#[test]
fn fixture_eval_fused_bit_identical_and_chained() {
    let dir = fixture_dir();
    let man = Manifest::load(&dir).unwrap();
    let meta = man.model("lm_tiny").unwrap().clone();
    let params = ParamStore::load_qnp1(&man.init_path(&meta)).unwrap();
    let n = meta.batch * meta.seq_len;
    let tokens: Vec<i32> = (0..n).map(|i| ((i * 7 + 3) % meta.vocab) as i32).collect();
    let targets: Vec<i32> = (0..n).map(|i| ((i * 5 + 1) % meta.vocab) as i32).collect();
    let keep = vec![1.0f32; meta.n_layers];
    let mut args: Vec<Value> =
        params.iter().map(|(_, t)| f32v(&t.shape, t.data.clone())).collect();
    args.push(i32v(&meta.tokens_shape, tokens));
    args.push(i32v(&meta.targets_shape, targets));
    args.push(f32v(&[keep.len()], keep));
    let m = HloModule::parse_file(&man.hlo_path(&meta, "eval").unwrap()).unwrap();
    let fs = assert_fused_matches(&m, &args, "eval");
    assert!(fs.fused_chains > 0 && fs.chain_steps >= fs.fused_chains, "{fs:?}");
}

#[test]
fn fixture_grad_second_seed_still_matches() {
    // a different (rate, seed) drives different mask bytes through the
    // same fused kernels
    let (m, args) = load_fixture_grad(1.0, 20260729);
    assert_fused_matches(&m, &args, "grad_mix@seed2");
}

// --------------------------------------------------- counted-loop unit ---

/// A counted loop with a *parameterized* start, so trip counts 4, 1
/// and 0 all exercise the trips = max(0, bound - start) logic.
const COUNTED: &str = "HloModule t\n\ncond.1 {\n  s.1 = (s32[], f32[2]) parameter(0)\n  \
    i.2 = s32[] get-tuple-element(s.1), index=0\n  n.3 = s32[] constant(4)\n  \
    ROOT lt.4 = pred[] compare(i.2, n.3), direction=LT\n}\n\nbody.1 {\n  \
    s.1 = (s32[], f32[2]) parameter(0)\n  i.2 = s32[] get-tuple-element(s.1), index=0\n  \
    v.3 = f32[2]{0} get-tuple-element(s.1), index=1\n  one.4 = s32[] constant(1)\n  \
    c.5 = f32[2]{0} constant({0.5, 0.25})\n  i2.6 = s32[] add(i.2, one.4)\n  \
    v2.7 = f32[2]{0} add(v.3, c.5)\n  ROOT t.8 = (s32[], f32[2]) tuple(i2.6, v2.7)\n}\n\n\
    ENTRY main.1 {\n  i0.1 = s32[] parameter(0)\n  v0.2 = f32[2]{0} parameter(1)\n  \
    st.3 = (s32[], f32[2]) tuple(i0.1, v0.2)\n  \
    ROOT w.4 = (s32[], f32[2]) while(st.3), condition=cond.1, body=body.1\n}\n";

#[test]
fn counted_loop_fuses_for_all_trip_counts() {
    let m = HloModule::parse_str(COUNTED).unwrap();
    for start in [0i32, 3, 4, 10, -2] {
        let args = vec![i32v(&[], vec![start]), f32v(&[2], vec![1.0, -1.0])];
        let fs = assert_fused_matches(&m, &args, &format!("counted[start={start}]"));
        assert_eq!((fs.counted_loops, fs.generic_whiles), (1, 0), "start={start}");
    }
}

#[test]
fn near_miss_loops_fall_back_and_still_match() {
    // per-variant starts are chosen so the generic loop terminates
    // under that variant's actual semantics
    let cases: Vec<(&str, String, Vec<i32>)> = vec![
        (
            "non-unit step",
            COUNTED.replace("one.4 = s32[] constant(1)", "one.4 = s32[] constant(2)"),
            vec![0, 3, 4, 10],
        ),
        (
            // cond false immediately for every start below the bound
            "GE direction",
            COUNTED.replace("direction=LT", "direction=GE"),
            vec![-5, 0, 3],
        ),
        (
            // bound reads the counter itself: i < i is always false
            "non-constant bound",
            COUNTED.replace(
                "n.3 = s32[] constant(4)",
                "n.3 = s32[] get-tuple-element(s.1), index=0",
            ),
            vec![0, 3, 10],
        ),
        (
            // counter doubles instead of incrementing (start > 0 so the
            // generic loop still terminates)
            "counter not add(i, 1)",
            COUNTED.replace(
                "i2.6 = s32[] add(i.2, one.4)",
                "two.9 = s32[] constant(2)\n  i2.6 = s32[] multiply(i.2, two.9)",
            ),
            vec![1, 3, 4, 10],
        ),
    ];
    for (label, text, starts) in cases {
        let m = HloModule::parse_str(&text).unwrap();
        for start in starts {
            let args = vec![i32v(&[], vec![start]), f32v(&[2], vec![0.5, 2.0])];
            let fs = assert_fused_matches(&m, &args, &format!("{label}[{start}]"));
            assert_eq!(
                (fs.counted_loops, fs.generic_whiles),
                (0, 1),
                "{label} must fall back"
            );
        }
    }
}

// -------------------------------------------------------- threefry pin ---

/// The jax threefry while (regions verbatim from the fixture, lanes=1)
/// with the expected u32 outputs computed by the validated reference
/// mirror (`tools/qnsim/plan_mirror.py check_threefry_pin`). Integer
/// arithmetic only, so these constants are platform-exact — if the
/// counted-loop or threefry kernels ever drift from jax semantics,
/// this pins the break to the PRNG.
const THREEFRY_PIN: &str = include_str!("fixtures/interp/threefry_pin.hlo.txt");

#[test]
fn threefry_pin_exact_u32_trajectory() {
    let m = HloModule::parse_str(THREEFRY_PIN).unwrap();
    let args = vec![
        u32v(&[1], vec![0x1BD1_1BDA]),
        u32v(&[1], vec![0xDEAD_BEEF]),
        u32v(&[], vec![42]),
        u32v(&[], vec![7]),
        u32v(&[], vec![0x1BD1_1BDA ^ 42 ^ 7]),
    ];
    let fs = assert_fused_matches(&m, &args, "threefry_pin");
    assert_eq!((fs.counted_loops, fs.threefry_calls), (1, 1), "{fs:?}");
    let plan = Plan::compile(&m);
    let out = plan.run_entry(args, 1).unwrap();
    let parts = out.tuple().unwrap();
    let x0 = parts[0].array().unwrap().as_u32().unwrap().to_vec();
    let x1 = parts[1].array().unwrap().as_u32().unwrap().to_vec();
    assert_eq!(x0, vec![0xE129_A3F2], "x0 after 5 fused round groups");
    assert_eq!(x1, vec![0xCDA2_7419], "x1 after 5 fused round groups");
}

// -------------------------------------------------- elementwise chains ---

/// exp feeds both a multiply and a compare (diamond): the multi-use
/// exp must stay an external materialized input of the chain while the
/// single-use multiply/compare/select and the broadcast-of-scalar are
/// elided.
const DIAMOND: &str = "HloModule t\n\nENTRY main.1 {\n  x.1 = f32[64]{0} parameter(0)\n  \
    c.2 = f32[] constant(2)\n  b.3 = f32[64]{0} broadcast(c.2), dimensions={}\n  \
    e.4 = f32[64]{0} exponential(x.1)\n  m.5 = f32[64]{0} multiply(e.4, b.3)\n  \
    p.6 = pred[64]{0} compare(x.1, e.4), direction=LT\n  \
    ROOT s.7 = f32[64]{0} select(p.6, m.5, x.1)\n}\n";

#[test]
fn multi_use_intermediate_stays_external_and_matches() {
    let m = HloModule::parse_str(DIAMOND).unwrap();
    let data: Vec<f32> = (0..64).map(|i| (i as f32 - 31.5) / 16.0).collect();
    let args = vec![f32v(&[64], data)];
    let fs = assert_fused_matches(&m, &args, "diamond");
    // one chain rooting the select; the multi-use exp executes
    // standalone (3 elided: folded broadcast + multiply + compare)
    assert_eq!((fs.fused_chains, fs.chain_steps), (1, 3), "{fs:?}");
}

/// bitcast-convert reinterprets the payload across dtypes and is never
/// a chain member: the u32 adds below it and the f32 cone above it
/// stay separate, and the plan still bit-matches the oracle.
const BITCAST: &str = "HloModule t\n\nENTRY main.1 {\n  x.1 = u32[64]{0} parameter(0)\n  \
    a.2 = u32[64]{0} add(x.1, x.1)\n  b.3 = f32[64]{0} bitcast-convert(a.2)\n  \
    m.4 = f32[64]{0} multiply(b.3, b.3)\n  ROOT n.5 = f32[64]{0} negate(m.4)\n}\n";

#[test]
fn dtype_crossing_bitcast_is_not_elided_and_matches() {
    let m = HloModule::parse_str(BITCAST).unwrap();
    // payloads that reinterpret to finite f32 values
    let data: Vec<u32> = (0..64).map(|i| 0x3F00_0000 + (i as u32) * 0x0001_0001).collect();
    let args = vec![u32v(&[64], data)];
    let fs = assert_fused_matches(&m, &args, "bitcast");
    // only multiply+negate chain; the add is a lone step below the
    // bitcast boundary and executes standalone
    assert_eq!((fs.fused_chains, fs.chain_steps), (1, 1), "{fs:?}");
}

// ------------------------------------------------------- shard scaling ---

/// Fused reduces (contiguous + strided) and elementwise chains large
/// enough to engage worker sharding; bit-identity across {1, 3, 8}
/// threads is asserted by `assert_fused_matches`.
const BIG: &str = "HloModule big\n\nsum.1 {\n  a.1 = f32[] parameter(0)\n  \
    b.2 = f32[] parameter(1)\n  ROOT add.3 = f32[] add(a.1, b.2)\n}\n\n\
    ENTRY main.1 {\n  x.1 = f32[96,128]{1,0} parameter(0)\n  \
    z.2 = f32[] constant(0)\n  r.3 = f32[96]{0} reduce(x.1, z.2), dimensions={1}, \
    to_apply=sum.1\n  rs.4 = f32[128]{0} reduce(x.1, z.2), dimensions={0}, \
    to_apply=sum.1\n  e.5 = f32[96,128]{1,0} exponential(x.1)\n  \
    m.6 = f32[96,128]{1,0} multiply(e.5, x.1)\n  \
    p.7 = pred[96,128]{1,0} compare(x.1, e.5), direction=LT\n  \
    s.8 = f32[96,128]{1,0} select(p.7, m.6, x.1)\n  \
    ROOT t.9 = (f32[96]{0}, f32[128]{0}, f32[96,128]{1,0}) tuple(r.3, rs.4, s.8)\n}\n";

#[test]
fn sharded_reduce_and_elementwise_bit_identical_across_threads() {
    let m = HloModule::parse_str(BIG).unwrap();
    let n = 96 * 128;
    let data: Vec<f32> = (0..n).map(|i| ((i * 37 % 501) as f32 - 250.0) / 83.0).collect();
    let args = vec![f32v(&[96, 128], data)];
    let fs = assert_fused_matches(&m, &args, "big");
    // 12288 elements puts the select-rooted chain on the sharded path
    assert!(fs.fused_chains >= 1, "{fs:?}");
}
