//! End-to-end step latency through the PJRT runtime: grad step with
//! noise off / Quant-Noise proxy / QAT / int8 noise, plus eval
//! throughput. Validates the paper's "<5% training overhead" claim at
//! our scale (Table: train_step). Requires `make artifacts`.
use quant_noise::runtime::client::Runtime;
use quant_noise::runtime::executable::{BatchInput, ModelSession};
use quant_noise::runtime::manifest::Manifest;
use quant_noise::util::bench::Bencher;

fn main() {
    let dir_s = std::env::var("QN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let dir = std::path::Path::new(&dir_s);
    let Ok(man) = Manifest::load(dir) else {
        eprintln!("SKIP train_step bench: run `make artifacts` first");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let (mut sess, _params) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    let meta = sess.meta.clone();
    let n = meta.batch * meta.seq_len;
    let tokens: Vec<i32> = (0..n).map(|i| (i % meta.vocab) as i32).collect();
    let targets: Vec<i32> = (0..n).map(|i| ((i + 1) % meta.vocab) as i32).collect();
    let keep = vec![1.0f32; meta.n_layers];

    // compile outside the timed region
    for e in ["grad_mix", "grad_int8", "eval"] {
        sess.warmup(e).unwrap();
    }

    let mut b = Bencher::default();
    b.budget = std::time::Duration::from_secs(4);
    println!(
        "--- train_step (lm_tiny, B={} T={}, artifacts={dir_s}) ---",
        meta.batch, meta.seq_len
    );
    let mut seed = 0;
    let base = b
        .bench("grad: noise off (rate 0)", || {
            seed += 1;
            sess.grad("grad_mix", &BatchInput::Tokens(&tokens), &targets, &keep, 0.0, seed)
                .unwrap()
                .0
        })
        .median_ns;
    let qn = b
        .bench("grad: Quant-Noise proxy p=0.1", || {
            seed += 1;
            sess.grad("grad_mix", &BatchInput::Tokens(&tokens), &targets, &keep, 0.1, seed)
                .unwrap()
                .0
        })
        .median_ns;
    b.bench("grad: QAT (rate 1.0)", || {
        seed += 1;
        sess.grad("grad_mix", &BatchInput::Tokens(&tokens), &targets, &keep, 1.0, seed)
            .unwrap()
            .0
    });
    b.bench("grad: int8 noise p=0.5", || {
        seed += 1;
        sess.grad("grad_int8", &BatchInput::Tokens(&tokens), &targets, &keep, 0.5, seed)
            .unwrap()
            .0
    });
    b.bench("eval pass", || {
        sess.eval("eval", &BatchInput::Tokens(&tokens), &targets, &keep).unwrap().0
    });
    let overhead = (qn / base - 1.0) * 100.0;
    println!(
        "\nQuant-Noise overhead vs noise-off: {overhead:+.1}% (paper claims < 5% — \
         the mask+mix runs in-graph either way, rate only gates the select)"
    );
}
