//! Micro-scale end-to-end pipeline bench: wall-clock of each table1
//! pipeline stage (train steps, k-means quantization, iPQ finetune
//! steps, eval) on the tiny LM. Requires `make artifacts`.
use quant_noise::bench_harness::common::Workbench;
use quant_noise::bench_harness::specs::{base_train, with_noise};
use quant_noise::coordinator::ipq::post_pq;
use quant_noise::coordinator::trainer::Trainer;
use quant_noise::quant::scheme::QuantSpec;
use quant_noise::util::bench::Bencher;

fn main() {
    let Ok(wb) = Workbench::new(std::path::Path::new("artifacts")) else {
        eprintln!("SKIP tables bench: run `make artifacts` first");
        return;
    };
    let mut lab = wb.lab("lm_tiny").unwrap();
    let mut b = Bencher::quick();
    b.budget = std::time::Duration::from_secs(6);
    println!("--- table pipeline stages (lm_tiny) ---");

    let cfg = with_noise(base_train("lm", 4), QuantSpec::Proxy, 0.1);
    let init = lab.init.clone();
    b.bench("train: 4 QN steps", || {
        let mut t = Trainer::new(&mut lab.sess, init.clone(), cfg.clone());
        t.train(lab.train_src.as_mut()).unwrap().final_loss
    });
    let params = lab.init.clone();
    b.bench("quantize: one-shot PQ k=64 (all layers)", || {
        post_pq(&params, &lab.sess.meta, &Default::default()).unwrap().bytes
    });
    let evb = lab.eval_batches.clone();
    b.bench("eval: 16 batches", || {
        lab.sess.upload_all_params(&params).unwrap();
        quant_noise::coordinator::evaluator::evaluate(
            &mut lab.sess,
            "eval",
            &evb,
            &[1.0, 1.0, 1.0, 1.0],
        )
        .unwrap()
        .ppl
    });
}
