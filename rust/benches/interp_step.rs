//! Interpreter step latency on the checked-in `lm_tiny` fixture:
//! the tree-walking reference evaluator vs the planned in-place
//! executor (1 thread / all cores) with and without loop fusion
//! (counted `while` + native threefry), plus deterministic
//! batch-sharded eval throughput and fused-reduce shard scaling, plus
//! the `img_tiny` conv grad/eval rows (`conv[direct]` + fused
//! reduce-window kernels), plus the paper-scale `lm_base`-shaped grad
//! step (1024-dim, 12-layer; `benches/fixtures/lm_base.grad.hlo.txt`)
//! isolating the blocked-dot microkernel (`dot_tile_speedup`) and the
//! elementwise-chain superinstructions (`chain_speedup_grad_1t`).
//! Runs with no artifacts and no Python.
//!
//! Emits a machine-readable `BENCH_interp.json` (path override:
//! `QN_BENCH_JSON`) so the perf trajectory is recorded per commit —
//! `make bench-interp` from the repo root; `QN_BENCH_QUICK=1` (or
//! `make bench-interp QUICK=1`) shrinks warmup/budget to a smoke run
//! so CI surfaces kernel-dispatch regressions (panics, fallback
//! storms) without paying for stable medians.

use std::path::Path;
use std::time::Duration;

use quant_noise::model::params::ParamStore;
use quant_noise::runtime::client::Runtime;
use quant_noise::runtime::executable::{BatchInput, ModelSession};
use quant_noise::runtime::interp::{
    ArrayValue, Buf, HloModule, Interp, Plan, PlanOptions, Value,
};
use quant_noise::runtime::manifest::Manifest;
use quant_noise::util::bench::Bencher;

/// A large fused reduce (contiguous + strided) for shard-scaling
/// numbers: 96x128 input, both axes reduced separately.
const BIG_REDUCE: &str = "HloModule big_reduce\n\nsum.1 {\n  a.1 = f32[] parameter(0)\n  \
    b.2 = f32[] parameter(1)\n  ROOT add.3 = f32[] add(a.1, b.2)\n}\n\n\
    ENTRY main.1 {\n  x.1 = f32[96,128]{1,0} parameter(0)\n  \
    z.2 = f32[] constant(0)\n  r.3 = f32[96]{0} reduce(x.1, z.2), dimensions={1}, \
    to_apply=sum.1\n  rs.4 = f32[128]{0} reduce(x.1, z.2), dimensions={0}, \
    to_apply=sum.1\n  ROOT t.5 = (f32[96]{0}, f32[128]{0}) tuple(r.3, rs.4)\n}\n";

fn f32v(dims: &[usize], data: Vec<f32>) -> Value {
    Value::Array(ArrayValue::new(dims.to_vec(), Buf::F32(data)).unwrap())
}

fn i32v(dims: &[usize], data: Vec<i32>) -> Value {
    Value::Array(ArrayValue::new(dims.to_vec(), Buf::S32(data)).unwrap())
}

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp");
    let man = Manifest::load(&dir).expect("checked-in interp fixture must load");
    let meta = man.model("lm_tiny").unwrap().clone();
    let params = ParamStore::load_qnp1(&man.init_path(&meta)).unwrap();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // fixed inputs (what the integration tests use)
    let n = meta.batch * meta.seq_len;
    let tokens: Vec<i32> = (0..n).map(|i| (i % meta.vocab) as i32).collect();
    let targets: Vec<i32> = (0..n).map(|i| ((i + 1) % meta.vocab) as i32).collect();
    let keep = vec![1.0f32; meta.n_layers];

    // raw argument vectors in manifest order (params are sorted)
    let pvals: Vec<Value> =
        params.iter().map(|(_, t)| f32v(&t.shape, t.data.clone())).collect();
    let hvals: Vec<Value> =
        params.iter().map(|(_, t)| f32v(&t.shape, vec![0.0; t.data.len()])).collect();
    let mut grad_args = pvals.clone();
    grad_args.extend(hvals);
    grad_args.push(i32v(&meta.tokens_shape, tokens.clone()));
    grad_args.push(i32v(&meta.targets_shape, targets.clone()));
    grad_args.push(f32v(&[keep.len()], keep.clone()));
    grad_args.push(f32v(&[], vec![0.1]));
    grad_args.push(i32v(&[], vec![42]));
    let mut eval_args = pvals;
    eval_args.push(i32v(&meta.tokens_shape, tokens.clone()));
    eval_args.push(i32v(&meta.targets_shape, targets.clone()));
    eval_args.push(f32v(&[keep.len()], keep.clone()));

    let grad_mod = HloModule::parse_file(&man.hlo_path(&meta, "grad_mix").unwrap()).unwrap();
    let eval_mod = HloModule::parse_file(&man.hlo_path(&meta, "eval").unwrap()).unwrap();
    let grad_plan = Plan::compile(&grad_mod);
    let eval_plan = Plan::compile(&eval_mod);
    let nofuse = PlanOptions { counted_loops: false, threefry: false, chains: false };
    let grad_plan_nofuse = Plan::compile_opts(&grad_mod, nofuse);
    let fs = grad_plan.fusion_stats();
    println!(
        "fusion census (grad_mix): {} counted loops, {} threefry call sites, \
         {} generic whiles, {} chains ({} steps)",
        fs.counted_loops, fs.threefry_calls, fs.generic_whiles, fs.fused_chains, fs.chain_steps
    );
    assert_eq!(fs.generic_whiles, 0, "fallback storm: a fixture while failed to fuse");
    assert!(fs.fused_chains > 0, "no elementwise chains fused in the lm grad plan");

    let quick = std::env::var("QN_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let mut b = Bencher::quick();
    if quick {
        b.warmup = Duration::from_millis(20);
        b.budget = Duration::from_millis(150);
        b.min_iters = 1;
    } else {
        b.warmup = Duration::from_millis(200);
        b.budget = Duration::from_secs(2);
        b.min_iters = 3;
    }

    println!("--- interp step (lm_tiny fixture, B={} T={}) ---", meta.batch, meta.seq_len);
    let mut rec: Vec<(String, f64)> = Vec::new();
    let mut run = |b: &mut Bencher, key: &str, name: &str, f: &mut dyn FnMut() -> Value| {
        let ns = b.bench(name, f).median_ns;
        rec.push((key.to_string(), ns));
        ns
    };

    let gm_tree = run(&mut b, "grad_mix_tree_walk_ns", "grad_mix: tree-walk evaluator", &mut || {
        Interp::new(&grad_mod).run_entry(&grad_args).unwrap()
    });
    let gm_nofuse = run(
        &mut b,
        "grad_mix_planned_nofuse_1t_ns",
        "grad_mix: planned, no loop fusion, 1 thread",
        &mut || grad_plan_nofuse.run_entry(grad_args.clone(), 1).unwrap(),
    );
    let gm_1t = run(&mut b, "grad_mix_planned_1t_ns", "grad_mix: planned+fused, 1 thread", &mut || {
        grad_plan.run_entry(grad_args.clone(), 1).unwrap()
    });
    let gm_mt =
        run(&mut b, "grad_mix_planned_mt_ns", "grad_mix: planned+fused, all cores", &mut || {
            grad_plan.run_entry(grad_args.clone(), cores).unwrap()
        });
    let ev_tree = run(&mut b, "eval_tree_walk_ns", "eval: tree-walk evaluator", &mut || {
        Interp::new(&eval_mod).run_entry(&eval_args).unwrap()
    });
    let ev_1t = run(&mut b, "eval_planned_1t_ns", "eval: planned, 1 thread", &mut || {
        eval_plan.run_entry(eval_args.clone(), 1).unwrap()
    });

    // img_tiny: the conv forward plus both conv grad forms
    // (reversed-kernel input grad, batch-group weight grad) through
    // the same three executors
    let imeta = man.model("img_tiny").unwrap().clone();
    let iparams = ParamStore::load_qnp1(&man.init_path(&imeta)).unwrap();
    let n_px: usize = imeta.tokens_shape.iter().product();
    let images: Vec<f32> = (0..n_px).map(|i| (i % 256) as f32 / 255.0).collect();
    let ilabels: Vec<i32> =
        (0..imeta.batch).map(|i| (i % imeta.n_classes) as i32).collect();
    let ikeep = vec![1.0f32; imeta.n_layers];
    let ipvals: Vec<Value> =
        iparams.iter().map(|(_, t)| f32v(&t.shape, t.data.clone())).collect();
    let mut ig_args = ipvals.clone();
    ig_args.extend(iparams.iter().map(|(_, t)| f32v(&t.shape, vec![0.0; t.data.len()])));
    ig_args.push(f32v(&imeta.tokens_shape, images.clone()));
    ig_args.push(i32v(&imeta.targets_shape, ilabels.clone()));
    ig_args.push(f32v(&[ikeep.len()], ikeep.clone()));
    ig_args.push(f32v(&[], vec![0.1]));
    ig_args.push(i32v(&[], vec![42]));
    let mut ie_args = ipvals;
    ie_args.push(f32v(&imeta.tokens_shape, images));
    ie_args.push(i32v(&imeta.targets_shape, ilabels));
    ie_args.push(f32v(&[ikeep.len()], ikeep));
    let ig_mod = HloModule::parse_file(&man.hlo_path(&imeta, "grad_mix").unwrap()).unwrap();
    let ie_mod = HloModule::parse_file(&man.hlo_path(&imeta, "eval").unwrap()).unwrap();
    let ig_plan = Plan::compile(&ig_mod);
    let ie_plan = Plan::compile(&ie_mod);
    let ifs = ig_plan.fusion_stats();
    assert_eq!(ifs.generic_whiles, 0, "fallback storm: an img fixture while failed to fuse");
    println!("--- img conv step (img_tiny fixture, B={}) ---", imeta.batch);
    let ig_tree =
        run(&mut b, "img_grad_tree_walk_ns", "img grad_mix: tree-walk evaluator", &mut || {
            Interp::new(&ig_mod).run_entry(&ig_args).unwrap()
        });
    let ig_1t =
        run(&mut b, "img_grad_planned_1t_ns", "img grad_mix: planned+fused, 1 thread", &mut || {
            ig_plan.run_entry(ig_args.clone(), 1).unwrap()
        });
    let ig_mt =
        run(&mut b, "img_grad_planned_mt_ns", "img grad_mix: planned+fused, all cores", &mut || {
            ig_plan.run_entry(ig_args.clone(), cores).unwrap()
        });
    let ie_1t = run(&mut b, "img_eval_planned_1t_ns", "img eval: planned, 1 thread", &mut || {
        ie_plan.run_entry(ie_args.clone(), 1).unwrap()
    });

    // paper-scale lm_base-shaped grad step: 1024-dim, 12-layer residual
    // MLP stack with a hand-derived backward (36 [B,D]x[D,D] dots + one
    // elementwise chain per layer per direction). The module is checked
    // in; `make fixture` / tools/qnsim/gen_lm_base.py regenerates it.
    // Weights are synthesized here — no training, no Python.
    let base_path =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("benches/fixtures/lm_base.grad.hlo.txt");
    let base_mod = HloModule::parse_file(&base_path).expect("checked-in lm_base bench fixture");
    let base_plan = Plan::compile(&base_mod);
    let base_nochain =
        Plan::compile_opts(&base_mod, PlanOptions { chains: false, ..PlanOptions::default() });
    let bfs = base_plan.fusion_stats();
    assert!(bfs.fused_chains > 0, "no elementwise chains fused in the lm_base grad plan");
    let (bb, bd, bl) = (8usize, 1024usize, 12usize);
    let mut base_args: Vec<Value> = Vec::with_capacity(1 + 2 * bl);
    base_args.push(f32v(
        &[bb, bd],
        (0..bb * bd).map(|i| (i % 97) as f32 / 97.0 - 0.5).collect(),
    ));
    for l in 0..bl {
        base_args.push(f32v(
            &[bd, bd],
            (0..bd * bd).map(|i| (((i * 31 + l) % 113) as f32 / 113.0 - 0.5) * 0.02).collect(),
        ));
        base_args.push(f32v(&[bd], (0..bd).map(|i| ((i + l) % 7) as f32 / 7.0 - 0.5).collect()));
    }
    println!(
        "--- paper-scale lm_base grad step (D={bd}, L={bl}, B={bb}; \
         {} chains / {} captured steps) ---",
        bfs.fused_chains, bfs.chain_steps
    );
    let lb_1t =
        run(&mut b, "lm_base_grad_1t_ns", "lm_base grad: planned+fused, 1 thread", &mut || {
            base_plan.run_entry(base_args.clone(), 1).unwrap()
        });
    let lb_mt =
        run(&mut b, "lm_base_grad_mt_ns", "lm_base grad: planned+fused, all cores", &mut || {
            base_plan.run_entry(base_args.clone(), cores).unwrap()
        });
    let lb_nochain = run(
        &mut b,
        "lm_base_grad_nochain_1t_ns",
        "lm_base grad: chains disabled, 1 thread",
        &mut || base_nochain.run_entry(base_args.clone(), 1).unwrap(),
    );

    // blocked-dot microkernel vs the scalar ops::dot path the tree-walk
    // evaluator dispatches, isolated on one paper-dim [B,D]x[D,D] dot
    let dot_txt = format!(
        "HloModule dot_tile\n\nENTRY main.1 {{\n  \
         x.1 = f32[{bb},{bd}]{{1,0}} parameter(0)\n  \
         w.2 = f32[{bd},{bd}]{{1,0}} parameter(1)\n  \
         ROOT dot.3 = f32[{bb},{bd}]{{1,0}} dot(x.1, w.2), \
         lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n}}\n"
    );
    let dot_mod = HloModule::parse_str(&dot_txt).unwrap();
    let dot_plan = Plan::compile(&dot_mod);
    let dot_args = vec![base_args[0].clone(), base_args[1].clone()];
    let dt_scalar =
        run(&mut b, "dot_scalar_ref_ns", "paper-dim dot: scalar ops::dot (tree-walk)", &mut || {
            Interp::new(&dot_mod).run_entry(&dot_args).unwrap()
        });
    let dt_tile =
        run(&mut b, "dot_tile_1t_ns", "paper-dim dot: blocked microkernel, 1 thread", &mut || {
            dot_plan.run_entry(dot_args.clone(), 1).unwrap()
        });

    // fused-reduce shard scaling on a synthetic large reduce
    let big_mod = HloModule::parse_str(BIG_REDUCE).unwrap();
    let big_plan = Plan::compile(&big_mod);
    let big_args =
        vec![f32v(&[96, 128], (0..96 * 128).map(|i| (i % 97) as f32 - 48.0).collect())];
    let rd_1t = run(&mut b, "reduce_shard_1t_ns", "big fused reduce: 1 thread", &mut || {
        big_plan.run_entry(big_args.clone(), 1).unwrap()
    });
    let rd_mt = run(&mut b, "reduce_shard_mt_ns", "big fused reduce: all cores", &mut || {
        big_plan.run_entry(big_args.clone(), cores).unwrap()
    });

    // batch-sharded eval through the full runtime seam (macro-batch M=8)
    let m = 8usize;
    let rt = Runtime::interp();
    let (mut sess, _init) = ModelSession::new(&rt, &man, "lm_tiny").unwrap();
    sess.warmup("eval").unwrap();
    let macro_tokens: Vec<i32> = (0..m).flat_map(|_| tokens.iter().copied()).collect();
    let macro_targets: Vec<i32> = (0..m).flat_map(|_| targets.iter().copied()).collect();
    println!("--- batch-sharded eval (M={m} shards) ---");
    let mut bench_batched = |b: &mut Bencher, name: &str, threads: usize| {
        rt.set_threads(threads);
        b.bench(name, || {
            sess.eval_batched("eval", &BatchInput::Tokens(&macro_tokens), &macro_targets, &keep)
                .unwrap()
        })
        .median_ns
            / m as f64
    };
    let eb_1t = bench_batched(&mut b, "eval x8 batched, 1 thread (per step)", 1);
    let eb_mt = bench_batched(&mut b, "eval x8 batched, all cores (per step)", 0);
    rec.push(("eval_batched_per_step_1t_ns".into(), eb_1t));
    rec.push(("eval_batched_per_step_mt_ns".into(), eb_mt));

    let speedup_grad = gm_tree / gm_1t;
    let speedup_eval = ev_tree / ev_1t;
    let fuse_speedup_grad = gm_nofuse / gm_1t;
    let reduce_scaling = rd_1t / rd_mt;
    let scaling = eb_1t / eb_mt;
    let chain_speedup_grad = lb_nochain / lb_1t;
    let dot_tile_speedup = dt_scalar / dt_tile;
    println!(
        "lm_base (paper-scale): grad step {:.1}ms 1t / {:.1}ms all-cores; \
         chain superinstructions {chain_speedup_grad:.2}x vs chains-off; \
         blocked dot {dot_tile_speedup:.2}x vs scalar ops::dot",
        lb_1t / 1e6,
        lb_mt / 1e6
    );
    println!(
        "\nplanned vs tree-walk (1 thread): grad_mix {speedup_grad:.2}x, eval {speedup_eval:.2}x"
    );
    println!(
        "loop fusion (counted while + native threefry): grad_mix \
         {fuse_speedup_grad:.2}x vs the unfused plan"
    );
    println!(
        "batch sharding: {scaling:.2}x per-step on {cores} cores \
         (grad_mix all-cores: {:.2}x vs tree-walk); \
         fused-reduce sharding: {reduce_scaling:.2}x",
        gm_tree / gm_mt
    );
    println!(
        "img conv: grad_mix {:.2}x vs tree-walk (1 thread), all-cores {:.2}x, \
         eval planned {:.1}ms; {} fused windows in the grad plan",
        ig_tree / ig_1t,
        ig_tree / ig_mt,
        ie_1t / 1e6,
        ifs.fused_windows
    );

    // machine-readable record for the perf trajectory
    let mut json = String::from("{\n  \"fixture\": \"lm_tiny+img_tiny\",\n");
    json.push_str(&format!("  \"cores\": {cores},\n  \"batch_shards\": {m},\n"));
    json.push_str(&format!(
        "  \"quick\": {quick},\n  \"counted_loops\": {},\n  \"threefry_call_sites\": {},\n",
        fs.counted_loops, fs.threefry_calls
    ));
    json.push_str(&format!(
        "  \"fused_chains\": {},\n  \"chain_steps\": {},\n  \"lm_base_fused_chains\": {},\n",
        fs.fused_chains, fs.chain_steps, bfs.fused_chains
    ));
    for (k, v) in &rec {
        json.push_str(&format!("  \"{k}\": {v:.1},\n"));
    }
    json.push_str(&format!(
        "  \"speedup_grad_1t\": {speedup_grad:.3},\n  \"speedup_eval_1t\": {speedup_eval:.3},\n"
    ));
    json.push_str(&format!(
        "  \"fuse_speedup_grad_1t\": {fuse_speedup_grad:.3},\n  \
         \"reduce_shard_scaling\": {reduce_scaling:.3},\n"
    ));
    json.push_str(&format!(
        "  \"img_speedup_grad_1t\": {:.3},\n  \"img_fused_windows\": {},\n",
        ig_tree / ig_1t,
        ifs.fused_windows
    ));
    json.push_str(&format!(
        "  \"chain_speedup_grad_1t\": {chain_speedup_grad:.3},\n  \
         \"dot_tile_speedup\": {dot_tile_speedup:.3},\n"
    ));
    json.push_str(&format!("  \"batch_scaling\": {scaling:.3}\n}}\n"));
    let out = std::env::var("QN_BENCH_JSON").unwrap_or_else(|_| "BENCH_interp.json".into());
    std::fs::write(&out, json).unwrap();
    println!("wrote {out}");
}
