//! Component micro-benchmarks: k-means fit, PQ encode/decode, scalar
//! round-trips, histogram observer, size accounting. Uses the in-repo
//! bench harness (criterion is not in the offline registry).
use quant_noise::quant::kmeans::{kmeans, KmeansConfig};
use quant_noise::quant::observer::HistogramObserver;
use quant_noise::quant::pq::{encode, fit, PqConfig};
use quant_noise::quant::scalar::{self, QParams};
use quant_noise::util::bench::Bencher;
use quant_noise::util::rng::Pcg;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Pcg::new(1);
    // a realistic FFN matrix from the tiny LM: 512×128
    let w: Vec<f32> = (0..512 * 128).map(|_| rng.next_normal()).collect();

    println!("--- quant_ops (512x128 f32 weight) ---");
    b.bench("kmeans k=64 d=8 (8192 subvectors, 10 iters)", || {
        kmeans(&w, 8, &KmeansConfig { k: 64, max_iters: 10, ..Default::default() }, &mut Pcg::new(2))
    });
    let cfg = PqConfig { block_size: 8, n_centroids: 64, kmeans_iters: 10 };
    let pq = fit(&w, 512, 128, &cfg, &mut Pcg::new(3));
    b.bench("pq encode (existing codebook)", || encode(&w, 512, 128, &pq.codebook));
    b.bench("pq decode", || pq.decode());
    let qp = QParams::from_minmax(&w, 8);
    b.bench("int8 roundtrip", || {
        let mut d = w.clone();
        scalar::roundtrip(&mut d, &qp);
        d
    });
    b.bench("per-channel int4 roundtrip", || {
        let mut d = w.clone();
        scalar::roundtrip_per_channel(&mut d, 512, 128, 4);
        d
    });
    b.bench("histogram observe+qparams (2048 bins)", || {
        let mut h = HistogramObserver::new(2048);
        h.observe(&w);
        h.qparams(8)
    });
    b.bench("size accounting (43-param inventory)", || {
        let infos: Vec<_> = (0..43)
            .map(|i| quant_noise::quant::size::ParamInfo {
                name: format!("p{i}"),
                numel: 65536,
                rows: 512,
                cols: 128,
                quantized: i % 5 != 0,
                pq_block: 8,
            })
            .collect();
        quant_noise::quant::size::model_bytes(
            &infos,
            quant_noise::quant::size::Scheme::Pq { k: 256, int8_centroids: false },
        )
    });
}
