//! Component micro-benchmarks: k-means fit, PQ encode/decode, scalar
//! round-trips, histogram observer, size accounting, and the paper-
//! scale nearest-codeword assignment engine (seed scalar loop vs the
//! norm-decomposed parallel engine). Uses the in-repo bench harness
//! (criterion is not in the offline registry).
use std::time::Duration;

use quant_noise::quant::assign;
use quant_noise::quant::codebook::Codebook;
use quant_noise::quant::kmeans::{kmeans, KmeansConfig};
use quant_noise::quant::observer::HistogramObserver;
use quant_noise::quant::pq::{encode, encode_scalar, encode_with, fit, PqConfig};
use quant_noise::quant::scalar::{self, QParams};
use quant_noise::util::bench::Bencher;
use quant_noise::util::rng::Pcg;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Pcg::new(1);
    // a realistic FFN matrix from the tiny LM: 512×128
    let w: Vec<f32> = (0..512 * 128).map(|_| rng.next_normal()).collect();

    println!("--- quant_ops (512x128 f32 weight) ---");
    b.bench("kmeans k=64 d=8 (8192 subvectors, 10 iters)", || {
        let cfg = KmeansConfig { k: 64, max_iters: 10, ..Default::default() };
        kmeans(&w, 8, &cfg, &mut Pcg::new(2))
    });
    let cfg = PqConfig { block_size: 8, n_centroids: 64, kmeans_iters: 10, threads: 0 };
    let pq = fit(&w, 512, 128, &cfg, &mut Pcg::new(3));
    b.bench("pq encode (existing codebook)", || encode(&w, 512, 128, &pq.codebook));
    b.bench("pq decode", || pq.decode());
    let qp = QParams::from_minmax(&w, 8);
    b.bench("int8 roundtrip", || {
        let mut d = w.clone();
        scalar::roundtrip(&mut d, &qp);
        d
    });
    b.bench("per-channel int4 roundtrip", || {
        let mut d = w.clone();
        scalar::roundtrip_per_channel(&mut d, 512, 128, 4);
        d
    });
    b.bench("histogram observe+qparams (2048 bins)", || {
        let mut h = HistogramObserver::new(2048);
        h.observe(&w);
        h.qparams(8)
    });
    b.bench("size accounting (43-param inventory)", || {
        let infos: Vec<_> = (0..43)
            .map(|i| quant_noise::quant::size::ParamInfo {
                name: format!("p{i}"),
                structure: "ffn".to_string(),
                numel: 65536,
                rows: 512,
                cols: 128,
                quantized: i % 5 != 0,
                pq_block: 8,
            })
            .collect();
        quant_noise::quant::size::model_bytes(
            &infos,
            &quant_noise::quant::scheme::QuantSpec::pq(256),
        )
    });

    // ---- paper-scale encode: the hat-refresh / iPQ hot path ----------
    // 1024×1024 weights, d=8, K=256 ⇒ 131072 subvectors × 256 codewords.
    // The seed re-encoded this with a single-threaded scalar loop; the
    // engine must win by ≥3× on a multi-core runner.
    let (rows, cols, d, k) = (1024usize, 1024usize, 8usize, 256usize);
    let mut rng = Pcg::new(42);
    let big: Vec<f32> = (0..rows * cols).map(|_| rng.next_normal()).collect();
    let cb = Codebook::new((0..k * d).map(|_| rng.next_normal()).collect(), k, d);

    // (field-by-field: `results` is private, so no struct literal here)
    let mut be = Bencher::quick();
    be.warmup = Duration::from_millis(100);
    be.budget = Duration::from_millis(1500);
    be.min_iters = 3;
    println!("\n--- encode 1024x1024, d=8, K=256 ({} cores) ---", assign::default_threads());
    let slow = be
        .bench("encode: seed scalar loop (baseline)", || {
            encode_scalar(&big, rows, cols, &cb)
        })
        .median_ns;
    let one = be
        .bench("encode: engine, 1 thread", || {
            encode_with(&big, rows, cols, &cb, 1)
        })
        .median_ns;
    let par = be
        .bench("encode: engine, all cores", || encode(&big, rows, cols, &cb))
        .median_ns;
    println!(
        "\nengine speedup vs seed scalar encode: {:.2}x single-thread, {:.2}x parallel",
        slow / one,
        slow / par
    );

    // ---- SIMD lane blocking: blocked engine kernel vs the pre-SIMD
    // scalar-unrolled kernel (assign_reference), same decomposition,
    // bit-identical codes — this line is the ROADMAP item's receipt.
    println!("\n--- assign kernels (131072 pts, d=8, K=256, 1 thread) ---");
    let scalar_1t = be
        .bench("assign: scalar-unrolled kernel (pre-SIMD)", || {
            assign::assign_reference(&big, d, &cb.centroids, k)
        })
        .median_ns;
    let lane_1t = be
        .bench("assign: 8-lane blocked kernel", || {
            assign::assign(&big, d, &cb.centroids, k, 1)
        })
        .median_ns;
    println!(
        "lane-blocking delta: {:.2}x vs scalar-unrolled (single thread)",
        scalar_1t / lane_1t
    );

    // ---- histogram observer sharding (same engine sharding shape;
    // counts are bit-identical to the serial scan)
    let big_obs: Vec<f32> = {
        let mut r = Pcg::new(7);
        (0..1 << 20).map(|_| r.next_normal()).collect()
    };
    println!("\n--- histogram observe, 1M values, 2048 bins ---");
    let ser = be
        .bench("observe: serial scan", || {
            let mut h = HistogramObserver::new(2048);
            h.observe(&big_obs);
            h
        })
        .median_ns;
    let par_obs = be
        .bench("observe: sharded, all cores", || {
            let mut h = HistogramObserver::new(2048);
            h.observe_sharded(&big_obs, 0);
            h
        })
        .median_ns;
    println!(
        "observer sharding delta: {:.2}x ({} cores)",
        ser / par_obs,
        assign::default_threads()
    );
}
