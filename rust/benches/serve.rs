//! `qn serve` end-to-end latency/throughput on the checked-in
//! `lm_tiny` fixture: solo HTTP eval round trips, a concurrent-client
//! burst through the coalescing batcher (assert batching actually
//! engages), online re-encode cost, and the lazy JSON path-extraction
//! micro-bench behind the handlers. Runs with no artifacts and no
//! Python; emits `BENCH_serve.json` (path override: `QN_BENCH_JSON`).
//! `QN_BENCH_QUICK=1` (or `make bench-serve QUICK=1`) shrinks the
//! client counts and budgets to a CI smoke run.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::time::Duration;

use quant_noise::runtime::client::Backend;
use quant_noise::runtime::manifest::Manifest;
use quant_noise::serve::{ServeConfig, Server};
use quant_noise::util::bench::Bencher;
use quant_noise::util::json::{self, Json};

/// One-shot HTTP exchange: returns (status, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(150))).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: b\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).expect("send");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("recv");
    let status: u16 = raw.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn burst(addr: SocketAddr, body: &str, clients: usize, per_client: usize) {
    std::thread::scope(|s| {
        for _ in 0..clients {
            s.spawn(|| {
                for _ in 0..per_client {
                    let (status, resp) = http(addr, "POST", "/v1/eval", body);
                    assert_eq!(status, 200, "burst eval failed: {resp}");
                }
            });
        }
    });
}

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/interp");
    let man = Manifest::load(&dir).expect("checked-in interp fixture must load");
    let meta = man.model("lm_tiny").unwrap();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    let n = meta.batch * meta.seq_len;
    let tokens: Vec<String> = (0..n).map(|i| (i % meta.vocab).to_string()).collect();
    let targets: Vec<String> = (0..n).map(|i| ((i + 1) % meta.vocab).to_string()).collect();
    let body = format!(
        r#"{{"model": "lm_tiny", "tokens": [{}], "targets": [{}]}}"#,
        tokens.join(","),
        targets.join(",")
    );

    let quick = std::env::var("QN_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let (clients, per_client) = if quick { (4, 8) } else { (8, 50) };
    let mut b = Bencher::quick();
    if quick {
        b.warmup = Duration::from_millis(20);
        b.budget = Duration::from_millis(150);
        b.min_iters = 1;
    } else {
        b.warmup = Duration::from_millis(200);
        b.budget = Duration::from_secs(2);
        b.min_iters = 3;
    }
    let mut rec: Vec<(String, f64)> = Vec::new();

    // --- latency server: zero linger, so solo round trips pay no
    // coalescing wait and the row measures HTTP + batcher + eval only
    let lat_cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        linger: Duration::ZERO,
        backend: Some(Backend::Interp),
        ..ServeConfig::default()
    };
    let lat_srv = Server::start(&dir, lat_cfg).unwrap();
    let lat = lat_srv.addr();
    println!("--- qn serve (lm_tiny fixture, {cores} cores) ---");

    let solo = b
        .bench("eval: solo HTTP round trip", || {
            let (status, resp) = http(lat, "POST", "/v1/eval", &body);
            assert_eq!(status, 200, "{resp}");
            resp
        })
        .median_ns;
    rec.push(("eval_solo_ns".into(), solo));

    let stats_ns = b
        .bench("stats: GET /v1/stats", || {
            let (status, resp) = http(lat, "GET", "/v1/stats", "");
            assert_eq!(status, 200);
            resp
        })
        .median_ns;
    rec.push(("stats_ns".into(), stats_ns));

    let reenc = b
        .bench("reencode: int8 refit + atomic swap", || {
            let (status, resp) =
                http(lat, "POST", "/v1/models/lm_tiny/reencode", r#"{"scheme": "int8"}"#);
            assert_eq!(status, 200, "{resp}");
            resp
        })
        .median_ns;
    rec.push(("reencode_int8_ns".into(), reenc));

    // exercise PTQ-on-upload once (unique id; timing is the reencode row)
    let (status, resp) =
        http(lat, "POST", "/v1/quantize", r#"{"model": "lm_tiny", "scheme": "int4", "id": "b4"}"#);
    assert_eq!(status, 200, "quantize failed: {resp}");
    let (status, resp) = http(lat, "POST", "/v1/eval", &body.replace("\"lm_tiny\"", "\"b4\""));
    assert_eq!(status, 200, "derived-model eval failed: {resp}");
    lat_srv.shutdown();

    // --- throughput server: linger long enough for concurrent clients
    // to coalesce into macro-batches
    let thru_cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        http_threads: clients * 2,
        max_batch: 8,
        linger: Duration::from_millis(10),
        backend: Some(Backend::Interp),
        ..ServeConfig::default()
    };
    let thru_srv = Server::start(&dir, thru_cfg).unwrap();
    let thru = thru_srv.addr();
    let total = (clients * per_client) as f64;
    let burst_ns = b
        .bench(&format!("eval: {clients} clients x {per_client} reqs"), || {
            burst(thru, &body, clients, per_client)
        })
        .median_ns;
    rec.push(("eval_burst_per_req_ns".into(), burst_ns / total));

    let (status, stats) = http(thru, "GET", "/v1/stats", "");
    assert_eq!(status, 200);
    let j = Json::parse(&stats).unwrap();
    let max_batch = j.get_path("batching.max_batch").as_f64().unwrap();
    let batches = j.get_path("batching.batches").as_f64().unwrap();
    let coalesced = j.get_path("batching.coalesced_requests").as_f64().unwrap();
    assert!(
        max_batch > 1.0,
        "coalescing never engaged under {clients} concurrent clients: {stats}"
    );
    thru_srv.shutdown();

    // --- lazy JSON path extraction vs a full parse (what /v1/eval's
    // handler does to read "model" before touching the token arrays)
    let big_toks: Vec<String> = (0..4096).map(|i| (i % 97).to_string()).collect();
    let big = format!(
        r#"{{"model": "lm_tiny", "tokens": [{}], "targets": [{}]}}"#,
        big_toks.join(","),
        big_toks.join(",")
    );
    let full = b
        .bench(&format!("json: full parse ({}KB eval body)", big.len() / 1024), || {
            Json::parse(&big).unwrap()
        })
        .median_ns;
    let lazy = b
        .bench("json: lazy path_str(\"model\")", || json::path_str(&big, "model").unwrap())
        .median_ns;
    let json_speedup = full / lazy;
    rec.push(("json_full_parse_ns".into(), full));
    rec.push(("json_path_model_ns".into(), lazy));

    println!(
        "\nsolo eval round trip {}, burst per-request {} ({clients} clients, \
         max_batch {max_batch:.0}, {batches:.0} macro-batches)",
        quant_noise::util::bench::fmt_ns(solo),
        quant_noise::util::bench::fmt_ns(burst_ns / total)
    );
    println!("lazy \"model\" extraction: {json_speedup:.1}x vs a full parse of the same body");

    let mut out = String::from("{\n  \"fixture\": \"lm_tiny\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n  \"cores\": {cores},\n"));
    out.push_str(&format!("  \"clients\": {clients},\n  \"per_client\": {per_client},\n"));
    for (k, v) in &rec {
        out.push_str(&format!("  \"{k}\": {v:.1},\n"));
    }
    out.push_str(&format!(
        "  \"max_batch\": {max_batch:.0},\n  \"batches\": {batches:.0},\n  \
         \"coalesced_requests\": {coalesced:.0},\n"
    ));
    out.push_str(&format!("  \"json_path_speedup\": {json_speedup:.1}\n}}\n"));
    let path = std::env::var("QN_BENCH_JSON").unwrap_or_else(|_| "BENCH_serve.json".into());
    std::fs::write(&path, out).unwrap();
    println!("wrote {path}");
}
