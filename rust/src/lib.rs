//! Quant-Noise: training with quantization noise for extreme model
//! compression (Fan*, Stock* et al., ICLR 2021) — Rust coordinator.
//!
//! Layer map (see DESIGN.md §2):
//! - [`util`] — offline substrates (JSON/CLI/RNG/bench/proptest).
//! - [`quant`] — quantization: scalar intN, observers, k-means PQ, size
//!   accounting, pruning/sharing.
//! - [`model`] — host-side tensors, configs, parameter store.
//! - [`data`] — synthetic corpora and batchers.
//! - [`runtime`] — loads AOT HLO-text artifacts and executes them on a
//!   selectable backend: the pure-Rust interpreter
//!   ([`runtime::interp`], the default) or PJRT.
//! - [`coordinator`] — training/quantization pipelines (the paper).
//! - [`serve`] — batching inference + online-quantization HTTP service.
//! - [`bench_harness`] — regenerates every paper table and figure.

// The whole crate is safe Rust (determinism relies on it: no aliasing
// tricks, no uninitialized reads); keep it that way.
#![forbid(unsafe_code)]

pub mod util;
pub mod quant;
pub mod model;
pub mod data;
pub mod runtime;
pub mod coordinator;
pub mod serve;
pub mod bench_harness;
