//! Quant-Noise: training with quantization noise for extreme model
//! compression (Fan*, Stock* et al., ICLR 2021) — Rust coordinator.
//!
//! Layer map (see DESIGN.md):
//! - [`util`] — offline substrates (JSON/CLI/RNG/bench/proptest).
//! - [`quant`] — quantization: scalar intN, observers, k-means PQ, size
//!   accounting, pruning/sharing.
//! - [`model`] — host-side tensors, configs, parameter store.
//! - [`data`] — synthetic corpora and batchers.
//! - [`runtime`] — PJRT client; loads AOT HLO-text artifacts.
//! - [`coordinator`] — training/quantization pipelines (the paper).
//! - [`bench_harness`] — regenerates every paper table and figure.
pub mod util;
pub mod quant;
pub mod model;
pub mod data;
pub mod runtime;
pub mod coordinator;
pub mod bench_harness;
