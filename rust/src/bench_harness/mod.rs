//! Regenerates every table and figure of the paper's evaluation
//! (DESIGN.md §5 experiment index). Entry point: `qn bench --exp <id>`.
pub mod common;
pub mod e2e;
pub mod figures;
pub mod report;
pub mod specs;
pub mod tables;
