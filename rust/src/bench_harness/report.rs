//! Report sink: collects rows per experiment and appends a markdown
//! section to a results file (EXPERIMENTS.md sources these).

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::bench_harness::common::Row;

pub fn append_markdown(path: &Path, title: &str, rows: &[Row]) -> Result<()> {
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "\n### {title}\n")?;
    writeln!(f, "| scheme | size (MB) | comp. | metric |")?;
    writeln!(f, "|---|---|---|---|")?;
    for r in rows {
        let comp = if r.compression.is_nan() {
            "—".to_string()
        } else {
            format!("×{:.1}", r.compression)
        };
        writeln!(
            f,
            "| {} | {:.3} | {} | {:.2} {} |",
            r.label, r.size_mb, comp, r.metric, r.metric_name
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::temp_dir;

    #[test]
    fn writes_markdown_table() {
        let dir = temp_dir("report");
        let p = dir.join("r.md");
        let rows = vec![Row {
            label: "x".into(),
            size_mb: 1.5,
            compression: 4.0,
            metric: 20.0,
            metric_name: "ppl",
        }];
        append_markdown(&p, "Table 1", &rows).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("### Table 1"));
        assert!(text.contains("| x | 1.500 | ×4.0 | 20.00 ppl |"));
        std::fs::remove_dir_all(dir).ok();
    }
}
