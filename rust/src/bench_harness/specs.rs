//! Canonical training/quantization configurations per task — one place
//! so every table/figure reuses the same trained models (and thus the
//! train cache).

use crate::coordinator::ipq::IpqConfig;
use crate::coordinator::optim::Schedule;
use crate::coordinator::trainer::{OptKind, TrainConfig};
use crate::quant::noise::NoiseKind;

/// Steps per task at scale 1.0.
pub fn default_steps(task: &str) -> usize {
    match task {
        "lm" => 240,
        "cls" => 160,
        _ => 240,
    }
}

/// Base training config for a task (paper §7.6 translated to our scale:
/// Nesterov SGD + cosine for LM/IMG, Adam + poly-ish for CLS).
pub fn base_train(task: &str, steps: usize) -> TrainConfig {
    let (schedule, optimizer, clip) = match task {
        "cls" => (
            Schedule::Poly { lr: 3e-3, warmup: steps / 10, total: steps, power: 1.0 },
            OptKind::Adam,
            1.0,
        ),
        _ => (
            Schedule::Cosine {
                lr: 0.3,
                min_lr: 1e-3,
                warmup: steps / 10,
                total: steps,
            },
            OptKind::Sgd { momentum: 0.95, nesterov: true },
            0.25,
        ),
    };
    TrainConfig {
        steps,
        schedule,
        optimizer,
        clip,
        noise: NoiseKind::None,
        noise_rate: 0.0,
        layerdrop: 0.0,
        ldste: false,
        share_chunk: 0,
        hat_refresh: 60,
        pq_k: 64,
        threads: 0,
        seed: 42,
        log_every: 40,
    }
}

/// With a noise kind at its paper-default rate. Full-rate (QAT) runs
/// get a damped LR: with every block quantized each forward the STE
/// bias plus high momentum diverges at the base LR — QAT should be
/// *bad* (the paper's point), not NaN.
pub fn with_noise(mut cfg: TrainConfig, noise: NoiseKind, rate: f32) -> TrainConfig {
    cfg.noise = noise;
    cfg.noise_rate = rate;
    if rate >= 0.99 && !matches!(noise, NoiseKind::None) {
        cfg.schedule = scale_lr(cfg.schedule, 0.2);
    }
    cfg
}

pub fn scale_lr(s: Schedule, f: f32) -> Schedule {
    match s {
        Schedule::Constant { lr } => Schedule::Constant { lr: lr * f },
        Schedule::Cosine { lr, min_lr, warmup, total } => {
            Schedule::Cosine { lr: lr * f, min_lr: min_lr * f, warmup, total }
        }
        Schedule::Poly { lr, warmup, total, power } => {
            Schedule::Poly { lr: lr * f, warmup, total, power }
        }
    }
}

/// Paper rates: proxy/exact PQ noise at low p; intN noise tolerates
/// high p (Fig. 3 / Table 9).
pub fn default_rate(noise: NoiseKind) -> f32 {
    match noise {
        NoiseKind::None => 0.0,
        NoiseKind::Proxy | NoiseKind::ExactPq | NoiseKind::MeanSub => 0.1,
        _ => 0.5,
    }
}

/// iPQ at our scale: K=64 centroids (the models are ~10⁶ weights;
/// K=256 with d=8 would make many layers trivially losslessly
/// quantizable — Fig. 4 sweeps K explicitly).
pub fn base_ipq(steps: usize) -> IpqConfig {
    IpqConfig {
        k: 64,
        kmeans_iters: 10,
        finetune_steps: steps,
        codeword_lr: 0.02,
        float_lr: 5e-3,
        ..Default::default()
    }
}

pub fn default_ipq_finetune(task: &str) -> usize {
    match task {
        "cls" => 20,
        _ => 25,
    }
}
