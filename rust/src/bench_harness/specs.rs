//! Canonical training/quantization configurations per task — one place
//! so every table/figure reuses the same trained models (and thus the
//! train cache). Noise functions are plain [`QuantSpec`]s.

use crate::coordinator::ipq::IpqConfig;
use crate::coordinator::optim::Schedule;
use crate::coordinator::trainer::{OptKind, TrainConfig};
use crate::quant::scheme::QuantSpec;

/// Steps per task at scale 1.0.
pub fn default_steps(task: &str) -> usize {
    match task {
        "lm" => 240,
        "cls" => 160,
        _ => 240,
    }
}

/// Base training config for a task (paper §7.6 translated to our scale:
/// Nesterov SGD + cosine for LM/IMG, Adam + poly-ish for CLS).
pub fn base_train(task: &str, steps: usize) -> TrainConfig {
    let (schedule, optimizer, clip) = match task {
        "cls" => (
            Schedule::Poly { lr: 3e-3, warmup: steps / 10, total: steps, power: 1.0 },
            OptKind::Adam,
            1.0,
        ),
        _ => (
            Schedule::Cosine {
                lr: 0.3,
                min_lr: 1e-3,
                warmup: steps / 10,
                total: steps,
            },
            OptKind::Sgd { momentum: 0.95, nesterov: true },
            0.25,
        ),
    };
    TrainConfig {
        steps,
        schedule,
        optimizer,
        clip,
        noise: QuantSpec::None,
        noise_rate: 0.0,
        layerdrop: 0.0,
        ldste: false,
        share_chunk: 0,
        hat_refresh: 60,
        threads: 0,
        seed: 42,
        log_every: 40,
    }
}

/// With a noise scheme at the given rate. Full-rate (QAT) runs get a
/// damped LR: with every block quantized each forward the STE bias plus
/// high momentum diverges at the base LR — QAT should be *bad* (the
/// paper's point), not NaN.
pub fn with_noise(mut cfg: TrainConfig, noise: QuantSpec, rate: f32) -> TrainConfig {
    let damp = rate >= 0.99 && !matches!(noise, QuantSpec::None);
    cfg.noise = noise;
    cfg.noise_rate = rate;
    if damp {
        cfg.schedule = scale_lr(cfg.schedule, 0.2);
    }
    cfg
}

pub fn scale_lr(s: Schedule, f: f32) -> Schedule {
    match s {
        Schedule::Constant { lr } => Schedule::Constant { lr: lr * f },
        Schedule::Cosine { lr, min_lr, warmup, total } => {
            Schedule::Cosine { lr: lr * f, min_lr: min_lr * f, warmup, total }
        }
        Schedule::Poly { lr, warmup, total, power } => {
            Schedule::Poly { lr: lr * f, warmup, total, power }
        }
    }
}

/// Paper rates: proxy/exact PQ noise at low p; intN noise tolerates
/// high p (Fig. 3 / Table 9).
pub fn default_rate(noise: &QuantSpec) -> f32 {
    match noise {
        QuantSpec::None => 0.0,
        QuantSpec::Proxy | QuantSpec::Pq(_) | QuantSpec::MeanSub => 0.1,
        QuantSpec::Int { .. } => 0.5,
    }
}

/// The exact-φ_PQ training noise at the table defaults: K=64 codewords
/// at our model scale, 6 Lloyd iterations per hat refresh.
pub fn exact_pq_noise() -> QuantSpec {
    QuantSpec::pq_noise(64)
}

/// iPQ at our scale: K=64 centroids (the models are ~10⁶ weights;
/// K=256 with d=8 would make many layers trivially losslessly
/// quantizable — Fig. 4 sweeps K explicitly).
pub fn base_ipq(steps: usize) -> IpqConfig {
    IpqConfig {
        k: 64,
        kmeans_iters: 10,
        finetune_steps: steps,
        codeword_lr: 0.02,
        float_lr: 5e-3,
        ..Default::default()
    }
}

pub fn default_ipq_finetune(task: &str) -> usize {
    match task {
        "cls" => 20,
        _ => 25,
    }
}
