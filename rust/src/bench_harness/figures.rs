//! Figure regeneration (paper Figs. 2–6 and the numeric Tables 6–9
//! behind them). Prints the series each figure plots.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::bench_harness::common::{task_metric, Row, Workbench};
use crate::bench_harness::specs::*;
use crate::bench_harness::tables::post_pq_row;
use crate::coordinator::ipq::run_ipq;
use crate::coordinator::quantize::quantize_params;
use crate::quant::prune::every_other_chunk_mask;
use crate::quant::scheme::{IntObserver, QuantSpec};
use crate::util::rng::Pcg;

/// Fig. 2 / Tables 6-8: size-vs-quality trade-off. Our measured
/// operating points next to the paper's cited baselines (constants from
/// Tables 6/7/8 — we cannot retrain TinyBERT et al.; printed for the
/// qualitative comparison the figure makes).
pub fn fig2(wb: &Workbench, model: &str) -> Result<Vec<Row>> {
    let mut lab = wb.lab(model)?;
    let task = lab.sess.meta.task.clone();
    let steps = wb.scaled(default_steps(&task));
    let base = base_train(&task, steps);

    let mut rows = Vec::new();

    // measured points: fp32, iPQ+QN, iPQ+QN+share+prune
    let plain = lab.train_cached(&base)?;
    let fp_bytes = crate::coordinator::quantize::scheme_bytes(&lab.sess.meta, &QuantSpec::None);
    {
        let keep = lab.keep_all();
        let ev = lab.eval_params(&plain, "eval", &keep)?;
        let (m, n) = task_metric(&task, &ev);
        rows.push(Row {
            label: "ours: original fp32".into(),
            size_mb: crate::quant::size::mb(fp_bytes),
            compression: 1.0,
            metric: m,
            metric_name: n,
        });
    }

    let qn = lab.train_cached(&with_noise(base.clone(), QuantSpec::Proxy, 0.1))?;
    lab.sess.upload_all_params(&qn)?;
    let (q, _) = run_ipq(
        &mut lab.sess,
        &qn,
        lab.train_src.as_mut(),
        &base_ipq(default_ipq_finetune(&task)),
    )?;
    {
        let keep = lab.keep_all();
        lab.sess.upload_all_params(&q.store)?;
        let ev = crate::coordinator::evaluator::evaluate(
            &mut lab.sess,
            "eval",
            &lab.eval_batches,
            &keep,
        )?;
        let (m, n) = task_metric(&task, &ev);
        rows.push(Row {
            label: "ours: iPQ + Quant-Noise".into(),
            size_mb: crate::quant::size::mb(q.bytes),
            compression: fp_bytes as f64 / q.bytes as f64,
            metric: m,
            metric_name: n,
        });
    }

    let mut qn_share = with_noise(base, QuantSpec::Proxy, 0.1);
    qn_share.layerdrop = 0.2;
    qn_share.share_chunk = 2;
    let qns = lab.train_cached(&qn_share)?;
    lab.sess.upload_all_params(&qns)?;
    let (q2, _) = run_ipq(
        &mut lab.sess,
        &qns,
        lab.train_src.as_mut(),
        &base_ipq(default_ipq_finetune(&task)),
    )?;
    {
        let n_layers = lab.sess.meta.n_layers;
        let prune_keep = every_other_chunk_mask(n_layers, 2);
        lab.sess.upload_all_params(&q2.store)?;
        let ev = crate::coordinator::evaluator::evaluate(
            &mut lab.sess,
            "eval",
            &lab.eval_batches,
            &prune_keep,
        )?;
        let (m, n) = task_metric(&task, &ev);
        // share+prune bytes: half the layers stored, half of those kept
        let stored = crate::quant::prune::stored_layers(n_layers, 2, &prune_keep);
        let infos = lab.sess.meta.param_infos();
        let mask: Vec<bool> = lab
            .sess
            .meta
            .params
            .iter()
            .map(|p| {
                for l in 0..n_layers {
                    if p.name.starts_with(&format!("layer{l:02}."))
                        || p.name.starts_with(&format!("block{l:02}."))
                    {
                        return stored[l];
                    }
                }
                true
            })
            .collect();
        let bytes = crate::quant::size::model_bytes_with_mask(&infos, &QuantSpec::pq(64), &mask);
        rows.push(Row {
            label: "ours: iPQ + QN + share + prune".into(),
            size_mb: crate::quant::size::mb(bytes),
            compression: fp_bytes as f64 / bytes as f64,
            metric: m,
            metric_name: n,
        });
    }

    // cited literature points (paper Tables 6/7/8)
    let cited: &[(&str, f64, f64)] = match task.as_str() {
        "lm" => &[
            ("paper: Trans-XL Large", 970.0, 18.3),
            ("paper: Compressive Trans", 970.0, 17.1),
            ("paper: GCNN", 870.0, 37.2),
            ("paper: Trans-XL Base", 570.0, 24.0),
            ("paper: Tensorized core-2", 325.0, 18.9),
            ("paper: Quant-Noise", 38.0, 20.7),
            ("paper: QN + Share + Prune", 10.0, 24.2),
        ],
        "cls" => &[
            ("paper: RoBERTa Base + LD", 480.0, 84.8),
            ("paper: BERT Base", 420.0, 84.4),
            ("paper: DistilBERT", 250.0, 81.8),
            ("paper: MobileBERT", 96.0, 84.4),
            ("paper: TinyBERT", 55.0, 82.8),
            ("paper: ALBERT Base", 45.0, 81.6),
            ("paper: AdaBERT", 36.0, 81.6),
            ("paper: Quant-Noise", 38.0, 83.6),
            ("paper: QN + Share + Prune", 14.0, 82.5),
        ],
        _ => &[
            ("paper: EfficientNet-B7", 260.0, 84.4),
            ("paper: ResNet-50", 97.5, 76.1),
            ("paper: EfficientNet-B0", 20.2, 77.3),
            ("paper: MobileNet-v2", 13.4, 71.9),
            ("paper: ShuffleNet-v2", 8.7, 69.4),
            ("paper: HAQ 4 bits", 12.4, 76.2),
            ("paper: iPQ ResNet-50", 5.09, 76.1),
            ("paper: Quant-Noise", 3.3, 80.0),
            ("paper: QN + Share + Prune", 2.3, 77.8),
        ],
    };
    let metric_name = if task == "lm" { "ppl" } else { "top1%" };
    for &(label, size, metric) in cited {
        rows.push(Row {
            label: label.into(),
            size_mb: size,
            compression: f64::NAN,
            metric,
            metric_name,
        });
    }

    Row::print_header(&format!("Fig 2 / Tables 6-8 — {model} ({task})"));
    for r in &rows {
        r.print();
    }
    Ok(rows)
}

/// Fig. 3 (LM) / Table 9 (IMG): quantized quality as a function of the
/// Quant-Noise rate p, for the proxy-PQ noise and the intN noise.
pub fn fig3(wb: &Workbench, model: &str) -> Result<Vec<Row>> {
    let mut lab = wb.lab(model)?;
    let task = lab.sess.meta.task.clone();
    let steps = wb.scaled(default_steps(&task));
    let base = base_train(&task, steps);
    let rates = [0.0f32, 0.25, 0.5, 0.75, 1.0];

    let mut rows = Vec::new();
    // proxy noise → iPQ quantization (one-shot PQ for sweep speed,
    // constant across points so the trend is comparable)
    for &p in &rates {
        let noise = if p == 0.0 { QuantSpec::None } else { QuantSpec::Proxy };
        let params = lab.train_cached(&with_noise(base.clone(), noise, p))?;
        let mut row = post_pq_row(&mut lab, &format!("proxy p={p}"), &params, 64, BTreeMap::new())?;
        row.label = format!("proxy p={p} -> PQ");
        rows.push(row);
    }
    // int8 noise → int8 quantization
    for &p in &rates {
        let noise = if p == 0.0 {
            QuantSpec::None
        } else {
            QuantSpec::int(8, IntObserver::MinMax)
        };
        let params = lab.train_cached(&with_noise(base.clone(), noise, p))?;
        let q = quantize_params(
            &params,
            &lab.sess.meta,
            &QuantSpec::int(8, IntObserver::Histogram),
            &mut Pcg::new(5),
        )?;
        let keep = lab.keep_all();
        lab.sess.upload_all_params(&q.store)?;
        let ev = crate::coordinator::evaluator::evaluate(
            &mut lab.sess,
            "eval",
            &lab.eval_batches,
            &keep,
        )?;
        let (m, n) = task_metric(&task, &ev);
        rows.push(Row {
            label: format!("int8 p={p} -> int8"),
            size_mb: crate::quant::size::mb(q.bytes),
            compression: f64::NAN,
            metric: m,
            metric_name: n,
        });
    }

    Row::print_header(&format!("Fig 3 / Table 9 — {model} ({task}) noise-rate sweep"));
    for r in &rows {
        r.print();
    }
    Ok(rows)
}

/// Fig. 4: number of centroids K vs quantized quality and size.
pub fn fig4(wb: &Workbench, model: &str) -> Result<Vec<Row>> {
    let mut lab = wb.lab(model)?;
    let task = lab.sess.meta.task.clone();
    let steps = wb.scaled(default_steps(&task));
    let base = base_train(&task, steps);
    let qn = lab.train_cached(&with_noise(base, QuantSpec::Proxy, 0.1))?;

    let mut rows = Vec::new();
    for k in [16usize, 32, 64, 128, 256] {
        rows.push(post_pq_row(&mut lab, &format!("K={k}"), &qn, k, BTreeMap::new())?);
    }

    Row::print_header(&format!("Fig 4 — {model} ({task}) centroid sweep"));
    for r in &rows {
        r.print();
    }
    Ok(rows)
}

/// Fig. 5: effect of initial model size (shallower / skinnier LMs):
/// fp32 vs quantized gap. Needs the fig5 model configs exported.
pub fn fig5(wb: &Workbench) -> Result<Vec<Row>> {
    let variants = ["lm_l2", "lm_tiny", "lm_l6", "lm_ffn256", "lm_ffn128"];
    let mut rows = Vec::new();
    for v in variants {
        if wb.manifest.models.get(v).is_none() {
            println!("fig5: model {v} not exported — run `make artifacts-fig5`");
            continue;
        }
        let mut lab = wb.lab(v)?;
        let steps = wb.scaled(default_steps("lm"));
        let qn = lab.train_cached(&with_noise(base_train("lm", steps), QuantSpec::Proxy, 0.1))?;
        let keep = lab.keep_all();
        let ev = lab.eval_params(&qn, "eval", &keep)?;
        let (m, n) = task_metric("lm", &ev);
        rows.push(Row {
            label: format!("{v}: fp32"),
            size_mb: crate::quant::size::mb(crate::coordinator::quantize::scheme_bytes(
                &lab.sess.meta,
                &QuantSpec::None,
            )),
            compression: 1.0,
            metric: m,
            metric_name: n,
        });
        rows.push(post_pq_row(&mut lab, &format!("{v}: PQ"), &qn, 64, BTreeMap::new())?);
    }

    Row::print_header("Fig 5 — model size vs quantizability");
    for r in &rows {
        r.print();
    }
    Ok(rows)
}

/// Fig. 6: (a) quantization order of FFN/emb/attn; (b) per-structure
/// block-size robustness.
pub fn fig6(wb: &Workbench, model: &str) -> Result<Vec<Row>> {
    let mut lab = wb.lab(model)?;
    let task = lab.sess.meta.task.clone();
    let steps = wb.scaled(default_steps(&task));
    let base = base_train(&task, steps);
    let qn = lab.train_cached(&with_noise(base, QuantSpec::Proxy, 0.1))?;

    let mut rows = Vec::new();
    // (a) order ablation — full iPQ with different group orders
    for order in [
        vec!["ffn", "emb", "attn"],
        vec!["attn", "ffn", "emb"],
        vec!["emb", "attn", "ffn"],
    ] {
        let mut cfg = base_ipq(default_ipq_finetune(&task));
        cfg.finetune_steps = cfg.finetune_steps / 2; // ablation budget
        cfg.order = order.iter().map(|s| s.to_string()).collect();
        lab.sess.upload_all_params(&qn)?;
        let (q, _) = run_ipq(&mut lab.sess, &qn, lab.train_src.as_mut(), &cfg)?;
        let keep = lab.keep_all();
        lab.sess.upload_all_params(&q.store)?;
        let ev = crate::coordinator::evaluator::evaluate(
            &mut lab.sess,
            "eval",
            &lab.eval_batches,
            &keep,
        )?;
        let (m, n) = task_metric(&task, &ev);
        rows.push(Row {
            label: format!("order {}", order.join("->")),
            size_mb: crate::quant::size::mb(q.bytes),
            compression: f64::NAN,
            metric: m,
            metric_name: n,
        });
    }

    // (b) block-size robustness per structure (others held at default)
    for structure in ["ffn", "emb", "attn"] {
        for bs in [4usize, 8, 16, 32] {
            let overrides = BTreeMap::from([(structure.to_string(), bs)]);
            rows.push(post_pq_row(
                &mut lab,
                &format!("{structure} block={bs}"),
                &qn,
                64,
                overrides,
            )?);
        }
    }

    Row::print_header(&format!("Fig 6 — {model} ({task}) order + block-size"));
    for r in &rows {
        r.print();
    }
    Ok(rows)
}
