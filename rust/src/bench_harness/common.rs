//! Shared experiment machinery: the Workbench (runtime + manifest +
//! data), operating-point specs/results, and a disk cache of trained
//! parameter sets so every table/figure that needs "the QN-trained LM"
//! trains it exactly once.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::coordinator::evaluator::{self, EvalResult};
use crate::coordinator::trainer::{
    BatchSource, ClsSource, ImgSource, LmSource, TrainBatch, TrainConfig, Trainer,
};
use crate::data::batcher::{EpochBatcher, LmBatcher};
use crate::data::corpus::{make_cls_dataset, make_img_dataset, MarkovCorpus};
use crate::log_info;
use crate::model::params::ParamStore;
use crate::quant::scheme::QuantizerFactory;
use crate::runtime::client::Runtime;
use crate::runtime::executable::ModelSession;
use crate::runtime::manifest::Manifest;

pub struct Workbench {
    pub rt: Runtime,
    pub manifest: Manifest,
    pub cache_dir: PathBuf,
    /// global scale on training steps (quick smoke runs: --scale 0.1)
    pub step_scale: f64,
}

impl Workbench {
    pub fn new(artifacts: &Path) -> Result<Workbench> {
        Workbench::at(artifacts, &artifacts.join("cache"))
    }

    /// Workbench over any manifest directory — e.g. the checked-in
    /// interpreter fixture (`rust/tests/fixtures/interp`) — with an
    /// explicit trained-parameter cache location.
    pub fn at(manifest_dir: &Path, cache_dir: &Path) -> Result<Workbench> {
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load(manifest_dir)?;
        std::fs::create_dir_all(cache_dir)?;
        Ok(Workbench { rt, manifest, cache_dir: cache_dir.to_path_buf(), step_scale: 1.0 })
    }

    pub fn scaled(&self, steps: usize) -> usize {
        ((steps as f64 * self.step_scale) as usize).max(5)
    }

    /// Open a model lab: session + init params + train/eval data.
    pub fn lab(&self, model: &str) -> Result<Lab<'_>> {
        let (sess, init) = ModelSession::new(&self.rt, &self.manifest, model)?;
        let meta = sess.meta.clone();
        let (train_src, eval_batches): (Box<dyn BatchSource>, Vec<TrainBatch>) =
            match meta.task.as_str() {
                "lm" => {
                    let corpus = MarkovCorpus::generate(meta.vocab, 400_000, 1234);
                    let split = corpus.tokens.len() * 9 / 10;
                    let train = LmBatcher::new(&corpus.tokens[..split], meta.batch, meta.seq_len);
                    let evalb = evaluator::lm_eval_batches(
                        &corpus.tokens[split..],
                        meta.batch,
                        meta.seq_len,
                        16,
                    );
                    (Box::new(LmSource { batcher: train }), evalb)
                }
                "cls" => {
                    let (tokens, labels) =
                        make_cls_dataset(4096, meta.seq_len, meta.vocab, meta.n_classes, 77);
                    let n_eval = meta.batch * 16;
                    let n_train = labels.len() - n_eval;
                    let train = EpochBatcher::new(
                        tokens[..n_train * meta.seq_len].to_vec(),
                        labels[..n_train].to_vec(),
                        meta.seq_len,
                        meta.batch,
                        5,
                    );
                    let evalb = EpochBatcher::new(
                        tokens[n_train * meta.seq_len..].to_vec(),
                        labels[n_train..].to_vec(),
                        meta.seq_len,
                        meta.batch,
                        6,
                    );
                    let batches = evaluator::cls_eval_batches(&evalb, 16);
                    (Box::new(ClsSource { batcher: train }), batches)
                }
                "img" => {
                    let size = meta.tokens_shape[1];
                    let chans = meta.tokens_shape[3];
                    let (px, labels) = make_img_dataset(4096, size, chans, 99);
                    let ex_len = size * size * chans;
                    let n_eval = meta.batch * 16;
                    let n_train = labels.len() - n_eval;
                    let train = EpochBatcher::new(
                        px[..n_train * ex_len].to_vec(),
                        labels[..n_train].to_vec(),
                        ex_len,
                        meta.batch,
                        7,
                    );
                    let evalb = EpochBatcher::new(
                        px[n_train * ex_len..].to_vec(),
                        labels[n_train..].to_vec(),
                        ex_len,
                        meta.batch,
                        8,
                    );
                    let batches = evaluator::img_eval_batches(&evalb, 16);
                    (Box::new(ImgSource { batcher: train }), batches)
                }
                t => anyhow::bail!("unknown task {t}"),
            };
        Ok(Lab { sess, init, train_src, eval_batches, cache_dir: self.cache_dir.clone() })
    }
}

pub struct Lab<'rt> {
    pub sess: ModelSession<'rt>,
    pub init: ParamStore,
    pub train_src: Box<dyn BatchSource>,
    pub eval_batches: Vec<TrainBatch>,
    cache_dir: PathBuf,
}

/// Cache key for a training configuration (everything that affects the
/// final weights).
fn train_key(model: &str, cfg: &TrainConfig) -> String {
    let mut h = DefaultHasher::new();
    // algorithm-version salt: bump when the training algorithm changes
    // output for identical configs (v3 = QuantSpec-described noise; the
    // spec string now carries K/iters/blocks), so stale caches never
    // get served
    "qn-train-v3".hash(&mut h);
    model.hash(&mut h);
    cfg.steps.hash(&mut h);
    // spec_string normalizes the thread knob out of the key: worker
    // counts cannot change training output (engine results are
    // thread-invariant and refresh_hats overrides them per wave anyway)
    let spec = cfg.noise.spec_string();
    spec.hash(&mut h);
    (cfg.noise_rate.to_bits(), cfg.layerdrop.to_bits(), cfg.clip.to_bits()).hash(&mut h);
    (cfg.share_chunk, cfg.ldste, cfg.hat_refresh, cfg.seed).hash(&mut h);
    // keep cache filenames filesystem-friendly: the hash carries the
    // exact spec, the prefix is only a human-readable hint
    let tag: String = spec
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    format!("{model}-{tag}-r{}-s{}-{:016x}", cfg.noise_rate, cfg.steps, h.finish())
}

impl<'rt> Lab<'rt> {
    /// Train (or load from cache) a parameter set under `cfg`, starting
    /// from the shared init. Leaves the trained params uploaded.
    pub fn train_cached(&mut self, cfg: &TrainConfig) -> Result<ParamStore> {
        let key = train_key(&self.sess.meta.name, cfg);
        let path = self.cache_dir.join(format!("{key}.qnp1"));
        if path.exists() {
            log_info!("cache hit: {key}");
            let params = ParamStore::load_qnp1(&path)?;
            params.check_against(&self.sess.meta)?;
            self.sess.upload_all_params(&params)?;
            self.sess.zero_hats()?;
            return Ok(params);
        }
        log_info!("training {key} ({} steps)", cfg.steps);
        self.sess.upload_all_params(&self.init)?;
        self.sess.zero_hats()?;
        let mut trainer = Trainer::new(&mut self.sess, self.init.clone(), cfg.clone());
        trainer.train(self.train_src.as_mut())?;
        let params = trainer.into_params();
        params.save_qnp1(&path)?;
        // reset hats for subsequent users (trainer may have set PQ hats)
        self.sess.zero_hats()?;
        Ok(params)
    }

    /// Evaluate the given params through `entry`.
    pub fn eval_params(
        &mut self,
        params: &ParamStore,
        entry: &str,
        layer_keep: &[f32],
    ) -> Result<EvalResult> {
        self.sess.upload_all_params(params)?;
        evaluator::evaluate(&mut self.sess, entry, &self.eval_batches, layer_keep)
    }

    pub fn keep_all(&self) -> Vec<f32> {
        vec![1.0; self.sess.meta.n_layers]
    }
}

// -------------------------------------------------------- result rows ---

#[derive(Debug, Clone)]
pub struct Row {
    pub label: String,
    pub size_mb: f64,
    pub compression: f64,
    /// PPL for LM, top-1 % for cls/img
    pub metric: f64,
    pub metric_name: &'static str,
}

impl Row {
    pub fn print_header(title: &str) {
        println!("\n=== {title} ===");
        println!(
            "{:<44} {:>9} {:>8} {:>10}",
            "scheme", "size(MB)", "comp.", "metric"
        );
    }

    pub fn print(&self) {
        println!(
            "{:<44} {:>9.3} {:>7.1}x {:>7.2} {}",
            self.label, self.size_mb, self.compression, self.metric, self.metric_name
        );
    }
}

/// metric for a task: LM reports PPL (lower better), others top-1 %.
pub fn task_metric(task: &str, ev: &EvalResult) -> (f64, &'static str) {
    if task == "lm" {
        (ev.ppl, "ppl")
    } else {
        (ev.accuracy * 100.0, "top1%")
    }
}
