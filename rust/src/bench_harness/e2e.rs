//! End-to-end validation driver (deliverable (e2) in the system spec):
//! generate a real synthetic workload, train with Quant-Noise logging
//! the loss curve, iPQ-quantize, and print Table-1-shaped rows proving
//! all three layers compose. Recorded in EXPERIMENTS.md.

use anyhow::Result;

use crate::bench_harness::common::{task_metric, Row, Workbench};
use crate::bench_harness::specs::*;
use crate::coordinator::ipq::run_ipq;
use crate::coordinator::quantize::scheme_bytes;
use crate::coordinator::trainer::Trainer;
use crate::log_info;
use crate::quant::scheme::QuantSpec;

// wall-clock prints progress timings only, never results (clippy.toml)
#[allow(clippy::disallowed_methods)]
pub fn run(wb: &Workbench, model: &str, steps_override: Option<usize>) -> Result<()> {
    let mut lab = wb.lab(model)?;
    let task = lab.sess.meta.task.clone();
    let steps = steps_override.unwrap_or_else(|| wb.scaled(default_steps(&task)));
    let n_params: usize = lab.init.total_params();
    println!(
        "e2e: model={model} task={task} params={n_params} ({:.2} MB fp32) steps={steps}",
        n_params as f64 * 4.0 / 1e6
    );

    // ---- 1. baseline (no noise) --------------------------------------
    let base = base_train(&task, steps);
    let t0 = std::time::Instant::now();
    let baseline = lab.train_cached(&base)?;
    log_info!("baseline trained in {:.1}s", t0.elapsed().as_secs_f64());

    // ---- 2. Quant-Noise training with loss curve ---------------------
    let qn_cfg = with_noise(base.clone(), QuantSpec::Proxy, 0.1);
    let key_exists = {
        // train manually (not via cache) when we want the loss curve
        let mut cfg = qn_cfg.clone();
        cfg.log_every = (steps / 20).max(1);
        lab.sess.upload_all_params(&lab.init.clone())?;
        lab.sess.zero_hats()?;
        let mut trainer = Trainer::new(&mut lab.sess, lab.init.clone(), cfg);
        let t1 = std::time::Instant::now();
        let stats = trainer.train(lab.train_src.as_mut())?;
        let dt = t1.elapsed().as_secs_f64();
        println!("\nloss curve (Quant-Noise proxy p=0.1):");
        for (s, l) in &stats.history {
            println!("  step {s:>5}  loss {l:.4}");
        }
        println!(
            "trained {} steps in {dt:.1}s ({:.0} ms/step)",
            stats.steps,
            dt * 1000.0 / stats.steps as f64
        );
        let params = trainer.into_params();
        params
    };
    let qn = key_exists;

    // ---- 3. evaluate fp32 / post-PQ / iPQ ----------------------------
    let keep = lab.keep_all();
    let fp = scheme_bytes(&lab.sess.meta, &QuantSpec::None);
    let mut rows: Vec<Row> = Vec::new();

    for (label, params) in [("baseline fp32", &baseline), ("Quant-Noise fp32", &qn)] {
        let ev = lab.eval_params(params, "eval", &keep)?;
        let (m, n) = task_metric(&task, &ev);
        rows.push(Row {
            label: label.into(),
            size_mb: fp as f64 / 1e6,
            compression: 1.0,
            metric: m,
            metric_name: n,
        });
    }

    for (label, params) in [("iPQ on baseline", &baseline), ("iPQ on Quant-Noise", &qn)] {
        lab.sess.upload_all_params(params)?;
        lab.sess.zero_hats()?;
        let (q, _) = run_ipq(
            &mut lab.sess,
            params,
            lab.train_src.as_mut(),
            &base_ipq(default_ipq_finetune(&task)),
        )?;
        lab.sess.upload_all_params(&q.store)?;
        let ev = crate::coordinator::evaluator::evaluate(
            &mut lab.sess,
            "eval",
            &lab.eval_batches,
            &keep,
        )?;
        let (m, n) = task_metric(&task, &ev);
        rows.push(Row {
            label: label.into(),
            size_mb: q.bytes as f64 / 1e6,
            compression: fp as f64 / q.bytes as f64,
            metric: m,
            metric_name: n,
        });
    }

    Row::print_header(&format!("e2e — {model}"));
    for r in &rows {
        r.print();
    }
    println!(
        "\nexpected shape: 'iPQ on Quant-Noise' beats 'iPQ on baseline' at the same size;\n\
         both fp32 rows should be close."
    );
    Ok(())
}
