//! Table regeneration (paper Tables 1–5, 9–11). Each function prints
//! rows in the paper's format; absolute numbers come from our scaled-
//! down substrate, the *shape* (who wins, by roughly what factor) is
//! the reproduction target (see EXPERIMENTS.md).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::bench_harness::common::{task_metric, Lab, Row, Workbench};
use crate::bench_harness::specs::*;
use crate::coordinator::ipq::{post_pq, run_ipq};
use crate::coordinator::quantize::{quantize_params, scheme_bytes};
use crate::model::params::ParamStore;
use crate::quant::prune::{every_other_chunk_mask, stored_layers};
use crate::quant::scheme::{IntObserver, QuantSpec};
use crate::quant::size::{mb, model_bytes_with_mask};
use crate::util::rng::Pcg;

fn fp32_bytes(lab: &Lab) -> u64 {
    scheme_bytes(&lab.sess.meta, &QuantSpec::None)
}

/// Evaluate `params` and produce a row.
fn eval_row(
    lab: &mut Lab,
    label: &str,
    params: &ParamStore,
    bytes: u64,
    entry: &str,
    keep: &[f32],
) -> Result<Row> {
    let ev = lab.eval_params(params, entry, keep)?;
    let task = lab.sess.meta.task.clone();
    let (metric, name) = task_metric(&task, &ev);
    Ok(Row {
        label: label.to_string(),
        size_mb: mb(bytes),
        compression: fp32_bytes(lab) as f64 / bytes as f64,
        metric,
        metric_name: name,
    })
}

/// intN quantize + eval.
fn int_row(
    lab: &mut Lab,
    label: &str,
    params: &ParamStore,
    bits: u8,
    observer: IntObserver,
) -> Result<Row> {
    let q = quantize_params(
        params,
        &lab.sess.meta,
        &QuantSpec::int(bits, observer),
        &mut Pcg::new(5),
    )?;
    let keep = lab.keep_all();
    eval_row(lab, label, &q.store, q.bytes, "eval", &keep)
}

/// Full iPQ (with Eq. 4 finetuning) + eval.
fn ipq_row(
    lab: &mut Lab,
    label: &str,
    params: &ParamStore,
    int8_centroids: bool,
    entry: &str,
) -> Result<Row> {
    let mut cfg = base_ipq(default_ipq_finetune(&lab.sess.meta.task));
    cfg.centroid_bits = int8_centroids.then_some(8);
    lab.sess.upload_all_params(params)?;
    lab.sess.zero_hats()?;
    let (q, _report) = run_ipq(&mut lab.sess, params, lab.train_src.as_mut(), &cfg)?;
    let keep = lab.keep_all();
    eval_row(lab, label, &q.store, q.bytes, entry, &keep)
}

// ================================================================ T1 ===

/// Table 1: quantization schemes × {post, QAT, Quant-Noise} for the LM
/// and the image model.
pub fn table1(wb: &Workbench, model: &str) -> Result<Vec<Row>> {
    let mut lab = wb.lab(model)?;
    let task = lab.sess.meta.task.clone();
    let steps = wb.scaled(default_steps(&task));
    let base = base_train(&task, steps);

    let baseline = lab.train_cached(&base)?;
    let mut rows = Vec::new();
    let fp = fp32_bytes(&lab);
    let keep = lab.keep_all();
    rows.push(eval_row(&mut lab, "uncompressed", &baseline, fp, "eval", &keep)?);

    for bits in [4u8, 8] {
        let noise_q = QuantSpec::int(bits, IntObserver::MinMax);
        let noise_n = format!("int{bits}");
        // post-training quantization of the plain model
        let hist = IntObserver::Histogram;
        rows.push(int_row(&mut lab, &format!("{noise_n} (post)"), &baseline, bits, hist)?);
        // QAT = noise at rate 1.0
        let qat = lab.train_cached(&with_noise(base.clone(), noise_q.clone(), 1.0))?;
        rows.push(int_row(&mut lab, &format!("{noise_n} + QAT"), &qat, bits, hist)?);
        // Quant-Noise at partial rate
        let rate = default_rate(&noise_q);
        let qn = lab.train_cached(&with_noise(base.clone(), noise_q, rate))?;
        rows.push(int_row(&mut lab, &format!("{noise_n} + Quant-Noise"), &qn, bits, hist)?);
    }

    // iPQ: post / QAT (exact PQ noise at rate 1.0) / QN (proxy)
    rows.push(ipq_row(&mut lab, "iPQ (post)", &baseline, false, "eval")?);
    let qat_pq = lab.train_cached(&with_noise(base.clone(), exact_pq_noise(), 1.0))?;
    rows.push(ipq_row(&mut lab, "iPQ + QAT", &qat_pq, false, "eval")?);
    let qn_pq = lab.train_cached(&with_noise(
        base.clone(),
        QuantSpec::Proxy,
        default_rate(&QuantSpec::Proxy),
    ))?;
    rows.push(ipq_row(&mut lab, "iPQ + Quant-Noise", &qn_pq, false, "eval")?);

    // §3.3 combination: int8 centroids + int8 activations
    let combo_entry = if lab.sess.has_entry("eval_int8act") { "eval_int8act" } else { "eval" };
    rows.push(ipq_row(&mut lab, "iPQ & int8 + Quant-Noise", &qn_pq, true, combo_entry)?);

    Row::print_header(&format!("Table 1 — {model} ({task})"));
    for r in &rows {
        r.print();
    }
    Ok(rows)
}

// ================================================================ T2 ===

/// Size under a scheme with sharing/pruning masks (§7.9: shared layers
/// stored once, pruned chunks not stored).
fn masked_bytes(
    lab: &Lab,
    scheme: &QuantSpec,
    share_chunk: usize,
    keep: &[f32],
) -> u64 {
    let meta = &lab.sess.meta;
    let stored = stored_layers(meta.n_layers, share_chunk.max(1), keep);
    let infos = meta.param_infos();
    let mask: Vec<bool> = meta
        .params
        .iter()
        .map(|p| {
            for l in 0..meta.n_layers {
                if p.name.starts_with(&format!("layer{l:02}."))
                    || p.name.starts_with(&format!("block{l:02}."))
                {
                    return stored[l];
                }
            }
            true // non-layer params always stored
        })
        .collect();
    model_bytes_with_mask(&infos, scheme, &mask)
}

/// Table 2: decomposing compression: sharing, pruning, iPQ, Quant-Noise.
pub fn table2(wb: &Workbench, model: &str) -> Result<Vec<Row>> {
    let mut lab = wb.lab(model)?;
    let task = lab.sess.meta.task.clone();
    let steps = wb.scaled(default_steps(&task));
    let n_layers = lab.sess.meta.n_layers;
    let mut base = base_train(&task, steps);
    base.layerdrop = 0.2; // Table 2 models train with LayerDrop

    let mut rows = Vec::new();
    let keep_all = lab.keep_all();
    let prune_keep = every_other_chunk_mask(n_layers, 2);

    // ---- unquantized block
    let orig = lab.train_cached(&base)?;
    let fp = fp32_bytes(&lab);
    rows.push(eval_row(&mut lab, "original", &orig, fp, "eval", &keep_all)?);

    let mut share_cfg = base.clone();
    share_cfg.share_chunk = 2;
    let shared = lab.train_cached(&share_cfg)?;
    let b = masked_bytes(&lab, &QuantSpec::None, 2, &keep_all);
    rows.push(eval_row(&mut lab, "+ sharing", &shared, b, "eval", &keep_all)?);

    let b = masked_bytes(&lab, &QuantSpec::None, 2, &prune_keep);
    rows.push(eval_row(&mut lab, "+ share + prune", &shared, b, "eval", &prune_keep)?);

    // ---- quantized block
    let ipq_cfg = base_ipq(default_ipq_finetune(&task));
    lab.sess.upload_all_params(&orig)?;
    let (q, _) = run_ipq(&mut lab.sess, &orig, lab.train_src.as_mut(), &ipq_cfg)?;
    rows.push(eval_row(&mut lab, "iPQ", &q.store, q.bytes, "eval", &keep_all)?);

    let qn = lab.train_cached(&with_noise(base.clone(), QuantSpec::Proxy, 0.1))?;
    lab.sess.upload_all_params(&qn)?;
    let (q, _) = run_ipq(&mut lab.sess, &qn, lab.train_src.as_mut(), &ipq_cfg)?;
    rows.push(eval_row(&mut lab, "iPQ + Quant-Noise", &q.store, q.bytes, "eval", &keep_all)?);

    let mut qn_share = with_noise(base.clone(), QuantSpec::Proxy, 0.1);
    qn_share.share_chunk = 2;
    let qns = lab.train_cached(&qn_share)?;
    lab.sess.upload_all_params(&qns)?;
    let (q, _) = run_ipq(&mut lab.sess, &qns, lab.train_src.as_mut(), &ipq_cfg)?;
    let pq_scheme = QuantSpec::pq(ipq_cfg.k);
    let b = masked_bytes(&lab, &pq_scheme, 2, &keep_all);
    rows.push(eval_row(&mut lab, "iPQ + QN + share", &q.store, b, "eval", &keep_all)?);

    let b = masked_bytes(&lab, &pq_scheme, 2, &prune_keep);
    rows.push(eval_row(&mut lab, "iPQ + QN + share + prune", &q.store, b, "eval", &prune_keep)?);

    Row::print_header(&format!("Table 2 — {model} ({task})"));
    for r in &rows {
        r.print();
    }
    Ok(rows)
}

// ================================================================ T3 ===

/// Table 3: training with Quant-Noise from scratch vs finetuning an
/// existing model with Quant-Noise (then iPQ).
pub fn table3(wb: &Workbench, model: &str) -> Result<Vec<Row>> {
    let mut lab = wb.lab(model)?;
    let task = lab.sess.meta.task.clone();
    let steps = wb.scaled(default_steps(&task));
    let base = base_train(&task, steps);

    let mut rows = Vec::new();
    // (a) no QN at all
    let plain = lab.train_cached(&base)?;
    rows.push(ipq_row(&mut lab, "train without Quant-Noise", &plain, false, "eval")?);

    // (b) short QN finetune on top of the plain model (paper: ~10 extra
    // epochs). Model the finetune by continuing with QN for 25% steps.
    let mut ft = with_noise(base.clone(), QuantSpec::Proxy, 0.1);
    ft.steps = (steps / 4).max(10);
    ft.seed = base.seed ^ 0xF1;
    // continue from plain (bypass cache: custom continuation)
    lab.sess.upload_all_params(&plain)?;
    lab.sess.zero_hats()?;
    let mut trainer = crate::coordinator::trainer::Trainer::new(&mut lab.sess, plain.clone(), ft);
    trainer.train(lab.train_src.as_mut())?;
    let finetuned = trainer.into_params();
    rows.push(ipq_row(&mut lab, "+ finetune with Quant-Noise", &finetuned, false, "eval")?);

    // (c) QN from scratch
    let qn = lab.train_cached(&with_noise(base, QuantSpec::Proxy, 0.1))?;
    rows.push(ipq_row(&mut lab, "train with Quant-Noise", &qn, false, "eval")?);

    Row::print_header(&format!("Table 3 — {model} ({task})"));
    for r in &rows {
        r.print();
    }
    Ok(rows)
}

// ================================================================ T4 ===

/// Table 4: ±Quant-Noise at fixed compression in small-block and
/// large-block PQ regimes (ResNet-50 stand-in: MicroConv).
pub fn table4(wb: &Workbench, model: &str) -> Result<Vec<Row>> {
    let mut lab = wb.lab(model)?;
    let task = lab.sess.meta.task.clone();
    let steps = wb.scaled(default_steps(&task));
    let base = base_train(&task, steps);

    let plain = lab.train_cached(&base)?;
    let qn = lab.train_cached(&with_noise(base, QuantSpec::Proxy, 0.1))?;

    let mut rows = Vec::new();
    for (regime, overrides) in [
        ("small blocks", BTreeMap::new()),
        (
            "large blocks",
            BTreeMap::from([("conv1x1".to_string(), 8usize), ("cls".to_string(), 8)]),
        ),
    ] {
        for (label, params) in [("no QN (Stock et al.)", &plain), ("Quant-Noise", &qn)] {
            let mut cfg = base_ipq(default_ipq_finetune(&task));
            cfg.block_override = overrides.clone();
            lab.sess.upload_all_params(params)?;
            let (q, _) = run_ipq(&mut lab.sess, params, lab.train_src.as_mut(), &cfg)?;
            let keep = lab.keep_all();
            rows.push(eval_row(
                &mut lab,
                &format!("{regime}: {label}"),
                &q.store,
                q.bytes,
                "eval",
                &keep,
            )?);
        }
    }

    Row::print_header(&format!("Table 4 — {model} ({task})"));
    for r in &rows {
        r.print();
    }
    Ok(rows)
}

// ================================================================ T5 ===

/// Table 5: exact φ_PQ vs φ_proxy vs mean-subvector noise (block
/// selection over subvectors; the paper's cluster-grouped selection is
/// a documented non-reproduction — the in-graph mask draws blocks
/// independently).
pub fn table5(wb: &Workbench, model: &str) -> Result<Vec<Row>> {
    let mut lab = wb.lab(model)?;
    let task = lab.sess.meta.task.clone();
    let steps = wb.scaled(default_steps(&task));
    let base = base_train(&task, steps);

    let mut rows = Vec::new();
    for (label, noise) in [
        ("phi_PQ (exact), subvectors", exact_pq_noise()),
        ("phi_proxy (zero-out), subvectors", QuantSpec::Proxy),
        ("phi_mean (subvector mean), subvectors", QuantSpec::MeanSub),
    ] {
        let params = lab.train_cached(&with_noise(base.clone(), noise, 0.1))?;
        // pre-quantization quality
        let keep = lab.keep_all();
        let ev = lab.eval_params(&params, "eval", &keep)?;
        let (m, mname) = task_metric(&task, &ev);
        println!("  {label}: unquantized {m:.2} {mname}");
        rows.push(ipq_row(&mut lab, label, &params, false, "eval")?);
    }

    Row::print_header(&format!("Table 5 — {model} ({task})"));
    for r in &rows {
        r.print();
    }
    Ok(rows)
}

// =============================================================== T10 ===

/// Table 10: Histogram vs per-channel intN, ± Quant-Noise.
pub fn table10(wb: &Workbench, model: &str) -> Result<Vec<Row>> {
    let mut lab = wb.lab(model)?;
    let task = lab.sess.meta.task.clone();
    let steps = wb.scaled(default_steps(&task));
    let base = base_train(&task, steps);
    let baseline = lab.train_cached(&base)?;

    let mut rows = Vec::new();
    for bits in [4u8, 8] {
        for (observer, mode_label, noise) in [
            // no in-graph histogram kernel exists, so histogram PTQ
            // trains against the per-tensor MinMax noise (as before)
            (IntObserver::Histogram, "histogram", QuantSpec::int(bits, IntObserver::MinMax)),
            (IntObserver::PerChannel, "channel", QuantSpec::int(bits, IntObserver::PerChannel)),
        ] {
            rows.push(int_row(
                &mut lab,
                &format!("int{bits} {mode_label} (post)"),
                &baseline,
                bits,
                observer,
            )?);
            let rate = default_rate(&noise);
            let qn = lab.train_cached(&with_noise(base.clone(), noise, rate))?;
            rows.push(int_row(
                &mut lab,
                &format!("int{bits} {mode_label} + Quant-Noise"),
                &qn,
                bits,
                observer,
            )?);
        }
    }

    Row::print_header(&format!("Table 10 — {model} ({task})"));
    for r in &rows {
        r.print();
    }
    Ok(rows)
}

// =============================================================== T11 ===

/// Table 11: STE through LayerDrop's pruning noise (ablation).
pub fn table11(wb: &Workbench, model: &str) -> Result<Vec<Row>> {
    let mut lab = wb.lab(model)?;
    let task = lab.sess.meta.task.clone();
    let steps = wb.scaled(default_steps(&task));
    let n_layers = lab.sess.meta.n_layers;
    let mut base = with_noise(base_train(&task, steps), QuantSpec::Proxy, 0.1);
    base.layerdrop = 0.2;
    base.share_chunk = 2;

    let prune_keep = every_other_chunk_mask(n_layers, 2);
    let pq_scheme = QuantSpec::pq(64);
    let mut rows = Vec::new();
    for (label, ldste) in
        [("QN + share + prune", false), ("QN + share + prune, LayerDrop STE", true)]
    {
        let mut cfg = base.clone();
        cfg.ldste = ldste;
        let params = lab.train_cached(&cfg)?;
        lab.sess.upload_all_params(&params)?;
        let (q, _) = run_ipq(
            &mut lab.sess,
            &params,
            lab.train_src.as_mut(),
            &base_ipq(default_ipq_finetune(&task)),
        )?;
        let b = masked_bytes(&lab, &pq_scheme, 2, &prune_keep);
        rows.push(eval_row(&mut lab, label, &q.store, b, "eval", &prune_keep)?);
    }

    Row::print_header(&format!("Table 11 — {model} ({task})"));
    for r in &rows {
        r.print();
    }
    Ok(rows)
}

// ---------------------------------------------------------- helpers ---

/// One-shot PQ row (no finetuning) — used by figure sweeps where full
/// iPQ would dominate wall-clock.
pub fn post_pq_row(
    lab: &mut Lab,
    label: &str,
    params: &ParamStore,
    k: usize,
    overrides: BTreeMap<String, usize>,
) -> Result<Row> {
    let mut cfg = base_ipq(0);
    cfg.k = k;
    cfg.block_override = overrides;
    let q = post_pq(params, &lab.sess.meta, &cfg)?;
    let keep = lab.keep_all();
    eval_row(lab, label, &q.store, q.bytes, "eval", &keep)
}

/// Sanity check: param_bits arithmetic used in reports.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::size::{param_bits, ParamInfo};

    #[test]
    fn masked_and_param_bits_consistent() {
        let p = ParamInfo {
            name: "w".into(),
            structure: "ffn".into(),
            numel: 4096,
            rows: 64,
            cols: 64,
            quantized: true,
            pq_block: 8,
        };
        // one stored + one masked == single-param total
        let spec = QuantSpec::int(8, IntObserver::MinMax);
        let both = model_bytes_with_mask(&[p.clone(), p.clone()], &spec, &[true, false]);
        assert_eq!(both, param_bits(&p, &spec) / 8);
    }
}
