//! Minimal HTTP/1.1 message framing for the serving layer.
//!
//! Hand-rolled over `std::io` (the offline registry has no HTTP
//! crates, and the subset we need is small): request-line + headers +
//! `Content-Length` bodies, keep-alive by default on HTTP/1.1, hard
//! caps on header and body size so a hostile peer cannot balloon
//! memory. No chunked encoding, no TLS — `qn serve` fronts a trusted
//! network or a reverse proxy (DESIGN.md §9).

use std::io::{BufRead, Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::Json;

/// Reject request heads (request line + headers) larger than this.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Reject bodies larger than this (a macro-batch of eval requests for
/// the tiny fixtures is a few KB; real token payloads stay well under).
pub const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;

/// One parsed request. `path` excludes the query string.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: String,
    pub body: Vec<u8>,
    pub keep_alive: bool,
}

/// One response to serialize. `Content-Length` and `Connection` are
/// emitted by [`write_response`]; `headers` carries the rest.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: &Json) -> Response {
        Response {
            status,
            headers: vec![("Content-Type".into(), "application/json".into())],
            body: body.to_string().into_bytes(),
        }
    }

    /// The uniform error envelope: `{"error": "..."}`.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(status, &Json::obj(vec![("error", Json::str(msg))]))
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }
}

pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Read one request off a (possibly keep-alive) connection.
/// `Ok(None)` on clean EOF before the first byte; `Err` on anything
/// malformed or over the caps — the caller answers 400 and closes.
pub fn read_request(r: &mut impl BufRead) -> Result<Option<Request>> {
    let mut line = String::new();
    let n = r.read_line(&mut line).context("reading request line")?;
    if n == 0 {
        return Ok(None); // clean close between requests
    }
    ensure!(n <= MAX_HEAD_BYTES, "request line too long");
    let mut parts = line.split_whitespace();
    let method = parts.next().context("empty request line")?.to_string();
    let target = parts.next().context("request line missing target")?.to_string();
    let version = parts.next().context("request line missing version")?;
    ensure!(version.starts_with("HTTP/1."), "unsupported protocol version {version}");
    let mut keep_alive = version == "HTTP/1.1"; // 1.1 defaults to keep-alive
    let mut content_len = 0usize;
    let mut total = n;
    loop {
        let mut h = String::new();
        let n = r.read_line(&mut h).context("reading header")?;
        ensure!(n > 0, "connection closed mid-headers");
        total += n;
        ensure!(total <= MAX_HEAD_BYTES, "headers larger than {MAX_HEAD_BYTES} bytes");
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let Some((name, value)) = h.split_once(':') else {
            bail!("malformed header line");
        };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_len = value.parse().context("bad content-length")?;
        } else if name.eq_ignore_ascii_case("connection") {
            let v = value.to_ascii_lowercase();
            if v.split(',').any(|t| t.trim() == "close") {
                keep_alive = false;
            } else if v.split(',').any(|t| t.trim() == "keep-alive") {
                keep_alive = true;
            }
        }
    }
    ensure!(content_len <= MAX_BODY_BYTES, "body larger than {MAX_BODY_BYTES} bytes");
    let mut body = vec![0u8; content_len];
    r.read_exact(&mut body).context("reading body")?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    Ok(Some(Request { method, path, query, body, keep_alive }))
}

/// Serialize one response. `keep_alive` reflects what the connection
/// loop will actually do, so the header never lies to the client.
pub fn write_response(
    w: &mut impl Write,
    resp: &Response,
    keep_alive: bool,
) -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", resp.status, status_text(resp.status))?;
    for (name, value) in &resp.headers {
        write!(w, "{name}: {value}\r\n")?;
    }
    write!(w, "Content-Length: {}\r\n", resp.body.len())?;
    write!(w, "Connection: {}\r\n", if keep_alive { "keep-alive" } else { "close" })?;
    write!(w, "\r\n")?;
    w.write_all(&resp.body)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(s: &str) -> Result<Option<Request>> {
        read_request(&mut BufReader::new(s.as_bytes()))
    }

    #[test]
    fn parses_post_with_body_and_query() {
        let req = parse(
            "POST /v1/eval?x=1 HTTP/1.1\r\nHost: a\r\nContent-Length: 4\r\n\r\nbody",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/eval");
        assert_eq!(req.query, "x=1");
        assert_eq!(req.body, b"body");
        assert!(req.keep_alive);
    }

    #[test]
    fn connection_close_and_http10() {
        let req = parse("GET /v1/stats HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive);
        let req = parse("GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive);
        let req = parse("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap().unwrap();
        assert!(req.keep_alive);
    }

    #[test]
    fn eof_and_malformed() {
        assert!(parse("").unwrap().is_none());
        assert!(parse("GET\r\n\r\n").is_err()); // no target
        assert!(parse("GET / SPDY/3\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/1.1\r\nbadheader\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/1.1\r\nContent-Length: zap\r\n\r\n").is_err());
        // truncated body
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 9\r\n\r\nabc").is_err());
    }

    #[test]
    fn caps_enforced() {
        let big = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "y".repeat(MAX_HEAD_BYTES));
        assert!(parse(&big).is_err());
        let huge = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(parse(&huge).is_err());
    }

    #[test]
    fn response_roundtrips() {
        let resp = Response::json(200, &Json::obj(vec![("ok", Json::Bool(true))]))
            .with_header("Retry-After", "1");
        let mut out = Vec::new();
        write_response(&mut out, &resp, false).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"), "{s}");
        assert!(s.contains("Content-Type: application/json\r\n"));
        assert!(s.contains("Retry-After: 1\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.ends_with("\r\n\r\n{\"ok\":true}"), "{s}");
        let err = Response::error(429, "queue full");
        assert_eq!(err.status, 429);
        assert_eq!(err.body, br#"{"error":"queue full"}"#);
    }
}
