//! Minimal HTTP/1.1 message framing for the serving layer.
//!
//! Hand-rolled over `std::io` (the offline registry has no HTTP
//! crates, and the subset we need is small): request-line + headers +
//! `Content-Length` bodies, keep-alive by default on HTTP/1.1, hard
//! caps on header and body size so a hostile peer cannot balloon
//! memory. Reads run through a [`DeadlineReader`] with a whole-request
//! deadline, so a slowloris peer dripping one header byte per second
//! cannot pin a worker (a plain per-read socket timeout resets on
//! every byte and never fires against a drip-feed). No chunked
//! encoding, no TLS — `qn serve` fronts a trusted network or a reverse
//! proxy (DESIGN.md §9).

use std::io::{BufRead, ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Reject request heads (request line + headers) larger than this.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Reject bodies larger than this (a macro-batch of eval requests for
/// the tiny fixtures is a few KB; real token payloads stay well under).
pub const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;
/// Body bytes read per chunk between deadline checks.
const BODY_CHUNK: usize = 64 * 1024;

// Wall-clock helpers: `Instant::now` is clippy-banned repo-wide as a
// determinism hazard; deadlines are timing-only and never touch result
// bits, so the allow is carried here once.
#[allow(clippy::disallowed_methods)]
pub fn deadline_after(budget: Duration) -> Instant {
    Instant::now() + budget
}

#[allow(clippy::disallowed_methods)]
pub fn time_left(deadline: Instant) -> Duration {
    deadline.saturating_duration_since(Instant::now())
}

/// A [`TcpStream`] reader that enforces an absolute per-request
/// deadline on top of a per-read socket timeout. Before every read the
/// socket timeout is set to `min(io_timeout, time-to-deadline)`, so a
/// peer dripping bytes still hits the deadline, and a silent peer hits
/// the io timeout. Re-arm the deadline per request with [`arm`].
///
/// [`arm`]: DeadlineReader::arm
pub struct DeadlineReader {
    stream: TcpStream,
    io_timeout: Duration,
    deadline: Option<Instant>,
}

impl DeadlineReader {
    pub fn new(stream: TcpStream, io_timeout: Duration) -> DeadlineReader {
        DeadlineReader { stream, io_timeout, deadline: None }
    }

    /// Start a fresh deadline `budget` from now (call at the top of
    /// every keep-alive request — this is also the idle cap).
    pub fn arm(&mut self, budget: Duration) {
        self.deadline = Some(deadline_after(budget));
    }
}

impl Read for DeadlineReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let mut t = self.io_timeout;
        if let Some(d) = self.deadline {
            let left = time_left(d);
            if left.is_zero() {
                return Err(std::io::Error::new(
                    ErrorKind::TimedOut,
                    "request read deadline exceeded",
                ));
            }
            t = t.min(left);
        }
        // set_read_timeout(ZERO) would mean "block forever" — clamp up
        self.stream.set_read_timeout(Some(t.max(Duration::from_millis(1))))?;
        self.stream.read(buf)
    }
}

/// One parsed request. `path` excludes the query string.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: String,
    pub body: Vec<u8>,
    pub keep_alive: bool,
}

/// One response to serialize. `Content-Length` and `Connection` are
/// emitted by [`write_response`]; `headers` carries the rest.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: &Json) -> Response {
        Response {
            status,
            headers: vec![("Content-Type".into(), "application/json".into())],
            body: body.to_string().into_bytes(),
        }
    }

    /// The uniform error envelope: `{"error": "..."}`.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(status, &Json::obj(vec![("error", Json::str(msg))]))
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }
}

pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Why a request could not be read. `timeout` distinguishes deadline
/// expiry (idle keep-alive or a slow peer) from protocol garbage;
/// `started` distinguishes a silent idle connection (close quietly)
/// from a peer that began a request and stalled (answer 408).
#[derive(Debug)]
pub struct RequestError {
    pub timeout: bool,
    pub started: bool,
    pub err: anyhow::Error,
}

impl RequestError {
    fn from_io(e: std::io::Error, started: bool, what: &str) -> RequestError {
        let timeout = matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut);
        RequestError { timeout, started, err: anyhow::Error::new(e).context(what.to_string()) }
    }

    fn expired(started: bool, what: &str) -> RequestError {
        RequestError { timeout: true, started, err: anyhow::anyhow!("{what}: deadline exceeded") }
    }

    fn malformed(started: bool, msg: String) -> RequestError {
        RequestError { timeout: false, started, err: anyhow::anyhow!(msg) }
    }
}

/// Read one request off a (possibly keep-alive) connection, spending at
/// most `budget` wall clock. `Ok(None)` on clean EOF before the first
/// byte; `Err` on timeout, caps, or anything malformed.
///
/// The budget is enforced twice: byte-level by [`DeadlineReader`] when
/// the reader wraps one (the real slowloris guard), and here between
/// header lines / body chunks as defense when it does not (tests,
/// non-socket readers).
pub fn read_request(
    r: &mut impl BufRead,
    budget: Duration,
) -> Result<Option<Request>, RequestError> {
    let deadline = deadline_after(budget);
    let mut line = String::new();
    let n = match r.read_line(&mut line) {
        Ok(n) => n,
        Err(e) => return Err(RequestError::from_io(e, !line.is_empty(), "reading request line")),
    };
    if n == 0 {
        return Ok(None); // clean close between requests
    }
    let started = true;
    if n > MAX_HEAD_BYTES {
        return Err(RequestError::malformed(started, "request line too long".into()));
    }
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| RequestError::malformed(started, "empty request line".into()))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| RequestError::malformed(started, "request line missing target".into()))?
        .to_string();
    let version = parts
        .next()
        .ok_or_else(|| RequestError::malformed(started, "request line missing version".into()))?;
    if !version.starts_with("HTTP/1.") {
        return Err(RequestError::malformed(
            started,
            format!("unsupported protocol version {version}"),
        ));
    }
    let mut keep_alive = version == "HTTP/1.1"; // 1.1 defaults to keep-alive
    let mut content_len = 0usize;
    let mut total = n;
    loop {
        if time_left(deadline).is_zero() {
            return Err(RequestError::expired(started, "reading headers"));
        }
        let mut h = String::new();
        let n = match r.read_line(&mut h) {
            Ok(n) => n,
            Err(e) => return Err(RequestError::from_io(e, started, "reading header")),
        };
        if n == 0 {
            return Err(RequestError::malformed(started, "connection closed mid-headers".into()));
        }
        total += n;
        if total > MAX_HEAD_BYTES {
            return Err(RequestError::malformed(
                started,
                format!("headers larger than {MAX_HEAD_BYTES} bytes"),
            ));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let Some((name, value)) = h.split_once(':') else {
            return Err(RequestError::malformed(started, "malformed header line".into()));
        };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_len = value.parse().map_err(|_| {
                RequestError::malformed(started, format!("bad content-length '{value}'"))
            })?;
        } else if name.eq_ignore_ascii_case("connection") {
            let v = value.to_ascii_lowercase();
            if v.split(',').any(|t| t.trim() == "close") {
                keep_alive = false;
            } else if v.split(',').any(|t| t.trim() == "keep-alive") {
                keep_alive = true;
            }
        }
    }
    if content_len > MAX_BODY_BYTES {
        return Err(RequestError::malformed(
            started,
            format!("body larger than {MAX_BODY_BYTES} bytes"),
        ));
    }
    // chunked body read with deadline checks between chunks, so a peer
    // that sends headers fast then drips the body still times out
    let mut body = vec![0u8; content_len];
    let mut off = 0usize;
    while off < content_len {
        if time_left(deadline).is_zero() {
            return Err(RequestError::expired(started, "reading body"));
        }
        let end = (off + BODY_CHUNK).min(content_len);
        match r.read_exact(&mut body[off..end]) {
            Ok(()) => off = end,
            Err(e) => return Err(RequestError::from_io(e, started, "reading body")),
        }
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    Ok(Some(Request { method, path, query, body, keep_alive }))
}

/// Serialize one response. `keep_alive` reflects what the connection
/// loop will actually do, so the header never lies to the client.
pub fn write_response(
    w: &mut impl Write,
    resp: &Response,
    keep_alive: bool,
) -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", resp.status, status_text(resp.status))?;
    for (name, value) in &resp.headers {
        write!(w, "{name}: {value}\r\n")?;
    }
    write!(w, "Content-Length: {}\r\n", resp.body.len())?;
    write!(w, "Connection: {}\r\n", if keep_alive { "keep-alive" } else { "close" })?;
    write!(w, "\r\n")?;
    w.write_all(&resp.body)?;
    w.flush()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(s: &str) -> Result<Option<Request>, RequestError> {
        read_request(&mut BufReader::new(s.as_bytes()), Duration::from_secs(5))
    }

    #[test]
    fn parses_post_with_body_and_query() {
        let req = parse(
            "POST /v1/eval?x=1 HTTP/1.1\r\nHost: a\r\nContent-Length: 4\r\n\r\nbody",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/eval");
        assert_eq!(req.query, "x=1");
        assert_eq!(req.body, b"body");
        assert!(req.keep_alive);
    }

    #[test]
    fn connection_close_and_http10() {
        let req = parse("GET /v1/stats HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive);
        let req = parse("GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive);
        let req = parse("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap().unwrap();
        assert!(req.keep_alive);
    }

    #[test]
    fn eof_and_malformed() {
        assert!(parse("").unwrap().is_none());
        assert!(parse("GET\r\n\r\n").is_err()); // no target
        assert!(parse("GET / SPDY/3\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/1.1\r\nbadheader\r\n\r\n").is_err());
        assert!(parse("GET / HTTP/1.1\r\nContent-Length: zap\r\n\r\n").is_err());
        // truncated body
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 9\r\n\r\nabc").is_err());
        // none of the above are timeouts
        let e = parse("GET / SPDY/3\r\n\r\n").unwrap_err();
        assert!(!e.timeout && e.started);
    }

    #[test]
    fn caps_enforced() {
        let big = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "y".repeat(MAX_HEAD_BYTES));
        assert!(parse(&big).is_err());
        let huge = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(parse(&huge).is_err());
    }

    #[test]
    fn expired_budget_is_a_started_timeout() {
        let req = "POST / HTTP/1.1\r\nContent-Length: 4\r\n\r\nbody";
        let e = read_request(&mut BufReader::new(req.as_bytes()), Duration::ZERO)
            .expect_err("zero budget must expire");
        assert!(e.timeout, "{:#}", e.err);
        assert!(e.started);
    }

    #[test]
    fn status_text_covers_new_codes() {
        assert_eq!(status_text(408), "Request Timeout");
        assert_eq!(status_text(429), "Too Many Requests");
    }

    #[test]
    fn response_roundtrips() {
        let resp = Response::json(200, &Json::obj(vec![("ok", Json::Bool(true))]))
            .with_header("Retry-After", "1");
        let mut out = Vec::new();
        write_response(&mut out, &resp, false).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"), "{s}");
        assert!(s.contains("Content-Type: application/json\r\n"));
        assert!(s.contains("Retry-After: 1\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.ends_with("\r\n\r\n{\"ok\":true}"), "{s}");
        let err = Response::error(429, "queue full");
        assert_eq!(err.status, 429);
        assert_eq!(err.body, br#"{"error":"queue full"}"#);
    }
}
