//! Serving metrics: per-route request/error counters + latency
//! histograms, and batcher-side coalescing statistics.
//!
//! Everything is lock-free ([`AtomicU64`] counters and the power-of-two
//! [`Hist`] from `interp/stats.rs`) so the HTTP workers never contend
//! on a metrics mutex. `GET /v1/stats` renders a snapshot; counters are
//! monotone since server start.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::runtime::interp::stats::Hist;
use crate::util::json::Json;

/// Metric label for a request, derived from the routing outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    Eval,
    Quantize,
    Reencode,
    Upload,
    Models,
    Stats,
    /// 404/405 and anything else that never reached a handler.
    Other,
}

impl Route {
    pub fn name(self) -> &'static str {
        match self {
            Route::Eval => "eval",
            Route::Quantize => "quantize",
            Route::Reencode => "reencode",
            Route::Upload => "upload",
            Route::Models => "models",
            Route::Stats => "stats",
            Route::Other => "other",
        }
    }
}

const ALL_ROUTES: [Route; 7] = [
    Route::Eval,
    Route::Quantize,
    Route::Reencode,
    Route::Upload,
    Route::Models,
    Route::Stats,
    Route::Other,
];

#[derive(Debug, Default)]
pub struct RouteStats {
    pub requests: AtomicU64,
    /// Responses with status >= 400.
    pub errors: AtomicU64,
    /// Wall time from parsed request to serialized response.
    pub latency_ns: Hist,
}

#[derive(Debug, Default)]
pub struct Metrics {
    eval: RouteStats,
    quantize: RouteStats,
    reencode: RouteStats,
    upload: RouteStats,
    models: RouteStats,
    stats: RouteStats,
    other: RouteStats,
    /// 429s from the admission queue.
    pub rejected: AtomicU64,
    /// 429s from the per-model admission quota specifically.
    pub rejected_quota: AtomicU64,
    /// Requests that blew their read/write deadline (408s and idle
    /// keep-alive closes after a started request).
    pub timeouts: AtomicU64,
    /// Macro-batches executed by the batcher.
    pub batches: AtomicU64,
    /// Eval requests that rode those macro-batches.
    pub batched_requests: AtomicU64,
    /// Requests that shared a macro-batch with at least one stranger.
    pub coalesced_requests: AtomicU64,
    /// Largest macro-batch observed (the coalescing witness).
    pub max_batch: AtomicU64,
    pub batch_size: Hist,
    /// Time eval jobs spent queued before their batch started.
    pub queue_wait_ns: Hist,
    /// Successful `/reencode` (and first-publish `/quantize`) swaps.
    pub swaps: AtomicU64,
}

impl Metrics {
    pub fn route(&self, r: Route) -> &RouteStats {
        match r {
            Route::Eval => &self.eval,
            Route::Quantize => &self.quantize,
            Route::Reencode => &self.reencode,
            Route::Upload => &self.upload,
            Route::Models => &self.models,
            Route::Stats => &self.stats,
            Route::Other => &self.other,
        }
    }

    /// Record one finished request.
    pub fn observe(&self, r: Route, status: u16, latency_ns: u64) {
        let rs = self.route(r);
        rs.requests.fetch_add(1, Ordering::Relaxed);
        if status >= 400 {
            rs.errors.fetch_add(1, Ordering::Relaxed);
        }
        if status == 429 {
            self.rejected.fetch_add(1, Ordering::Relaxed);
        }
        rs.latency_ns.record(latency_ns);
    }

    /// Record one executed macro-batch of `m` coalesced eval jobs.
    pub fn note_batch(&self, m: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(m as u64, Ordering::Relaxed);
        if m > 1 {
            self.coalesced_requests.fetch_add(m as u64, Ordering::Relaxed);
        }
        self.max_batch.fetch_max(m as u64, Ordering::Relaxed);
        self.batch_size.record(m as u64);
    }

    /// The `/v1/stats` payload, minus queue depth (owned by the caller).
    pub fn to_json(&self) -> Json {
        fn us(ns: u64) -> Json {
            Json::num((ns / 1_000) as f64)
        }
        let routes = ALL_ROUTES
            .iter()
            .map(|&r| {
                let rs = self.route(r);
                let j = Json::obj(vec![
                    ("requests", Json::num(rs.requests.load(Ordering::Relaxed) as f64)),
                    ("errors", Json::num(rs.errors.load(Ordering::Relaxed) as f64)),
                    ("p50_us", us(rs.latency_ns.quantile(0.5))),
                    ("p99_us", us(rs.latency_ns.quantile(0.99))),
                ]);
                (r.name().to_string(), j)
            })
            .collect();
        Json::obj(vec![
            ("routes", Json::Obj(routes)),
            (
                "batching",
                Json::obj(vec![
                    ("batches", Json::num(self.batches.load(Ordering::Relaxed) as f64)),
                    ("requests", Json::num(self.batched_requests.load(Ordering::Relaxed) as f64)),
                    (
                        "coalesced_requests",
                        Json::num(self.coalesced_requests.load(Ordering::Relaxed) as f64),
                    ),
                    ("max_batch", Json::num(self.max_batch.load(Ordering::Relaxed) as f64)),
                    ("p50_batch", Json::num(self.batch_size.quantile(0.5) as f64)),
                    ("p50_queue_wait_us", us(self.queue_wait_ns.quantile(0.5))),
                    ("p99_queue_wait_us", us(self.queue_wait_ns.quantile(0.99))),
                ]),
            ),
            ("swaps", Json::num(self.swaps.load(Ordering::Relaxed) as f64)),
            ("rejected", Json::num(self.rejected.load(Ordering::Relaxed) as f64)),
            ("rejected_quota", Json::num(self.rejected_quota.load(Ordering::Relaxed) as f64)),
            ("timeouts", Json::num(self.timeouts.load(Ordering::Relaxed) as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_counts_and_classifies() {
        let m = Metrics::default();
        m.observe(Route::Eval, 200, 1_000);
        m.observe(Route::Eval, 503, 2_000);
        m.observe(Route::Other, 429, 500);
        let rs = m.route(Route::Eval);
        assert_eq!(rs.requests.load(Ordering::Relaxed), 2);
        assert_eq!(rs.errors.load(Ordering::Relaxed), 1);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 1);
        assert_eq!(rs.latency_ns.count(), 2);
    }

    #[test]
    fn note_batch_tracks_coalescing() {
        let m = Metrics::default();
        m.note_batch(1);
        m.note_batch(4);
        m.note_batch(2);
        assert_eq!(m.batches.load(Ordering::Relaxed), 3);
        assert_eq!(m.batched_requests.load(Ordering::Relaxed), 7);
        assert_eq!(m.coalesced_requests.load(Ordering::Relaxed), 6);
        assert_eq!(m.max_batch.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn stats_json_has_all_routes() {
        let m = Metrics::default();
        m.observe(Route::Stats, 200, 10);
        let j = m.to_json();
        let s = j.to_string();
        for name in ["eval", "quantize", "reencode", "upload", "models", "stats", "other"] {
            assert!(s.contains(&format!("\"{name}\"")), "{s}");
        }
        assert_eq!(j.get_path("routes.stats.requests").as_f64(), Some(1.0));
        assert_eq!(j.get_path("timeouts").as_f64(), Some(0.0));
        assert_eq!(j.get_path("rejected_quota").as_f64(), Some(0.0));
    }
}
