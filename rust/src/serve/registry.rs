//! Model registry: named, atomically swappable parameter snapshots.
//!
//! Each served model keeps two things: the pristine fp32 weights it
//! was loaded (or uploaded) with, and the *served* snapshot — an
//! `Arc<ServedState>` behind an `RwLock`. Eval batches clone the Arc
//! (a pointer copy) and run against an immutable snapshot, so an
//! online `/reencode` swap never blocks or torments in-flight work:
//! requests see wholly-pre-swap or wholly-post-swap weights, nothing
//! in between. Re-encodes always fit on the pristine fp32 copy —
//! re-quantizing a dequantized model is generation loss.
//!
//! The registry itself is append-only (models are added by manifest
//! load and `/v1/quantize`, never removed), which keeps id lookups
//! race-free without generation counters.

use std::collections::BTreeMap;
use std::sync::{Arc, PoisonError, RwLock};

use anyhow::{Context, Result};

use crate::coordinator::quantize::scheme_bytes;
use crate::model::config::ModelMeta;
use crate::model::params::ParamStore;
use crate::quant::scheme::QuantSpec;
use crate::runtime::manifest::Manifest;

/// One immutable published snapshot of a served model.
#[derive(Debug)]
pub struct ServedState {
    pub params: Arc<ParamStore>,
    /// Canonical `QuantSpec` string ("none" for raw fp32).
    pub scheme: String,
    /// Exact storage accounting under `scheme`.
    pub bytes: u64,
    /// Total squared reconstruction error vs the fp32 weights.
    pub sq_error: f64,
    /// Bumped on every swap; echoed in eval responses so clients can
    /// attribute each result to a snapshot.
    pub version: u64,
}

#[derive(Debug)]
pub struct ServedModel {
    pub meta: ModelMeta,
    /// Pristine fp32 weights — the source every re-encode fits on.
    pub fp: Arc<ParamStore>,
    /// fp32 storage bytes (the compression-ratio denominator).
    pub fp_bytes: u64,
    state: RwLock<Arc<ServedState>>,
}

impl ServedModel {
    pub fn new(meta: ModelMeta, fp: Arc<ParamStore>, fp_bytes: u64, state: ServedState) -> Self {
        ServedModel { meta, fp, fp_bytes, state: RwLock::new(Arc::new(state)) }
    }

    /// The current snapshot (pointer clone; holds no lock afterwards).
    ///
    /// Snapshots are published whole (one `Arc` store under the lock),
    /// so a panicked writer cannot leave torn state — recover from
    /// poisoning instead of propagating it to every later request.
    pub fn snapshot(&self) -> Arc<ServedState> {
        self.state.read().unwrap_or_else(PoisonError::into_inner).clone()
    }

    /// Atomically publish a new snapshot; returns its version.
    pub fn swap(&self, params: ParamStore, scheme: String, bytes: u64, sq_error: f64) -> u64 {
        let mut guard = self.state.write().unwrap_or_else(PoisonError::into_inner);
        let version = guard.version + 1;
        *guard = Arc::new(ServedState {
            params: Arc::new(params),
            scheme,
            bytes,
            sq_error,
            version,
        });
        version
    }
}

pub struct Registry {
    models: RwLock<BTreeMap<String, Arc<ServedModel>>>,
}

impl Registry {
    /// Load every manifest model's init params and serve them as fp32
    /// (`scheme: "none"`, version 1).
    pub fn from_manifest(manifest: &Manifest) -> Result<Registry> {
        let mut models = BTreeMap::new();
        for (name, meta) in &manifest.models {
            let params = ParamStore::load_qnp1(&manifest.init_path(meta))
                .with_context(|| format!("loading init params for {name}"))?;
            params.check_against(meta)?;
            let fp = Arc::new(params);
            let fp_bytes = scheme_bytes(meta, &QuantSpec::None);
            let state = ServedState {
                params: fp.clone(), // served == pristine until a swap
                scheme: QuantSpec::None.to_string(),
                bytes: fp_bytes,
                sq_error: 0.0,
                version: 1,
            };
            models.insert(
                name.clone(),
                Arc::new(ServedModel::new(meta.clone(), fp, fp_bytes, state)),
            );
        }
        Ok(Registry { models: RwLock::new(models) })
    }

    #[cfg(test)]
    pub fn empty() -> Registry {
        Registry { models: RwLock::new(BTreeMap::new()) }
    }

    pub fn get(&self, id: &str) -> Option<Arc<ServedModel>> {
        self.models.read().unwrap_or_else(PoisonError::into_inner).get(id).cloned()
    }

    pub fn ids(&self) -> Vec<String> {
        self.models.read().unwrap_or_else(PoisonError::into_inner).keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap_or_else(PoisonError::into_inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Register a new id; `Err` (⇒ 409) if it already exists. The check
    /// and insert are one critical section, so two concurrent uploads
    /// of the same id cannot both win.
    pub fn insert_new(&self, id: &str, model: ServedModel) -> Result<(), ()> {
        let mut models = self.models.write().unwrap_or_else(PoisonError::into_inner);
        if models.contains_key(id) {
            return Err(());
        }
        models.insert(id.to_string(), Arc::new(model));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tensor::Tensor;

    fn tiny_meta() -> ModelMeta {
        // metas in unit tests only need params/name; use the real
        // fixture loader in integration tests instead
        crate::model::config::ModelMeta {
            name: "m".into(),
            task: "lm".into(),
            n_layers: 1,
            batch: 1,
            seq_len: 2,
            tokens_shape: vec![1, 2],
            targets_shape: vec![1, 2],
            vocab: 4,
            n_classes: 0,
            params: vec![],
            entries: vec![],
            init_file: "init.qnp1".into(),
        }
    }

    fn store() -> ParamStore {
        let mut s = ParamStore::new();
        s.insert("w", Tensor::from_vec(&[2], vec![1.0, 2.0]));
        s
    }

    #[test]
    fn swap_bumps_version_and_old_snapshots_stay_valid() {
        let fp = Arc::new(store());
        let state = ServedState {
            params: fp.clone(),
            scheme: "none".into(),
            bytes: 8,
            sq_error: 0.0,
            version: 1,
        };
        let m = ServedModel::new(tiny_meta(), fp, 8, state);
        let before = m.snapshot();
        let v2 = m.swap(store(), "int8_tensor".into(), 2, 0.5);
        assert_eq!(v2, 2);
        let after = m.snapshot();
        assert_eq!(before.version, 1); // old Arc still readable
        assert_eq!(before.scheme, "none");
        assert_eq!(after.version, 2);
        assert_eq!(after.scheme, "int8_tensor");
        assert_eq!(m.swap(store(), "none".into(), 8, 0.0), 3);
    }

    #[test]
    fn insert_new_rejects_duplicates() {
        let reg = Registry::empty();
        let mk = || {
            let fp = Arc::new(store());
            let st = ServedState {
                params: fp.clone(),
                scheme: "none".into(),
                bytes: 8,
                sq_error: 0.0,
                version: 1,
            };
            ServedModel::new(tiny_meta(), fp, 8, st)
        };
        assert!(reg.insert_new("a", mk()).is_ok());
        assert!(reg.insert_new("a", mk()).is_err());
        assert_eq!(reg.ids(), vec!["a".to_string()]);
        assert!(reg.get("a").is_some());
        assert!(reg.get("b").is_none());
    }
}
