//! URL routing for the `qn serve` API surface.
//!
//! Seven routes, one dynamic segment — a hand-matched prefix tree beats
//! a table-driven router at this size and keeps 405-vs-404 semantics
//! explicit (wrong method on a known path is 405, unknown path is 404).

/// A successfully matched route; dynamic segments are extracted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteMatch {
    /// `POST /v1/eval`
    Eval,
    /// `POST /v1/quantize`
    Quantize,
    /// `POST /v1/models/{id}/reencode`
    Reencode(String),
    /// `POST /v1/models/{id}/params` — checksum-validated weight upload
    Upload(String),
    /// `GET /v1/models`
    Models,
    /// `GET /v1/models/{id}`
    ModelInfo(String),
    /// `GET /v1/stats`
    Stats,
}

/// Match a method + path to a route, or the HTTP status to answer
/// with (404 unknown path, 405 known path / wrong method).
pub fn route(method: &str, path: &str) -> Result<RouteMatch, u16> {
    let get = method == "GET";
    let post = method == "POST";
    let only = |ok: bool, m: RouteMatch| if ok { Ok(m) } else { Err(405) };
    match path {
        "/v1/eval" => only(post, RouteMatch::Eval),
        "/v1/quantize" => only(post, RouteMatch::Quantize),
        "/v1/models" => only(get, RouteMatch::Models),
        "/v1/stats" => only(get, RouteMatch::Stats),
        _ => {
            if let Some(rest) = path.strip_prefix("/v1/models/") {
                if let Some(id) = rest.strip_suffix("/reencode") {
                    if !id.is_empty() && !id.contains('/') {
                        return only(post, RouteMatch::Reencode(id.to_string()));
                    }
                } else if let Some(id) = rest.strip_suffix("/params") {
                    if !id.is_empty() && !id.contains('/') {
                        return only(post, RouteMatch::Upload(id.to_string()));
                    }
                } else if !rest.is_empty() && !rest.contains('/') {
                    return only(get, RouteMatch::ModelInfo(rest.to_string()));
                }
            }
            Err(404)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_routes() {
        assert_eq!(route("POST", "/v1/eval"), Ok(RouteMatch::Eval));
        assert_eq!(route("POST", "/v1/quantize"), Ok(RouteMatch::Quantize));
        assert_eq!(route("GET", "/v1/models"), Ok(RouteMatch::Models));
        assert_eq!(route("GET", "/v1/stats"), Ok(RouteMatch::Stats));
    }

    #[test]
    fn dynamic_routes() {
        assert_eq!(route("GET", "/v1/models/lm_tiny"), Ok(RouteMatch::ModelInfo("lm_tiny".into())));
        assert_eq!(
            route("POST", "/v1/models/lm_tiny@pq:k=8/reencode"),
            Ok(RouteMatch::Reencode("lm_tiny@pq:k=8".into()))
        );
        assert_eq!(
            route("POST", "/v1/models/lm_tiny/params"),
            Ok(RouteMatch::Upload("lm_tiny".into()))
        );
    }

    #[test]
    fn wrong_method_is_405_unknown_is_404() {
        assert_eq!(route("GET", "/v1/eval"), Err(405));
        assert_eq!(route("POST", "/v1/models"), Err(405));
        assert_eq!(route("POST", "/v1/models/x"), Err(405));
        assert_eq!(route("GET", "/v1/models/x/reencode"), Err(405));
        assert_eq!(route("GET", "/v1/models/x/params"), Err(405));
        assert_eq!(route("POST", "/v1/models//params"), Err(404));
        assert_eq!(route("GET", "/"), Err(404));
        assert_eq!(route("GET", "/v1/models/"), Err(404));
        assert_eq!(route("POST", "/v1/models//reencode"), Err(404));
        assert_eq!(route("GET", "/v1/models/a/b"), Err(404));
        assert_eq!(route("DELETE", "/v1/eval"), Err(405));
    }
}
