//! Bounded FIFO admission queue that coalesces eval requests into
//! macro-batches.
//!
//! HTTP workers [`AdmissionQueue::push`] one [`EvalJob`] per `/v1/eval`
//! request and block on a rendezvous channel for the outcome; the
//! single batcher thread [`AdmissionQueue::pop_batch`]es up to
//! `max_batch` jobs *for the same model* off the front, preserving
//! arrival order. Determinism note: batching composition never affects
//! response bits — `execute_f32_batched` guarantees each shard's result
//! is independent of its co-batched neighbours (DESIGN.md §4), so the
//! queue is free to group greedily.
//!
//! Backpressure: `push` fails fast when `max_queue` jobs are already
//! waiting (the handler answers 429 + `Retry-After`) instead of letting
//! latency grow without bound. `close` wakes the batcher; it drains
//! what's left and then gets `None`.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Eval input matching [`crate::runtime::executable::BatchInput`]:
/// token tasks feed i32, image tasks feed f32.
#[derive(Debug, Clone)]
pub enum JobInput {
    Tokens(Vec<i32>),
    Pixels(Vec<f32>),
}

/// What the batcher sends back on the job's rendezvous channel.
#[derive(Debug, Clone)]
pub enum JobOutcome {
    Done { sum_nll: f64, sum_correct: f64, batch_size: usize, version: u64 },
    Failed { status: u16, msg: String },
}

#[derive(Debug)]
pub struct EvalJob {
    pub model: String,
    pub input: JobInput,
    pub targets: Vec<i32>,
    pub resp: std::sync::mpsc::SyncSender<JobOutcome>,
    /// For the queue-wait histogram only — never reaches results.
    pub enqueued_at: std::time::Instant,
}

/// Why a push was refused (maps to 429 / 503 respectively).
#[derive(Debug)]
pub enum PushError {
    Full(EvalJob),
    Closed(EvalJob),
}

struct Inner {
    q: VecDeque<EvalJob>,
    closed: bool,
}

pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    not_empty: Condvar,
    max_queue: usize,
}

impl AdmissionQueue {
    pub fn new(max_queue: usize) -> AdmissionQueue {
        AdmissionQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            max_queue: max_queue.max(1),
        }
    }

    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    /// Admit a job, or hand it back if the queue is full / closed.
    pub fn push(&self, job: EvalJob) -> Result<(), PushError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(PushError::Closed(job));
        }
        if inner.q.len() >= self.max_queue {
            return Err(PushError::Full(job));
        }
        inner.q.push_back(job);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Stop admitting; wake the batcher so it can drain and exit.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    /// Take the next macro-batch: up to `max_batch` jobs for the model
    /// at the front of the queue, in arrival order. Jobs for other
    /// models keep their relative order for the next call. Blocks while
    /// empty; once non-empty, waits up to `linger` for stragglers to
    /// coalesce. Returns `None` only when closed *and* drained.
    ///
    /// Single-consumer: exactly one batcher thread calls this (the
    /// queue never shrinks under us between the waits below).
    pub fn pop_batch(&self, max_batch: usize, linger: Duration) -> Option<Vec<EvalJob>> {
        let max_batch = max_batch.max(1);
        let mut inner = self.inner.lock().unwrap();
        while inner.q.is_empty() {
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
        if !linger.is_zero() && !inner.closed {
            // deadline math is scheduling-only and never reaches result
            // bits, hence the determinism-lint exemption
            #[allow(clippy::disallowed_methods)]
            let deadline = std::time::Instant::now() + linger;
            loop {
                let head = &inner.q.front().expect("queue non-empty").model;
                let ready = inner.q.iter().filter(|j| &j.model == head).count();
                if ready >= max_batch || inner.closed {
                    break;
                }
                #[allow(clippy::disallowed_methods)]
                let now = std::time::Instant::now();
                let Some(left) = deadline.checked_duration_since(now).filter(|d| !d.is_zero())
                else {
                    break;
                };
                let (guard, timeout) = self.not_empty.wait_timeout(inner, left).unwrap();
                inner = guard;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        let head = inner.q.front().expect("queue non-empty").model.clone();
        let mut batch = Vec::new();
        let mut rest = VecDeque::with_capacity(inner.q.len());
        while let Some(job) = inner.q.pop_front() {
            if batch.len() < max_batch && job.model == head {
                batch.push(job);
            } else {
                rest.push_back(job);
            }
        }
        inner.q = rest;
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    fn job(model: &str, tag: i32) -> EvalJob {
        // outcome channel unused here: queue tests never run a batcher
        let (tx, _rx) = sync_channel(1);
        #[allow(clippy::disallowed_methods)]
        let now = std::time::Instant::now();
        EvalJob {
            model: model.to_string(),
            input: JobInput::Tokens(vec![tag]),
            targets: vec![tag],
            resp: tx,
            enqueued_at: now,
        }
    }

    fn tags(batch: &[EvalJob]) -> Vec<i32> {
        batch.iter().map(|j| j.targets[0]).collect()
    }

    #[test]
    fn fifo_order_within_and_across_batches() {
        let q = AdmissionQueue::new(16);
        for i in 0..5 {
            q.push(job("a", i)).unwrap();
        }
        let b1 = q.pop_batch(3, Duration::ZERO).unwrap();
        assert_eq!(tags(&b1), vec![0, 1, 2]);
        let b2 = q.pop_batch(3, Duration::ZERO).unwrap();
        assert_eq!(tags(&b2), vec![3, 4]);
    }

    #[test]
    fn batches_split_by_model_preserving_order() {
        let q = AdmissionQueue::new(16);
        q.push(job("a", 0)).unwrap();
        q.push(job("b", 1)).unwrap();
        q.push(job("a", 2)).unwrap();
        q.push(job("b", 3)).unwrap();
        let b1 = q.pop_batch(8, Duration::ZERO).unwrap();
        assert_eq!(tags(&b1), vec![0, 2]); // both "a" jobs, arrival order
        let b2 = q.pop_batch(8, Duration::ZERO).unwrap();
        assert_eq!(tags(&b2), vec![1, 3]); // "b" kept its relative order
    }

    #[test]
    fn push_bounded_then_accepts_after_drain() {
        let q = AdmissionQueue::new(2);
        q.push(job("a", 0)).unwrap();
        q.push(job("a", 1)).unwrap();
        match q.push(job("a", 2)) {
            Err(PushError::Full(j)) => assert_eq!(j.targets[0], 2),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.depth(), 2);
        let _ = q.pop_batch(8, Duration::ZERO).unwrap();
        q.push(job("a", 3)).unwrap();
    }

    #[test]
    fn close_drains_then_none_and_rejects_pushes() {
        let q = AdmissionQueue::new(8);
        q.push(job("a", 0)).unwrap();
        q.close();
        match q.push(job("a", 1)) {
            Err(PushError::Closed(_)) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
        let b = q.pop_batch(8, Duration::from_millis(50)).unwrap();
        assert_eq!(tags(&b), vec![0]);
        assert!(q.pop_batch(8, Duration::ZERO).is_none());
    }

    #[test]
    fn pop_blocks_until_push_across_threads() {
        let q = AdmissionQueue::new(8);
        std::thread::scope(|s| {
            let consumer = s.spawn(|| q.pop_batch(4, Duration::from_millis(20)));
            std::thread::sleep(Duration::from_millis(30));
            q.push(job("a", 7)).unwrap();
            let got = consumer.join().unwrap().unwrap();
            assert_eq!(tags(&got), vec![7]);
        });
    }

    #[test]
    fn linger_coalesces_late_arrivals() {
        let q = AdmissionQueue::new(8);
        std::thread::scope(|s| {
            let consumer = s.spawn(|| q.pop_batch(4, Duration::from_millis(300)));
            std::thread::sleep(Duration::from_millis(10));
            for i in 0..4 {
                q.push(job("a", i)).unwrap();
                std::thread::sleep(Duration::from_millis(5));
            }
            let got = consumer.join().unwrap().unwrap();
            // all four arrived within the linger window ⇒ one batch
            assert_eq!(tags(&got), vec![0, 1, 2, 3]);
        });
    }
}
