//! Bounded FIFO admission queue that coalesces eval requests into
//! macro-batches.
//!
//! HTTP workers [`AdmissionQueue::push`] one [`EvalJob`] per `/v1/eval`
//! request and block on a rendezvous channel for the outcome; the
//! single batcher thread [`AdmissionQueue::pop_batch`]es up to
//! `max_batch` jobs *for the same model* off the front, preserving
//! arrival order. Determinism note: batching composition never affects
//! response bits — `execute_f32_batched` guarantees each shard's result
//! is independent of its co-batched neighbours (DESIGN.md §4), so the
//! queue is free to group greedily.
//!
//! Backpressure: `push` fails fast when `max_queue` jobs are already
//! waiting, or when one model has `max_per_model` jobs queued (the
//! per-model admission quota: one hot model cannot starve the rest of
//! the fleet) — the handler answers 429 + `Retry-After` instead of
//! letting latency grow without bound. `close` wakes the batcher; it
//! drains what's left and then gets `None`.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Eval input matching [`crate::runtime::executable::BatchInput`]:
/// token tasks feed i32, image tasks feed f32.
#[derive(Debug, Clone)]
pub enum JobInput {
    Tokens(Vec<i32>),
    Pixels(Vec<f32>),
}

/// What the batcher sends back on the job's rendezvous channel.
#[derive(Debug, Clone)]
pub enum JobOutcome {
    Done { sum_nll: f64, sum_correct: f64, batch_size: usize, version: u64 },
    Failed { status: u16, msg: String },
}

#[derive(Debug)]
pub struct EvalJob {
    pub model: String,
    pub input: JobInput,
    pub targets: Vec<i32>,
    pub resp: std::sync::mpsc::SyncSender<JobOutcome>,
    /// For the queue-wait histogram only — never reaches results.
    pub enqueued_at: std::time::Instant,
}

/// Why a push was refused (maps to 429 / 429 / 503 respectively).
#[derive(Debug)]
pub enum PushError {
    Full(EvalJob),
    /// The per-model admission quota is exhausted (queue has room, but
    /// this model already holds its share).
    Quota(EvalJob),
    Closed(EvalJob),
}

struct Inner {
    q: VecDeque<EvalJob>,
    /// queued-job count per model (quota accounting)
    per_model: BTreeMap<String, usize>,
    closed: bool,
}

fn dec(map: &mut BTreeMap<String, usize>, model: &str) {
    if let Some(c) = map.get_mut(model) {
        *c = c.saturating_sub(1);
        if *c == 0 {
            map.remove(model);
        }
    }
}

pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    not_empty: Condvar,
    max_queue: usize,
    /// 0 = quota disabled
    max_per_model: usize,
}

impl AdmissionQueue {
    pub fn new(max_queue: usize) -> AdmissionQueue {
        AdmissionQueue::with_quota(max_queue, 0)
    }

    pub fn with_quota(max_queue: usize, max_per_model: usize) -> AdmissionQueue {
        AdmissionQueue {
            inner: Mutex::new(Inner {
                q: VecDeque::new(),
                per_model: BTreeMap::new(),
                closed: false,
            }),
            not_empty: Condvar::new(),
            max_queue: max_queue.max(1),
            max_per_model,
        }
    }

    /// Queue state is plain data: a panicked holder cannot leave it
    /// logically torn, so recover the guard rather than poisoning every
    /// later request.
    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn depth(&self) -> usize {
        self.lock().q.len()
    }

    /// Admit a job, or hand it back if the queue is full, the model's
    /// quota is spent, or the queue is closed.
    pub fn push(&self, job: EvalJob) -> Result<(), PushError> {
        let mut inner = self.lock();
        if inner.closed {
            return Err(PushError::Closed(job));
        }
        if inner.q.len() >= self.max_queue {
            return Err(PushError::Full(job));
        }
        if self.max_per_model > 0
            && inner.per_model.get(&job.model).copied().unwrap_or(0) >= self.max_per_model
        {
            return Err(PushError::Quota(job));
        }
        *inner.per_model.entry(job.model.clone()).or_insert(0) += 1;
        inner.q.push_back(job);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Stop admitting; wake the batcher so it can drain and exit.
    pub fn close(&self) {
        self.lock().closed = true;
        self.not_empty.notify_all();
    }

    /// Take the next macro-batch: up to `max_batch` jobs for the model
    /// at the front of the queue, in arrival order. Jobs for other
    /// models keep their relative order for the next call. Blocks while
    /// empty; once non-empty, waits up to `linger` for stragglers to
    /// coalesce. Returns `None` only when closed *and* drained.
    ///
    /// Single-consumer: exactly one batcher thread calls this (the
    /// queue never shrinks under us between the waits below).
    pub fn pop_batch(&self, max_batch: usize, linger: Duration) -> Option<Vec<EvalJob>> {
        let max_batch = max_batch.max(1);
        let mut inner = self.lock();
        loop {
            while inner.q.is_empty() {
                if inner.closed {
                    return None;
                }
                inner = self
                    .not_empty
                    .wait(inner)
                    .unwrap_or_else(PoisonError::into_inner);
            }
            if !linger.is_zero() && !inner.closed {
                // deadline math is scheduling-only and never reaches
                // result bits, hence the determinism-lint exemption
                #[allow(clippy::disallowed_methods)]
                let deadline = std::time::Instant::now() + linger;
                loop {
                    let Some(front) = inner.q.front() else { break };
                    let head = front.model.clone();
                    let ready = inner.q.iter().filter(|j| j.model == head).count();
                    if ready >= max_batch || inner.closed {
                        break;
                    }
                    #[allow(clippy::disallowed_methods)]
                    let now = std::time::Instant::now();
                    let Some(left) =
                        deadline.checked_duration_since(now).filter(|d| !d.is_zero())
                    else {
                        break;
                    };
                    let (guard, timeout) = self
                        .not_empty
                        .wait_timeout(inner, left)
                        .unwrap_or_else(PoisonError::into_inner);
                    inner = guard;
                    if timeout.timed_out() {
                        break;
                    }
                }
            }
            // single consumer ⇒ still non-empty here; if that invariant
            // is ever violated, loop back to the wait rather than panic
            let Some(front) = inner.q.front() else { continue };
            let head = front.model.clone();
            let mut batch = Vec::new();
            let mut rest = VecDeque::with_capacity(inner.q.len());
            while let Some(job) = inner.q.pop_front() {
                if batch.len() < max_batch && job.model == head {
                    batch.push(job);
                } else {
                    rest.push_back(job);
                }
            }
            inner.q = rest;
            for job in &batch {
                dec(&mut inner.per_model, &job.model);
            }
            return Some(batch);
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    fn job(model: &str, tag: i32) -> EvalJob {
        // outcome channel unused here: queue tests never run a batcher
        let (tx, _rx) = sync_channel(1);
        #[allow(clippy::disallowed_methods)]
        let now = std::time::Instant::now();
        EvalJob {
            model: model.to_string(),
            input: JobInput::Tokens(vec![tag]),
            targets: vec![tag],
            resp: tx,
            enqueued_at: now,
        }
    }

    fn tags(batch: &[EvalJob]) -> Vec<i32> {
        batch.iter().map(|j| j.targets[0]).collect()
    }

    #[test]
    fn fifo_order_within_and_across_batches() {
        let q = AdmissionQueue::new(16);
        for i in 0..5 {
            q.push(job("a", i)).unwrap();
        }
        let b1 = q.pop_batch(3, Duration::ZERO).unwrap();
        assert_eq!(tags(&b1), vec![0, 1, 2]);
        let b2 = q.pop_batch(3, Duration::ZERO).unwrap();
        assert_eq!(tags(&b2), vec![3, 4]);
    }

    #[test]
    fn batches_split_by_model_preserving_order() {
        let q = AdmissionQueue::new(16);
        q.push(job("a", 0)).unwrap();
        q.push(job("b", 1)).unwrap();
        q.push(job("a", 2)).unwrap();
        q.push(job("b", 3)).unwrap();
        let b1 = q.pop_batch(8, Duration::ZERO).unwrap();
        assert_eq!(tags(&b1), vec![0, 2]); // both "a" jobs, arrival order
        let b2 = q.pop_batch(8, Duration::ZERO).unwrap();
        assert_eq!(tags(&b2), vec![1, 3]); // "b" kept its relative order
    }

    #[test]
    fn push_bounded_then_accepts_after_drain() {
        let q = AdmissionQueue::new(2);
        q.push(job("a", 0)).unwrap();
        q.push(job("a", 1)).unwrap();
        match q.push(job("a", 2)) {
            Err(PushError::Full(j)) => assert_eq!(j.targets[0], 2),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.depth(), 2);
        let _ = q.pop_batch(8, Duration::ZERO).unwrap();
        q.push(job("a", 3)).unwrap();
    }

    #[test]
    fn per_model_quota_rejects_only_the_hot_model() {
        let q = AdmissionQueue::with_quota(16, 2);
        q.push(job("hot", 0)).unwrap();
        q.push(job("hot", 1)).unwrap();
        match q.push(job("hot", 2)) {
            Err(PushError::Quota(j)) => assert_eq!(j.targets[0], 2),
            other => panic!("expected Quota, got {other:?}"),
        }
        // other models still admitted: the queue itself has room
        q.push(job("cold", 3)).unwrap();
        // draining the hot model frees its quota
        let b = q.pop_batch(8, Duration::ZERO).unwrap();
        assert_eq!(tags(&b), vec![0, 1]);
        q.push(job("hot", 4)).unwrap();
    }

    #[test]
    fn quota_zero_means_disabled() {
        let q = AdmissionQueue::with_quota(4, 0);
        for i in 0..4 {
            q.push(job("a", i)).unwrap();
        }
        assert!(matches!(q.push(job("a", 9)), Err(PushError::Full(_))));
    }

    #[test]
    fn close_drains_then_none_and_rejects_pushes() {
        let q = AdmissionQueue::new(8);
        q.push(job("a", 0)).unwrap();
        q.close();
        match q.push(job("a", 1)) {
            Err(PushError::Closed(_)) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
        let b = q.pop_batch(8, Duration::from_millis(50)).unwrap();
        assert_eq!(tags(&b), vec![0]);
        assert!(q.pop_batch(8, Duration::ZERO).is_none());
    }

    #[test]
    fn pop_blocks_until_push_across_threads() {
        let q = AdmissionQueue::new(8);
        std::thread::scope(|s| {
            let consumer = s.spawn(|| q.pop_batch(4, Duration::from_millis(20)));
            std::thread::sleep(Duration::from_millis(30));
            q.push(job("a", 7)).unwrap();
            let got = consumer.join().unwrap().unwrap();
            assert_eq!(tags(&got), vec![7]);
        });
    }

    #[test]
    fn linger_coalesces_late_arrivals() {
        let q = AdmissionQueue::new(8);
        std::thread::scope(|s| {
            let consumer = s.spawn(|| q.pop_batch(4, Duration::from_millis(300)));
            std::thread::sleep(Duration::from_millis(10));
            for i in 0..4 {
                q.push(job("a", i)).unwrap();
                std::thread::sleep(Duration::from_millis(5));
            }
            let got = consumer.join().unwrap().unwrap();
            // all four arrived within the linger window ⇒ one batch
            assert_eq!(tags(&got), vec![0, 1, 2, 3]);
        });
    }
}
