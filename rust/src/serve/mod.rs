//! `qn serve`: a batching inference + online-quantization HTTP service
//! (DESIGN.md §9).
//!
//! Layering:
//!
//! ```text
//!   acceptor ──► conn channel ──► http workers ──► handlers
//!                                      │  /v1/eval jobs
//!                                      ▼
//!                              admission queue ──► batcher ──► ModelSession
//!                                  (bounded,          │        eval_batched
//!                                   FIFO, 429)        └── macro-batches
//! ```
//!
//! The batcher is the only thread that touches the runtime; HTTP
//! workers rendezvous with it through per-job channels. Requests
//! coalesce into macro-batches that ride `execute_f32_batched`, whose
//! deterministic shard-order merge guarantees each response's bits are
//! independent of co-batched traffic — `ServeConfig::selfcheck` makes
//! the batcher re-run every shard solo and assert exactly that.
//! `/v1/models/{id}/reencode` refits the quantizer on the pristine
//! fp32 weights and atomically swaps the served snapshot (no
//! downtime: in-flight batches keep their `Arc`).
//!
//! Robustness (DESIGN.md §10): every request runs under an absolute
//! read/write deadline ([`http::DeadlineReader`] — slowloris guard),
//! one model cannot monopolize the admission queue
//! (`ServeConfig::max_per_model`), and shutdown drains the batcher for
//! at most `ServeConfig::drain_timeout` before abandoning it — a
//! wedged backend cannot hold `SIGTERM` hostage.

// The serving layer must degrade, not die: a panic in one worker takes
// its connection, a panic while holding a lock must not poison every
// later request. Bare unwrap/expect are banned here; the few justified
// ones carry a local `#[allow]` with a reason.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod handlers;
pub mod http;
pub mod metrics;
pub mod queue;
pub mod registry;
pub mod router;

use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::runtime::client::{Backend, BackendError, Runtime};
use crate::runtime::executable::{BatchInput, ModelSession};
use crate::runtime::manifest::Manifest;
use crate::util::fault;
use crate::{log_error, log_info, log_warn};

use http::{DeadlineReader, Response};
use metrics::Metrics;
use queue::{AdmissionQueue, EvalJob, JobInput, JobOutcome};
use registry::Registry;

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub addr: String,
    /// Interpreter worker threads (0 ⇒ all cores).
    pub threads: usize,
    /// Macro-batch size cap for coalesced evals.
    pub max_batch: usize,
    /// Admission-queue bound; pushes beyond it get 429.
    pub max_queue: usize,
    /// Per-model admission quota (0 ⇒ disabled): one hot model cannot
    /// occupy more than this many queued jobs.
    pub max_per_model: usize,
    /// HTTP worker threads — one live connection each, so keep this at
    /// or above the expected concurrent-client count.
    pub http_threads: usize,
    /// How long the batcher waits for stragglers once a job is ready.
    pub linger: Duration,
    /// Whole-request read/write deadline and idle keep-alive cap.
    pub io_timeout: Duration,
    /// How long graceful shutdown waits for the batcher to drain
    /// before abandoning it (bounds `run_until`'s exit latency).
    pub drain_timeout: Duration,
    /// Requests served per connection before keep-alive is refused
    /// (bounds how long one peer can pin a worker).
    pub max_conn_requests: usize,
    /// Backend override; `None` ⇒ `QN_BACKEND` (interp by default).
    pub backend: Option<Backend>,
    /// Re-run every coalesced shard solo and assert bit-identity.
    pub selfcheck: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7171".into(),
            threads: 0,
            max_batch: 8,
            max_queue: 64,
            max_per_model: 0,
            http_threads: 8,
            linger: Duration::from_millis(2),
            io_timeout: Duration::from_secs(5),
            drain_timeout: Duration::from_secs(30),
            max_conn_requests: 1000,
            backend: None,
            selfcheck: false,
        }
    }
}

/// Everything the worker/batcher threads share.
pub struct ServerState {
    pub cfg: ServeConfig,
    pub manifest: Manifest,
    pub registry: Registry,
    pub metrics: Metrics,
    pub queue: AdmissionQueue,
    pub shutdown: AtomicBool,
    /// Set when shutdown gave up waiting on a wedged batcher; eval
    /// handlers still blocked on rendezvous channels answer 503.
    pub abandoned: AtomicBool,
}

pub struct Server {
    addr: SocketAddr,
    state: Arc<ServerState>,
    /// Kept apart from `threads` so shutdown can bound its drain.
    batcher: Option<std::thread::JoinHandle<()>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

// Service threads are detached-by-name rather than scoped: they never
// produce result bits (the determinism-lint's concern), and
// `Server::stop` joins every one of them (or deliberately abandons a
// wedged batcher after `drain_timeout`).
fn spawn_named(
    name: &str,
    f: impl FnOnce() + Send + 'static,
) -> Result<std::thread::JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("qn-serve-{name}"))
        .spawn(f)
        .with_context(|| format!("spawning {name} thread"))
}

impl Server {
    /// Bind, load every manifest model, and start the service threads.
    /// Use port 0 to let the OS pick ([`Server::addr`] has the result).
    pub fn start(artifacts: &Path, cfg: ServeConfig) -> Result<Server> {
        let manifest = Manifest::load(artifacts)?;
        let registry = Registry::from_manifest(&manifest)?;
        anyhow::ensure!(
            !registry.is_empty(),
            "no models in manifest at {}",
            artifacts.display()
        );
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let http_threads = cfg.http_threads.max(1);
        let queue = AdmissionQueue::with_quota(cfg.max_queue, cfg.max_per_model);
        let state = Arc::new(ServerState {
            cfg,
            manifest,
            registry,
            metrics: Metrics::default(),
            queue,
            shutdown: AtomicBool::new(false),
            abandoned: AtomicBool::new(false),
        });
        let batcher = {
            let st = state.clone();
            Some(spawn_named("batcher", move || batcher_main(&st))?)
        };
        let mut threads = Vec::with_capacity(http_threads + 1);
        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        for i in 0..http_threads {
            let st = state.clone();
            let rx = conn_rx.clone();
            threads.push(spawn_named(&format!("http-{i}"), move || http_worker(&st, &rx))?);
        }
        {
            let st = state.clone();
            threads.push(spawn_named("acceptor", move || acceptor_main(&st, listener, conn_tx))?);
        }
        Ok(Server { addr, state, batcher, threads })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    fn stop(&mut self) {
        if self.batcher.is_none() && self.threads.is_empty() {
            return;
        }
        self.state.shutdown.store(true, Ordering::Relaxed);
        self.state.queue.close();
        // wake the blocking accept so the acceptor sees the flag
        let _ = TcpStream::connect(self.addr);
        // bounded drain: the batcher normally finishes the queued work
        // within milliseconds of `close()`, but a wedged backend must
        // not hold shutdown hostage — after `drain_timeout` the handle
        // is dropped (thread detached) and blocked handlers answer 503
        if let Some(b) = self.batcher.take() {
            let deadline = http::deadline_after(self.state.cfg.drain_timeout);
            loop {
                if b.is_finished() {
                    let _ = b.join();
                    break;
                }
                if http::time_left(deadline).is_zero() {
                    self.state.abandoned.store(true, Ordering::Relaxed);
                    log_warn!(
                        "qn serve: batcher still draining after {:?}; abandoning it",
                        self.state.cfg.drain_timeout
                    );
                    break;
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Graceful shutdown: stop admitting, drain the queue (bounded by
    /// `drain_timeout`), join all service threads.
    pub fn shutdown(mut self) {
        self.stop();
    }

    /// Block until the server is stopped externally (CLI mode).
    pub fn wait(mut self) {
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// CLI entry: start and serve until killed.
pub fn run(artifacts: &Path, cfg: ServeConfig) -> Result<()> {
    let server = Server::start(artifacts, cfg)?;
    let ids = server.state.registry.ids();
    log_info!("qn serve listening on http://{} serving {:?}", server.addr(), ids);
    server.wait();
    Ok(())
}

/// CLI entry with graceful shutdown: serve until `stop` is raised (the
/// binary flips it from its SIGINT/SIGTERM handler), then stop
/// admitting work (new jobs get 503), drain queued jobs through the
/// batcher for at most `cfg.drain_timeout`, and join every service
/// thread before returning. Exit latency is bounded even when the
/// backend wedges mid-batch.
pub fn run_until(artifacts: &Path, cfg: ServeConfig, stop: &AtomicBool) -> Result<()> {
    let server = Server::start(artifacts, cfg)?;
    let ids = server.state.registry.ids();
    log_info!("qn serve listening on http://{} serving {:?}", server.addr(), ids);
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(Duration::from_millis(50));
    }
    log_info!("qn serve: stop signal received; draining queue and shutting down");
    server.shutdown();
    log_info!("qn serve: shutdown complete");
    Ok(())
}

// ------------------------------------------------------------ http ---

fn acceptor_main(state: &ServerState, listener: TcpListener, tx: mpsc::Sender<TcpStream>) {
    for stream in listener.incoming() {
        if state.shutdown.load(Ordering::Relaxed) {
            break;
        }
        // fault point: drop the connection on the floor before any
        // worker sees it (client observes a reset / empty reply)
        if fault::check("serve.accept").is_err() {
            continue;
        }
        match stream {
            Ok(s) => {
                if tx.send(s).is_err() {
                    break;
                }
            }
            Err(e) => log_warn!("accept failed: {e}"),
        }
    }
    // dropping `tx` unblocks every http worker's recv()
}

fn http_worker(state: &ServerState, rx: &Mutex<mpsc::Receiver<TcpStream>>) {
    loop {
        // holding the lock while blocked in recv() is fine: connection
        // handling happens outside it, so workers still run in parallel.
        // A worker that panicked mid-recv cannot leave the receiver torn
        // (mpsc is internally synchronized) — recover, don't poison.
        let stream = match rx.lock().unwrap_or_else(PoisonError::into_inner).recv() {
            Ok(s) => s,
            Err(_) => return, // acceptor gone ⇒ shutdown
        };
        handle_conn(state, stream);
    }
}

fn handle_conn(state: &ServerState, stream: TcpStream) {
    let io = state.cfg.io_timeout;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(io));
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(DeadlineReader::new(read_half, io));
    let mut writer = BufWriter::new(stream);
    let max_requests = state.cfg.max_conn_requests.max(1);
    let mut served = 0usize;
    loop {
        if state.shutdown.load(Ordering::Relaxed) {
            break;
        }
        // each keep-alive request gets a fresh whole-request deadline;
        // this doubles as the idle keep-alive cap
        reader.get_mut().arm(io);
        let req = match http::read_request(&mut reader, io) {
            Ok(Some(r)) => r,
            Ok(None) => break, // clean close
            Err(e) => {
                if e.timeout {
                    if e.started {
                        // the peer began a request and stalled: 408
                        state.metrics.timeouts.fetch_add(1, Ordering::Relaxed);
                        let resp =
                            Response::error(408, "request deadline exceeded");
                        let _ = http::write_response(&mut writer, &resp, false);
                    }
                    // idle keep-alive expiry closes silently
                } else {
                    let resp = Response::error(400, &format!("{:#}", e.err));
                    let _ = http::write_response(&mut writer, &resp, false);
                }
                break;
            }
        };
        // fault point: connection dies right after the request is read
        // (tests assert the worker survives and serves the next peer)
        if fault::check("serve.read").is_err() {
            break;
        }
        // request latency metric: timing only, never result bits
        #[allow(clippy::disallowed_methods)]
        let t0 = std::time::Instant::now();
        served += 1;
        let keep = req.keep_alive && served < max_requests;
        let (route, resp) = handlers::dispatch(state, &req);
        state.metrics.observe(route, resp.status, t0.elapsed().as_nanos() as u64);
        // fault point: connection dies before the response goes out
        if fault::check("serve.write").is_err() {
            break;
        }
        if http::write_response(&mut writer, &resp, keep).is_err() || !keep {
            break;
        }
    }
}

// --------------------------------------------------------- batcher ---

struct Slot<'rt> {
    sess: ModelSession<'rt>,
    /// Registry snapshot version currently uploaded to the session.
    version: u64,
}

fn batcher_main(state: &ServerState) {
    let rt = match state.cfg.backend {
        Some(b) => Runtime::with_backend(b),
        None => Runtime::cpu(),
    };
    let rt = match rt {
        Ok(rt) => rt,
        Err(e) => {
            log_error!("batcher: no runtime, failing all evals: {e:#}");
            // serve 503s instead of dying: health endpoints stay up
            while let Some(batch) = state.queue.pop_batch(usize::MAX, Duration::ZERO) {
                for job in batch {
                    let _ = job.resp.send(JobOutcome::Failed {
                        status: 503,
                        msg: format!("backend unavailable: {e:#}"),
                    });
                }
            }
            return;
        }
    };
    rt.set_threads(state.cfg.threads);
    log_info!(
        "batcher ready: platform {}, {} worker threads, max_batch {}",
        rt.platform(),
        rt.threads(),
        state.cfg.max_batch
    );
    // sessions declared after rt ⇒ dropped before it (borrow order)
    let mut sessions: BTreeMap<String, Slot<'_>> = BTreeMap::new();
    while let Some(batch) = state.queue.pop_batch(state.cfg.max_batch, state.cfg.linger) {
        serve_batch(state, &rt, &mut sessions, batch);
    }
}

fn serve_batch<'rt>(
    state: &ServerState,
    rt: &'rt Runtime,
    sessions: &mut BTreeMap<String, Slot<'rt>>,
    batch: Vec<EvalJob>,
) {
    // fault point: a wedged (`hang`) or failing (`err`) backend — the
    // drain-timeout and 503-path tests drive shutdown through this
    if let Err(e) = fault::check("serve.batch") {
        for job in batch {
            let _ = job.resp.send(JobOutcome::Failed { status: 503, msg: e.to_string() });
        }
        return;
    }
    let m = batch.len();
    for job in &batch {
        state.metrics.queue_wait_ns.record(job.enqueued_at.elapsed().as_nanos() as u64);
    }
    let model_id = batch[0].model.clone();
    let Some(model) = state.registry.get(&model_id) else {
        // unreachable (registry is append-only), but fail soft
        for job in batch {
            let _ = job.resp.send(JobOutcome::Failed {
                status: 500,
                msg: format!("model '{model_id}' vanished from the registry"),
            });
        }
        return;
    };
    let snap = model.snapshot();
    let keep = vec![1.0f32; model.meta.n_layers];
    let result = (|| -> Result<Vec<(f64, f64)>> {
        let slot = match sessions.entry(model_id.clone()) {
            std::collections::btree_map::Entry::Occupied(o) => o.into_mut(),
            std::collections::btree_map::Entry::Vacant(v) => {
                let sess = ModelSession::with_params(rt, &state.manifest, &model.meta, &snap.params)
                    .with_context(|| format!("creating session for {model_id}"))?;
                v.insert(Slot { sess, version: snap.version })
            }
        };
        if slot.version != snap.version {
            // a /reencode swapped the snapshot since the last batch;
            // sync once — every macro-batch is wholly pre- or post-swap
            slot.sess.upload_all_params(&snap.params)?;
            slot.version = snap.version;
        }
        let is_img = matches!(batch[0].input, JobInput::Pixels(_));
        let mut toks: Vec<i32> = Vec::new();
        let mut px: Vec<f32> = Vec::new();
        let mut targets: Vec<i32> = Vec::new();
        for job in &batch {
            match &job.input {
                JobInput::Tokens(t) => toks.extend_from_slice(t),
                JobInput::Pixels(p) => px.extend_from_slice(p),
            }
            targets.extend_from_slice(&job.targets);
        }
        let input = if is_img { BatchInput::Images(&px) } else { BatchInput::Tokens(&toks) };
        let sums = slot.sess.eval_batched("eval", &input, &targets, &keep)?;
        anyhow::ensure!(sums.len() == m, "batched eval returned {} shards for {m}", sums.len());
        if state.cfg.selfcheck {
            // the coalescing-independence assertion: each request's
            // bits must match a solo run against the same snapshot
            for (i, job) in batch.iter().enumerate() {
                let solo_in = match &job.input {
                    JobInput::Tokens(t) => BatchInput::Tokens(t.as_slice()),
                    JobInput::Pixels(p) => BatchInput::Images(p.as_slice()),
                };
                let solo = slot.sess.eval("eval", &solo_in, &job.targets, &keep)?;
                anyhow::ensure!(
                    solo.0.to_bits() == sums[i].0.to_bits()
                        && solo.1.to_bits() == sums[i].1.to_bits(),
                    "coalescing changed request {i}/{m} bits: solo {:?} vs batched {:?}",
                    solo,
                    sums[i]
                );
            }
        }
        Ok(sums)
    })();
    match result {
        Ok(sums) => {
            state.metrics.note_batch(m);
            for (job, (sum_nll, sum_correct)) in batch.into_iter().zip(sums) {
                let _ = job.resp.send(JobOutcome::Done {
                    sum_nll,
                    sum_correct,
                    batch_size: m,
                    version: snap.version,
                });
            }
        }
        Err(e) => {
            // a declining backend is the service degrading, not a bug
            let status = if e.is::<BackendError>() { 503 } else { 500 };
            let msg = format!("{e:#}");
            log_warn!("batch of {m} on {model_id} failed ({status}): {msg}");
            for job in batch {
                let _ = job.resp.send(JobOutcome::Failed { status, msg: msg.clone() });
            }
        }
    }
}
