//! `qn serve`: a batching inference + online-quantization HTTP service
//! (DESIGN.md §9).
//!
//! Layering:
//!
//! ```text
//!   acceptor ──► conn channel ──► http workers ──► handlers
//!                                      │  /v1/eval jobs
//!                                      ▼
//!                              admission queue ──► batcher ──► ModelSession
//!                                  (bounded,          │        eval_batched
//!                                   FIFO, 429)        └── macro-batches
//! ```
//!
//! The batcher is the only thread that touches the runtime; HTTP
//! workers rendezvous with it through per-job channels. Requests
//! coalesce into macro-batches that ride `execute_f32_batched`, whose
//! deterministic shard-order merge guarantees each response's bits are
//! independent of co-batched traffic — `ServeConfig::selfcheck` makes
//! the batcher re-run every shard solo and assert exactly that.
//! `/v1/models/{id}/reencode` refits the quantizer on the pristine
//! fp32 weights and atomically swaps the served snapshot (no
//! downtime: in-flight batches keep their `Arc`).

pub mod handlers;
pub mod http;
pub mod metrics;
pub mod queue;
pub mod registry;
pub mod router;

use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::runtime::client::{Backend, BackendError, Runtime};
use crate::runtime::executable::{BatchInput, ModelSession};
use crate::runtime::manifest::Manifest;
use crate::{log_error, log_info, log_warn};

use http::Response;
use metrics::Metrics;
use queue::{AdmissionQueue, EvalJob, JobInput, JobOutcome};
use registry::Registry;

/// Per-connection socket read/write timeout: bounds slow-loris peers
/// and how long shutdown waits on an idle keep-alive connection.
const IO_TIMEOUT: Duration = Duration::from_secs(5);

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub addr: String,
    /// Interpreter worker threads (0 ⇒ all cores).
    pub threads: usize,
    /// Macro-batch size cap for coalesced evals.
    pub max_batch: usize,
    /// Admission-queue bound; pushes beyond it get 429.
    pub max_queue: usize,
    /// HTTP worker threads — one live connection each, so keep this at
    /// or above the expected concurrent-client count.
    pub http_threads: usize,
    /// How long the batcher waits for stragglers once a job is ready.
    pub linger: Duration,
    /// Backend override; `None` ⇒ `QN_BACKEND` (interp by default).
    pub backend: Option<Backend>,
    /// Re-run every coalesced shard solo and assert bit-identity.
    pub selfcheck: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7171".into(),
            threads: 0,
            max_batch: 8,
            max_queue: 64,
            http_threads: 8,
            linger: Duration::from_millis(2),
            backend: None,
            selfcheck: false,
        }
    }
}

/// Everything the worker/batcher threads share.
pub struct ServerState {
    pub cfg: ServeConfig,
    pub manifest: Manifest,
    pub registry: Registry,
    pub metrics: Metrics,
    pub queue: AdmissionQueue,
    pub shutdown: AtomicBool,
}

pub struct Server {
    addr: SocketAddr,
    state: Arc<ServerState>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

// Service threads are detached-by-name rather than scoped: they never
// produce result bits (the determinism-lint's concern), and
// `Server::stop` joins every one of them.
fn spawn_named(
    name: &str,
    f: impl FnOnce() + Send + 'static,
) -> Result<std::thread::JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("qn-serve-{name}"))
        .spawn(f)
        .with_context(|| format!("spawning {name} thread"))
}

impl Server {
    /// Bind, load every manifest model, and start the service threads.
    /// Use port 0 to let the OS pick ([`Server::addr`] has the result).
    pub fn start(artifacts: &Path, cfg: ServeConfig) -> Result<Server> {
        let manifest = Manifest::load(artifacts)?;
        let registry = Registry::from_manifest(&manifest)?;
        anyhow::ensure!(
            !registry.is_empty(),
            "no models in manifest at {}",
            artifacts.display()
        );
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let http_threads = cfg.http_threads.max(1);
        let queue = AdmissionQueue::new(cfg.max_queue);
        let state = Arc::new(ServerState {
            cfg,
            manifest,
            registry,
            metrics: Metrics::default(),
            queue,
            shutdown: AtomicBool::new(false),
        });
        let mut threads = Vec::with_capacity(http_threads + 2);
        {
            let st = state.clone();
            threads.push(spawn_named("batcher", move || batcher_main(&st))?);
        }
        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        for i in 0..http_threads {
            let st = state.clone();
            let rx = conn_rx.clone();
            threads.push(spawn_named(&format!("http-{i}"), move || http_worker(&st, &rx))?);
        }
        {
            let st = state.clone();
            threads.push(spawn_named("acceptor", move || acceptor_main(&st, listener, conn_tx))?);
        }
        Ok(Server { addr, state, threads })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    fn stop(&mut self) {
        if self.threads.is_empty() {
            return;
        }
        self.state.shutdown.store(true, Ordering::Relaxed);
        self.state.queue.close();
        // wake the blocking accept so the acceptor sees the flag
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Graceful shutdown: stop admitting, drain the queue, join all
    /// service threads.
    pub fn shutdown(mut self) {
        self.stop();
    }

    /// Block until the server is stopped externally (CLI mode).
    pub fn wait(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// CLI entry: start and serve until killed.
pub fn run(artifacts: &Path, cfg: ServeConfig) -> Result<()> {
    let server = Server::start(artifacts, cfg)?;
    let ids = server.state.registry.ids();
    log_info!("qn serve listening on http://{} serving {:?}", server.addr(), ids);
    server.wait();
    Ok(())
}

/// CLI entry with graceful shutdown: serve until `stop` is raised (the
/// binary flips it from its SIGINT/SIGTERM handler), then stop
/// admitting work (new jobs get 503), drain queued jobs through the
/// batcher, and join every service thread before returning.
pub fn run_until(artifacts: &Path, cfg: ServeConfig, stop: &AtomicBool) -> Result<()> {
    let server = Server::start(artifacts, cfg)?;
    let ids = server.state.registry.ids();
    log_info!("qn serve listening on http://{} serving {:?}", server.addr(), ids);
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(Duration::from_millis(50));
    }
    log_info!("qn serve: stop signal received; draining queue and shutting down");
    server.shutdown();
    log_info!("qn serve: shutdown complete");
    Ok(())
}

// ------------------------------------------------------------ http ---

fn acceptor_main(state: &ServerState, listener: TcpListener, tx: mpsc::Sender<TcpStream>) {
    for stream in listener.incoming() {
        if state.shutdown.load(Ordering::Relaxed) {
            break;
        }
        match stream {
            Ok(s) => {
                if tx.send(s).is_err() {
                    break;
                }
            }
            Err(e) => log_warn!("accept failed: {e}"),
        }
    }
    // dropping `tx` unblocks every http worker's recv()
}

fn http_worker(state: &ServerState, rx: &Mutex<mpsc::Receiver<TcpStream>>) {
    loop {
        // holding the lock while blocked in recv() is fine: connection
        // handling happens outside it, so workers still run in parallel
        let stream = match rx.lock().unwrap().recv() {
            Ok(s) => s,
            Err(_) => return, // acceptor gone ⇒ shutdown
        };
        handle_conn(state, stream);
    }
}

fn handle_conn(state: &ServerState, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    loop {
        if state.shutdown.load(Ordering::Relaxed) {
            break;
        }
        let req = match http::read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => break, // clean close
            Err(e) => {
                // idle keep-alive timeouts close silently; actual
                // protocol garbage gets a 400 first
                let idle = e
                    .downcast_ref::<std::io::Error>()
                    .map(|io| {
                        matches!(
                            io.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        )
                    })
                    .unwrap_or(false);
                if !idle {
                    let resp = Response::error(400, &format!("{e:#}"));
                    let _ = http::write_response(&mut writer, &resp, false);
                }
                break;
            }
        };
        // request latency metric: timing only, never result bits
        #[allow(clippy::disallowed_methods)]
        let t0 = std::time::Instant::now();
        let keep = req.keep_alive;
        let (route, resp) = handlers::dispatch(state, &req);
        state.metrics.observe(route, resp.status, t0.elapsed().as_nanos() as u64);
        if http::write_response(&mut writer, &resp, keep).is_err() || !keep {
            break;
        }
    }
}

// --------------------------------------------------------- batcher ---

struct Slot<'rt> {
    sess: ModelSession<'rt>,
    /// Registry snapshot version currently uploaded to the session.
    version: u64,
}

fn batcher_main(state: &ServerState) {
    let rt = match state.cfg.backend {
        Some(b) => Runtime::with_backend(b),
        None => Runtime::cpu(),
    };
    let rt = match rt {
        Ok(rt) => rt,
        Err(e) => {
            log_error!("batcher: no runtime, failing all evals: {e:#}");
            // serve 503s instead of dying: health endpoints stay up
            while let Some(batch) = state.queue.pop_batch(usize::MAX, Duration::ZERO) {
                for job in batch {
                    let _ = job.resp.send(JobOutcome::Failed {
                        status: 503,
                        msg: format!("backend unavailable: {e:#}"),
                    });
                }
            }
            return;
        }
    };
    rt.set_threads(state.cfg.threads);
    log_info!(
        "batcher ready: platform {}, {} worker threads, max_batch {}",
        rt.platform(),
        rt.threads(),
        state.cfg.max_batch
    );
    // sessions declared after rt ⇒ dropped before it (borrow order)
    let mut sessions: BTreeMap<String, Slot<'_>> = BTreeMap::new();
    while let Some(batch) = state.queue.pop_batch(state.cfg.max_batch, state.cfg.linger) {
        serve_batch(state, &rt, &mut sessions, batch);
    }
}

fn serve_batch<'rt>(
    state: &ServerState,
    rt: &'rt Runtime,
    sessions: &mut BTreeMap<String, Slot<'rt>>,
    batch: Vec<EvalJob>,
) {
    let m = batch.len();
    for job in &batch {
        state.metrics.queue_wait_ns.record(job.enqueued_at.elapsed().as_nanos() as u64);
    }
    let model_id = batch[0].model.clone();
    let Some(model) = state.registry.get(&model_id) else {
        // unreachable (registry is append-only), but fail soft
        for job in batch {
            let _ = job.resp.send(JobOutcome::Failed {
                status: 500,
                msg: format!("model '{model_id}' vanished from the registry"),
            });
        }
        return;
    };
    let snap = model.snapshot();
    let keep = vec![1.0f32; model.meta.n_layers];
    let result = (|| -> Result<Vec<(f64, f64)>> {
        let slot = match sessions.entry(model_id.clone()) {
            std::collections::btree_map::Entry::Occupied(o) => o.into_mut(),
            std::collections::btree_map::Entry::Vacant(v) => {
                let sess = ModelSession::with_params(rt, &state.manifest, &model.meta, &snap.params)
                    .with_context(|| format!("creating session for {model_id}"))?;
                v.insert(Slot { sess, version: snap.version })
            }
        };
        if slot.version != snap.version {
            // a /reencode swapped the snapshot since the last batch;
            // sync once — every macro-batch is wholly pre- or post-swap
            slot.sess.upload_all_params(&snap.params)?;
            slot.version = snap.version;
        }
        let is_img = matches!(batch[0].input, JobInput::Pixels(_));
        let mut toks: Vec<i32> = Vec::new();
        let mut px: Vec<f32> = Vec::new();
        let mut targets: Vec<i32> = Vec::new();
        for job in &batch {
            match &job.input {
                JobInput::Tokens(t) => toks.extend_from_slice(t),
                JobInput::Pixels(p) => px.extend_from_slice(p),
            }
            targets.extend_from_slice(&job.targets);
        }
        let input = if is_img { BatchInput::Images(&px) } else { BatchInput::Tokens(&toks) };
        let sums = slot.sess.eval_batched("eval", &input, &targets, &keep)?;
        anyhow::ensure!(sums.len() == m, "batched eval returned {} shards for {m}", sums.len());
        if state.cfg.selfcheck {
            // the coalescing-independence assertion: each request's
            // bits must match a solo run against the same snapshot
            for (i, job) in batch.iter().enumerate() {
                let solo_in = match &job.input {
                    JobInput::Tokens(t) => BatchInput::Tokens(t.as_slice()),
                    JobInput::Pixels(p) => BatchInput::Images(p.as_slice()),
                };
                let solo = slot.sess.eval("eval", &solo_in, &job.targets, &keep)?;
                anyhow::ensure!(
                    solo.0.to_bits() == sums[i].0.to_bits()
                        && solo.1.to_bits() == sums[i].1.to_bits(),
                    "coalescing changed request {i}/{m} bits: solo {:?} vs batched {:?}",
                    solo,
                    sums[i]
                );
            }
        }
        Ok(sums)
    })();
    match result {
        Ok(sums) => {
            state.metrics.note_batch(m);
            for (job, (sum_nll, sum_correct)) in batch.into_iter().zip(sums) {
                let _ = job.resp.send(JobOutcome::Done {
                    sum_nll,
                    sum_correct,
                    batch_size: m,
                    version: snap.version,
                });
            }
        }
        Err(e) => {
            // a declining backend is the service degrading, not a bug
            let status = if e.is::<BackendError>() { 503 } else { 500 };
            let msg = format!("{e:#}");
            log_warn!("batch of {m} on {model_id} failed ({status}): {msg}");
            for job in batch {
                let _ = job.resp.send(JobOutcome::Failed { status, msg: msg.clone() });
            }
        }
    }
}
