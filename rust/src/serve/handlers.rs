//! Endpoint logic for the `qn serve` API.
//!
//! Handlers parse bodies with the lazy path extractors from
//! `util/json.rs` — `/v1/eval` pulls the small `"model"` string
//! without materializing the (much larger) token arrays first, then
//! parses exactly the arrays it needs. Responses carry the raw
//! `sum_nll`/`sum_correct` accumulators as JSON numbers; the writer is
//! shortest-roundtrip for f64, so clients get the exact result bits
//! the engine produced (the determinism tests rely on this).

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::checkpoint;
use crate::coordinator::quantize::{reencode_params, scheme_bytes};
use crate::model::params::ParamStore;
use crate::quant::scheme::QuantSpec;
use crate::runtime::client::plan_cache_stats;
use crate::util::hash::{fnv1a64, from_hex, to_hex};
use crate::util::json::{self, Json};
use crate::util::rng::Pcg;

use super::http::{Request, Response};
use super::metrics::Route;
use super::queue::{EvalJob, JobInput, JobOutcome, PushError};
use super::registry::{ServedModel, ServedState};
use super::router::{self, RouteMatch};
use super::ServerState;

/// How long an admitted eval waits for its batch before 504.
const EVAL_TIMEOUT: Duration = Duration::from_secs(120);
/// Rendezvous poll tick: between outcomes the eval handler re-checks
/// the abandoned flag so a wedged batcher cannot pin workers past the
/// shutdown drain.
const EVAL_TICK: Duration = Duration::from_millis(100);
/// Default PTQ seed; matches `IpqConfig::default().seed` so a serve
/// re-encode reproduces the CLI's bits out of the box.
const DEFAULT_SEED: u64 = 17;

/// Route a parsed request to its handler; returns the metric label
/// alongside the response.
pub fn dispatch(state: &ServerState, req: &Request) -> (Route, Response) {
    match router::route(&req.method, &req.path) {
        Ok(RouteMatch::Eval) => (Route::Eval, eval(state, req)),
        Ok(RouteMatch::Quantize) => (Route::Quantize, quantize(state, req)),
        Ok(RouteMatch::Reencode(id)) => (Route::Reencode, reencode(state, req, &id)),
        Ok(RouteMatch::Upload(id)) => (Route::Upload, upload(state, req, &id)),
        Ok(RouteMatch::Models) => (Route::Models, models(state)),
        Ok(RouteMatch::ModelInfo(id)) => (Route::Models, model_info(state, &id)),
        Ok(RouteMatch::Stats) => (Route::Stats, stats(state)),
        Err(405) => (Route::Other, Response::error(405, "method not allowed")),
        Err(_) => (Route::Other, Response::error(404, "no such route")),
    }
}

fn body_str(req: &Request) -> Result<&str, Response> {
    std::str::from_utf8(&req.body).map_err(|_| Response::error(400, "body must be UTF-8 JSON"))
}

/// Flatten arbitrarily-nested numeric arrays into i32s. `cap` bounds
/// the output (callers know the exact element count up front), so a
/// hostile body cannot force a giant allocation.
fn flat_i32(v: &Json, cap: usize, out: &mut Vec<i32>) -> bool {
    match v {
        Json::Num(n) if n.fract() == 0.0 && out.len() < cap => {
            out.push(*n as i32);
            true
        }
        Json::Arr(a) => a.iter().all(|x| flat_i32(x, cap, out)),
        _ => false,
    }
}

fn flat_f32(v: &Json, cap: usize, out: &mut Vec<f32>) -> bool {
    match v {
        Json::Num(n) if out.len() < cap => {
            out.push(*n as f32);
            true
        }
        Json::Arr(a) => a.iter().all(|x| flat_f32(x, cap, out)),
        _ => false,
    }
}

/// Extract `path` as a numeric array flattened to i32, expecting
/// exactly `want` elements.
fn array_i32(body: &str, path: &str, want: usize) -> Result<Vec<i32>, Response> {
    let v = match json::path_value(body, path) {
        Ok(Some(v)) => v,
        Ok(None) => return Err(Response::error(400, &format!("missing field '{path}'"))),
        Err(e) => return Err(Response::error(400, &format!("bad JSON body: {e}"))),
    };
    let mut out = Vec::with_capacity(want);
    if !flat_i32(&v, want, &mut out) || out.len() != want {
        return Err(Response::error(400, &format!("'{path}' must hold {want} integers")));
    }
    Ok(out)
}

fn eval(state: &ServerState, req: &Request) -> Response {
    let body = match body_str(req) {
        Ok(b) => b,
        Err(r) => return r,
    };
    let Some(id) = json::path_str(body, "model") else {
        return Response::error(400, "missing string field 'model'");
    };
    let Some(model) = state.registry.get(&id) else {
        return Response::error(404, &format!("no such model '{id}'"));
    };
    if model.meta.entry("eval").is_none() {
        return Response::error(400, &format!("model '{id}' has no eval entry"));
    }
    let per_input: usize = model.meta.tokens_shape.iter().product();
    let per_target: usize = model.meta.targets_shape.iter().product();
    let input = if model.meta.task == "img" {
        let v = match json::path_value(body, "pixels") {
            Ok(Some(v)) => v,
            Ok(None) => return Response::error(400, "missing field 'pixels'"),
            Err(e) => return Response::error(400, &format!("bad JSON body: {e}")),
        };
        let mut px = Vec::with_capacity(per_input);
        if !flat_f32(&v, per_input, &mut px) || px.len() != per_input {
            return Response::error(400, &format!("'pixels' must hold {per_input} numbers"));
        }
        JobInput::Pixels(px)
    } else {
        match array_i32(body, "tokens", per_input) {
            Ok(t) => JobInput::Tokens(t),
            Err(r) => return r,
        }
    };
    let targets = match array_i32(body, "targets", per_target) {
        Ok(t) => t,
        Err(r) => return r,
    };

    let (tx, rx) = sync_channel(1);
    // enqueue timestamp feeds the queue-wait histogram only — never
    // result bits (determinism-lint exemption)
    #[allow(clippy::disallowed_methods)]
    let now = std::time::Instant::now();
    let job = EvalJob { model: id.clone(), input, targets, resp: tx, enqueued_at: now };
    match state.queue.push(job) {
        Err(PushError::Full(_)) => {
            Response::error(429, "admission queue full").with_header("Retry-After", "1")
        }
        Err(PushError::Quota(_)) => {
            state.metrics.rejected_quota.fetch_add(1, Ordering::Relaxed);
            Response::error(429, &format!("per-model quota for '{id}' exhausted"))
                .with_header("Retry-After", "1")
        }
        Err(PushError::Closed(_)) => Response::error(503, "server is shutting down"),
        Ok(()) => await_outcome(state, &rx, &model.meta, &id),
    }
}

/// Wait for an admitted eval job's outcome, polling in short ticks so
/// the handler notices a batcher that shutdown abandoned (it would
/// otherwise block the full `EVAL_TIMEOUT` and hold shutdown hostage).
fn await_outcome(
    state: &ServerState,
    rx: &Receiver<JobOutcome>,
    meta: &crate::model::config::ModelMeta,
    id: &str,
) -> Response {
    let deadline = super::http::deadline_after(EVAL_TIMEOUT);
    loop {
        match rx.recv_timeout(EVAL_TICK) {
            Ok(JobOutcome::Done { sum_nll, sum_correct, batch_size, version }) => {
                let denom = meta.eval_denominator() as f64;
                let nll = sum_nll / denom;
                return Response::json(
                    200,
                    &Json::obj(vec![
                        ("model", Json::str(id)),
                        ("version", Json::num(version as f64)),
                        ("batch_size", Json::num(batch_size as f64)),
                        ("sum_nll", Json::num(sum_nll)),
                        ("sum_correct", Json::num(sum_correct)),
                        ("nll", Json::num(nll)),
                        ("ppl", Json::num(nll.exp())),
                        ("accuracy", Json::num(sum_correct / denom)),
                    ]),
                );
            }
            Ok(JobOutcome::Failed { status, msg }) => return Response::error(status, &msg),
            Err(RecvTimeoutError::Disconnected) => {
                return Response::error(503, "batcher exited before answering");
            }
            Err(RecvTimeoutError::Timeout) => {
                if state.abandoned.load(Ordering::Relaxed) {
                    return Response::error(503, "batcher abandoned during shutdown drain");
                }
                if super::http::time_left(deadline).is_zero() {
                    return Response::error(504, "eval timed out in the batcher");
                }
            }
        }
    }
}

/// PTQ-on-upload: fit `scheme` on the source model's pristine fp32
/// weights and publish the result under a new id (default
/// `{src}@{canonical-scheme}`).
fn quantize(state: &ServerState, req: &Request) -> Response {
    let body = match body_str(req) {
        Ok(b) => b,
        Err(r) => return r,
    };
    let Some(src_id) = json::path_str(body, "model") else {
        return Response::error(400, "missing string field 'model'");
    };
    let Some(scheme_s) = json::path_str(body, "scheme") else {
        return Response::error(400, "missing string field 'scheme'");
    };
    let Some(src) = state.registry.get(&src_id) else {
        return Response::error(404, &format!("no such model '{src_id}'"));
    };
    let spec = match QuantSpec::parse(&scheme_s) {
        Ok(s) => s,
        Err(e) => return Response::error(400, &format!("bad scheme: {e}")),
    };
    let seed = json::path_f64(body, "seed").map(|v| v as u64).unwrap_or(DEFAULT_SEED);
    let new_id = json::path_str(body, "id").unwrap_or_else(|| format!("{src_id}@{spec}"));
    let q = match reencode_params(&src.fp, &src.meta, &spec, &mut Pcg::new(seed)) {
        Ok(q) => q,
        Err(e) => return Response::error(500, &format!("quantize failed: {e:#}")),
    };
    let served = ServedState {
        params: Arc::new(q.store),
        scheme: spec.to_string(),
        bytes: q.bytes,
        sq_error: q.sq_error,
        version: 1,
    };
    let model = ServedModel::new(src.meta.clone(), src.fp.clone(), src.fp_bytes, served);
    if state.registry.insert_new(&new_id, model).is_err() {
        return Response::error(409, &format!("model '{new_id}' already exists"));
    }
    match state.registry.get(&new_id) {
        Some(m) => Response::json(200, &model_json(&new_id, &m)),
        // unreachable: the registry is append-only — but a 500 beats a
        // worker panic if that invariant ever breaks
        None => Response::error(500, &format!("model '{new_id}' vanished after insert")),
    }
}

fn query_param(query: &str, name: &str) -> Option<String> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == name).then(|| v.to_string())
    })
}

/// Checksum-validated weight upload: `POST /v1/models/{id}/params`
/// replaces a served model's snapshot with the raw bytes of a QNP1
/// store or QNC1 checkpoint (the trainer's native outputs). An
/// optional `?checksum=<hex>` query must match the body's FNV-1a 64
/// hash; corrupt payloads are rejected with a typed 400 carrying the
/// byte offset where decoding stopped.
fn upload(state: &ServerState, req: &Request, id: &str) -> Response {
    let Some(model) = state.registry.get(id) else {
        return Response::error(404, &format!("no such model '{id}'"));
    };
    if req.body.is_empty() {
        return Response::error(400, "empty body; expected QNP1 or QNC1 bytes");
    }
    if let Some(want_s) = query_param(&req.query, "checksum") {
        let Some(want) = from_hex(&want_s) else {
            return Response::error(
                400,
                &format!("bad checksum '{want_s}': want up to 16 hex digits"),
            );
        };
        let got = fnv1a64(&req.body);
        if got != want {
            return Response::error(
                400,
                &format!(
                    "checksum mismatch: body hashes to {}, expected {}",
                    to_hex(got),
                    to_hex(want)
                ),
            );
        }
    }
    let loaded = if req.body.starts_with(b"QNC1") {
        checkpoint::params_from_qnc1_bytes(&req.body)
    } else {
        ParamStore::load_qnp1_bytes(&req.body)
    };
    let store = match loaded {
        Ok(s) => s,
        Err(e) => return Response::error(400, &e.to_string()),
    };
    if let Err(e) = store.check_against(&model.meta) {
        return Response::error(400, &format!("payload does not fit '{id}': {e:#}"));
    }
    // uploaded weights are served raw; sq_error tracks their drift
    // from the pristine fp32 copy the model was loaded with
    let mut sq = 0.0f64;
    for (n, t) in store.iter() {
        if let Some(ft) = model.fp.get(n) {
            for (a, b) in t.data.iter().zip(&ft.data) {
                let d = (*a - *b) as f64;
                sq += d * d;
            }
        }
    }
    let bytes = scheme_bytes(&model.meta, &QuantSpec::None);
    let version = model.swap(store, QuantSpec::None.to_string(), bytes, sq);
    state.metrics.swaps.fetch_add(1, Ordering::Relaxed);
    Response::json(
        200,
        &Json::obj(vec![
            ("id", Json::str(id)),
            ("version", Json::num(version as f64)),
            ("scheme", Json::str(QuantSpec::None.to_string())),
            ("storage_bytes", Json::num(bytes as f64)),
            ("sq_error", Json::num(sq)),
        ]),
    )
}

/// Online re-encode: refit the (possibly new) scheme on the pristine
/// fp32 weights and atomically swap the served snapshot — in-flight
/// evals keep their old Arc, later ones see the new version.
fn reencode(state: &ServerState, req: &Request, id: &str) -> Response {
    let Some(model) = state.registry.get(id) else {
        return Response::error(404, &format!("no such model '{id}'"));
    };
    let body = match body_str(req) {
        Ok(b) => b,
        Err(r) => return r,
    };
    let explicit = json::path_str(body, "scheme");
    let scheme_s = match &explicit {
        Some(s) => s.clone(),
        None => model.snapshot().scheme.clone(),
    };
    let spec = match QuantSpec::parse(&scheme_s) {
        Ok(s) => s,
        Err(e) => return Response::error(400, &format!("bad scheme: {e}")),
    };
    if explicit.is_none() && matches!(spec, QuantSpec::None) {
        return Response::error(400, "model is served fp32; pass 'scheme' to quantize it");
    }
    let seed = json::path_f64(body, "seed").map(|v| v as u64).unwrap_or(DEFAULT_SEED);
    let q = match reencode_params(&model.fp, &model.meta, &spec, &mut Pcg::new(seed)) {
        Ok(q) => q,
        Err(e) => return Response::error(500, &format!("re-encode failed: {e:#}")),
    };
    let version = model.swap(q.store, spec.to_string(), q.bytes, q.sq_error);
    state.metrics.swaps.fetch_add(1, Ordering::Relaxed);
    Response::json(
        200,
        &Json::obj(vec![
            ("id", Json::str(id)),
            ("version", Json::num(version as f64)),
            ("scheme", Json::str(spec.to_string())),
            ("storage_bytes", Json::num(q.bytes as f64)),
            ("sq_error", Json::num(q.sq_error)),
        ]),
    )
}

fn model_json(id: &str, m: &ServedModel) -> Json {
    let s = m.snapshot();
    let compression = if s.bytes > 0 { m.fp_bytes as f64 / s.bytes as f64 } else { 0.0 };
    let total_params: usize = m.meta.params.iter().map(|p| p.numel()).sum();
    Json::obj(vec![
        ("id", Json::str(id)),
        ("task", Json::str(m.meta.task.clone())),
        ("scheme", Json::str(s.scheme.clone())),
        ("version", Json::num(s.version as f64)),
        ("params", Json::num(m.meta.params.len() as f64)),
        ("total_params", Json::num(total_params as f64)),
        ("storage_bytes", Json::num(s.bytes as f64)),
        ("storage_bits", Json::num((s.bytes * 8) as f64)),
        ("fp32_bytes", Json::num(m.fp_bytes as f64)),
        ("compression", Json::num(compression)),
        ("sq_error", Json::num(s.sq_error)),
    ])
}

fn plan_cache_json() -> Json {
    let (hits, misses) = plan_cache_stats();
    Json::obj(vec![
        ("hits", Json::num(hits as f64)),
        ("misses", Json::num(misses as f64)),
    ])
}

fn models(state: &ServerState) -> Response {
    let list: Vec<Json> = state
        .registry
        .ids()
        .iter()
        .filter_map(|id| state.registry.get(id).map(|m| model_json(id, &m)))
        .collect();
    Response::json(
        200,
        &Json::obj(vec![("models", Json::Arr(list)), ("plan_cache", plan_cache_json())]),
    )
}

fn model_info(state: &ServerState, id: &str) -> Response {
    match state.registry.get(id) {
        Some(m) => Response::json(200, &model_json(id, &m)),
        None => Response::error(404, &format!("no such model '{id}'")),
    }
}

fn stats(state: &ServerState) -> Response {
    let mut j = state.metrics.to_json();
    if let Json::Obj(map) = &mut j {
        map.insert(
            "queue".into(),
            Json::obj(vec![
                ("depth", Json::num(state.queue.depth() as f64)),
                ("max_queue", Json::num(state.cfg.max_queue as f64)),
            ]),
        );
        map.insert("plan_cache".into(), plan_cache_json());
        map.insert("models".into(), Json::num(state.registry.len() as f64));
    }
    Response::json(200, &j)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn query_params_parse() {
        assert_eq!(query_param("checksum=ab12", "checksum"), Some("ab12".into()));
        assert_eq!(query_param("a=1&checksum=ff&b=2", "checksum"), Some("ff".into()));
        assert_eq!(query_param("a=1", "checksum"), None);
        assert_eq!(query_param("", "checksum"), None);
        assert_eq!(query_param("checksum", "checksum"), None); // no '='
    }

    #[test]
    fn flatteners_handle_nesting_and_reject_junk() {
        let v = Json::parse("[[1,2],[3,4]]").unwrap();
        let mut out = Vec::new();
        assert!(flat_i32(&v, 4, &mut out));
        assert_eq!(out, vec![1, 2, 3, 4]);
        // over cap
        let mut out = Vec::new();
        assert!(!flat_i32(&v, 3, &mut out));
        // non-integer
        let v = Json::parse("[1.5]").unwrap();
        let mut out = Vec::new();
        assert!(!flat_i32(&v, 4, &mut out));
        // but floats are fine for pixels
        let mut px = Vec::new();
        assert!(flat_f32(&v, 4, &mut px));
        assert_eq!(px, vec![1.5f32]);
        // strings rejected everywhere
        let v = Json::parse("[\"x\"]").unwrap();
        let mut out = Vec::new();
        assert!(!flat_i32(&v, 4, &mut out));
    }
}
