//! Offline-substrate utilities: JSON, CLI, RNG, logging, benchmarking,
//! property testing (the image's crate registry only vendors the xla
//! closure, so these replace serde/clap/rand/env_logger/criterion/proptest).
pub mod bench;
pub mod cli;
pub mod fault;
pub mod hash;
pub mod json;
pub mod logging;
pub mod rng;
pub mod testing;
