//! Tiny declarative CLI argument parser (clap is not in the offline
//! registry). Supports `--flag`, `--key value`, `--key=value`, positional
//! arguments, defaults, and generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
    pub required: bool,
    /// accepted alternative spelling; values are stored under `name`
    pub alias: Option<&'static str>,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn parse_num<T: std::str::FromStr>(&self, name: &str) -> Option<T> {
        self.get(name).and_then(|v| v.parse().ok())
    }
    pub fn num_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.parse_num(name).unwrap_or(default)
    }
}

pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    specs: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
            required: false,
            alias: None,
        });
        self
    }

    pub fn opt_default(
        mut self,
        name: &'static str,
        default: &str,
        help: &'static str,
    ) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
            required: false,
            alias: None,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
            required: true,
            alias: None,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: true,
            required: false,
            alias: None,
        });
        self
    }

    /// Accept `--alias` as another spelling of the most recently added
    /// option (values land under the canonical name).
    pub fn alias(mut self, alias: &'static str) -> Self {
        if let Some(last) = self.specs.last_mut() {
            last.alias = Some(alias);
        }
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = writeln!(s, "options:");
        for spec in &self.specs {
            let kind = if spec.is_flag { "" } else { " <value>" };
            let def = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let req = if spec.required { " (required)" } else { "" };
            let alias = spec
                .alias
                .map(|a| format!(" (alias --{a})"))
                .unwrap_or_default();
            let _ = writeln!(s, "  --{}{kind}\t{}{def}{req}{alias}", spec.name, spec.help);
        }
        s
    }

    /// Parse an argv slice (without the program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        for spec in &self.specs {
            if let Some(d) = &spec.default {
                out.values.insert(spec.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name || s.alias == Some(name))
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{name} is a flag, no value allowed"));
                    }
                    out.flags.push(spec.name.to_string());
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} needs a value"))?
                        }
                    };
                    out.values.insert(spec.name.to_string(), v);
                }
            } else {
                out.positionals.push(a.clone());
            }
            i += 1;
        }
        for spec in &self.specs {
            if spec.required && !out.values.contains_key(spec.name) {
                return Err(format!("missing required --{}\n\n{}", spec.name, self.usage()));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("t", "test")
            .req("model", "model name")
            .opt_default("steps", "100", "steps")
            .alias("iters")
            .flag("verbose", "chatty")
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = cmd()
            .parse(&argv(&["--model", "lm", "--steps=50", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get("model"), Some("lm"));
        assert_eq!(a.num_or::<usize>("steps", 0), 50);
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&["--model", "x"])).unwrap();
        assert_eq!(a.get("steps"), Some("100"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cmd().parse(&argv(&["--steps", "1"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&argv(&["--model", "x", "--bogus", "1"])).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(cmd().parse(&argv(&["--model", "x", "--verbose=1"])).is_err());
    }

    #[test]
    fn help_is_error_with_usage() {
        let e = cmd().parse(&argv(&["--help"])).unwrap_err();
        assert!(e.contains("--model"));
        assert!(e.contains("--steps"));
        assert!(e.contains("alias --iters"));
    }

    #[test]
    fn alias_resolves_to_canonical_name() {
        let a = cmd().parse(&argv(&["--model", "lm", "--iters", "7"])).unwrap();
        assert_eq!(a.get("steps"), Some("7"));
        assert_eq!(a.get("iters"), None);
        let a = cmd().parse(&argv(&["--model", "lm", "--iters=9"])).unwrap();
        assert_eq!(a.num_or::<usize>("steps", 0), 9);
    }
}
