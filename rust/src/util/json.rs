//! Minimal JSON parser/serializer.
//!
//! The offline crate registry only vendors the `xla` closure, so serde is
//! unavailable; this module is the project's JSON substrate. It supports
//! the full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null) which is all the manifest/config files need.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap so serialization is
/// deterministic (useful for golden-file tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Object field access; `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte 0x{c:02x}"))),
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }
    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xd800..0xdc00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    let c =
                                        0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Collect the raw UTF-8 byte run directly.
                    let start = self.i - 1;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }
    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

// ------------------------------------------------------------ serialize ---

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Bool(false));
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        // surrogate pair: 😀
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true,"s\"x"],"n":-3,"o":{"k":[]}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""\q""#).is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" :\r [ ] } ").unwrap();
        assert_eq!(v.get("a"), &Json::Arr(vec![]));
    }
}
