//! Minimal JSON parser/serializer.
//!
//! The offline crate registry only vendors the `xla` closure, so serde is
//! unavailable; this module is the project's JSON substrate. It supports
//! the full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null) which is all the manifest/config files need.
//!
//! Two access styles:
//! - tree: [`Json::parse`] + [`Json::get`]/[`Json::get_path`];
//! - lazy: [`path_value`]/[`path_str`]/[`path_f64`] scan the raw bytes
//!   and materialize only the value addressed by an `"a.b[2].c"` path,
//!   skipping (not building) everything else — the cheap way for
//!   request handlers to pluck a small field out of a large body.
//!
//! Nesting depth is capped ([`MAX_DEPTH`]) so hostile bodies cannot
//! overflow the stack, and the lazy skipper is iterative for the same
//! reason.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap so serialization is
/// deterministic (useful for golden-file tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Maximum object/array nesting [`Json::parse`] and the lazy path
/// scanners accept. Deeper documents are rejected, not recursed into.
pub const MAX_DEPTH: usize = 512;

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser::new(s);
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Object field access; `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }
    /// Navigate a parsed tree by an `"a.b[2].c"`-style path.
    /// `Json::Null` for anything missing or a malformed path — the same
    /// total contract as [`Json::get`].
    pub fn get_path(&self, path: &str) -> &Json {
        static NULL: Json = Json::Null;
        let Ok(steps) = parse_path(path) else {
            return &NULL;
        };
        let mut cur = self;
        for s in &steps {
            let next = match s {
                Step::Key(k) => cur.as_obj().and_then(|m| m.get(*k)),
                Step::Index(n) => cur.as_arr().and_then(|a| a.get(*n)),
            };
            match next {
                Some(v) => cur = v,
                None => return &NULL,
            }
        }
        cur
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

// ----------------------------------------------------------- lazy paths ---

/// One step of an `"a.b[2].c"` path: a key lookup or an array index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Step<'a> {
    Key(&'a str),
    Index(usize),
}

/// Parse `"a.b[2].c"` into steps. Keys are any run of bytes other than
/// `.`/`[`; indices are `[<digits>]` and may chain (`"m[0][1]"`, or
/// `"[2]"` when the document root is an array).
fn parse_path(path: &str) -> Result<Vec<Step<'_>>, JsonError> {
    let perr = |msg: &str, pos: usize| JsonError { msg: format!("bad path: {msg}"), pos };
    let b = path.as_bytes();
    let mut steps = Vec::new();
    let mut i = 0;
    while i < b.len() {
        if b[i] == b'[' {
            let start = i + 1;
            let mut j = start;
            while j < b.len() && b[j].is_ascii_digit() {
                j += 1;
            }
            if j == start || b.get(j) != Some(&b']') {
                return Err(perr("expected [<digits>]", i));
            }
            let n = path[start..j].parse().map_err(|_| perr("index out of range", start))?;
            steps.push(Step::Index(n));
            i = j + 1;
        } else {
            let start = i;
            while i < b.len() && b[i] != b'.' && b[i] != b'[' {
                i += 1;
            }
            if i == start {
                return Err(perr("empty key", i));
            }
            steps.push(Step::Key(&path[start..i]));
        }
        // a '.' separates this step from a following *named* key
        if i < b.len() && b[i] == b'.' {
            i += 1;
            if i == b.len() || b[i] == b'.' || b[i] == b'[' {
                return Err(perr("empty key", i));
            }
        }
    }
    if steps.is_empty() {
        return Err(perr("empty path", 0));
    }
    Ok(steps)
}

/// Lazily extract the value at `path` without building the full tree:
/// scan the bytes, skip every value the path does not address, and
/// parse only the target (mik-sdk ADR-002 measured ~33x for partial
/// reads of large payloads). `Ok(None)` when the path is absent.
/// Skipped regions get bracket/string-level validation only.
pub fn path_value(src: &str, path: &str) -> Result<Option<Json>, JsonError> {
    let steps = parse_path(path)?;
    let mut p = Parser::new(src);
    p.skip_ws();
    if !p.seek(&steps)? {
        return Ok(None);
    }
    Ok(Some(p.value()?))
}

/// Lazy scan for a string at `path`; `None` if absent, mistyped, or
/// the document is malformed.
pub fn path_str(src: &str, path: &str) -> Option<String> {
    match path_value(src, path) {
        Ok(Some(Json::Str(s))) => Some(s),
        _ => None,
    }
}

/// Lazy scan for a number at `path`; `None` if absent, mistyped, or
/// the document is malformed.
pub fn path_f64(src: &str, path: &str) -> Option<f64> {
    match path_value(src, path) {
        Ok(Some(Json::Num(n))) => Some(n),
        _ => None,
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Parser<'a> {
        Parser { b: s.as_bytes(), i: 0, depth: 0 }
    }
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn value(&mut self) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.depth += 1;
        let v = match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte 0x{c:02x}"))),
        }?;
        self.depth -= 1;
        Ok(v)
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }
    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xd800..0xdc00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    let c =
                                        0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Collect the raw UTF-8 byte run directly.
                    let start = self.i - 1;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }
    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    // ------------------------------------------------ lazy skip/seek ---

    /// Advance past one string literal without materializing it.
    fn skip_string(&mut self) -> Result<(), JsonError> {
        self.eat(b'"')?;
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(());
                }
                b'\\' => {
                    // skip the escape introducer and the escaped byte;
                    // \uXXXX needs no care: hex digits are ordinary bytes
                    self.i += 1;
                    if self.peek().is_none() {
                        return Err(self.err("bad escape"));
                    }
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
    }

    /// Advance past one complete JSON value without building a tree.
    /// Iterative (a depth counter, not recursion) so arbitrarily nested
    /// hostile input cannot overflow the stack; skipped regions are
    /// validated only at the bracket/string level.
    fn skip_value(&mut self) -> Result<(), JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'"' => self.skip_string(),
            b'{' | b'[' => {
                let mut depth = 0usize;
                loop {
                    match self.peek().ok_or_else(|| self.err("unterminated value"))? {
                        b'"' => {
                            self.skip_string()?;
                            continue;
                        }
                        b'{' | b'[' => depth += 1,
                        b'}' | b']' => {
                            depth = depth.checked_sub(1).ok_or_else(|| self.err("unbalanced"))?
                        }
                        _ => {}
                    }
                    self.i += 1;
                    if depth == 0 {
                        return Ok(());
                    }
                }
            }
            b't' => self.lit("true", Json::Null).map(|_| ()),
            b'f' => self.lit("false", Json::Null).map(|_| ()),
            b'n' => self.lit("null", Json::Null).map(|_| ()),
            b'-' | b'0'..=b'9' => self.number().map(|_| ()),
            c => Err(self.err(&format!("unexpected byte 0x{c:02x}"))),
        }
    }

    /// Position the cursor at the start of the value addressed by
    /// `steps`. `Ok(false)` when any step is absent (wrong container
    /// kind, missing key, index past the end).
    fn seek(&mut self, steps: &[Step<'_>]) -> Result<bool, JsonError> {
        for step in steps {
            self.skip_ws();
            match step {
                Step::Key(k) => {
                    if self.peek() != Some(b'{') {
                        return Ok(false);
                    }
                    self.i += 1;
                    loop {
                        self.skip_ws();
                        if self.peek() == Some(b'}') {
                            self.i += 1;
                            return Ok(false);
                        }
                        let key = self.string()?;
                        self.skip_ws();
                        self.eat(b':')?;
                        self.skip_ws();
                        if key == *k {
                            break; // cursor is at this key's value
                        }
                        self.skip_value()?;
                        self.skip_ws();
                        match self.peek() {
                            Some(b',') => self.i += 1,
                            Some(b'}') => {
                                self.i += 1;
                                return Ok(false);
                            }
                            _ => return Err(self.err("expected ',' or '}'")),
                        }
                    }
                }
                Step::Index(n) => {
                    if self.peek() != Some(b'[') {
                        return Ok(false);
                    }
                    self.i += 1;
                    let mut idx = 0usize;
                    loop {
                        self.skip_ws();
                        if self.peek() == Some(b']') {
                            self.i += 1;
                            return Ok(false);
                        }
                        if idx == *n {
                            break; // cursor is at element n
                        }
                        self.skip_value()?;
                        self.skip_ws();
                        match self.peek() {
                            Some(b',') => {
                                self.i += 1;
                                idx += 1;
                            }
                            Some(b']') => {
                                self.i += 1;
                                return Ok(false);
                            }
                            _ => return Err(self.err("expected ',' or ']'")),
                        }
                    }
                }
            }
        }
        Ok(true)
    }
}

// ------------------------------------------------------------ serialize ---

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Bool(false));
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        // surrogate pair: 😀
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true,"s\"x"],"n":-3,"o":{"k":[]}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""\q""#).is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" :\r [ ] } ").unwrap();
        assert_eq!(v.get("a"), &Json::Arr(vec![]));
    }

    const DOC: &str = r#"{
        "model": "lm_tiny",
        "big": [0, 1, 2, 3, 4, 5, 6, 7],
        "nested": {"a": [{"b": 10}, {"b": [20, 21]}], "s": "x\"]y"},
        "f": -2.5
    }"#;

    #[test]
    fn get_path_navigates_tree() {
        let v = Json::parse(DOC).unwrap();
        assert_eq!(v.get_path("model").as_str(), Some("lm_tiny"));
        assert_eq!(v.get_path("nested.a[1].b[0]").as_f64(), Some(20.0));
        assert_eq!(v.get_path("big[7]").as_f64(), Some(7.0));
        assert!(v.get_path("nested.a[2]").is_null());
        assert!(v.get_path("nested.missing").is_null());
        assert!(v.get_path("model[0]").is_null()); // not an array
        assert!(v.get_path("").is_null()); // malformed path
    }

    #[test]
    fn lazy_path_matches_tree_walk() {
        let v = Json::parse(DOC).unwrap();
        for p in ["model", "big[3]", "nested.a[1].b[1]", "nested.s", "f", "nested.a[0]"] {
            assert_eq!(path_value(DOC, p).unwrap().as_ref(), Some(v.get_path(p)), "path {p}");
        }
        assert_eq!(path_value(DOC, "missing").unwrap(), None);
        assert_eq!(path_value(DOC, "big[8]").unwrap(), None);
        assert_eq!(path_value(DOC, "model.x").unwrap(), None);
        assert_eq!(path_str(DOC, "model").as_deref(), Some("lm_tiny"));
        assert_eq!(path_str(DOC, "f"), None); // type mismatch
        assert_eq!(path_f64(DOC, "f"), Some(-2.5));
    }

    #[test]
    fn lazy_path_skips_strings_with_brackets() {
        // the "s" value contains '"' and ']' — the skipper must not be
        // fooled while scanning past it to reach "z"
        let doc = r#"{"s": "tr\"icky]}", "z": 9}"#;
        assert_eq!(path_f64(doc, "z"), Some(9.0));
    }

    #[test]
    fn lazy_path_array_root() {
        assert_eq!(path_f64(r#"[5, [6, 7]]"#, "[1][0]"), Some(6.0));
        assert_eq!(path_value(r#"[5]"#, "[1]").unwrap(), None);
    }

    #[test]
    fn bad_paths_rejected() {
        for p in ["", ".", "a..b", "a.", "a.[0]", "a[", "a[]", "a[x]"] {
            assert!(path_value(DOC, p).is_err(), "path {p:?} should be malformed");
        }
    }

    #[test]
    fn lazy_path_reports_malformed_doc() {
        assert!(path_value(r#"{"a": [1, "b": 2}"#, "b").is_err());
        assert!(path_value(r#"{"a": "#, "b").is_err());
    }

    #[test]
    fn depth_capped() {
        let deep = "[".repeat(MAX_DEPTH + 8) + &"]".repeat(MAX_DEPTH + 8);
        assert!(Json::parse(&deep).is_err());
        // the iterative skipper is immune to depth
        let doc = format!("{{\"deep\": {deep}, \"z\": 1}}");
        assert_eq!(path_f64(&doc, "z"), Some(1.0));
        let ok = "[".repeat(64) + "1" + &"]".repeat(64);
        assert!(Json::parse(&ok).is_ok());
    }
}
