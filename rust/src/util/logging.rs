//! Minimal leveled logger (env_logger is not in the offline registry).
//! Level from `QN_LOG` (error|warn|info|debug|trace), default info.

// timestamps decorate log lines only, never results (clippy.toml bans
// Instant::now in result-feeding code)
#![allow(clippy::disallowed_methods)]

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(2); // info
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("QN_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "error" => 0,
            "warn" => 1,
            "info" => 2,
            "debug" => 3,
            "trace" => 4,
            _ => 2,
        };
        LEVEL.store(lvl, Ordering::Relaxed);
    }
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        init();
        LEVEL.store(1, Ordering::Relaxed);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        LEVEL.store(2, Ordering::Relaxed);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
