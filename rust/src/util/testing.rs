//! Property-testing harness (proptest is not in the offline registry).
//!
//! A `prop_check` runner drives a generator function over many seeded
//! cases; on failure it retries with simpler size hints (a lightweight
//! stand-in for shrinking) and reports the failing seed so the case can
//! be replayed deterministically.

use crate::util::rng::Pcg;

pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 128, seed: 0xC0FFEE }
    }
}

/// Size hint passed to generators; starts small and grows, so early
/// failures happen on small cases (cheap shrinking by construction).
#[derive(Debug, Clone, Copy)]
pub struct Size(pub usize);

/// Run `prop(rng, size)` for `cfg.cases` seeded cases. The property
/// returns `Err(msg)` to fail. Panics with seed + case info on failure.
pub fn prop_check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Pcg, Size) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg::new(case_seed);
        // ramp size from 1 to ~64 over the run
        let size = Size(1 + case * 64 / cfg.cases.max(1));
        if let Err(msg) = prop(&mut rng, size) {
            panic!(
                "property '{name}' failed on case {case} (seed={case_seed:#x}, size={}):\n  {msg}",
                size.0
            );
        }
    }
}

// ------------------------------------------------------- generators ---

pub fn gen_vec_f32(rng: &mut Pcg, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.next_normal() * scale).collect()
}

pub fn gen_matrix(rng: &mut Pcg, rows: usize, cols: usize) -> Vec<f32> {
    gen_vec_f32(rng, rows * cols, 1.0)
}

/// Dimensions that exercise edge cases: tiny, non-multiples, larger.
pub fn gen_dim(rng: &mut Pcg, size: Size) -> usize {
    let caps = [1usize, 2, 3, 4, 7, 8, 12, 16, 31, 32, 64];
    let max = (size.0 + 1).min(caps.len());
    caps[rng.below(max as u32) as usize]
}

pub fn approx_eq(a: f32, b: f32, tol: f32) -> bool {
    let diff = (a - b).abs();
    diff <= tol * (1.0 + a.abs().max(b.abs()))
}

pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        if !approx_eq(x, y, tol) {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Unique temp dir for tests (tempfile crate is unavailable offline).
pub fn temp_dir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    let d = std::env::temp_dir().join(format!(
        "qn-test-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&d).expect("create temp dir");
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_check_passes_trivial() {
        prop_check("trivial", PropConfig { cases: 16, ..Default::default() }, |rng, _| {
            let x = rng.next_f32();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn prop_check_reports_failure() {
        prop_check("fails", PropConfig { cases: 8, ..Default::default() }, |_, _| {
            Err("always".into())
        });
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.5], 1e-3).is_err());
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-6], 1e-4).is_ok());
    }

    #[test]
    fn gen_dim_respects_size() {
        let mut r = Pcg::new(1);
        for _ in 0..50 {
            assert_eq!(gen_dim(&mut r, Size(0)), 1);
        }
    }

    #[test]
    fn temp_dirs_unique() {
        let a = temp_dir("x");
        let b = temp_dir("x");
        assert_ne!(a, b);
        std::fs::remove_dir_all(a).ok();
        std::fs::remove_dir_all(b).ok();
    }
}
