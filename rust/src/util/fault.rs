//! Deterministic fault injection (DESIGN.md §10).
//!
//! Crash-safety claims are only as good as the failures exercised, so
//! every fragile seam — checkpoint I/O, artifact loading, the serve
//! accept/read/write/batch paths — calls a *named fault point* here.
//! In production the registry is empty and a check is one relaxed
//! atomic-free read of an unset `RwLock` option; under test the
//! `QN_FAULT` environment variable (or [`install`] in-process) arms
//! points with a spec:
//!
//! ```text
//!   QN_FAULT="point=kind[:arg][@N[+]][~permille:seed];point2=..."
//! ```
//!
//! Kinds:
//! - `err`        — the call fails with an injected `io::Error`
//! - `short`      — [`write_all`] writes only half the bytes, then fails
//!                  (a torn write / full-disk simulation)
//! - `kill`       — the process exits immediately with code 137
//!                  (SIGKILL-alike: no destructors, no flushes)
//! - `hang:<ms>`  — the call sleeps `<ms>` milliseconds, then succeeds
//!                  (a wedged backend / stuck peer simulation)
//!
//! Triggers (default: every hit):
//! - `@N`  — only the N-th hit (1-based) fires
//! - `@N+` — the N-th and every later hit fire
//! - `~permille:seed` — each hit fires with probability permille/1000,
//!   decided by a PRNG keyed on (seed, point name, hit index): the same
//!   spec replays the same fault schedule bit-for-bit on every run.
//!
//! Point names are dotted `layer.action` (e.g. `ckpt.write`,
//! `serve.batch`); the full inventory lives in DESIGN.md §10.

use std::collections::BTreeMap;
use std::io::{Error, ErrorKind, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, PoisonError, RwLock};
use std::time::Duration;

use crate::util::hash::fnv1a64;
use crate::util::rng::Pcg;

#[derive(Debug, Clone, PartialEq, Eq)]
enum Kind {
    Err,
    Short,
    Kill,
    Hang(u64),
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum When {
    Always,
    Nth(u64),
    From(u64),
    Permille { permille: u32, seed: u64 },
}

#[derive(Debug)]
struct Point {
    kind: Kind,
    when: When,
    hits: AtomicU64,
}

/// A parsed fault plan: named points with kinds and triggers.
#[derive(Debug, Default)]
pub struct Faults {
    points: BTreeMap<String, Point>,
}

impl Faults {
    /// Parse a `QN_FAULT` spec (grammar in the module docs).
    pub fn parse(spec: &str) -> Result<Faults, String> {
        let mut points = BTreeMap::new();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (name, rhs) = clause
                .split_once('=')
                .ok_or_else(|| format!("fault clause '{clause}' is missing '='"))?;
            let name = name.trim();
            if name.is_empty() {
                return Err(format!("fault clause '{clause}' has an empty point name"));
            }
            // split the trigger suffix off the kind
            let (kind_s, when) = if let Some((k, t)) = rhs.split_once('@') {
                let (n_s, from) = match t.strip_suffix('+') {
                    Some(n) => (n, true),
                    None => (t, false),
                };
                let n: u64 = n_s
                    .parse()
                    .map_err(|_| format!("'{clause}': bad hit index '{n_s}'"))?;
                if n == 0 {
                    return Err(format!("'{clause}': hit indices are 1-based"));
                }
                (k, if from { When::From(n) } else { When::Nth(n) })
            } else if let Some((k, t)) = rhs.split_once('~') {
                let (p_s, s_s) = t
                    .split_once(':')
                    .ok_or_else(|| format!("'{clause}': want ~permille:seed"))?;
                let permille: u32 = p_s
                    .parse()
                    .ok()
                    .filter(|&p| p <= 1000)
                    .ok_or_else(|| format!("'{clause}': bad permille '{p_s}'"))?;
                let seed: u64 =
                    s_s.parse().map_err(|_| format!("'{clause}': bad seed '{s_s}'"))?;
                (k, When::Permille { permille, seed })
            } else {
                (rhs, When::Always)
            };
            let kind = match kind_s.trim() {
                "err" => Kind::Err,
                "short" => Kind::Short,
                "kill" => Kind::Kill,
                other => match other.strip_prefix("hang:") {
                    Some(ms) => Kind::Hang(
                        ms.parse()
                            .map_err(|_| format!("'{clause}': bad hang duration '{ms}'"))?,
                    ),
                    None => {
                        return Err(format!(
                            "'{clause}': unknown kind '{other}' (err|short|kill|hang:<ms>)"
                        ))
                    }
                },
            };
            points.insert(name.to_string(), Point { kind, when, hits: AtomicU64::new(0) });
        }
        Ok(Faults { points })
    }

    /// Record a hit at `name`; returns the fault to inject, if any.
    fn fire(&self, name: &str) -> Option<Kind> {
        let p = self.points.get(name)?;
        let n = p.hits.fetch_add(1, Ordering::Relaxed) + 1;
        let hit = match p.when {
            When::Always => true,
            When::Nth(k) => n == k,
            When::From(k) => n >= k,
            When::Permille { permille, seed } => {
                // keyed on (seed, point, hit index): deterministic per
                // hit, independent of thread scheduling
                let mut rng = Pcg::new(seed ^ fnv1a64(name.as_bytes()) ^ n);
                rng.below(1000) < permille
            }
        };
        hit.then(|| p.kind.clone())
    }
}

/// Process-global registry. `None` (the overwhelmingly common case)
/// means fault injection is disabled.
fn registry() -> &'static RwLock<Option<Arc<Faults>>> {
    static REG: OnceLock<RwLock<Option<Arc<Faults>>>> = OnceLock::new();
    REG.get_or_init(|| {
        let initial = std::env::var("QN_FAULT").ok().and_then(|spec| {
            if spec.trim().is_empty() {
                return None;
            }
            match Faults::parse(&spec) {
                Ok(f) => Some(Arc::new(f)),
                Err(e) => {
                    crate::log_warn!("QN_FAULT ignored: {e}");
                    None
                }
            }
        });
        RwLock::new(initial)
    })
}

fn current() -> Option<Arc<Faults>> {
    registry().read().unwrap_or_else(PoisonError::into_inner).clone()
}

/// Arm a fault plan in-process (tests). Replaces any active plan,
/// including one loaded from `QN_FAULT`. Hit counters start at zero.
pub fn install(spec: &str) -> Result<(), String> {
    let f = Arc::new(Faults::parse(spec)?);
    *registry().write().unwrap_or_else(PoisonError::into_inner) = Some(f);
    Ok(())
}

/// Disarm all fault points.
pub fn clear() {
    *registry().write().unwrap_or_else(PoisonError::into_inner) = None;
}

/// True when any fault plan is armed (cheap gate for hot paths).
pub fn active() -> bool {
    registry().read().unwrap_or_else(PoisonError::into_inner).is_some()
}

fn injected(name: &str) -> Error {
    Error::new(ErrorKind::Other, format!("injected fault at '{name}'"))
}

/// Pass through the named fault point. `Ok(())` unless an armed fault
/// fires: `err`/`short` return an injected `io::Error`, `hang` sleeps
/// first, `kill` exits the process (no unwinding — a crash, not an
/// error path).
pub fn check(name: &str) -> std::io::Result<()> {
    let Some(f) = current() else {
        return Ok(());
    };
    match f.fire(name) {
        None => Ok(()),
        Some(Kind::Err) | Some(Kind::Short) => Err(injected(name)),
        Some(Kind::Hang(ms)) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
        Some(Kind::Kill) => die(name),
    }
}

fn die(name: &str) -> ! {
    // stderr directly: the logger may hold locks we must not touch in a
    // simulated crash
    eprintln!("qn: injected kill at fault point '{name}'");
    std::process::exit(137);
}

/// Fault-aware `write_all`: `short` writes the first half of `bytes`
/// and then fails (the torn-write case atomic protocols must survive);
/// `kill` writes the first half and exits; `err` fails before writing
/// anything; `hang` sleeps, then writes normally.
pub fn write_all(name: &str, w: &mut impl Write, bytes: &[u8]) -> std::io::Result<()> {
    let fired = current().and_then(|f| f.fire(name));
    match fired {
        None => w.write_all(bytes),
        Some(Kind::Err) => Err(injected(name)),
        Some(Kind::Short) => {
            w.write_all(&bytes[..bytes.len() / 2])?;
            let _ = w.flush();
            Err(injected(name))
        }
        Some(Kind::Kill) => {
            let _ = w.write_all(&bytes[..bytes.len() / 2]);
            let _ = w.flush();
            die(name)
        }
        Some(Kind::Hang(ms)) => {
            std::thread::sleep(Duration::from_millis(ms));
            w.write_all(bytes)
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    // The registry is process-global; unit tests here only exercise the
    // pure parser/fire layer so they cannot race integration tests that
    // install/clear plans (those live in their own test binaries).

    #[test]
    fn parse_kinds_and_triggers() {
        let f = Faults::parse("a.b=err;c.d=short@2;e.f=kill@3+;g.h=hang:50").unwrap();
        assert_eq!(f.points.len(), 4);
        assert_eq!(f.points["a.b"].kind, Kind::Err);
        assert_eq!(f.points["a.b"].when, When::Always);
        assert_eq!(f.points["c.d"].when, When::Nth(2));
        assert_eq!(f.points["e.f"].when, When::From(3));
        assert_eq!(f.points["g.h"].kind, Kind::Hang(50));
    }

    #[test]
    fn parse_rejects_junk() {
        assert!(Faults::parse("noequals").is_err());
        assert!(Faults::parse("=err").is_err());
        assert!(Faults::parse("a=zap").is_err());
        assert!(Faults::parse("a=err@0").is_err());
        assert!(Faults::parse("a=err@x").is_err());
        assert!(Faults::parse("a=hang:xs").is_err());
        assert!(Faults::parse("a=err~1001:3").is_err());
        assert!(Faults::parse("a=err~5").is_err()); // missing :seed
        assert!(Faults::parse("").unwrap().points.is_empty());
        assert!(Faults::parse(" ; ").unwrap().points.is_empty());
    }

    #[test]
    fn nth_fires_exactly_once() {
        let f = Faults::parse("p=err@3").unwrap();
        let fired: Vec<bool> = (0..6).map(|_| f.fire("p").is_some()).collect();
        assert_eq!(fired, vec![false, false, true, false, false, false]);
    }

    #[test]
    fn from_fires_onward_and_unknown_points_never_fire() {
        let f = Faults::parse("p=err@2+").unwrap();
        let fired: Vec<bool> = (0..4).map(|_| f.fire("p").is_some()).collect();
        assert_eq!(fired, vec![false, true, true, true]);
        assert!(f.fire("other").is_none());
    }

    #[test]
    fn permille_is_deterministic_and_roughly_calibrated() {
        let run = || {
            let f = Faults::parse("p=err~250:42").unwrap();
            (0..2000).map(|_| f.fire("p").is_some()).collect::<Vec<bool>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same spec must replay the same schedule");
        let hits = a.iter().filter(|&&x| x).count();
        assert!((300..700).contains(&hits), "~25% of 2000, got {hits}");
    }

    #[test]
    fn short_write_is_torn_then_fails() {
        let f = Faults::parse("w=short").unwrap();
        // drive write_all's logic through a local plan
        let mut out: Vec<u8> = Vec::new();
        let bytes = b"0123456789";
        let r = match f.fire("w") {
            Some(Kind::Short) => {
                out.extend_from_slice(&bytes[..bytes.len() / 2]);
                Err(injected("w"))
            }
            _ => panic!("short must fire"),
        };
        assert!(r.is_err());
        assert_eq!(out, b"01234");
    }
}
