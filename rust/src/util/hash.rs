//! FNV-1a 64-bit content hashing for artifact integrity.
//!
//! Used by the QNC1 checkpoint trailer, the `LATEST` last-good pointer
//! and checksum-validated serve uploads. FNV-1a is not cryptographic —
//! it guards against torn writes and bit rot, not adversaries — but it
//! detects every single-bit flip: both the xor and the multiply by an
//! odd prime are bijections on u64, so two byte streams that differ
//! anywhere keep distinct running states (mirror-validated empirically
//! in `tools/qnsim/ckpt_mirror.py`).

pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Streaming FNV-1a (hash large payloads without concatenating).
#[derive(Debug, Clone)]
pub struct Fnv1a64(u64);

impl Fnv1a64 {
    pub fn new() -> Fnv1a64 {
        Fnv1a64(FNV_OFFSET)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a64 {
    fn default() -> Self {
        Self::new()
    }
}

/// Lower-case 16-digit hex (the on-disk/manifest encoding of a hash —
/// `util::json` numbers are f64 and cannot carry a full u64).
pub fn to_hex(x: u64) -> String {
    format!("{x:016x}")
}

/// Parse a hex string as written by [`to_hex`] (leading zeros optional).
pub fn from_hex(s: &str) -> Option<u64> {
    if s.is_empty() || s.len() > 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data = b"the quick brown fox";
        let mut h = Fnv1a64::new();
        h.update(&data[..7]);
        h.update(&data[7..]);
        assert_eq!(h.finish(), fnv1a64(data));
    }

    #[test]
    fn single_bit_flips_always_detected() {
        let base = b"QNC1 checkpoint payload 0123456789".to_vec();
        let want = fnv1a64(&base);
        for i in 0..base.len() {
            for bit in 0..8 {
                let mut m = base.clone();
                m[i] ^= 1 << bit;
                assert_ne!(fnv1a64(&m), want, "flip at byte {i} bit {bit} undetected");
            }
        }
    }

    #[test]
    fn hex_roundtrip() {
        for x in [0u64, 1, 0xdead_beef, u64::MAX, 0x0123_4567_89ab_cdef] {
            assert_eq!(from_hex(&to_hex(x)), Some(x));
        }
        assert_eq!(from_hex(""), None);
        assert_eq!(from_hex("zz"), None);
        assert_eq!(from_hex("00000000000000000"), None); // 17 digits
    }
}
