//! Micro-benchmark harness (criterion is not in the offline registry).
//!
//! Warmup + timed iterations with robust statistics (median, mean, p10,
//! p90, std); auto-scales the iteration count to a time budget the way
//! criterion does. `cargo bench` targets use this via `harness = false`.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub std_ns: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}  (n={})",
            self.name,
            fmt_ns(self.median_ns),
            format!("±{}", fmt_ns(self.std_ns)),
            format!("p10={}", fmt_ns(self.p10_ns)),
            format!("p90={}", fmt_ns(self.p90_ns)),
            self.iters,
        )
    }

    pub fn median(&self) -> Duration {
        Duration::from_nanos(self.median_ns as u64)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 100_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            ..Default::default()
        }
    }

    /// Run `f` repeatedly, print and record stats. The closure should
    /// return something to keep the optimizer honest (it is black-boxed).
    // timing IS this function's output; it never feeds model results
    #[allow(clippy::disallowed_methods)]
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        // warmup & calibration
        let wstart = Instant::now();
        let mut wcount = 0usize;
        while wstart.elapsed() < self.warmup || wcount < 2 {
            std::hint::black_box(f());
            wcount += 1;
        }
        let per_iter = wstart.elapsed().as_secs_f64() / wcount as f64;
        let iters = ((self.budget.as_secs_f64() / per_iter.max(1e-9)) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let std = (samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
            / samples.len() as f64)
            .sqrt();
        let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
        let stats = BenchStats {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            median_ns: q(0.5),
            p10_ns: q(0.1),
            p90_ns: q(0.9),
            std_ns: std,
        };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    pub fn find(&self, name: &str) -> Option<&BenchStats> {
        self.results.iter().find(|r| r.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(20),
            ..Default::default()
        };
        let s = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.median_ns > 0.0);
        assert!(s.iters >= 5);
    }

    #[test]
    fn percentiles_ordered() {
        let mut b = Bencher::quick();
        b.budget = Duration::from_millis(10);
        b.warmup = Duration::from_millis(2);
        let s = b.bench("noop", || 1 + 1).clone();
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50µs");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }
}
