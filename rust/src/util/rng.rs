//! Deterministic, splittable PRNG (PCG-XSH-RR 64/32).
//!
//! Every stochastic choice in the coordinator — corpus generation, batch
//! order, LayerDrop masks, k-means++ seeding, per-step noise seeds — runs
//! off this generator so experiments replay bit-for-bit from a seed.

#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (cheap "split" for sub-tasks).
    pub fn split(&mut self, tag: u64) -> Pcg {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Pcg::with_stream(seed ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag)
    }

    /// Expose the raw generator position for checkpointing. Together
    /// with [`Pcg::from_parts`] this makes the stream resumable at an
    /// exact draw boundary — required for bit-identical train resume.
    pub fn state_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator at a position captured by [`Pcg::state_parts`].
    pub fn from_parts(state: u64, inc: u64) -> Pcg {
        Pcg { state, inc }
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (n as u64);
            let l = m as u32;
            if l >= n || l >= (n.wrapping_neg() % n) {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in n - k..n {
            let t = self.below(j as u32 + 1) as usize;
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Pcg::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(11);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg::new(5);
        let idx = r.sample_indices(100, 30);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg::new(1);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn parts_roundtrip_resumes_mid_stream() {
        let mut a = Pcg::new(42);
        for _ in 0..17 {
            a.next_u32();
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg::from_parts(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
