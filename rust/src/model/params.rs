//! Named parameter store: the coordinator's single source of truth for
//! model weights, saved/loaded in the QNP1 format that
//! `python/compile/aot.py` writes for the initial parameters.
//!
//! QNP1: magic `QNP1`, u32 LE header length, JSON header
//! `{"params": [{"name", "shape"}...]}`, then concatenated f32 LE data
//! in header order.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::config::ModelMeta;
use crate::model::tensor::Tensor;
use crate::util::fault;
use crate::util::json::Json;

/// Typed artifact-corruption error: what went wrong and the byte
/// offset where decoding stopped. Returned by the pure byte-level
/// loaders (`load_qnp1_bytes`, `checkpoint::decode`) so callers — the
/// CLI, serve upload handlers — can map corruption to a 4xx with
/// context instead of a panic or an opaque I/O error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoadError {
    pub offset: usize,
    pub what: String,
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "corrupt artifact at byte {}: {}", self.offset, self.what)
    }
}

impl std::error::Error for LoadError {}

fn corrupt(offset: usize, what: impl Into<String>) -> LoadError {
    LoadError { offset, what: what.into() }
}

#[derive(Debug, Clone)]
pub struct ParamStore {
    /// insertion order = manifest order = artifact input order
    order: Vec<String>,
    map: BTreeMap<String, Tensor>,
}

impl ParamStore {
    pub fn new() -> Self {
        ParamStore { order: Vec::new(), map: BTreeMap::new() }
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        if !self.map.contains_key(name) {
            self.order.push(name.to_string());
        }
        self.map.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.map.get(name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.map.get_mut(name)
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.order.iter().map(move |n| (n, &self.map[n]))
    }

    pub fn total_params(&self) -> usize {
        self.map.values().map(|t| t.numel()).sum()
    }

    /// Zero-filled clone (gradient/momentum accumulators).
    pub fn zeros_like(&self) -> ParamStore {
        let mut out = ParamStore::new();
        for (n, t) in self.iter() {
            out.insert(n, Tensor::zeros(&t.shape));
        }
        out
    }

    /// Verify names/shapes against the manifest (artifact compatibility).
    pub fn check_against(&self, meta: &ModelMeta) -> Result<()> {
        if self.len() != meta.params.len() {
            bail!("param count {} != manifest {}", self.len(), meta.params.len());
        }
        for (i, pm) in meta.params.iter().enumerate() {
            if self.order[i] != pm.name {
                bail!("param order mismatch at {i}: {} vs {}", self.order[i], pm.name);
            }
            let t = &self.map[&pm.name];
            if t.shape != pm.shape {
                bail!("shape mismatch for {}: {:?} vs {:?}", pm.name, t.shape, pm.shape);
            }
        }
        Ok(())
    }

    // ------------------------------------------------------ QNP1 I/O ---

    /// Decode QNP1 bytes with full bounds checking. Truncated or
    /// bit-flipped input returns a [`LoadError`] carrying the byte
    /// offset where decoding stopped — never a panic, never a
    /// partially-filled store.
    pub fn load_qnp1_bytes(bytes: &[u8]) -> std::result::Result<ParamStore, LoadError> {
        if bytes.len() < 8 {
            return Err(corrupt(bytes.len(), format!("file too short ({} bytes)", bytes.len())));
        }
        if &bytes[..4] != b"QNP1" {
            return Err(corrupt(0, format!("bad magic {:?}", &bytes[..4])));
        }
        let mut lb = [0u8; 4];
        lb.copy_from_slice(&bytes[4..8]);
        let hlen = u32::from_le_bytes(lb) as usize;
        let hend = 8usize
            .checked_add(hlen)
            .filter(|&e| e <= bytes.len())
            .ok_or_else(|| corrupt(4, format!("header length {hlen} exceeds file")))?;
        let htext = std::str::from_utf8(&bytes[8..hend])
            .map_err(|e| corrupt(8 + e.valid_up_to(), "header is not UTF-8"))?;
        let j = Json::parse(htext).map_err(|e| corrupt(8, format!("header JSON: {e}")))?;
        let plist = j
            .get("params")
            .as_arr()
            .ok_or_else(|| corrupt(8, "header: missing 'params' array"))?;
        let mut store = ParamStore::new();
        let mut off = hend;
        for (i, p) in plist.iter().enumerate() {
            let name = p
                .get("name")
                .as_str()
                .ok_or_else(|| corrupt(8, format!("header: param {i} missing 'name'")))?;
            let shape_j = p
                .get("shape")
                .as_arr()
                .ok_or_else(|| corrupt(8, format!("header: param '{name}' missing 'shape'")))?;
            let mut shape = Vec::with_capacity(shape_j.len());
            for d in shape_j {
                shape.push(d.as_usize().ok_or_else(|| {
                    corrupt(8, format!("header: param '{name}' has a non-integer dim"))
                })?);
            }
            if store.get(name).is_some() {
                return Err(corrupt(8, format!("header: duplicate param '{name}'")));
            }
            let numel: usize = shape.iter().product::<usize>().max(1);
            let need = numel
                .checked_mul(4)
                .ok_or_else(|| corrupt(8, format!("param '{name}': {numel} elements overflows")))?;
            let end = off.checked_add(need).filter(|&e| e <= bytes.len()).ok_or_else(|| {
                corrupt(
                    bytes.len(),
                    format!("truncated: param '{name}' needs {need} bytes at offset {off}"),
                )
            })?;
            let data: Vec<f32> = bytes[off..end]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            store.insert(name, Tensor::from_vec(&shape, data));
            off = end;
        }
        if off != bytes.len() {
            return Err(corrupt(off, format!("{} trailing payload bytes", bytes.len() - off)));
        }
        Ok(store)
    }

    pub fn load_qnp1(path: &Path) -> Result<ParamStore> {
        let bytes =
            std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
        fault::check("load.qnp1").with_context(|| format!("load {}", path.display()))?;
        Self::load_qnp1_bytes(&bytes)
            .map_err(|e| anyhow::Error::new(e).context(format!("load {}", path.display())))
    }

    /// Serialize to QNP1 bytes (in-memory; the wire form serve uploads
    /// consume).
    pub fn to_qnp1_bytes(&self) -> Vec<u8> {
        let params: Vec<Json> = self
            .iter()
            .map(|(n, t)| {
                Json::obj(vec![
                    ("name", Json::str(n.clone())),
                    (
                        "shape",
                        Json::Arr(t.shape.iter().map(|&d| Json::num(d as f64)).collect()),
                    ),
                ])
            })
            .collect();
        let header = Json::obj(vec![("params", Json::Arr(params))]).to_string();
        let mut out = Vec::new();
        out.extend_from_slice(b"QNP1");
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for (_, t) in self.iter() {
            for &x in &t.data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        out
    }

    /// Crash-atomic save: write a sibling temp file, fsync, rename. A
    /// crash mid-save can leave a stale `.tmp` but never a torn
    /// artifact under the final name.
    pub fn save_qnp1(&self, path: &Path) -> Result<()> {
        let bytes = self.to_qnp1_bytes();
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| "params".to_string());
        let tmp = path.with_file_name(format!("{name}.tmp"));
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("create {}", tmp.display()))?;
            f.write_all(&bytes)
                .with_context(|| format!("write {}", tmp.display()))?;
            f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
        Ok(())
    }
}

impl Default for ParamStore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::temp_dir;

    fn sample() -> ParamStore {
        let mut s = ParamStore::new();
        s.insert("a", Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        s.insert("b", Tensor::from_vec(&[4], vec![-1.0, 0.5, 0.0, 9.0]));
        s
    }

    #[test]
    fn insertion_order_preserved() {
        let mut s = ParamStore::new();
        s.insert("z", Tensor::zeros(&[1]));
        s.insert("a", Tensor::zeros(&[1]));
        assert_eq!(s.names(), &["z".to_string(), "a".to_string()]);
        // re-insert does not duplicate
        s.insert("z", Tensor::zeros(&[2]));
        assert_eq!(s.len(), 2);
        assert_eq!(s.get("z").unwrap().numel(), 2);
    }

    #[test]
    fn qnp1_roundtrip() {
        let dir = temp_dir("qnp1");
        let path = dir.join("p.bin");
        let s = sample();
        s.save_qnp1(&path).unwrap();
        let l = ParamStore::load_qnp1(&path).unwrap();
        assert_eq!(l.names(), s.names());
        for (n, t) in s.iter() {
            assert_eq!(l.get(n).unwrap(), t);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = temp_dir("qnp1bad");
        let path = dir.join("x.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(ParamStore::load_qnp1(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn every_truncation_is_a_typed_error() {
        let bytes = sample().to_qnp1_bytes();
        for cut in 0..bytes.len() {
            let e = ParamStore::load_qnp1_bytes(&bytes[..cut])
                .expect_err("truncated input accepted");
            assert!(e.offset <= cut, "offset {} past cut {cut}", e.offset);
        }
    }

    #[test]
    fn header_length_cannot_run_past_the_file() {
        let mut bytes = sample().to_qnp1_bytes();
        bytes[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let e = ParamStore::load_qnp1_bytes(&bytes).unwrap_err();
        assert_eq!(e.offset, 4);
        assert!(e.what.contains("header length"), "{e}");
    }

    #[test]
    fn duplicate_params_rejected() {
        let mut dup = ParamStore::new();
        dup.insert("a", Tensor::from_vec(&[1], vec![1.0]));
        let mut bytes = dup.to_qnp1_bytes();
        // hand-craft a header that lists "a" twice
        let header = r#"{"params":[{"name":"a","shape":[1]},{"name":"a","shape":[1]}]}"#;
        let mut forged = Vec::new();
        forged.extend_from_slice(b"QNP1");
        forged.extend_from_slice(&(header.len() as u32).to_le_bytes());
        forged.extend_from_slice(header.as_bytes());
        forged.extend_from_slice(&1.0f32.to_le_bytes());
        forged.extend_from_slice(&2.0f32.to_le_bytes());
        let e = ParamStore::load_qnp1_bytes(&forged).unwrap_err();
        assert!(e.what.contains("duplicate"), "{e}");
        // and junk shapes are a strict error, not silently skipped
        bytes.clear();
        let header = r#"{"params":[{"name":"a","shape":[1,"x"]}]}"#;
        bytes.extend_from_slice(b"QNP1");
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        let e = ParamStore::load_qnp1_bytes(&bytes).unwrap_err();
        assert!(e.what.contains("non-integer"), "{e}");
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut bytes = sample().to_qnp1_bytes();
        let clean_len = bytes.len();
        bytes.extend_from_slice(&[0u8; 3]);
        let e = ParamStore::load_qnp1_bytes(&bytes).unwrap_err();
        assert_eq!(e.offset, clean_len);
        assert!(e.what.contains("trailing"), "{e}");
    }

    #[test]
    fn save_is_atomic_no_tmp_left_behind() {
        let dir = temp_dir("qnp1atomic");
        let path = dir.join("p.bin");
        sample().save_qnp1(&path).unwrap();
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["p.bin".to_string()]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let s = sample();
        let z = s.zeros_like();
        assert_eq!(z.names(), s.names());
        assert!(z.get("a").unwrap().data.iter().all(|&x| x == 0.0));
        assert_eq!(z.total_params(), s.total_params());
    }
}
