//! Named parameter store: the coordinator's single source of truth for
//! model weights, saved/loaded in the QNP1 format that
//! `python/compile/aot.py` writes for the initial parameters.
//!
//! QNP1: magic `QNP1`, u32 LE header length, JSON header
//! `{"params": [{"name", "shape"}...]}`, then concatenated f32 LE data
//! in header order.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::config::ModelMeta;
use crate::model::tensor::Tensor;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ParamStore {
    /// insertion order = manifest order = artifact input order
    order: Vec<String>,
    map: BTreeMap<String, Tensor>,
}

impl ParamStore {
    pub fn new() -> Self {
        ParamStore { order: Vec::new(), map: BTreeMap::new() }
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        if !self.map.contains_key(name) {
            self.order.push(name.to_string());
        }
        self.map.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.map.get(name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.map.get_mut(name)
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.order.iter().map(move |n| (n, &self.map[n]))
    }

    pub fn total_params(&self) -> usize {
        self.map.values().map(|t| t.numel()).sum()
    }

    /// Zero-filled clone (gradient/momentum accumulators).
    pub fn zeros_like(&self) -> ParamStore {
        let mut out = ParamStore::new();
        for (n, t) in self.iter() {
            out.insert(n, Tensor::zeros(&t.shape));
        }
        out
    }

    /// Verify names/shapes against the manifest (artifact compatibility).
    pub fn check_against(&self, meta: &ModelMeta) -> Result<()> {
        if self.len() != meta.params.len() {
            bail!("param count {} != manifest {}", self.len(), meta.params.len());
        }
        for (i, pm) in meta.params.iter().enumerate() {
            if self.order[i] != pm.name {
                bail!("param order mismatch at {i}: {} vs {}", self.order[i], pm.name);
            }
            let t = &self.map[&pm.name];
            if t.shape != pm.shape {
                bail!("shape mismatch for {}: {:?} vs {:?}", pm.name, t.shape, pm.shape);
            }
        }
        Ok(())
    }

    // ------------------------------------------------------ QNP1 I/O ---

    pub fn load_qnp1(path: &Path) -> Result<ParamStore> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"QNP1" {
            bail!("{}: bad magic {:?}", path.display(), magic);
        }
        let mut len_buf = [0u8; 4];
        f.read_exact(&mut len_buf)?;
        let hlen = u32::from_le_bytes(len_buf) as usize;
        let mut header = vec![0u8; hlen];
        f.read_exact(&mut header)?;
        let j = Json::parse(std::str::from_utf8(&header)?)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let mut store = ParamStore::new();
        for p in j.get("params").as_arr().context("missing params")? {
            let name = p.get("name").as_str().context("missing name")?;
            let shape: Vec<usize> = p
                .get("shape")
                .as_arr()
                .context("missing shape")?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect();
            let numel: usize = shape.iter().product::<usize>().max(1);
            let mut raw = vec![0u8; numel * 4];
            f.read_exact(&mut raw)
                .with_context(|| format!("reading {name} ({numel} f32)"))?;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            store.insert(name, Tensor::from_vec(&shape, data));
        }
        Ok(store)
    }

    pub fn save_qnp1(&self, path: &Path) -> Result<()> {
        let params: Vec<Json> = self
            .iter()
            .map(|(n, t)| {
                Json::obj(vec![
                    ("name", Json::str(n.clone())),
                    (
                        "shape",
                        Json::Arr(t.shape.iter().map(|&d| Json::num(d as f64)).collect()),
                    ),
                ])
            })
            .collect();
        let header = Json::obj(vec![("params", Json::Arr(params))]).to_string();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(b"QNP1")?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, t) in self.iter() {
            let mut raw = Vec::with_capacity(t.data.len() * 4);
            for &x in &t.data {
                raw.extend_from_slice(&x.to_le_bytes());
            }
            f.write_all(&raw)?;
        }
        Ok(())
    }
}

impl Default for ParamStore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::temp_dir;

    fn sample() -> ParamStore {
        let mut s = ParamStore::new();
        s.insert("a", Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        s.insert("b", Tensor::from_vec(&[4], vec![-1.0, 0.5, 0.0, 9.0]));
        s
    }

    #[test]
    fn insertion_order_preserved() {
        let mut s = ParamStore::new();
        s.insert("z", Tensor::zeros(&[1]));
        s.insert("a", Tensor::zeros(&[1]));
        assert_eq!(s.names(), &["z".to_string(), "a".to_string()]);
        // re-insert does not duplicate
        s.insert("z", Tensor::zeros(&[2]));
        assert_eq!(s.len(), 2);
        assert_eq!(s.get("z").unwrap().numel(), 2);
    }

    #[test]
    fn qnp1_roundtrip() {
        let dir = temp_dir("qnp1");
        let path = dir.join("p.bin");
        let s = sample();
        s.save_qnp1(&path).unwrap();
        let l = ParamStore::load_qnp1(&path).unwrap();
        assert_eq!(l.names(), s.names());
        for (n, t) in s.iter() {
            assert_eq!(l.get(n).unwrap(), t);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = temp_dir("qnp1bad");
        let path = dir.join("x.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(ParamStore::load_qnp1(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let s = sample();
        let z = s.zeros_like();
        assert_eq!(z.names(), s.names());
        assert!(z.get("a").unwrap().data.iter().all(|&x| x == 0.0));
        assert_eq!(z.total_params(), s.total_params());
    }
}
