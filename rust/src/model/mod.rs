//! Host-side model state: tensors, manifest-mirroring metadata, and the
//! named parameter store (QNP1 I/O shared with the AOT exporter).
pub mod config;
pub mod params;
pub mod tensor;
