//! Model metadata shared between the AOT manifest and the coordinator.
//! These structs mirror what `python/compile/aot.py` writes into
//! `artifacts/manifest.json`; the runtime parses JSON into them.

use crate::quant::size::ParamInfo;
use crate::util::json::Json;

/// One parameter's manifest record.
#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    /// structure group: emb / attn / ffn / cls / norm / conv1x1 / dw3x3 / stem
    pub structure: String,
    /// participates in Quant-Noise / quantization
    pub noised: bool,
    /// canonical 2-D view (rows, cols) — present iff noised
    pub view: Option<(usize, usize)>,
    /// noise/PQ block size — present iff noised
    pub block_size: Option<usize>,
}

impl ParamMeta {
    pub fn from_json(j: &Json) -> Option<ParamMeta> {
        let shape = j
            .get("shape")
            .as_arr()?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        let view = if j.get("view").is_null() {
            None
        } else {
            let a = j.get("view").as_arr()?;
            Some((a[0].as_usize()?, a[1].as_usize()?))
        };
        Some(ParamMeta {
            name: j.get("name").as_str()?.to_string(),
            shape,
            structure: j.get("structure").as_str().unwrap_or("?").to_string(),
            noised: j.get("noised").as_bool().unwrap_or(false),
            view,
            block_size: j.get("block_size").as_usize(),
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// Convert to the size-accounting record (optionally overriding the
    /// PQ block size, e.g. for Fig. 6's per-structure block sweeps).
    pub fn to_param_info(&self, pq_block_override: Option<usize>) -> ParamInfo {
        let (rows, cols) = self.view.unwrap_or((1, self.numel()));
        ParamInfo {
            name: self.name.clone(),
            structure: self.structure.clone(),
            numel: self.numel(),
            rows,
            cols,
            quantized: self.noised,
            pq_block: pq_block_override.or(self.block_size).unwrap_or(8),
        }
    }
}

/// One entry point (grad/eval) record.
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// One exported model.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub task: String, // lm | cls | img
    pub n_layers: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub tokens_shape: Vec<usize>,
    pub targets_shape: Vec<usize>,
    pub vocab: usize,
    pub n_classes: usize,
    pub params: Vec<ParamMeta>,
    pub entries: Vec<EntryMeta>,
    pub init_file: String,
}

impl ModelMeta {
    pub fn from_json(name: &str, j: &Json) -> Option<ModelMeta> {
        let params = j
            .get("params")
            .as_arr()?
            .iter()
            .filter_map(ParamMeta::from_json)
            .collect::<Vec<_>>();
        let mut entries = Vec::new();
        if let Some(obj) = j.get("entries").as_obj() {
            for (ename, e) in obj {
                entries.push(EntryMeta {
                    name: ename.clone(),
                    file: e.get("file").as_str()?.to_string(),
                    inputs: e
                        .get("inputs")
                        .as_arr()?
                        .iter()
                        .filter_map(|v| v.as_str().map(String::from))
                        .collect(),
                    outputs: e
                        .get("outputs")
                        .as_arr()?
                        .iter()
                        .filter_map(|v| v.as_str().map(String::from))
                        .collect(),
                });
            }
        }
        let usv = |key: &str| -> Vec<usize> {
            j.get(key)
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                .unwrap_or_default()
        };
        Some(ModelMeta {
            name: name.to_string(),
            task: j.get("task").as_str()?.to_string(),
            n_layers: j.get("n_layers").as_usize()?,
            batch: j.get("batch").as_usize()?,
            seq_len: j.get("seq_len").as_usize().unwrap_or(0),
            tokens_shape: usv("tokens_shape"),
            targets_shape: usv("targets_shape"),
            vocab: j.get("vocab").as_usize().unwrap_or(0),
            n_classes: j.get("n_classes").as_usize().unwrap_or(0),
            params,
            entries,
            init_file: j.get("init").as_str().unwrap_or("").to_string(),
        })
    }

    pub fn entry(&self, name: &str) -> Option<&EntryMeta> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn param(&self, name: &str) -> Option<&ParamMeta> {
        self.params.iter().find(|p| p.name == name)
    }

    /// Parameters of one structure group, in manifest order.
    pub fn params_of(&self, structure: &str) -> Vec<&ParamMeta> {
        self.params.iter().filter(|p| p.structure == structure).collect()
    }

    /// Size-accounting inventory (manifest order).
    pub fn param_infos(&self) -> Vec<ParamInfo> {
        self.params.iter().map(|p| p.to_param_info(None)).collect()
    }

    /// Param names belonging to layer `l` (Transformer "layerNN." /
    /// ConvNet "blockNN." prefixes).
    pub fn layer_params(&self, l: usize) -> Vec<&ParamMeta> {
        let p1 = format!("layer{l:02}.");
        let p2 = format!("block{l:02}.");
        self.params
            .iter()
            .filter(|p| p.name.starts_with(&p1) || p.name.starts_with(&p2))
            .collect()
    }

    /// Tokens per eval batch (LM) or examples per batch (cls/img) —
    /// the denominator for PPL / accuracy.
    pub fn eval_denominator(&self) -> usize {
        if self.task == "lm" {
            self.batch * self.seq_len
        } else {
            self.batch
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{
            "task": "lm", "n_layers": 2, "batch": 4, "seq_len": 8,
            "tokens_shape": [4, 8], "targets_shape": [4, 8],
            "vocab": 100, "n_classes": 0, "init": "m.init.bin",
            "params": [
              {"name": "embed", "shape": [100, 16], "structure": "emb",
               "noised": true, "view": [100, 16], "block_size": 8},
              {"name": "layer00.wq", "shape": [16, 16], "structure": "attn",
               "noised": true, "view": [16, 16], "block_size": 8},
              {"name": "lnf_g", "shape": [16], "structure": "norm",
               "noised": false, "view": null, "block_size": null}
            ],
            "entries": {
              "eval": {"file": "m.eval.hlo.txt",
                       "inputs": ["param:embed", "param:layer00.wq", "param:lnf_g",
                                  "tokens", "targets", "layer_keep"],
                       "outputs": ["sum_nll", "sum_correct"]}
            }}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_model_meta() {
        let m = ModelMeta::from_json("m", &sample_json()).unwrap();
        assert_eq!(m.task, "lm");
        assert_eq!(m.params.len(), 3);
        assert_eq!(m.param("embed").unwrap().view, Some((100, 16)));
        assert!(!m.param("lnf_g").unwrap().noised);
        assert_eq!(m.entry("eval").unwrap().inputs.len(), 6);
        assert_eq!(m.eval_denominator(), 32);
    }

    #[test]
    fn layer_params_by_prefix() {
        let m = ModelMeta::from_json("m", &sample_json()).unwrap();
        let l0 = m.layer_params(0);
        assert_eq!(l0.len(), 1);
        assert_eq!(l0[0].name, "layer00.wq");
        assert!(m.layer_params(1).is_empty());
    }

    #[test]
    fn param_infos_reflect_quantized_flag() {
        let m = ModelMeta::from_json("m", &sample_json()).unwrap();
        let infos = m.param_infos();
        assert!(infos[0].quantized && !infos[2].quantized);
        assert_eq!(infos[0].pq_block, 8);
        assert_eq!(infos[0].structure, "emb");
        assert_eq!(infos[1].structure, "attn");
    }
}
