//! Host-side dense f32 tensor — the coordinator's working currency.
//! Deliberately small: the heavy math lives in the AOT-compiled HLO;
//! the host only needs shape bookkeeping plus the vector ops the
//! optimizer, PQ pipeline and size accounting use.

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Canonical 2-D view dims: (rows, cols). 1-D/0-D → (1, numel).
    pub fn view2d(&self) -> (usize, usize) {
        match self.shape.len() {
            0 | 1 => (1, self.numel()),
            2 => (self.shape[0], self.shape[1]),
            _ => {
                // trailing dims folded into cols; callers that need a
                // different fold (convs) use the manifest's view field
                let rows = self.shape[0];
                (rows, self.numel() / rows)
            }
        }
    }

    // ------------------------------------------------ vector ops ---

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.numel(), other.numel());
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        self.data.iter_mut().for_each(|x| *x *= alpha);
    }

    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.numel(), other.numel());
        if self.data.is_empty() {
            return 0.0;
        }
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / self.numel() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_views() {
        let t = Tensor::zeros(&[3, 4]);
        assert_eq!(t.numel(), 12);
        assert_eq!(t.view2d(), (3, 4));
        assert_eq!(Tensor::zeros(&[5]).view2d(), (1, 5));
        assert_eq!(Tensor::scalar(2.0).view2d(), (1, 1));
        assert_eq!(Tensor::zeros(&[2, 3, 4]).view2d(), (2, 12));
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_checks_len() {
        Tensor::from_vec(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn axpy_and_norms() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0]);
        a.axpy(-1.0, &b);
        assert_eq!(a.data, vec![0.0, 1.0, 2.0]);
        assert_eq!(a.sq_norm(), 5.0);
        assert_eq!(a.max_abs(), 2.0);
        a.scale(2.0);
        assert_eq!(a.data, vec![0.0, 2.0, 4.0]);
    }

    #[test]
    fn mse_basic() {
        let a = Tensor::from_vec(&[2], vec![0.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![0.0, 0.0]);
        assert_eq!(a.mse(&b), 2.0);
    }
}
