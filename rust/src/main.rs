//! `qn` — Quant-Noise coordinator CLI.
//!
//! Subcommands:
//!   info                       manifest / artifact summary
//!   train                      one Quant-Noise training run
//!   quantize                   post-training quantization of saved params
//!   eval                       evaluate saved params (fp32 or quantized)
//!   e2e                        end-to-end driver (train → iPQ → report)
//!   serve                      batching inference + online-quantization HTTP service
//!   bench --exp `<id>`         regenerate a paper table/figure
//!   lint-plan `<hlo.txt>`...   statically verify compiled plans + census
//!
//! Python never runs here: all compute flows through the AOT artifacts
//! in artifacts/ (build them with `make artifacts`).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use quant_noise::bench_harness::common::{Row, Workbench};
use quant_noise::bench_harness::specs::{base_train, default_rate, default_steps, with_noise};
use quant_noise::bench_harness::{figures, report, tables};
use quant_noise::coordinator::checkpoint;
use quant_noise::coordinator::ipq::{run_ipq, IpqConfig};
use quant_noise::coordinator::quantize::quantize_params;
use quant_noise::coordinator::trainer::Trainer;
use quant_noise::model::params::ParamStore;
use quant_noise::quant::scheme::{IntObserver, PqSpec, QuantSpec, SchemeError};
use quant_noise::util::cli::Command;
use quant_noise::util::logging;
use quant_noise::util::rng::Pcg;
use quant_noise::{log_error, log_info};

/// Parse a `--scheme` spec string into a user-facing error on failure
/// (no panics, no backtraces — just the parser's message).
fn parse_scheme(s: &str) -> Result<QuantSpec> {
    s.parse().map_err(|e: SchemeError| anyhow::anyhow!("--scheme: {e}"))
}

fn artifacts_dir(args: &quant_noise::util::cli::Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    let code = match run(sub, rest) {
        Ok(()) => 0,
        Err(e) => {
            log_error!("{e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(sub: &str, rest: &[String]) -> Result<()> {
    match sub {
        "info" => info(rest),
        "train" => train(rest),
        "quantize" => quantize(rest),
        "eval" => eval(rest),
        "e2e" => e2e(rest),
        "serve" => serve(rest),
        "bench" => bench(rest),
        "lint-plan" => lint_plan(rest),
        _ => {
            println!(
                "qn — Quant-Noise (ICLR 2021) coordinator\n\n\
                 subcommands: info, train, quantize, eval, e2e, serve, bench, lint-plan\n\
                 run `qn <sub> --help` for options"
            );
            Ok(())
        }
    }
}

fn parse(cmd: Command, rest: &[String]) -> Result<quant_noise::util::cli::Args> {
    cmd.parse(rest).map_err(|msg| anyhow::anyhow!("{msg}"))
}

// ------------------------------------------------------------- info ---

fn info(rest: &[String]) -> Result<()> {
    let cmd = Command::new("info", "artifact / manifest summary")
        .opt_default("artifacts", "artifacts", "artifact directory");
    let args = parse(cmd, rest)?;
    let man = quant_noise::runtime::manifest::Manifest::load(&artifacts_dir(&args))?;
    for (name, m) in &man.models {
        let n_params: usize = m.params.iter().map(|p| p.numel()).sum();
        println!(
            "{name}: task={} layers={} batch={} seq={} vocab={} classes={} params={} \
             ({:.2} MB fp32)",
            m.task, m.n_layers, m.batch, m.seq_len, m.vocab, m.n_classes,
            n_params, n_params as f64 * 4.0 / 1e6
        );
        for e in &m.entries {
            println!(
                "  entry {:<18} {} inputs, {} outputs [{}]",
                e.name,
                e.inputs.len(),
                e.outputs.len(),
                e.file
            );
        }
    }
    Ok(())
}

// ------------------------------------------------------------ train ---

fn train(rest: &[String]) -> Result<()> {
    let cmd = Command::new("train", "train a model with Quant-Noise")
        .opt_default("artifacts", "artifacts", "artifact directory")
        .opt_default("model", "lm_tiny", "model name from the manifest")
        .opt_default(
            "scheme",
            "proxy",
            "noise scheme spec: none|proxy|mean_sub|exact_pq|pq:k=..|int8[:per_channel]|int4",
        )
        .alias("noise")
        .opt("rate", "noise rate p (default: per-scheme paper value)")
        .opt("steps", "training steps (default: per-task)")
        .opt_default("layerdrop", "0", "LayerDrop probability")
        .opt_default("share", "0", "weight-sharing chunk (0=off)")
        .opt_default("threads", "0", "hat-refresh / PQ worker threads (0=all cores)")
        .opt("save", "path to save trained params (QNP1)")
        .opt("checkpoint", "directory for periodic QNC1 checkpoints (crash-safe)")
        .opt_default("checkpoint-every", "25", "steps between checkpoints (0 = final only)")
        .opt("resume", "resume from the latest checkpoint in this directory")
        .opt("cache", "trained-parameter cache directory (default: <artifacts>/cache)")
        .flag("ldste", "STE through LayerDrop (Table 11 ablation)");
    let args = parse(cmd, rest)?;

    let artifacts = artifacts_dir(&args);
    let wb = match args.get("cache") {
        Some(c) => Workbench::at(&artifacts, Path::new(c))?,
        None => Workbench::new(&artifacts)?,
    };
    let model = args.get_or("model", "lm_tiny").to_string();
    let mut lab = wb.lab(&model)?;
    let task = lab.sess.meta.task.clone();
    let noise = parse_scheme(args.get_or("scheme", "proxy"))?;
    // fail fast on PTQ-only specs (e.g. int8:histogram has no in-graph
    // grad kernel) instead of erroring at the first training step
    noise.grad_entry().map_err(|e| anyhow::anyhow!("--scheme: {e}"))?;
    let steps = args.num_or("steps", default_steps(&task));
    let rate = args.num_or("rate", default_rate(&noise));
    let mut cfg = with_noise(base_train(&task, steps), noise, rate);
    cfg.layerdrop = args.num_or("layerdrop", 0.0);
    cfg.share_chunk = args.num_or("share", 0usize);
    cfg.threads = args.num_or("threads", 0usize);
    cfg.ldste = args.flag("ldste");

    let ckpt_dir = args.get("checkpoint").map(String::from);
    let resume_dir = args.get("resume").map(String::from);
    let params = if ckpt_dir.is_some() || resume_dir.is_some() {
        // checkpointing needs the live Trainer (the train cache stores
        // only final weights), so drive the loop directly
        lab.sess.upload_all_params(&lab.init)?;
        lab.sess.zero_hats()?;
        let mut trainer = Trainer::new(&mut lab.sess, lab.init.clone(), cfg.clone());
        if let Some(dir) = &resume_dir {
            match checkpoint::load_latest(Path::new(dir))? {
                Some(ck) => trainer.resume_from(ck)?,
                None => log_info!("--resume: no checkpoint in {dir}; starting from step 0"),
            }
        }
        // resuming without --checkpoint keeps checkpointing to the
        // resume directory, so repeated crashes keep making progress
        if let Some(dir) = ckpt_dir.as_deref().or(resume_dir.as_deref()) {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating checkpoint dir {dir}"))?;
            trainer.set_checkpoint(dir, args.num_or("checkpoint-every", 25usize));
        }
        trainer.train(lab.train_src.as_mut())?;
        let params = trainer.into_params();
        lab.sess.zero_hats()?;
        params
    } else {
        lab.train_cached(&cfg)?
    };
    let keep = lab.keep_all();
    let ev = lab.eval_params(&params, "eval", &keep)?;
    log_info!(
        "final eval: nll {:.4} ppl {:.2} acc {:.2}%",
        ev.nll, ev.ppl, ev.accuracy * 100.0
    );
    if let Some(path) = args.get("save") {
        params.save_qnp1(Path::new(path))?;
        log_info!("saved params to {path}");
    }
    Ok(())
}

// --------------------------------------------------------- quantize ---

fn quantize(rest: &[String]) -> Result<()> {
    let cmd = Command::new("quantize", "quantize saved params and report size/quality")
        .opt_default("artifacts", "artifacts", "artifact directory")
        .opt_default("model", "lm_tiny", "model name")
        .req("params", "QNP1 file of trained params")
        .opt_default(
            "scheme",
            "ipq",
            "ipq[:k=..,..]|pq|int8|int4 shorthands, or any spec string",
        )
        .opt_default("mode", "histogram", "intN observer: histogram|minmax|channel")
        .opt_default("k", "64", "PQ centroids")
        .opt_default("threads", "0", "PQ/k-means worker threads (0=all cores)")
        .flag("int8-centroids", "compress PQ centroids to int8 (§3.3)")
        .opt("save", "path to save quantized (dequantized) params");
    let args = parse(cmd, rest)?;

    let wb = Workbench::new(&artifacts_dir(&args))?;
    // --threads governs the backend too (batched eval after quantizing)
    wb.rt.set_threads(args.num_or("threads", 0usize));
    let model = args.get_or("model", "lm_tiny").to_string();
    let mut lab = wb.lab(&model)?;
    let params = ParamStore::load_qnp1(Path::new(args.get("params").unwrap()))?;
    params.check_against(&lab.sess.meta)?;

    let k: usize = args.num_or("k", 64);
    let scheme = args.get_or("scheme", "ipq").to_string();
    let (store, bytes, int8_cb) = if scheme == "ipq" || scheme.starts_with("ipq:") {
        // iPQ is a finetuning *procedure*, not just a storage scheme —
        // its options reuse the pq spec grammar (`ipq:k=128,cb=int8`)
        let mut cfg = IpqConfig { k, ..Default::default() };
        cfg.centroid_bits = args.flag("int8-centroids").then_some(8);
        cfg.threads = args.num_or("threads", 0usize);
        cfg.finetune_steps = 25;
        if let Some(opts) = scheme.strip_prefix("ipq:") {
            // apply only the keys the user actually typed: PqSpec's
            // defaults (K=256, iters=12) are not the iPQ CLI defaults
            let explicit: Vec<&str> = opts
                .split(',')
                .filter_map(|kv| kv.split_once('=').map(|(key, _)| key))
                .collect();
            let parsed = QuantSpec::parse(&format!("pq:{opts}")).map_err(|e| {
                let reason = match e {
                    SchemeError::Parse { reason, .. } => reason,
                    other => other.to_string(),
                };
                anyhow::anyhow!("--scheme {scheme}: {reason}")
            })?;
            if let QuantSpec::Pq(p) = parsed {
                if explicit.contains(&"k") {
                    cfg.k = p.k;
                }
                if explicit.contains(&"iters") {
                    cfg.kmeans_iters = p.kmeans_iters;
                }
                if explicit.contains(&"cb") {
                    // an explicitly typed cb= wins over --int8-centroids
                    cfg.centroid_bits = p.codebook_bits;
                }
                cfg.block = p.block;
                cfg.block_override = p.block_override;
                if explicit.contains(&"threads") {
                    cfg.threads = p.threads;
                }
            }
        }
        let int8_cb = cfg.centroid_bits == Some(8);
        lab.sess.upload_all_params(&params)?;
        let (q, _) = run_ipq(&mut lab.sess, &params, lab.train_src.as_mut(), &cfg)?;
        (q.store, q.bytes, int8_cb)
    } else {
        // one-shot PTQ: legacy shorthands keep their flag-driven
        // defaults; anything else is a full spec string
        let spec = match scheme.as_str() {
            "int8" | "int4" => {
                let bits = if scheme == "int8" { 8 } else { 4 };
                let observer = match args.get_or("mode", "histogram") {
                    "minmax" => IntObserver::MinMax,
                    "channel" => IntObserver::PerChannel,
                    "histogram" => IntObserver::Histogram,
                    other => anyhow::bail!(
                        "--mode: unknown observer '{other}' (histogram|minmax|channel)"
                    ),
                };
                QuantSpec::int(bits, observer)
            }
            "pq" => {
                let mut p = PqSpec::new(k);
                p.codebook_bits = args.flag("int8-centroids").then_some(8);
                p.threads = args.num_or("threads", 0usize);
                QuantSpec::Pq(p)
            }
            other => {
                // full spec strings carry their own options (--k/--mode
                // apply to the shorthands only), but --int8-centroids and
                // --threads compose rather than being silently dropped —
                // with explicitly typed spec keys winning over flags,
                // matching the ipq: precedence rule above
                let mut spec = parse_scheme(other)?;
                let explicit_cb = other
                    .split_once(':')
                    .map(|(_, opts)| {
                        opts.split(',')
                            .filter_map(|kv| kv.split_once('='))
                            .any(|(key, _)| key == "cb")
                    })
                    .unwrap_or(false);
                if args.flag("int8-centroids") && !explicit_cb {
                    if let QuantSpec::Pq(p) = &mut spec {
                        p.codebook_bits = Some(8);
                    }
                }
                let threads = args.num_or("threads", 0usize);
                if threads != 0 {
                    spec = spec.with_threads(threads);
                }
                spec
            }
        };
        let int8_cb = matches!(&spec, QuantSpec::Pq(p) if p.codebook_bits == Some(8));
        let q = quantize_params(&params, &lab.sess.meta, &spec, &mut Pcg::new(5))?;
        (q.store, q.bytes, int8_cb)
    };

    let keep = lab.keep_all();
    // §3.3 evaluation entry follows the scheme actually applied (an
    // int8 codebook requested via `cb=int8` counts, not just the flag)
    let entry = if int8_cb && lab.sess.has_entry("eval_int8act") {
        "eval_int8act"
    } else {
        "eval"
    };
    let fp = quant_noise::coordinator::quantize::scheme_bytes(&lab.sess.meta, &QuantSpec::None);
    let ev = lab.eval_params(&store, entry, &keep)?;
    println!(
        "scheme={scheme} size={:.3}MB compression=×{:.1} nll={:.4} ppl={:.2} acc={:.2}%",
        bytes as f64 / 1e6,
        fp as f64 / bytes as f64,
        ev.nll, ev.ppl, ev.accuracy * 100.0
    );
    if let Some(path) = args.get("save") {
        store.save_qnp1(Path::new(path))?;
    }
    Ok(())
}

// ------------------------------------------------------------- eval ---

fn eval(rest: &[String]) -> Result<()> {
    let cmd = Command::new("eval", "evaluate saved params")
        .opt_default("artifacts", "artifacts", "artifact directory")
        .opt_default("model", "lm_tiny", "model name")
        .req("params", "QNP1 file")
        .opt_default("entry", "eval", "eval|eval_int8act")
        .opt_default("threads", "0", "backend worker threads (0=all cores)")
        .flag("prune", "evaluate with every-other-chunk pruning");
    let args = parse(cmd, rest)?;
    let wb = Workbench::new(&artifacts_dir(&args))?;
    // eval batches shard across backend workers (bit-identical results)
    wb.rt.set_threads(args.num_or("threads", 0usize));
    let mut lab = wb.lab(args.get_or("model", "lm_tiny"))?;
    let params = ParamStore::load_qnp1(Path::new(args.get("params").unwrap()))?;
    let keep = if args.flag("prune") {
        quant_noise::quant::prune::every_other_chunk_mask(lab.sess.meta.n_layers, 2)
    } else {
        lab.keep_all()
    };
    let ev = lab.eval_params(&params, args.get_or("entry", "eval"), &keep)?;
    println!("nll={:.4} ppl={:.2} acc={:.2}% (n={})", ev.nll, ev.ppl, ev.accuracy * 100.0, ev.n);
    Ok(())
}

// -------------------------------------------------------------- e2e ---

fn e2e(rest: &[String]) -> Result<()> {
    let cmd = Command::new("e2e", "end-to-end driver: train with QN, iPQ-quantize, report")
        .opt_default("artifacts", "artifacts", "artifact directory")
        .opt_default("model", "lm_tiny", "model name")
        .opt("steps", "training steps")
        .opt_default("scale", "1.0", "step scale (quick runs: 0.1)");
    let args = parse(cmd, rest)?;
    let mut wb = Workbench::new(&artifacts_dir(&args))?;
    wb.step_scale = args.num_or("scale", 1.0);
    let model = args.get_or("model", "lm_tiny").to_string();
    quant_noise::bench_harness::e2e::run(&wb, &model, args.parse_num("steps"))
}

// ------------------------------------------------------------ serve ---

/// Raised by the SIGINT/SIGTERM handler; `serve::run_until` polls it
/// and drains the server gracefully when it flips.
static SERVE_STOP: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn serve_stop_handler(_signum: i32) {
    // Only async-signal-safe work here: a single atomic store.
    SERVE_STOP.store(true, std::sync::atomic::Ordering::Relaxed);
}

/// Install `serve_stop_handler` for SIGINT (2) and SIGTERM (15) via the
/// libc `signal(2)` entry point; no libc crate, so declare it directly.
/// Kept in the binary: the library forbids unsafe code.
fn install_serve_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    // SAFETY: the handler only stores to a static atomic, which is
    // async-signal-safe; `signal` is the standard C entry point.
    unsafe {
        signal(SIGINT, serve_stop_handler);
        signal(SIGTERM, serve_stop_handler);
    }
}

fn serve(rest: &[String]) -> Result<()> {
    let cmd = Command::new(
        "serve",
        "HTTP service: coalesced batched eval, PTQ-on-upload, online re-encode",
    )
    .opt_default("artifacts", "artifacts", "artifact directory")
    .opt_default("addr", "127.0.0.1:7171", "listen address (port 0 = OS-assigned)")
    .opt_default("threads", "0", "interpreter worker threads (0=all cores)")
    .opt_default("max-batch", "8", "macro-batch size cap for coalesced evals")
    .opt_default("max-queue", "64", "admission queue bound (beyond it: 429)")
    .opt_default("max-per-model", "0", "per-model admission quota (0 = disabled)")
    .opt_default("http-threads", "8", "HTTP worker threads (one live connection each)")
    .opt_default("linger-ms", "2", "how long a ready batch waits for stragglers")
    .opt_default("io-timeout-ms", "5000", "whole-request read/write deadline (slowloris guard)")
    .opt_default(
        "drain-timeout-ms",
        "30000",
        "max time shutdown waits for the batcher to drain before abandoning it",
    )
    .opt_default("max-conn-requests", "1000", "keep-alive requests served per connection")
    .flag("selfcheck", "re-run every coalesced shard solo and assert bit-identity");
    let args = parse(cmd, rest)?;
    let cfg = quant_noise::serve::ServeConfig {
        addr: args.get_or("addr", "127.0.0.1:7171").to_string(),
        threads: args.num_or("threads", 0usize),
        max_batch: args.num_or("max-batch", 8usize),
        max_queue: args.num_or("max-queue", 64usize),
        max_per_model: args.num_or("max-per-model", 0usize),
        http_threads: args.num_or("http-threads", 8usize),
        linger: std::time::Duration::from_millis(args.num_or("linger-ms", 2u64)),
        io_timeout: std::time::Duration::from_millis(args.num_or("io-timeout-ms", 5000u64)),
        drain_timeout: std::time::Duration::from_millis(
            args.num_or("drain-timeout-ms", 30_000u64),
        ),
        max_conn_requests: args.num_or("max-conn-requests", 1000usize),
        backend: None, // QN_BACKEND decides, same as every other subcommand
        selfcheck: args.flag("selfcheck"),
    };
    install_serve_signal_handlers();
    quant_noise::serve::run_until(&artifacts_dir(&args), cfg, &SERVE_STOP)
}

// -------------------------------------------------------- lint-plan ---

fn lint_plan(rest: &[String]) -> Result<()> {
    let cmd = Command::new(
        "lint-plan",
        "statically verify the compiled plan of each HLO file (at every \
         fusion setting) and print a plan census; non-zero exit on any \
         diagnostic",
    )
    .flag("quiet", "suppress the census, print diagnostics only");
    let args = parse(cmd, rest)?;
    anyhow::ensure!(
        !args.positionals.is_empty(),
        "usage: qn lint-plan [--quiet] <hlo.txt> [<hlo.txt> ...]"
    );
    use quant_noise::runtime::interp::{verify, HloModule, Plan, PlanOptions};
    let mut total = 0usize;
    for path in &args.positionals {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let module =
            HloModule::parse_str(&text).with_context(|| format!("parsing {path}"))?;
        println!("== {path}");
        // verify at every fusion setting: the nofuse plans execute too
        // (benches, regression tests), so they must be just as sound
        for bits in 0u8..8 {
            let (cl, tf, ch) = (bits & 1 != 0, bits & 2 != 0, bits & 4 != 0);
            let opts = PlanOptions { counted_loops: cl, threefry: tf, chains: ch };
            let plan = Plan::compile_unverified(&module, opts);
            let diags = verify::verify(&plan);
            for d in &diags {
                println!("  [counted_loops={cl} threefry={tf} chains={ch}] {d}");
            }
            total += diags.len();
        }
        if !args.flag("quiet") {
            let plan = Plan::compile_unverified(&module, PlanOptions::default());
            print!("{}", verify::census(&plan));
        }
    }
    anyhow::ensure!(total == 0, "{total} plan diagnostic(s)");
    println!("{} file(s) verified clean", args.positionals.len());
    Ok(())
}

// ------------------------------------------------------------ bench ---

fn bench(rest: &[String]) -> Result<()> {
    let cmd = Command::new("bench", "regenerate a paper table/figure")
        .opt_default("artifacts", "artifacts", "artifact directory")
        .req("exp", "table1..5|table10|table11|fig2..fig6|all")
        .opt("model", "model override (defaults per experiment)")
        .opt_default("scale", "1.0", "step scale (quick runs: 0.1)")
        .opt_default("out", "results/results.md", "markdown results sink");
    let args = parse(cmd, rest)?;
    let mut wb = Workbench::new(&artifacts_dir(&args))?;
    wb.step_scale = args.num_or("scale", 1.0);
    let out = PathBuf::from(args.get_or("out", "results/results.md"));
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }

    let exp = args.get("exp").unwrap().to_string();
    let chosen_model = args.get("model").map(String::from);
    let run_one = |id: &str| -> Result<()> {
        let rows: Vec<(String, Vec<Row>)> = match id {
            "table1" => {
                let mut out = Vec::new();
                for m in models_for(&chosen_model, &["lm_tiny", "img_tiny"]) {
                    out.push((format!("Table 1 — {m}"), tables::table1(&wb, &m)?));
                }
                out
            }
            "table2" => {
                let mut out = Vec::new();
                for m in models_for(&chosen_model, &["lm_tiny", "cls_tiny", "img_tiny"]) {
                    out.push((format!("Table 2 — {m}"), tables::table2(&wb, &m)?));
                }
                out
            }
            "table3" => {
                let mut out = Vec::new();
                for m in models_for(&chosen_model, &["lm_tiny", "cls_tiny"]) {
                    out.push((format!("Table 3 — {m}"), tables::table3(&wb, &m)?));
                }
                out
            }
            "table4" => {
                let m = chosen_model.clone().unwrap_or_else(|| "img_tiny".into());
                vec![(format!("Table 4 — {m}"), tables::table4(&wb, &m)?)]
            }
            "table5" => {
                let m = chosen_model.clone().unwrap_or_else(|| "lm_tiny".into());
                vec![(format!("Table 5 — {m}"), tables::table5(&wb, &m)?)]
            }
            "table10" => {
                let mut out = Vec::new();
                for m in models_for(&chosen_model, &["lm_tiny", "img_tiny"]) {
                    out.push((format!("Table 10 — {m}"), tables::table10(&wb, &m)?));
                }
                out
            }
            "table11" => {
                let m = chosen_model.clone().unwrap_or_else(|| "lm_tiny".into());
                vec![(format!("Table 11 — {m}"), tables::table11(&wb, &m)?)]
            }
            "fig2" => {
                let mut out = Vec::new();
                for m in models_for(&chosen_model, &["lm_tiny", "cls_tiny", "img_tiny"]) {
                    out.push((format!("Fig 2 — {m}"), figures::fig2(&wb, &m)?));
                }
                out
            }
            "fig3" => {
                let mut out = Vec::new();
                for m in models_for(&chosen_model, &["lm_tiny", "img_tiny"]) {
                    out.push((format!("Fig 3 / Table 9 — {m}"), figures::fig3(&wb, &m)?));
                }
                out
            }
            "fig4" => {
                let m = chosen_model.clone().unwrap_or_else(|| "lm_tiny".into());
                vec![(format!("Fig 4 — {m}"), figures::fig4(&wb, &m)?)]
            }
            "fig5" => vec![("Fig 5".to_string(), figures::fig5(&wb)?)],
            "fig6" => {
                let m = chosen_model.clone().unwrap_or_else(|| "lm_tiny".into());
                vec![(format!("Fig 6 — {m}"), figures::fig6(&wb, &m)?)]
            }
            other => anyhow::bail!("unknown experiment '{other}'"),
        };
        for (title, r) in rows {
            report::append_markdown(&out, &title, &r)?;
        }
        Ok(())
    };

    if exp == "all" {
        for id in [
            "table1", "table2", "table3", "table4", "table5", "table10", "table11",
            "fig2", "fig3", "fig4", "fig5", "fig6",
        ] {
            log_info!("=== running {id} ===");
            run_one(id)?;
        }
    } else {
        run_one(&exp)?;
    }
    println!("\nresults appended to {}", out.display());
    Ok(())
}

fn models_for(chosen: &Option<String>, default: &[&str]) -> Vec<String> {
    match chosen {
        Some(m) => vec![m.clone()],
        None => default.iter().map(|s| s.to_string()).collect(),
    }
}
