//! Crash-safe training checkpoints (DESIGN.md §10).
//!
//! A `QNC1` checkpoint captures *complete* trainer state — parameters,
//! optimizer moments, step counter, RNG stream position, data-batcher
//! cursor, and the current hat tensors — so `qn train --resume`
//! replays the remaining steps bit-identically to the uninterrupted
//! run at any `threads`.
//!
//! On-disk format (all integers little-endian):
//!
//! ```text
//!   "QNC1" | u32 header_len | JSON header | payload | u64 fnv1a64
//! ```
//!
//! The JSON header describes the payload layout:
//! `{"version":1,"model","step","batches","rng_state":"<hex>",
//!   "rng_inc":"<hex>","cfg_digest":"<hex>",
//!   "opt":{"kind":"sgd"|"adam","t":N,"slots":1|2},
//!   "params":[{"name","shape"}...],"hats":[{"idx","len"}...]}`
//! and the payload is the concatenated f32 LE data: params in manifest
//! order, then optimizer slots (SGD velocity, or Adam m then v), then
//! hat tensors. The trailer is FNV-1a over every preceding byte; a
//! torn write or bit flip fails validation and the loader falls back
//! to the previous checkpoint.
//!
//! Atomic-save protocol: encode → write `step-K.qnc1.tmp` → fsync →
//! rename → fsync dir → rewrite the `LATEST` pointer the same way →
//! prune. The last-good checkpoint is never touched until the new one
//! is durable, so a crash at *any* byte leaves a loadable state
//! (exercised via the `ckpt.*` fault points in `util::fault`).

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::trainer::TrainConfig;
use crate::model::params::{LoadError, ParamStore};
use crate::model::tensor::Tensor;
use crate::util::hash::{fnv1a64, from_hex, to_hex};
use crate::util::json::Json;
use crate::util::{fault, rng::Pcg};
use crate::{log_info, log_warn};

/// How many `step-*.qnc1` files to keep on disk (the newest and one
/// fallback in case the newest is torn by a crash mid-protocol).
const KEEP: usize = 2;

/// Where and how often the trainer checkpoints.
#[derive(Debug, Clone)]
pub struct CheckpointConfig {
    pub dir: PathBuf,
    /// save every N completed steps; 0 disables periodic saves
    pub every: usize,
}

/// Optimizer state captured alongside the parameters (slot tensors are
/// in param-store order, shapes mirror the params).
#[derive(Debug, Clone)]
pub enum OptState {
    Sgd { velocity: Vec<Tensor> },
    Adam { m: Vec<Tensor>, v: Vec<Tensor>, t: usize },
}

impl OptState {
    fn kind(&self) -> &'static str {
        match self {
            OptState::Sgd { .. } => "sgd",
            OptState::Adam { .. } => "adam",
        }
    }
}

/// Complete trainer state at a step boundary.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub model: String,
    /// completed steps — resume continues at this step index
    pub step: usize,
    /// batches drawn from the data source (the batcher cursor: resume
    /// re-draws and discards this many to realign the stream)
    pub batches: usize,
    /// trainer RNG position (`Pcg::state_parts`)
    pub rng: (u64, u64),
    /// digest of every bit-affecting `TrainConfig` field (see
    /// [`cfg_digest`]) — resume refuses a mismatched config
    pub cfg_digest: u64,
    pub params: ParamStore,
    pub opt: OptState,
    /// current hat tensors by manifest param index (sorted), as
    /// uploaded at the last refresh — without these, a resume before
    /// the next refresh boundary would diverge
    pub hats: Vec<(usize, Vec<f32>)>,
}

/// Digest of every `TrainConfig` field that affects the bit-exact
/// trajectory. `threads` and `log_every` are excluded on purpose: both
/// are proven bit-invariant (the whole point of the one-knob contract),
/// so a checkpoint taken at `--threads 8` may resume at `--threads 1`.
pub fn cfg_digest(model: &str, cfg: &TrainConfig) -> u64 {
    let s = format!(
        "v1|{model}|steps={}|sched={:?}|opt={:?}|clip={:08x}|noise={}|rate={:08x}|ld={:08x}|ldste={}|share={}|hat={}|seed={}",
        cfg.steps,
        cfg.schedule,
        cfg.optimizer,
        cfg.clip.to_bits(),
        cfg.noise,
        cfg.noise_rate.to_bits(),
        cfg.layerdrop.to_bits(),
        cfg.ldste,
        cfg.share_chunk,
        cfg.hat_refresh,
        cfg.seed,
    );
    fnv1a64(s.as_bytes())
}

// ------------------------------------------------------------ codec ---

fn push_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn corrupt(offset: usize, what: impl Into<String>) -> LoadError {
    LoadError { offset, what: what.into() }
}

fn take_f32s(
    bytes: &[u8],
    off: &mut usize,
    n: usize,
    what: &str,
) -> Result<Vec<f32>, LoadError> {
    let need = n
        .checked_mul(4)
        .ok_or_else(|| corrupt(*off, format!("{what}: element count {n} overflows")))?;
    let end = off
        .checked_add(need)
        .filter(|&e| e <= bytes.len())
        .ok_or_else(|| {
            corrupt(
                bytes.len(),
                format!("truncated payload: {what} needs {need} bytes at offset {off}"),
            )
        })?;
    let v = bytes[*off..end]
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    *off = end;
    Ok(v)
}

fn header_hex(j: &Json, key: &str) -> Result<u64, LoadError> {
    j.get(key)
        .as_str()
        .and_then(from_hex)
        .ok_or_else(|| corrupt(8, format!("header: missing/invalid hex field '{key}'")))
}

fn header_usize(j: &Json, key: &str) -> Result<usize, LoadError> {
    j.get(key)
        .as_usize()
        .ok_or_else(|| corrupt(8, format!("header: missing/invalid field '{key}'")))
}

/// Serialize to the QNC1 wire format (hats are emitted sorted by index
/// so encode is canonical: same state → same bytes → same hash).
pub fn encode(ck: &Checkpoint) -> Vec<u8> {
    let mut hats: Vec<&(usize, Vec<f32>)> = ck.hats.iter().collect();
    hats.sort_by_key(|(i, _)| *i);
    let (kind, t, slots) = match &ck.opt {
        OptState::Sgd { .. } => (ck.opt.kind(), 0usize, 1usize),
        OptState::Adam { t, .. } => (ck.opt.kind(), *t, 2usize),
    };
    let params_json: Vec<Json> = ck
        .params
        .iter()
        .map(|(n, tsr)| {
            Json::obj(vec![
                ("name", Json::str(n.clone())),
                (
                    "shape",
                    Json::Arr(tsr.shape.iter().map(|&d| Json::num(d as f64)).collect()),
                ),
            ])
        })
        .collect();
    let hats_json: Vec<Json> = hats
        .iter()
        .map(|(i, h)| {
            Json::obj(vec![
                ("idx", Json::num(*i as f64)),
                ("len", Json::num(h.len() as f64)),
            ])
        })
        .collect();
    let header = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("model", Json::str(ck.model.clone())),
        ("step", Json::num(ck.step as f64)),
        ("batches", Json::num(ck.batches as f64)),
        ("rng_state", Json::str(to_hex(ck.rng.0))),
        ("rng_inc", Json::str(to_hex(ck.rng.1))),
        ("cfg_digest", Json::str(to_hex(ck.cfg_digest))),
        (
            "opt",
            Json::obj(vec![
                ("kind", Json::str(kind)),
                ("t", Json::num(t as f64)),
                ("slots", Json::num(slots as f64)),
            ]),
        ),
        ("params", Json::Arr(params_json)),
        ("hats", Json::Arr(hats_json)),
    ])
    .to_string();

    let mut out = Vec::new();
    out.extend_from_slice(b"QNC1");
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for (_, tsr) in ck.params.iter() {
        push_f32s(&mut out, &tsr.data);
    }
    match &ck.opt {
        OptState::Sgd { velocity } => {
            for v in velocity {
                push_f32s(&mut out, &v.data);
            }
        }
        OptState::Adam { m, v, .. } => {
            for x in m {
                push_f32s(&mut out, &x.data);
            }
            for x in v {
                push_f32s(&mut out, &x.data);
            }
        }
    }
    for (_, h) in hats {
        push_f32s(&mut out, h);
    }
    let hash = fnv1a64(&out);
    out.extend_from_slice(&hash.to_le_bytes());
    out
}

/// Parse and validate QNC1 bytes. Every failure carries the byte
/// offset where decoding stopped; the trailer is verified *first* so a
/// torn write is reported as corruption, never as a half-parsed state.
pub fn decode(bytes: &[u8]) -> Result<Checkpoint, LoadError> {
    if bytes.len() < 16 {
        return Err(corrupt(bytes.len(), format!("file too short ({} bytes)", bytes.len())));
    }
    let body_len = bytes.len() - 8;
    let mut tb = [0u8; 8];
    tb.copy_from_slice(&bytes[body_len..]);
    let want = u64::from_le_bytes(tb);
    let got = fnv1a64(&bytes[..body_len]);
    if got != want {
        return Err(corrupt(
            body_len,
            format!(
                "trailer hash mismatch (stored {}, computed {}) — torn write or bit rot",
                to_hex(want),
                to_hex(got)
            ),
        ));
    }
    if &bytes[..4] != b"QNC1" {
        return Err(corrupt(0, format!("bad magic {:?}", &bytes[..4])));
    }
    let mut lb = [0u8; 4];
    lb.copy_from_slice(&bytes[4..8]);
    let hlen = u32::from_le_bytes(lb) as usize;
    let hend = 8usize
        .checked_add(hlen)
        .filter(|&e| e <= body_len)
        .ok_or_else(|| corrupt(4, format!("header length {hlen} exceeds file")))?;
    let htext = std::str::from_utf8(&bytes[8..hend])
        .map_err(|e| corrupt(8 + e.valid_up_to(), "header is not UTF-8"))?;
    let j = Json::parse(htext).map_err(|e| corrupt(8, format!("header JSON: {e}")))?;
    if j.get("version").as_usize() != Some(1) {
        return Err(corrupt(8, "unsupported checkpoint version (want 1)"));
    }
    let model = j
        .get("model")
        .as_str()
        .ok_or_else(|| corrupt(8, "header: missing 'model'"))?
        .to_string();
    let step = header_usize(&j, "step")?;
    let batches = header_usize(&j, "batches")?;
    let rng = (header_hex(&j, "rng_state")?, header_hex(&j, "rng_inc")?);
    let cfg = header_hex(&j, "cfg_digest")?;

    let mut off = hend;
    let body = &bytes[..body_len];
    let mut params = ParamStore::new();
    let plist = j
        .get("params")
        .as_arr()
        .ok_or_else(|| corrupt(8, "header: missing 'params' array"))?;
    let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(plist.len());
    for (i, p) in plist.iter().enumerate() {
        let name = p
            .get("name")
            .as_str()
            .ok_or_else(|| corrupt(8, format!("header: param {i} missing 'name'")))?;
        let shape_j = p
            .get("shape")
            .as_arr()
            .ok_or_else(|| corrupt(8, format!("header: param '{name}' missing 'shape'")))?;
        let mut shape = Vec::with_capacity(shape_j.len());
        for d in shape_j {
            shape.push(d.as_usize().ok_or_else(|| {
                corrupt(8, format!("header: param '{name}' has a non-integer dim"))
            })?);
        }
        if params.get(name).is_some() {
            return Err(corrupt(8, format!("header: duplicate param '{name}'")));
        }
        let numel: usize = shape.iter().product();
        let data = take_f32s(body, &mut off, numel, &format!("param '{name}'"))?;
        params.insert(name, Tensor::from_vec(&shape, data));
        shapes.push(shape);
    }

    let oj = j.get("opt");
    let kind = oj
        .get("kind")
        .as_str()
        .ok_or_else(|| corrupt(8, "header: missing 'opt.kind'"))?;
    let slots = header_usize(oj, "slots")?;
    let mut read_slot = |off: &mut usize, tag: &str| -> Result<Vec<Tensor>, LoadError> {
        let mut out = Vec::with_capacity(shapes.len());
        for shape in &shapes {
            let numel: usize = shape.iter().product();
            let data = take_f32s(body, off, numel, &format!("opt slot '{tag}'"))?;
            out.push(Tensor::from_vec(shape, data));
        }
        Ok(out)
    };
    let opt = match (kind, slots) {
        ("sgd", 1) => OptState::Sgd { velocity: read_slot(&mut off, "velocity")? },
        ("adam", 2) => {
            let t = header_usize(oj, "t")?;
            let m = read_slot(&mut off, "m")?;
            let v = read_slot(&mut off, "v")?;
            OptState::Adam { m, v, t }
        }
        _ => {
            return Err(corrupt(
                8,
                format!("header: unknown optimizer kind '{kind}' with {slots} slots"),
            ))
        }
    };

    let mut hats = Vec::new();
    if let Some(hlist) = j.get("hats").as_arr() {
        for (i, h) in hlist.iter().enumerate() {
            let idx = h
                .get("idx")
                .as_usize()
                .ok_or_else(|| corrupt(8, format!("header: hat {i} missing 'idx'")))?;
            let len = h
                .get("len")
                .as_usize()
                .ok_or_else(|| corrupt(8, format!("header: hat {i} missing 'len'")))?;
            let data = take_f32s(body, &mut off, len, &format!("hat {idx}"))?;
            hats.push((idx, data));
        }
    }
    if off != body_len {
        return Err(corrupt(off, format!("{} trailing payload bytes", body_len - off)));
    }
    Ok(Checkpoint { model, step, batches, rng, cfg_digest: cfg, params, opt, hats })
}

/// Extract just the parameters from QNC1 bytes (serve-side uploads
/// accept either QNP1 or a full checkpoint).
pub fn params_from_qnc1_bytes(bytes: &[u8]) -> Result<ParamStore, LoadError> {
    decode(bytes).map(|ck| ck.params)
}

// --------------------------------------------------- atomic save/load ---

fn ckpt_name(step: usize) -> String {
    // zero-padded so lexicographic order == numeric step order
    format!("step-{step:08}.qnc1")
}

/// fsync the directory so the rename itself is durable (best-effort:
/// not every filesystem supports fsync on a directory handle).
fn sync_dir(dir: &Path) {
    if let Ok(d) = fs::File::open(dir) {
        let _ = d.sync_all();
    }
}

fn atomic_write(dir: &Path, name: &str, bytes: &[u8], point: &str) -> Result<()> {
    let tmp = dir.join(format!("{name}.tmp"));
    let fin = dir.join(name);
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        fault::write_all(point, &mut f, bytes)
            .with_context(|| format!("write {}", tmp.display()))?;
        fault::check("ckpt.sync").context("pre-sync fault")?;
        f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    }
    fault::check("ckpt.rename").context("pre-rename fault")?;
    fs::rename(&tmp, &fin)
        .with_context(|| format!("rename {} -> {}", tmp.display(), fin.display()))?;
    sync_dir(dir);
    Ok(())
}

/// Write a checkpoint crash-atomically and update the `LATEST`
/// pointer. The previous checkpoint file and pointer stay untouched
/// until the new file is durable, so an injected failure anywhere in
/// this function leaves the directory loadable. Returns the final path.
pub fn save_checkpoint(dir: &Path, ck: &Checkpoint) -> Result<PathBuf> {
    fs::create_dir_all(dir).with_context(|| format!("mkdir {}", dir.display()))?;
    let bytes = encode(ck);
    let hash = fnv1a64(&bytes);
    let name = ckpt_name(ck.step);
    atomic_write(dir, &name, &bytes, "ckpt.write")?;
    fault::check("ckpt.latest").context("pre-latest fault")?;
    let latest = Json::obj(vec![
        ("file", Json::str(name.clone())),
        ("hash", Json::str(to_hex(hash))),
        ("step", Json::num(ck.step as f64)),
    ])
    .to_string();
    atomic_write(dir, "LATEST", latest.as_bytes(), "ckpt.latest.write")?;
    prune(dir);
    log_info!(
        "checkpoint: step {} -> {} ({} bytes, hash {})",
        ck.step,
        dir.join(&name).display(),
        bytes.len(),
        to_hex(hash)
    );
    Ok(dir.join(name))
}

/// Drop all but the newest [`KEEP`] checkpoints plus any stale temp
/// files left behind by a crashed save.
fn prune(dir: &Path) {
    let Ok(rd) = fs::read_dir(dir) else { return };
    let mut ckpts: Vec<String> = Vec::new();
    for ent in rd.flatten() {
        let name = ent.file_name().to_string_lossy().into_owned();
        if name.ends_with(".tmp") {
            let _ = fs::remove_file(ent.path());
        } else if name.starts_with("step-") && name.ends_with(".qnc1") {
            ckpts.push(name);
        }
    }
    ckpts.sort();
    let n = ckpts.len();
    for name in ckpts.into_iter().take(n.saturating_sub(KEEP)) {
        let _ = fs::remove_file(dir.join(name));
    }
}

/// Load a specific checkpoint file, validating the trailer.
pub fn load_file(path: &Path) -> Result<Checkpoint> {
    let bytes = fs::read(path).with_context(|| format!("read {}", path.display()))?;
    decode(&bytes).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

fn try_latest_pointer(dir: &Path) -> Option<Checkpoint> {
    let text = fs::read_to_string(dir.join("LATEST")).ok()?;
    let j = Json::parse(&text).ok()?;
    let file = j.get("file").as_str()?;
    let want = j.get("hash").as_str().and_then(from_hex)?;
    let bytes = fs::read(dir.join(file)).ok()?;
    if fnv1a64(&bytes) != want {
        log_warn!("checkpoint: {file} does not match LATEST hash; falling back");
        return None;
    }
    match decode(&bytes) {
        Ok(ck) => Some(ck),
        Err(e) => {
            log_warn!("checkpoint: {file} corrupt ({e}); falling back");
            None
        }
    }
}

/// Load the newest valid checkpoint from `dir`, or `None` when the
/// directory holds no usable checkpoint. Prefers the `LATEST` pointer;
/// on a stale/corrupt pointer (crash mid-protocol) scans `step-*.qnc1`
/// newest-first and takes the first file that self-validates.
pub fn load_latest(dir: &Path) -> Result<Option<Checkpoint>> {
    if !dir.exists() {
        return Ok(None);
    }
    if let Some(ck) = try_latest_pointer(dir) {
        return Ok(Some(ck));
    }
    let rd = fs::read_dir(dir).with_context(|| format!("scan {}", dir.display()))?;
    let mut names: Vec<String> = rd
        .flatten()
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("step-") && n.ends_with(".qnc1"))
        .collect();
    names.sort();
    for name in names.into_iter().rev() {
        match fs::read(dir.join(&name)) {
            Ok(bytes) => match decode(&bytes) {
                Ok(ck) => {
                    log_warn!("checkpoint: recovered from fallback scan: {name}");
                    return Ok(Some(ck));
                }
                Err(e) => log_warn!("checkpoint: skipping {name}: {e}"),
            },
            Err(e) => log_warn!("checkpoint: skipping {name}: {e}"),
        }
    }
    Ok(None)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::testing::temp_dir;

    fn sample(step: usize) -> Checkpoint {
        let mut params = ParamStore::new();
        params.insert("w0", Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 4.25, -0.5]));
        params.insert("b0", Tensor::from_vec(&[3], vec![0.1, 0.2, 0.3]));
        let velocity =
            vec![Tensor::from_vec(&[2, 3], vec![0.0; 6]), Tensor::from_vec(&[3], vec![9.0; 3])];
        Checkpoint {
            model: "lm".to_string(),
            step,
            batches: step + 1,
            rng: (0x0123_4567_89ab_cdef, 0xfedc_ba98_7654_3211),
            cfg_digest: 0xdead_beef_cafe_f00d,
            params,
            opt: OptState::Sgd { velocity },
            hats: vec![(0, vec![1.5, 2.5, 3.5, 4.5, 5.5, 6.5])],
        }
    }

    fn assert_same(a: &Checkpoint, b: &Checkpoint) {
        assert_eq!(a.model, b.model);
        assert_eq!(a.step, b.step);
        assert_eq!(a.batches, b.batches);
        assert_eq!(a.rng, b.rng);
        assert_eq!(a.cfg_digest, b.cfg_digest);
        assert_eq!(a.params.names(), b.params.names());
        for (n, t) in a.params.iter() {
            assert_eq!(b.params.get(n).unwrap(), t);
        }
        assert_eq!(a.hats, b.hats);
        match (&a.opt, &b.opt) {
            (OptState::Sgd { velocity: x }, OptState::Sgd { velocity: y }) => assert_eq!(x, y),
            (OptState::Adam { m: m1, v: v1, t: t1 }, OptState::Adam { m: m2, v: v2, t: t2 }) => {
                assert_eq!((m1, v1, t1), (m2, v2, t2))
            }
            _ => panic!("optimizer kind mismatch"),
        }
    }

    #[test]
    fn roundtrip_sgd() {
        let ck = sample(7);
        let got = decode(&encode(&ck)).unwrap();
        assert_same(&ck, &got);
    }

    #[test]
    fn roundtrip_adam() {
        let mut ck = sample(3);
        let zeros =
            vec![Tensor::from_vec(&[2, 3], vec![0.5; 6]), Tensor::from_vec(&[3], vec![0.25; 3])];
        ck.opt = OptState::Adam { m: zeros.clone(), v: zeros, t: 11 };
        let got = decode(&encode(&ck)).unwrap();
        assert_same(&ck, &got);
    }

    #[test]
    fn encode_is_canonical() {
        let mut a = sample(5);
        a.hats = vec![(1, vec![2.0]), (0, vec![1.0])];
        let mut b = sample(5);
        b.hats = vec![(0, vec![1.0]), (1, vec![2.0])];
        assert_eq!(encode(&a), encode(&b));
    }

    #[test]
    fn every_truncation_is_detected() {
        let bytes = encode(&sample(2));
        for cut in 0..bytes.len() {
            assert!(
                decode(&bytes[..cut]).is_err(),
                "truncation to {cut}/{} bytes not rejected",
                bytes.len()
            );
        }
    }

    #[test]
    fn every_bit_flip_is_detected() {
        let bytes = encode(&sample(2));
        // flipping any single bit must trip the fnv trailer
        for i in (0..bytes.len()).step_by(7) {
            let mut m = bytes.clone();
            m[i] ^= 0x10;
            let e = decode(&m).expect_err("bit flip undetected");
            assert!(e.to_string().contains("trailer hash"), "{e}");
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let ck = sample(1);
        let mut bytes = encode(&ck);
        // append junk and re-seal the trailer: framing must still fail
        let body = bytes.len() - 8;
        bytes.truncate(body);
        bytes.extend_from_slice(&[0, 0, 0, 0]);
        let h = fnv1a64(&bytes);
        bytes.extend_from_slice(&h.to_le_bytes());
        let e = decode(&bytes).expect_err("trailing bytes accepted");
        assert!(e.to_string().contains("trailing"), "{e}");
    }

    #[test]
    fn save_load_latest_roundtrip_and_prune() {
        let dir = temp_dir("qnc1");
        for s in [2usize, 4, 6] {
            save_checkpoint(&dir, &sample(s)).unwrap();
        }
        let got = load_latest(&dir).unwrap().expect("latest");
        assert_eq!(got.step, 6);
        // prune keeps the newest KEEP files
        let kept: Vec<String> = fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".qnc1"))
            .collect();
        assert_eq!(kept.len(), KEEP);
        assert!(kept.contains(&ckpt_name(6)) && kept.contains(&ckpt_name(4)));
        fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corrupt_latest_falls_back_to_scan() {
        let dir = temp_dir("qnc1fb");
        save_checkpoint(&dir, &sample(3)).unwrap();
        save_checkpoint(&dir, &sample(5)).unwrap();
        // corrupt the newest file AND leave LATEST pointing at it:
        // load must fall back to step 3
        let newest = dir.join(ckpt_name(5));
        let mut bytes = fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        fs::write(&newest, &bytes).unwrap();
        let got = load_latest(&dir).unwrap().expect("fallback");
        assert_eq!(got.step, 3);
        fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn empty_or_missing_dir_is_none() {
        let dir = temp_dir("qnc1empty");
        assert!(load_latest(&dir).unwrap().is_none());
        assert!(load_latest(&dir.join("nope")).unwrap().is_none());
        fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn cfg_digest_tracks_bit_affecting_fields_only() {
        let base = TrainConfig::default();
        let d0 = cfg_digest("lm", &base);
        assert_eq!(d0, cfg_digest("lm", &base));
        let mut threads = base.clone();
        threads.threads = 8;
        threads.log_every = 1;
        assert_eq!(d0, cfg_digest("lm", &threads), "threads/log_every must not matter");
        let mut seed = base.clone();
        seed.seed = 99;
        assert_ne!(d0, cfg_digest("lm", &seed));
        let mut rate = base.clone();
        rate.noise_rate += 0.01;
        assert_ne!(d0, cfg_digest("lm", &rate));
        assert_ne!(d0, cfg_digest("cls", &base));
    }
}
