//! Iterative Product Quantization (paper §3.2, following Stock et al.).
//!
//! Structures are quantized sequentially (default order FFN → emb →
//! attn, the paper's §7.11.4 choice); after each group is frozen to its
//! codebook the *remaining* float parameters keep training on the task
//! loss while the frozen groups' codewords are finetuned with Eq. (4):
//!
//! ```text
//! c ← c − η · mean_{(k,l): I_kl = c} ∂L/∂b_kl
//! ```
//!
//! i.e. each codeword moves by the average gradient of the subvectors
//! assigned to it. The paper finetunes under the uncompressed teacher;
//! we finetune on the task loss directly (DESIGN.md §Substitutions).
// The unwraps below are Option/position invariants internal to one
// fully-constructed pipeline pass (assignment tables built in the same
// function that indexes them), not I/O fallibility — module-wide allow
// with this justification rather than ten identical per-site notes.
#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::optim::{clip_grad_norm, Optimizer};
use crate::coordinator::quantize::QuantizedModel;
use crate::coordinator::trainer::BatchSource;
use crate::log_info;
use crate::model::params::ParamStore;
use crate::model::tensor::Tensor;
use crate::quant::pq::PqMatrix;
use crate::quant::scheme::{PqSpec, QuantSpec};
use crate::runtime::executable::ModelSession;
use crate::util::rng::Pcg;

#[derive(Debug, Clone)]
pub struct IpqConfig {
    pub k: usize,
    pub kmeans_iters: usize,
    /// finetune steps after each group is quantized
    pub finetune_steps: usize,
    /// codeword learning rate η in Eq. (4)
    pub codeword_lr: f32,
    /// float-parameter finetune LR (upper layers adapting to drift)
    pub float_lr: f32,
    /// structure quantization order; noised structures not listed are
    /// appended at the end in manifest order
    pub order: Vec<String>,
    /// §3.3: intN-compress centroids at the end (`Some(8)` = iPQ ⊕
    /// int8, `Some(4)` = the int4 variant, `None` = fp32 codebooks)
    pub centroid_bits: Option<u8>,
    /// global PQ block-size override; `None` ⇒ per-param manifest block
    pub block: Option<usize>,
    /// per-structure PQ block-size override (Fig. 6b; wins over `block`)
    pub block_override: BTreeMap<String, usize>,
    /// worker threads for k-means/encode (0 ⇒ default)
    pub threads: usize,
    pub seed: u64,
}

impl Default for IpqConfig {
    fn default() -> Self {
        IpqConfig {
            k: 256,
            kmeans_iters: 12,
            finetune_steps: 30,
            codeword_lr: 0.05,
            float_lr: 0.01,
            order: vec!["ffn".into(), "emb".into(), "attn".into()],
            centroid_bits: None,
            block: None,
            block_override: BTreeMap::new(),
            threads: 0,
            seed: 17,
        }
    }
}

impl IpqConfig {
    /// The storage/PTQ spec equivalent of this iPQ run (what the model
    /// looks like once the finetuning procedure is done).
    pub fn spec(&self) -> QuantSpec {
        QuantSpec::Pq(PqSpec {
            k: self.k,
            block: self.block,
            kmeans_iters: self.kmeans_iters,
            codebook_bits: self.centroid_bits,
            block_override: self.block_override.clone(),
            threads: self.threads,
        })
    }
}

/// Group the noised params by quantization order.
fn group_order(meta: &crate::model::config::ModelMeta, order: &[String]) -> Vec<Vec<String>> {
    let mut groups: Vec<Vec<String>> = Vec::new();
    let mut taken: Vec<String> = Vec::new();
    for s in order {
        let names: Vec<String> = meta
            .params
            .iter()
            .filter(|p| p.noised && &p.structure == s)
            .map(|p| p.name.clone())
            .collect();
        taken.extend(names.iter().cloned());
        if !names.is_empty() {
            groups.push(names);
        }
    }
    let rest: Vec<String> = meta
        .params
        .iter()
        .filter(|p| p.noised && !taken.contains(&p.name))
        .map(|p| p.name.clone())
        .collect();
    if !rest.is_empty() {
        groups.push(rest);
    }
    groups
}

/// Eq. (4): one codeword-gradient step for a frozen param, then refresh
/// the dequantized weights in-place (assignments stay fixed).
pub fn codeword_step(m: &mut PqMatrix, grad: &Tensor, lr: f32) {
    let d = m.block_size();
    let k = m.codebook.k;
    let mut acc = vec![0.0f64; k * d];
    let mut counts = vec![0usize; k];
    for (s, &code) in m.codes.iter().enumerate() {
        let g = &grad.data[s * d..(s + 1) * d];
        let c = code as usize;
        counts[c] += 1;
        for t in 0..d {
            acc[c * d + t] += g[t] as f64;
        }
    }
    for c in 0..k {
        if counts[c] == 0 {
            continue;
        }
        let cw = m.codebook.codeword_mut(c);
        for t in 0..d {
            cw[t] -= lr * (acc[c * d + t] / counts[c] as f64) as f32;
        }
    }
}

pub struct IpqReport {
    pub group_losses: Vec<(String, f32)>,
    pub bytes: u64,
    pub sq_error: f64,
}

/// Run the full iPQ pipeline on trained params. Returns the quantized
/// model (PQ state + dequantized store) and a report.
pub fn run_ipq(
    sess: &mut ModelSession,
    params: &ParamStore,
    data: &mut dyn BatchSource,
    cfg: &IpqConfig,
) -> Result<(QuantizedModel, IpqReport)> {
    let meta = sess.meta.clone();
    let mut rng = Pcg::new(cfg.seed);
    let mut work = params.clone();
    let mut pq_state: BTreeMap<String, PqMatrix> = BTreeMap::new();
    let mut frozen: Vec<bool> = meta.params.iter().map(|_| false).collect();
    let mut opt = Optimizer::sgd(&work, 0.9, false);
    let mut group_losses = Vec::new();

    let groups = group_order(&meta, &cfg.order);
    for (gi, group) in groups.iter().enumerate() {
        // 1. quantize this group against the *current* weights
        for name in group {
            let pm = meta.param(name).unwrap();
            let (rows, cols) = pm.view.unwrap();
            let bs = cfg
                .block_override
                .get(&pm.structure)
                .copied()
                .or(cfg.block)
                .or(pm.block_size)
                .unwrap_or(8);
            anyhow::ensure!(
                bs > 0 && cols % bs == 0,
                "{}: cols {cols} not divisible by PQ block {bs}",
                pm.name
            );
            let pcfg = crate::quant::pq::PqConfig {
                block_size: bs,
                n_centroids: cfg.k,
                kmeans_iters: cfg.kmeans_iters,
                threads: cfg.threads,
            };
            let m =
                crate::quant::pq::fit(&work.get(name).unwrap().data, rows, cols, &pcfg, &mut rng);
            m.decode_into(&mut work.get_mut(name).unwrap().data);
            let idx = meta.params.iter().position(|p| &p.name == name).unwrap();
            frozen[idx] = true;
            pq_state.insert(name.clone(), m);
        }
        sess.upload_all_params(&work)?;

        // 2. finetune: float params via SGD, frozen groups via Eq. (4)
        let mut last_loss = f32::NAN;
        for _ in 0..cfg.finetune_steps {
            let batch = data.next_batch();
            let keep = vec![1.0f32; meta.n_layers];
            let seed = (rng.next_u32() & 0x7fff_ffff) as i32;
            let (loss, mut grads) =
                sess.grad("grad_mix", &batch.input(), batch.targets(), &keep, 0.0, seed)?;
            last_loss = loss;
            clip_grad_norm(&mut grads, 0.25);
            // codeword updates for every frozen param
            for (idx, pm) in meta.params.iter().enumerate() {
                if !frozen[idx] || !pq_state.contains_key(&pm.name) {
                    continue;
                }
                let m = pq_state.get_mut(&pm.name).unwrap();
                codeword_step(m, &grads[idx], cfg.codeword_lr);
                // refresh the dequantized weights straight from the
                // stored assignments on the engine's decode kernel —
                // no re-encode, no per-step temporary buffer
                m.decode_into(&mut work.get_mut(&pm.name).unwrap().data);
            }
            // float updates for everything else
            opt.step(&mut work, &grads, cfg.float_lr, &frozen);
            sess.upload_all_params(&work)?;
        }
        log_info!(
            "ipq[{}] group {}/{} ({:?}…) frozen, loss {last_loss:.4}",
            meta.name,
            gi + 1,
            groups.len(),
            group.first()
        );
        group_losses.push((group.join(","), last_loss));
    }

    // 3. optional §3.3 combination: intN-compress all codebooks
    if let Some(bits) = cfg.centroid_bits {
        for (name, m) in pq_state.iter_mut() {
            m.codebook.compress(bits);
            m.decode_into(&mut work.get_mut(name).unwrap().data);
        }
        sess.upload_all_params(&work)?;
    }

    // storage accounting via the unified scheme machinery
    let bytes = crate::coordinator::quantize::scheme_bytes(&meta, &cfg.spec());
    let sq_error: f64 = meta
        .params
        .iter()
        .filter(|p| p.noised)
        .map(|p| {
            params
                .get(&p.name)
                .unwrap()
                .data
                .iter()
                .zip(&work.get(&p.name).unwrap().data)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
        })
        .sum();

    Ok((
        QuantizedModel { store: work, bytes, pq: pq_state, sq_error },
        IpqReport { group_losses, bytes, sq_error },
    ))
}

/// One-shot PQ without finetuning — the "iPQ (post)" baseline rows,
/// and the codebook-refresh primitive behind the serving layer's
/// online `/reencode` (same fit, same determinism contract).
pub fn post_pq(
    params: &ParamStore,
    meta: &crate::model::config::ModelMeta,
    cfg: &IpqConfig,
) -> Result<QuantizedModel> {
    crate::coordinator::quantize::reencode_params(
        params,
        meta,
        &cfg.spec(),
        &mut Pcg::new(cfg.seed),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::Codebook;

    #[test]
    fn codeword_step_moves_by_mean_gradient() {
        // 2 codewords (d=2), 4 subvectors: codes [0,0,1,1]
        let cb = Codebook::new(vec![0.0, 0.0, 1.0, 1.0], 2, 2);
        let mut m = PqMatrix { codebook: cb, codes: vec![0, 0, 1, 1], rows: 2, cols: 4 };
        let grad = Tensor::from_vec(&[2, 4], vec![1.0, 0.0, 3.0, 0.0, 0.0, 2.0, 0.0, 4.0]);
        codeword_step(&mut m, &grad, 0.5);
        // codeword 0: mean grad (2.0, 0.0) ⇒ 0 - 0.5·2 = -1.0
        assert_eq!(m.codebook.codeword(0), &[-1.0, 0.0]);
        // codeword 1: mean grad (0.0, 3.0) ⇒ 1 - 0.5·3 = -0.5
        assert_eq!(m.codebook.codeword(1), &[1.0, -0.5]);
    }

    #[test]
    fn codeword_step_ignores_empty_codewords() {
        let cb = Codebook::new(vec![5.0, 5.0, 7.0, 7.0], 2, 2);
        let mut m = PqMatrix { codebook: cb, codes: vec![0, 0], rows: 1, cols: 4 };
        let grad = Tensor::from_vec(&[1, 4], vec![1.0; 4]);
        codeword_step(&mut m, &grad, 1.0);
        assert_eq!(m.codebook.codeword(1), &[7.0, 7.0]); // untouched
    }

    #[test]
    fn codeword_step_reduces_linear_loss() {
        // loss = <G, W>; moving codewords along -G must reduce it
        let cb = Codebook::new(vec![0.5, -0.5, 1.5, 0.25], 2, 2);
        let mut m = PqMatrix { codebook: cb, codes: vec![0, 1, 1, 0], rows: 2, cols: 4 };
        let g = Tensor::from_vec(&[2, 4], (0..8).map(|i| (i as f32 - 3.5) / 4.0).collect());
        let loss = |m: &PqMatrix| -> f64 {
            m.decode().iter().zip(&g.data).map(|(&w, &gi)| (w * gi) as f64).sum()
        };
        let before = loss(&m);
        codeword_step(&mut m, &g, 0.1);
        assert!(loss(&m) < before);
    }
}
