//! Post-training weight quantization: apply a compression scheme to a
//! parameter store, producing the quantized weights the eval artifact
//! sees plus exact storage accounting.
//!
//! Covers: intN per-tensor (MinMax or Histogram observers, §7.7), intN
//! per-channel (Table 10), one-shot PQ (no finetuning — the "iPQ" rows
//! *without* finetuning in ablations), and the iPQ ⊕ int8 combination
//! (§3.3: int8 centroids; activations are handled by the
//! `eval_int8act` artifact).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::config::ModelMeta;
use crate::model::params::ParamStore;
use crate::quant::observer::HistogramObserver;
use crate::quant::pq::{fit, PqConfig, PqMatrix};
use crate::quant::scalar;
use crate::quant::size::{model_bytes, Scheme};
use crate::util::rng::Pcg;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum IntMode {
    MinMax,
    Histogram,
    PerChannel,
}

#[derive(Debug, Clone)]
pub enum WeightScheme {
    /// fp32 passthrough (size accounting only)
    None,
    Int {
        bits: u8,
        mode: IntMode,
    },
    Pq {
        k: usize,
        kmeans_iters: usize,
        /// per-structure block-size override (Fig. 6b); falls back to
        /// the manifest's per-param block size
        block_override: BTreeMap<String, usize>,
        int8_centroids: bool,
        /// k-means/encode worker threads (0 ⇒ all cores)
        threads: usize,
    },
}

impl WeightScheme {
    pub fn pq(k: usize) -> WeightScheme {
        WeightScheme::Pq {
            k,
            kmeans_iters: 12,
            block_override: BTreeMap::new(),
            int8_centroids: false,
            threads: 0,
        }
    }
}

pub struct QuantizedModel {
    /// Dequantized weights to feed the eval artifact.
    pub store: ParamStore,
    /// Exact storage under the scheme (norms/biases stay fp32).
    pub bytes: u64,
    /// PQ state per param (kept for iPQ finetuning / exact-noise reuse).
    pub pq: BTreeMap<String, PqMatrix>,
    /// Total squared reconstruction error across quantized params.
    pub sq_error: f64,
}

/// Apply `scheme` to every noised parameter.
pub fn quantize_params(
    params: &ParamStore,
    meta: &ModelMeta,
    scheme: &WeightScheme,
    rng: &mut Pcg,
) -> Result<QuantizedModel> {
    let mut store = ParamStore::new();
    let mut pq_map = BTreeMap::new();
    let mut sq_error = 0.0f64;

    for pm in &meta.params {
        let t = params
            .get(&pm.name)
            .ok_or_else(|| anyhow::anyhow!("missing param {}", pm.name))?;
        if !pm.noised {
            store.insert(&pm.name, t.clone());
            continue;
        }
        let (rows, cols) = pm.view.unwrap_or((1, t.numel()));
        let mut data = t.data.clone();
        match scheme {
            WeightScheme::None => {}
            WeightScheme::Int { bits, mode } => match mode {
                IntMode::MinMax => {
                    let qp = scalar::QParams::from_minmax(&data, *bits);
                    scalar::roundtrip(&mut data, &qp);
                }
                IntMode::Histogram => {
                    let mut h = HistogramObserver::new(2048);
                    h.observe(&data);
                    let qp = h.qparams(*bits);
                    scalar::roundtrip(&mut data, &qp);
                }
                IntMode::PerChannel => {
                    scalar::roundtrip_per_channel(&mut data, rows, cols, *bits);
                }
            },
            WeightScheme::Pq { k, kmeans_iters, block_override, int8_centroids, threads } => {
                let bs = block_override
                    .get(&pm.structure)
                    .copied()
                    .or(pm.block_size)
                    .unwrap_or(8);
                anyhow::ensure!(
                    cols % bs == 0,
                    "{}: cols {cols} not divisible by PQ block {bs}",
                    pm.name
                );
                let cfg = PqConfig {
                    block_size: bs,
                    n_centroids: *k,
                    kmeans_iters: *kmeans_iters,
                    threads: *threads,
                };
                let mut m = fit(&data, rows, cols, &cfg, rng);
                if *int8_centroids {
                    m.codebook.compress_int8();
                }
                data = m.decode();
                pq_map.insert(pm.name.clone(), m);
            }
        }
        sq_error += t
            .data
            .iter()
            .zip(&data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>();
        store.insert(&pm.name, crate::model::tensor::Tensor::from_vec(&pm.shape, data));
    }

    let bytes = scheme_bytes(meta, scheme);
    Ok(QuantizedModel { store, bytes, pq: pq_map, sq_error })
}

/// Storage accounting for a scheme over this model's inventory.
pub fn scheme_bytes(meta: &ModelMeta, scheme: &WeightScheme) -> u64 {
    let infos: Vec<_> = match scheme {
        WeightScheme::Pq { block_override, .. } => meta
            .params
            .iter()
            .map(|p| p.to_param_info(block_override.get(&p.structure).copied()))
            .collect(),
        _ => meta.param_infos(),
    };
    let s = match scheme {
        WeightScheme::None => Scheme::Fp32,
        WeightScheme::Int { bits, .. } => Scheme::Int { bits: *bits },
        WeightScheme::Pq { k, int8_centroids, .. } => {
            Scheme::Pq { k: *k, int8_centroids: *int8_centroids }
        }
    };
    model_bytes(&infos, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ParamMeta;
    use crate::model::tensor::Tensor;

    fn tiny_meta() -> ModelMeta {
        ModelMeta {
            name: "t".into(),
            task: "lm".into(),
            n_layers: 1,
            batch: 1,
            seq_len: 4,
            tokens_shape: vec![1, 4],
            targets_shape: vec![1, 4],
            vocab: 8,
            n_classes: 0,
            params: vec![
                ParamMeta {
                    name: "w".into(),
                    shape: vec![16, 32],
                    structure: "ffn".into(),
                    noised: true,
                    view: Some((16, 32)),
                    block_size: Some(8),
                },
                ParamMeta {
                    name: "ln".into(),
                    shape: vec![16],
                    structure: "norm".into(),
                    noised: false,
                    view: None,
                    block_size: None,
                },
            ],
            entries: vec![],
            init_file: String::new(),
        }
    }

    fn tiny_params() -> ParamStore {
        let mut rng = Pcg::new(3);
        let mut p = ParamStore::new();
        p.insert(
            "w",
            Tensor::from_vec(&[16, 32], (0..512).map(|_| rng.next_normal()).collect()),
        );
        p.insert("ln", Tensor::from_vec(&[16], vec![1.0; 16]));
        p
    }

    #[test]
    fn int8_roundtrip_close_and_norms_untouched() {
        let meta = tiny_meta();
        let params = tiny_params();
        let q = quantize_params(
            &params,
            &meta,
            &WeightScheme::Int { bits: 8, mode: IntMode::MinMax },
            &mut Pcg::new(0),
        )
        .unwrap();
        assert_eq!(q.store.get("ln").unwrap(), params.get("ln").unwrap());
        let mse = q.store.get("w").unwrap().mse(params.get("w").unwrap());
        assert!(mse < 1e-3, "{mse}");
        assert!(q.sq_error > 0.0);
    }

    #[test]
    fn int4_worse_than_int8() {
        let meta = tiny_meta();
        let params = tiny_params();
        let q8 = quantize_params(&params, &meta, &WeightScheme::Int { bits: 8, mode: IntMode::MinMax }, &mut Pcg::new(0)).unwrap();
        let q4 = quantize_params(&params, &meta, &WeightScheme::Int { bits: 4, mode: IntMode::MinMax }, &mut Pcg::new(0)).unwrap();
        assert!(q4.sq_error > q8.sq_error);
        assert!(q4.bytes < q8.bytes);
    }

    #[test]
    fn pq_returns_codebooks_and_smaller_size() {
        let meta = tiny_meta();
        let params = tiny_params();
        let q = quantize_params(&params, &meta, &WeightScheme::pq(16), &mut Pcg::new(1)).unwrap();
        assert!(q.pq.contains_key("w"));
        assert!(!q.pq.contains_key("ln"));
        let fp = scheme_bytes(&meta, &WeightScheme::None);
        assert!(q.bytes < fp, "{} vs {fp}", q.bytes);
        // decoded store matches PqMatrix::decode
        assert_eq!(q.store.get("w").unwrap().data, q.pq["w"].decode());
    }

    #[test]
    fn int8_centroids_shrink_codebook() {
        let meta = tiny_meta();
        let params = tiny_params();
        let plain = quantize_params(&params, &meta, &WeightScheme::pq(16), &mut Pcg::new(2)).unwrap();
        let mut s = WeightScheme::pq(16);
        if let WeightScheme::Pq { int8_centroids, .. } = &mut s {
            *int8_centroids = true;
        }
        let combo = quantize_params(&params, &meta, &s, &mut Pcg::new(2)).unwrap();
        assert!(combo.bytes < plain.bytes);
        // slightly more error than plain PQ, but same order of magnitude
        assert!(combo.sq_error >= plain.sq_error);
        assert!(combo.sq_error < plain.sq_error * 2.0 + 1.0);
    }

    #[test]
    fn block_override_changes_size() {
        // needs a matrix large enough that the index term dominates the
        // codebook term (as in real models) for bigger blocks to win
        let mut meta = tiny_meta();
        meta.params[0].shape = vec![128, 128];
        meta.params[0].view = Some((128, 128));
        let mut rng = Pcg::new(9);
        let mut params = ParamStore::new();
        params.insert(
            "w",
            Tensor::from_vec(&[128, 128], (0..128 * 128).map(|_| rng.next_normal()).collect()),
        );
        params.insert("ln", Tensor::from_vec(&[16], vec![1.0; 16]));
        let mut s = WeightScheme::pq(4);
        if let WeightScheme::Pq { block_override, .. } = &mut s {
            block_override.insert("ffn".into(), 16);
        }
        let big_blocks = quantize_params(&params, &meta, &s, &mut Pcg::new(3)).unwrap();
        let small = quantize_params(&params, &meta, &WeightScheme::pq(4), &mut Pcg::new(3)).unwrap();
        assert!(big_blocks.bytes < small.bytes, "{} vs {}", big_blocks.bytes, small.bytes);
        assert!(big_blocks.sq_error > small.sq_error);
    }

    #[test]
    fn histogram_mode_runs() {
        let meta = tiny_meta();
        let params = tiny_params();
        let q = quantize_params(
            &params,
            &meta,
            &WeightScheme::Int { bits: 4, mode: IntMode::Histogram },
            &mut Pcg::new(4),
        )
        .unwrap();
        assert!(q.sq_error.is_finite());
    }

    #[test]
    fn per_channel_beats_per_tensor_on_scaled_rows() {
        let meta = tiny_meta();
        let mut params = tiny_params();
        // scale half the rows ×50 so per-tensor quantization suffers
        {
            let w = params.get_mut("w").unwrap();
            for r in 0..8 {
                for c in 0..32 {
                    w.data[r * 32 + c] *= 50.0;
                }
            }
        }
        let pt = quantize_params(&params, &meta, &WeightScheme::Int { bits: 4, mode: IntMode::MinMax }, &mut Pcg::new(5)).unwrap();
        let pc = quantize_params(&params, &meta, &WeightScheme::Int { bits: 4, mode: IntMode::PerChannel }, &mut Pcg::new(5)).unwrap();
        assert!(pc.sq_error < pt.sq_error);
    }
}
