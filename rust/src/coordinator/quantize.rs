//! Post-training weight quantization: apply a compression scheme to a
//! parameter store, producing the quantized weights the eval artifact
//! sees plus exact storage accounting.
//!
//! The pipeline is one loop over per-parameter
//! [`Quantizer`](crate::quant::scheme::Quantizer) objects
//! resolved from a [`QuantSpec`] (or any [`QuantizerFactory`] — new
//! schemes plug in without touching this module). Covers: intN
//! per-tensor (MinMax or Histogram observers, §7.7), intN per-channel
//! (Table 10), one-shot PQ (the "iPQ" rows *without* finetuning in
//! ablations), and the iPQ ⊕ int8 combination (§3.3: int8 centroids;
//! activations are handled by the `eval_int8act` artifact).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::config::ModelMeta;
use crate::model::params::ParamStore;
use crate::quant::pq::PqMatrix;
use crate::quant::scheme::{QuantSpec, Quantizer as _, QuantizerFactory};
use crate::quant::size::model_bytes_with;
use crate::util::rng::Pcg;

pub struct QuantizedModel {
    /// Dequantized weights to feed the eval artifact.
    pub store: ParamStore,
    /// Exact storage under the scheme (norms/biases stay fp32).
    pub bytes: u64,
    /// PQ state per param (kept for iPQ finetuning / exact-noise reuse).
    pub pq: BTreeMap<String, PqMatrix>,
    /// Total squared reconstruction error across quantized params.
    pub sq_error: f64,
}

/// Apply a spec to every noised parameter.
pub fn quantize_params(
    params: &ParamStore,
    meta: &ModelMeta,
    spec: &QuantSpec,
    rng: &mut Pcg,
) -> Result<QuantizedModel> {
    quantize_params_with(params, meta, spec, rng)
}

/// [`quantize_params`] over any quantizer family — the extension point
/// a new scheme implements ([`QuantizerFactory`] + `Quantizer`) to get
/// the whole PTQ pipeline and storage accounting for free.
pub fn quantize_params_with(
    params: &ParamStore,
    meta: &ModelMeta,
    scheme: &dyn QuantizerFactory,
    rng: &mut Pcg,
) -> Result<QuantizedModel> {
    let mut store = ParamStore::new();
    let mut pq_map = BTreeMap::new();
    let mut sq_error = 0.0f64;

    for pm in &meta.params {
        let t = params
            .get(&pm.name)
            .ok_or_else(|| anyhow::anyhow!("missing param {}", pm.name))?;
        if !pm.noised {
            store.insert(&pm.name, t.clone());
            continue;
        }
        let (rows, cols) = pm.view.unwrap_or((1, t.numel()));
        let info = pm.to_param_info(None);
        let qt = scheme
            .for_param(&info)
            .fit(&t.data, rows, cols, rng)
            .map_err(|e| anyhow::anyhow!("{} ({}): {e}", pm.name, scheme.spec_string()))?;
        sq_error += t
            .data
            .iter()
            .zip(&qt.data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>();
        if let Some(m) = qt.pq {
            pq_map.insert(pm.name.clone(), m);
        }
        store.insert(&pm.name, crate::model::tensor::Tensor::from_vec(&pm.shape, qt.data));
    }

    let bytes = inventory_bytes(meta, scheme);
    Ok(QuantizedModel { store, bytes, pq: pq_map, sq_error })
}

/// Online re-encode entry point (DESIGN.md §9): fit fresh codebooks /
/// scales for `spec` on a pristine fp32 parameter set and return the
/// decoded weights plus storage accounting. This is what
/// `POST /v1/models/{id}/reencode` and `POST /v1/quantize` call before
/// atomically swapping the result into the serving registry.
///
/// Deterministic in `(params, meta, spec, seed)` — k-means inits and
/// any stochastic tie-breaks come only from the caller's `rng` — so a
/// re-encode can be reproduced offline bit-for-bit to audit what a
/// server is currently serving. Always fit on the *pristine* fp32
/// weights, never on previously decoded ones: re-encoding a decode is
/// generation loss.
pub fn reencode_params(
    params: &ParamStore,
    meta: &ModelMeta,
    spec: &QuantSpec,
    rng: &mut Pcg,
) -> Result<QuantizedModel> {
    quantize_params_with(params, meta, spec, rng)
}

/// Storage accounting for a spec over this model's inventory.
pub fn scheme_bytes(meta: &ModelMeta, spec: &QuantSpec) -> u64 {
    inventory_bytes(meta, spec)
}

fn inventory_bytes(meta: &ModelMeta, scheme: &dyn QuantizerFactory) -> u64 {
    model_bytes_with(&meta.param_infos(), scheme)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::config::ParamMeta;
    use crate::model::tensor::Tensor;
    use crate::quant::scheme::IntObserver;

    fn tiny_meta() -> ModelMeta {
        ModelMeta {
            name: "t".into(),
            task: "lm".into(),
            n_layers: 1,
            batch: 1,
            seq_len: 4,
            tokens_shape: vec![1, 4],
            targets_shape: vec![1, 4],
            vocab: 8,
            n_classes: 0,
            params: vec![
                ParamMeta {
                    name: "w".into(),
                    shape: vec![16, 32],
                    structure: "ffn".into(),
                    noised: true,
                    view: Some((16, 32)),
                    block_size: Some(8),
                },
                ParamMeta {
                    name: "ln".into(),
                    shape: vec![16],
                    structure: "norm".into(),
                    noised: false,
                    view: None,
                    block_size: None,
                },
            ],
            entries: vec![],
            init_file: String::new(),
        }
    }

    fn tiny_params() -> ParamStore {
        let mut rng = Pcg::new(3);
        let mut p = ParamStore::new();
        p.insert(
            "w",
            Tensor::from_vec(&[16, 32], (0..512).map(|_| rng.next_normal()).collect()),
        );
        p.insert("ln", Tensor::from_vec(&[16], vec![1.0; 16]));
        p
    }

    #[test]
    fn int8_roundtrip_close_and_norms_untouched() {
        let meta = tiny_meta();
        let params = tiny_params();
        let q = quantize_params(
            &params,
            &meta,
            &QuantSpec::int(8, IntObserver::MinMax),
            &mut Pcg::new(0),
        )
        .unwrap();
        assert_eq!(q.store.get("ln").unwrap(), params.get("ln").unwrap());
        let mse = q.store.get("w").unwrap().mse(params.get("w").unwrap());
        assert!(mse < 1e-3, "{mse}");
        assert!(q.sq_error > 0.0);
    }

    #[test]
    fn int4_worse_than_int8() {
        let meta = tiny_meta();
        let params = tiny_params();
        let q8 = quantize_params(
            &params,
            &meta,
            &QuantSpec::int(8, IntObserver::MinMax),
            &mut Pcg::new(0),
        )
        .unwrap();
        let q4 = quantize_params(
            &params,
            &meta,
            &QuantSpec::int(4, IntObserver::MinMax),
            &mut Pcg::new(0),
        )
        .unwrap();
        assert!(q4.sq_error > q8.sq_error);
        assert!(q4.bytes < q8.bytes);
    }

    #[test]
    fn pq_returns_codebooks_and_smaller_size() {
        let meta = tiny_meta();
        let params = tiny_params();
        let q = quantize_params(&params, &meta, &QuantSpec::pq(16), &mut Pcg::new(1)).unwrap();
        assert!(q.pq.contains_key("w"));
        assert!(!q.pq.contains_key("ln"));
        let fp = scheme_bytes(&meta, &QuantSpec::None);
        assert!(q.bytes < fp, "{} vs {fp}", q.bytes);
        // decoded store matches PqMatrix::decode
        assert_eq!(q.store.get("w").unwrap().data, q.pq["w"].decode());
    }

    #[test]
    fn int8_centroids_shrink_codebook() {
        let meta = tiny_meta();
        let params = tiny_params();
        let plain = quantize_params(&params, &meta, &QuantSpec::pq(16), &mut Pcg::new(2)).unwrap();
        let mut s = QuantSpec::pq(16);
        if let QuantSpec::Pq(p) = &mut s {
            p.codebook_bits = Some(8);
        }
        let combo = quantize_params(&params, &meta, &s, &mut Pcg::new(2)).unwrap();
        assert!(combo.bytes < plain.bytes);
        // slightly more error than plain PQ, but same order of magnitude
        assert!(combo.sq_error >= plain.sq_error);
        assert!(combo.sq_error < plain.sq_error * 2.0 + 1.0);
    }

    #[test]
    fn block_override_changes_size() {
        // needs a matrix large enough that the index term dominates the
        // codebook term (as in real models) for bigger blocks to win
        let mut meta = tiny_meta();
        meta.params[0].shape = vec![128, 128];
        meta.params[0].view = Some((128, 128));
        let mut rng = Pcg::new(9);
        let mut params = ParamStore::new();
        params.insert(
            "w",
            Tensor::from_vec(&[128, 128], (0..128 * 128).map(|_| rng.next_normal()).collect()),
        );
        params.insert("ln", Tensor::from_vec(&[16], vec![1.0; 16]));
        let mut s = QuantSpec::pq(4);
        if let QuantSpec::Pq(p) = &mut s {
            p.block_override.insert("ffn".into(), 16);
        }
        let big_blocks = quantize_params(&params, &meta, &s, &mut Pcg::new(3)).unwrap();
        let small = quantize_params(&params, &meta, &QuantSpec::pq(4), &mut Pcg::new(3)).unwrap();
        assert!(big_blocks.bytes < small.bytes, "{} vs {}", big_blocks.bytes, small.bytes);
        assert!(big_blocks.sq_error > small.sq_error);
    }

    #[test]
    fn reencode_is_deterministic_and_matches_quantize() {
        // the serving swap protocol depends on this: a re-encode with
        // the same (params, spec, seed) must reproduce served bits
        let meta = tiny_meta();
        let params = tiny_params();
        let a = reencode_params(&params, &meta, &QuantSpec::pq(16), &mut Pcg::new(7)).unwrap();
        let b = reencode_params(&params, &meta, &QuantSpec::pq(16), &mut Pcg::new(7)).unwrap();
        assert_eq!(a.store.get("w").unwrap().data, b.store.get("w").unwrap().data);
        assert_eq!((a.bytes, a.sq_error.to_bits()), (b.bytes, b.sq_error.to_bits()));
        let q = quantize_params(&params, &meta, &QuantSpec::pq(16), &mut Pcg::new(7)).unwrap();
        assert_eq!(a.store.get("w").unwrap().data, q.store.get("w").unwrap().data);
    }

    #[test]
    fn histogram_mode_runs() {
        let meta = tiny_meta();
        let params = tiny_params();
        let q = quantize_params(
            &params,
            &meta,
            &QuantSpec::int(4, IntObserver::Histogram),
            &mut Pcg::new(4),
        )
        .unwrap();
        assert!(q.sq_error.is_finite());
    }

    #[test]
    fn per_channel_beats_per_tensor_on_scaled_rows() {
        let meta = tiny_meta();
        let mut params = tiny_params();
        // scale half the rows ×50 so per-tensor quantization suffers
        {
            let w = params.get_mut("w").unwrap();
            for r in 0..8 {
                for c in 0..32 {
                    w.data[r * 32 + c] *= 50.0;
                }
            }
        }
        let pt = quantize_params(
            &params,
            &meta,
            &QuantSpec::int(4, IntObserver::MinMax),
            &mut Pcg::new(5),
        )
        .unwrap();
        let pc = quantize_params(
            &params,
            &meta,
            &QuantSpec::int(4, IntObserver::PerChannel),
            &mut Pcg::new(5),
        )
        .unwrap();
        assert!(pc.sq_error < pt.sq_error);
    }

    #[test]
    fn bad_block_size_is_a_user_error_not_a_panic() {
        let meta = tiny_meta();
        let params = tiny_params();
        let mut s = QuantSpec::pq(4);
        if let QuantSpec::Pq(p) = &mut s {
            p.block = Some(7); // 32 cols not divisible by 7
        }
        let e = quantize_params(&params, &meta, &s, &mut Pcg::new(6)).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains('w') && msg.contains("divisible"), "{msg}");
    }
}
