//! Optimizers and LR schedules (paper §7.6: Nesterov SGD with momentum
//! 0.99 + gradient-norm renormalization at 0.1 and a cosine schedule for
//! the LM; Adam with polynomial decay for RoBERTa-style training).

use crate::model::params::ParamStore;
use crate::model::tensor::Tensor;

#[derive(Debug, Clone, Copy)]
pub enum Schedule {
    Constant { lr: f32 },
    /// Linear warmup then cosine decay to `min_lr`.
    Cosine { lr: f32, min_lr: f32, warmup: usize, total: usize },
    /// Linear warmup then polynomial decay.
    Poly { lr: f32, warmup: usize, total: usize, power: f32 },
}

impl Schedule {
    pub fn lr(&self, step: usize) -> f32 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::Cosine { lr, min_lr, warmup, total } => {
                if step < warmup {
                    lr * (step + 1) as f32 / warmup.max(1) as f32
                } else {
                    let t = (step - warmup) as f32 / (total.saturating_sub(warmup)).max(1) as f32;
                    let t = t.min(1.0);
                    min_lr + 0.5 * (lr - min_lr) * (1.0 + (std::f32::consts::PI * t).cos())
                }
            }
            Schedule::Poly { lr, warmup, total, power } => {
                if step < warmup {
                    lr * (step + 1) as f32 / warmup.max(1) as f32
                } else {
                    let t = (step - warmup) as f32 / (total.saturating_sub(warmup)).max(1) as f32;
                    lr * (1.0 - t.min(1.0)).powf(power)
                }
            }
        }
    }
}

/// Renormalize gradients if the global norm exceeds `max_norm`
/// (Pascanu et al., the paper clips at 0.1). Returns the pre-clip norm.
pub fn clip_grad_norm(grads: &mut [Tensor], max_norm: f32) -> f32 {
    let norm = grads.iter().map(|g| g.sq_norm()).sum::<f64>().sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            g.scale(scale);
        }
    }
    norm
}

pub enum Optimizer {
    Sgd { momentum: f32, nesterov: bool, velocity: Vec<Tensor> },
    Adam { beta1: f32, beta2: f32, eps: f32, m: Vec<Tensor>, v: Vec<Tensor>, t: usize },
}

impl Optimizer {
    pub fn sgd(params: &ParamStore, momentum: f32, nesterov: bool) -> Optimizer {
        Optimizer::Sgd {
            momentum,
            nesterov,
            velocity: params.iter().map(|(_, t)| Tensor::zeros(&t.shape)).collect(),
        }
    }

    pub fn adam(params: &ParamStore) -> Optimizer {
        Optimizer::Adam {
            beta1: 0.9,
            beta2: 0.98,
            eps: 1e-8,
            m: params.iter().map(|(_, t)| Tensor::zeros(&t.shape)).collect(),
            v: params.iter().map(|(_, t)| Tensor::zeros(&t.shape)).collect(),
            t: 0,
        }
    }

    /// In-place parameter update. `grads` must be in param-store order.
    /// `frozen[i]` skips parameter i (used by the iPQ pipeline, which
    /// updates quantized layers through their codewords instead).
    // param lookups use names() keys and the grads length is asserted:
    // a miss is a caller bug, not an I/O condition
    #[allow(clippy::unwrap_used)]
    pub fn step(&mut self, params: &mut ParamStore, grads: &[Tensor], lr: f32, frozen: &[bool]) {
        let names: Vec<String> = params.names().to_vec();
        assert_eq!(names.len(), grads.len());
        match self {
            Optimizer::Sgd { momentum, nesterov, velocity } => {
                for (i, name) in names.iter().enumerate() {
                    if frozen[i] {
                        continue;
                    }
                    let g = &grads[i];
                    let vel = &mut velocity[i];
                    // v ← μ v − lr g ;  w ← w + v  (+ nesterov lookahead)
                    vel.scale(*momentum);
                    vel.axpy(-lr, g);
                    let p = params.get_mut(name).unwrap();
                    if *nesterov {
                        p.axpy(*momentum, vel);
                        p.axpy(-lr, g);
                    } else {
                        p.axpy(1.0, vel);
                    }
                }
            }
            Optimizer::Adam { beta1, beta2, eps, m, v, t } => {
                *t += 1;
                let bc1 = 1.0 - beta1.powi(*t as i32);
                let bc2 = 1.0 - beta2.powi(*t as i32);
                for (i, name) in names.iter().enumerate() {
                    if frozen[i] {
                        continue;
                    }
                    let g = &grads[i];
                    let mi = &mut m[i];
                    mi.scale(*beta1);
                    mi.axpy(1.0 - *beta1, g);
                    let vi = &mut v[i];
                    for (vj, &gj) in vi.data.iter_mut().zip(&g.data) {
                        *vj = *beta2 * *vj + (1.0 - *beta2) * gj * gj;
                    }
                    let p = params.get_mut(name).unwrap();
                    for ((pj, &mj), &vj) in p.data.iter_mut().zip(&mi.data).zip(&vi.data) {
                        let mhat = mj / bc1;
                        let vhat = vj / bc2;
                        *pj -= lr * mhat / (vhat.sqrt() + *eps);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn quad_params(x0: f32) -> ParamStore {
        let mut p = ParamStore::new();
        p.insert("x", Tensor::from_vec(&[2], vec![x0, -x0]));
        p
    }

    fn quad_grad(p: &ParamStore) -> Vec<Tensor> {
        // f = |x|²/2 ⇒ ∇f = x
        vec![p.get("x").unwrap().clone()]
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut p = quad_params(5.0);
        let mut opt = Optimizer::sgd(&p, 0.9, true);
        for _ in 0..200 {
            let g = quad_grad(&p);
            opt.step(&mut p, &g, 0.05, &[false]);
        }
        assert!(p.get("x").unwrap().max_abs() < 1e-2);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let mut p = quad_params(3.0);
        let mut opt = Optimizer::adam(&p);
        for _ in 0..500 {
            let g = quad_grad(&p);
            opt.step(&mut p, &g, 0.05, &[false]);
        }
        assert!(p.get("x").unwrap().max_abs() < 1e-2);
    }

    #[test]
    fn frozen_params_do_not_move() {
        let mut p = quad_params(2.0);
        let before = p.get("x").unwrap().clone();
        let mut opt = Optimizer::sgd(&p, 0.9, false);
        let g = quad_grad(&p);
        opt.step(&mut p, &g, 0.1, &[true]);
        assert_eq!(p.get("x").unwrap(), &before);
    }

    #[test]
    fn clip_rescales_large_gradients() {
        let mut g = vec![Tensor::from_vec(&[2], vec![3.0, 4.0])];
        let norm = clip_grad_norm(&mut g, 1.0);
        assert!((norm - 5.0).abs() < 1e-6);
        let after = g[0].sq_norm().sqrt();
        assert!((after - 1.0).abs() < 1e-5);
        // small grads untouched
        let mut g2 = vec![Tensor::from_vec(&[1], vec![0.05])];
        clip_grad_norm(&mut g2, 1.0);
        assert_eq!(g2[0].data[0], 0.05);
    }

    #[test]
    fn cosine_schedule_shape() {
        let s = Schedule::Cosine { lr: 1.0, min_lr: 0.1, warmup: 10, total: 110 };
        assert!(s.lr(0) < 0.2); // warmup start
        assert!((s.lr(9) - 1.0).abs() < 0.01); // warmup end
        assert!(s.lr(60) < 1.0 && s.lr(60) > 0.1); // mid-decay
        assert!((s.lr(109) - 0.1).abs() < 0.01); // end ≈ min
        assert!((s.lr(500) - 0.1).abs() < 0.01); // clamped after total
    }

    #[test]
    fn poly_schedule_shape() {
        let s = Schedule::Poly { lr: 1.0, warmup: 5, total: 105, power: 1.0 };
        assert!((s.lr(4) - 1.0).abs() < 0.01);
        assert!((s.lr(55) - 0.5).abs() < 0.02);
        assert!(s.lr(104) < 0.02);
    }

    #[test]
    fn sgd_momentum_accelerates() {
        // with momentum the first two steps move farther than without
        let run = |mom: f32| {
            let mut p = quad_params(1.0);
            let mut opt = Optimizer::sgd(&p, mom, false);
            for _ in 0..3 {
                let g = quad_grad(&p);
                opt.step(&mut p, &g, 0.1, &[false]);
            }
            1.0 - p.get("x").unwrap().data[0]
        };
        assert!(run(0.9) > run(0.0));
    }
}
