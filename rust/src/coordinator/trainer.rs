//! The Quant-Noise training loop (paper §4).
//!
//! Each step the coordinator samples a LayerDrop mask, refreshes the
//! quantized-image ("hat") tensors when the noise kind needs them
//! (exact φ_PQ: k-means once per refresh interval, per the paper once
//! per epoch), runs the AOT grad artifact, folds shared-layer
//! gradients, clips, applies the optimizer and re-uploads parameters.

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::coordinator::checkpoint::{self, Checkpoint, CheckpointConfig, OptState};
use crate::coordinator::optim::{clip_grad_norm, Optimizer, Schedule};
use crate::log_info;
use crate::util::fault;
use crate::util::hash::to_hex;
use crate::model::params::ParamStore;
use crate::model::tensor::Tensor;
use crate::quant::assign;
use crate::quant::prune::share_map;
use crate::quant::scheme::{HatKind, QuantSpec, Quantizer as _, SchemeError};
use crate::quant::size::ParamInfo;
use crate::runtime::executable::{BatchInput, ModelSession};
use crate::util::rng::Pcg;

/// One training batch (owned — the session borrows it per step).
#[derive(Debug, Clone)]
pub enum TrainBatch {
    Tokens { tokens: Vec<i32>, targets: Vec<i32> },
    Images { images: Vec<f32>, labels: Vec<i32> },
}

impl TrainBatch {
    pub fn input(&self) -> BatchInput<'_> {
        match self {
            TrainBatch::Tokens { tokens, .. } => BatchInput::Tokens(tokens),
            TrainBatch::Images { images, .. } => BatchInput::Images(images),
        }
    }
    pub fn targets(&self) -> &[i32] {
        match self {
            TrainBatch::Tokens { targets, .. } => targets,
            TrainBatch::Images { labels, .. } => labels,
        }
    }
}

pub trait BatchSource {
    fn next_batch(&mut self) -> TrainBatch;
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptKind {
    Sgd { momentum: f32, nesterov: bool },
    Adam,
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub schedule: Schedule,
    pub optimizer: OptKind,
    /// gradient-norm clip; 0 disables (paper uses 0.1 for the LM)
    pub clip: f32,
    /// the noise function φ (§4.2) — any [`QuantSpec`]; PQ specs carry
    /// their own K/iteration/block options
    pub noise: QuantSpec,
    pub noise_rate: f32,
    /// LayerDrop probability (paper: 0.2)
    pub layerdrop: f32,
    /// STE through LayerDrop (Table 11 ablation) — uses grad_mix_ldste
    pub ldste: bool,
    /// adjacent-layer weight sharing chunk size; 0/1 = off (§7.9)
    pub share_chunk: usize,
    /// steps between exact-PQ hat refreshes ("once per epoch")
    pub hat_refresh: usize,
    /// worker threads (0 ⇒ all available cores) for the hat refresh /
    /// assignment engine AND the interpreter backend's intra-op and
    /// batch sharding — one knob governs host + backend parallelism;
    /// every path is bit-deterministic at any thread count
    pub threads: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            schedule: Schedule::Cosine { lr: 0.05, min_lr: 1e-4, warmup: 30, total: 300 },
            optimizer: OptKind::Sgd { momentum: 0.9, nesterov: true },
            clip: 0.1,
            noise: QuantSpec::Proxy,
            noise_rate: 0.1,
            layerdrop: 0.0,
            ldste: false,
            share_chunk: 0,
            hat_refresh: 100,
            threads: 0,
            seed: 0,
            log_every: 50,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainStats {
    /// (step, loss) samples
    pub history: Vec<(usize, f32)>,
    pub final_loss: f32,
    pub steps: usize,
}

pub struct Trainer<'s, 'rt> {
    pub sess: &'s mut ModelSession<'rt>,
    pub params: ParamStore,
    opt: Optimizer,
    cfg: TrainConfig,
    rng: Pcg,
    /// param index → canonical param index under sharing (identity
    /// when sharing is off)
    share_idx: Vec<usize>,
    step: usize,
    /// hat tensors uploaded at the last refresh, by manifest param
    /// index — checkpointed so a resume between refresh boundaries
    /// replays bit-identically
    hats: Vec<(usize, Vec<f32>)>,
    /// batches drawn from the data source since step 0 (the cursor
    /// recorded in checkpoints)
    batches_drawn: usize,
    /// batches to draw-and-discard at the next `train_for` call to
    /// realign a fresh data source after a resume
    data_skip: usize,
    ckpt: Option<CheckpointConfig>,
}

impl<'s, 'rt> Trainer<'s, 'rt> {
    pub fn new(
        sess: &'s mut ModelSession<'rt>,
        params: ParamStore,
        cfg: TrainConfig,
    ) -> Trainer<'s, 'rt> {
        let opt = match cfg.optimizer {
            OptKind::Sgd { momentum, nesterov } => Optimizer::sgd(&params, momentum, nesterov),
            OptKind::Adam => Optimizer::adam(&params),
        };
        let share_idx = Self::build_share_idx(sess, &params, cfg.share_chunk);
        let rng = Pcg::new(cfg.seed ^ 0x7261_696e);
        // the same knob drives the backend's deterministic sharding
        sess.set_backend_threads(cfg.threads);
        Trainer {
            sess,
            params,
            opt,
            cfg,
            rng,
            share_idx,
            step: 0,
            hats: Vec::new(),
            batches_drawn: 0,
            data_skip: 0,
            ckpt: None,
        }
    }

    /// Enable periodic checkpointing to `dir` every `every` completed
    /// steps (0 = only a final checkpoint at the end of the run).
    pub fn set_checkpoint(&mut self, dir: impl Into<PathBuf>, every: usize) {
        self.ckpt = Some(CheckpointConfig { dir: dir.into(), every });
    }

    pub fn completed_steps(&self) -> usize {
        self.step
    }

    /// Map each per-layer param to its canonical (shared) sibling.
    // lookups use names sourced from params.names(): they cannot miss
    #[allow(clippy::unwrap_used)]
    fn build_share_idx(sess: &ModelSession, params: &ParamStore, chunk: usize) -> Vec<usize> {
        let n_layers = sess.meta.n_layers;
        let names = params.names();
        let mut idx: Vec<usize> = (0..names.len()).collect();
        if chunk <= 1 {
            return idx;
        }
        let map = share_map(n_layers, chunk);
        for (i, name) in names.iter().enumerate() {
            for l in 0..n_layers {
                for prefix in ["layer", "block"] {
                    let p = format!("{prefix}{l:02}.");
                    if let Some(suffix) = name.strip_prefix(&p) {
                        if map[l] != l {
                            let canon = format!("{prefix}{:02}.{suffix}", map[l]);
                            if let Some(j) = names.iter().position(|n| n == &canon) {
                                // only alias when shapes agree (conv
                                // blocks can change width across layers)
                                if params.get(&canon).unwrap().shape
                                    == params.get(name).unwrap().shape
                                {
                                    idx[i] = j;
                                }
                            }
                        }
                    }
                }
            }
        }
        idx
    }

    /// Copy canonical params onto their shared siblings (host side).
    // lookups use names sourced from params.names(): they cannot miss
    #[allow(clippy::unwrap_used)]
    fn sync_shared(&mut self) {
        let names: Vec<String> = self.params.names().to_vec();
        for (i, &ci) in self.share_idx.iter().enumerate() {
            if ci != i {
                let canon = self.params.get(&names[ci]).unwrap().clone();
                *self.params.get_mut(&names[i]).unwrap() = canon;
            }
        }
    }

    /// Fold shared-sibling grads into the canonical grad, zero siblings.
    fn fold_shared_grads(&self, grads: &mut [Tensor]) {
        for (i, &ci) in self.share_idx.iter().enumerate() {
            if ci != i {
                let shape = grads[i].shape.clone();
                let sib = std::mem::replace(&mut grads[i], Tensor::zeros(&shape));
                grads[ci].axpy(1.0, &sib);
            }
        }
    }

    fn grad_entry(&self) -> Result<&'static str> {
        if self.cfg.ldste && self.sess.has_entry("grad_mix_ldste") {
            return Ok("grad_mix_ldste");
        }
        Ok(self.cfg.noise.grad_entry()?)
    }

    /// Sample this step's LayerDrop keep mask (chunks drop together
    /// when sharing is on, matching §7.6's chunk-level LayerDrop).
    fn sample_keep(&mut self) -> Vec<f32> {
        let n = self.sess.meta.n_layers;
        if self.cfg.layerdrop <= 0.0 {
            return vec![1.0; n];
        }
        let chunk = self.cfg.share_chunk.max(1);
        let map = share_map(n, chunk);
        // dense per-chunk memo (not a HashMap): layers visit in
        // ascending order, so each chunk's first layer draws its keep
        // bit — RNG consumption order is the layer order by definition
        let mut chunk_keep: Vec<Option<f32>> = vec![None; n];
        (0..n)
            .map(|l| {
                *chunk_keep[map[l]].get_or_insert_with(|| {
                    if self.rng.next_f32() < self.cfg.layerdrop {
                        0.0
                    } else {
                        1.0
                    }
                })
            })
            .collect()
    }

    /// Refresh hat tensors for the mix-noise family.
    ///
    /// Weight matrices are sharded across scoped workers so the per-
    /// epoch exact-φ_PQ re-quantization scales with cores twice over:
    /// across matrices here, and across subvectors inside each k-means
    /// via the shared assignment engine. Every matrix draws its own RNG
    /// stream split from the trainer RNG in manifest order, so the
    /// result is deterministic and independent of scheduling.
    // the param lookup keys come from the manifest the store was
    // checked against, and scoped-thread joins only fail on a worker
    // panic (which should propagate)
    #[allow(clippy::unwrap_used)]
    pub fn refresh_hats(&mut self) -> Result<()> {
        if !self.cfg.noise.needs_hat() {
            return Ok(()); // zero hats uploaded at session creation
        }
        struct HatJob {
            idx: usize,
            info: ParamInfo,
            rng: Pcg,
        }
        impl HatJob {
            fn work(&self) -> usize {
                self.info.rows * self.info.cols
            }
        }
        let needs_rng = matches!(self.cfg.noise, QuantSpec::Pq(_));
        let mut jobs = Vec::new();
        for (i, pm) in self.sess.meta.params.iter().enumerate() {
            if !pm.noised {
                continue;
            }
            // mean-sub hats are RNG-free: don't burn trainer stream draws
            let rng = if needs_rng { self.rng.split(i as u64) } else { Pcg::new(0) };
            jobs.push(HatJob { idx: i, info: pm.to_param_info(None), rng });
        }
        if jobs.is_empty() {
            return Ok(());
        }
        let noise = self.cfg.noise.clone();
        let total = assign::resolve_threads(self.cfg.threads);
        let outer = total.clamp(1, jobs.len());
        // Largest-first order groups similarly-sized matrices into the
        // same wave so no worker idles at the join barrier behind one
        // dominant matrix (ties keep manifest order; uploads are keyed
        // by idx, and the per-matrix RNG streams were already split
        // above, so scheduling order cannot change results).
        jobs.sort_by_key(|j| std::cmp::Reverse(j.work()));
        // Waves of `outer` matrices: each wave computes in parallel (one
        // worker per matrix) and uploads before the next wave starts, so
        // peak extra memory is bounded by `outer` hats — not a full copy
        // of every noised weight at once.
        let mut new_hats: Vec<(usize, Vec<f32>)> = Vec::with_capacity(jobs.len());
        for wave in jobs.chunks_mut(outer) {
            // Give each matrix inner k-means threads proportional to its
            // share of the wave's work: a skewed wave hands the dominant
            // matrix most of the machine instead of pinning it to one
            // core while finished workers idle (engine codes are
            // thread-count-invariant, so this cannot change results).
            let wave_work: usize = wave.iter().map(|j| j.work()).sum();
            let wave_len = wave.len();
            let wave_hats: Vec<Result<(usize, Vec<f32>), SchemeError>> = {
                let params = &self.params;
                let metas = &self.sess.meta.params;
                let noise = &noise;
                // allocate inner threads from a shared budget (largest
                // job first) so Σinner ≤ total — proportional rounding
                // alone can oversubscribe the machine
                let mut budget = total;
                let mut work_left = wave_work;
                std::thread::scope(|s| {
                    let handles: Vec<_> = wave
                        .iter_mut()
                        .enumerate()
                        .map(|(pos, job)| {
                            let work = job.work();
                            let after = wave_len - 1 - pos;
                            let cap = budget.saturating_sub(after).max(1);
                            let prop = (budget as f64 * work as f64
                                / work_left.max(1) as f64)
                                .round() as usize;
                            let inner = prop.clamp(1, cap);
                            budget = budget.saturating_sub(inner);
                            work_left = work_left.saturating_sub(work);
                            s.spawn(move || {
                                let w = &params.get(&metas[job.idx].name).unwrap().data;
                                // PQ hats refit with a short k-means whose
                                // final assignments come from the same
                                // engine kernel pq::encode uses, so the
                                // decoded hat is bit-identical to a
                                // re-encode — minus the redundant
                                // O(n·K·d) pass.
                                let q = noise.clone().with_threads(inner).resolve(&job.info);
                                match q.hat(w, job.info.rows, job.info.cols, &mut job.rng)? {
                                    HatKind::Host(hat) => Ok((job.idx, hat)),
                                    HatKind::InGraph { entry } => Err(SchemeError::InGraphOnly {
                                        scheme: noise.to_string(),
                                        entry,
                                    }),
                                }
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                })
            };
            for r in wave_hats {
                let (i, hat) = r?;
                self.sess.upload_hat(i, &hat)?;
                new_hats.push((i, hat));
            }
        }
        new_hats.sort_by_key(|(i, _)| *i);
        self.hats = new_hats;
        Ok(())
    }

    /// One training step; returns the loss.
    pub fn step_once(&mut self, batch: &TrainBatch) -> Result<f32> {
        if self.cfg.noise.needs_hat()
            && self.step % self.cfg.hat_refresh.max(1) == 0
        {
            self.refresh_hats()?;
        }
        let keep = self.sample_keep();
        let rate = if matches!(self.cfg.noise, QuantSpec::None) {
            0.0
        } else {
            self.cfg.noise_rate
        };
        let seed = (self.rng.next_u32() & 0x7fff_ffff) as i32;
        let entry = self.grad_entry()?;
        let (loss, mut grads) =
            self.sess
                .grad(entry, &batch.input(), batch.targets(), &keep, rate, seed)?;
        self.fold_shared_grads(&mut grads);
        if self.cfg.clip > 0.0 {
            clip_grad_norm(&mut grads, self.cfg.clip);
        }
        let lr = self.cfg.schedule.lr(self.step);
        let frozen = vec![false; grads.len()];
        self.opt.step(&mut self.params, &grads, lr, &frozen);
        self.sync_shared();
        self.sess.upload_all_params(&self.params)?;
        self.step += 1;
        Ok(loss)
    }

    /// Snapshot complete trainer state at the current step boundary.
    pub fn to_checkpoint(&self) -> Checkpoint {
        let opt = match &self.opt {
            Optimizer::Sgd { velocity, .. } => OptState::Sgd { velocity: velocity.clone() },
            Optimizer::Adam { m, v, t, .. } => {
                OptState::Adam { m: m.clone(), v: v.clone(), t: *t }
            }
        };
        Checkpoint {
            model: self.sess.meta.name.clone(),
            step: self.step,
            batches: self.batches_drawn,
            rng: self.rng.state_parts(),
            cfg_digest: checkpoint::cfg_digest(&self.sess.meta.name, &self.cfg),
            params: self.params.clone(),
            opt,
            hats: self.hats.clone(),
        }
    }

    /// Restore trainer state from a checkpoint. The next `train_for`
    /// call continues at `ck.step` and replays bit-identically to the
    /// uninterrupted run at any `threads`. Refuses a checkpoint whose
    /// model or config digest differs from this trainer's.
    pub fn resume_from(&mut self, ck: Checkpoint) -> Result<()> {
        let model = self.sess.meta.name.clone();
        if ck.model != model {
            bail!("checkpoint is for model '{}', trainer is '{model}'", ck.model);
        }
        let want = checkpoint::cfg_digest(&model, &self.cfg);
        if ck.cfg_digest != want {
            bail!(
                "checkpoint config digest {} != current {} — resume requires an \
                 identical training configuration (threads/log_every may differ)",
                to_hex(ck.cfg_digest),
                to_hex(want)
            );
        }
        ck.params.check_against(&self.sess.meta)?;
        let n = ck.params.len();
        self.opt = match (self.cfg.optimizer, ck.opt) {
            (OptKind::Sgd { momentum, nesterov }, OptState::Sgd { velocity }) => {
                if velocity.len() != n {
                    bail!("checkpoint has {} velocity slots for {n} params", velocity.len());
                }
                Optimizer::Sgd { momentum, nesterov, velocity }
            }
            (OptKind::Adam, OptState::Adam { m, v, t }) => {
                if m.len() != n || v.len() != n {
                    bail!("checkpoint has {}/{} adam slots for {n} params", m.len(), v.len());
                }
                // constants must mirror Optimizer::adam
                Optimizer::Adam { beta1: 0.9, beta2: 0.98, eps: 1e-8, m, v, t }
            }
            _ => bail!("checkpoint optimizer kind does not match the configured optimizer"),
        };
        self.params = ck.params;
        self.rng = Pcg::from_parts(ck.rng.0, ck.rng.1);
        self.step = ck.step;
        self.batches_drawn = ck.batches;
        self.data_skip = ck.batches;
        self.hats = ck.hats;
        log_info!(
            "resume[{model}] at step {}/{} ({} batches consumed)",
            self.step,
            self.cfg.steps,
            self.batches_drawn
        );
        Ok(())
    }

    /// Full training run.
    pub fn train(&mut self, data: &mut dyn BatchSource) -> Result<TrainStats> {
        self.train_for(data, usize::MAX)
    }

    /// Run at most `limit` further steps (stopping at `cfg.steps`).
    ///
    /// Taking a limit instead of mutating `cfg.steps` keeps the LR
    /// schedule — whose shape depends on the *total* step count —
    /// bit-identical between an interrupted run and the full run, which
    /// is what makes kill-at-step-k resume tests meaningful.
    pub fn train_for(&mut self, data: &mut dyn BatchSource, limit: usize) -> Result<TrainStats> {
        self.sync_shared();
        self.sess.upload_all_params(&self.params)?;
        // re-arm the session's hat tensors after a resume: uploads are
        // device state, not part of the params — without this, steps
        // between resume and the next refresh would see zero hats
        for (i, hat) in &self.hats {
            self.sess.upload_hat(*i, hat)?;
        }
        // realign a fresh data source to the checkpointed cursor
        for _ in 0..self.data_skip {
            let _ = data.next_batch();
        }
        self.data_skip = 0;
        let mut history = Vec::new();
        let mut last = f32::NAN;
        let mut done = 0usize;
        while self.step < self.cfg.steps && done < limit {
            let s = self.step;
            let batch = data.next_batch();
            self.batches_drawn += 1;
            last = self.step_once(&batch)?;
            done += 1;
            if let Some(ck) = self.ckpt.clone() {
                if ck.every > 0 && self.step % ck.every == 0 && self.step < self.cfg.steps {
                    checkpoint::save_checkpoint(&ck.dir, &self.to_checkpoint())?;
                }
            }
            // the kill-at-step fault point sits *after* the periodic
            // save so an injected crash exercises resume, not the
            // (separately-faulted) save protocol
            fault::check("train.step")?;
            if s % self.cfg.log_every.max(1) == 0 || self.step == self.cfg.steps {
                history.push((s, last));
                log_info!(
                    "train[{}] step {s}/{} loss {last:.4} (noise {} rate {})",
                    self.sess.meta.name,
                    self.cfg.steps,
                    self.cfg.noise,
                    self.cfg.noise_rate
                );
            }
        }
        if self.step >= self.cfg.steps {
            if let Some(ck) = self.ckpt.clone() {
                checkpoint::save_checkpoint(&ck.dir, &self.to_checkpoint())?;
            }
        }
        Ok(TrainStats { history, final_loss: last, steps: self.step })
    }

    pub fn into_params(self) -> ParamStore {
        self.params
    }

    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }
}

// ------------------------------------------------- batch source impls ---

pub struct LmSource {
    pub batcher: crate::data::batcher::LmBatcher,
}

impl BatchSource for LmSource {
    fn next_batch(&mut self) -> TrainBatch {
        let b = self.batcher.next();
        TrainBatch::Tokens { tokens: b.tokens, targets: b.targets }
    }
}

pub struct ClsSource {
    pub batcher: crate::data::batcher::EpochBatcher<i32>,
}

impl BatchSource for ClsSource {
    fn next_batch(&mut self) -> TrainBatch {
        let (tokens, labels) = self.batcher.next();
        TrainBatch::Tokens { tokens, targets: labels }
    }
}

pub struct ImgSource {
    pub batcher: crate::data::batcher::EpochBatcher<f32>,
}

impl BatchSource for ImgSource {
    fn next_batch(&mut self) -> TrainBatch {
        let (images, labels) = self.batcher.next();
        TrainBatch::Images { images, labels }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn train_batch_accessors() {
        let b = TrainBatch::Tokens { tokens: vec![1, 2], targets: vec![2, 3] };
        assert_eq!(b.targets(), &[2, 3]);
        match b.input() {
            BatchInput::Tokens(t) => assert_eq!(t, &[1, 2]),
            _ => panic!(),
        }
    }

    #[test]
    fn default_config_sane() {
        let c = TrainConfig::default();
        assert!(c.steps > 0 && c.noise_rate > 0.0);
        assert_eq!(c.noise, QuantSpec::Proxy);
    }
}
