//! The paper's pipelines: Quant-Noise training loop, post-training
//! quantization, iPQ with Eq. (4) codeword finetuning, and evaluation.
pub mod evaluator;
pub mod ipq;
pub mod optim;
pub mod quantize;
pub mod trainer;
