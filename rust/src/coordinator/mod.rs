//! The paper's pipelines: Quant-Noise training loop, post-training
//! quantization, iPQ with Eq. (4) codeword finetuning, and evaluation.
//!
//! This tree is crash-path code (checkpointing, resume, long training
//! runs): bare `unwrap()`/`expect()` are denied module-wide so every
//! panic site is either removed or carries a justified `#[allow]`
//! stating the invariant that makes it unreachable.
#![deny(clippy::unwrap_used, clippy::expect_used)]
pub mod checkpoint;
pub mod evaluator;
pub mod ipq;
pub mod optim;
pub mod quantize;
pub mod trainer;
