//! Evaluation: perplexity (LM) and accuracy (CLS/IMG) over a fixed set
//! of eval batches, for fp32 or quantized weights, optionally through
//! the int8-activation artifact (§3.3).

use anyhow::Result;

use crate::coordinator::trainer::TrainBatch;
use crate::model::params::ParamStore;
use crate::runtime::executable::ModelSession;

#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub nll: f64,
    pub ppl: f64,
    pub accuracy: f64,
    pub n: usize,
}

/// Evaluate over `batches` via `entry` ("eval" or "eval_int8act") with
/// the weights currently uploaded to the session.
pub fn evaluate(
    sess: &mut ModelSession,
    entry: &str,
    batches: &[TrainBatch],
    layer_keep: &[f32],
) -> Result<EvalResult> {
    anyhow::ensure!(!batches.is_empty(), "no eval batches");
    let denom = sess.meta.eval_denominator();
    let mut sum_nll = 0.0;
    let mut sum_correct = 0.0;
    for b in batches {
        let (nll, correct) = sess.eval(entry, &b.input(), b.targets(), layer_keep)?;
        sum_nll += nll;
        sum_correct += correct;
    }
    let n = denom * batches.len();
    let nll = sum_nll / n as f64;
    Ok(EvalResult { nll, ppl: nll.exp(), accuracy: sum_correct / n as f64, n })
}

/// Evaluate a specific weight set (uploads, evaluates, restores).
pub fn evaluate_params(
    sess: &mut ModelSession,
    params: &ParamStore,
    restore: &ParamStore,
    entry: &str,
    batches: &[TrainBatch],
    layer_keep: &[f32],
) -> Result<EvalResult> {
    sess.upload_all_params(params)?;
    let r = evaluate(sess, entry, batches, layer_keep);
    sess.upload_all_params(restore)?;
    r
}

/// Build a deterministic eval batch set for an LM token stream
/// (held-out tail of the corpus).
pub fn lm_eval_batches(
    tokens: &[i32],
    batch: usize,
    seq_len: usize,
    n_batches: usize,
) -> Vec<TrainBatch> {
    let mut b = crate::data::batcher::LmBatcher::new(tokens, batch, seq_len);
    let n = n_batches.min(b.batches_per_epoch());
    (0..n)
        .map(|_| {
            let lb = b.next();
            TrainBatch::Tokens { tokens: lb.tokens, targets: lb.targets }
        })
        .collect()
}

/// Deterministic eval batches from an example/label set.
pub fn cls_eval_batches(
    batcher: &crate::data::batcher::EpochBatcher<i32>,
    n_batches: usize,
) -> Vec<TrainBatch> {
    (0..n_batches.min(batcher.batches_per_epoch()))
        .map(|i| {
            let (tokens, labels) = batcher.eval_batch(i);
            TrainBatch::Tokens { tokens, targets: labels }
        })
        .collect()
}

pub fn img_eval_batches(
    batcher: &crate::data::batcher::EpochBatcher<f32>,
    n_batches: usize,
) -> Vec<TrainBatch> {
    (0..n_batches.min(batcher.batches_per_epoch()))
        .map(|i| {
            let (images, labels) = batcher.eval_batch(i);
            TrainBatch::Images { images, labels }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_eval_batches_deterministic_and_sized() {
        let tokens: Vec<i32> = (0..2000).map(|i| i % 50).collect();
        let a = lm_eval_batches(&tokens, 4, 16, 5);
        let b = lm_eval_batches(&tokens, 4, 16, 5);
        assert_eq!(a.len(), 5);
        match (&a[0], &b[0]) {
            (TrainBatch::Tokens { tokens: t1, .. }, TrainBatch::Tokens { tokens: t2, .. }) => {
                assert_eq!(t1, t2)
            }
            _ => panic!(),
        }
    }

    #[test]
    fn lm_eval_batches_capped_by_epoch() {
        let tokens: Vec<i32> = (0..500).map(|i| i % 10).collect();
        let b = lm_eval_batches(&tokens, 2, 16, 1000);
        assert_eq!(b.len(), (250 - 1) / 16);
    }
}
