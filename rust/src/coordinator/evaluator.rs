//! Evaluation: perplexity (LM) and accuracy (CLS/IMG) over a fixed set
//! of eval batches, for fp32 or quantized weights, optionally through
//! the int8-activation artifact (§3.3).

use anyhow::Result;

use crate::coordinator::trainer::TrainBatch;
use crate::model::params::ParamStore;
use crate::runtime::executable::{BatchInput, ModelSession};

#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub nll: f64,
    pub ppl: f64,
    pub accuracy: f64,
    pub n: usize,
}

/// Evaluate over `batches` via `entry` ("eval" or "eval_int8act") with
/// the weights currently uploaded to the session.
///
/// The batches are concatenated into one macro-batch and executed
/// through the backend's deterministic batch sharding
/// ([`ModelSession::eval_batched`]); the per-batch sums are folded in
/// ascending batch order, so the result is bit-identical to the old
/// sequential loop at every thread count (DESIGN.md §4).
pub fn evaluate(
    sess: &mut ModelSession,
    entry: &str,
    batches: &[TrainBatch],
    layer_keep: &[f32],
) -> Result<EvalResult> {
    anyhow::ensure!(!batches.is_empty(), "no eval batches");
    let denom = sess.meta.eval_denominator();
    // with one batch or one worker the macro-batch buys nothing: skip
    // its concatenation/slicing copies and run the plain per-batch loop
    // (bit-identical either way)
    if batches.len() == 1 || sess.backend_threads() <= 1 {
        let sums = batches
            .iter()
            .map(|b| sess.eval(entry, &b.input(), b.targets(), layer_keep))
            .collect::<Result<Vec<_>>>()?;
        return Ok(fold_sums(&sums, denom));
    }
    let all_tokens = batches.iter().all(|b| matches!(b, TrainBatch::Tokens { .. }));
    let all_images = batches.iter().all(|b| matches!(b, TrainBatch::Images { .. }));
    anyhow::ensure!(all_tokens || all_images, "mixed eval batch kinds");
    // validate each batch BEFORE concatenation: an irregular batch must
    // error (as the sequential path's uploads would), not be mis-sliced
    // at macro-batch boundaries
    let per_input: usize = sess.meta.tokens_shape.iter().product();
    let per_target: usize = sess.meta.targets_shape.iter().product();
    for (i, b) in batches.iter().enumerate() {
        let len = match b {
            TrainBatch::Tokens { tokens, .. } => tokens.len(),
            TrainBatch::Images { images, .. } => images.len(),
        };
        anyhow::ensure!(
            len == per_input && b.targets().len() == per_target,
            "eval batch {i}: {len} inputs / {} targets, expected {per_input} / {per_target}",
            b.targets().len()
        );
    }
    let macro_targets: Vec<i32> =
        batches.iter().flat_map(|b| b.targets().iter().copied()).collect();
    let sums = if all_tokens {
        let macro_tokens: Vec<i32> = batches
            .iter()
            .flat_map(|b| match b {
                TrainBatch::Tokens { tokens, .. } => tokens.iter().copied(),
                TrainBatch::Images { .. } => unreachable!(),
            })
            .collect();
        sess.eval_batched(entry, &BatchInput::Tokens(&macro_tokens), &macro_targets, layer_keep)?
    } else {
        let macro_images: Vec<f32> = batches
            .iter()
            .flat_map(|b| match b {
                TrainBatch::Images { images, .. } => images.iter().copied(),
                TrainBatch::Tokens { .. } => unreachable!(),
            })
            .collect();
        sess.eval_batched(entry, &BatchInput::Images(&macro_images), &macro_targets, layer_keep)?
    };
    Ok(fold_sums(&sums, denom))
}

/// Fold per-batch `(sum_nll, sum_correct)` pairs in batch order into an
/// [`EvalResult`] — one tail shared by the sequential and macro-batch
/// paths so their arithmetic can never diverge.
fn fold_sums(sums: &[(f64, f64)], denom: usize) -> EvalResult {
    let mut sum_nll = 0.0;
    let mut sum_correct = 0.0;
    for &(nll, correct) in sums {
        sum_nll += nll;
        sum_correct += correct;
    }
    let n = denom * sums.len();
    let nll = sum_nll / n as f64;
    EvalResult { nll, ppl: nll.exp(), accuracy: sum_correct / n as f64, n }
}

/// Evaluate a specific weight set (uploads, evaluates, restores).
pub fn evaluate_params(
    sess: &mut ModelSession,
    params: &ParamStore,
    restore: &ParamStore,
    entry: &str,
    batches: &[TrainBatch],
    layer_keep: &[f32],
) -> Result<EvalResult> {
    sess.upload_all_params(params)?;
    let r = evaluate(sess, entry, batches, layer_keep);
    sess.upload_all_params(restore)?;
    r
}

/// Build a deterministic eval batch set for an LM token stream
/// (held-out tail of the corpus).
pub fn lm_eval_batches(
    tokens: &[i32],
    batch: usize,
    seq_len: usize,
    n_batches: usize,
) -> Vec<TrainBatch> {
    let mut b = crate::data::batcher::LmBatcher::new(tokens, batch, seq_len);
    let n = n_batches.min(b.batches_per_epoch());
    (0..n)
        .map(|_| {
            let lb = b.next();
            TrainBatch::Tokens { tokens: lb.tokens, targets: lb.targets }
        })
        .collect()
}

/// Deterministic eval batches from an example/label set.
pub fn cls_eval_batches(
    batcher: &crate::data::batcher::EpochBatcher<i32>,
    n_batches: usize,
) -> Vec<TrainBatch> {
    (0..n_batches.min(batcher.batches_per_epoch()))
        .map(|i| {
            let (tokens, labels) = batcher.eval_batch(i);
            TrainBatch::Tokens { tokens, targets: labels }
        })
        .collect()
}

pub fn img_eval_batches(
    batcher: &crate::data::batcher::EpochBatcher<f32>,
    n_batches: usize,
) -> Vec<TrainBatch> {
    (0..n_batches.min(batcher.batches_per_epoch()))
        .map(|i| {
            let (images, labels) = batcher.eval_batch(i);
            TrainBatch::Images { images, labels }
        })
        .collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn lm_eval_batches_deterministic_and_sized() {
        let tokens: Vec<i32> = (0..2000).map(|i| i % 50).collect();
        let a = lm_eval_batches(&tokens, 4, 16, 5);
        let b = lm_eval_batches(&tokens, 4, 16, 5);
        assert_eq!(a.len(), 5);
        match (&a[0], &b[0]) {
            (TrainBatch::Tokens { tokens: t1, .. }, TrainBatch::Tokens { tokens: t2, .. }) => {
                assert_eq!(t1, t2)
            }
            _ => panic!(),
        }
    }

    #[test]
    fn lm_eval_batches_capped_by_epoch() {
        let tokens: Vec<i32> = (0..500).map(|i| i % 10).collect();
        let b = lm_eval_batches(&tokens, 2, 16, 1000);
        assert_eq!(b.len(), (250 - 1) / 16);
    }
}
