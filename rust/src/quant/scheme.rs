//! The unified quantization-scheme API: one [`QuantSpec`] description
//! and one [`Quantizer`] trait for every quantization operator φ the
//! paper applies — as partial noise during training (§4.2) and as the
//! real compressor afterwards (§3).
//!
//! Before this module the same set of schemes was enumerated three
//! times (PTQ `WeightScheme`, training `NoiseKind`, size accounting
//! `size::Scheme`) with hand-kept sync. Now every consumer — the
//! post-training quantizer, the trainer's hat refresh, the storage
//! accounting, the CLI — resolves a [`QuantSpec`] (or any other
//! [`QuantizerFactory`]) into per-parameter [`Quantizer`] objects, so a
//! new scheme is one new implementation of the trait, registered in
//! exactly one place.
//!
//! Canonical string forms (round-trip via [`QuantSpec::parse`] /
//! `Display`):
//!
//! | spec                    | paper      | meaning                                   |
//! |-------------------------|------------|-------------------------------------------|
//! | `none`                  | —          | fp32 passthrough                          |
//! | `proxy`                 | §4.2       | φ_proxy zero-out noise (in grad_mix)      |
//! | `mean_sub`              | §4.2/T5    | blockwise-mean intermediate approximation |
//! | `int8` / `int4`         | §3.1       | intN per-tensor MinMax                    |
//! | `int8:histogram`        | §7.7       | intN with histogram-clipped range (PTQ)   |
//! | `int8:per_channel`      | Table 10   | intN with per-row scale/zero              |
//! | `pq:k=256,d=8`          | §3.2       | Product Quantization, K codewords, d-dim  |
//! | `pq:k=256,d=8,cb=int8`  | §3.3/Eq. 5 | iPQ ⊕ int8 codebook combination           |
//! | `pq:k=256,d=8,cb=int4`  | §3.3 ext.  | iPQ ⊕ int4 codebook (8× smaller than fp32)|
//!
//! `pq` options: `k=` codebook size, `d=`/`block=` global subvector
//! length (defaults to each parameter's manifest block size),
//! `iters=` k-means iterations (default 12), `cb=int8|int4|fp32`
//! codebook storage, `threads=` workers (0 ⇒ all cores), `block.<structure>=`
//! per-structure block override (Fig. 6b). `exact_pq` — and a bare `pq`
//! with no options, matching the old `--noise pq` — are legacy aliases
//! for the trainer's φ_PQ noise defaults (`pq:k=64,iters=6`).
//!
//! Every canonical string above round-trips (this runs as a doctest,
//! so the table cannot rot):
//!
//! ```
//! use quant_noise::quant::scheme::QuantSpec;
//! for s in ["none", "proxy", "mean_sub", "int8", "int4",
//!           "int8:histogram", "int8:per_channel",
//!           "pq:k=256,d=8", "pq:k=256,d=8,cb=int8",
//!           "pq:k=256,d=8,cb=int4"] {
//!     assert_eq!(QuantSpec::parse(s)?.to_string(), s, "{s} must round-trip");
//! }
//! # Ok::<(), quant_noise::quant::scheme::SchemeError>(())
//! ```

use std::collections::BTreeMap;
use std::fmt;

use crate::quant::observer::HistogramObserver;
use crate::quant::pq::{self, PqConfig, PqMatrix};
use crate::quant::scalar;
use crate::quant::size::ParamInfo;
use crate::util::rng::Pcg;

// ---------------------------------------------------------- errors ---

/// Typed error for spec parsing and quantizer operations — the
/// `build_hat` panic paths of the old `NoiseKind` API surface here
/// instead, and the `qn` CLI prints them as user errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SchemeError {
    /// A spec string did not parse.
    Parse { spec: String, reason: String },
    /// Matrix shape incompatible with the scheme's subvector length.
    BlockMismatch { cols: usize, block: usize },
    /// A host hat was requested for a scheme whose noise runs inside
    /// the grad artifact.
    InGraphOnly { scheme: String, entry: &'static str },
    /// The scheme has no in-graph grad entry (post-training only).
    NoGradEntry { scheme: String },
    /// `decode_into` was handed a tensor without the state it needs.
    MissingState { scheme: String },
}

impl fmt::Display for SchemeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SchemeError::Parse { spec, reason } => {
                write!(f, "bad scheme spec '{spec}': {reason}")
            }
            SchemeError::BlockMismatch { cols, block } => {
                write!(f, "cols {cols} not divisible by PQ block {block}")
            }
            SchemeError::InGraphOnly { scheme, entry } => {
                write!(
                    f,
                    "{scheme} noise is computed in-graph (entry {entry}); it has no host-side hat"
                )
            }
            SchemeError::NoGradEntry { scheme } => {
                write!(f, "{scheme} has no in-graph grad entry (post-training quantization only)")
            }
            SchemeError::MissingState { scheme } => {
                write!(f, "{scheme}: quantized tensor carries no codebook state to decode")
            }
        }
    }
}

impl std::error::Error for SchemeError {}

// ------------------------------------------------------------ spec ---

/// Range observer / calibration mode for scalar intN quantization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntObserver {
    /// Per-tensor min/max range (the in-graph fake-quant convention).
    MinMax,
    /// Histogram-searched clip range (§7.7); PTQ only — no grad entry.
    Histogram,
    /// One scale/zero per output row (Table 10's "Quant Channel").
    PerChannel,
}

/// Options of a Product-Quantization scheme (§3.2, §3.3).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PqSpec {
    /// Codebook size K (256 ⇒ int8 indices).
    pub k: usize,
    /// Global subvector length d; `None` ⇒ each parameter's manifest
    /// block size.
    pub block: Option<usize>,
    pub kmeans_iters: usize,
    /// §3.3: store the codebook intN-quantized (`Some(8)` is Eq. 5's
    /// 8·K·d term; `Some(4)` halves it again; `None` keeps fp32).
    pub codebook_bits: Option<u8>,
    /// Per-structure block override (Fig. 6b).
    pub block_override: BTreeMap<String, usize>,
    /// k-means/encode worker threads (0 ⇒ all cores).
    pub threads: usize,
}

impl Default for PqSpec {
    fn default() -> Self {
        PqSpec {
            k: 256,
            block: None,
            kmeans_iters: 12,
            codebook_bits: None,
            block_override: BTreeMap::new(),
            threads: 0,
        }
    }
}

impl PqSpec {
    pub fn new(k: usize) -> PqSpec {
        PqSpec { k, ..Default::default() }
    }
}

/// Canonical, parseable description of one quantization scheme.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QuantSpec {
    /// fp32 passthrough (size accounting / zero-rate noise).
    None,
    /// φ_proxy: zero out selected blocks (structured dropout, §4.2).
    Proxy,
    /// Blockwise-mean intermediate approximation (§4.2 / Table 5).
    MeanSub,
    /// Scalar intN fixed-point quantization (§3.1, Eq. 2).
    Int { bits: u8, observer: IntObserver },
    /// Product Quantization (§3.2), optionally ⊕ int8 codebook (§3.3).
    Pq(PqSpec),
}

impl QuantSpec {
    pub fn int(bits: u8, observer: IntObserver) -> QuantSpec {
        QuantSpec::Int { bits, observer }
    }

    /// PQ with K codewords at the PTQ defaults (12 k-means iterations).
    pub fn pq(k: usize) -> QuantSpec {
        QuantSpec::Pq(PqSpec::new(k))
    }

    /// PQ at the trainer's per-epoch hat-refresh budget (6 Lloyd
    /// iterations — the hat is refit every `hat_refresh` steps, so a
    /// short k-means per refresh matches the paper's once-per-epoch
    /// re-quantization).
    pub fn pq_noise(k: usize) -> QuantSpec {
        QuantSpec::Pq(PqSpec { k, kmeans_iters: 6, ..Default::default() })
    }

    /// Short kind name ("none" / "proxy" / "mean_sub" / "int" / "pq").
    pub fn kind(&self) -> &'static str {
        match self {
            QuantSpec::None => "none",
            QuantSpec::Proxy => "proxy",
            QuantSpec::MeanSub => "mean_sub",
            QuantSpec::Int { .. } => "int",
            QuantSpec::Pq(_) => "pq",
        }
    }

    /// Does training with this scheme need host-computed hat tensors?
    pub fn needs_hat(&self) -> bool {
        matches!(self, QuantSpec::MeanSub | QuantSpec::Pq(_))
    }

    /// The grad-artifact entry point implementing this scheme's noise.
    pub fn grad_entry(&self) -> Result<&'static str, SchemeError> {
        match self {
            QuantSpec::None | QuantSpec::Proxy | QuantSpec::MeanSub | QuantSpec::Pq(_) => {
                Ok("grad_mix")
            }
            QuantSpec::Int { bits, observer } => int_entry(*bits, *observer)
                .ok_or_else(|| SchemeError::NoGradEntry { scheme: self.to_string() }),
        }
    }

    /// Same spec with the worker-thread knob overridden (no-op for
    /// schemes without one).
    pub fn with_threads(mut self, threads: usize) -> QuantSpec {
        if let QuantSpec::Pq(p) = &mut self {
            p.threads = threads;
        }
        self
    }

    /// Resolve this spec against one parameter, yielding a ready-to-run
    /// quantizer (per-structure/manifest block sizes applied here).
    ///
    /// Block-override precedence: exact structure match
    /// (`block.dw3x3=`), then the `conv` family alias covering every
    /// convolution weight family (`block.conv=` applies to `stem`,
    /// `conv1x1` and `dw3x3` — Fig. 6b's whole-filter ablation as
    /// `pq:k=64,block.conv=9`), then the global `d=`/`block=`, then
    /// the manifest's per-parameter block size.
    pub fn resolve(&self, p: &ParamInfo) -> Box<dyn Quantizer> {
        match self {
            QuantSpec::None => Box::new(NoneQuant),
            QuantSpec::Proxy => Box::new(ProxyQuant),
            QuantSpec::MeanSub => Box::new(MeanSubQuant { block: p.pq_block }),
            QuantSpec::Int { bits, observer } => {
                Box::new(ScalarQuant { bits: *bits, observer: *observer })
            }
            QuantSpec::Pq(s) => {
                let family = match p.structure.as_str() {
                    "stem" | "conv1x1" | "dw3x3" => Some("conv"),
                    _ => None,
                };
                let d = s
                    .block_override
                    .get(&p.structure)
                    .or_else(|| family.and_then(|f| s.block_override.get(f)))
                    .copied()
                    .or(s.block)
                    .unwrap_or(p.pq_block);
                Box::new(PqQuant {
                    cfg: PqConfig {
                        block_size: d,
                        n_centroids: s.k,
                        kmeans_iters: s.kmeans_iters,
                        threads: s.threads,
                    },
                    codebook_bits: s.codebook_bits,
                })
            }
        }
    }

    /// Parse a canonical spec string (see the module docs for the
    /// grammar). Inverse of `Display`.
    ///
    /// ```
    /// use quant_noise::quant::scheme::{QuantSpec, SchemeError};
    ///
    /// let spec = QuantSpec::parse("pq:k=256,d=8,cb=int8")?;
    /// assert_eq!(spec.to_string(), "pq:k=256,d=8,cb=int8");
    ///
    /// // non-default options round-trip in canonical order
    /// let full = QuantSpec::parse("pq:k=64,d=4,iters=6,cb=int8,block.ffn=16")?;
    /// assert_eq!(full.to_string(), "pq:k=64,d=4,iters=6,cb=int8,block.ffn=16");
    ///
    /// // legacy aliases parse but display canonically
    /// assert_eq!(QuantSpec::parse("exact_pq")?.to_string(), "pq:k=64,iters=6");
    /// assert_eq!(QuantSpec::parse("pq")?.to_string(), "pq:k=64,iters=6");
    /// assert_eq!(QuantSpec::parse("int8_channel")?.to_string(), "int8:per_channel");
    /// assert_eq!(QuantSpec::parse("mean")?.to_string(), "mean_sub");
    ///
    /// // malformed specs are typed errors, not panics
    /// assert!(matches!(QuantSpec::parse("pq:k=oops"),
    ///                  Err(SchemeError::Parse { .. })));
    /// # Ok::<(), SchemeError>(())
    /// ```
    pub fn parse(s: &str) -> Result<QuantSpec, SchemeError> {
        let s = s.trim();
        let err = |reason: String| SchemeError::Parse { spec: s.to_string(), reason };
        let (head, opts) = match s.split_once(':') {
            Some((h, o)) => (h, Some(o)),
            None => (s, None),
        };
        let no_opts = |spec: QuantSpec| -> Result<QuantSpec, SchemeError> {
            match opts {
                Some(o) => Err(err(format!("'{head}' takes no options, got '{o}'"))),
                None => Ok(spec),
            }
        };
        match head {
            "none" | "fp32" => no_opts(QuantSpec::None),
            "proxy" => no_opts(QuantSpec::Proxy),
            "mean_sub" | "mean" => no_opts(QuantSpec::MeanSub),
            // legacy noise-kind names; a bare `pq` (no options) keeps
            // the old `--noise pq` meaning — exact-φ_PQ at the trainer
            // defaults — while `pq:<opts>` uses the full grammar below
            "exact_pq" => no_opts(QuantSpec::pq_noise(64)),
            "pq" if opts.is_none() => Ok(QuantSpec::pq_noise(64)),
            "int8_channel" => no_opts(QuantSpec::int(8, IntObserver::PerChannel)),
            "int4_channel" => no_opts(QuantSpec::int(4, IntObserver::PerChannel)),
            "pq" => {
                let mut p = PqSpec::default();
                for kv in opts.iter().flat_map(|o| o.split(',')) {
                    let (key, val) = kv
                        .split_once('=')
                        .ok_or_else(|| err(format!("expected key=value, got '{kv}'")))?;
                    let usize_val = || -> Result<usize, SchemeError> {
                        val.parse::<usize>()
                            .map_err(|_| err(format!("'{key}' needs an integer, got '{val}'")))
                    };
                    match key {
                        "k" => p.k = usize_val()?,
                        "d" | "block" => p.block = Some(usize_val()?),
                        "iters" => p.kmeans_iters = usize_val()?,
                        "threads" => p.threads = usize_val()?,
                        "cb" => {
                            p.codebook_bits = match val {
                                "int8" => Some(8),
                                "int4" => Some(4),
                                "fp32" => None,
                                _ => {
                                    return Err(err(format!(
                                        "cb must be int8|int4|fp32, got '{val}'"
                                    )))
                                }
                            }
                        }
                        _ => match key.strip_prefix("block.") {
                            Some(structure) if !structure.is_empty() => {
                                p.block_override.insert(structure.to_string(), usize_val()?);
                            }
                            _ => return Err(err(format!("unknown pq option '{key}'"))),
                        },
                    }
                }
                if p.k == 0 {
                    return Err(err("k must be >= 1".to_string()));
                }
                if p.block == Some(0) || p.block_override.values().any(|&b| b == 0) {
                    return Err(err("block size must be >= 1".to_string()));
                }
                Ok(QuantSpec::Pq(p))
            }
            _ => {
                if let Some(bits_str) = head.strip_prefix("int") {
                    let bits: u8 = bits_str
                        .parse()
                        .map_err(|_| err(format!("bad intN bit-width '{bits_str}'")))?;
                    if !(1..=8).contains(&bits) {
                        return Err(err(format!("intN bits must be 1..=8, got {bits}")));
                    }
                    let observer = match opts {
                        None => IntObserver::MinMax,
                        Some("minmax") => IntObserver::MinMax,
                        Some("histogram") => IntObserver::Histogram,
                        Some("per_channel") | Some("channel") => IntObserver::PerChannel,
                        Some(o) => return Err(err(format!("unknown intN observer '{o}'"))),
                    };
                    Ok(QuantSpec::Int { bits, observer })
                } else {
                    Err(err(format!("unknown scheme '{head}'")))
                }
            }
        }
    }
}

impl fmt::Display for QuantSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QuantSpec::None => write!(f, "none"),
            QuantSpec::Proxy => write!(f, "proxy"),
            QuantSpec::MeanSub => write!(f, "mean_sub"),
            QuantSpec::Int { bits, observer } => {
                write!(f, "int{bits}")?;
                match observer {
                    IntObserver::MinMax => Ok(()),
                    IntObserver::Histogram => write!(f, ":histogram"),
                    IntObserver::PerChannel => write!(f, ":per_channel"),
                }
            }
            QuantSpec::Pq(p) => {
                write!(f, "pq:k={}", p.k)?;
                if let Some(d) = p.block {
                    write!(f, ",d={d}")?;
                }
                if p.kmeans_iters != 12 {
                    write!(f, ",iters={}", p.kmeans_iters)?;
                }
                if let Some(bits) = p.codebook_bits {
                    write!(f, ",cb=int{bits}")?;
                }
                if p.threads != 0 {
                    write!(f, ",threads={}", p.threads)?;
                }
                for (s, b) in &p.block_override {
                    write!(f, ",block.{s}={b}")?;
                }
                Ok(())
            }
        }
    }
}

impl std::str::FromStr for QuantSpec {
    type Err = SchemeError;

    fn from_str(s: &str) -> Result<QuantSpec, SchemeError> {
        QuantSpec::parse(s)
    }
}

/// In-graph grad entry for an intN noise configuration, when one exists.
fn int_entry(bits: u8, observer: IntObserver) -> Option<&'static str> {
    match (bits, observer) {
        (8, IntObserver::MinMax) => Some("grad_int8"),
        (4, IntObserver::MinMax) => Some("grad_int4"),
        (8, IntObserver::PerChannel) => Some("grad_int8_channel"),
        (4, IntObserver::PerChannel) => Some("grad_int4_channel"),
        _ => None,
    }
}

// ----------------------------------------------------------- trait ---

/// One parameter's quantization result: the dequantized image plus any
/// codebook state kept for finetuning / exact-noise reuse.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    /// Dequantized weights (what the eval artifact sees).
    pub data: Vec<f32>,
    /// PQ state when the scheme keeps a codebook.
    pub pq: Option<PqMatrix>,
}

/// How a scheme injects training noise.
#[derive(Debug, Clone, PartialEq)]
pub enum HatKind {
    /// Host-computed quantized image ("hat") for the grad_mix family.
    Host(Vec<f32>),
    /// Noise computed inside the grad artifact; no host tensor.
    InGraph { entry: &'static str },
}

/// A quantization operator φ, resolved for one parameter. Implementing
/// this trait (plus a [`QuantizerFactory`]) is all a new scheme needs —
/// PTQ, storage accounting, and training noise come along for free.
pub trait Quantizer {
    /// Short static kind name for logs.
    fn name(&self) -> &'static str;

    /// Quantize-dequantize one weight matrix in its canonical 2-D view.
    fn fit(
        &self,
        w: &[f32],
        rows: usize,
        cols: usize,
        rng: &mut Pcg,
    ) -> Result<QuantizedTensor, SchemeError>;

    /// Reconstruct a fitted tensor into a caller-provided buffer.
    fn decode_into(&self, qt: &QuantizedTensor, out: &mut [f32]) -> Result<(), SchemeError> {
        assert_eq!(out.len(), qt.data.len(), "decode buffer size mismatch");
        out.copy_from_slice(&qt.data);
        Ok(())
    }

    /// Build this scheme's training-noise hat (§4.2). In-graph kinds
    /// return [`HatKind::InGraph`] with their grad entry instead of a
    /// string side-channel. Every user-reachable failure (bad spec,
    /// incompatible block size, missing grad entry) is a typed
    /// [`SchemeError`]; caller-side shape invariants (buffer length vs
    /// `rows·cols`) still assert, like the rest of the quant substrate.
    fn hat(
        &self,
        w: &[f32],
        rows: usize,
        cols: usize,
        rng: &mut Pcg,
    ) -> Result<HatKind, SchemeError>;

    /// Bits to store one parameter under this scheme (Eq. 5 without the
    /// activation term; unquantized params stay fp32).
    fn storage_bits(&self, p: &ParamInfo) -> u64;
}

/// A family of quantizers resolvable per parameter. [`QuantSpec`] is
/// the built-in implementation; external schemes implement this to plug
/// into `quantize_params_with` / `model_bytes_with` without touching
/// any consumer module.
pub trait QuantizerFactory {
    fn for_param(&self, p: &ParamInfo) -> Box<dyn Quantizer>;

    /// Canonical label for logs / cache keys. Implementations must
    /// normalize out execution-only knobs that cannot affect results
    /// (e.g. worker-thread counts), so equal workloads get equal keys.
    fn spec_string(&self) -> String;
}

impl QuantizerFactory for QuantSpec {
    fn for_param(&self, p: &ParamInfo) -> Box<dyn Quantizer> {
        self.resolve(p)
    }

    /// `Display` with the thread knob zeroed: engine results are
    /// thread-count-invariant, so `pq:k=64` and `pq:k=64,threads=8`
    /// are the same workload and must key identically.
    fn spec_string(&self) -> String {
        self.clone().with_threads(0).to_string()
    }
}

// ----------------------------------------------------- built-in φs ---

fn fp32_bits(p: &ParamInfo) -> u64 {
    32 * p.numel as u64
}

/// fp32 passthrough.
pub struct NoneQuant;

impl Quantizer for NoneQuant {
    fn name(&self) -> &'static str {
        "none"
    }

    fn fit(
        &self,
        w: &[f32],
        _rows: usize,
        _cols: usize,
        _rng: &mut Pcg,
    ) -> Result<QuantizedTensor, SchemeError> {
        Ok(QuantizedTensor { data: w.to_vec(), pq: None })
    }

    fn hat(
        &self,
        w: &[f32],
        _rows: usize,
        _cols: usize,
        _rng: &mut Pcg,
    ) -> Result<HatKind, SchemeError> {
        Ok(HatKind::Host(vec![0.0; w.len()]))
    }

    fn storage_bits(&self, p: &ParamInfo) -> u64 {
        fp32_bits(p)
    }
}

/// φ_proxy: the grad artifact zeroes selected blocks; as a compressor
/// it is the identity (it exists to *train* for PQ, not to store).
pub struct ProxyQuant;

impl Quantizer for ProxyQuant {
    fn name(&self) -> &'static str {
        "proxy"
    }

    fn fit(
        &self,
        w: &[f32],
        _rows: usize,
        _cols: usize,
        _rng: &mut Pcg,
    ) -> Result<QuantizedTensor, SchemeError> {
        Ok(QuantizedTensor { data: w.to_vec(), pq: None })
    }

    fn hat(
        &self,
        w: &[f32],
        _rows: usize,
        _cols: usize,
        _rng: &mut Pcg,
    ) -> Result<HatKind, SchemeError> {
        Ok(HatKind::Host(vec![0.0; w.len()]))
    }

    fn storage_bits(&self, p: &ParamInfo) -> u64 {
        fp32_bits(p)
    }
}

/// Blockwise-mean approximation: each subvector stored as its mean.
pub struct MeanSubQuant {
    pub block: usize,
}

impl MeanSubQuant {
    fn check(&self, w: &[f32], rows: usize, cols: usize) -> Result<(), SchemeError> {
        assert_eq!(w.len(), rows * cols, "matrix size mismatch");
        if self.block == 0 || cols % self.block != 0 {
            return Err(SchemeError::BlockMismatch { cols, block: self.block });
        }
        Ok(())
    }
}

impl Quantizer for MeanSubQuant {
    fn name(&self) -> &'static str {
        "mean_sub"
    }

    fn fit(
        &self,
        w: &[f32],
        rows: usize,
        cols: usize,
        _rng: &mut Pcg,
    ) -> Result<QuantizedTensor, SchemeError> {
        self.check(w, rows, cols)?;
        Ok(QuantizedTensor { data: pq::mean_subvector_hat(w, rows, cols, self.block), pq: None })
    }

    fn hat(
        &self,
        w: &[f32],
        rows: usize,
        cols: usize,
        _rng: &mut Pcg,
    ) -> Result<HatKind, SchemeError> {
        self.check(w, rows, cols)?;
        Ok(HatKind::Host(pq::mean_subvector_hat(w, rows, cols, self.block)))
    }

    /// One fp32 mean per subvector.
    fn storage_bits(&self, p: &ParamInfo) -> u64 {
        if !p.quantized {
            return fp32_bits(p);
        }
        32 * (p.numel / self.block.max(1)) as u64
    }
}

/// Scalar intN fixed-point quantization (§3.1), with the observer
/// choices of §7.7 / Table 10.
pub struct ScalarQuant {
    pub bits: u8,
    pub observer: IntObserver,
}

impl ScalarQuant {
    fn spec_string(&self) -> String {
        QuantSpec::int(self.bits, self.observer).to_string()
    }
}

impl Quantizer for ScalarQuant {
    fn name(&self) -> &'static str {
        "int"
    }

    fn fit(
        &self,
        w: &[f32],
        rows: usize,
        cols: usize,
        _rng: &mut Pcg,
    ) -> Result<QuantizedTensor, SchemeError> {
        let mut data = w.to_vec();
        match self.observer {
            IntObserver::MinMax => {
                let qp = scalar::QParams::from_minmax(&data, self.bits);
                scalar::roundtrip(&mut data, &qp);
            }
            IntObserver::Histogram => {
                let mut h = HistogramObserver::new(2048);
                // serial scan: `Quantizer::fit` carries no worker knob,
                // and spawning all cores here would bypass the
                // one-knob contract (DESIGN.md §4). Callers that do
                // hold a knob use `observe_sharded` (bit-identical).
                h.observe(&data);
                let qp = h.qparams(self.bits);
                scalar::roundtrip(&mut data, &qp);
            }
            IntObserver::PerChannel => {
                scalar::roundtrip_per_channel(&mut data, rows, cols, self.bits);
            }
        }
        Ok(QuantizedTensor { data, pq: None })
    }

    fn hat(
        &self,
        _w: &[f32],
        _rows: usize,
        _cols: usize,
        _rng: &mut Pcg,
    ) -> Result<HatKind, SchemeError> {
        match int_entry(self.bits, self.observer) {
            Some(entry) => Ok(HatKind::InGraph { entry }),
            None => Err(SchemeError::NoGradEntry { scheme: self.spec_string() }),
        }
    }

    /// Codes plus one fp32 scale and zero-point per tensor. (Kept
    /// identical for all observers — per-channel qparams are not
    /// charged — matching the accounting the paper tables use.)
    fn storage_bits(&self, p: &ParamInfo) -> u64 {
        if !p.quantized {
            return fp32_bits(p);
        }
        self.bits as u64 * p.numel as u64 + 64
    }
}

/// Product Quantization (§3.2), optionally with the §3.3 intN-codebook
/// combination (`cb=int8` / `cb=int4`). The block size is already
/// resolved for one parameter.
pub struct PqQuant {
    pub cfg: PqConfig,
    pub codebook_bits: Option<u8>,
}

impl Quantizer for PqQuant {
    fn name(&self) -> &'static str {
        "pq"
    }

    fn fit(
        &self,
        w: &[f32],
        rows: usize,
        cols: usize,
        rng: &mut Pcg,
    ) -> Result<QuantizedTensor, SchemeError> {
        assert_eq!(w.len(), rows * cols, "matrix size mismatch");
        let d = self.cfg.block_size;
        if d == 0 || cols % d != 0 {
            return Err(SchemeError::BlockMismatch { cols, block: d });
        }
        let mut m = pq::fit(w, rows, cols, &self.cfg, rng);
        if let Some(bits) = self.codebook_bits {
            m.codebook.compress(bits);
        }
        let data = m.decode();
        Ok(QuantizedTensor { data, pq: Some(m) })
    }

    /// Decode straight from the stored assignments on the shared
    /// engine's decode kernel — no re-encode, no temporary copy.
    fn decode_into(&self, qt: &QuantizedTensor, out: &mut [f32]) -> Result<(), SchemeError> {
        match &qt.pq {
            Some(m) => {
                pq::decode_codes_into(&m.codebook, &m.codes, out);
                Ok(())
            }
            None => Err(SchemeError::MissingState { scheme: "pq".to_string() }),
        }
    }

    /// The exact φ_PQ hat: refit against the current weights and decode
    /// the assignments (bit-identical to encode-then-decode, minus the
    /// redundant O(n·K·d) pass).
    fn hat(
        &self,
        w: &[f32],
        rows: usize,
        cols: usize,
        rng: &mut Pcg,
    ) -> Result<HatKind, SchemeError> {
        Ok(HatKind::Host(self.fit(w, rows, cols, rng)?.data))
    }

    /// Eq. 5 without the activation term: codebook (b·K·d for a
    /// `cb=intN` codebook, 32·K·d fp32, +64 qparam bits when
    /// compressed) plus log2(K) bits per subvector index.
    fn storage_bits(&self, p: &ParamInfo) -> u64 {
        if !p.quantized {
            return fp32_bits(p);
        }
        let d = self.cfg.block_size;
        let k = self.cfg.n_centroids;
        let n_sub = (p.numel / d) as u64;
        let index_bits = (k.max(2) as f64).log2().ceil() as u64;
        let cb_per = self.codebook_bits.map_or(32u64, u64::from);
        let centroid_bits = cb_per * (k * d) as u64;
        centroid_bits + index_bits * n_sub + if self.codebook_bits.is_some() { 64 } else { 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(numel: usize, rows: usize, cols: usize) -> ParamInfo {
        ParamInfo {
            name: "w".into(),
            structure: "ffn".into(),
            numel,
            rows,
            cols,
            quantized: true,
            pq_block: 8,
        }
    }

    #[test]
    fn parse_canonical_forms() {
        assert_eq!(QuantSpec::parse("none").unwrap(), QuantSpec::None);
        assert_eq!(QuantSpec::parse("proxy").unwrap(), QuantSpec::Proxy);
        assert_eq!(QuantSpec::parse("mean_sub").unwrap(), QuantSpec::MeanSub);
        assert_eq!(QuantSpec::parse("mean").unwrap(), QuantSpec::MeanSub);
        assert_eq!(
            QuantSpec::parse("int8").unwrap(),
            QuantSpec::int(8, IntObserver::MinMax)
        );
        assert_eq!(
            QuantSpec::parse("int4:per_channel").unwrap(),
            QuantSpec::int(4, IntObserver::PerChannel)
        );
        assert_eq!(
            QuantSpec::parse("int8:histogram").unwrap(),
            QuantSpec::int(8, IntObserver::Histogram)
        );
        let pq = QuantSpec::parse("pq:k=256,d=8,cb=int8").unwrap();
        match &pq {
            QuantSpec::Pq(p) => {
                assert_eq!((p.k, p.block, p.codebook_bits), (256, Some(8), Some(8)));
                assert_eq!(p.kmeans_iters, 12);
            }
            other => panic!("{other:?}"),
        }
        // legacy noise names (bare `pq` kept the old `--noise pq` meaning)
        assert_eq!(QuantSpec::parse("exact_pq").unwrap(), QuantSpec::pq_noise(64));
        assert_eq!(QuantSpec::parse("pq").unwrap(), QuantSpec::pq_noise(64));
        assert_eq!(
            QuantSpec::parse("int8_channel").unwrap(),
            QuantSpec::int(8, IntObserver::PerChannel)
        );
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "bogus",
            "int99",
            "int8:foo",
            "pq:k",
            "pq:k=abc",
            "pq:wat=1",
            "pq:k=0",
            "pq:block.=4",
            "none:opt",
            "proxy:x",
            "intx",
        ] {
            let e = QuantSpec::parse(bad).unwrap_err();
            assert!(matches!(e, SchemeError::Parse { .. }), "{bad}: {e:?}");
        }
    }

    #[test]
    fn display_roundtrips_options() {
        let mut p = PqSpec::new(64);
        p.block = Some(4);
        p.kmeans_iters = 6;
        p.codebook_bits = Some(8);
        p.threads = 3;
        p.block_override.insert("emb".into(), 4);
        p.block_override.insert("ffn".into(), 16);
        let spec = QuantSpec::Pq(p);
        let s = spec.to_string();
        assert_eq!(s, "pq:k=64,d=4,iters=6,cb=int8,threads=3,block.emb=4,block.ffn=16");
        assert_eq!(QuantSpec::parse(&s).unwrap(), spec);
    }

    #[test]
    fn grad_entries_match_artifact_names() {
        assert_eq!(QuantSpec::Proxy.grad_entry().unwrap(), "grad_mix");
        assert_eq!(QuantSpec::pq(64).grad_entry().unwrap(), "grad_mix");
        assert_eq!(QuantSpec::MeanSub.grad_entry().unwrap(), "grad_mix");
        assert_eq!(QuantSpec::int(8, IntObserver::MinMax).grad_entry().unwrap(), "grad_int8");
        assert_eq!(
            QuantSpec::int(4, IntObserver::PerChannel).grad_entry().unwrap(),
            "grad_int4_channel"
        );
        // histogram observer and odd bit-widths are PTQ-only
        assert!(matches!(
            QuantSpec::int(8, IntObserver::Histogram).grad_entry(),
            Err(SchemeError::NoGradEntry { .. })
        ));
        assert!(matches!(
            QuantSpec::int(2, IntObserver::MinMax).grad_entry(),
            Err(SchemeError::NoGradEntry { .. })
        ));
        assert!(!QuantSpec::Proxy.needs_hat());
        assert!(QuantSpec::pq(64).needs_hat());
        assert!(QuantSpec::MeanSub.needs_hat());
    }

    #[test]
    fn resolve_applies_block_overrides() {
        let mut p = PqSpec::new(16);
        p.block_override.insert("ffn".into(), 16);
        let spec = QuantSpec::Pq(p);
        let q = spec.resolve(&info(256, 16, 16));
        // structure override (16) wins over the manifest block (8)
        let bits = q.storage_bits(&info(256, 16, 16));
        let expect = 32 * (16 * 16) as u64 + 4 * (256 / 16) as u64;
        assert_eq!(bits, expect);
        // a different structure falls back to the manifest block
        let mut other = info(256, 16, 16);
        other.structure = "attn".into();
        let q2 = spec.resolve(&other);
        let expect2 = 32 * (16 * 8) as u64 + 4 * (256 / 8) as u64;
        assert_eq!(q2.storage_bits(&other), expect2);
    }

    #[test]
    fn conv_family_alias_resolves_block_overrides() {
        // Fig. 6b shape: one `block.conv=` knob covers every conv
        // weight family unless an exact override names it
        let mut p = PqSpec::new(64);
        p.block_override.insert("conv".into(), 16);
        p.block_override.insert("dw3x3".into(), 4);
        let spec = QuantSpec::Pq(p);
        for (structure, want_block) in
            [("stem", 16), ("conv1x1", 16), ("dw3x3", 4), ("cls", 8)]
        {
            let mut i = info(256, 16, 16);
            i.structure = structure.into();
            let bits = spec.resolve(&i).storage_bits(&i);
            let expect =
                32 * (64 * want_block) as u64 + 6 * (256 / want_block) as u64;
            assert_eq!(bits, expect, "structure {structure}");
        }
        // the alias round-trips through the canonical string
        assert_eq!(
            QuantSpec::parse("pq:k=64,block.conv=9").unwrap().to_string(),
            "pq:k=64,block.conv=9"
        );
    }

    #[test]
    fn pq_fit_reports_block_mismatch_as_typed_error() {
        let spec = QuantSpec::Pq(PqSpec { block: Some(7), ..PqSpec::new(4) });
        let w = vec![0.0f32; 4 * 10];
        let e = spec.resolve(&info(40, 4, 10)).fit(&w, 4, 10, &mut Pcg::new(0)).unwrap_err();
        assert_eq!(e, SchemeError::BlockMismatch { cols: 10, block: 7 });
    }

    #[test]
    fn int_hat_is_in_graph_and_histogram_is_typed_error() {
        let mut rng = Pcg::new(1);
        let w = vec![1.0f32; 32];
        match QuantSpec::int(8, IntObserver::MinMax)
            .resolve(&info(32, 4, 8))
            .hat(&w, 4, 8, &mut rng)
            .unwrap()
        {
            HatKind::InGraph { entry } => assert_eq!(entry, "grad_int8"),
            other => panic!("{other:?}"),
        }
        let e = QuantSpec::int(8, IntObserver::Histogram)
            .resolve(&info(32, 4, 8))
            .hat(&w, 4, 8, &mut rng)
            .unwrap_err();
        assert!(matches!(e, SchemeError::NoGradEntry { .. }), "{e}");
    }

    #[test]
    fn pq_decode_into_matches_fit_data() {
        let mut rng = Pcg::new(3);
        let w: Vec<f32> = (0..32 * 16).map(|_| rng.next_normal()).collect();
        let spec = QuantSpec::pq(8);
        let q = spec.resolve(&info(32 * 16, 32, 16));
        let qt = q.fit(&w, 32, 16, &mut Pcg::new(4)).unwrap();
        let mut out = vec![0.0f32; w.len()];
        q.decode_into(&qt, &mut out).unwrap();
        assert_eq!(out, qt.data);
        // a PQ tensor stripped of its state is a typed error
        let bare = QuantizedTensor { data: qt.data.clone(), pq: None };
        assert!(matches!(
            q.decode_into(&bare, &mut out),
            Err(SchemeError::MissingState { .. })
        ));
    }

    #[test]
    fn spec_string_normalizes_thread_knob() {
        let a = QuantSpec::pq(64);
        let b = QuantSpec::pq(64).with_threads(8);
        assert_ne!(b.to_string(), a.to_string()); // Display round-trips it
        assert_eq!(b.spec_string(), a.spec_string()); // keys ignore it
        assert_eq!(b.spec_string(), "pq:k=64");
    }

    #[test]
    fn error_messages_are_user_readable() {
        let e = QuantSpec::parse("pq:k=oops").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("pq:k=oops") && msg.contains("integer"), "{msg}");
        let e = SchemeError::InGraphOnly { scheme: "int8".into(), entry: "grad_int8" };
        assert!(e.to_string().contains("in-graph"));
    }
}
