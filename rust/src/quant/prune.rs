//! LayerDrop pruning and weight sharing (paper §4.2 + §7.9).
//!
//! * Training-time LayerDrop: each residual layer kept with prob 1−p,
//!   sampled per step by the coordinator (the mask is an artifact input).
//! * Inference-time pruning: the "Every Other Layer" strategy applied to
//!   *chunks* — when sharing is on, adjacent layers are shared in chunks
//!   of two (A=B, C=D, …) and pruning drops every other chunk.

use crate::util::rng::Pcg;

/// Sample a training LayerDrop mask (1.0 = keep).
pub fn sample_mask(n_layers: usize, drop_rate: f32, rng: &mut Pcg) -> Vec<f32> {
    (0..n_layers)
        .map(|_| if rng.next_f32() < drop_rate { 0.0 } else { 1.0 })
        .collect()
}

/// Layer → canonical layer under chunked sharing (chunks of `chunk`
/// adjacent layers share one set of weights). chunk=1 ⇒ identity.
pub fn share_map(n_layers: usize, chunk: usize) -> Vec<usize> {
    assert!(chunk >= 1);
    (0..n_layers).map(|l| (l / chunk) * chunk).collect()
}

/// "Every Other Layer" chunk pruning: keep chunks with even index.
/// Returns the keep mask over layers.
pub fn every_other_chunk_mask(n_layers: usize, chunk: usize) -> Vec<f32> {
    (0..n_layers)
        .map(|l| if (l / chunk) % 2 == 0 { 1.0 } else { 0.0 })
        .collect()
}

/// Which layers physically store weights, given sharing and a keep mask:
/// a layer stores iff it is its chunk's canonical layer AND its chunk is
/// kept. (Pruned chunks cost nothing; shared non-canonical layers alias.)
pub fn stored_layers(n_layers: usize, chunk: usize, keep: &[f32]) -> Vec<bool> {
    let map = share_map(n_layers, chunk);
    (0..n_layers)
        .map(|l| map[l] == l && keep[l] > 0.0)
        .collect()
}

/// FLOPs fraction surviving pruning (paper: "pruning reduces FLOPS by
/// the same ratio as its compression factor").
pub fn flops_fraction(keep: &[f32]) -> f64 {
    if keep.is_empty() {
        return 1.0;
    }
    keep.iter().filter(|&&k| k > 0.0).count() as f64 / keep.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_rate_statistics() {
        let mut rng = Pcg::new(1);
        let n = 20_000;
        let dropped: usize = (0..n)
            .map(|_| sample_mask(1, 0.2, &mut rng)[0] as usize)
            .filter(|&k| k == 0)
            .count();
        let frac = dropped as f64 / n as f64;
        assert!((frac - 0.2).abs() < 0.02, "{frac}");
    }

    #[test]
    fn zero_rate_keeps_everything() {
        let mut rng = Pcg::new(2);
        assert_eq!(sample_mask(8, 0.0, &mut rng), vec![1.0; 8]);
    }

    #[test]
    fn share_map_chunks_of_two() {
        assert_eq!(share_map(8, 2), vec![0, 0, 2, 2, 4, 4, 6, 6]);
        assert_eq!(share_map(5, 2), vec![0, 0, 2, 2, 4]);
        assert_eq!(share_map(4, 1), vec![0, 1, 2, 3]);
    }

    #[test]
    fn every_other_chunk() {
        // 8 layers, chunks of 2: keep {0,1}, drop {2,3}, keep {4,5}, drop {6,7}
        assert_eq!(
            every_other_chunk_mask(8, 2),
            vec![1.0, 1.0, 0.0, 0.0, 1.0, 1.0, 0.0, 0.0]
        );
    }

    #[test]
    fn stored_layers_sharing_and_pruning_compose() {
        let keep = every_other_chunk_mask(8, 2);
        let stored = stored_layers(8, 2, &keep);
        // only canonical layers of kept chunks: layers 0 and 4
        assert_eq!(
            stored,
            vec![true, false, false, false, true, false, false, false]
        );
        // sharing alone: canonical layers of every chunk
        let stored_all = stored_layers(8, 2, &vec![1.0; 8]);
        assert_eq!(stored_all.iter().filter(|&&s| s).count(), 4);
    }

    #[test]
    fn flops_fraction_matches_kept_count() {
        let keep = every_other_chunk_mask(8, 2);
        assert_eq!(flops_fraction(&keep), 0.5);
        assert_eq!(flops_fraction(&[1.0, 1.0]), 1.0);
    }
}
