//! Quantization substrate: scalar intN (§3.1), observers (§7.7),
//! k-means + Product Quantization (§3.2) on the shared parallel
//! nearest-codeword [`assign`] engine, codebooks incl. the int8
//! combination (§3.3), model-size accounting (Eq. 5), LayerDrop pruning
//! and weight sharing (§4.2/§7.9), and noise-kind plumbing (§4.2).
pub mod assign;
pub mod codebook;
pub mod kmeans;
pub mod noise;
pub mod observer;
pub mod pq;
pub mod prune;
pub mod scalar;
pub mod size;
