//! Quantization substrate, unified behind the [`scheme`] module's
//! [`scheme::QuantSpec`] / [`scheme::Quantizer`] API: every operator φ
//! is described once and reused for post-training quantization,
//! training noise (§4.2), and storage accounting (Eq. 5).
//!
//! Paper-section → spec-string map:
//!
//! | paper               | spec                   | notes                             |
//! |---------------------|------------------------|-----------------------------------|
//! | §3.1 intN           | `int8`, `int4`         | per-tensor MinMax (Eq. 2)         |
//! | §7.7 observers      | `int8:histogram`       | clipped range search (PTQ only)   |
//! | Table 10 channel    | `int8:per_channel`     | per-row scale/zero                |
//! | §3.2 PQ / iPQ       | `pq:k=256,d=8`         | shared codebook over subvectors   |
//! | §3.3 iPQ ⊕ int8     | `pq:k=256,d=8,cb=int8` | int8 codebook (Eq. 5)             |
//! | §4.2 φ_proxy        | `proxy`                | zero-out noise (grad_mix)         |
//! | §4.2 φ_mean / T5    | `mean_sub`             | blockwise-mean approximation      |
//! | §4.2 exact φ_PQ     | `pq:k=64,iters=6`      | alias `exact_pq` (hat refresh)    |
//! | Fig. 6b blocks      | `pq:k=64,block.ffn=16` | per-structure block override      |
//!
//! Supporting modules: scalar intN kernels ([`scalar`]), range
//! observers ([`observer`]), k-means + Product Quantization
//! ([`kmeans`], [`pq`]) on the shared parallel nearest-codeword
//! [`assign`] engine, codebooks incl. the int8 combination
//! ([`codebook`]), model-size accounting ([`size`]), LayerDrop pruning
//! and weight sharing ([`prune`]), and hat builders ([`noise`]).
pub mod assign;
pub mod codebook;
pub mod kmeans;
pub mod noise;
pub mod observer;
pub mod pq;
pub mod prune;
pub mod scalar;
pub mod scheme;
pub mod size;
