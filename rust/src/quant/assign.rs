//! Shared nearest-codeword assignment engine.
//!
//! Every nearest-codeword search in the repo — k-means Lloyd
//! iterations, `pq::encode` re-encoding against an existing codebook
//! (the exact-φ_PQ hat refresh and iPQ eval both live on it), and the
//! `noise::build_hat` decode path — funnels through this module.
//!
//! The kernel uses the classic decomposition
//!
//! ```text
//! argmin_j ‖p − c_j‖²  =  argmin_j ‖c_j‖² − 2⟨p, c_j⟩
//! ```
//!
//! with per-codeword squared norms precomputed once per call, a doubly
//! blocked inner loop — points in blocks of `POINT_BLOCK`, codewords
//! in SIMD-width lanes of `LANE_BLOCK` against a transposed codebook
//! tile so the compiler can vectorize across codewords — and points
//! sharded across `std::thread::scope` workers.
//!
//! Determinism contract: `codes` and `dists` are computed per point by
//! kernels whose per-(point, codeword) arithmetic is the *same
//! operation sequence* as the scalar [`dot`] (the lane kernel keeps
//! `dot`'s 4-way partial sums per lane), and comparisons scan codewords
//! in ascending index order — so results are bit-identical across
//! thread counts AND across the blocked/unblocked kernels (tested
//! against [`assign_reference`]). The `objective` is a sum of per-shard
//! partial sums and is only guaranteed identical for a fixed thread
//! count.

/// Points per block in the inner loop. Small enough that the per-point
/// running best/argmin state stays in registers, large enough that each
/// centroid row is reused across the whole block.
const POINT_BLOCK: usize = 8;

/// Codewords per lane block: distances to 8 codewords are accumulated
/// simultaneously from a `[d][8]` transposed tile (one f32x8 vector's
/// worth — the ROADMAP's SIMD-width item).
const LANE_BLOCK: usize = 8;

/// Result of one assignment pass.
#[derive(Debug, Clone)]
pub struct Assignment {
    /// Nearest-codeword index per point (ties: lowest index wins).
    pub codes: Vec<u32>,
    /// Squared distance to the assigned codeword per point
    /// (reconstructed as `‖c‖² − 2⟨p,c⟩ + ‖p‖²`, clamped at 0).
    pub dists: Vec<f32>,
    /// Sum of `dists` in f64.
    pub objective: f64,
}

/// Default worker count (`0` in configs means "use this").
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Map a configured thread count to an effective one (0 ⇒ default).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        default_threads()
    } else {
        threads
    }
}

/// Deterministic 4-way-unrolled dot product. One kernel shared by the
/// parallel engine and the single-threaded reference so results match
/// bit-for-bit.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let b = &b[..n];
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let n4 = n - n % 4;
    let mut i = 0;
    while i < n4 {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    while i < n {
        acc += a[i] * b[i];
        i += 1;
    }
    acc
}

/// Per-codeword squared norms ‖c_j‖², j = 0..k.
pub fn sq_norms(centroids: &[f32], k: usize, d: usize) -> Vec<f32> {
    (0..k)
        .map(|j| {
            let c = &centroids[j * d..(j + 1) * d];
            dot(c, c)
        })
        .collect()
}

/// Per-call codebook preparation, shared read-only by every shard:
/// squared norms plus the codebook transposed into `[k / 8][d][8]`
/// lane-major tiles (full 8-lane blocks only; the `k % 8` remainder
/// stays row-major and is handled scalarly).
struct Prepared<'a> {
    centroids: &'a [f32],
    k: usize,
    d: usize,
    norms: Vec<f32>,
    tiles: Vec<f32>,
}

impl<'a> Prepared<'a> {
    fn new(centroids: &'a [f32], k: usize, d: usize) -> Prepared<'a> {
        let kb = k / LANE_BLOCK;
        let mut tiles = vec![0f32; kb * d * LANE_BLOCK];
        for b in 0..kb {
            for t in 0..d {
                for l in 0..LANE_BLOCK {
                    tiles[(b * d + t) * LANE_BLOCK + l] = centroids[(b * LANE_BLOCK + l) * d + t];
                }
            }
        }
        Prepared { centroids, k, d, norms: sq_norms(centroids, k, d), tiles }
    }
}

/// Eight dot products at once against one transposed tile. Per lane
/// this performs *exactly* the operation sequence of [`dot`] (four
/// stride-4 partial sums combined as `(s0+s1)+(s2+s3)`, then a
/// sequential tail), so `out[l] == dot(p, c_l)` bit-for-bit.
#[inline]
fn dot8(p: &[f32], tile: &[f32], d: usize, out: &mut [f32; LANE_BLOCK]) {
    let mut s0 = [0f32; LANE_BLOCK];
    let mut s1 = [0f32; LANE_BLOCK];
    let mut s2 = [0f32; LANE_BLOCK];
    let mut s3 = [0f32; LANE_BLOCK];
    let d4 = d - d % 4;
    let mut t = 0;
    while t < d4 {
        let r0 = &tile[t * LANE_BLOCK..(t + 1) * LANE_BLOCK];
        let r1 = &tile[(t + 1) * LANE_BLOCK..(t + 2) * LANE_BLOCK];
        let r2 = &tile[(t + 2) * LANE_BLOCK..(t + 3) * LANE_BLOCK];
        let r3 = &tile[(t + 3) * LANE_BLOCK..(t + 4) * LANE_BLOCK];
        for l in 0..LANE_BLOCK {
            s0[l] += p[t] * r0[l];
            s1[l] += p[t + 1] * r1[l];
            s2[l] += p[t + 2] * r2[l];
            s3[l] += p[t + 3] * r3[l];
        }
        t += 4;
    }
    for l in 0..LANE_BLOCK {
        out[l] = (s0[l] + s1[l]) + (s2[l] + s3[l]);
    }
    while t < d {
        let r = &tile[t * LANE_BLOCK..(t + 1) * LANE_BLOCK];
        for l in 0..LANE_BLOCK {
            out[l] += p[t] * r[l];
        }
        t += 1;
    }
}

/// Lane-blocked kernel over one shard of points: full 8-codeword
/// blocks via [`dot8`] + transposed tiles, scalar remainder, both in
/// ascending codeword order (ties: lowest index, like the scalar
/// kernel). `dists`, when present, must be the same length as `codes`.
/// Returns the shard's objective.
fn assign_shard(
    points: &[f32],
    cb: &Prepared,
    codes: &mut [u32],
    mut dists: Option<&mut [f32]>,
) -> f64 {
    let (centroids, k, d) = (cb.centroids, cb.k, cb.d);
    let (norms, tiles) = (&cb.norms, &cb.tiles);
    let n = codes.len();
    let kfull = k - k % LANE_BLOCK;
    let mut objective = 0.0f64;
    let mut base = 0;
    while base < n {
        let block = POINT_BLOCK.min(n - base);
        let mut best = [f32::INFINITY; POINT_BLOCK];
        let mut best_j = [0u32; POINT_BLOCK];
        for jb in 0..kfull / LANE_BLOCK {
            let tile = &tiles[jb * d * LANE_BLOCK..(jb + 1) * d * LANE_BLOCK];
            for bi in 0..block {
                let p = &points[(base + bi) * d..(base + bi + 1) * d];
                let mut dots = [0f32; LANE_BLOCK];
                dot8(p, tile, d, &mut dots);
                for (l, &pc) in dots.iter().enumerate() {
                    let j = jb * LANE_BLOCK + l;
                    let v = norms[j] - 2.0 * pc;
                    if v < best[bi] {
                        best[bi] = v;
                        best_j[bi] = j as u32;
                    }
                }
            }
        }
        for j in kfull..k {
            let c = &centroids[j * d..(j + 1) * d];
            let nj = norms[j];
            for bi in 0..block {
                let p = &points[(base + bi) * d..(base + bi + 1) * d];
                let v = nj - 2.0 * dot(p, c);
                if v < best[bi] {
                    best[bi] = v;
                    best_j[bi] = j as u32;
                }
            }
        }
        for bi in 0..block {
            codes[base + bi] = best_j[bi];
        }
        if let Some(out) = dists.as_deref_mut() {
            for bi in 0..block {
                let p = &points[(base + bi) * d..(base + bi + 1) * d];
                let dist = (best[bi] + dot(p, p)).max(0.0);
                out[base + bi] = dist;
                objective += dist as f64;
            }
        }
        base += block;
    }
    objective
}

/// The pre-SIMD scalar-unrolled kernel, kept verbatim as the reference
/// the lane-blocked engine is tested (and benchmarked) against.
fn assign_shard_scalar(
    points: &[f32],
    d: usize,
    centroids: &[f32],
    k: usize,
    norms: &[f32],
    codes: &mut [u32],
    mut dists: Option<&mut [f32]>,
) -> f64 {
    let n = codes.len();
    let mut objective = 0.0f64;
    let mut base = 0;
    while base < n {
        let block = POINT_BLOCK.min(n - base);
        let mut best = [f32::INFINITY; POINT_BLOCK];
        let mut best_j = [0u32; POINT_BLOCK];
        for j in 0..k {
            let c = &centroids[j * d..(j + 1) * d];
            let nj = norms[j];
            for bi in 0..block {
                let p = &points[(base + bi) * d..(base + bi + 1) * d];
                let v = nj - 2.0 * dot(p, c);
                if v < best[bi] {
                    best[bi] = v;
                    best_j[bi] = j as u32;
                }
            }
        }
        for bi in 0..block {
            codes[base + bi] = best_j[bi];
        }
        if let Some(out) = dists.as_deref_mut() {
            for bi in 0..block {
                let p = &points[(base + bi) * d..(base + bi + 1) * d];
                let dist = (best[bi] + dot(p, p)).max(0.0);
                out[base + bi] = dist;
                objective += dist as f64;
            }
        }
        base += block;
    }
    objective
}

fn check_dims(points: &[f32], d: usize, centroids: &[f32], k: usize) -> usize {
    assert!(d > 0, "assign: zero subvector length");
    assert!(k > 0, "assign: empty codebook");
    assert_eq!(points.len() % d, 0, "assign: points not a multiple of d");
    assert_eq!(centroids.len(), k * d, "assign: centroid matrix shape");
    points.len() / d
}

fn run_sharded(
    points: &[f32],
    d: usize,
    centroids: &[f32],
    k: usize,
    threads: usize,
    codes: &mut [u32],
    dists: Option<&mut [f32]>,
) -> f64 {
    let n = codes.len();
    let cb = Prepared::new(centroids, k, d);
    let threads = resolve_threads(threads).clamp(1, n.max(1));
    if threads <= 1 || n < 2 * POINT_BLOCK {
        return assign_shard(points, &cb, codes, dists);
    }
    // Shard on block boundaries so blocking never changes per-point
    // results between thread counts (it cannot anyway — each point's
    // comparisons are independent — but aligned shards also keep the
    // work distribution even).
    let blocks = n.div_ceil(POINT_BLOCK);
    let chunk = blocks.div_ceil(threads).max(1) * POINT_BLOCK;
    let cb_ref = &cb;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        match dists {
            Some(dists) => {
                for ((code_c, dist_c), pts_c) in codes
                    .chunks_mut(chunk)
                    .zip(dists.chunks_mut(chunk))
                    .zip(points.chunks(chunk * d))
                {
                    handles.push(
                        s.spawn(move || assign_shard(pts_c, cb_ref, code_c, Some(dist_c))),
                    );
                }
            }
            None => {
                for (code_c, pts_c) in codes.chunks_mut(chunk).zip(points.chunks(chunk * d)) {
                    handles.push(s.spawn(move || assign_shard(pts_c, cb_ref, code_c, None)));
                }
            }
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    })
}

/// Assign every point to its nearest codeword; returns codes, exact-ish
/// squared distances and their sum. `threads == 0` uses the default.
pub fn assign(points: &[f32], d: usize, centroids: &[f32], k: usize, threads: usize) -> Assignment {
    let n = check_dims(points, d, centroids, k);
    let mut codes = vec![0u32; n];
    let mut dists = vec![0.0f32; n];
    let objective = run_sharded(points, d, centroids, k, threads, &mut codes, Some(&mut dists));
    Assignment { codes, dists, objective }
}

/// Codes-only variant for `pq::encode`-style callers: skips the ‖p‖²
/// reconstruction work entirely.
pub fn assign_codes(
    points: &[f32],
    d: usize,
    centroids: &[f32],
    k: usize,
    threads: usize,
) -> Vec<u32> {
    let n = check_dims(points, d, centroids, k);
    let mut codes = vec![0u32; n];
    run_sharded(points, d, centroids, k, threads, &mut codes, None);
    codes
}

/// Single-threaded reference: the pre-SIMD scalar-unrolled kernel, no
/// sharding, no lane blocking. Tests assert the lane-blocked parallel
/// engine matches this bit-for-bit; `benches/quant_ops.rs` reports the
/// lane-blocking delta against it.
pub fn assign_reference(points: &[f32], d: usize, centroids: &[f32], k: usize) -> Assignment {
    let n = check_dims(points, d, centroids, k);
    let norms = sq_norms(centroids, k, d);
    let mut codes = vec![0u32; n];
    let mut dists = vec![0.0f32; n];
    let objective =
        assign_shard_scalar(points, d, centroids, k, &norms, &mut codes, Some(&mut dists));
    Assignment { codes, dists, objective }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randv(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Pcg::new(seed);
        (0..n).map(|_| r.next_normal()).collect()
    }

    /// Plain O(n·K·d) dist2 loop — the semantic oracle.
    fn naive(points: &[f32], d: usize, centroids: &[f32], k: usize) -> (Vec<u32>, Vec<f32>) {
        let n = points.len() / d;
        let mut codes = vec![0u32; n];
        let mut dists = vec![0.0f32; n];
        for i in 0..n {
            let p = &points[i * d..(i + 1) * d];
            let mut best = f32::INFINITY;
            let mut best_j = 0u32;
            for j in 0..k {
                let c = &centroids[j * d..(j + 1) * d];
                let mut acc = 0.0f32;
                for t in 0..d {
                    let diff = p[t] - c[t];
                    acc += diff * diff;
                }
                if acc < best {
                    best = acc;
                    best_j = j as u32;
                }
            }
            codes[i] = best_j;
            dists[i] = best;
        }
        (codes, dists)
    }

    #[test]
    fn matches_reference_across_thread_counts() {
        for (n, d, k) in [(3usize, 2usize, 5usize), (100, 8, 16), (257, 4, 3), (64, 8, 256)] {
            let pts = randv(n as u64 + 1, n * d);
            let cbs = randv(n as u64 + 100, k * d);
            let reference = assign_reference(&pts, d, &cbs, k);
            for threads in [1usize, 2, 3, 7, 64] {
                let got = assign(&pts, d, &cbs, k, threads);
                assert_eq!(got.codes, reference.codes, "n={n} d={d} k={k} t={threads}");
                assert_eq!(got.dists, reference.dists, "n={n} d={d} k={k} t={threads}");
                let codes_only = assign_codes(&pts, d, &cbs, k, threads);
                assert_eq!(codes_only, reference.codes);
            }
        }
    }

    #[test]
    fn agrees_with_naive_dist2_up_to_ties() {
        let (n, d, k) = (300usize, 8usize, 32usize);
        let pts = randv(7, n * d);
        let cbs = randv(8, k * d);
        let got = assign(&pts, d, &cbs, k, 4);
        let (ncodes, ndists) = naive(&pts, d, &cbs, k);
        for i in 0..n {
            if got.codes[i] != ncodes[i] {
                // only acceptable on a numerical near-tie
                let p = &pts[i * d..(i + 1) * d];
                let c = &cbs[got.codes[i] as usize * d..][..d];
                let dd: f32 = p.iter().zip(c).map(|(a, b)| (a - b) * (a - b)).sum();
                assert!(
                    (dd - ndists[i]).abs() <= 1e-4 * (1.0 + ndists[i]),
                    "point {i}: engine code {} (d²={dd}) vs naive {} (d²={})",
                    got.codes[i],
                    ncodes[i],
                    ndists[i]
                );
            } else {
                assert!((got.dists[i] - ndists[i]).abs() <= 1e-3 * (1.0 + ndists[i]));
            }
        }
    }

    #[test]
    fn well_separated_codebook_matches_naive_exactly() {
        // Codewords on a coarse integer lattice, points jittered around
        // them: every decision margin is O(1), far beyond fp noise, so
        // the decomposed metric must reproduce naive dist2 exactly.
        let d = 4;
        let k = 16;
        let mut rng = Pcg::new(3);
        let centroids: Vec<f32> = (0..k * d)
            .map(|i| (i / d) as f32 * 10.0 + (i % d) as f32)
            .collect();
        let pts: Vec<f32> = (0..200)
            .flat_map(|i| {
                let j = i % k;
                let base = &centroids[j * d..(j + 1) * d];
                let noise: Vec<f32> =
                    (0..d).map(|t| base[t] + rng.next_normal() * 0.05).collect();
                noise
            })
            .collect();
        let got = assign(&pts, d, &centroids, k, 3);
        let (ncodes, _) = naive(&pts, d, &centroids, k);
        assert_eq!(got.codes, ncodes);
    }

    #[test]
    fn ties_pick_lowest_index() {
        // duplicate codewords: the first must win, like the scalar loops
        let centroids = vec![1.0f32, 1.0, 1.0, 1.0, 5.0, 5.0];
        let pts = vec![1.1f32, 0.9, 4.9, 5.2];
        let a = assign(&pts, 2, &centroids, 3, 2);
        assert_eq!(a.codes, vec![0, 2]);
    }

    #[test]
    fn degenerate_shapes() {
        // single point, k > n
        let a = assign(&[0.5, 0.5], 2, &randv(1, 64 * 2), 64, 8);
        assert_eq!(a.codes.len(), 1);
        // n smaller than any thread count
        let pts = randv(2, 3 * 4);
        let r = assign_reference(&pts, 4, &randv(3, 2 * 4), 2);
        let p = assign(&pts, 4, &randv(3, 2 * 4), 2, 32);
        assert_eq!(r.codes, p.codes);
        // d == 1
        let a1 = assign(&[0.0, 0.9, 2.1], 1, &[0.0, 1.0, 2.0], 3, 2);
        assert_eq!(a1.codes, vec![0, 1, 2]);
    }

    #[test]
    fn dists_are_true_squared_distances() {
        let pts = randv(11, 50 * 8);
        let cbs = randv(12, 16 * 8);
        let a = assign(&pts, 8, &cbs, 16, 2);
        let mut sum = 0.0f64;
        for i in 0..50 {
            let p = &pts[i * 8..(i + 1) * 8];
            let c = &cbs[a.codes[i] as usize * 8..][..8];
            let exact: f32 = p.iter().zip(c).map(|(x, y)| (x - y) * (x - y)).sum();
            assert!(
                (a.dists[i] - exact).abs() <= 1e-3 * (1.0 + exact),
                "point {i}: {} vs {exact}",
                a.dists[i]
            );
            sum += a.dists[i] as f64;
        }
        assert!((a.objective - sum).abs() <= 1e-6 * sum.abs().max(1.0));
    }

    #[test]
    fn dot8_matches_dot_bitwise_per_lane() {
        // the lane kernel must reproduce the scalar 4-way-unrolled dot
        // exactly, for every d (full quads, tails, d < 4)
        for d in [1usize, 2, 3, 4, 7, 8, 9, 16] {
            let p = randv(d as u64, d);
            let centroids = randv(d as u64 + 50, LANE_BLOCK * d);
            let cb = Prepared::new(&centroids, LANE_BLOCK, d);
            let mut dots = [0f32; LANE_BLOCK];
            dot8(&p, &cb.tiles, d, &mut dots);
            for (l, &got) in dots.iter().enumerate() {
                let want = dot(&p, &centroids[l * d..(l + 1) * d]);
                assert_eq!(got.to_bits(), want.to_bits(), "d={d} lane={l}");
            }
        }
    }

    #[test]
    fn dot_handles_all_lengths() {
        for len in 0..12 {
            let a: Vec<f32> = (0..len).map(|i| i as f32 + 1.0).collect();
            let b: Vec<f32> = (0..len).map(|i| 2.0 * i as f32 - 3.0).collect();
            let expect: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - expect).abs() < 1e-4, "len {len}");
        }
    }
}
