//! Model-size accounting (paper Table 1 "Size"/"Compression" columns
//! and Eq. 5 for the iPQ ⊕ int8 combination).
//!
//! Sizes are computed from the parameter inventory the manifest
//! describes by summing each parameter's [`Quantizer::storage_bits`]
//! under a [`QuantSpec`] (or any other [`QuantizerFactory`]), including
//! the sharing/pruning adjustments of §7.9 (shared chunks stored once;
//! pruned chunks not stored at all). The legacy `Scheme` enum shipped
//! one release as a deprecated shim and is gone — parse a spec string
//! (`"pq:k=256"`) or construct a [`QuantSpec`] directly.

use crate::quant::scheme::{QuantSpec, Quantizer, QuantizerFactory};

/// One parameter's storage-relevant description.
#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    /// structure group (emb / attn / ffn / …) — drives per-structure
    /// PQ block overrides
    pub structure: String,
    pub numel: usize,
    /// canonical 2-D view (rows, cols); scalars/vectors use (1, numel)
    pub rows: usize,
    pub cols: usize,
    /// participates in quantization (norms/biases stay fp32)
    pub quantized: bool,
    /// PQ subvector length for this structure
    pub pq_block: usize,
}

/// Bits to store one parameter under a scheme.
pub fn param_bits(p: &ParamInfo, spec: &QuantSpec) -> u64 {
    spec.for_param(p).storage_bits(p)
}

/// Total model bits under any quantizer family.
pub fn model_bits_with(params: &[ParamInfo], family: &dyn QuantizerFactory) -> u64 {
    params.iter().map(|p| family.for_param(p).storage_bits(p)).sum()
}

/// Total model bytes under any quantizer family.
pub fn model_bytes_with(params: &[ParamInfo], family: &dyn QuantizerFactory) -> u64 {
    model_bits_with(params, family) / 8
}

/// Total model bytes under a scheme.
pub fn model_bytes(params: &[ParamInfo], spec: &QuantSpec) -> u64 {
    model_bytes_with(params, spec)
}

/// Layer-sharing/pruning adjustment: `stored` lists whether each param
/// is physically stored (false for weights aliased to a shared sibling
/// or living in a pruned chunk).
pub fn model_bytes_with_mask(params: &[ParamInfo], spec: &QuantSpec, stored: &[bool]) -> u64 {
    assert_eq!(params.len(), stored.len());
    params
        .iter()
        .zip(stored)
        .filter(|(_, &s)| s)
        .map(|(p, _)| param_bits(p, spec))
        .sum::<u64>()
        / 8
}

pub fn mb(bytes: u64) -> f64 {
    bytes as f64 / 1e6
}

pub fn compression_ratio(params: &[ParamInfo], spec: &QuantSpec) -> f64 {
    model_bytes(params, &QuantSpec::None) as f64 / model_bytes(params, spec) as f64
}

/// Activation memory term of Eq. 5 for a forward pass with batch 1:
/// 8 bits × input dimension when activations are int8, else 32 bits.
pub fn activation_bits(input_dim: usize, int8: bool) -> u64 {
    (if int8 { 8 } else { 32 }) * input_dim as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scheme::{IntObserver, PqSpec};

    fn pq_spec(k: usize, int8: bool) -> QuantSpec {
        QuantSpec::Pq(PqSpec { k, codebook_bits: int8.then_some(8), ..Default::default() })
    }

    fn pq_spec_cb(k: usize, cb: Option<u8>) -> QuantSpec {
        QuantSpec::Pq(PqSpec { k, codebook_bits: cb, ..Default::default() })
    }

    fn inv() -> Vec<ParamInfo> {
        vec![
            ParamInfo {
                name: "w".into(),
                structure: "ffn".into(),
                numel: 1024 * 1024,
                rows: 1024,
                cols: 1024,
                quantized: true,
                pq_block: 8,
            },
            ParamInfo {
                name: "ln".into(),
                structure: "norm".into(),
                numel: 1024,
                rows: 1,
                cols: 1024,
                quantized: false,
                pq_block: 8,
            },
        ]
    }

    #[test]
    fn fp32_baseline() {
        let params = inv();
        assert_eq!(model_bytes(&params, &QuantSpec::None), (1024 * 1024 + 1024) * 4);
    }

    #[test]
    fn int8_is_4x_on_quantized_weights() {
        let params = inv();
        let fp = model_bytes(&params, &QuantSpec::None) as f64;
        let i8b = model_bytes(&params, &QuantSpec::int(8, IntObserver::MinMax)) as f64;
        let ratio = fp / i8b;
        assert!((ratio - 4.0).abs() < 0.05, "{ratio}");
    }

    #[test]
    fn int4_is_8x() {
        let params = inv();
        let r = compression_ratio(&params, &QuantSpec::int(4, IntObserver::MinMax));
        assert!((r - 8.0).abs() < 0.1, "{r}");
    }

    #[test]
    fn int_accounting_is_observer_independent() {
        // size never depended on the observer mode; the unified API
        // must keep that (Table 10 compares observers at equal size)
        let params = inv();
        let a = model_bytes(&params, &QuantSpec::int(4, IntObserver::MinMax));
        let b = model_bytes(&params, &QuantSpec::int(4, IntObserver::Histogram));
        let c = model_bytes(&params, &QuantSpec::int(4, IntObserver::PerChannel));
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn pq_matches_eq5_arithmetic() {
        // 1M weights, d=8, K=256: indices = 8 bits × 131072 subvectors,
        // centroids = 32×256×8 bits fp32.
        let params = vec![ParamInfo {
            name: "w".into(),
            structure: "ffn".into(),
            numel: 1 << 20,
            rows: 1024,
            cols: 1024,
            quantized: true,
            pq_block: 8,
        }];
        let bits = param_bits(&params[0], &pq_spec(256, false));
        assert_eq!(bits, 32 * 256 * 8 + 8 * (1 << 17));
        // int8 centroids divide the codebook term by 4 (+64 qparams bits)
        let bits8 = param_bits(&params[0], &pq_spec(256, true));
        assert_eq!(bits8, 8 * 256 * 8 + 8 * (1 << 17) + 64);
        // int4 centroids divide it by 8; the index term is untouched
        let bits4 = param_bits(&params[0], &pq_spec_cb(256, Some(4)));
        assert_eq!(bits4, 4 * 256 * 8 + 8 * (1 << 17) + 64);
        assert!(bits4 < bits8);
    }

    #[test]
    fn pq_compression_near_30x_for_d8_k256() {
        // per-weight cost: 8 bits per 8-weight subvector = 1 bit/weight
        // (+ codebook amortized) ⇒ ratio just under 32×
        let params = vec![ParamInfo {
            name: "w".into(),
            structure: "ffn".into(),
            numel: 1 << 22,
            rows: 2048,
            cols: 2048,
            quantized: true,
            pq_block: 8,
        }];
        let r = compression_ratio(&params, &pq_spec(256, false));
        assert!(r > 28.0 && r < 32.0, "{r}");
    }

    #[test]
    fn unquantized_params_always_fp32() {
        let p = ParamInfo {
            name: "ln".into(),
            structure: "norm".into(),
            numel: 100,
            rows: 1,
            cols: 100,
            quantized: false,
            pq_block: 8,
        };
        assert_eq!(param_bits(&p, &QuantSpec::int(4, IntObserver::MinMax)), 3200);
        assert_eq!(param_bits(&p, &pq_spec(256, true)), 3200);
        assert_eq!(param_bits(&p, &QuantSpec::MeanSub), 3200);
    }

    #[test]
    fn sharing_mask_halves_shared_layers() {
        let params = inv();
        let all = model_bytes_with_mask(&params, &QuantSpec::None, &[true, true]);
        let masked = model_bytes_with_mask(&params, &QuantSpec::None, &[false, true]);
        assert_eq!(all - masked, 4 * 1024 * 1024);
    }

    #[test]
    fn activation_term() {
        assert_eq!(activation_bits(1024, true), 8 * 1024);
        assert_eq!(activation_bits(1024, false), 32 * 1024);
    }

    #[test]
    fn spec_strings_cover_legacy_scheme_surface() {
        // the deprecated `Scheme` shim is gone; its three variants map
        // to spec strings, which must keep producing identical sizes
        let params = inv();
        for (spec_str, new) in [
            ("none", QuantSpec::None),
            ("int8", QuantSpec::int(8, IntObserver::MinMax)),
            ("pq:k=64", pq_spec(64, false)),
            ("pq:k=64,cb=int8", pq_spec(64, true)),
        ] {
            let parsed = QuantSpec::parse(spec_str).unwrap();
            // parsed defaults may differ in non-size knobs (iters); the
            // storage accounting must agree regardless
            assert_eq!(model_bytes(&params, &parsed), model_bytes(&params, &new));
            assert_eq!(model_bytes_with(&params, &parsed), model_bytes(&params, &new));
        }
    }
}
