//! Model-size accounting (paper Table 1 "Size"/"Compression" columns
//! and Eq. 5 for the iPQ ⊕ int8 combination).
//!
//! Sizes are computed from the parameter inventory the manifest
//! describes, per compression scheme, including the sharing/pruning
//! adjustments of §7.9 (shared chunks stored once; pruned chunks not
//! stored at all).

/// One parameter's storage-relevant description.
#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub numel: usize,
    /// canonical 2-D view (rows, cols); scalars/vectors use (1, numel)
    pub rows: usize,
    pub cols: usize,
    /// participates in quantization (norms/biases stay fp32)
    pub quantized: bool,
    /// PQ subvector length for this structure
    pub pq_block: usize,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scheme {
    Fp32,
    Int { bits: u8 },
    /// PQ with K centroids; `int8_centroids` applies §3.3 (Eq. 5).
    Pq { k: usize, int8_centroids: bool },
}

/// Bits to store one parameter under a scheme.
pub fn param_bits(p: &ParamInfo, scheme: Scheme) -> u64 {
    if !p.quantized {
        return 32 * p.numel as u64;
    }
    match scheme {
        Scheme::Fp32 => 32 * p.numel as u64,
        // intN: codes + one fp32 scale and zero-point per tensor
        Scheme::Int { bits } => bits as u64 * p.numel as u64 + 64,
        Scheme::Pq { k, int8_centroids } => {
            let d = p.pq_block;
            let n_sub = (p.numel / d) as u64;
            let index_bits = (k.max(2) as f64).log2().ceil() as u64;
            let centroid_bits = if int8_centroids { 8 } else { 32 } * (k * d) as u64;
            // Eq. 5 (without the activation term, which is not model
            // storage): centroid table + index matrix (+64 for the
            // centroid int8 scale/zero when applicable)
            centroid_bits + index_bits * n_sub + if int8_centroids { 64 } else { 0 }
        }
    }
}

/// Total model bytes under a scheme.
pub fn model_bytes(params: &[ParamInfo], scheme: Scheme) -> u64 {
    params.iter().map(|p| param_bits(p, scheme)).sum::<u64>() / 8
}

/// Layer-sharing/pruning adjustment: `stored` lists whether each param
/// is physically stored (false for weights aliased to a shared sibling
/// or living in a pruned chunk).
pub fn model_bytes_with_mask(params: &[ParamInfo], scheme: Scheme, stored: &[bool]) -> u64 {
    assert_eq!(params.len(), stored.len());
    params
        .iter()
        .zip(stored)
        .filter(|(_, &s)| s)
        .map(|(p, _)| param_bits(p, scheme))
        .sum::<u64>()
        / 8
}

pub fn mb(bytes: u64) -> f64 {
    bytes as f64 / 1e6
}

pub fn compression_ratio(params: &[ParamInfo], scheme: Scheme) -> f64 {
    model_bytes(params, Scheme::Fp32) as f64 / model_bytes(params, scheme) as f64
}

/// Activation memory term of Eq. 5 for a forward pass with batch 1:
/// 8 bits × input dimension when activations are int8, else 32 bits.
pub fn activation_bits(input_dim: usize, int8: bool) -> u64 {
    (if int8 { 8 } else { 32 }) * input_dim as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inv() -> Vec<ParamInfo> {
        vec![
            ParamInfo {
                name: "w".into(),
                numel: 1024 * 1024,
                rows: 1024,
                cols: 1024,
                quantized: true,
                pq_block: 8,
            },
            ParamInfo {
                name: "ln".into(),
                numel: 1024,
                rows: 1,
                cols: 1024,
                quantized: false,
                pq_block: 8,
            },
        ]
    }

    #[test]
    fn fp32_baseline() {
        let params = inv();
        assert_eq!(model_bytes(&params, Scheme::Fp32), (1024 * 1024 + 1024) * 4);
    }

    #[test]
    fn int8_is_4x_on_quantized_weights() {
        let params = inv();
        let fp = model_bytes(&params, Scheme::Fp32) as f64;
        let i8b = model_bytes(&params, Scheme::Int { bits: 8 }) as f64;
        let ratio = fp / i8b;
        assert!((ratio - 4.0).abs() < 0.05, "{ratio}");
    }

    #[test]
    fn int4_is_8x() {
        let params = inv();
        let r = compression_ratio(&params, Scheme::Int { bits: 4 });
        assert!((r - 8.0).abs() < 0.1, "{r}");
    }

    #[test]
    fn pq_matches_eq5_arithmetic() {
        // 1M weights, d=8, K=256: indices = 8 bits × 131072 subvectors,
        // centroids = 32×256×8 bits fp32.
        let params = vec![ParamInfo {
            name: "w".into(),
            numel: 1 << 20,
            rows: 1024,
            cols: 1024,
            quantized: true,
            pq_block: 8,
        }];
        let bits = param_bits(&params[0], Scheme::Pq { k: 256, int8_centroids: false });
        assert_eq!(bits, 32 * 256 * 8 + 8 * (1 << 17));
        // int8 centroids divide the codebook term by 4 (+64 qparams bits)
        let bits8 = param_bits(&params[0], Scheme::Pq { k: 256, int8_centroids: true });
        assert_eq!(bits8, 8 * 256 * 8 + 8 * (1 << 17) + 64);
    }

    #[test]
    fn pq_compression_near_30x_for_d8_k256() {
        // per-weight cost: 8 bits per 8-weight subvector = 1 bit/weight
        // (+ codebook amortized) ⇒ ratio just under 32×
        let params = vec![ParamInfo {
            name: "w".into(),
            numel: 1 << 22,
            rows: 2048,
            cols: 2048,
            quantized: true,
            pq_block: 8,
        }];
        let r = compression_ratio(&params, Scheme::Pq { k: 256, int8_centroids: false });
        assert!(r > 28.0 && r < 32.0, "{r}");
    }

    #[test]
    fn unquantized_params_always_fp32() {
        let p = ParamInfo {
            name: "ln".into(),
            numel: 100,
            rows: 1,
            cols: 100,
            quantized: false,
            pq_block: 8,
        };
        assert_eq!(param_bits(&p, Scheme::Int { bits: 4 }), 3200);
        assert_eq!(param_bits(&p, Scheme::Pq { k: 256, int8_centroids: true }), 3200);
    }

    #[test]
    fn sharing_mask_halves_shared_layers() {
        let params = inv();
        let all = model_bytes_with_mask(&params, Scheme::Fp32, &[true, true]);
        let masked = model_bytes_with_mask(&params, Scheme::Fp32, &[false, true]);
        assert_eq!(all - masked, 4 * 1024 * 1024);
    }

    #[test]
    fn activation_term() {
        assert_eq!(activation_bits(1024, true), 8 * 1024);
        assert_eq!(activation_bits(1024, false), 32 * 1024);
    }
}
