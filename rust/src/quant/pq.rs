//! Product Quantization of weight matrices (paper §3.2, Eq. 1/3).
//!
//! A matrix is viewed in its canonical 2-D layout (rows = output units,
//! cols = input features — the same view the L2 noise uses) and split
//! into contiguous subvectors of length `block_size` along the columns,
//! i.e. each row contributes `cols / block_size` subvectors. One shared
//! codebook of K codewords is learned over all `rows · cols / block_size`
//! subvectors with k-means; the matrix is stored as (codebook, index
//! matrix) and reconstructed as `b̂_kl = c[I_kl]` at eval time.

use crate::quant::assign;
use crate::quant::codebook::Codebook;
use crate::quant::kmeans::{kmeans, KmeansConfig};
use crate::util::rng::Pcg;

#[derive(Debug, Clone, Copy)]
pub struct PqConfig {
    /// Subvector length d (the paper's per-structure "block size").
    pub block_size: usize,
    /// Codebook size K (256 ⇒ int8 indices).
    pub n_centroids: usize,
    pub kmeans_iters: usize,
    /// Worker threads for k-means assignment and re-encoding
    /// (0 ⇒ [`assign::default_threads`]).
    pub threads: usize,
}

impl Default for PqConfig {
    fn default() -> Self {
        PqConfig { block_size: 8, n_centroids: 256, kmeans_iters: 15, threads: 0 }
    }
}

/// A PQ-compressed matrix: codebook + index matrix (row-major, one code
/// per subvector, `cols/block_size` codes per row).
#[derive(Debug, Clone)]
pub struct PqMatrix {
    pub codebook: Codebook,
    pub codes: Vec<u32>,
    pub rows: usize,
    pub cols: usize,
}

impl PqMatrix {
    pub fn block_size(&self) -> usize {
        self.codebook.d
    }

    pub fn subvectors_per_row(&self) -> usize {
        self.cols / self.block_size()
    }

    /// Reconstruct the dense matrix (Eq. 1 right-hand side).
    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        self.decode_into(&mut out);
        out
    }

    /// Reconstruct into a caller-provided buffer.
    pub fn decode_into(&self, out: &mut [f32]) {
        decode_codes_into(&self.codebook, &self.codes, out);
    }

    /// Reconstruction error ‖W − Ŵ‖² (Eq. 3).
    pub fn objective(&self, original: &[f32]) -> f64 {
        let rec = self.decode();
        original
            .iter()
            .zip(&rec)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum()
    }

    /// Storage in bits: Eq. 5 without the activation term —
    /// 32·K·d for fp32 centroids (or 8·K·d once int8-compressed) plus
    /// log2(K) per subvector index.
    pub fn storage_bits(&self) -> u64 {
        let centroid_bits = self.codebook.storage_bits();
        let index_bits = (self.codebook.k.max(2) as f64).log2().ceil() as u64;
        centroid_bits + index_bits * self.codes.len() as u64
    }
}

/// View a (rows × cols) weight as its subvector matrix (n_sub × d).
/// The flat row-major layout already is subvector-major (subvectors are
/// contiguous along cols), so this validates the shape and returns the
/// borrow — no copy (the seed cloned the full matrix here, once per
/// `fit`).
pub fn subvectors(w: &[f32], rows: usize, cols: usize, d: usize) -> &[f32] {
    assert_eq!(w.len(), rows * cols, "matrix size mismatch");
    assert!(
        cols % d == 0,
        "cols {cols} not divisible by block_size {d}"
    );
    w
}

/// Fit PQ to a matrix in its canonical 2-D view.
pub fn fit(w: &[f32], rows: usize, cols: usize, cfg: &PqConfig, rng: &mut Pcg) -> PqMatrix {
    let d = cfg.block_size;
    let subs = subvectors(w, rows, cols, d);
    let km = kmeans(
        subs,
        d,
        &KmeansConfig {
            k: cfg.n_centroids,
            max_iters: cfg.kmeans_iters,
            threads: assign::resolve_threads(cfg.threads),
            ..Default::default()
        },
        rng,
    );
    PqMatrix {
        codebook: Codebook::new(km.centroids, km.k, d),
        codes: km.assignments,
        rows,
        cols,
    }
}

/// Re-encode a matrix against an *existing* codebook (used after
/// codeword finetuning steps, and by the exact-noise hat refresh).
/// Runs on the shared parallel assignment engine with the default
/// thread count; use [`encode_with`] to control sharding.
pub fn encode(w: &[f32], rows: usize, cols: usize, cb: &Codebook) -> Vec<u32> {
    encode_with(w, rows, cols, cb, 0)
}

/// [`encode`] with an explicit worker count (0 ⇒ default).
pub fn encode_with(
    w: &[f32],
    rows: usize,
    cols: usize,
    cb: &Codebook,
    threads: usize,
) -> Vec<u32> {
    assert_eq!(w.len(), rows * cols);
    assert!(cols % cb.d == 0);
    assign::assign_codes(w, cb.d, &cb.centroids, cb.k, threads)
}

/// The seed's single-threaded O(n·K·d) scalar loop, kept as the
/// benchmark baseline and as a semantic oracle in regression tests.
pub fn encode_scalar(w: &[f32], rows: usize, cols: usize, cb: &Codebook) -> Vec<u32> {
    let d = cb.d;
    assert_eq!(w.len(), rows * cols);
    assert!(cols % d == 0);
    let n = rows * cols / d;
    let mut codes = vec![0u32; n];
    for i in 0..n {
        let p = &w[i * d..(i + 1) * d];
        let mut best = f32::INFINITY;
        let mut best_j = 0u32;
        for j in 0..cb.k {
            let c = cb.codeword(j);
            let mut acc = 0.0f32;
            for t in 0..d {
                let diff = p[t] - c[t];
                acc += diff * diff;
            }
            if acc < best {
                best = acc;
                best_j = j as u32;
            }
        }
        codes[i] = best_j;
    }
    codes
}

/// Decode a code sequence through a codebook into a caller buffer.
pub fn decode_codes_into(cb: &Codebook, codes: &[u32], out: &mut [f32]) {
    let d = cb.d;
    assert_eq!(out.len(), codes.len() * d, "decode buffer size mismatch");
    for (s, &code) in codes.iter().enumerate() {
        let dst = s * d;
        out[dst..dst + d].copy_from_slice(cb.codeword(code as usize));
    }
}

/// Blockwise-mean "hat": each subvector replaced by its own mean value
/// (the paper's intermediate approximation in §4.2).
pub fn mean_subvector_hat(w: &[f32], rows: usize, cols: usize, d: usize) -> Vec<f32> {
    assert_eq!(w.len(), rows * cols);
    assert!(cols % d == 0);
    let mut out = vec![0.0f32; w.len()];
    for s in 0..w.len() / d {
        let sv = &w[s * d..(s + 1) * d];
        let mean = sv.iter().sum::<f32>() / d as f32;
        out[s * d..(s + 1) * d].fill(mean);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randmat(seed: u64, rows: usize, cols: usize) -> Vec<f32> {
        let mut r = Pcg::new(seed);
        (0..rows * cols).map(|_| r.next_normal()).collect()
    }

    #[test]
    fn decode_shape_and_determinism() {
        let w = randmat(1, 16, 32);
        let cfg = PqConfig { block_size: 8, n_centroids: 16, kmeans_iters: 8, threads: 0 };
        let a = fit(&w, 16, 32, &cfg, &mut Pcg::new(7));
        let b = fit(&w, 16, 32, &cfg, &mut Pcg::new(7));
        assert_eq!(a.decode().len(), 16 * 32);
        assert_eq!(a.decode(), b.decode());
    }

    #[test]
    fn more_centroids_lower_error() {
        let w = randmat(2, 32, 64);
        let mut errs = Vec::new();
        for k in [4usize, 16, 64, 256] {
            let cfg = PqConfig { block_size: 8, n_centroids: k, kmeans_iters: 12, threads: 0 };
            let pq = fit(&w, 32, 64, &cfg, &mut Pcg::new(3));
            errs.push(pq.objective(&w));
        }
        for pair in errs.windows(2) {
            assert!(pair[1] <= pair[0] * 1.05, "{errs:?}"); // allow tiny noise
        }
        // K = n_subvectors(=256) ⇒ exact reconstruction
        assert!(errs[3] < 1e-9, "{errs:?}");
    }

    #[test]
    fn repeated_rows_reconstruct_exactly() {
        // a matrix whose subvectors take only 4 distinct values is
        // reconstructed exactly with K >= 4
        let pattern = [1.0f32, -1.0, 0.5, 2.0];
        let mut w = Vec::new();
        for r in 0..32 {
            for _ in 0..4 {
                // subvector = constant 4-vector from the pattern
                let v = pattern[r % 4];
                w.extend_from_slice(&[v; 4]);
            }
        }
        let cfg = PqConfig { block_size: 4, n_centroids: 8, kmeans_iters: 10, threads: 0 };
        let pq = fit(&w, 32, 16, &cfg, &mut Pcg::new(5));
        assert!(pq.objective(&w) < 1e-10);
    }

    #[test]
    fn encode_matches_fit_assignments() {
        let w = randmat(4, 16, 16);
        let cfg = PqConfig { block_size: 4, n_centroids: 16, kmeans_iters: 10, threads: 0 };
        let pq = fit(&w, 16, 16, &cfg, &mut Pcg::new(6));
        let codes = encode(&w, 16, 16, &pq.codebook);
        // re-encoding with the same codebook can only improve or match
        let rec_fit = pq.objective(&w);
        let pq2 = PqMatrix { codebook: pq.codebook.clone(), codes, rows: 16, cols: 16 };
        let rec_enc = pq2.objective(&w);
        assert!(rec_enc <= rec_fit + 1e-9, "{rec_enc} vs {rec_fit}");
    }

    #[test]
    fn storage_bits_formula() {
        let w = randmat(7, 64, 64);
        let cfg = PqConfig { block_size: 8, n_centroids: 256, kmeans_iters: 2, threads: 0 };
        let pq = fit(&w, 64, 64, &cfg, &mut Pcg::new(8));
        // fp32 codebook: 32·K·d + 8 bits per code (log2 256)
        let expect = 32 * 256 * 8 + 8 * (64 * 64 / 8) as u64;
        assert_eq!(pq.storage_bits(), expect);
    }

    #[test]
    fn mean_subvector_hat_is_blockwise_constant() {
        let w = randmat(9, 8, 16);
        let hat = mean_subvector_hat(&w, 8, 16, 4);
        for s in 0..(8 * 16 / 4) {
            let sv = &hat[s * 4..(s + 1) * 4];
            assert!(sv.iter().all(|&x| (x - sv[0]).abs() < 1e-6));
            let orig = &w[s * 4..(s + 1) * 4];
            let mean = orig.iter().sum::<f32>() / 4.0;
            assert!((sv[0] - mean).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn rejects_bad_block_size() {
        let w = randmat(10, 4, 10);
        subvectors(&w, 4, 10, 8);
    }
}
