//! Training-noise plumbing (§4.2): host-side "hat" (quantized image)
//! builders for the grad_mix family, expressed through the unified
//! [`Quantizer`](crate::quant::scheme::Quantizer) API.
//!
//! The old `NoiseKind` enum (a third, hand-synced copy of the scheme
//! list) is gone: a noise function φ *is* a [`QuantSpec`], and the
//! grad-artifact entry point comes from [`QuantSpec::grad_entry`].
//! Kinds computed in-graph report [`HatKind::InGraph`] instead of
//! panicking, and every failure is a typed [`SchemeError`].

use crate::quant::scheme::{HatKind, QuantSpec, Quantizer as _, SchemeError};
use crate::quant::size::ParamInfo;
use crate::util::rng::Pcg;

/// Build the mix-family hat for one weight's canonical 2-D view.
/// `block_size` is the parameter's manifest noise-block size. This
/// helper has no structure context, so a spec's per-structure
/// `block.<structure>=` overrides do not apply here — callers that need
/// them (like `Trainer::refresh_hats`) resolve the spec against a real
/// `ParamInfo` and call [`Quantizer::hat`](crate::quant::scheme::Quantizer::hat)
/// directly. Schemes whose
/// noise runs inside the grad artifact return
/// [`SchemeError::InGraphOnly`] — they have no host hat.
pub fn build_hat(
    spec: &QuantSpec,
    w: &[f32],
    rows: usize,
    cols: usize,
    block_size: usize,
    rng: &mut Pcg,
) -> Result<Vec<f32>, SchemeError> {
    let info = ParamInfo {
        name: String::new(),
        structure: String::new(),
        numel: w.len(),
        rows,
        cols,
        quantized: true,
        pq_block: block_size,
    };
    match spec.resolve(&info).hat(w, rows, cols, rng)? {
        HatKind::Host(hat) => Ok(hat),
        HatKind::InGraph { entry } => {
            Err(SchemeError::InGraphOnly { scheme: spec.to_string(), entry })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::Codebook;
    use crate::quant::kmeans::{kmeans, KmeansConfig};
    use crate::quant::pq::{decode_codes_into, encode, fit, PqConfig};
    use crate::quant::scheme::IntObserver;

    #[test]
    fn entry_mapping() {
        assert_eq!(QuantSpec::Proxy.grad_entry().unwrap(), "grad_mix");
        assert_eq!(
            QuantSpec::int(4, IntObserver::PerChannel).grad_entry().unwrap(),
            "grad_int4_channel"
        );
        assert!(!QuantSpec::Proxy.needs_hat());
        assert!(QuantSpec::pq_noise(16).needs_hat());
    }

    #[test]
    fn parse_covers_legacy_noise_names() {
        // the old `--noise` vocabulary keeps parsing
        for (name, spec) in [
            ("none", QuantSpec::None),
            ("proxy", QuantSpec::Proxy),
            ("exact_pq", QuantSpec::pq_noise(64)),
            ("pq", QuantSpec::pq_noise(64)),
            ("mean_sub", QuantSpec::MeanSub),
            ("int8", QuantSpec::int(8, IntObserver::MinMax)),
            ("int4", QuantSpec::int(4, IntObserver::MinMax)),
            ("int8_channel", QuantSpec::int(8, IntObserver::PerChannel)),
            ("int4_channel", QuantSpec::int(4, IntObserver::PerChannel)),
        ] {
            assert_eq!(QuantSpec::parse(name).unwrap(), spec, "{name}");
        }
        assert!(QuantSpec::parse("bogus").is_err());
    }

    #[test]
    fn proxy_hat_is_zero() {
        let w = vec![1.0f32; 64];
        let hat = build_hat(&QuantSpec::Proxy, &w, 8, 8, 4, &mut Pcg::new(0)).unwrap();
        assert!(hat.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn exact_pq_hat_equals_fit_decode() {
        // the spec-built hat runs the same fit the PTQ path runs: same
        // seed ⇒ identical codebook ⇒ identical decoded image
        let mut rng = Pcg::new(1);
        let w: Vec<f32> = (0..256).map(|_| rng.next_normal()).collect();
        let cfg = PqConfig { block_size: 8, n_centroids: 8, kmeans_iters: 6, threads: 0 };
        let m = fit(&w, 16, 16, &cfg, &mut Pcg::new(2));
        let hat = build_hat(&QuantSpec::pq_noise(8), &w, 16, 16, 8, &mut Pcg::new(2)).unwrap();
        assert_eq!(hat, m.decode());
    }

    #[test]
    fn int_kinds_have_no_host_hat() {
        // typed error instead of the old panic
        let e = build_hat(
            &QuantSpec::int(8, IntObserver::MinMax),
            &[0.0; 8],
            1,
            8,
            8,
            &mut Pcg::new(0),
        )
        .unwrap_err();
        assert!(matches!(e, SchemeError::InGraphOnly { entry: "grad_int8", .. }), "{e}");
        assert!(e.to_string().contains("in-graph"));
    }

    #[test]
    fn exact_pq_hat_deterministic_for_fixed_seed() {
        // fixed-seed regression: the hat refresh path must be
        // byte-stable run to run (sharding must not leak into results)
        let mut rng = Pcg::new(9);
        let w: Vec<f32> = (0..32 * 32).map(|_| rng.next_normal()).collect();
        let spec = QuantSpec::pq_noise(16);
        let a = build_hat(&spec, &w, 32, 32, 8, &mut Pcg::new(4)).unwrap();
        let b = build_hat(&spec, &w, 32, 32, 8, &mut Pcg::new(4)).unwrap();
        assert_eq!(a, b);
        // and a differently-sharded run of the same seed agrees too
        let c = build_hat(&spec.clone().with_threads(1), &w, 32, 32, 8, &mut Pcg::new(4)).unwrap();
        assert_eq!(a, c);
    }

    #[test]
    fn trainer_hat_path_equals_reencode_path() {
        // Trainer::refresh_hats decodes the k-means assignments
        // directly into the hat buffer; the seed's path re-encoded the
        // weights against the fitted codebook first. Both run the same
        // engine kernel, so the hats must be byte-identical.
        let mut rng = Pcg::new(5);
        let w: Vec<f32> = (0..48 * 32).map(|_| rng.next_normal()).collect();
        let km = kmeans(
            &w,
            8,
            &KmeansConfig { k: 16, max_iters: 6, ..Default::default() },
            &mut Pcg::new(6),
        );
        let cb = Codebook::new(km.centroids.clone(), km.k, 8);
        let mut direct = vec![0.0f32; w.len()];
        decode_codes_into(&cb, &km.assignments, &mut direct);
        let codes = encode(&w, 48, 32, &cb);
        let mut reencoded = vec![0.0f32; w.len()];
        decode_codes_into(&cb, &codes, &mut reencoded);
        assert_eq!(direct, reencoded);
    }

    #[test]
    fn mean_sub_hat_matches_direct_kernel() {
        let mut rng = Pcg::new(7);
        let w: Vec<f32> = (0..8 * 16).map(|_| rng.next_normal()).collect();
        let hat = build_hat(&QuantSpec::MeanSub, &w, 8, 16, 4, &mut Pcg::new(0)).unwrap();
        assert_eq!(hat, crate::quant::pq::mean_subvector_hat(&w, 8, 16, 4));
    }
}
