//! Noise-kind plumbing: maps the paper's noise functions φ (§4.2) to
//! grad-artifact entry points and host-side "hat" (quantized image)
//! builders for the mix family.

use crate::quant::codebook::Codebook;
use crate::quant::pq;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NoiseKind {
    /// rate 0 through grad_mix with zero hats (no noise — baseline).
    None,
    /// φ_proxy: zero out selected blocks (structured dropout).
    Proxy,
    /// exact φ_PQ: blocks snap to their nearest codeword (hats refreshed
    /// by coordinator-side k-means once per epoch, per the paper).
    ExactPq,
    /// mean-subvector intermediate approximation (§4.2 / Table 5).
    MeanSub,
    /// φ_intN computed in-graph (per-tensor histogram-free minmax).
    Int8,
    Int4,
    /// per-channel intN variants (Table 10).
    Int8Channel,
    Int4Channel,
}

impl NoiseKind {
    /// Which grad entry point implements this noise.
    pub fn entry(&self) -> &'static str {
        match self {
            NoiseKind::None | NoiseKind::Proxy | NoiseKind::ExactPq | NoiseKind::MeanSub => {
                "grad_mix"
            }
            NoiseKind::Int8 => "grad_int8",
            NoiseKind::Int4 => "grad_int4",
            NoiseKind::Int8Channel => "grad_int8_channel",
            NoiseKind::Int4Channel => "grad_int4_channel",
        }
    }

    /// Does this kind need host-computed hat tensors?
    pub fn needs_hat(&self) -> bool {
        matches!(self, NoiseKind::ExactPq | NoiseKind::MeanSub)
    }

    pub fn parse(s: &str) -> Option<NoiseKind> {
        Some(match s {
            "none" => NoiseKind::None,
            "proxy" => NoiseKind::Proxy,
            "exact_pq" | "pq" => NoiseKind::ExactPq,
            "mean_sub" | "mean" => NoiseKind::MeanSub,
            "int8" => NoiseKind::Int8,
            "int4" => NoiseKind::Int4,
            "int8_channel" => NoiseKind::Int8Channel,
            "int4_channel" => NoiseKind::Int4Channel,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            NoiseKind::None => "none",
            NoiseKind::Proxy => "proxy",
            NoiseKind::ExactPq => "exact_pq",
            NoiseKind::MeanSub => "mean_sub",
            NoiseKind::Int8 => "int8",
            NoiseKind::Int4 => "int4",
            NoiseKind::Int8Channel => "int8_channel",
            NoiseKind::Int4Channel => "int4_channel",
        }
    }
}

/// Build the mix-family hat for one weight's canonical 2-D view.
/// `codebook` is required for `ExactPq` (the epoch's k-means result).
pub fn build_hat(
    kind: NoiseKind,
    w: &[f32],
    rows: usize,
    cols: usize,
    block_size: usize,
    codebook: Option<&Codebook>,
) -> Vec<f32> {
    match kind {
        NoiseKind::None | NoiseKind::Proxy => vec![0.0; w.len()],
        NoiseKind::MeanSub => pq::mean_subvector_hat(w, rows, cols, block_size),
        NoiseKind::ExactPq => {
            let cb = codebook.expect("ExactPq noise needs a codebook");
            assert_eq!(cb.d, block_size, "codebook dim mismatch");
            // encode on the shared engine and decode straight into the
            // hat buffer — no codebook clone, no temporary PqMatrix
            let codes = pq::encode(w, rows, cols, cb);
            let mut hat = vec![0.0f32; w.len()];
            pq::decode_codes_into(cb, &codes, &mut hat);
            hat
        }
        _ => panic!("{kind:?} noise is computed in-graph; no host hat"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pq::{fit, PqConfig};
    use crate::util::rng::Pcg;

    #[test]
    fn entry_mapping() {
        assert_eq!(NoiseKind::Proxy.entry(), "grad_mix");
        assert_eq!(NoiseKind::Int4Channel.entry(), "grad_int4_channel");
        assert!(!NoiseKind::Proxy.needs_hat());
        assert!(NoiseKind::ExactPq.needs_hat());
    }

    #[test]
    fn parse_roundtrip() {
        for k in [
            NoiseKind::None,
            NoiseKind::Proxy,
            NoiseKind::ExactPq,
            NoiseKind::MeanSub,
            NoiseKind::Int8,
            NoiseKind::Int4,
            NoiseKind::Int8Channel,
            NoiseKind::Int4Channel,
        ] {
            assert_eq!(NoiseKind::parse(k.name()), Some(k));
        }
        assert_eq!(NoiseKind::parse("bogus"), None);
    }

    #[test]
    fn proxy_hat_is_zero() {
        let w = vec![1.0f32; 64];
        assert!(build_hat(NoiseKind::Proxy, &w, 8, 8, 4, None)
            .iter()
            .all(|&x| x == 0.0));
    }

    #[test]
    fn exact_pq_hat_equals_decode() {
        let mut rng = Pcg::new(1);
        let w: Vec<f32> = (0..256).map(|_| rng.next_normal()).collect();
        let cfg = PqConfig { block_size: 8, n_centroids: 8, kmeans_iters: 8, threads: 0 };
        let m = fit(&w, 16, 16, &cfg, &mut Pcg::new(2));
        let hat = build_hat(NoiseKind::ExactPq, &w, 16, 16, 8, Some(&m.codebook));
        assert_eq!(hat, m.decode());
    }

    #[test]
    #[should_panic(expected = "in-graph")]
    fn int_kinds_have_no_host_hat() {
        build_hat(NoiseKind::Int8, &[0.0; 8], 1, 8, 8, None);
    }

    #[test]
    fn exact_pq_hat_deterministic_for_fixed_seed() {
        // fixed-seed regression: the hat refresh path must be
        // byte-stable run to run (sharding must not leak into results)
        let mut rng = Pcg::new(9);
        let w: Vec<f32> = (0..32 * 32).map(|_| rng.next_normal()).collect();
        let cfg = PqConfig { block_size: 8, n_centroids: 16, kmeans_iters: 6, threads: 0 };
        let m = fit(&w, 32, 32, &cfg, &mut Pcg::new(4));
        let a = build_hat(NoiseKind::ExactPq, &w, 32, 32, 8, Some(&m.codebook));
        let b = build_hat(NoiseKind::ExactPq, &w, 32, 32, 8, Some(&m.codebook));
        assert_eq!(a, b);
        // and a differently-sharded fit of the same seed agrees too
        let cfg1 = PqConfig { threads: 1, ..cfg };
        let m1 = fit(&w, 32, 32, &cfg1, &mut Pcg::new(4));
        let c = build_hat(NoiseKind::ExactPq, &w, 32, 32, 8, Some(&m1.codebook));
        assert_eq!(a, c);
    }

    #[test]
    fn trainer_hat_path_equals_reencode_path() {
        // Trainer::refresh_hats decodes the k-means assignments
        // directly into the hat buffer; the seed's path re-encoded the
        // weights against the fitted codebook first. Both run the same
        // engine kernel, so the hats must be byte-identical.
        use crate::quant::kmeans::{kmeans, KmeansConfig};
        use crate::quant::pq::decode_codes_into;
        let mut rng = Pcg::new(5);
        let w: Vec<f32> = (0..48 * 32).map(|_| rng.next_normal()).collect();
        let km = kmeans(
            &w,
            8,
            &KmeansConfig { k: 16, max_iters: 6, ..Default::default() },
            &mut Pcg::new(6),
        );
        let cb = Codebook::new(km.centroids.clone(), km.k, 8);
        let mut direct = vec![0.0f32; w.len()];
        decode_codes_into(&cb, &km.assignments, &mut direct);
        let reencoded = build_hat(NoiseKind::ExactPq, &w, 48, 32, 8, Some(&cb));
        assert_eq!(direct, reencoded);
    }
}
