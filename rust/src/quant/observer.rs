//! Range observers for scalar quantization (paper §7.7).
//!
//! * [`MinMaxObserver`] — running min/max (the baseline scheme).
//! * [`HistogramObserver`] — accumulates a histogram and searches the
//!   clip range (lo, hi) that approximately minimizes the L2
//!   quantization error, "a refinement of the MinMax scheme" exactly as
//!   the paper describes PyTorch's Histogram method.

use crate::quant::scalar::QParams;

#[derive(Debug, Clone, Default)]
pub struct MinMaxObserver {
    lo: f32,
    hi: f32,
    seen: bool,
}

impl MinMaxObserver {
    pub fn new() -> Self {
        MinMaxObserver { lo: 0.0, hi: 0.0, seen: false }
    }

    pub fn observe(&mut self, data: &[f32]) {
        for &x in data {
            if !self.seen {
                self.lo = x;
                self.hi = x;
                self.seen = true;
            } else {
                self.lo = self.lo.min(x);
                self.hi = self.hi.max(x);
            }
        }
    }

    pub fn range(&self) -> (f32, f32) {
        (self.lo, self.hi)
    }

    pub fn qparams(&self, bits: u8) -> QParams {
        QParams::from_range(self.lo, self.hi, bits)
    }
}

#[derive(Debug, Clone)]
pub struct HistogramObserver {
    pub bins: Vec<f64>,
    pub lo: f32,
    pub hi: f32,
    seen: bool,
    n_bins: usize,
}

impl HistogramObserver {
    pub fn new(n_bins: usize) -> Self {
        HistogramObserver { bins: vec![0.0; n_bins], lo: 0.0, hi: 0.0, seen: false, n_bins }
    }

    /// Observe a batch. If the data range grows, the existing histogram
    /// is re-binned into the wider range (mass-preserving).
    pub fn observe(&mut self, data: &[f32]) {
        if data.is_empty() {
            return;
        }
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &x in data {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if !self.seen {
            self.lo = lo;
            self.hi = hi.max(lo + 1e-12);
            self.seen = true;
        } else if lo < self.lo || hi > self.hi {
            let new_lo = self.lo.min(lo);
            let new_hi = self.hi.max(hi);
            self.rebin(new_lo, new_hi);
        }
        let width = (self.hi - self.lo).max(1e-12);
        for &x in data {
            let b = (((x - self.lo) / width) * self.n_bins as f32) as usize;
            self.bins[b.min(self.n_bins - 1)] += 1.0;
        }
    }

    fn rebin(&mut self, new_lo: f32, new_hi: f32) {
        let mut new_bins = vec![0.0; self.n_bins];
        let old_width = (self.hi - self.lo).max(1e-12) / self.n_bins as f32;
        let new_width = (new_hi - new_lo).max(1e-12) / self.n_bins as f32;
        for (i, &mass) in self.bins.iter().enumerate() {
            if mass == 0.0 {
                continue;
            }
            let center = self.lo + (i as f32 + 0.5) * old_width;
            let b = (((center - new_lo) / new_width) as usize).min(self.n_bins - 1);
            new_bins[b] += mass;
        }
        self.bins = new_bins;
        self.lo = new_lo;
        self.hi = new_hi;
    }

    /// Expected squared quantization error for a candidate clip range:
    /// each bin's mass incurs the *actual* round-trip error of its bin
    /// center under QParams(lo, hi) — clipping and rounding both fall
    /// out of the same formula, and concentrated distributions (where a
    /// uniform s²/12 model is badly wrong) are handled correctly.
    fn l2_error(&self, clip_lo: f32, clip_hi: f32, bits: u8) -> f64 {
        let qp = QParams::from_range(clip_lo, clip_hi, bits);
        let bin_w = ((self.hi - self.lo) as f64 / self.n_bins as f64).max(1e-18);
        let mut err = 0.0f64;
        for (i, &mass) in self.bins.iter().enumerate() {
            if mass == 0.0 {
                continue;
            }
            let center = (self.lo as f64 + (i as f64 + 0.5) * bin_w) as f32;
            let e = (center - qp.roundtrip_one(center)) as f64;
            err += mass * e * e;
        }
        err
    }

    /// Search a shrinking family of clip ranges for the L2-minimizing
    /// one (grid over symmetric trims of the observed range).
    pub fn best_range(&self, bits: u8) -> (f32, f32) {
        if !self.seen {
            return (0.0, 0.0);
        }
        let width = self.hi - self.lo;
        let mut best = (self.lo, self.hi);
        let mut best_err = self.l2_error(self.lo, self.hi, bits);
        let steps = 64;
        for i in 0..steps {
            for j in 0..steps {
                if i + j >= steps {
                    break;
                }
                let lo = self.lo + width * (i as f32 / steps as f32) * 0.5;
                let hi = self.hi - width * (j as f32 / steps as f32) * 0.5;
                if hi <= lo {
                    continue;
                }
                let err = self.l2_error(lo, hi, bits);
                if err < best_err {
                    best_err = err;
                    best = (lo, hi);
                }
            }
        }
        best
    }

    pub fn qparams(&self, bits: u8) -> QParams {
        let (lo, hi) = self.best_range(bits);
        QParams::from_range(lo, hi, bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scalar::quant_mse;
    use crate::util::rng::Pcg;

    fn heavy_tail(seed: u64, n: usize) -> Vec<f32> {
        // mostly N(0,1) with a few large outliers — histogram should clip
        let mut r = Pcg::new(seed);
        (0..n)
            .map(|i| {
                if i % 97 == 0 {
                    r.next_normal() * 30.0
                } else {
                    r.next_normal()
                }
            })
            .collect()
    }

    #[test]
    fn minmax_tracks_range() {
        let mut o = MinMaxObserver::new();
        o.observe(&[1.0, -2.0]);
        o.observe(&[5.0]);
        assert_eq!(o.range(), (-2.0, 5.0));
    }

    #[test]
    fn histogram_beats_minmax_on_outliers() {
        let data = heavy_tail(1, 20_000);
        let mut mm = MinMaxObserver::new();
        mm.observe(&data);
        let mut h = HistogramObserver::new(2048);
        h.observe(&data);
        let mse_mm = quant_mse(&data, &mm.qparams(4));
        let mse_h = quant_mse(&data, &h.qparams(4));
        assert!(mse_h < mse_mm, "hist {mse_h} vs minmax {mse_mm}");
    }

    #[test]
    fn histogram_matches_minmax_on_uniform() {
        // No outliers: clipping should not help much; hist ≤ ~2× minmax.
        let mut r = Pcg::new(2);
        let data: Vec<f32> = (0..10_000).map(|_| r.next_f32() * 2.0 - 1.0).collect();
        let mut h = HistogramObserver::new(2048);
        h.observe(&data);
        let mut mm = MinMaxObserver::new();
        mm.observe(&data);
        let mse_h = quant_mse(&data, &h.qparams(8));
        let mse_mm = quant_mse(&data, &mm.qparams(8));
        assert!(mse_h <= mse_mm * 2.0 + 1e-12, "{mse_h} vs {mse_mm}");
    }

    #[test]
    fn rebin_preserves_mass() {
        let mut h = HistogramObserver::new(128);
        h.observe(&[0.0, 0.5, 1.0]);
        let before: f64 = h.bins.iter().sum();
        h.observe(&[10.0]); // forces rebin
        let after: f64 = h.bins.iter().sum();
        assert_eq!(before + 1.0, after);
        assert_eq!(h.hi, 10.0);
    }

    #[test]
    fn best_range_within_observed() {
        let data = heavy_tail(3, 5_000);
        let mut h = HistogramObserver::new(512);
        h.observe(&data);
        let (lo, hi) = h.best_range(8);
        assert!(lo >= h.lo - 1e-6 && hi <= h.hi + 1e-6 && lo < hi);
    }

    #[test]
    fn empty_observer_safe() {
        let h = HistogramObserver::new(64);
        assert_eq!(h.best_range(8), (0.0, 0.0));
        let qp = h.qparams(8);
        assert_eq!(qp.scale, 1.0); // degenerate fallback
    }
}
