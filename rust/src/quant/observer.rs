//! Range observers for scalar quantization (paper §7.7).
//!
//! * [`MinMaxObserver`] — running min/max (the baseline scheme).
//! * [`HistogramObserver`] — accumulates a histogram and searches the
//!   clip range (lo, hi) that approximately minimizes the L2
//!   quantization error, "a refinement of the MinMax scheme" exactly as
//!   the paper describes PyTorch's Histogram method. Binning is an
//!   embarrassingly parallel scan: [`HistogramObserver::observe_sharded`]
//!   shards it across scoped workers with the same shape as the
//!   `quant::assign` engine — bin counts are integer-valued f64s, so
//!   the ascending-shard merge is *exactly* the serial result.

use crate::quant::assign;
use crate::quant::scalar::QParams;

/// Below this many elements the sharded observe falls back to the
/// serial scan (thread spawn would dominate).
const SHARD_MIN: usize = 1 << 15;

#[derive(Debug, Clone, Default)]
pub struct MinMaxObserver {
    lo: f32,
    hi: f32,
    seen: bool,
}

impl MinMaxObserver {
    pub fn new() -> Self {
        MinMaxObserver { lo: 0.0, hi: 0.0, seen: false }
    }

    pub fn observe(&mut self, data: &[f32]) {
        for &x in data {
            if !self.seen {
                self.lo = x;
                self.hi = x;
                self.seen = true;
            } else {
                self.lo = self.lo.min(x);
                self.hi = self.hi.max(x);
            }
        }
    }

    pub fn range(&self) -> (f32, f32) {
        (self.lo, self.hi)
    }

    pub fn qparams(&self, bits: u8) -> QParams {
        QParams::from_range(self.lo, self.hi, bits)
    }
}

#[derive(Debug, Clone)]
pub struct HistogramObserver {
    pub bins: Vec<f64>,
    pub lo: f32,
    pub hi: f32,
    seen: bool,
    n_bins: usize,
}

impl HistogramObserver {
    pub fn new(n_bins: usize) -> Self {
        HistogramObserver { bins: vec![0.0; n_bins], lo: 0.0, hi: 0.0, seen: false, n_bins }
    }

    /// Observe a batch. If the data range grows, the existing histogram
    /// is re-binned into the wider range (mass-preserving).
    pub fn observe(&mut self, data: &[f32]) {
        if data.is_empty() {
            return;
        }
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &x in data {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if !self.seen {
            self.lo = lo;
            self.hi = hi.max(lo + 1e-12);
            self.seen = true;
        } else if lo < self.lo || hi > self.hi {
            let new_lo = self.lo.min(lo);
            let new_hi = self.hi.max(hi);
            self.rebin(new_lo, new_hi);
        }
        let width = (self.hi - self.lo).max(1e-12);
        for &x in data {
            let b = (((x - self.lo) / width) * self.n_bins as f32) as usize;
            self.bins[b.min(self.n_bins - 1)] += 1.0;
        }
    }

    /// [`HistogramObserver::observe`] sharded across `threads` scoped
    /// workers (0 ⇒ all cores): parallel min/max scan, then per-shard
    /// local histograms merged in ascending shard order. Bit-identical
    /// to the serial scan — bin indices are computed per element by the
    /// same arithmetic, and counts are exact small integers in f64, so
    /// neither sharding nor merge order can change any bin.
    pub fn observe_sharded(&mut self, data: &[f32], threads: usize) {
        let threads = assign::resolve_threads(threads);
        if data.len() < SHARD_MIN || threads <= 1 {
            self.observe(data);
            return;
        }
        let chunk = data.len().div_ceil(threads);
        // pass 1: global range (min/max fold is order-insensitive)
        let (lo, hi) = std::thread::scope(|s| {
            let handles: Vec<_> = data
                .chunks(chunk)
                .map(|c| {
                    s.spawn(move || {
                        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                        for &x in c {
                            lo = lo.min(x);
                            hi = hi.max(x);
                        }
                        (lo, hi)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).fold(
                (f32::INFINITY, f32::NEG_INFINITY),
                |(alo, ahi), (lo, hi)| (alo.min(lo), ahi.max(hi)),
            )
        });
        // identical range bookkeeping to the serial observe
        if !self.seen {
            self.lo = lo;
            self.hi = hi.max(lo + 1e-12);
            self.seen = true;
        } else if lo < self.lo || hi > self.hi {
            let new_lo = self.lo.min(lo);
            let new_hi = self.hi.max(hi);
            self.rebin(new_lo, new_hi);
        }
        // pass 2: per-shard local histograms, merged in shard order
        let (slo, width) = (self.lo, (self.hi - self.lo).max(1e-12));
        let n_bins = self.n_bins;
        let parts: Vec<Vec<f64>> = std::thread::scope(|s| {
            let handles: Vec<_> = data
                .chunks(chunk)
                .map(|c| {
                    s.spawn(move || {
                        let mut bins = vec![0.0f64; n_bins];
                        for &x in c {
                            let b = (((x - slo) / width) * n_bins as f32) as usize;
                            bins[b.min(n_bins - 1)] += 1.0;
                        }
                        bins
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for part in parts {
            for (b, v) in self.bins.iter_mut().zip(part) {
                *b += v;
            }
        }
    }

    fn rebin(&mut self, new_lo: f32, new_hi: f32) {
        let mut new_bins = vec![0.0; self.n_bins];
        let old_width = (self.hi - self.lo).max(1e-12) / self.n_bins as f32;
        let new_width = (new_hi - new_lo).max(1e-12) / self.n_bins as f32;
        for (i, &mass) in self.bins.iter().enumerate() {
            if mass == 0.0 {
                continue;
            }
            let center = self.lo + (i as f32 + 0.5) * old_width;
            let b = (((center - new_lo) / new_width) as usize).min(self.n_bins - 1);
            new_bins[b] += mass;
        }
        self.bins = new_bins;
        self.lo = new_lo;
        self.hi = new_hi;
    }

    /// Expected squared quantization error for a candidate clip range:
    /// each bin's mass incurs the *actual* round-trip error of its bin
    /// center under QParams(lo, hi) — clipping and rounding both fall
    /// out of the same formula, and concentrated distributions (where a
    /// uniform s²/12 model is badly wrong) are handled correctly.
    fn l2_error(&self, clip_lo: f32, clip_hi: f32, bits: u8) -> f64 {
        let qp = QParams::from_range(clip_lo, clip_hi, bits);
        let bin_w = ((self.hi - self.lo) as f64 / self.n_bins as f64).max(1e-18);
        let mut err = 0.0f64;
        for (i, &mass) in self.bins.iter().enumerate() {
            if mass == 0.0 {
                continue;
            }
            let center = (self.lo as f64 + (i as f64 + 0.5) * bin_w) as f32;
            let e = (center - qp.roundtrip_one(center)) as f64;
            err += mass * e * e;
        }
        err
    }

    /// Search a shrinking family of clip ranges for the L2-minimizing
    /// one (grid over symmetric trims of the observed range).
    pub fn best_range(&self, bits: u8) -> (f32, f32) {
        if !self.seen {
            return (0.0, 0.0);
        }
        let width = self.hi - self.lo;
        let mut best = (self.lo, self.hi);
        let mut best_err = self.l2_error(self.lo, self.hi, bits);
        let steps = 64;
        for i in 0..steps {
            for j in 0..steps {
                if i + j >= steps {
                    break;
                }
                let lo = self.lo + width * (i as f32 / steps as f32) * 0.5;
                let hi = self.hi - width * (j as f32 / steps as f32) * 0.5;
                if hi <= lo {
                    continue;
                }
                let err = self.l2_error(lo, hi, bits);
                if err < best_err {
                    best_err = err;
                    best = (lo, hi);
                }
            }
        }
        best
    }

    pub fn qparams(&self, bits: u8) -> QParams {
        let (lo, hi) = self.best_range(bits);
        QParams::from_range(lo, hi, bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scalar::quant_mse;
    use crate::util::rng::Pcg;

    fn heavy_tail(seed: u64, n: usize) -> Vec<f32> {
        // mostly N(0,1) with a few large outliers — histogram should clip
        let mut r = Pcg::new(seed);
        (0..n)
            .map(|i| {
                if i % 97 == 0 {
                    r.next_normal() * 30.0
                } else {
                    r.next_normal()
                }
            })
            .collect()
    }

    #[test]
    fn minmax_tracks_range() {
        let mut o = MinMaxObserver::new();
        o.observe(&[1.0, -2.0]);
        o.observe(&[5.0]);
        assert_eq!(o.range(), (-2.0, 5.0));
    }

    #[test]
    fn histogram_beats_minmax_on_outliers() {
        let data = heavy_tail(1, 20_000);
        let mut mm = MinMaxObserver::new();
        mm.observe(&data);
        let mut h = HistogramObserver::new(2048);
        h.observe(&data);
        let mse_mm = quant_mse(&data, &mm.qparams(4));
        let mse_h = quant_mse(&data, &h.qparams(4));
        assert!(mse_h < mse_mm, "hist {mse_h} vs minmax {mse_mm}");
    }

    #[test]
    fn histogram_matches_minmax_on_uniform() {
        // No outliers: clipping should not help much; hist ≤ ~2× minmax.
        let mut r = Pcg::new(2);
        let data: Vec<f32> = (0..10_000).map(|_| r.next_f32() * 2.0 - 1.0).collect();
        let mut h = HistogramObserver::new(2048);
        h.observe(&data);
        let mut mm = MinMaxObserver::new();
        mm.observe(&data);
        let mse_h = quant_mse(&data, &h.qparams(8));
        let mse_mm = quant_mse(&data, &mm.qparams(8));
        assert!(mse_h <= mse_mm * 2.0 + 1e-12, "{mse_h} vs {mse_mm}");
    }

    #[test]
    fn sharded_observe_is_bit_identical_to_serial() {
        // above SHARD_MIN so the parallel path actually engages
        let data = heavy_tail(9, SHARD_MIN + 1234);
        for threads in [1usize, 2, 3, 8] {
            let mut serial = HistogramObserver::new(512);
            serial.observe(&data);
            let mut sharded = HistogramObserver::new(512);
            sharded.observe_sharded(&data, threads);
            assert_eq!(serial.bins, sharded.bins, "threads={threads}");
            assert_eq!(serial.lo.to_bits(), sharded.lo.to_bits());
            assert_eq!(serial.hi.to_bits(), sharded.hi.to_bits());
            // incremental observe after the sharded pass stays coherent
            serial.observe(&data[..100]);
            sharded.observe_sharded(&data[..100], threads); // serial fallback
            assert_eq!(serial.bins, sharded.bins);
        }
    }

    #[test]
    fn sharded_observe_rebins_like_serial() {
        let a = heavy_tail(10, SHARD_MIN + 17);
        let mut serial = HistogramObserver::new(128);
        let mut sharded = HistogramObserver::new(128);
        serial.observe(&[0.5, -0.5]);
        sharded.observe(&[0.5, -0.5]);
        // second batch widens the range ⇒ both must rebin identically
        serial.observe(&a);
        sharded.observe_sharded(&a, 4);
        assert_eq!(serial.bins, sharded.bins);
        assert_eq!(serial.best_range(8), sharded.best_range(8));
    }

    #[test]
    fn rebin_preserves_mass() {
        let mut h = HistogramObserver::new(128);
        h.observe(&[0.0, 0.5, 1.0]);
        let before: f64 = h.bins.iter().sum();
        h.observe(&[10.0]); // forces rebin
        let after: f64 = h.bins.iter().sum();
        assert_eq!(before + 1.0, after);
        assert_eq!(h.hi, 10.0);
    }

    #[test]
    fn best_range_within_observed() {
        let data = heavy_tail(3, 5_000);
        let mut h = HistogramObserver::new(512);
        h.observe(&data);
        let (lo, hi) = h.best_range(8);
        assert!(lo >= h.lo - 1e-6 && hi <= h.hi + 1e-6 && lo < hi);
    }

    #[test]
    fn empty_observer_safe() {
        let h = HistogramObserver::new(64);
        assert_eq!(h.best_range(8), (0.0, 0.0));
        let qp = h.qparams(8);
        assert_eq!(qp.scale, 1.0); // degenerate fallback
    }
}
