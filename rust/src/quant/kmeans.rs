//! k-means for PQ codebook learning (paper §3.2).
//!
//! k-means++ seeding, Lloyd iterations whose assignment step runs on
//! the shared [`crate::quant::assign`] engine (precomputed codeword
//! norms, blocked inner loops, scoped-thread sharding — rayon is not in
//! the offline registry), and empty-cluster re-seeding to the points
//! farthest from their centroid (the standard fix that keeps K
//! codewords live at extreme K/n ratios).

use crate::quant::assign;
use crate::util::rng::Pcg;

#[derive(Debug, Clone)]
pub struct KmeansResult {
    /// K × d centroids, row-major.
    pub centroids: Vec<f32>,
    pub k: usize,
    pub d: usize,
    /// Assignment of each input point to a centroid.
    pub assignments: Vec<u32>,
    /// Objective (sum of squared distances) after each iteration —
    /// must be non-increasing (tested).
    pub objective_history: Vec<f64>,
}

pub struct KmeansConfig {
    pub k: usize,
    pub max_iters: usize,
    /// Relative objective improvement below which we stop early.
    pub tol: f64,
    pub threads: usize,
}

impl Default for KmeansConfig {
    fn default() -> Self {
        KmeansConfig {
            k: 256,
            max_iters: 15,
            tol: 1e-5,
            // 0 ⇒ resolved to all cores by the assign engine
            threads: 0,
        }
    }
}

#[inline]
fn dist2(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// Assign each point to its nearest centroid via the shared engine;
/// returns (assignments, per-point squared distances, total objective).
///
/// The engine only picks the argmin; distances and the objective are
/// recomputed here with the exact `Σ(p−c)²` form in one sequential
/// O(n·d) pass. Two reasons: the engine's decomposed reconstruction
/// carries a cancellation error up to ~2⁻²⁴·‖p‖² that could mask tiny
/// true decreases late in Lloyd iterations (breaking the documented
/// non-increasing history), and the tol-based early stop must not
/// depend on thread-count-sensitive partial-sum association — this
/// way the whole trajectory is deterministic for a seed regardless of
/// sharding.
fn assign_step(
    points: &[f32],
    d: usize,
    centroids: &[f32],
    k: usize,
    threads: usize,
) -> (Vec<u32>, Vec<f32>, f64) {
    let codes = assign::assign_codes(points, d, centroids, k, threads);
    let mut dists = vec![0.0f32; codes.len()];
    let mut obj = 0.0f64;
    for (i, (&code, dv)) in codes.iter().zip(dists.iter_mut()).enumerate() {
        let p = &points[i * d..(i + 1) * d];
        let c = &centroids[code as usize * d..(code as usize + 1) * d];
        *dv = dist2(p, c);
        obj += *dv as f64;
    }
    (codes, dists, obj)
}

/// k-means++ seeding.
fn init_pp(points: &[f32], n: usize, d: usize, k: usize, rng: &mut Pcg) -> Vec<f32> {
    let mut centroids = Vec::with_capacity(k * d);
    let first = rng.below(n as u32) as usize;
    centroids.extend_from_slice(&points[first * d..(first + 1) * d]);
    let mut dists: Vec<f32> = (0..n)
        .map(|i| dist2(&points[i * d..(i + 1) * d], &centroids[0..d]))
        .collect();
    for _ in 1..k {
        let total: f64 = dists.iter().map(|&x| x as f64).sum();
        let next = if total <= 0.0 {
            rng.below(n as u32) as usize
        } else {
            let mut target = rng.next_f64() * total;
            let mut pick = n - 1;
            for (i, &w) in dists.iter().enumerate() {
                target -= w as f64;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        let c_start = centroids.len();
        centroids.extend_from_slice(&points[next * d..(next + 1) * d]);
        let c = centroids[c_start..c_start + d].to_vec();
        for i in 0..n {
            let dd = dist2(&points[i * d..(i + 1) * d], &c);
            if dd < dists[i] {
                dists[i] = dd;
            }
        }
    }
    centroids
}

/// Run k-means on `n` points of dimension `d` (row-major `points`).
/// If `n <= k`, every point becomes its own centroid (exact).
pub fn kmeans(points: &[f32], d: usize, cfg: &KmeansConfig, rng: &mut Pcg) -> KmeansResult {
    assert!(d > 0 && points.len() % d == 0);
    let n = points.len() / d;
    assert!(n > 0, "kmeans on empty input");
    let k = cfg.k.min(n);

    if n <= k {
        // exact: each point its own codeword; pad (never read) if n < k
        let mut centroids = points.to_vec();
        centroids.resize(k * d, 0.0);
        return KmeansResult {
            centroids,
            k,
            d,
            assignments: (0..n as u32).collect(),
            objective_history: vec![0.0],
        };
    }

    let mut centroids = init_pp(points, n, d, k, rng);
    let mut history = Vec::new();
    let mut last_obj = f64::INFINITY;
    let mut assignments = Vec::new();

    for _ in 0..cfg.max_iters {
        let (assign_now, dists, obj) = assign_step(points, d, &centroids, k, cfg.threads);
        assignments = assign_now;
        history.push(obj);

        // update step
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let a = assignments[i] as usize;
            counts[a] += 1;
            let p = &points[i * d..(i + 1) * d];
            for j in 0..d {
                sums[a * d + j] += p[j] as f64;
            }
        }
        // empty-cluster re-seeding: steal the farthest points
        let mut far: Vec<usize> = (0..n).collect();
        far.sort_by(|&a, &b| dists[b].partial_cmp(&dists[a]).unwrap());
        let mut steal = far.into_iter();
        for j in 0..k {
            if counts[j] == 0 {
                if let Some(p) = steal.next() {
                    let src = &points[p * d..(p + 1) * d];
                    centroids[j * d..(j + 1) * d].copy_from_slice(src);
                }
            } else {
                for t in 0..d {
                    centroids[j * d + t] = (sums[j * d + t] / counts[j] as f64) as f32;
                }
            }
        }

        if last_obj.is_finite() && (last_obj - obj).abs() <= cfg.tol * last_obj.abs() {
            break;
        }
        last_obj = obj;
    }
    // final assignment against the last update
    let (assignments_f, _d, obj) = assign_step(points, d, &centroids, k, cfg.threads);
    history.push(obj);
    let _ = assignments;
    KmeansResult { centroids, k, d, assignments: assignments_f, objective_history: history }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob_data(seed: u64, per_blob: usize, d: usize) -> Vec<f32> {
        // 4 well-separated gaussian blobs
        let mut rng = Pcg::new(seed);
        let mut pts = Vec::new();
        for b in 0..4 {
            let center = b as f32 * 10.0;
            for _ in 0..per_blob {
                for _ in 0..d {
                    pts.push(center + rng.next_normal() * 0.1);
                }
            }
        }
        pts
    }

    #[test]
    fn objective_nonincreasing() {
        let pts = blob_data(1, 100, 4);
        let mut rng = Pcg::new(2);
        let cfg = KmeansConfig { k: 8, max_iters: 20, tol: 0.0, threads: 2 };
        let r = kmeans(&pts, 4, &cfg, &mut rng);
        for w in r.objective_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-6 * w[0].abs().max(1.0), "{:?}", r.objective_history);
        }
    }

    #[test]
    fn finds_separated_blobs() {
        let pts = blob_data(3, 200, 2);
        let mut rng = Pcg::new(4);
        let cfg = KmeansConfig { k: 4, max_iters: 25, tol: 1e-9, threads: 4 };
        let r = kmeans(&pts, 2, &cfg, &mut rng);
        // objective should be tiny relative to data spread
        let final_obj = *r.objective_history.last().unwrap();
        assert!(final_obj / (pts.len() as f64) < 0.1, "{final_obj}");
    }

    #[test]
    fn exact_when_n_le_k() {
        let pts = vec![1.0f32, 2.0, 3.0, 4.0]; // 2 points, d=2
        let mut rng = Pcg::new(5);
        let cfg = KmeansConfig { k: 16, ..Default::default() };
        let r = kmeans(&pts, 2, &cfg, &mut rng);
        assert_eq!(r.assignments, vec![0, 1]);
        assert_eq!(*r.objective_history.last().unwrap(), 0.0);
    }

    #[test]
    fn assignments_are_nearest() {
        let pts = blob_data(6, 50, 3);
        let mut rng = Pcg::new(7);
        let cfg = KmeansConfig { k: 6, max_iters: 10, tol: 1e-7, threads: 3 };
        let r = kmeans(&pts, 3, &cfg, &mut rng);
        let n = pts.len() / 3;
        for i in 0..n {
            let p = &pts[i * 3..(i + 1) * 3];
            let assigned = dist2(p, &r.centroids[r.assignments[i] as usize * 3..][..3]);
            for j in 0..r.k {
                let dj = dist2(p, &r.centroids[j * 3..(j + 1) * 3]);
                assert!(assigned <= dj + 1e-5, "point {i}: {assigned} > {dj}");
            }
        }
    }

    #[test]
    fn no_empty_clusters_on_degenerate_data() {
        // all points identical except a couple — re-seeding must keep
        // assignments valid (all indices < k)
        let mut pts = vec![0.5f32; 64 * 2];
        pts[0] = 5.0;
        pts[3] = -5.0;
        let mut rng = Pcg::new(8);
        let cfg = KmeansConfig { k: 4, max_iters: 8, tol: 0.0, threads: 2 };
        let r = kmeans(&pts, 2, &cfg, &mut rng);
        assert!(r.assignments.iter().all(|&a| (a as usize) < r.k));
    }

    #[test]
    fn deterministic_given_seed() {
        let pts = blob_data(9, 60, 2);
        let cfg = KmeansConfig { k: 5, max_iters: 10, tol: 1e-7, threads: 2 };
        let a = kmeans(&pts, 2, &cfg, &mut Pcg::new(42));
        let b = kmeans(&pts, 2, &cfg, &mut Pcg::new(42));
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.assignments, b.assignments);
    }
}
