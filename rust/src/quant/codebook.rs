//! PQ codebook storage, including the intN-compressed variants of §3.3
//! (iPQ ⊕ int8: centroids stored as int8 codes, dividing the codebook
//! overhead by 4 while the index matrix stays log2(K) bits per block;
//! `cb=int4` halves the codebook term again at a higher centroid MSE).

use crate::quant::scalar::{self, QParams};

#[derive(Debug, Clone)]
pub struct Codebook {
    /// K × d codewords, row-major, fp32 (possibly already an intN
    /// round-trip if `quant` is set).
    pub centroids: Vec<f32>,
    pub k: usize,
    pub d: usize,
    /// Set when the centroids have been intN-quantized (affects
    /// storage accounting and marks that values lie on the intN grid;
    /// the bit width lives in [`QParams::bits`]).
    pub quant: Option<QParams>,
}

impl Codebook {
    pub fn new(centroids: Vec<f32>, k: usize, d: usize) -> Codebook {
        assert_eq!(centroids.len(), k * d);
        Codebook { centroids, k, d, quant: None }
    }

    #[inline]
    pub fn codeword(&self, j: usize) -> &[f32] {
        &self.centroids[j * self.d..(j + 1) * self.d]
    }

    pub fn codeword_mut(&mut self, j: usize) -> &mut [f32] {
        &mut self.centroids[j * self.d..(j + 1) * self.d]
    }

    /// Quantize the centroids themselves to intN (Eq. 2 over the whole
    /// codebook; `bits=8` is the paper's §3.3 combination). Returns the
    /// quantization MSE over centroid entries.
    pub fn compress(&mut self, bits: u8) -> f64 {
        let qp = QParams::from_minmax(&self.centroids, bits);
        let before = self.centroids.clone();
        scalar::roundtrip(&mut self.centroids, &qp);
        self.quant = Some(qp);
        before
            .iter()
            .zip(&self.centroids)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / before.len().max(1) as f64
    }

    /// Quantize the centroids to int8 (§3.3's iPQ ⊕ int8).
    pub fn compress_int8(&mut self) -> f64 {
        self.compress(8)
    }

    /// Codebook storage in bits: b·K·d when intN-compressed (Eq. 5's
    /// first term at b=8), else 32·K·d for fp32 centroids.
    pub fn storage_bits(&self) -> u64 {
        let per = self.quant.map_or(32u64, |q| u64::from(q.bits));
        per * (self.k * self.d) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn cb(seed: u64, k: usize, d: usize) -> Codebook {
        let mut r = Pcg::new(seed);
        Codebook::new((0..k * d).map(|_| r.next_normal()).collect(), k, d)
    }

    #[test]
    fn codeword_slicing() {
        let c = cb(1, 8, 4);
        assert_eq!(c.codeword(3), &c.centroids[12..16]);
    }

    #[test]
    fn int8_compression_shrinks_storage_4x() {
        let mut c = cb(2, 256, 8);
        let fp32 = c.storage_bits();
        let mse = c.compress_int8();
        assert_eq!(c.storage_bits() * 4, fp32);
        assert!(mse > 0.0); // lossy
        // error per entry bounded by s/2
        let qp = c.quant.unwrap();
        assert!(mse.sqrt() <= (qp.scale / 2.0) as f64 + 1e-6);
    }

    #[test]
    fn int4_compression_shrinks_storage_8x_at_higher_mse() {
        let fp32 = cb(2, 256, 8).storage_bits();
        let mut c8 = cb(2, 256, 8);
        let mse8 = c8.compress(8);
        let mut c4 = cb(2, 256, 8);
        let mse4 = c4.compress(4);
        assert_eq!(c4.storage_bits() * 8, fp32);
        assert_eq!(c4.quant.unwrap().bits, 4);
        // 16 grid points instead of 256: strictly coarser
        assert!(mse4 > mse8);
        let qp = c4.quant.unwrap();
        assert!(mse4.sqrt() <= (qp.scale / 2.0) as f64 + 1e-6);
    }

    #[test]
    fn int8_values_on_grid() {
        let mut c = cb(3, 16, 4);
        c.compress_int8();
        let qp = c.quant.unwrap();
        for &v in &c.centroids {
            // v must equal its own round-trip (already on the grid)
            assert!((v - qp.roundtrip_one(v)).abs() < 1e-6);
        }
    }
}
